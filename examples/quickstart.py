"""Quickstart: the paper's codesign workflow on one page.

1. Build a quantized model (QAT, arbitrary bit width)      [C1]
2. Train it on synthetic data                              [C9]
3. Fold BN + merge ReLU (training-time fusion)             [C3]
4. Streamline to an integer-only threshold graph           [C2]
5. Execute the deployed graph on the fused Pallas kernel   [C4]
6. Report BOPs / weight-memory / roofline latency          [C7]

Run: PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bops import ModelCost, dense_cost
from repro.core.codesign import deploy_report, train_tiny
from repro.core.qlayers import QDense, QDenseBatchNorm
from repro.core.streamline import streamline_mlp
from repro.kernels import ops

# --- 1. a 4-bit MLP classifier (QDense+BN stages, merged ReLU) -------------
DIMS, N_CLASSES, BITS = [32, 24, 16], 4, 4
layers = [QDenseBatchNorm(DIMS[i], DIMS[i + 1], weight_bits=BITS,
                          act_bits=BITS) for i in range(len(DIMS) - 1)]
head = QDense(DIMS[-1], N_CLASSES, weight_bits=32, act_bits=32)

key = jax.random.PRNGKey(0)
params = {"hidden": [l.init(k) for l, k in zip(layers, jax.random.split(key, 2))],
          "head": head.init(jax.random.fold_in(key, 9))}

# --- 2. train on a synthetic 4-class problem --------------------------------
protos = jax.random.normal(jax.random.PRNGKey(7), (N_CLASSES, DIMS[0])) * 2


def make_batch(step):
    k = jax.random.PRNGKey(step)
    y = jax.random.randint(k, (64,), 0, N_CLASSES)
    x = protos[y] + 0.5 * jax.random.normal(jax.random.fold_in(k, 1),
                                            (64, DIMS[0]))
    return x, y


def forward(ps, x, train=False):
    h, new_hidden = x, []
    for l, p in zip(layers, ps["hidden"]):
        h, p = l.apply(p, h, train=train)
        new_hidden.append(p)
    return head.apply(ps["head"], h, train=train), new_hidden


def loss_fn(ps, batch):
    x, y = batch
    logits, _ = forward(ps, x)
    return jnp.mean(jax.nn.logsumexp(logits, -1)
                    - jnp.take_along_axis(logits, y[:, None], 1)[:, 0])


params, losses = train_tiny(loss_fn, params, make_batch, steps=150, lr=3e-3)
print(f"[2] QAT training: loss {losses[0]:.3f} -> {losses[-1]:.3f}")

# --- 3. BN statistics warm-up (the fold uses running stats) -----------------
for s in range(5):
    x, _ = make_batch(500 + s)
    _, params["hidden"] = forward(params, x, train=True)

# --- 4. streamline: float graph -> integer thresholds -----------------------
IN_SCALE = 0.1
smlp = streamline_mlp(layers, params["hidden"], IN_SCALE, params["head"])
print(f"[4] streamlined: {len(smlp.stages)} integer threshold stages, "
      f"out_scales={[f'{s.out_scale:.4f}' for s in smlp.stages]}")

# --- 5. run the deployed graph, once in jnp and once on the Pallas kernel ---
x, y = make_batch(9_999)
x_int = jnp.clip(jnp.round(x / IN_SCALE), -127, 127).astype(jnp.int8)

h = x_int.astype(jnp.int32)
for st in smlp.stages:
    h = ops.threshold_matmul(h.astype(jnp.int8), st.w_int, st.thresholds,
                             block_m=32, block_n=8, block_k=8)
logits = (h.astype(jnp.float32) @ smlp.head_w * smlp.stages[-1].out_scale
          + smlp.head_b)
acc_kernel = float((jnp.argmax(logits, -1) == y).mean())
acc_float = float((jnp.argmax(forward(params, x)[0], -1) == y).mean())
print(f"[5] accuracy: float QAT graph {acc_float:.1%} | "
      f"integer Pallas deployment {acc_kernel:.1%}")

# --- 6. hardware cost report -------------------------------------------------
cost = ModelCost([dense_cost(f"fc{i}", DIMS[i], DIMS[i + 1], BITS, BITS)
                  for i in range(len(DIMS) - 1)]
                 + [dense_cost("head", DIMS[-1], N_CLASSES, 8, 8)])
rep = deploy_report(cost, batch=1, bits=BITS)
print(f"[6] BOPs={cost.bops:.2e}  WM={cost.wm_bits} bits  "
      f"roofline latency={rep['latency_us']:.2f}us ({rep['bound']}-bound)  "
      f"energy={rep['energy_uJ']:.2f}uJ")
