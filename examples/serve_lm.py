"""Batched serving example: continuous-batching engine over a reduced LM,
with the paper's deployment quantization (int8 weights) switchable.

Run: PYTHONPATH=src python examples/serve_lm.py [--quant] [--requests 8]
"""

import argparse
import time

import numpy as np
import jax

from repro.configs import get_config
from repro.models.model import Model
from repro.serving.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--quant", action="store_true",
                    help="serve int8-quantized weights (paper C1 deployment)")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if args.quant:
        params = model.quantize_params(params, bits=8)
        print("serving int8-quantized weights")

    eng = ServeEngine(model, params, n_slots=args.slots, max_len=64)
    rng = np.random.default_rng(0)
    t0 = time.monotonic()
    for i in range(args.requests):
        plen = int(rng.integers(3, 10))
        eng.submit(Request(uid=i,
                           prompt=rng.integers(0, cfg.vocab, plen).astype(np.int32),
                           max_new_tokens=args.max_new))
    steps = eng.run_until_drained()
    dt = time.monotonic() - t0

    s = eng.stats()
    print(f"drained {s['n_requests']} requests in {steps} engine steps, "
          f"{dt:.2f}s wall")
    print(f"mean TTFT {s['mean_ttft_s']*1e3:.1f} ms | mean latency "
          f"{s['mean_latency_s']*1e3:.1f} ms | throughput "
          f"{s['throughput_tok_s']:.1f} tok/s")
    for r in eng.finished[:3]:
        print(f"  req {r.uid}: prompt[{len(r.prompt)}] -> {r.output}")


if __name__ == "__main__":
    main()
