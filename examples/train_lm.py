"""End-to-end LM training driver: a ~100M-param llama3-family model trained
for a few hundred steps on the synthetic token stream, with the full
production stack — sharded data pipeline, fault-tolerant loop, atomic
checkpointing, QAT weight fake-quant optional.

Scaled for this CPU container by default (--preset cpu: ~3M params, 200
steps, minutes); --preset 100m builds the real ~100M config (what you'd run
on a TPU slice with the same code).

Run: PYTHONPATH=src python examples/train_lm.py [--steps 200] [--preset cpu]
     # kill it mid-run and re-run: it resumes from the last checkpoint.
"""

import argparse
import dataclasses
import logging
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.pipeline import DataPipeline
from repro.data.synthetic import SyntheticTokens
from repro.models.model import Model
from repro.optim.adamw import make_optimizer
from repro.train.loop import LoopConfig, run_training
from repro.train.steps import TrainState, make_train_step

logging.basicConfig(level=logging.INFO, format="%(message)s")


def build_cfg(preset: str):
    base = get_config("llama3-8b")
    if preset == "100m":
        # ~100M params: 12L x 512d x 8H, 16k vocab
        return dataclasses.replace(
            base, name="llama3-100m", n_layers=12, d_model=512, n_heads=8,
            n_kv_heads=4, d_ff=1792, vocab=16384, head_dim=64,
            dtype="float32", remat="none")
    # cpu preset: small enough to run 200 steps in minutes
    return dataclasses.replace(
        base, name="llama3-cpu", n_layers=4, d_model=128, n_heads=4,
        n_kv_heads=2, d_ff=512, vocab=2048, head_dim=32,
        dtype="float32", remat="none", weight_bits=8)   # QAT on


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--preset", choices=["cpu", "100m"], default="cpu")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    cfg = build_cfg(args.preset)
    model = Model(cfg)
    print(f"arch={cfg.name}  params={cfg.n_params()/1e6:.1f}M  "
          f"weight_bits={cfg.weight_bits} (QAT {'on' if cfg.weight_bits < 16 else 'off'})")

    params = model.init(jax.random.PRNGKey(0))
    opt = make_optimizer(base_lr=3e-4, warmup=20, total=args.steps)
    state = TrainState(params=params, opt=opt.init(params))
    train_step = jax.jit(make_train_step(model, opt,
                                         microbatches=args.microbatches),
                         donate_argnums=(0,))

    data = SyntheticTokens(vocab=cfg.vocab, seq_len=args.seq)

    with DataPipeline(lambda s: data.batch(s, args.batch)) as pipe:
        it = iter(pipe)

        def batch_fn(step):
            # pipeline is keyed by step; keep it aligned on resume
            while True:
                s, b = next(it)
                if s >= step:
                    return {k: jnp.asarray(v) for k, v in b.items()}

        lcfg = LoopConfig(total_steps=args.steps, ckpt_every=50,
                          ckpt_dir=args.ckpt_dir, log_every=10)
        t0 = time.time()
        res = run_training(train_step, state, batch_fn, lcfg)
        dt = time.time() - t0

    first = res.metrics_history[0]["loss"] if res.metrics_history else float("nan")
    last = res.metrics_history[-1]["loss"] if res.metrics_history else float("nan")
    toks = args.batch * args.seq * (res.final_step - (res.resumed_from or 0))
    print(f"\ndone: steps={res.final_step} resumed_from={res.resumed_from} "
          f"loss {first:.3f} -> {last:.3f}")
    print(f"throughput: {toks/dt:.0f} tok/s on {jax.device_count()} device(s)")
    assert last < first, "loss must decrease"


if __name__ == "__main__":
    main()
