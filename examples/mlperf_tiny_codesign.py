"""The paper's full §5 methodology, end to end, on the AD benchmark task:

  float baseline -> hardware-aware NAS (ASHA, scored by quality + BOPs)
  -> bit-width descent (smallest width retaining quality, Fig. 4 procedure)
  -> QONNX-style export -> **compiled deployment** (repro.deploy: QIR ->
  streamlined integer stages -> jit executor) -> MLPerf-Tiny scenario run.

Run: PYTHONPATH=src python examples/mlperf_tiny_codesign.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bops import dense_cost, ModelCost
from repro.core.codesign import bitwidth_descent, deploy_report, train_tiny
from repro.core.qir import export_qmlp
from repro.core.search import Choice, asha_search
from repro.data.synthetic import SyntheticMelWindows
from repro.models.tiny import ADAutoencoder

DATA = SyntheticMelWindows(dim=64, rank=8, seed=0)


def _auc(scores, labels):
    order = np.argsort(scores)
    ranks = np.empty_like(order, dtype=float)
    ranks[order] = np.arange(len(scores))
    pos = labels == 1
    n_pos, n_neg = int(pos.sum()), int((~pos).sum())
    return (ranks[pos].sum() - n_pos * (n_pos - 1) / 2) / max(n_pos * n_neg, 1)


def train_eval(width, bottleneck, bits, steps):
    model = ADAutoencoder(in_dim=64, width=width, bottleneck=bottleneck,
                          weight_bits=bits, act_bits=bits)
    params = model.init(jax.random.PRNGKey(width * 31 + bits))

    def loss_fn(ps, x):
        recon, _ = model.apply(ps, x, train=False)
        return jnp.mean(jnp.square(recon - x))

    params, _ = train_tiny(loss_fn, params,
                           lambda s: jnp.asarray(DATA.batch(s, 64)[0]),
                           steps=steps, lr=2e-3)
    x, y = DATA.batch(10_000, 300, anomaly_frac=0.25)
    auc = _auc(np.asarray(model.anomaly_score(params, jnp.asarray(x))), y)
    return auc, model, params


def model_bops(width, bottleneck, bits):
    dims = [64, width, width, bottleneck, width, width, 64]
    return ModelCost([dense_cost(f"fc{i}", dims[i], dims[i + 1], bits, bits)
                      for i in range(6)])


# --- 1. float baseline -------------------------------------------------------
print("[1] float baseline (width=96, bottleneck=8)")
auc_ref, _, _ = train_eval(96, 8, 32, steps=100)
print(f"    reference AUC = {auc_ref:.3f}")

# --- 2. ASHA NAS scored by quality-per-cost ---------------------------------
print("[2] ASHA architecture search (quality - cost penalty)")
ref_cost = model_bops(96, 8, 32)


def objective(cfg, budget, rng):
    auc, _, _ = train_eval(cfg["width"], cfg["bottleneck"], 32,
                           steps=20 * budget)
    c = model_bops(cfg["width"], cfg["bottleneck"], 32).cost_vs(ref_cost)
    return auc - 0.05 * c


space = [Choice("width", (24, 48, 72)), Choice("bottleneck", (4, 8, 16))]
best, trials = asha_search(objective, space, n_trials=6, r_min=1, eta=2,
                           max_rung=2, seed=0)
W, B = best.config["width"], best.config["bottleneck"]
print(f"    chosen: width={W} bottleneck={B} (score {best.score:.3f}, "
      f"{sum(t.budget_used for t in trials)} budget units)")

# --- 3. bit-width descent (Fig. 4 procedure) ---------------------------------
print("[3] bit-width descent")


def eval_at_bits(bits):
    auc, _, _ = train_eval(W, B, bits, steps=80)
    return auc, model_bops(W, B, bits).bops


scan = bitwidth_descent(eval_at_bits, bit_ladder=(32, 8, 6, 4, 3),
                        tolerance=0.03)
for e in scan.entries:
    print(f"    W{e['bits']}A{e['bits']}: AUC={e['quality']:.3f} "
          f"BOPs={e['bops']:.2e}")
print(f"    chosen bits = {scan.chosen_bits}")

# --- 4. final train + QONNX-style export + deploy report ---------------------
print("[4] final model, QIR export, deploy report")
auc, model, params = train_eval(W, B, scan.chosen_bits, steps=150)
hidden_defs, _ = model.layers()
graph = export_qmlp(hidden_defs, params["hidden"], params["head"],
                    meta={"task": "AD", "bits": scan.chosen_bits})
path = "/tmp/ad_model.qir.json"
graph.save(path)
rep = deploy_report(model_bops(W, B, scan.chosen_bits), batch=1,
                    bits=scan.chosen_bits)
print(f"    AUC={auc:.3f}  exported {len(graph.nodes)} QIR nodes -> {path}")
print(f"    deploy: latency={rep['latency_us']:.2f}us "
      f"energy={rep['energy_uJ']:.2f}uJ ({rep['bound']}-bound)  "
      f"params={rep['params']}")

# --- 5. compile the exported graph and measure it under MLPerf load ----------
print("[5] compiled deployment (QIR -> fused integer stages -> jit)")
from repro.core.qir import Graph
from repro.deploy import compile_graph
from repro.deploy.scenarios import offline as offline_scenario
from repro.deploy.scenarios import single_stream

IN_SCALE = 1.0 / 127.0
compiled = compile_graph(Graph.load(path), in_scale=IN_SCALE,
                         use_pallas=False)
for line in compiled.schedule.describe().splitlines():
    print(f"    {line}")

rng = np.random.default_rng(0)
mk = lambda i: rng.integers(-127, 128, (64,)).astype(np.int32)
cost = model_bops(W, B, scan.chosen_bits)
ss = single_stream(compiled.offline, mk, n_queries=32,
                   model_cost=cost, bits=scan.chosen_bits)
off = offline_scenario(compiled.offline, mk, n_samples=256,
                       model_cost=cost, bits=scan.chosen_bits)
xb = jnp.asarray(np.stack([mk(i) for i in range(64)]), jnp.int32)
# compiled segment waves (the hot path) vs the host queue-loop reference:
# both must match offline bit for bit
y_cmp, fifo = compiled.streaming_compiled(xb, micro_batch=8)
y_str, _ = compiled.streaming_host(xb, micro_batch=8)
assert bool(jnp.all(compiled.offline(xb) == y_cmp))
assert bool(jnp.all(compiled.offline(xb) == y_str))
print(f"    SingleStream: p50={ss.p50_ms:.3f}ms p99={ss.p99_ms:.3f}ms "
      f"(roofline energy proxy {ss.energy_proxy_uJ:.2f}uJ)")
print(f"    Offline:      {off.throughput_qps:.0f} inf/s (batch {off.extras['batch']})")
print(f"    Streaming:    fifo_depths={fifo.fifo_depths} "
      f"segments={fifo.segments} "
      f"(sized by core.dataflow, compiled waves match offline)")
