"""Fault-tolerance demo: a training run that loses a device mid-flight.

Simulates the production failure path end to end on CPU:
  1. train on the full device set, checkpointing every N steps,
  2. a persistent straggler trips the watchdog -> ElasticRestart (the loop
     checkpoints first),
  3. the launcher rebuilds a smaller mesh from the "surviving" devices,
     restores the checkpoint (resharding onto the new topology), and resumes
     to completion — with the loss curve continuing where it left off.

Run: PYTHONPATH=src python examples/elastic_restart.py
"""

import logging
import shutil
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpoint import latest_step
from repro.configs import get_config
from repro.data.synthetic import SyntheticTokens
from repro.models.model import Model
from repro.optim.adamw import make_optimizer
from repro.train.loop import ElasticRestart, LoopConfig, run_training
from repro.train.steps import TrainState, make_train_step

logging.basicConfig(level=logging.WARNING)

CKPT = "/tmp/repro_elastic_demo"
shutil.rmtree(CKPT, ignore_errors=True)

cfg = get_config("internlm2-1.8b").reduced()
model = Model(cfg)
opt = make_optimizer(base_lr=1e-3, warmup=5, total=60)
data = SyntheticTokens(vocab=cfg.vocab, seq_len=32)


def batch_fn(step):
    return {k: jnp.asarray(v) for k, v in data.batch(step, 4).items()}


params = model.init(jax.random.PRNGKey(0))
state = TrainState(params=params, opt=opt.init(params))
step_fn = jax.jit(make_train_step(model, opt))

# --- phase 1: healthy training until a straggler develops -------------------
clock = {"t": 0.0}


def time_fn():
    return clock["t"]


def degrade(step):                      # device goes slow at step 25
    clock["t"] += 10.0 if step >= 25 else 1.0


lcfg = LoopConfig(total_steps=60, ckpt_every=10, ckpt_dir=CKPT, log_every=20,
                  slow_factor=3.0, max_consecutive_slow=4, watchdog_warmup=10)
print("[1] training on the full slice ...")
try:
    run_training(step_fn, state, batch_fn, lcfg, step_hook=degrade,
                 time_fn=time_fn)
    raise SystemExit("expected an ElasticRestart")
except ElasticRestart as e:
    ckpt_at = latest_step(CKPT)
    print(f"[2] watchdog fired: {e}")
    print(f"    emergency checkpoint at step {ckpt_at}")

# --- phase 2: "rebuild" the mesh without the slow device and resume ---------
print("[3] relaunching on the surviving devices (mesh rebuild + reshard) ...")
t0 = time.time()
res = run_training(step_fn, state, batch_fn, lcfg)   # auto-resumes
print(f"[4] resumed from step {res.resumed_from}, finished at "
      f"{res.final_step} in {time.time()-t0:.1f}s wall")
hist = res.metrics_history
print(f"    loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
      f"(continuing the pre-failure curve)")
assert res.resumed_from is not None and res.final_step == 60
