"""Falcon-Mamba-7B [ssm] — 64L d_model=4096 (attention-free) vocab=65024,
ssm_state=16 — mamba1 architecture. [arXiv:2410.05355]

Pure SSM: no attention, no separate FFN (the mamba block IS the mixer+FFN,
d_inner = 2 * d_model = 8192, dt_rank = 4096/16 = 256, conv kernel 4).
long_500k RUNS for this arch (linear-time scan).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=65024,
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    ssm_chunk=128,
)
