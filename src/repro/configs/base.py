"""Architecture config schema + input-shape definitions.

Every assigned architecture is a frozen ``ArchConfig``; the four assigned
input shapes are ``ShapeConfig``s. ``reduced()`` produces the small same-family
config used by the CPU smoke tests (full configs are only ever lowered
abstractly in the dry-run).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    # --- MoE ---------------------------------------------------------
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_every: int = 1           # MoE in every k-th layer (jamba: 2)
    moe_shared_expert: bool = False
    capacity_factor: float = 1.25
    # --- SSM (mamba1) --------------------------------------------------
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: Optional[int] = None   # default d_model // 16
    attn_every: int = 1          # hybrid: attention layer every k-th (jamba: 8)
    # --- attention flavour ----------------------------------------------
    rope_theta: float = 1e4
    mrope: bool = False          # qwen2-vl 3-section M-RoPE
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)  # t/h/w halves of head_dim
    window: int = 0              # SWA window (h2o-danube)
    causal: bool = True
    encoder_only: bool = False
    qkv_bias: bool = False
    norm: str = "rms"            # rms | ln
    embed_inputs: bool = True    # False: input_specs provides embeddings (vlm/audio)
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    # --- quantization (the paper's technique, first-class) ---------------
    weight_bits: int = 16        # 16 = bf16 baseline; 8 / 4 = quantized serve path
    act_bits: int = 16
    # --- numerics / scan -------------------------------------------------
    dtype: str = "bfloat16"
    scan_layers: bool = True
    remat: str = "full"          # full | dots | none (hillclimb lever)
    ssm_chunk: int = 256
    attn_chunk: int = 1024       # flash-jnp q/kv chunk for long sequences
    attn_impl: str = "auto"      # auto | naive | chunked

    # ------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return self.ssm_dt_rank or max(self.d_model // 16, 1)

    @property
    def has_attention(self) -> bool:
        return self.family != "ssm"

    @property
    def has_ssm(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def is_moe(self) -> bool:
        return self.moe_experts > 0

    @property
    def block_period(self) -> int:
        """Layers per scanned block: lcm of the attn/moe interleave patterns."""
        import math

        p = 1
        if self.has_ssm and self.has_attention:
            p = math.lcm(p, self.attn_every)
        if self.is_moe:
            p = math.lcm(p, self.moe_every)
        return p

    @property
    def n_groups(self) -> int:
        assert self.n_layers % self.block_period == 0
        return self.n_layers // self.block_period

    def layer_kind(self, i: int) -> str:
        """'attn' or 'ssm' mixer for layer i within the repeating pattern."""
        if not self.has_ssm:
            return "attn"
        if not self.has_attention:
            return "ssm"
        # jamba: one attention layer per attn_every, placed mid-period
        return "attn" if (i % self.attn_every) == self.attn_every // 2 else "ssm"

    def layer_is_moe(self, i: int) -> bool:
        if not self.is_moe:
            return False
        return (i % self.moe_every) == self.moe_every - 1

    # ------------------------------------------------------------------
    def n_params(self) -> int:
        """Exact parameter count of this implementation (embedding included)."""
        d, f, V = self.d_model, self.d_ff, self.vocab
        H, K, hd = self.n_heads, self.n_kv_heads, self.hd
        nrm = 2 * d if self.norm == "ln" else d  # ln carries a bias
        total = V * d if self.embed_inputs else 0
        if not self.tie_embeddings:
            total += V * d                       # lm head
        total += nrm                             # final norm
        for i in range(self.n_layers):
            kind = self.layer_kind(i)
            if kind == "attn":
                qkv = d * H * hd + 2 * d * K * hd + H * hd * d
                if self.qkv_bias:
                    qkv += (H + 2 * K) * hd
                total += qkv + nrm               # + attn norm
            else:
                di, st, dtr = self.d_inner, self.ssm_state, self.dt_rank
                total += (
                    d * 2 * di                   # in_proj
                    + di * self.ssm_conv + di    # depthwise conv + bias
                    + di * (dtr + 2 * st)        # x_proj
                    + dtr * di + di              # dt_proj
                    + di * st + di               # A_log, D
                    + di * d                     # out_proj
                    + nrm                        # norm
                )
            if self.d_ff > 0:
                if self.layer_is_moe(i):
                    total += self.moe_experts * 3 * d * f + d * self.moe_experts
                    if self.moe_shared_expert:
                        total += 3 * d * f
                else:
                    total += 3 * d * f
                total += nrm                     # mlp norm
        return total

    def n_active_params(self) -> int:
        """Params touched per token (MoE: top_k experts only) — the N in 6ND."""
        if not self.is_moe:
            return self.n_params()
        d, f = self.d_model, self.d_ff
        dense_equiv = dataclasses.replace(self, moe_experts=0, moe_top_k=0)
        total = dense_equiv.n_params()
        n_moe_layers = sum(self.layer_is_moe(i) for i in range(self.n_layers))
        # dense_equiv counted 3*d*f per layer; replace MoE layers with top_k experts
        total += n_moe_layers * (self.moe_top_k - 1) * 3 * d * f
        total += n_moe_layers * d * self.moe_experts  # router
        if self.moe_shared_expert:
            total += n_moe_layers * 3 * d * f
        return total

    # ------------------------------------------------------------------
    def reduced(self) -> "ArchConfig":
        """Same-family tiny config for CPU smoke tests."""
        period = self.block_period
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=max(period, 2 if period == 1 else period),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            d_ff=128 if self.d_ff > 0 else 0,
            vocab=256,
            head_dim=16,
            moe_experts=min(self.moe_experts, 4) if self.is_moe else 0,
            moe_top_k=min(self.moe_top_k, 2) if self.is_moe else 0,
            ssm_state=min(self.ssm_state, 8) if self.has_ssm else 0,
            ssm_dt_rank=8 if self.has_ssm else None,
            window=min(self.window, 32) if self.window else 0,
            mrope_sections=(2, 3, 3) if self.mrope else self.mrope_sections,
            ssm_chunk=16,
            attn_chunk=32,
            dtype="float32",
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str              # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(arch: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """(runnable, reason-if-skipped) per the assignment rules."""
    if arch.encoder_only and shape.kind == "decode":
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k":
        sub_quadratic = arch.has_ssm or arch.window > 0
        if not sub_quadratic:
            return False, "pure full-attention arch: 500k decode needs sub-quadratic attention"
    return True, ""
