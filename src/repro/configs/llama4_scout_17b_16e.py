"""Llama-4-Scout-17B-16E [moe] — 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16 routed experts top-1 + shared expert (every layer),
early fusion. [hf:meta-llama/Llama-4-Scout-17B-16E]

head_dim = 128. Active params/token ~ 17B (1 routed + 1 shared expert).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab=202048,
    head_dim=128,
    moe_experts=16,
    moe_top_k=1,
    moe_every=1,
    moe_shared_expert=True,
    rope_theta=5e5,
)
