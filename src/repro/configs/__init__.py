"""Config registry: ``get_config(name)`` / ``list_configs()``.

The ten assigned architectures (public-literature configs, sources in each
file) plus the paper's own four MLPerf Tiny models.
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import SHAPES, ArchConfig, ShapeConfig, shape_applicable  # noqa: F401

ARCH_IDS = [
    "qwen2-vl-2b",
    "falcon-mamba-7b",
    "hubert-xlarge",
    "grok-1-314b",
    "llama4-scout-17b-16e",
    "internlm2-1.8b",
    "h2o-danube-1.8b",
    "llama3-8b",
    "qwen1.5-4b",
    "jamba-v0.1-52b",
]

_MODULES = {
    "qwen2-vl-2b": "qwen2_vl_2b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "hubert-xlarge": "hubert_xlarge",
    "grok-1-314b": "grok_1_314b",
    "llama4-scout-17b-16e": "llama4_scout_17b_16e",
    "internlm2-1.8b": "internlm2_1_8b",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "llama3-8b": "llama3_8b",
    "qwen1.5-4b": "qwen1_5_4b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
}


def get_config(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch '{name}'; available: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def list_configs() -> List[str]:
    return list(ARCH_IDS)
