"""Jamba-v0.1-52B [hybrid] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16 experts top-2 — Mamba+attention 1:7 interleave, MoE
every other layer. [arXiv:2403.19887; hf]

Block structure (period 8): layers 0-7 with attention at index 4 (1:7
attn:mamba), MoE FFN on odd layers (every other), dense FFN on even.
ssm_state=16, d_inner=8192. long_500k RUNS (only 4 attention layers hold a
full-length KV cache; mamba state is O(1)).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=65536,
    head_dim=128,
    moe_experts=16,
    moe_top_k=2,
    moe_every=2,
    attn_every=8,
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    ssm_chunk=128,
)
