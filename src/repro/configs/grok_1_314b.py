"""Grok-1-314B [moe] — 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072, MoE 8 experts top-2 (every layer). [hf:xai-org/grok-1]

head_dim = 6144/48 = 128. The 8x(3*6144*32768) expert FFNs dominate the
param count (~309B of 314B).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32768,
    vocab=131072,
    head_dim=128,
    moe_experts=8,
    moe_top_k=2,
    moe_every=1,
)
