"""Qwen2-VL-2B [vlm] — 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936 — M-RoPE, dynamic resolution. [arXiv:2409.12191; hf]

Backbone only: the vision frontend is a stub; input_specs() provides
precomputed patch embeddings + (3, B, S) M-RoPE positions.
head_dim = 1536/12 = 128; M-RoPE sections (t,h,w) = (16, 24, 24) over the
64 rotary half-dims, matching the HF config.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab=151936,
    head_dim=128,
    mrope=True,
    mrope_sections=(16, 24, 24),
    qkv_bias=True,
    rope_theta=1e6,
    embed_inputs=False,   # patch/frame embeddings provided by the stub
    tie_embeddings=False,
)
