"""HuBERT-XLarge [audio] — 48L d_model=1280 16H (kv=16, MHA) d_ff=5120
vocab=504 — encoder-only, wav2vec2-style. [arXiv:2106.07447]

Backbone only: the CNN feature extractor is a stub; input_specs() provides
frame embeddings (B, S, 1280). Encoder-only => no decode shapes
(decode_32k / long_500k skipped per assignment). Training objective here is
masked-frame prediction over the 504-codebook vocab (HuBERT-style CE).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab=504,
    head_dim=80,
    causal=False,
    encoder_only=True,
    norm="ln",
    embed_inputs=False,
)
