"""H2O-Danube-1.8B [dense] — 24L d_model=2560 32H (GQA kv=8) d_ff=6912
vocab=32000 — llama+mistral mix with sliding-window attention.
[arXiv:2401.16818; hf]

SWA window 4096 (mistral-style) => sub-quadratic => long_500k RUNS for this
arch; decode uses a ring-buffer KV cache of window size.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-1.8b",
    family="dense",
    n_layers=24,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6912,
    vocab=32000,
    head_dim=80,
    window=4096,
)
