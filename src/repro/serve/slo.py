"""SLO-aware admission control for the serve router.

The question the shedder answers per arriving request: *if we admit this,
will it (and the requests behind it) still finish inside the p99 budget?*
Answering needs a service-time estimate, and the serve runtime builds it
the same way the streaming autotuner does — model first, measurement
second:

  1. **FIFO cost model** — ``core.dataflow.micro_batch_stage`` prices every
     compiled stage at a wave size (``overhead + ceil(work*mb/elems)``
     simulated cycles, ``work`` from ``executor.stage_work``); summing the
     stage latencies gives the modeled fill+drain cycles of one wave
     through the segment pipeline.
  2. **stage_latencies calibration** — the executor's measured per-stage
     probe converts cycles to seconds: ``sec_per_cycle = measured wall
     seconds at the probe batch / modeled cycles at that batch``.
  3. **online correction** — every dispatched wave's measured service time
     feeds an EWMA ratio on top of the calibrated model, so drift (thermal,
     competing load) is tracked without re-probing.

Queue state then closes the loop: the controller tracks the arrival rate
in a sliding window and estimates steady-state queue occupancy by
Little's law (``L = lambda * W``); admission compares the *realized*
backlog's completion estimate against the budget and sheds the request up
front — a shed costs the client one fast rejection instead of a blown
p99.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Dict, List, Optional, Sequence, Tuple

from repro.core.dataflow import micro_batch_stage


@dataclasses.dataclass
class ServiceModel:
    """Cycles -> seconds service-time model for one compiled schedule.

    ``works`` is the per-stage (name, fifo_work) list; ``sec_per_cycle``
    the stage_latencies calibration. ``calibration`` keeps the audit trail
    (probe batch, measured ms, modeled cycles) for the bench JSON.
    """

    works: List[Tuple[str, int]]
    sec_per_cycle: float
    calibration: Dict = dataclasses.field(default_factory=dict)

    def wave_cycles(self, micro_batch: int) -> int:
        """Modeled fill+drain cycles of ONE wave: the sum of per-stage
        service latencies under the FIFO cost model (a single wave visits
        every stage once; there is no pipelining inside one wave)."""
        return sum(micro_batch_stage(name, work, micro_batch).latency
                   for name, work in self.works)

    def wave_service_s(self, micro_batch: int) -> float:
        return self.wave_cycles(micro_batch) * self.sec_per_cycle

    def saturation_qps(self, micro_batch: int) -> float:
        """Max sustainable arrival rate at this wave size: full waves,
        back to back."""
        return micro_batch / max(self.wave_service_s(micro_batch), 1e-12)

    def recalibrated(self, measured_s: float, micro_batch: int
                     ) -> "ServiceModel":
        """New model rescaled so ``wave_service_s(micro_batch)`` equals a
        *measured* wave service time.

        The stage_latencies calibration prices the stage compute but not
        the per-wave dispatch overhead (host crossing, jit dispatch),
        which dominates small models on CPU — so capacity planning from
        the raw model over-estimates saturation badly there. One measured
        ``submit_wave`` probe pins the model to reality at the operating
        wave size while keeping the FIFO model's *shape* across sizes.
        """
        modeled = self.wave_service_s(micro_batch)
        if measured_s <= 0 or modeled <= 0:
            return self
        ratio = measured_s / modeled
        return dataclasses.replace(
            self, sec_per_cycle=self.sec_per_cycle * ratio,
            calibration={**self.calibration,
                         "measured_wave_ms": measured_s * 1e3,
                         "wave_micro_batch": int(micro_batch),
                         "dispatch_overhead_ratio": ratio})

    @classmethod
    def from_compiled(cls, cm, stage_ms: Optional[Sequence[Dict]] = None,
                      probe_batch: int = 8) -> "ServiceModel":
        """Build the model for a ``CompiledTinyModel``: FIFO-model stage
        works plus a stage_latencies calibration at ``probe_batch``.

        Pass a precomputed ``stage_ms`` breakdown (e.g. the autotuner's
        ``seed_stage_ms``) to skip the probe; its batch must then be
        ``probe_batch``.
        """
        from repro.deploy.autotune import default_sample
        from repro.deploy.executor import stage_work

        works = [(s.name, stage_work(s)) for s in cm.schedule.stages]
        if stage_ms is None:
            stage_ms = cm.stage_latencies(default_sample(cm, probe_batch))
        measured_s = sum(s["ms"] for s in stage_ms) / 1e3
        model = cls(works=works, sec_per_cycle=1.0)
        cycles = model.wave_cycles(probe_batch)
        model.sec_per_cycle = measured_s / max(cycles, 1)
        model.calibration = {"probe_batch": int(probe_batch),
                             "measured_ms": measured_s * 1e3,
                             "modeled_cycles": int(cycles)}
        return model


@dataclasses.dataclass
class PredictedServiceModel(ServiceModel):
    """Predictor-priced service model for a COLD model — no probe, no
    completed wave, no ``stage_latencies`` run.

    ``predicted_s`` tables the learned wave-cost predictor's per-wave
    service estimate at each candidate micro-batch
    (``repro.costmodel``); ``scale`` is the online correction factor
    ``recalibrated`` folds measured waves into. Off-table wave sizes are
    extrapolated with the FIFO model's *shape* (cycles ratio against the
    nearest tabled size) — the same stance as the calibrated base class,
    just anchored on a prediction instead of a probe. The first measured
    wave starts pulling ``scale`` toward reality (and the
    ``SLOController`` EWMA corrects on top), so cold-start pricing decays
    into the measured path with no mode switch.
    """

    predicted_s: Dict[int, float] = dataclasses.field(default_factory=dict)
    scale: float = 1.0

    def wave_service_s(self, micro_batch: int) -> float:
        mb = int(micro_batch)
        if not self.predicted_s:
            return super().wave_service_s(mb)
        s = self.predicted_s.get(mb)
        if s is None:
            ref = min(sorted(self.predicted_s),
                      key=lambda m: (abs(m - mb), m))
            s = self.predicted_s[ref] * (
                self.wave_cycles(mb) / max(self.wave_cycles(ref), 1))
        return s * self.scale

    def recalibrated(self, measured_s: float, micro_batch: int
                     ) -> "PredictedServiceModel":
        modeled = self.wave_service_s(micro_batch)
        if measured_s <= 0 or modeled <= 0:
            return self
        ratio = measured_s / modeled
        return dataclasses.replace(
            self, scale=self.scale * ratio,
            calibration={**self.calibration,
                         "measured_wave_ms": measured_s * 1e3,
                         "wave_micro_batch": int(micro_batch),
                         "dispatch_overhead_ratio": ratio})

    @classmethod
    def from_predictor(cls, predictor, cm,
                       candidates: Sequence[int] = (1, 2, 4, 8, 16, 32, 64)
                       ) -> "PredictedServiceModel":
        """Price a compiled model's waves from static structure alone.

        ``predictor`` is a ``repro.costmodel.WaveCostPredictor`` (or
        anything with ``predict_ms(features_dict)``); the features come
        from the versioned extractor, so this runs zero probes and zero
        model executions — admission control for a model the server has
        never seen.
        """
        from repro.costmodel.features import wave_features
        from repro.deploy.executor import stage_work

        works = [(s.name, stage_work(s)) for s in cm.schedule.stages]
        table = {int(mb): float(predictor.predict_ms(wave_features(cm, mb)))
                 / 1e3
                 for mb in sorted({int(m) for m in candidates if m >= 1})}
        model = cls(works=works, sec_per_cycle=1.0, predicted_s=table)
        ref = min(table)
        model.sec_per_cycle = table[ref] / max(model.wave_cycles(ref), 1)
        model.calibration = {
            "source": "predicted",
            "feature_schema_version": int(getattr(predictor,
                                                  "schema_version", 0)),
            "candidates": sorted(table),
        }
        return model

    @classmethod
    def from_table(cls, works: List[Tuple[str, int]],
                   predicted_s: Dict[int, float]) -> "PredictedServiceModel":
        """Build directly from a predicted per-micro-batch table — the
        scripted-simulation entry point (no compiled model needed)."""
        table = {int(k): float(v) for k, v in predicted_s.items()}
        model = cls(works=list(works), sec_per_cycle=1.0,
                    predicted_s=table)
        ref = min(table)
        model.sec_per_cycle = table[ref] / max(model.wave_cycles(ref), 1)
        model.calibration = {"source": "predicted",
                             "candidates": sorted(table)}
        return model


def measure_wave_service_s(cm, micro_batch: int, iters: int = 5) -> float:
    """Median wall seconds of one padded wave through ``submit_wave`` —
    the probe ``ServiceModel.recalibrated`` consumes (one compile + one
    discarded warm iteration first, the ``stage_latencies`` convention)."""
    import jax

    from repro.deploy.autotune import default_sample
    from repro.obs import timer as obs_timer

    x = default_sample(cm, micro_batch)
    for _ in range(2):                   # compile + discarded warm
        y, _ = cm.submit_wave(x, micro_batch=micro_batch)
        jax.block_until_ready(y)
    times = []
    for _ in range(max(iters, 1)):
        t0 = obs_timer.now()
        y, _ = cm.submit_wave(x, micro_batch=micro_batch)
        jax.block_until_ready(y)
        times.append(obs_timer.now() - t0)
    times.sort()
    return times[len(times) // 2]


def queued_waves(n_pending: int, micro_batch: int, n_inflight: int = 0
                 ) -> int:
    """Waves an arriving request must wait out before its own wave
    completes, its own wave *excluded* (``SLOController.admit`` adds the
    +1 for it): queued work counted in waves plus every wave still in
    flight on a replica.

    The queued term is ``ceil((n_pending + 1) / micro_batch) - 1`` — the
    arriving request joins the queue and the total is rounded *up* to
    whole waves, so the partial wave it lands in is priced. (For a pure
    pending queue this equals ``n_pending // micro_batch``; the
    floor-division form the router used to inline only *looked* like it
    dropped the partial wave because of that identity — but it had no
    slot for in-flight waves at all, which is where the async router's
    real queue delay lives: a wave submitted but not completed still
    occupies a replica exactly like a queued one.)
    """
    if micro_batch < 1:
        raise ValueError(f"micro_batch must be >= 1, got {micro_batch}")
    if n_pending < 0 or n_inflight < 0:
        raise ValueError(
            f"negative queue state: pending={n_pending} "
            f"inflight={n_inflight}")
    return (int(n_pending) + micro_batch) // micro_batch - 1 \
        + int(n_inflight)


class SLOController:
    """Per-model admission controller against a p99 latency budget.

    ``admit`` estimates the arriving request's completion latency —
    batching wait (it may sit out the full deadline) plus the backlog's
    service time plus its own wave's — and sheds when the estimate
    exceeds ``headroom * budget``. ``occupancy_estimate`` is the Little's
    law monitoring signal (windowed arrival rate times estimated time in
    system); ``utilization`` the offered-load / capacity ratio that tells
    the bench where saturation sits.
    """

    def __init__(self, p99_budget_ms: float, service: ServiceModel,
                 window_s: float = 10.0, headroom: float = 1.0,
                 ewma_alpha: float = 0.25):
        if p99_budget_ms <= 0:
            raise ValueError(f"p99_budget_ms must be > 0, got {p99_budget_ms}")
        self.p99_budget_ms = float(p99_budget_ms)
        self.service = service
        self.window_s = float(window_s)
        self.headroom = float(headroom)
        self.ewma_alpha = float(ewma_alpha)
        self._ratio = 1.0          # EWMA of measured / modeled service
        self._arrivals: Deque[float] = collections.deque()

    # -- service-time estimate (model x online correction) -----------------
    def wave_service_s(self, micro_batch: int) -> float:
        return self.service.wave_service_s(micro_batch) * self._ratio

    def observe_service(self, micro_batch: int, measured_s: float) -> None:
        modeled = self.service.wave_service_s(micro_batch)
        if modeled <= 0 or measured_s <= 0:
            return
        a = self.ewma_alpha
        self._ratio = (1 - a) * self._ratio + a * (measured_s / modeled)

    # -- arrival rate ------------------------------------------------------
    def observe_arrival(self, now: float) -> None:
        self._arrivals.append(now)
        cutoff = now - self.window_s
        while self._arrivals and self._arrivals[0] < cutoff:
            self._arrivals.popleft()

    def arrival_qps(self, now: float) -> float:
        if not self._arrivals:
            return 0.0
        span = max(now - self._arrivals[0], 1e-9)
        return len(self._arrivals) / span

    # -- queue-state estimates ---------------------------------------------
    def utilization(self, now: float, micro_batch: int) -> float:
        """Offered load over capacity: rho = lambda / saturation_qps."""
        cap = self.service.saturation_qps(micro_batch) / max(self._ratio, 1e-9)
        return self.arrival_qps(now) / max(cap, 1e-9)

    def occupancy_estimate(self, now: float, micro_batch: int,
                           max_wait_s: float = 0.0) -> float:
        """Little's law: L = lambda * W with W = batching wait + one wave
        of service. The steady-state queue length this arrival rate implies
        — the monitoring number reported next to the realized backlog."""
        w = max_wait_s + self.wave_service_s(micro_batch)
        return self.arrival_qps(now) * w

    def estimated_latency_s(self, backlog_waves: int, micro_batch: int,
                            max_wait_s: float, lag_s: float = 0.0,
                            n_workers: int = 1) -> float:
        """Completion estimate for a request admitted *now*: the time it
        already spent blocked behind the server (``lag_s`` — arrival to
        admission), worst-case batching wait, every queued or in-flight
        wave ahead of it, then its own wave's service.

        ``n_workers`` is the replica count draining the queue: an
        N-replica pool under a non-blocking engine retires up to N waves
        per service period, so the backlog's delay is
        ``ceil(waves / N)`` service *rounds*, not ``waves`` serial
        services (with ``n_workers=1`` this reduces exactly to the
        single-worker arithmetic)."""
        waves = int(backlog_waves) + 1
        rounds = -(-waves // max(int(n_workers), 1))
        return max(lag_s, 0.0) + max_wait_s \
            + rounds * self.wave_service_s(micro_batch)

    def admit(self, now: float, backlog_waves: int, micro_batch: int,
              max_wait_s: float, lag_s: float = 0.0,
              n_workers: int = 1) -> bool:
        est = self.estimated_latency_s(backlog_waves, micro_batch,
                                       max_wait_s, lag_s, n_workers)
        return est * 1e3 <= self.p99_budget_ms * self.headroom


def slo_operating_point(service: ServiceModel, p99_budget_ms: float,
                        candidates: Sequence[int] = (1, 2, 4, 8, 16, 32, 64),
                        ) -> Dict[str, object]:
    """The SLO-constrained operating point for one model: the largest wave
    size whose modeled fill+drain stays inside the latency budget (bigger
    waves amortize dispatch overhead -> more throughput, but a full wave's
    service time bounds every member's latency from below). Returns the
    choice plus the scored candidate table (the bench's audit trail).
    """
    rows = []
    best = None
    for mb in sorted({int(m) for m in candidates if m >= 1}):
        s = service.wave_service_s(mb)
        fits = s * 1e3 <= p99_budget_ms
        rows.append({"micro_batch": mb, "service_ms": s * 1e3,
                     "saturation_qps": service.saturation_qps(mb),
                     "fits_budget": fits})
        if fits:
            best = rows[-1]
    if best is None:            # nothing fits: serve single queries anyway
        best = rows[0]
    return {"micro_batch": int(best["micro_batch"]),
            "service_ms": float(best["service_ms"]),
            "saturation_qps": float(best["saturation_qps"]),
            "fits_budget": bool(best["fits_budget"]),
            "candidates": rows}
