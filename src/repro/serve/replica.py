"""Replica pool: one compiled schedule per device, least-work placement.

The hls4ml platform framing — a dataflow accelerator as a *shared* serving
engine — maps here to one compiled executor per available ``jax.device()``.
Each replica owns its own ``CompiledTinyModel`` (jit caches are
per-instance, so replicas never contend on compilation) pinned to one
device, and the pool places each wave on the replica with the least
outstanding modeled work — the queueing-theory argument for
join-shortest-queue over round-robin under heterogeneous wave sizes.

Wave execution is split into ``submit`` (``device_put`` + ``submit_wave``;
JAX's async dispatch makes the returned arrays promises, so this does not
block) and the returned ``WaveHandle``'s ``wait`` — the seam the dispatch
engines (``serve.dispatch``) are built on. ``run_wave`` remains as the
blocking submit-then-wait composition.

On the CPU container there is exactly one device; the pool degenerates to
a single replica and the placement/overlap logic is exercised by the
tests through fake executors (a fake exposing ``submit_wave_async`` can
script completion times against a manual clock).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence

import jax
import numpy as np

from repro.obs.tracer import NULL_TRACER
from repro.serve.dispatch import WaveHandle
from repro.serve.faults import NoReplicaAvailable

#: Replica health states (the failure-domain state machine — see
#: ``docs/faults.md``): healthy -> suspect on the first observed failure,
#: suspect -> quarantined on the next (excluded from placement),
#: quarantined -> recovering when a probe wave is due (exactly one wave is
#: allowed through), recovering -> healthy on probe success / back to
#: quarantined on probe failure. Any success from any state heals.
HEALTHY = "healthy"
SUSPECT = "suspect"
QUARANTINED = "quarantined"
RECOVERING = "recovering"


@dataclasses.dataclass
class Replica:
    """One executor instance bound to one device."""

    index: int
    model: object                 # anything with submit_wave(...) -> (y, mask)
    device: Optional[object] = None
    outstanding_s: float = 0.0    # modeled seconds of work placed, not done
    n_dispatched: int = 0
    n_inflight: int = 0           # waves submitted, not yet reaped
    health: str = HEALTHY
    n_failures: int = 0           # consecutive failures since last success
    last_failure: str = ""        # reason string of the latest failure
    next_probe_t: float = 0.0     # quarantined: when the probe wave is due

    def submit(self, x, valid=None, micro_batch: Optional[int] = None
               ) -> WaveHandle:
        """Launch one padded wave on this replica's device without waiting
        for the result.

        Prefers the model's ``submit_wave_async`` when it has one (the
        scripted-fake protocol: returns an object with ``ready_t`` and
        ``wait()``); otherwise calls ``submit_wave`` directly — under JAX
        async dispatch that call returns unmaterialized device arrays, so
        the wave is in flight, not done, until the handle's ``wait``.
        """
        if self.device is not None:
            x = jax.device_put(np.asarray(x), self.device)
        submit_async = getattr(self.model, "submit_wave_async", None)
        if submit_async is not None:
            inner = submit_async(x, valid=valid, micro_batch=micro_batch)
            return WaveHandle(self, inner=inner)
        y, mask = self.model.submit_wave(x, valid=valid,
                                         micro_batch=micro_batch)
        return WaveHandle(self, y=y, mask=mask)

    def run_wave(self, x, valid=None, micro_batch: Optional[int] = None):
        """Run one padded wave and block until the result is ready, so the
        caller's clock reading is the completion (the sync-engine path)."""
        return self.submit(x, valid=valid, micro_batch=micro_batch).wait()


class ReplicaPool:
    """Replicas of one model across devices, placed by least work.

    ``factory`` builds a fresh executor per device (e.g.
    ``lambda: compile_graph(graph, ...)``); when only ``model`` is given
    the pool has that single replica (the CPU case). The first replica
    reuses ``model`` so single-device callers pay zero extra compiles.
    """

    def __init__(self, model=None, *,
                 factory: Optional[Callable[[], object]] = None,
                 devices: Optional[Sequence[object]] = None,
                 probe_interval_s: float = 0.05):
        if model is None and factory is None:
            raise ValueError("need a model or a factory")
        if probe_interval_s <= 0:
            raise ValueError(
                f"probe_interval_s must be > 0, got {probe_interval_s}")
        #: quarantined -> recovering probe cadence: how long a quarantined
        #: replica sits out before one probe wave is allowed through
        self.probe_interval_s = float(probe_interval_s)
        if devices is None:
            devices = jax.devices() if factory is not None else [None]
        if not devices:
            raise ValueError("no devices to place replicas on")
        #: per-replica outstanding-work counter sink; the router installs
        #: its tracer here so placement decisions show up as counter
        #: tracks (pid 1+i = replica i in the exported timeline)
        self.tracer = NULL_TRACER
        if len(devices) > 1 and factory is None:
            raise ValueError(
                f"{len(devices)} devices but no factory: replicas beyond "
                "the first need their own executor (jit caches are "
                "per-instance) — pass factory=lambda: compile_graph(...)")
        self.replicas: List[Replica] = []
        for i, dev in enumerate(devices):
            m = model if (i == 0 and model is not None) else factory()
            self.replicas.append(Replica(index=i, model=m, device=dev))

    @property
    def default_micro_batch(self) -> int:
        m = self.replicas[0].model
        return int(getattr(m, "default_micro_batch", 1))

    @property
    def n_replicas(self) -> int:
        return len(self.replicas)

    @property
    def n_available(self) -> int:
        """Replicas the pool can place work on (not quarantined) — the
        worker count the admission controller prices the surviving pool
        with, so a half-dead pool sheds like the half it really is."""
        return sum(1 for r in self.replicas if r.health != QUARANTINED)

    def place(self, work_s: float = 0.0, now: Optional[float] = None,
              exclude: Sequence[int] = ()) -> Replica:
        """Pick the least-outstanding-work replica and charge it the wave's
        modeled service time; ``complete`` credits it back. Equal-work ties
        break to the replica that has dispatched fewest waves (round-robin
        under uniform load), then to index.

        Health-aware: quarantined replicas are skipped; when ``now`` is
        given and a quarantined replica's probe is due, that replica takes
        this one wave as its readmission probe (state -> recovering —
        exactly one wave, so a still-dead replica costs one retry, not a
        burst). ``exclude`` holds replica indices a retried wave must
        avoid (the ones it already failed on) — a *preference*: with
        every other replica down, retrying in place beats shedding. Raises
        ``NoReplicaAvailable`` (typed, never an IndexError) when the pool
        has nowhere at all to put the wave.

        The caller owes a *real* ``work_s`` estimate for join-shortest-queue
        to mean anything: with ``work_s=0`` every replica always ties and
        placement silently degenerates to dispatch-count round-robin —
        the bug the router's lane-level service estimate now closes even
        when SLO shedding is off.
        """
        exclude = frozenset(exclude)
        r = None
        if now is not None:
            due = [p for p in self.replicas
                   if p.health == QUARANTINED and now >= p.next_probe_t
                   and p.index not in exclude]
            if due:
                r = min(due, key=lambda p: (p.next_probe_t, p.index))
                r.health = RECOVERING
                if self.tracer.enabled:
                    self._trace_health(r, now)
        if r is None:
            live = [p for p in self.replicas
                    if p.health in (HEALTHY, SUSPECT)]
            candidates = [p for p in live if p.index not in exclude] or live
            if not candidates:
                raise NoReplicaAvailable(
                    "no replica available: "
                    + ", ".join(f"replica{p.index}={p.health}"
                                for p in self.replicas))
            r = min(candidates,
                    key=lambda r: (r.outstanding_s, r.n_dispatched, r.index))
        r.outstanding_s += float(work_s)
        r.n_dispatched += 1
        if self.tracer.enabled:
            self.tracer.counter("outstanding_s", r.outstanding_s,
                                cat="replica", pid=1 + r.index)
        return r

    # -- health state machine ----------------------------------------------
    def _trace_health(self, r: Replica, now: Optional[float]) -> None:
        kw = {} if now is None else {"t": now}
        self.tracer.instant("replica_health", cat="replica",
                            pid=1 + r.index, health=r.health,
                            failures=r.n_failures, **kw)
        self.tracer.counter("available_replicas", self.n_available,
                            cat="replica", **kw)

    def mark_failure(self, replica: Replica, now: float,
                     reason: str = "") -> str:
        """One observed failure (timeout, crash, corrupt output, submit
        error) on this replica: healthy degrades to suspect; anything
        already under suspicion — suspect, recovering (a failed probe) —
        goes to quarantine with the next probe scheduled. Returns the new
        health state."""
        replica.n_failures += 1
        replica.last_failure = str(reason)
        if replica.health == HEALTHY:
            replica.health = SUSPECT
        else:
            replica.health = QUARANTINED
            replica.next_probe_t = now + self.probe_interval_s
        if self.tracer.enabled:
            self._trace_health(replica, now)
        return replica.health

    def mark_success(self, replica: Replica, now: float) -> None:
        """One completed, integrity-clean wave: full health, from any
        state (a recovering replica's probe success readmits it)."""
        replica.n_failures = 0
        if replica.health != HEALTHY:
            replica.health = HEALTHY
            if self.tracer.enabled:
                self._trace_health(replica, now)

    def complete(self, replica: Replica, work_s: float = 0.0) -> None:
        replica.outstanding_s = max(0.0, replica.outstanding_s
                                    - float(work_s))
        if self.tracer.enabled:
            self.tracer.counter("outstanding_s", replica.outstanding_s,
                                cat="replica", pid=1 + replica.index)

    def stats(self) -> List[dict]:
        return [{"replica": r.index,
                 "device": str(r.device) if r.device is not None else "local",
                 "dispatched": r.n_dispatched,
                 "inflight": r.n_inflight,
                 "outstanding_s": r.outstanding_s,
                 "health": r.health,
                 "failures": r.n_failures}
                for r in self.replicas]
