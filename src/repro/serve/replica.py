"""Replica pool: one compiled schedule per device, least-work placement.

The hls4ml platform framing — a dataflow accelerator as a *shared* serving
engine — maps here to one compiled executor per available ``jax.device()``.
Each replica owns its own ``CompiledTinyModel`` (jit caches are
per-instance, so replicas never contend on compilation) pinned to one
device, and the pool places each wave on the replica with the least
outstanding modeled work — the queueing-theory argument for
join-shortest-queue over round-robin under heterogeneous wave sizes.

Wave execution is split into ``submit`` (``device_put`` + ``submit_wave``;
JAX's async dispatch makes the returned arrays promises, so this does not
block) and the returned ``WaveHandle``'s ``wait`` — the seam the dispatch
engines (``serve.dispatch``) are built on. ``run_wave`` remains as the
blocking submit-then-wait composition.

On the CPU container there is exactly one device; the pool degenerates to
a single replica and the placement/overlap logic is exercised by the
tests through fake executors (a fake exposing ``submit_wave_async`` can
script completion times against a manual clock).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence

import jax
import numpy as np

from repro.obs.tracer import NULL_TRACER
from repro.serve.dispatch import WaveHandle


@dataclasses.dataclass
class Replica:
    """One executor instance bound to one device."""

    index: int
    model: object                 # anything with submit_wave(...) -> (y, mask)
    device: Optional[object] = None
    outstanding_s: float = 0.0    # modeled seconds of work placed, not done
    n_dispatched: int = 0
    n_inflight: int = 0           # waves submitted, not yet reaped

    def submit(self, x, valid=None, micro_batch: Optional[int] = None
               ) -> WaveHandle:
        """Launch one padded wave on this replica's device without waiting
        for the result.

        Prefers the model's ``submit_wave_async`` when it has one (the
        scripted-fake protocol: returns an object with ``ready_t`` and
        ``wait()``); otherwise calls ``submit_wave`` directly — under JAX
        async dispatch that call returns unmaterialized device arrays, so
        the wave is in flight, not done, until the handle's ``wait``.
        """
        if self.device is not None:
            x = jax.device_put(np.asarray(x), self.device)
        submit_async = getattr(self.model, "submit_wave_async", None)
        if submit_async is not None:
            inner = submit_async(x, valid=valid, micro_batch=micro_batch)
            return WaveHandle(self, inner=inner)
        y, mask = self.model.submit_wave(x, valid=valid,
                                         micro_batch=micro_batch)
        return WaveHandle(self, y=y, mask=mask)

    def run_wave(self, x, valid=None, micro_batch: Optional[int] = None):
        """Run one padded wave and block until the result is ready, so the
        caller's clock reading is the completion (the sync-engine path)."""
        return self.submit(x, valid=valid, micro_batch=micro_batch).wait()


class ReplicaPool:
    """Replicas of one model across devices, placed by least work.

    ``factory`` builds a fresh executor per device (e.g.
    ``lambda: compile_graph(graph, ...)``); when only ``model`` is given
    the pool has that single replica (the CPU case). The first replica
    reuses ``model`` so single-device callers pay zero extra compiles.
    """

    def __init__(self, model=None, *,
                 factory: Optional[Callable[[], object]] = None,
                 devices: Optional[Sequence[object]] = None):
        if model is None and factory is None:
            raise ValueError("need a model or a factory")
        if devices is None:
            devices = jax.devices() if factory is not None else [None]
        if not devices:
            raise ValueError("no devices to place replicas on")
        #: per-replica outstanding-work counter sink; the router installs
        #: its tracer here so placement decisions show up as counter
        #: tracks (pid 1+i = replica i in the exported timeline)
        self.tracer = NULL_TRACER
        if len(devices) > 1 and factory is None:
            raise ValueError(
                f"{len(devices)} devices but no factory: replicas beyond "
                "the first need their own executor (jit caches are "
                "per-instance) — pass factory=lambda: compile_graph(...)")
        self.replicas: List[Replica] = []
        for i, dev in enumerate(devices):
            m = model if (i == 0 and model is not None) else factory()
            self.replicas.append(Replica(index=i, model=m, device=dev))

    @property
    def default_micro_batch(self) -> int:
        m = self.replicas[0].model
        return int(getattr(m, "default_micro_batch", 1))

    @property
    def n_replicas(self) -> int:
        return len(self.replicas)

    def place(self, work_s: float = 0.0) -> Replica:
        """Pick the least-outstanding-work replica and charge it the wave's
        modeled service time; ``complete`` credits it back. Equal-work ties
        break to the replica that has dispatched fewest waves (round-robin
        under uniform load), then to index.

        The caller owes a *real* ``work_s`` estimate for join-shortest-queue
        to mean anything: with ``work_s=0`` every replica always ties and
        placement silently degenerates to dispatch-count round-robin —
        the bug the router's lane-level service estimate now closes even
        when SLO shedding is off.
        """
        r = min(self.replicas,
                key=lambda r: (r.outstanding_s, r.n_dispatched, r.index))
        r.outstanding_s += float(work_s)
        r.n_dispatched += 1
        if self.tracer.enabled:
            self.tracer.counter("outstanding_s", r.outstanding_s,
                                cat="replica", pid=1 + r.index)
        return r

    def complete(self, replica: Replica, work_s: float = 0.0) -> None:
        replica.outstanding_s = max(0.0, replica.outstanding_s
                                    - float(work_s))
        if self.tracer.enabled:
            self.tracer.counter("outstanding_s", replica.outstanding_s,
                                cat="replica", pid=1 + replica.index)

    def stats(self) -> List[dict]:
        return [{"replica": r.index,
                 "device": str(r.device) if r.device is not None else "local",
                 "dispatched": r.n_dispatched,
                 "inflight": r.n_inflight,
                 "outstanding_s": r.outstanding_s}
                for r in self.replicas]
