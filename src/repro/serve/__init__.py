"""repro.serve — SLO-aware dynamic-batching inference server runtime.

The layer between request traffic and the compiled streaming pipeline
(``repro.deploy``): a dynamic batcher (``router``) coalesces arriving
requests into padded micro-batch waves and dispatches them through the
executor's compiled segment programs (``CompiledTinyModel.submit_wave``),
a replica pool (``replica``) places waves across devices by least
outstanding work, an injectable dispatch engine (``dispatch``) decides
whether waves block in the submit path (``SyncEngine`` — the exact
discrete-event default) or overlap across replicas through an in-flight
table (``AsyncEngine`` — JAX async dispatch, completions reaped by the
event loop), an admission controller (``slo``) sheds load before the
p99 budget blows using the FIFO cost model calibrated by measured stage
latencies, traffic generators (``traffic``) produce seedable
Poisson/bursty/diurnal/replay arrival traces, and sliding-window metrics
(``metrics``) report percentiles, throughput, shed rate, and wave
occupancy. A seedable fault-injection plane (``faults``) drives wave
timeouts, replica crashes/slowdowns, corrupt outputs, and transient
submit errors through a deterministic schedule, and the router answers
with wave deadlines, bounded retries, a replica health state machine,
and an output integrity guard — see ``docs/faults.md``.
Everything reads time through an injectable clock (``clock``),
so the whole server is a deterministic discrete-event system under
``ManualClock`` — see ``docs/serving.md``.

    from repro.serve import Router, RouterConfig, poisson_trace
    router = Router({"ic": compiled}, RouterConfig(p99_budget_ms=50.0))
    done = router.run_trace("ic", poisson_trace(qps=200, n=512), make_query)
"""

from repro.serve.clock import ManualClock, SystemClock  # noqa: F401
from repro.serve.dispatch import (  # noqa: F401
    AsyncEngine,
    DispatchEngine,
    SyncEngine,
    WaveHandle,
)
from repro.serve.faults import (  # noqa: F401
    DEFAULT_OUTPUT_BOUND,
    CorruptWave,
    FaultError,
    FaultPlan,
    FaultSpec,
    FaultyModel,
    NoReplicaAvailable,
    ReplicaCrashed,
    TransientSubmitError,
    WaveError,
    WaveTimeout,
    faulty_pool,
    wave_integrity_ok,
)
from repro.serve.metrics import (  # noqa: F401
    MetricsSnapshot,
    ServeMetrics,
)
from repro.serve.replica import Replica, ReplicaPool  # noqa: F401
from repro.serve.router import (  # noqa: F401
    Router,
    RouterConfig,
    ServeRequest,
)
from repro.serve.slo import (  # noqa: F401
    PredictedServiceModel,
    ServiceModel,
    SLOController,
    measure_wave_service_s,
    queued_waves,
    slo_operating_point,
)
from repro.serve.traffic import (  # noqa: F401
    GENERATORS,
    Trace,
    diurnal_trace,
    mmpp_trace,
    poisson_trace,
    replay_trace,
)
