"""Sliding-window serving metrics: latency percentiles, throughput, shed
rate, and the batch-occupancy histogram.

The router records three event kinds — admissions/sheds, wave dispatches,
and request completions — against an injectable clock. ``snapshot`` prunes
everything older than the window and reports the numbers the SLO story is
judged on: p50/p90/p99 latency, completion throughput, the fraction of
offered load that was shed, and how full the dispatched waves were (the
dynamic batcher's efficiency: occupancy 1.0 means every wave left full,
low occupancy means deadline flushes dominate).

All accounting is exact arithmetic over recorded timestamps — under a
``ManualClock`` every reported percentile is reproducible to the bit,
which is what the hand-simulated-trace tests check.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Dict, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class MetricsSnapshot:
    """One window's worth of serving numbers (latencies in ms)."""

    window_s: float
    n_completed: int
    n_shed: int
    n_admitted: int
    p50_ms: float
    p90_ms: float
    p99_ms: float
    throughput_qps: float
    shed_rate: float
    n_waves: int
    mean_occupancy: float                 # mean n_valid / micro_batch
    occupancy_hist: Dict[int, int]        # n_valid -> wave count
    #: median measured wave service time (submit -> completion) across the
    #: window's waves; 0.0 when no wave carried a measurement. The number
    #: the lane's EWMA placement estimate converges to.
    wave_service_p50_ms: float = 0.0
    #: fault kind -> count in the window (retried timeouts, integrity
    #: violations, crashed submissions...) — the chaos observability story
    fault_counts: Dict[str, int] = dataclasses.field(default_factory=dict)
    #: shed reason -> count ("slo" admission sheds, "no_replica",
    #: "retries_exhausted")
    shed_reasons: Dict[str, int] = dataclasses.field(default_factory=dict)

    def row(self) -> Dict[str, object]:
        return {
            "completed": self.n_completed, "shed": self.n_shed,
            "p50_ms": round(self.p50_ms, 4), "p90_ms": round(self.p90_ms, 4),
            "p99_ms": round(self.p99_ms, 4),
            "qps": round(self.throughput_qps, 1),
            "shed_rate": round(self.shed_rate, 4),
            "waves": self.n_waves,
            "occupancy": round(self.mean_occupancy, 3),
            "wave_service_p50_ms": round(self.wave_service_p50_ms, 4),
            "faults": dict(self.fault_counts),
            "shed_reasons": dict(self.shed_reasons),
        }


class ServeMetrics:
    """Event recorder with a time-based sliding window."""

    def __init__(self, window_s: float = 30.0, start_t: float = 0.0):
        self.window_s = float(window_s)
        self.start_t = float(start_t)
        #: timestamp of the first recorded event of any kind — the
        #: throughput window opens here, not at recorder creation (a
        #: recorder idling long before traffic must not dilute qps)
        self.first_event_t: Optional[float] = None
        self._completions: Deque[Tuple[float, float]] = collections.deque()
        self._admits: Deque[float] = collections.deque()
        self._sheds: Deque[Tuple[float, str]] = collections.deque()
        #: (t, kind) per observed fault event (timeout, integrity, ...)
        self._faults: Deque[Tuple[float, str]] = collections.deque()
        #: (t, n_valid, micro_batch, service_s or None) per dispatched wave
        self._waves: Deque[Tuple[float, int, int, Optional[float]]] = \
            collections.deque()

    def _mark(self, now: float) -> None:
        if self.first_event_t is None:
            self.first_event_t = float(now)

    # -- event recorders ---------------------------------------------------
    def record_admit(self, now: float) -> None:
        self._mark(now)
        self._admits.append(now)

    def record_shed(self, now: float, reason: str = "slo") -> None:
        """One rejected request; ``reason`` distinguishes admission sheds
        ("slo", the default every legacy caller gets) from failure-path
        sheds ("no_replica", "retries_exhausted")."""
        self._mark(now)
        self._sheds.append((now, str(reason)))

    def record_fault(self, now: float, kind: str) -> None:
        """One observed fault event (a wave timeout, a corrupt output, a
        crashed/failed submission) — counted per kind in the window.
        Faults are *not* sheds: a retried wave that eventually lands shows
        up here but never in the shed rate."""
        self._mark(now)
        self._faults.append((now, str(kind)))

    def record_completion(self, now: float, latency_s: float) -> None:
        self._mark(now)
        self._completions.append((now, latency_s))

    def record_wave(self, now: float, n_valid: int, micro_batch: int,
                    service_s: Optional[float] = None) -> None:
        """One dispatched wave; ``service_s`` is its measured submit ->
        completion time when the caller settles completions (the router's
        completion callback does; legacy callers may omit it)."""
        self._mark(now)
        self._waves.append((now, int(n_valid), int(micro_batch),
                            None if service_s is None else float(service_s)))

    # -- window accounting -------------------------------------------------
    def _prune(self, now: float) -> None:
        """Drop events strictly older than ``now - window_s``.

        The boundary is **inclusive**: an event stamped *exactly* at
        ``now - window_s`` stays in the window (the comparison is ``<``,
        not ``<=``). Under a manual clock events routinely land exactly on
        window edges, so the tie direction is part of the contract the
        exact-accounting tests rely on — don't flip it.
        """
        cutoff = now - self.window_s
        while self._completions and self._completions[0][0] < cutoff:
            self._completions.popleft()
        while self._admits and self._admits[0] < cutoff:
            self._admits.popleft()
        while self._sheds and self._sheds[0][0] < cutoff:
            self._sheds.popleft()
        while self._faults and self._faults[0][0] < cutoff:
            self._faults.popleft()
        while self._waves and self._waves[0][0] < cutoff:
            self._waves.popleft()

    def snapshot(self, now: float) -> MetricsSnapshot:
        self._prune(now)
        lats = np.asarray([l for _, l in self._completions]) * 1e3
        if lats.size:
            p50, p90, p99 = (float(np.percentile(lats, q))
                             for q in (50, 90, 99))
        else:
            p50 = p90 = p99 = 0.0
        # the throughput window only opens as far back as traffic has
        # existed: the denominator starts at the FIRST recorded event, not
        # at recorder creation. A server that came up long before its
        # first request (or spent its cold start shedding everything —
        # sheds mark the window open too, since shedding time is serving
        # time) used to have ``span`` pinned at the recorder lifetime,
        # diluting qps once completions finally arrived.
        opened = self.first_event_t if self.first_event_t is not None else now
        span = max(min(now - opened, self.window_s), 1e-9)
        offered = len(self._admits) + len(self._sheds)
        hist: Dict[int, int] = {}
        occ = 0.0
        services = []
        for _, n_valid, mb, service_s in self._waves:
            hist[n_valid] = hist.get(n_valid, 0) + 1
            occ += n_valid / max(mb, 1)
            if service_s is not None:
                services.append(service_s)
        wave_p50 = (float(np.percentile(np.asarray(services) * 1e3, 50))
                    if services else 0.0)
        faults: Dict[str, int] = {}
        for _, kind in self._faults:
            faults[kind] = faults.get(kind, 0) + 1
        reasons: Dict[str, int] = {}
        for _, reason in self._sheds:
            reasons[reason] = reasons.get(reason, 0) + 1
        return MetricsSnapshot(
            window_s=self.window_s,
            n_completed=len(self._completions),
            n_shed=len(self._sheds),
            n_admitted=len(self._admits),
            p50_ms=p50, p90_ms=p90, p99_ms=p99,
            throughput_qps=len(self._completions) / span,
            shed_rate=len(self._sheds) / offered if offered else 0.0,
            n_waves=len(self._waves),
            mean_occupancy=occ / len(self._waves) if self._waves else 0.0,
            occupancy_hist=hist,
            wave_service_p50_ms=wave_p50,
            fault_counts=faults,
            shed_reasons=reasons,
        )
