"""Dispatch engines: how assembled waves reach replicas.

The router's batching policy (when a wave forms) is separate from its
dispatch policy (how the wave's execution relates to the submit path),
and the second one is what this module makes injectable:

  * ``SyncEngine`` — submit and block. The wave completes inside
    ``Router._dispatch`` before the next line runs, exactly the pre-engine
    semantics: under ``ManualClock`` the scripted executor advances the
    clock during the blocking call and every existing hand-simulated trace
    stays bit-identical.

  * ``AsyncEngine`` — submit and return. ``Replica.submit`` launches the
    wave (``device_put`` + ``submit_wave``; JAX's async dispatch means the
    returned arrays are promises, not results) and hands back a
    ``WaveHandle``; the router parks it in an in-flight table and *reaps*
    completions on its next event-loop pass. Waves on different replicas
    overlap — the pool finally runs as wide as it is — and each replica is
    double-buffered up to ``max_inflight`` waves before the engine applies
    backpressure by reaping its oldest wave.

Both engines speak one protocol — ``dispatch`` returns either a completed
wave or an in-flight handle — so the router's completion bookkeeping
(metrics, SLO feedback, pool credit, trace spans) lives in exactly one
place, ``Router._complete``, no matter which engine is driving.

Discrete-event testing survives the split: a scripted model can expose
``submit_wave_async`` returning an object with ``ready_t`` (absolute
completion time on the injected clock) and ``wait()``; the handle then
reports readiness against the manual clock and ``Router.reap`` settles
completions in ``ready_t`` order, so two overlapping waves on two
replicas take max — not sum — of their service times, exactly.
"""

from __future__ import annotations

from typing import Optional, Tuple

from repro.serve.faults import WaveTimeout


class WaveHandle:
    """One in-flight wave on one replica.

    Wraps either a model-level async handle (``submit_wave_async`` — the
    scripted-fake path) or raw ``submit_wave`` outputs (the JAX path,
    where ``y`` is an unmaterialized device promise).

    ``ready_t`` is the absolute completion time on the injected clock when
    the model can script it (manual-clock fakes), else ``None`` (real
    devices don't pre-announce). ``done_t`` is set by ``wait()`` when the
    model knows the true completion instant; the router falls back to its
    own clock reading otherwise.

    ``deadline_t`` is the router's wave timeout (submit time + the lane's
    service estimate x ``RouterConfig.wave_timeout_mult``), ``None`` when
    timeouts are off. A wave still unfinished past its deadline is
    ``cancel``-ed: the handle reports not-ready forever after, and a
    late ``wait`` raises ``WaveTimeout`` instead of handing a client a
    result the router already re-dispatched elsewhere.
    """

    def __init__(self, replica, y=None, mask=None, *, inner=None):
        self.replica = replica
        self._y = y
        self._mask = mask
        self._inner = inner           # model-level async handle, if any
        self._result: Optional[Tuple[object, object]] = None
        self.ready_t: Optional[float] = getattr(inner, "ready_t", None)
        self.done_t: Optional[float] = None
        self.deadline_t: Optional[float] = None
        self.cancelled = False

    def cancel(self) -> None:
        """Abandon the wave: the device may still finish it, but its
        result must never reach a client (the router re-dispatched the
        requests). Idempotent; a completed handle keeps its result."""
        if self._result is None:
            self.cancelled = True

    def ready(self, now: Optional[float] = None) -> bool:
        """Non-blocking readiness probe. Scripted handles compare their
        ``ready_t`` against the caller's clock; JAX arrays answer
        ``is_ready``; anything else is conservatively "ready" (the
        subsequent ``wait`` blocks as needed)."""
        if self._result is not None:
            return True
        if self.cancelled:
            return False
        if self.ready_t is not None:
            return now is not None and now >= self.ready_t
        probe = getattr(self._y, "is_ready", None)
        if probe is not None:
            try:
                return bool(probe())
            except Exception:  # pragma: no cover - defensive
                return True
        return True

    def wait(self) -> Tuple[object, object]:
        """Block until the wave's result is materialized (idempotent).
        A cancelled handle raises ``WaveTimeout`` instead of blocking —
        the wave was abandoned past its deadline and its requests live
        elsewhere now."""
        if self._result is not None:
            return self._result
        if self.cancelled:
            raise WaveTimeout(
                f"wave on replica {getattr(self.replica, 'index', '?')} "
                "was cancelled past its deadline")
        if self._inner is not None:
            y, mask = self._inner.wait()
            self.done_t = getattr(self._inner, "done_t", self.ready_t)
        else:
            y, mask = self._y, self._mask
            try:
                import jax

                y = jax.block_until_ready(y)
            except ImportError:  # pragma: no cover - jax is a hard dep
                pass
        self._result = (y, mask)
        return self._result


class DispatchEngine:
    """Protocol: ``submit`` launches a wave on a replica, returning a
    ``WaveHandle``; ``blocking`` tells the router whether to complete the
    wave inline (sync) or park the handle in its in-flight table (async)."""

    blocking = True
    #: per-replica in-flight ceiling before the router must reap (the
    #: async engine's backpressure knob; irrelevant when blocking)
    max_inflight = 1

    def submit(self, replica, x, valid=None, micro_batch=None) -> WaveHandle:
        return replica.submit(x, valid=valid, micro_batch=micro_batch)


class SyncEngine(DispatchEngine):
    """Blocking dispatch: today's semantics, bit-exact. The wave is
    submitted and waited on inside the router's dispatch call, so manual
    clocks advance inside ``_dispatch`` exactly as before the engine
    split."""

    blocking = True
    max_inflight = 1


class AsyncEngine(DispatchEngine):
    """Non-blocking dispatch: submit the wave, return the handle, let the
    router overlap waves across replicas and reap completions on its next
    event-loop pass.

    ``max_inflight`` bounds uncompleted waves per replica (2 =
    double-buffering: one executing, one queued behind it); at the bound
    the router block-reaps the replica's oldest wave before submitting —
    backpressure instead of unbounded device queues.
    """

    blocking = False

    def __init__(self, max_inflight: int = 2):
        if max_inflight < 1:
            raise ValueError(f"max_inflight must be >= 1, got {max_inflight}")
        self.max_inflight = int(max_inflight)
