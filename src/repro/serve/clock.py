"""Injectable clocks for the serve runtime.

Every serve component reads time through one of these objects instead of
the ``time`` module, so the whole server — batching deadlines, SLO
estimates, sliding-window metrics, trace replay — runs identically under
the real monotonic clock and under a test-controlled manual clock (the
same trick ``tests/test_scenarios.py`` plays on the scenario runtime, made
first-class here because the router's correctness *is* its timing).
"""

from __future__ import annotations

import time


class SystemClock:
    """The real monotonic clock."""

    def now(self) -> float:
        return time.monotonic()

    def sleep(self, seconds: float) -> None:
        if seconds > 0:
            time.sleep(seconds)


class ManualClock:
    """Deterministic clock: time moves only when told to.

    ``sleep`` advances instead of blocking, so trace replay under a
    ManualClock is an exact discrete-event simulation — every latency the
    metrics report is reproducible arithmetic, not wall-clock noise.
    """

    def __init__(self, start: float = 0.0):
        self.t = float(start)

    def now(self) -> float:
        return self.t

    def sleep(self, seconds: float) -> None:
        # typed, not a bare assert: sleeping a negative duration would
        # silently run time backwards under ``python -O``
        if seconds < 0:
            raise ValueError(f"cannot sleep a negative duration: {seconds}")
        self.t += seconds

    def advance(self, seconds: float) -> None:
        if seconds < 0:
            raise ValueError(f"cannot advance time backwards: {seconds}")
        self.t += seconds
