"""Deterministic fault injection + the failure taxonomy for the serving
stack.

The paper's accelerators sit behind a host–device boundary where the real
failure modes live: stalled DMA waves, hung replicas, transient submit
errors, silent numeric corruption. This module gives the router a typed
vocabulary for those failures and a *seedable, clock-driven* way to
inject them, exploiting the one asset this repo has that real clusters
don't — the whole server is an exact discrete-event simulation under
``ManualClock``, so chaos tests are byte-for-byte reproducible.

Three pieces:

  * **Taxonomy** — ``FaultError`` and its subclasses are the failures the
    router knows how to *survive* (retry on another replica, quarantine,
    shed with a reason code). Anything else escaping a wave is a bug and
    still propagates. ``WaveError`` wraps executor-side execution
    failures so raw backend exceptions never escape ``submit_wave``.
  * **FaultPlan / FaultSpec** — a deterministic schedule of injectable
    faults keyed by (replica, wave-index or clock-window). The sim layer
    (``serve.sim.ScriptedWaveModel``) consults the plan on every submit;
    the real path gets the same plan through ``FaultyModel``, a wrapper
    around any ``submit_wave`` executor.
  * **Integrity guard** — ``wave_integrity_ok`` is the cheap per-wave
    output check the router runs at settle time: finite, and in range
    against the lowering's proven integer bound (every exact fast path is
    proven ``< 2**24`` — ``deploy.lower._float_mm_safe`` — so any larger
    magnitude is corruption, not a big activation). ``corrupt_output``
    faults are caught here and routed to retry instead of being served.

See ``docs/faults.md`` for the taxonomy table, the replica health state
machine, and the retry/backoff pricing.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence

import numpy as np

# -- taxonomy ---------------------------------------------------------------


class FaultError(RuntimeError):
    """Base of the failures the router survives (retry/quarantine/shed).

    Subclassing ``RuntimeError`` keeps legacy ``except RuntimeError``
    callers working; the router itself catches ``FaultError`` so
    *unexpected* exceptions (genuine bugs) still propagate loudly.
    """


class WaveError(FaultError):
    """A wave failed inside the executor: the typed wrapper around any
    backend/runtime exception escaping ``submit_wave`` execution (the
    input-validation ``ValueError``s are *not* wrapped — a malformed wave
    is a caller bug, not a device failure)."""


class WaveTimeout(FaultError):
    """An in-flight wave missed its deadline (lost response / hung
    device) and was cancelled by the router."""


class ReplicaCrashed(FaultError):
    """A replica refused the wave because it is down (crash outage)."""


class TransientSubmitError(FaultError):
    """Submission itself failed transiently (queue full, DMA hiccup);
    the wave never reached the device and is safe to retry anywhere."""


class CorruptWave(FaultError):
    """A completed wave failed the output integrity guard (non-finite or
    out of the proven integer range) — served to retry, never to a
    client."""


class NoReplicaAvailable(FaultError):
    """The pool has no replica to place a wave on: empty, or every
    replica quarantined with no probe due. The router sheds the wave with
    a distinct reason code instead of hanging."""


# -- output integrity guard -------------------------------------------------

#: The lowering exactness bound: every integer fast path is admitted only
#: when its worst-case magnitude is proven ``< 2**24`` (exact in float32 —
#: ``deploy.lower._float_mm_safe`` and the threshold-bank check). A healthy
#: wave can therefore never carry a magnitude past this; the float head's
#: logits are far smaller still. Anything bigger is corruption.
DEFAULT_OUTPUT_BOUND = float(1 << 24)


def wave_integrity_ok(y, bound: float = DEFAULT_OUTPUT_BOUND) -> bool:
    """Cheap per-wave output check: every value finite and within
    ``bound`` in magnitude. O(wave) numpy reductions — negligible next to
    the wave's own matmuls."""
    y = np.asarray(y)
    if y.size == 0:
        return True
    if y.dtype.kind == "f" and not bool(np.isfinite(y).all()):
        return False
    return bool(np.abs(y.astype(np.float64, copy=False)).max() <= bound)


# -- the fault plan ---------------------------------------------------------

#: Injectable fault kinds (the ``FaultSpec.kind`` vocabulary).
FAULT_KINDS = ("wave_timeout", "replica_crash", "replica_slowdown",
               "corrupt_output", "transient_submit_error")


@dataclasses.dataclass
class FaultSpec:
    """One scheduled fault: *what* happens on *which* replica, *when*.

    Keyed either by ``wave`` (the 1-based index of the submission attempt
    on that replica) or by a clock window ``[after_t, until_t)``. All
    kinds except ``replica_slowdown`` are consumable events
    (``n_times`` firings, then inert); a slowdown is a modifier that
    applies to every wave inside its window.

    ``factor`` scales service time for ``replica_slowdown``;
    ``duration_s`` is the outage length for ``replica_crash`` (``inf`` =
    never recovers on its own — only useful with the router's probe
    machinery disabled) and, when finite, how long a ``wave_timeout``'s
    response is delayed before the handle is abandoned.
    """

    kind: str
    replica: int = 0
    wave: Optional[int] = None
    after_t: Optional[float] = None
    until_t: float = math.inf
    factor: float = 2.0
    duration_s: float = math.inf
    n_times: int = 1

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"one of {FAULT_KINDS}")
        if self.wave is None and self.after_t is None:
            raise ValueError(
                "a FaultSpec needs a key: wave= (1-based wave index) or "
                "after_t= (clock-window start)")
        if self.kind == "replica_slowdown" and self.factor <= 0:
            raise ValueError(f"slowdown factor must be > 0, "
                             f"got {self.factor}")

    def matches(self, replica: int, wave: int, now: float) -> bool:
        if replica != self.replica:
            return False
        if self.wave is not None:
            return wave == self.wave
        return self.after_t <= now < self.until_t


class FaultPlan:
    """A deterministic schedule of faults, shared by every replica of a
    pool (specs name their replica). ``active`` is the single consultation
    point: it returns the specs firing for this (replica, wave, now) and
    consumes one firing from each consumable spec, so a plan replayed
    under the same clock produces the identical fault sequence — the
    determinism the chaos suite's byte-identical-trace check rests on.
    """

    def __init__(self, specs: Sequence[FaultSpec] = (),
                 seed: Optional[int] = None):
        self.specs: List[FaultSpec] = list(specs)
        self.seed = seed
        self._remaining = [s.n_times for s in self.specs]

    def __repr__(self):
        return f"FaultPlan({self.specs!r}, seed={self.seed!r})"

    def reset(self) -> None:
        """Re-arm every consumable spec (replaying the same run)."""
        self._remaining = [s.n_times for s in self.specs]

    def active(self, replica: int, wave: int, now: float
               ) -> List[FaultSpec]:
        out = []
        for i, s in enumerate(self.specs):
            if not s.matches(replica, wave, now):
                continue
            if s.kind == "replica_slowdown":     # modifier, never consumed
                out.append(s)
            elif self._remaining[i] > 0:
                self._remaining[i] -= 1
                out.append(s)
        return out

    @classmethod
    def chaos(cls, seed: int, n_replicas: int, horizon_s: float,
              n_faults: int = 4,
              kinds: Sequence[str] = FAULT_KINDS) -> "FaultPlan":
        """A seeded random plan: ``n_faults`` faults of the given kinds,
        uniformly placed over ``[0, horizon_s)`` across the replicas.
        Pure function of its arguments — two plans built from the same
        seed are identical, so a chaos run is reproducible end to end."""
        rng = np.random.default_rng(seed)
        specs = []
        for _ in range(int(n_faults)):
            kind = str(rng.choice(list(kinds)))
            t0 = float(rng.uniform(0.0, horizon_s))
            spec = FaultSpec(
                kind=kind,
                replica=int(rng.integers(0, max(n_replicas, 1))),
                after_t=t0,
                until_t=(t0 + float(rng.uniform(0.05, 0.25)) * horizon_s
                         if kind == "replica_slowdown" else math.inf),
                factor=float(rng.uniform(1.5, 4.0)),
                duration_s=float(rng.uniform(0.05, 0.25)) * horizon_s)
            specs.append(spec)
        return cls(specs, seed=seed)


# -- real-path injector -----------------------------------------------------


class FaultyModel:
    """Wrap any ``submit_wave`` executor with a ``FaultPlan`` — the real
    (compiled-model) counterpart of the scripted sim's injection.

    The wrapper is deliberately *synchronous* (``submit_wave_async`` is
    pinned to ``None`` so ``Replica.submit`` takes the sync path): faults
    fire inside the submit call, where the blocking engine — and the
    async engine's handle ``wait`` — will see them as typed exceptions.
    Everything else (``default_micro_batch``, ``schedule``, ...) passes
    through to the wrapped model, so the wrapper drops into a
    ``ReplicaPool`` wherever the real model did.
    """

    #: pin the async protocol off: Replica.submit probes this attribute
    #: and must fall through to ``submit_wave`` for faults to fire in-line
    submit_wave_async = None

    def __init__(self, model, plan: FaultPlan, replica: int = 0,
                 clock=None):
        self._model = model
        self.plan = plan
        self.replica = int(replica)
        self._clock = clock            # None -> the injectable obs timer
        self.n_attempts = 0
        self.crashed_until = -math.inf
        self.n_injected = 0

    def __getattr__(self, name):
        return getattr(self._model, name)

    def _now(self) -> float:
        if self._clock is not None:
            return self._clock.now()
        from repro.obs import timer as obs_timer

        return obs_timer.now()

    def _sleep(self, seconds: float) -> None:
        if seconds <= 0:
            return
        if self._clock is not None:
            self._clock.sleep(seconds)
        else:
            from repro.obs import timer as obs_timer

            obs_timer.sleep(seconds)

    def submit_wave(self, x, valid=None, micro_batch=None):
        now = self._now()
        self.n_attempts += 1
        if now < self.crashed_until:
            raise ReplicaCrashed(
                f"replica {self.replica} is down until "
                f"t={self.crashed_until:.6f} (now t={now:.6f})")
        slowdown = 1.0
        corrupt = timeout = None
        for f in self.plan.active(self.replica, self.n_attempts, now):
            self.n_injected += 1
            if f.kind == "replica_crash":
                self.crashed_until = now + f.duration_s
                raise ReplicaCrashed(
                    f"replica {self.replica} crashed at t={now:.6f} "
                    f"(outage {f.duration_s}s)")
            if f.kind == "transient_submit_error":
                raise TransientSubmitError(
                    f"replica {self.replica} wave {self.n_attempts}: "
                    "transient submit failure")
            if f.kind == "replica_slowdown":
                slowdown *= f.factor
            elif f.kind == "corrupt_output":
                corrupt = f
            elif f.kind == "wave_timeout":
                timeout = f
        t0 = self._now()
        y, mask = self._model.submit_wave(x, valid=valid,
                                          micro_batch=micro_batch)
        if slowdown > 1.0:
            self._sleep((slowdown - 1.0) * max(self._now() - t0, 0.0))
        if timeout is not None:
            if math.isfinite(timeout.duration_s):
                self._sleep(timeout.duration_s)
            raise WaveTimeout(
                f"replica {self.replica} wave {self.n_attempts}: "
                "response lost (injected)")
        if corrupt is not None:
            y = np.array(y)
            if y.dtype.kind == "f":
                y[..., 0] = np.inf        # non-finite: integrity guard
            else:
                y[..., 0] = y[..., 0] + (1 << 26)   # beyond the 2**24 proof
        return y, mask


def faulty_pool(pool, plan: FaultPlan, clock=None):
    """Wrap every replica of an existing ``ReplicaPool`` in a
    ``FaultyModel`` sharing one plan (replica indices line up with the
    plan's ``FaultSpec.replica`` keys). Returns the pool, mutated."""
    for r in pool.replicas:
        r.model = FaultyModel(r.model, plan, replica=r.index, clock=clock)
    return pool
