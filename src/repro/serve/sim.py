"""Scripted replica simulator: exact discrete-event fakes for the router.

``ScriptedWaveModel`` speaks the executor's ``submit_wave_async``
protocol against a ``ManualClock``: submitting a wave *schedules* its
completion (``ready_t = max(now, busy_until) + service_s``) without
advancing the clock, the way a real device runs a wave in the background
under JAX async dispatch. Each instance serializes its own waves (one
device, one pipeline); instances built by a pool factory are independent,
so waves on different replicas overlap and an N-replica pool behaves as N
parallel servers with deterministic, hand-checkable timing.

Two consumers:

  * ``tests/test_serve_async.py`` — every expected latency is worked out
    by hand against these fakes, not by re-running the router;
  * ``benchmarks/serve_bench.py`` — the replica-scaling sweep anchors
    ``service_s`` to a *measured* wave service time per model family and
    sweeps replica count as a discrete-event simulation (the container
    exposes one physical device, so real multi-device scaling cannot be
    measured; the simulation isolates the router/engine scheduling from
    the device count).
"""

from __future__ import annotations

from typing import Callable, Sequence, Union

import numpy as np

from repro.serve.replica import ReplicaPool


class ScriptedWaveHandle:
    """In-flight wave on the manual clock: knows its completion instant up
    front; ``wait`` advances the clock there (no-op when reaped late)."""

    def __init__(self, clock, ready_t: float, y, mask):
        self.clock = clock
        self.ready_t = ready_t
        self.done_t = None
        self._y, self._mask = y, mask

    def wait(self):
        self.clock.advance(max(self.ready_t - self.clock.now(), 0.0))
        self.done_t = self.ready_t
        return self._y, self._mask


class ScriptedWaveModel:
    """``submit_wave_async`` fake with the executor's padding contract:
    waves complete ``service_s`` after the instance frees up, scheduled on
    (not advancing) the manual clock. ``service_s`` may be a float or a
    callable of the 1-based wave index (heterogeneous service times).
    Outputs identify their input row (sum of codes) so results trace
    back."""

    def __init__(self, clock, service_s: Union[float, Callable] = 0.003,
                 micro_batch: int = 4):
        self.clock = clock
        self.service_s = service_s
        self.default_micro_batch = micro_batch
        self.calls = []          # (n_valid, micro_batch) per wave
        self.busy_until = 0.0

    def submit_wave_async(self, x, valid=None, micro_batch=None
                          ) -> ScriptedWaveHandle:
        mb = int(micro_batch or self.default_micro_batch)
        x = np.asarray(x)
        n = x.shape[0]
        if n > mb:
            raise ValueError(f"wave of {n} rows exceeds micro_batch={mb}")
        mask = np.ones(n, bool) if valid is None else np.asarray(valid, bool)
        mask = np.concatenate([mask, np.zeros(mb - n, bool)])
        self.calls.append((int(mask.sum()), mb))
        s = self.service_s(len(self.calls)) if callable(self.service_s) \
            else self.service_s
        start = max(self.clock.now(), self.busy_until)
        self.busy_until = start + s
        y = np.zeros((mb, 1), np.float32)
        y[:n, 0] = x.reshape(n, -1).sum(axis=1)
        return ScriptedWaveHandle(self.clock, self.busy_until, y, mask)


def scripted_pool(clock, services: Sequence[Union[float, Callable]],
                  micro_batch: int = 2) -> ReplicaPool:
    """Replica pool whose i-th replica runs at ``services[i]`` per wave —
    the factory hands each replica slot its own independent scripted
    model, so the pool simulates ``len(services)`` devices."""
    it = iter(list(services))
    return ReplicaPool(
        factory=lambda: ScriptedWaveModel(clock, next(it),
                                          micro_batch=micro_batch),
        devices=[None] * len(services))
