"""Scripted replica simulator: exact discrete-event fakes for the router.

``ScriptedWaveModel`` speaks the executor's ``submit_wave_async``
protocol against a ``ManualClock``: submitting a wave *schedules* its
completion (``ready_t = max(now, busy_until) + service_s``) without
advancing the clock, the way a real device runs a wave in the background
under JAX async dispatch. Each instance serializes its own waves (one
device, one pipeline); instances built by a pool factory are independent,
so waves on different replicas overlap and an N-replica pool behaves as N
parallel servers with deterministic, hand-checkable timing.

Fault injection rides the same protocol: give the model (or
``scripted_pool``) a ``serve.faults.FaultPlan`` and scheduled faults fire
deterministically at submit time — a crash refuses the wave (and every
wave until the outage ends), a transient error refuses just this one, a
slowdown stretches the service time, a timeout schedules a wave that
never completes (``ready_t = inf`` — the response is lost but the device
itself recovers), and ``corrupt_output`` poisons the payload past the
integrity guard's proven bound. Because the plan is consulted on the
manual clock, a chaos run replays byte-identically.

Three consumers:

  * ``tests/test_serve_async.py`` — every expected latency is worked out
    by hand against these fakes, not by re-running the router;
  * ``tests/test_faults.py`` — the deterministic chaos suite;
  * ``benchmarks/serve_bench.py`` — the replica-scaling sweep anchors
    ``service_s`` to a *measured* wave service time per model family and
    sweeps replica count as a discrete-event simulation (the container
    exposes one physical device, so real multi-device scaling cannot be
    measured; the simulation isolates the router/engine scheduling from
    the device count).
"""

from __future__ import annotations

import math
from typing import Callable, Optional, Sequence, Union

import numpy as np

from repro.serve.faults import (
    FaultPlan,
    ReplicaCrashed,
    TransientSubmitError,
    WaveTimeout,
)
from repro.serve.replica import ReplicaPool


class ScriptedWaveHandle:
    """In-flight wave on the manual clock: knows its completion instant up
    front; ``wait`` advances the clock there (no-op when reaped late). A
    lost wave (``ready_t = inf`` — injected timeout) refuses to block:
    waiting on it would advance the clock to infinity, so ``wait`` raises
    ``WaveTimeout`` instead — the typed fast-fail that keeps even a
    deadline-less blocking drain from hanging."""

    def __init__(self, clock, ready_t: float, y, mask):
        self.clock = clock
        self.ready_t = ready_t
        self.done_t = None
        self._y, self._mask = y, mask

    def wait(self):
        if not math.isfinite(self.ready_t):
            raise WaveTimeout(
                "scripted wave never completes (injected timeout)")
        self.clock.advance(max(self.ready_t - self.clock.now(), 0.0))
        self.done_t = self.ready_t
        return self._y, self._mask


class ScriptedWaveModel:
    """``submit_wave_async`` fake with the executor's padding contract:
    waves complete ``service_s`` after the instance frees up, scheduled on
    (not advancing) the manual clock. ``service_s`` may be a float or a
    callable of the 1-based wave index (heterogeneous service times).
    Outputs identify their input row (sum of codes) so results trace
    back.

    ``plan`` injects faults (``serve.faults.FaultPlan``); specs keyed by
    ``wave=`` count 1-based *submission attempts* on this replica
    (``n_attempts`` — refused submissions included), while ``calls``
    keeps its historical meaning of accepted waves only.
    """

    def __init__(self, clock, service_s: Union[float, Callable] = 0.003,
                 micro_batch: int = 4, plan: Optional[FaultPlan] = None,
                 replica: int = 0):
        self.clock = clock
        self.service_s = service_s
        self.default_micro_batch = micro_batch
        self.plan = plan
        self.replica = int(replica)
        self.calls = []          # (n_valid, micro_batch) per accepted wave
        self.busy_until = 0.0
        self.n_attempts = 0      # submissions offered, accepted or not
        self.crashed_until = -math.inf

    def submit_wave_async(self, x, valid=None, micro_batch=None
                          ) -> ScriptedWaveHandle:
        now = self.clock.now()
        self.n_attempts += 1
        if now < self.crashed_until:
            raise ReplicaCrashed(
                f"replica {self.replica} is down until "
                f"t={self.crashed_until:.6f} (now t={now:.6f})")
        mb = int(micro_batch or self.default_micro_batch)
        x = np.asarray(x)
        n = x.shape[0]
        if n > mb:
            raise ValueError(f"wave of {n} rows exceeds micro_batch={mb}")
        mask = np.ones(n, bool) if valid is None else np.asarray(valid, bool)
        mask = np.concatenate([mask, np.zeros(mb - n, bool)])
        s = self.service_s(len(self.calls) + 1) if callable(self.service_s) \
            else self.service_s
        lost = corrupt = False
        if self.plan is not None:
            for f in self.plan.active(self.replica, self.n_attempts, now):
                if f.kind == "replica_crash":
                    self.crashed_until = now + f.duration_s
                    raise ReplicaCrashed(
                        f"replica {self.replica} crashed at t={now:.6f} "
                        f"(outage {f.duration_s}s)")
                if f.kind == "transient_submit_error":
                    raise TransientSubmitError(
                        f"replica {self.replica} wave {self.n_attempts}: "
                        "transient submit failure")
                if f.kind == "replica_slowdown":
                    s *= f.factor
                elif f.kind == "wave_timeout":
                    lost = True
                elif f.kind == "corrupt_output":
                    corrupt = True
        self.calls.append((int(mask.sum()), mb))
        start = max(self.clock.now(), self.busy_until)
        # the device still *runs* a lost wave (it burns service time and
        # then recovers); only the response never arrives
        self.busy_until = start + s
        y = np.zeros((mb, 1), np.float32)
        y[:n, 0] = x.reshape(n, -1).sum(axis=1)
        if corrupt:
            y[:n, 0] += 2.0 ** 26        # beyond the proven 2**24 bound
        ready_t = math.inf if lost else self.busy_until
        return ScriptedWaveHandle(self.clock, ready_t, y, mask)


def scripted_pool(clock, services: Sequence[Union[float, Callable]],
                  micro_batch: int = 2, plan: Optional[FaultPlan] = None,
                  probe_interval_s: float = 0.05) -> ReplicaPool:
    """Replica pool whose i-th replica runs at ``services[i]`` per wave —
    the factory hands each replica slot its own independent scripted
    model, so the pool simulates ``len(services)`` devices. ``plan`` is
    shared across the replicas (specs name theirs by index)."""
    svc = list(services)
    slots = iter(range(len(svc)))

    def make():
        i = next(slots)
        return ScriptedWaveModel(clock, svc[i], micro_batch=micro_batch,
                                 plan=plan, replica=i)

    return ReplicaPool(factory=make, devices=[None] * len(svc),
                       probe_interval_s=probe_interval_s)
