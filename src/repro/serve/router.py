"""The dynamic batcher: request traffic in, compiled segment waves out.

This is the runtime that was missing between individual requests and the
PR-4 compiled streaming pipeline. Per model ("lane") the router keeps a
pending queue and two dispatch triggers:

  * **full wave** — the moment ``micro_batch`` requests (the autotuned wave
    size by default) are queued, they leave as one wave;
  * **deadline flush** — the oldest pending request never waits longer than
    ``max_wait_ms``: when the deadline passes, the partial wave leaves
    anyway, zero-padded through the executor's ``submit_wave`` padding-mask
    contract (padded rows are inert; valid rows stay bit-exact vs
    ``offline``).

Waves are placed on a ``ReplicaPool`` by least outstanding work, and an
optional ``SLOController`` sheds arrivals whose estimated completion
would blow the per-model p99 budget. *How* a placed wave executes is the
injectable ``DispatchEngine``'s business (``serve.dispatch``): the
default ``SyncEngine`` blocks inside dispatch (the original semantics),
while ``AsyncEngine`` submits without waiting — the router parks a
``WaveHandle`` per wave in its in-flight table and **reaps** completions
on every event-loop pass, so waves on different replicas overlap and an
N-replica pool finally runs N wide. Completion bookkeeping (result
stamping, metrics, SLO feedback, pool credit, trace spans) lives in one
place — ``_complete`` — for both engines.

All timing goes through an injectable clock, so the router is an exact
discrete-event system under ``ManualClock`` — the property the
hand-simulated-trace tests exploit — and a real server under
``SystemClock``.

Typical use (the ``ServerStreaming`` scenario, the serve bench, and the
``TinyModelServer`` compatibility shim are all thin wrappers over this):

    router = Router({"ic": cm}, RouterConfig(max_wait_ms=2.0,
                                             p99_budget_ms=50.0),
                    engine=AsyncEngine())
    done = router.run_trace("ic", poisson_trace(qps, n), make_query)
    print(router.stats()["ic"]["metrics"])
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Callable, Deque, Dict, List, Optional, Union

import numpy as np

from repro.obs.tracer import NULL_TRACER
from repro.serve.clock import SystemClock
from repro.serve.dispatch import DispatchEngine, SyncEngine, WaveHandle
from repro.serve.metrics import ServeMetrics
from repro.serve.replica import Replica, ReplicaPool
from repro.serve.slo import ServiceModel, SLOController, queued_waves
from repro.serve.traffic import Trace

#: Sleep bound while waves with unannounced completion times are in
#: flight (real devices under ``SystemClock``): the event loop wakes at
#: least this often to reap, so completion stamping lags the device by at
#: most one poll. Scripted handles announce ``ready_t`` and never poll —
#: manual-clock runs stay exact discrete-event simulations.
_POLL_S = 0.5e-3


def _backend_name() -> str:
    """The platform string stamped on dispatch spans (prediction-error
    rows group by it); empty when jax isn't importable."""
    try:
        import jax

        return str(jax.default_backend())
    except Exception:  # pragma: no cover
        return ""


@dataclasses.dataclass
class ServeRequest:
    """One inference request as the router tracks it."""

    uid: int
    model: str
    x: np.ndarray
    arrival_t: float
    done_t: float = 0.0
    result: Optional[np.ndarray] = None
    shed: bool = False

    @property
    def latency_s(self) -> float:
        return self.done_t - self.arrival_t


@dataclasses.dataclass
class RouterConfig:
    """Per-model routing policy.

    ``micro_batch=None`` consumes the executor's (autotuned) default wave
    size; ``p99_budget_ms=None`` disables shedding (every request is
    admitted). ``slo_headroom`` scales the budget the admission test uses
    (0.8 = shed at 80% of budget, keeping margin for estimate error).
    """

    max_wait_ms: float = 2.0
    micro_batch: Optional[int] = None
    p99_budget_ms: Optional[float] = None
    slo_headroom: float = 1.0
    window_s: float = 30.0
    #: False = never dispatch from inside ``submit`` (a full wave waits for
    #: the next ``step``/``dispatch_one``) — the explicitly-stepped
    #: compatibility mode the ``TinyModelServer`` shim runs in.
    auto_dispatch: bool = True


class _Lane:
    """Internal per-model state: pool + queue + policy + metrics."""

    #: EWMA weight for the measured-wave-time fallback service estimate
    #: (same spirit as ``SLOController.ewma_alpha``).
    EWMA_ALPHA = 0.25

    def __init__(self, name: str, pool: ReplicaPool, cfg: RouterConfig,
                 slo: Optional[SLOController], start_t: float,
                 service: Optional[ServiceModel] = None, tid: int = 0):
        self.name = name
        self.pool = pool
        self.cfg = cfg
        self.slo = slo
        #: the raw FIFO-cost-model service estimate (uncorrected by the
        #: SLO controller's EWMA) — what dispatch spans record as the
        #: *predicted* wave service time, the learned-cost-model trail
        self.service = service
        self.tid = tid                       # trace track for this lane
        self.n_shed = 0
        self.n_inflight = 0                  # this lane's unreaped waves
        #: measured-wave-time EWMA: the placement work estimate of last
        #: resort when the lane has neither controller nor service model
        self.ewma_service_s: Optional[float] = None
        self.pending: Deque[ServeRequest] = collections.deque()
        self.metrics = ServeMetrics(window_s=cfg.window_s, start_t=start_t)
        self.micro_batch = int(cfg.micro_batch
                               or pool.default_micro_batch or 1)

    def deadline(self) -> Optional[float]:
        if not self.pending:
            return None
        return self.pending[0].arrival_t + self.cfg.max_wait_ms / 1e3

    def work_estimate_s(self) -> float:
        """The wave service estimate placement charges a replica.

        Best available source wins: the SLO controller's EWMA-corrected
        model, else the raw lane service model, else the measured-wave
        EWMA. Never 0.0 once anything has been observed — with a zero
        charge every replica ties on outstanding work and least-work
        placement silently degenerates to dispatch-count round-robin,
        which misplaces heterogeneous waves.
        """
        if self.slo is not None:
            return self.slo.wave_service_s(self.micro_batch)
        if self.service is not None:
            return self.service.wave_service_s(self.micro_batch)
        return self.ewma_service_s if self.ewma_service_s is not None \
            else 0.0

    def observe_service(self, measured_s: float) -> None:
        """Feed one completed wave's measured service time back into the
        lane's estimate (controller EWMA when present, lane EWMA else)."""
        if self.slo is not None:
            self.slo.observe_service(self.micro_batch, measured_s)
            return
        if measured_s <= 0:
            return
        if self.ewma_service_s is None:
            self.ewma_service_s = float(measured_s)
        else:
            a = self.EWMA_ALPHA
            self.ewma_service_s = \
                (1 - a) * self.ewma_service_s + a * float(measured_s)


@dataclasses.dataclass
class _InFlightWave:
    """One dispatched wave between submit and completion — the in-flight
    table's row (sync waves pass through without ever being parked)."""

    lane: _Lane
    reqs: List[ServeRequest]
    replica: Replica
    handle: WaveHandle
    t0: float                    # submit time (span start, service clock)
    work_s: float                # modeled work charged at placement
    n_valid: int
    seq: int                     # submission order: FIFO reap tiebreak


class Router:
    """Dynamic-batching front end over compiled executors.

    ``models`` maps name -> executor (``CompiledTinyModel`` or anything
    with ``submit_wave``/``default_micro_batch``) or a prebuilt
    ``ReplicaPool``. ``config`` is one ``RouterConfig`` for every model or
    a per-model dict. ``service_models`` supplies the SLO service-time
    model per name; when omitted and a p99 budget is set, it is built from
    the compiled schedule (``ServiceModel.from_compiled`` — FIFO cost
    model calibrated by a ``stage_latencies`` probe). ``engine`` picks the
    dispatch semantics (default ``SyncEngine``; pass ``AsyncEngine()`` to
    overlap waves across replicas).
    """

    def __init__(self, models: Dict[str, object],
                 config: Union[RouterConfig, Dict[str, RouterConfig], None]
                 = None,
                 clock: Optional[object] = None,
                 service_models: Optional[Dict[str, ServiceModel]] = None,
                 tracer: Optional[object] = None,
                 engine: Optional[DispatchEngine] = None):
        self.clock = clock if clock is not None else SystemClock()
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.engine = engine if engine is not None else SyncEngine()
        self.platform = _backend_name() if self.tracer.enabled else ""
        self._uid = 0
        self._wave_seq = 0
        self._inflight: List[_InFlightWave] = []
        self.lanes: Dict[str, _Lane] = {}
        now = self.clock.now()
        for i, (name, model) in enumerate(models.items()):
            cfg = (config.get(name, RouterConfig())
                   if isinstance(config, dict)
                   else (config or RouterConfig()))
            pool = model if isinstance(model, ReplicaPool) \
                else ReplicaPool(model)
            if self.tracer.enabled:
                pool.tracer = self.tracer
            service = (service_models or {}).get(name)
            slo = None
            if cfg.p99_budget_ms is not None:
                if service is None:
                    service = ServiceModel.from_compiled(
                        pool.replicas[0].model)
                slo = SLOController(cfg.p99_budget_ms, service,
                                    window_s=cfg.window_s,
                                    headroom=cfg.slo_headroom)
            self.lanes[name] = _Lane(name, pool, cfg, slo, start_t=now,
                                     service=service, tid=i + 1)

    def trace_names(self) -> Dict[str, Dict]:
        """Process/track naming maps for ``obs.export.export_chrome``:
        pid 0 is the router, pid 1+i replica i; one track per lane."""
        pids = {0: "router"}
        tids = {}
        for lane in self.lanes.values():
            tids[(0, lane.tid)] = f"lane:{lane.name}"
            for r in lane.pool.replicas:
                pids[1 + r.index] = f"replica{r.index}"
                tids[(1 + r.index, lane.tid)] = f"waves:{lane.name}"
        return {"process_names": pids, "thread_names": tids}

    # -- submission --------------------------------------------------------
    def submit(self, model: str, x, arrival_t: Optional[float] = None
               ) -> ServeRequest:
        """Admit (or shed) one request; a full wave dispatches in-line."""
        lane = self._lane(model)
        now = self.clock.now() if arrival_t is None else float(arrival_t)
        req = ServeRequest(uid=self._uid, model=model, x=np.asarray(x),
                           arrival_t=now)
        self._uid += 1
        tr = self.tracer
        if tr.enabled:
            tr.instant("enqueue", t=now, cat="router", tid=lane.tid,
                       uid=req.uid, model=model)
        if lane.slo is not None:
            lane.slo.observe_arrival(now)
            # waves this request must wait out: the ceiling form prices
            # the partial wave it joins, and every still-in-flight wave
            # holds a replica slot so it is queue delay too (zero under
            # the blocking engine, where dispatch and completion coincide)
            backlog_waves = queued_waves(len(lane.pending),
                                         lane.micro_batch, lane.n_inflight)
            # a request admitted late (the server was busy past its arrival
            # time) has already burned budget: the admission estimate must
            # carry that lag, or an overloaded single-worker lane would
            # never shed — its pending queue stays short while the clock
            # falls behind the trace
            lag_s = max(self.clock.now() - now, 0.0)
            if not lane.slo.admit(now, backlog_waves, lane.micro_batch,
                                  lane.cfg.max_wait_ms / 1e3, lag_s=lag_s,
                                  n_workers=lane.pool.n_replicas):
                req.shed = True
                lane.n_shed += 1
                lane.metrics.record_shed(now)
                if tr.enabled:
                    tr.instant("shed", t=now, cat="router", tid=lane.tid,
                               uid=req.uid, model=model)
                    tr.counter("shed_total", lane.n_shed, t=now,
                               tid=lane.tid)
                    # a shed request's span is its (empty) lifetime: it
                    # exists in the trace but not in latency populations
                    tr.add_span("request", now, now, cat="router",
                                tid=lane.tid,
                                args={"uid": req.uid, "model": model,
                                      "shed": True})
                return req
        lane.metrics.record_admit(now)
        lane.pending.append(req)
        if tr.enabled:
            tr.instant("admit", t=now, cat="router", tid=lane.tid,
                       uid=req.uid, model=model)
            tr.counter("backlog", len(lane.pending), t=now, tid=lane.tid)
        if lane.cfg.auto_dispatch:
            while len(lane.pending) >= lane.micro_batch:
                self._dispatch(lane, lane.micro_batch)
        return req

    def _lane(self, model: str) -> _Lane:
        lane = self.lanes.get(model)
        if lane is None:
            raise KeyError(f"unknown model {model!r}; "
                           f"lanes: {sorted(self.lanes)}")
        return lane

    # -- dispatch ----------------------------------------------------------
    def _dispatch(self, lane: _Lane, n: int) -> int:
        """Pop up to ``n`` requests and submit them as one padded wave.

        Under the blocking engine the wave also completes here; under the
        async engine it lands in the in-flight table and ``reap`` settles
        it later.
        """
        n = min(n, len(lane.pending))
        if n == 0:
            return 0
        reqs = [lane.pending.popleft() for _ in range(n)]
        mb = lane.micro_batch
        work_s = lane.work_estimate_s()
        tr = self.tracer
        if tr.enabled:
            tr.instant("wave_assemble", cat="router", tid=lane.tid,
                       model=lane.name, n_valid=n)
        replica = lane.pool.place(work_s)
        if not self.engine.blocking:
            # backpressure: a replica never holds more than the engine's
            # in-flight allowance — reap (in completion order) until the
            # chosen replica frees a slot
            while replica.n_inflight >= self.engine.max_inflight \
                    and self._inflight:
                self._settle(min(self._inflight, key=self._completion_key))
        xb = np.stack([r.x for r in reqs])
        t0 = self.clock.now()
        handle = self.engine.submit(replica, xb, micro_batch=mb)
        replica.n_inflight += 1
        lane.n_inflight += 1
        self._wave_seq += 1
        wave = _InFlightWave(lane=lane, reqs=reqs, replica=replica,
                             handle=handle, t0=t0, work_s=work_s,
                             n_valid=n, seq=self._wave_seq)
        if self.engine.blocking:
            self._complete(wave)
        else:
            self._inflight.append(wave)
            if tr.enabled:
                tr.counter("inflight", lane.n_inflight, t=t0, tid=lane.tid)
        return n

    # -- completion --------------------------------------------------------
    @staticmethod
    def _completion_key(w: _InFlightWave):
        """Reap order: known completion times ascending (the discrete-event
        contract — callbacks settle in event order), then submission order
        for handles that don't pre-announce (real devices: FIFO)."""
        rt = w.handle.ready_t
        return (0, rt, w.seq) if rt is not None else (1, 0.0, w.seq)

    def _settle(self, wave: _InFlightWave) -> None:
        self._inflight.remove(wave)
        self._complete(wave)

    def _complete(self, wave: _InFlightWave) -> None:
        """Wait on one wave and run its completion: stamp ``done_t``,
        settle metrics, credit the pool, feed the SLO controller or lane
        EWMA, close the wave/request trace spans."""
        y, mask = wave.handle.wait()
        lane = wave.lane
        # a scripted handle knows the true completion instant (possibly
        # earlier than this reap); a real device doesn't — the clock
        # reading after the blocking wait is the completion
        done = wave.handle.done_t
        if done is None:
            done = self.clock.now()
        lane.pool.complete(wave.replica, wave.work_s)
        wave.replica.n_inflight -= 1
        lane.n_inflight -= 1
        y = np.asarray(y)
        mask = np.asarray(mask)
        n, mb = wave.n_valid, lane.micro_batch
        if not (mask[:n].all() and not mask[n:].any()):
            # a bare assert here would vanish under ``python -O`` and let
            # an executor that mislabels its padding hand garbage rows to
            # clients — this is a result-integrity check, not a debug aid
            raise RuntimeError(
                f"lane {lane.name!r}: executor returned an invalid wave "
                f"mask {mask.tolist()} for {n} valid rows in a wave of "
                f"{mb} — padded rows must be masked out and valid rows "
                "masked in (see the submit_wave padding contract)")
        for r in wave.reqs:
            r.done_t = done
        for i, r in enumerate(wave.reqs):
            r.result = y[i]
            lane.metrics.record_completion(done, done - r.arrival_t)
        lane.metrics.record_wave(done, n, mb, service_s=done - wave.t0)
        lane.observe_service(done - wave.t0)
        tr = self.tracer
        if tr.enabled:
            # the dispatch span carries the FIFO-cost-model *predicted*
            # service time next to its measured duration — one
            # predicted-vs-measured training row per wave (obs.report)
            args = {"model": lane.name, "platform": self.platform,
                    "n_valid": n, "micro_batch": mb,
                    "replica": wave.replica.index}
            if lane.service is not None:
                args["predicted_ms"] = \
                    lane.service.wave_service_s(mb) * 1e3
                if lane.slo is not None:
                    # the controller's EWMA-corrected estimate, for
                    # auditing admission decisions (distinct from the raw
                    # model prediction above)
                    args["predicted_ewma_ms"] = wave.work_s * 1e3
            tr.add_span("wave", wave.t0, done, cat="router",
                        pid=1 + wave.replica.index, tid=lane.tid, args=args)
            for r in wave.reqs:
                # request span: arrival (enqueue) -> completion; duration
                # is exactly the latency ServeMetrics recorded, so
                # span-derived percentiles match snapshots to the bit
                tr.add_span("request", r.arrival_t, done, cat="router",
                            tid=lane.tid,
                            args={"uid": r.uid, "model": lane.name})
            tr.counter("backlog", len(lane.pending), t=done, tid=lane.tid)
            tr.counter("wave_occupancy", n / max(mb, 1), t=done,
                       tid=lane.tid)
            if not self.engine.blocking:
                tr.counter("inflight", lane.n_inflight, t=done,
                           tid=lane.tid)

    def reap(self, block: bool = False) -> int:
        """Settle completed in-flight waves (all of them with ``block``);
        returns the number of requests whose results landed. A no-op under
        the blocking engine — waves never park in the table there."""
        served = 0
        while self._inflight:
            now = self.clock.now()
            ready = [w for w in self._inflight if w.handle.ready(now)]
            if ready:
                w = min(ready, key=self._completion_key)
            elif block:
                # nothing done yet: wait out the earliest completion
                # (known ready_t first, else oldest submission)
                w = min(self._inflight, key=self._completion_key)
            else:
                break
            self._settle(w)
            served += w.n_valid
        return served

    # -- event loop hooks --------------------------------------------------
    def step(self, now: Optional[float] = None) -> int:
        """Reap finished waves, then dispatch every lane whose wave is full
        or whose oldest pending request has hit the max-wait deadline.
        Returns #requests dispatched (== completed under the blocking
        engine)."""
        now = self.clock.now() if now is None else now
        self.reap()
        served = 0
        for lane in self.lanes.values():
            while len(lane.pending) >= lane.micro_batch:
                served += self._dispatch(lane, lane.micro_batch)
            dl = lane.deadline()
            if dl is not None and now >= dl:
                served += self._dispatch(lane, lane.micro_batch)
        return served

    def next_deadline(self) -> Optional[float]:
        """Earliest pending batch deadline across lanes (None when idle)."""
        dls = [d for d in (lane.deadline() for lane in self.lanes.values())
               if d is not None]
        return min(dls) if dls else None

    def _next_wake(self) -> Optional[float]:
        """Earliest event the loop must wake for: a batch deadline or a
        scripted in-flight completion. Real-device handles announce no
        ready_t; the caller bounds its sleep with ``_POLL_S`` instead."""
        times = [d for d in (self.next_deadline(),) if d is not None]
        times += [w.handle.ready_t for w in self._inflight
                  if w.handle.ready_t is not None]
        return min(times) if times else None

    def _has_blind_inflight(self) -> bool:
        return any(w.handle.ready_t is None for w in self._inflight)

    def dispatch_one(self, model: str, max_n: Optional[int] = None) -> int:
        """Dispatch at most one (possibly partial) wave for one lane —
        the explicit-stepping hook the ``TinyModelServer`` shim drives."""
        lane = self._lane(model)
        n = lane.micro_batch if max_n is None else min(int(max_n),
                                                       lane.micro_batch)
        return self._dispatch(lane, n)

    def flush(self, model: Optional[str] = None) -> int:
        """Force-dispatch pending requests (partial waves included)."""
        lanes = [self._lane(model)] if model else list(self.lanes.values())
        served = 0
        for lane in lanes:
            while lane.pending:
                served += self._dispatch(lane, lane.micro_batch)
        return served

    def drain(self) -> int:
        """Flush everything and reap every in-flight wave; the
        end-of-trace barrier."""
        served = self.flush()
        self.reap(block=True)
        return served

    # -- trace replay ------------------------------------------------------
    def run_trace(self, model: str, trace: Trace,
                  make_query: Callable[[int], np.ndarray]
                  ) -> List[ServeRequest]:
        """Replay an arrival trace against one lane in (clock) real time.

        Between arrivals the router sleeps only as far as the next event —
        a batch deadline or (async engine) a scripted in-flight completion
        — so deadline flushes and completion reaps fire at the right
        moment even in arrival gaps. Under a ``ManualClock`` this loop is
        an exact simulation: sleeps advance the clock instantly and
        service time is whatever the executor (or a scripted fake) makes
        of it.
        """
        t0 = self.clock.now()
        out: List[ServeRequest] = []
        arr = np.asarray(trace.arrivals)
        i = 0
        while i < len(arr):
            target = t0 + float(arr[i])
            if self.clock.now() >= target:
                # due (or late) arrival: submit before stepping. While the
                # server was busy these requests were conceptually queuing
                # — admitting the whole late burst first lets it coalesce
                # into full waves, as it would in a threaded server, and
                # ``arrival_t=target`` keeps the blocked wait on the books.
                out.append(self.submit(model, make_query(i),
                                       arrival_t=target))
                i += 1
                continue
            self.step()
            wake = self._next_wake()
            if self._has_blind_inflight():
                # real-device waves in flight: wake to reap at least every
                # poll interval so completion stamping tracks the device
                poll = self.clock.now() + _POLL_S
                wake = poll if wake is None else min(wake, poll)
            if wake is not None and wake < target:
                self.clock.sleep(max(wake - self.clock.now(), 0.0))
                self.step()
            else:
                self.clock.sleep(max(target - self.clock.now(), 0.0))
        # drain the tail: honour remaining deadlines and scripted
        # completions in event order, then flush + reap what's left
        wake = self._next_wake()
        while wake is not None:
            self.clock.sleep(max(wake - self.clock.now(), 0.0))
            self.step()
            wake = self._next_wake()
        self.drain()
        return out

    # -- observability -----------------------------------------------------
    def stats(self) -> Dict[str, Dict]:
        """Per-lane snapshot: metrics window + SLO estimates + replicas."""
        now = self.clock.now()
        out: Dict[str, Dict] = {}
        for name, lane in self.lanes.items():
            snap = lane.metrics.snapshot(now)
            d = {"metrics": snap, "micro_batch": lane.micro_batch,
                 "pending": len(lane.pending),
                 "inflight": lane.n_inflight,
                 "replicas": lane.pool.stats()}
            if lane.slo is not None:
                d["slo"] = {
                    "p99_budget_ms": lane.slo.p99_budget_ms,
                    "wave_service_ms":
                        lane.slo.wave_service_s(lane.micro_batch) * 1e3,
                    "arrival_qps": lane.slo.arrival_qps(now),
                    "utilization":
                        lane.slo.utilization(now, lane.micro_batch),
                    "occupancy_estimate": lane.slo.occupancy_estimate(
                        now, lane.micro_batch,
                        lane.cfg.max_wait_ms / 1e3),
                }
            out[name] = d
        return out
