"""The dynamic batcher: request traffic in, compiled segment waves out.

This is the runtime that was missing between individual requests and the
PR-4 compiled streaming pipeline. Per model ("lane") the router keeps a
pending queue and two dispatch triggers:

  * **full wave** — the moment ``micro_batch`` requests (the autotuned wave
    size by default) are queued, they leave as one wave;
  * **deadline flush** — the oldest pending request never waits longer than
    ``max_wait_ms``: when the deadline passes, the partial wave leaves
    anyway, zero-padded through the executor's ``submit_wave`` padding-mask
    contract (padded rows are inert; valid rows stay bit-exact vs
    ``offline``).

Waves are placed on a ``ReplicaPool`` by least outstanding work, and an
optional ``SLOController`` sheds arrivals whose estimated completion
would blow the per-model p99 budget. All timing goes through an
injectable clock, so the router is an exact discrete-event system under
``ManualClock`` — the property the hand-simulated-trace tests exploit —
and a real server under ``SystemClock``.

Typical use (the ``ServerStreaming`` scenario, the serve bench, and the
``TinyModelServer`` compatibility shim are all thin wrappers over this):

    router = Router({"ic": cm}, RouterConfig(max_wait_ms=2.0,
                                             p99_budget_ms=50.0))
    done = router.run_trace("ic", poisson_trace(qps, n), make_query)
    print(router.stats()["ic"]["metrics"])
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Callable, Deque, Dict, List, Optional, Union

import numpy as np

from repro.obs.tracer import NULL_TRACER
from repro.serve.clock import SystemClock
from repro.serve.metrics import ServeMetrics
from repro.serve.replica import ReplicaPool
from repro.serve.slo import ServiceModel, SLOController
from repro.serve.traffic import Trace


def _backend_name() -> str:
    """The platform string stamped on dispatch spans (prediction-error
    rows group by it); empty when jax isn't importable."""
    try:
        import jax

        return str(jax.default_backend())
    except Exception:  # pragma: no cover
        return ""


@dataclasses.dataclass
class ServeRequest:
    """One inference request as the router tracks it."""

    uid: int
    model: str
    x: np.ndarray
    arrival_t: float
    done_t: float = 0.0
    result: Optional[np.ndarray] = None
    shed: bool = False

    @property
    def latency_s(self) -> float:
        return self.done_t - self.arrival_t


@dataclasses.dataclass
class RouterConfig:
    """Per-model routing policy.

    ``micro_batch=None`` consumes the executor's (autotuned) default wave
    size; ``p99_budget_ms=None`` disables shedding (every request is
    admitted). ``slo_headroom`` scales the budget the admission test uses
    (0.8 = shed at 80% of budget, keeping margin for estimate error).
    """

    max_wait_ms: float = 2.0
    micro_batch: Optional[int] = None
    p99_budget_ms: Optional[float] = None
    slo_headroom: float = 1.0
    window_s: float = 30.0
    #: False = never dispatch from inside ``submit`` (a full wave waits for
    #: the next ``step``/``dispatch_one``) — the explicitly-stepped
    #: compatibility mode the ``TinyModelServer`` shim runs in.
    auto_dispatch: bool = True


class _Lane:
    """Internal per-model state: pool + queue + policy + metrics."""

    def __init__(self, name: str, pool: ReplicaPool, cfg: RouterConfig,
                 slo: Optional[SLOController], start_t: float,
                 service: Optional[ServiceModel] = None, tid: int = 0):
        self.name = name
        self.pool = pool
        self.cfg = cfg
        self.slo = slo
        #: the raw FIFO-cost-model service estimate (uncorrected by the
        #: SLO controller's EWMA) — what dispatch spans record as the
        #: *predicted* wave service time, the learned-cost-model trail
        self.service = service
        self.tid = tid                       # trace track for this lane
        self.n_shed = 0
        self.pending: Deque[ServeRequest] = collections.deque()
        self.metrics = ServeMetrics(window_s=cfg.window_s, start_t=start_t)
        self.micro_batch = int(cfg.micro_batch
                               or pool.default_micro_batch or 1)

    def deadline(self) -> Optional[float]:
        if not self.pending:
            return None
        return self.pending[0].arrival_t + self.cfg.max_wait_ms / 1e3


class Router:
    """Dynamic-batching front end over compiled executors.

    ``models`` maps name -> executor (``CompiledTinyModel`` or anything
    with ``submit_wave``/``default_micro_batch``) or a prebuilt
    ``ReplicaPool``. ``config`` is one ``RouterConfig`` for every model or
    a per-model dict. ``service_models`` supplies the SLO service-time
    model per name; when omitted and a p99 budget is set, it is built from
    the compiled schedule (``ServiceModel.from_compiled`` — FIFO cost
    model calibrated by a ``stage_latencies`` probe).
    """

    def __init__(self, models: Dict[str, object],
                 config: Union[RouterConfig, Dict[str, RouterConfig], None]
                 = None,
                 clock: Optional[object] = None,
                 service_models: Optional[Dict[str, ServiceModel]] = None,
                 tracer: Optional[object] = None):
        self.clock = clock if clock is not None else SystemClock()
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.platform = _backend_name() if self.tracer.enabled else ""
        self._uid = 0
        self.lanes: Dict[str, _Lane] = {}
        now = self.clock.now()
        for i, (name, model) in enumerate(models.items()):
            cfg = (config.get(name, RouterConfig())
                   if isinstance(config, dict)
                   else (config or RouterConfig()))
            pool = model if isinstance(model, ReplicaPool) \
                else ReplicaPool(model)
            if self.tracer.enabled:
                pool.tracer = self.tracer
            service = (service_models or {}).get(name)
            slo = None
            if cfg.p99_budget_ms is not None:
                if service is None:
                    service = ServiceModel.from_compiled(
                        pool.replicas[0].model)
                slo = SLOController(cfg.p99_budget_ms, service,
                                    window_s=cfg.window_s,
                                    headroom=cfg.slo_headroom)
            self.lanes[name] = _Lane(name, pool, cfg, slo, start_t=now,
                                     service=service, tid=i + 1)

    def trace_names(self) -> Dict[str, Dict]:
        """Process/track naming maps for ``obs.export.export_chrome``:
        pid 0 is the router, pid 1+i replica i; one track per lane."""
        pids = {0: "router"}
        tids = {}
        for lane in self.lanes.values():
            tids[(0, lane.tid)] = f"lane:{lane.name}"
            for r in lane.pool.replicas:
                pids[1 + r.index] = f"replica{r.index}"
                tids[(1 + r.index, lane.tid)] = f"waves:{lane.name}"
        return {"process_names": pids, "thread_names": tids}

    # -- submission --------------------------------------------------------
    def submit(self, model: str, x, arrival_t: Optional[float] = None
               ) -> ServeRequest:
        """Admit (or shed) one request; a full wave dispatches in-line."""
        lane = self._lane(model)
        now = self.clock.now() if arrival_t is None else float(arrival_t)
        req = ServeRequest(uid=self._uid, model=model, x=np.asarray(x),
                           arrival_t=now)
        self._uid += 1
        tr = self.tracer
        if tr.enabled:
            tr.instant("enqueue", t=now, cat="router", tid=lane.tid,
                       uid=req.uid, model=model)
        if lane.slo is not None:
            lane.slo.observe_arrival(now)
            backlog_waves = len(lane.pending) // lane.micro_batch
            # a request admitted late (the server was busy past its arrival
            # time) has already burned budget: the admission estimate must
            # carry that lag, or an overloaded single-worker lane would
            # never shed — its pending queue stays short while the clock
            # falls behind the trace
            lag_s = max(self.clock.now() - now, 0.0)
            if not lane.slo.admit(now, backlog_waves, lane.micro_batch,
                                  lane.cfg.max_wait_ms / 1e3, lag_s=lag_s):
                req.shed = True
                lane.n_shed += 1
                lane.metrics.record_shed(now)
                if tr.enabled:
                    tr.instant("shed", t=now, cat="router", tid=lane.tid,
                               uid=req.uid, model=model)
                    tr.counter("shed_total", lane.n_shed, t=now,
                               tid=lane.tid)
                    # a shed request's span is its (empty) lifetime: it
                    # exists in the trace but not in latency populations
                    tr.add_span("request", now, now, cat="router",
                                tid=lane.tid,
                                args={"uid": req.uid, "model": model,
                                      "shed": True})
                return req
        lane.metrics.record_admit(now)
        lane.pending.append(req)
        if tr.enabled:
            tr.instant("admit", t=now, cat="router", tid=lane.tid,
                       uid=req.uid, model=model)
            tr.counter("backlog", len(lane.pending), t=now, tid=lane.tid)
        if lane.cfg.auto_dispatch:
            while len(lane.pending) >= lane.micro_batch:
                self._dispatch(lane, lane.micro_batch)
        return req

    def _lane(self, model: str) -> _Lane:
        lane = self.lanes.get(model)
        if lane is None:
            raise KeyError(f"unknown model {model!r}; "
                           f"lanes: {sorted(self.lanes)}")
        return lane

    # -- dispatch ----------------------------------------------------------
    def _dispatch(self, lane: _Lane, n: int) -> int:
        """Pop up to ``n`` requests and run them as one padded wave."""
        n = min(n, len(lane.pending))
        if n == 0:
            return 0
        reqs = [lane.pending.popleft() for _ in range(n)]
        mb = lane.micro_batch
        work_s = (lane.slo.wave_service_s(mb) if lane.slo is not None
                  else 0.0)
        tr = self.tracer
        if tr.enabled:
            tr.instant("wave_assemble", cat="router", tid=lane.tid,
                       model=lane.name, n_valid=n)
        replica = lane.pool.place(work_s)
        xb = np.stack([r.x for r in reqs])
        t0 = self.clock.now()
        y, mask = replica.run_wave(xb, micro_batch=mb)
        done = self.clock.now()
        lane.pool.complete(replica, work_s)
        y = np.asarray(y)
        assert mask[:n].all() and not mask[n:].any(), mask
        for i, r in enumerate(reqs):
            r.result = y[i]
            r.done_t = done
            lane.metrics.record_completion(done, done - r.arrival_t)
        lane.metrics.record_wave(done, n, mb)
        if lane.slo is not None:
            lane.slo.observe_service(mb, done - t0)
        if tr.enabled:
            # the dispatch span carries the FIFO-cost-model *predicted*
            # service time next to its measured duration — one
            # predicted-vs-measured training row per wave (obs.report)
            args = {"model": lane.name, "platform": self.platform,
                    "n_valid": n, "micro_batch": mb,
                    "replica": replica.index}
            if lane.service is not None:
                args["predicted_ms"] = \
                    lane.service.wave_service_s(mb) * 1e3
                if lane.slo is not None:
                    # the controller's EWMA-corrected estimate, for
                    # auditing admission decisions (distinct from the raw
                    # model prediction above)
                    args["predicted_ewma_ms"] = work_s * 1e3
            tr.add_span("wave", t0, done, cat="router",
                        pid=1 + replica.index, tid=lane.tid, args=args)
            for r in reqs:
                # request span: arrival (enqueue) -> completion; duration
                # is exactly the latency ServeMetrics recorded, so
                # span-derived percentiles match snapshots to the bit
                tr.add_span("request", r.arrival_t, done, cat="router",
                            tid=lane.tid,
                            args={"uid": r.uid, "model": lane.name})
            tr.counter("backlog", len(lane.pending), t=done, tid=lane.tid)
            tr.counter("wave_occupancy", n / max(mb, 1), t=done,
                       tid=lane.tid)
        return n

    # -- event loop hooks --------------------------------------------------
    def step(self, now: Optional[float] = None) -> int:
        """Dispatch every lane whose wave is full or whose oldest pending
        request has hit the max-wait deadline. Returns #requests served."""
        now = self.clock.now() if now is None else now
        served = 0
        for lane in self.lanes.values():
            while len(lane.pending) >= lane.micro_batch:
                served += self._dispatch(lane, lane.micro_batch)
            dl = lane.deadline()
            if dl is not None and now >= dl:
                served += self._dispatch(lane, lane.micro_batch)
        return served

    def next_deadline(self) -> Optional[float]:
        """Earliest pending batch deadline across lanes (None when idle)."""
        dls = [d for d in (lane.deadline() for lane in self.lanes.values())
               if d is not None]
        return min(dls) if dls else None

    def dispatch_one(self, model: str, max_n: Optional[int] = None) -> int:
        """Dispatch at most one (possibly partial) wave for one lane —
        the explicit-stepping hook the ``TinyModelServer`` shim drives."""
        lane = self._lane(model)
        n = lane.micro_batch if max_n is None else min(int(max_n),
                                                       lane.micro_batch)
        return self._dispatch(lane, n)

    def flush(self, model: Optional[str] = None) -> int:
        """Force-dispatch pending requests (partial waves included)."""
        lanes = [self._lane(model)] if model else list(self.lanes.values())
        served = 0
        for lane in lanes:
            while lane.pending:
                served += self._dispatch(lane, lane.micro_batch)
        return served

    def drain(self) -> int:
        """Flush everything; the end-of-trace barrier."""
        return self.flush()

    # -- trace replay ------------------------------------------------------
    def run_trace(self, model: str, trace: Trace,
                  make_query: Callable[[int], np.ndarray]
                  ) -> List[ServeRequest]:
        """Replay an arrival trace against one lane in (clock) real time.

        Between arrivals the router sleeps only as far as the next batch
        deadline, so deadline flushes fire at the right moment even in
        arrival gaps. Under a ``ManualClock`` this loop is an exact
        simulation: sleeps advance the clock instantly and service time is
        whatever the executor (or a scripted fake) makes of it.
        """
        t0 = self.clock.now()
        out: List[ServeRequest] = []
        arr = np.asarray(trace.arrivals)
        i = 0
        while i < len(arr):
            target = t0 + float(arr[i])
            if self.clock.now() >= target:
                # due (or late) arrival: submit before stepping. While the
                # server was busy these requests were conceptually queuing
                # — admitting the whole late burst first lets it coalesce
                # into full waves, as it would in a threaded server, and
                # ``arrival_t=target`` keeps the blocked wait on the books.
                out.append(self.submit(model, make_query(i),
                                       arrival_t=target))
                i += 1
                continue
            self.step()
            dl = self.next_deadline()
            if dl is not None and dl < target:
                self.clock.sleep(max(dl - self.clock.now(), 0.0))
                self.step()
            else:
                self.clock.sleep(max(target - self.clock.now(), 0.0))
        # drain the tail: honour remaining deadlines, then flush
        dl = self.next_deadline()
        while dl is not None:
            self.clock.sleep(max(dl - self.clock.now(), 0.0))
            self.step()
            dl = self.next_deadline()
        self.drain()
        return out

    # -- observability -----------------------------------------------------
    def stats(self) -> Dict[str, Dict]:
        """Per-lane snapshot: metrics window + SLO estimates + replicas."""
        now = self.clock.now()
        out: Dict[str, Dict] = {}
        for name, lane in self.lanes.items():
            snap = lane.metrics.snapshot(now)
            d = {"metrics": snap, "micro_batch": lane.micro_batch,
                 "pending": len(lane.pending),
                 "replicas": lane.pool.stats()}
            if lane.slo is not None:
                d["slo"] = {
                    "p99_budget_ms": lane.slo.p99_budget_ms,
                    "wave_service_ms":
                        lane.slo.wave_service_s(lane.micro_batch) * 1e3,
                    "arrival_qps": lane.slo.arrival_qps(now),
                    "utilization":
                        lane.slo.utilization(now, lane.micro_batch),
                    "occupancy_estimate": lane.slo.occupancy_estimate(
                        now, lane.micro_batch,
                        lane.cfg.max_wait_ms / 1e3),
                }
            out[name] = d
        return out
