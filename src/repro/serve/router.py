"""The dynamic batcher: request traffic in, compiled segment waves out.

This is the runtime that was missing between individual requests and the
PR-4 compiled streaming pipeline. Per model ("lane") the router keeps a
pending queue and two dispatch triggers:

  * **full wave** — the moment ``micro_batch`` requests (the autotuned wave
    size by default) are queued, they leave as one wave;
  * **deadline flush** — the oldest pending request never waits longer than
    ``max_wait_ms``: when the deadline passes, the partial wave leaves
    anyway, zero-padded through the executor's ``submit_wave`` padding-mask
    contract (padded rows are inert; valid rows stay bit-exact vs
    ``offline``).

Waves are placed on a ``ReplicaPool`` by least outstanding work, and an
optional ``SLOController`` sheds arrivals whose estimated completion
would blow the per-model p99 budget. *How* a placed wave executes is the
injectable ``DispatchEngine``'s business (``serve.dispatch``): the
default ``SyncEngine`` blocks inside dispatch (the original semantics),
while ``AsyncEngine`` submits without waiting — the router parks a
``WaveHandle`` per wave in its in-flight table and **reaps** completions
on every event-loop pass, so waves on different replicas overlap and an
N-replica pool finally runs N wide. Completion bookkeeping (result
stamping, metrics, SLO feedback, pool credit, trace spans) lives in one
place — ``_complete`` — for both engines.

Failure handling (``serve.faults``, ``docs/faults.md``): waves carry a
deadline priced off the lane's service estimate
(``RouterConfig.wave_timeout_mult``); ``reap`` cancels overdue waves and
re-dispatches their requests to a different replica with bounded retries
and exponential backoff — retried waves keep their original ``arrival_t``
so p99 stays honest. Every failure feeds the pool's replica health state
machine (healthy -> suspect -> quarantined -> recovering), admission is
repriced to the surviving pool, and a per-wave output integrity guard
(finite, inside the lowering's proven ``2**24`` bound) routes corrupt
results to retry instead of clients. Requests that exhaust retries — or
arrive when every replica is quarantined — are shed with a typed reason
code, never hung.

All timing goes through an injectable clock, so the router is an exact
discrete-event system under ``ManualClock`` — the property the
hand-simulated-trace tests exploit — and a real server under
``SystemClock``.

Typical use (the ``ServerStreaming`` scenario, the serve bench, and the
``TinyModelServer`` compatibility shim are all thin wrappers over this):

    router = Router({"ic": cm}, RouterConfig(max_wait_ms=2.0,
                                             p99_budget_ms=50.0),
                    engine=AsyncEngine())
    done = router.run_trace("ic", poisson_trace(qps, n), make_query)
    print(router.stats()["ic"]["metrics"])
"""

from __future__ import annotations

import collections
import dataclasses
import math
from typing import Callable, Deque, Dict, FrozenSet, List, Optional, Union

import numpy as np

from repro.obs.tracer import NULL_TRACER
from repro.serve.clock import SystemClock
from repro.serve.dispatch import DispatchEngine, SyncEngine, WaveHandle
from repro.serve.faults import (
    DEFAULT_OUTPUT_BOUND,
    CorruptWave,
    FaultError,
    NoReplicaAvailable,
    WaveTimeout,
    wave_integrity_ok,
)
from repro.serve.metrics import ServeMetrics
from repro.serve.replica import Replica, ReplicaPool
from repro.serve.slo import ServiceModel, SLOController, queued_waves
from repro.serve.traffic import Trace

#: Poll bounds while waves with unannounced completion times are in
#: flight (real devices under ``SystemClock``): the event loop starts at
#: ``_POLL_MIN_S`` and backs off exponentially to ``_POLL_MAX_S`` while
#: nothing completes — a hung device no longer burns a core at a fixed
#: 0.5 ms spin — resetting to the floor the moment a wave settles. The
#: backoff never sleeps past a wave deadline or batch deadline, so
#: timeouts still fire on time. Scripted handles announce ``ready_t`` and
#: never poll — manual-clock runs stay exact discrete-event simulations.
_POLL_MIN_S = 0.5e-3
_POLL_MAX_S = 16e-3


def _backend_name() -> str:
    """The platform string stamped on dispatch spans (prediction-error
    rows group by it); empty when jax isn't importable."""
    try:
        import jax

        return str(jax.default_backend())
    except Exception:  # pragma: no cover
        return ""


@dataclasses.dataclass
class ServeRequest:
    """One inference request as the router tracks it."""

    uid: int
    model: str
    x: np.ndarray
    arrival_t: float
    done_t: float = 0.0
    result: Optional[np.ndarray] = None
    shed: bool = False
    #: why a shed/failed request carries no result ("slo", "no_replica",
    #: "retries_exhausted: ..."); None for served requests
    error: Optional[str] = None

    @property
    def latency_s(self) -> float:
        return self.done_t - self.arrival_t


@dataclasses.dataclass
class RouterConfig:
    """Per-model routing policy.

    ``micro_batch=None`` consumes the executor's (autotuned) default wave
    size; ``p99_budget_ms=None`` disables shedding (every request is
    admitted). ``slo_headroom`` scales the budget the admission test uses
    (0.8 = shed at 80% of budget, keeping margin for estimate error).
    """

    max_wait_ms: float = 2.0
    micro_batch: Optional[int] = None
    p99_budget_ms: Optional[float] = None
    slo_headroom: float = 1.0
    window_s: float = 30.0
    #: False = never dispatch from inside ``submit`` (a full wave waits for
    #: the next ``step``/``dispatch_one``) — the explicitly-stepped
    #: compatibility mode the ``TinyModelServer`` shim runs in.
    auto_dispatch: bool = True
    #: Wave deadline as a multiple of the lane's service estimate
    #: (``deadline = submit_t + max(mult * estimate, floor)``); ``None``
    #: disables wave timeouts entirely — the default, so deployments (and
    #: the exact hand-simulated tests) that never asked for fault
    #: handling keep bit-identical timing.
    wave_timeout_mult: Optional[float] = None
    #: Deadline floor: a lane whose estimate is still 0 (nothing observed
    #: yet) must not declare every wave instantly overdue.
    wave_timeout_floor_ms: float = 1.0
    #: Failed waves (timeout, crash, corrupt output, submit error) are
    #: re-dispatched to a different replica at most this many times before
    #: their requests are shed with reason "retries_exhausted".
    max_retries: int = 2
    #: Retry backoff base: attempt k waits ``retry_backoff_ms * 2**(k-1)``
    #: before re-dispatch (exponential, so a flapping pool isn't hammered).
    retry_backoff_ms: float = 0.5
    #: Per-wave output integrity guard at settle time (finite + inside
    #: ``output_bound``); violations are retried, never served.
    integrity_check: bool = True
    #: Magnitude bound the guard checks against; ``None`` resolves to the
    #: model's ``output_bound`` attribute when it has one, else the
    #: lowering exactness bound (``faults.DEFAULT_OUTPUT_BOUND = 2**24``).
    output_bound: Optional[float] = None
    #: Override the pool's quarantine probe cadence (seconds between
    #: readmission probe waves); ``None`` keeps the pool's own setting.
    probe_interval_ms: Optional[float] = None


class _Lane:
    """Internal per-model state: pool + queue + policy + metrics."""

    #: EWMA weight for the measured-wave-time fallback service estimate
    #: (same spirit as ``SLOController.ewma_alpha``).
    EWMA_ALPHA = 0.25

    def __init__(self, name: str, pool: ReplicaPool, cfg: RouterConfig,
                 slo: Optional[SLOController], start_t: float,
                 service: Optional[ServiceModel] = None, tid: int = 0):
        self.name = name
        self.pool = pool
        self.cfg = cfg
        self.slo = slo
        #: the raw FIFO-cost-model service estimate (uncorrected by the
        #: SLO controller's EWMA) — what dispatch spans record as the
        #: *predicted* wave service time, the learned-cost-model trail
        self.service = service
        self.tid = tid                       # trace track for this lane
        self.n_shed = 0
        self.n_inflight = 0                  # this lane's unreaped waves
        #: measured-wave-time EWMA: the placement work estimate of last
        #: resort when the lane has neither controller nor service model
        self.ewma_service_s: Optional[float] = None
        self.pending: Deque[ServeRequest] = collections.deque()
        self.metrics = ServeMetrics(window_s=cfg.window_s, start_t=start_t)
        self.micro_batch = int(cfg.micro_batch
                               or pool.default_micro_batch or 1)
        #: integrity-guard magnitude bound: config override, else the
        #: model's own declared bound, else the lowering proof's 2**24
        bound = cfg.output_bound
        if bound is None:
            bound = getattr(pool.replicas[0].model, "output_bound", None)
        self.output_bound = float(bound) if bound is not None \
            else DEFAULT_OUTPUT_BOUND

    def wave_deadline_s(self, work_s: float) -> Optional[float]:
        """Seconds an in-flight wave may run before it is declared
        overdue: the lane's service estimate times the configured
        multiplier, floored so an uncalibrated lane (estimate 0) doesn't
        declare every wave instantly late. ``None`` = timeouts off."""
        if self.cfg.wave_timeout_mult is None:
            return None
        return max(self.cfg.wave_timeout_mult * max(work_s, 0.0),
                   self.cfg.wave_timeout_floor_ms / 1e3)

    def deadline(self) -> Optional[float]:
        if not self.pending:
            return None
        return self.pending[0].arrival_t + self.cfg.max_wait_ms / 1e3

    def work_estimate_s(self) -> float:
        """The wave service estimate placement charges a replica.

        Best available source wins: the SLO controller's EWMA-corrected
        model, else the raw lane service model, else the measured-wave
        EWMA. Never 0.0 once anything has been observed — with a zero
        charge every replica ties on outstanding work and least-work
        placement silently degenerates to dispatch-count round-robin,
        which misplaces heterogeneous waves.
        """
        if self.slo is not None:
            return self.slo.wave_service_s(self.micro_batch)
        if self.service is not None:
            return self.service.wave_service_s(self.micro_batch)
        return self.ewma_service_s if self.ewma_service_s is not None \
            else 0.0

    def observe_service(self, measured_s: float) -> None:
        """Feed one completed wave's measured service time back into the
        lane's estimate (controller EWMA when present, lane EWMA else)."""
        if self.slo is not None:
            self.slo.observe_service(self.micro_batch, measured_s)
            return
        if measured_s <= 0:
            return
        if self.ewma_service_s is None:
            self.ewma_service_s = float(measured_s)
        else:
            a = self.EWMA_ALPHA
            self.ewma_service_s = \
                (1 - a) * self.ewma_service_s + a * float(measured_s)


@dataclasses.dataclass
class _InFlightWave:
    """One dispatched wave between submit and completion — the in-flight
    table's row (sync waves pass through without ever being parked)."""

    lane: _Lane
    reqs: List[ServeRequest]
    replica: Replica
    handle: WaveHandle
    t0: float                    # submit time (span start, service clock)
    work_s: float                # modeled work charged at placement
    n_valid: int
    seq: int                     # submission order: FIFO reap tiebreak
    deadline_t: Optional[float] = None   # overdue past this (None = never)
    attempt: int = 0                     # 0 = first dispatch, 1+ = retries
    #: replica indices this wave already failed on (retry placement avoids
    #: them — a preference place() may override when nothing else is up)
    exclude: FrozenSet[int] = frozenset()


@dataclasses.dataclass
class _RetryWave:
    """A failed wave's requests parked for re-dispatch after backoff."""

    lane: _Lane
    reqs: List[ServeRequest]
    not_before_t: float          # backoff expiry (absolute clock time)
    attempt: int                 # the attempt number of the re-dispatch
    exclude: FrozenSet[int]


class Router:
    """Dynamic-batching front end over compiled executors.

    ``models`` maps name -> executor (``CompiledTinyModel`` or anything
    with ``submit_wave``/``default_micro_batch``) or a prebuilt
    ``ReplicaPool``. ``config`` is one ``RouterConfig`` for every model or
    a per-model dict. ``service_models`` supplies the SLO service-time
    model per name; when omitted and a p99 budget is set, it is built from
    the compiled schedule (``ServiceModel.from_compiled`` — FIFO cost
    model calibrated by a ``stage_latencies`` probe). ``engine`` picks the
    dispatch semantics (default ``SyncEngine``; pass ``AsyncEngine()`` to
    overlap waves across replicas).
    """

    def __init__(self, models: Dict[str, object],
                 config: Union[RouterConfig, Dict[str, RouterConfig], None]
                 = None,
                 clock: Optional[object] = None,
                 service_models: Optional[Dict[str, ServiceModel]] = None,
                 tracer: Optional[object] = None,
                 engine: Optional[DispatchEngine] = None):
        self.clock = clock if clock is not None else SystemClock()
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.engine = engine if engine is not None else SyncEngine()
        self.platform = _backend_name() if self.tracer.enabled else ""
        self._uid = 0
        self._wave_seq = 0
        self._inflight: List[_InFlightWave] = []
        self._retries: List[_RetryWave] = []
        self._poll_s = _POLL_MIN_S       # blind-handle backoff state
        self.lanes: Dict[str, _Lane] = {}
        now = self.clock.now()
        for i, (name, model) in enumerate(models.items()):
            cfg = (config.get(name, RouterConfig())
                   if isinstance(config, dict)
                   else (config or RouterConfig()))
            pool = model if isinstance(model, ReplicaPool) \
                else ReplicaPool(model)
            if cfg.probe_interval_ms is not None:
                pool.probe_interval_s = cfg.probe_interval_ms / 1e3
            if self.tracer.enabled:
                pool.tracer = self.tracer
            service = (service_models or {}).get(name)
            slo = None
            if cfg.p99_budget_ms is not None:
                if service is None:
                    service = ServiceModel.from_compiled(
                        pool.replicas[0].model)
                slo = SLOController(cfg.p99_budget_ms, service,
                                    window_s=cfg.window_s,
                                    headroom=cfg.slo_headroom)
            self.lanes[name] = _Lane(name, pool, cfg, slo, start_t=now,
                                     service=service, tid=i + 1)

    def trace_names(self) -> Dict[str, Dict]:
        """Process/track naming maps for ``obs.export.export_chrome``:
        pid 0 is the router, pid 1+i replica i; one track per lane."""
        pids = {0: "router"}
        tids = {}
        for lane in self.lanes.values():
            tids[(0, lane.tid)] = f"lane:{lane.name}"
            for r in lane.pool.replicas:
                pids[1 + r.index] = f"replica{r.index}"
                tids[(1 + r.index, lane.tid)] = f"waves:{lane.name}"
        return {"process_names": pids, "thread_names": tids}

    # -- submission --------------------------------------------------------
    def submit(self, model: str, x, arrival_t: Optional[float] = None
               ) -> ServeRequest:
        """Admit (or shed) one request; a full wave dispatches in-line."""
        lane = self._lane(model)
        now = self.clock.now() if arrival_t is None else float(arrival_t)
        req = ServeRequest(uid=self._uid, model=model, x=np.asarray(x),
                           arrival_t=now)
        self._uid += 1
        tr = self.tracer
        if tr.enabled:
            tr.instant("enqueue", t=now, cat="router", tid=lane.tid,
                       uid=req.uid, model=model)
        if lane.slo is not None:
            lane.slo.observe_arrival(now)
            # waves this request must wait out: the ceiling form prices
            # the partial wave it joins, and every still-in-flight wave
            # holds a replica slot so it is queue delay too (zero under
            # the blocking engine, where dispatch and completion coincide)
            backlog_waves = queued_waves(len(lane.pending),
                                         lane.micro_batch, lane.n_inflight)
            # a request admitted late (the server was busy past its arrival
            # time) has already burned budget: the admission estimate must
            # carry that lag, or an overloaded single-worker lane would
            # never shed — its pending queue stays short while the clock
            # falls behind the trace
            lag_s = max(self.clock.now() - now, 0.0)
            # capacity is the SURVIVING pool: quarantined replicas take no
            # waves, so pricing the backlog across the nominal replica
            # count would under-shed exactly when the pool is degraded
            if not lane.slo.admit(now, backlog_waves, lane.micro_batch,
                                  lane.cfg.max_wait_ms / 1e3, lag_s=lag_s,
                                  n_workers=max(lane.pool.n_available, 1)):
                req.shed = True
                lane.n_shed += 1
                lane.metrics.record_shed(now)
                if tr.enabled:
                    tr.instant("shed", t=now, cat="router", tid=lane.tid,
                               uid=req.uid, model=model)
                    tr.counter("shed_total", lane.n_shed, t=now,
                               tid=lane.tid)
                    # a shed request's span is its (empty) lifetime: it
                    # exists in the trace but not in latency populations
                    tr.add_span("request", now, now, cat="router",
                                tid=lane.tid,
                                args={"uid": req.uid, "model": model,
                                      "shed": True})
                return req
        lane.metrics.record_admit(now)
        lane.pending.append(req)
        if tr.enabled:
            tr.instant("admit", t=now, cat="router", tid=lane.tid,
                       uid=req.uid, model=model)
            tr.counter("backlog", len(lane.pending), t=now, tid=lane.tid)
        if lane.cfg.auto_dispatch:
            while len(lane.pending) >= lane.micro_batch:
                self._dispatch(lane, lane.micro_batch)
        return req

    def _lane(self, model: str) -> _Lane:
        lane = self.lanes.get(model)
        if lane is None:
            raise KeyError(f"unknown model {model!r}; "
                           f"lanes: {sorted(self.lanes)}")
        return lane

    # -- dispatch ----------------------------------------------------------
    def _dispatch(self, lane: _Lane, n: int,
                  reqs: Optional[List[ServeRequest]] = None,
                  attempt: int = 0,
                  exclude: FrozenSet[int] = frozenset()) -> int:
        """Pop up to ``n`` requests and submit them as one padded wave
        (or re-submit a failed wave's ``reqs`` — a retry keeps its
        requests' original ``arrival_t`` so p99 stays honest).

        Under the blocking engine the wave also completes here; under the
        async engine it lands in the in-flight table and ``reap`` settles
        it later. A submission-time failure (crashed replica, transient
        error) parks the wave for retry; an empty / fully-quarantined pool
        sheds it with reason "no_replica".
        """
        if reqs is None:
            n = min(n, len(lane.pending))
            if n == 0:
                return 0
            reqs = [lane.pending.popleft() for _ in range(n)]
        else:
            n = len(reqs)
        mb = lane.micro_batch
        work_s = lane.work_estimate_s()
        tr = self.tracer
        if tr.enabled:
            tr.instant("wave_assemble", cat="router", tid=lane.tid,
                       model=lane.name, n_valid=n)
        now = self.clock.now()
        try:
            replica = lane.pool.place(work_s, now=now, exclude=exclude)
        except NoReplicaAvailable as e:
            # nowhere to put the wave at all: typed fast-fail, distinct
            # shed reason — never a hang, never an IndexError
            self._shed_wave(lane, reqs, now, reason="no_replica", exc=e)
            return 0
        if not self.engine.blocking:
            # backpressure: a replica never holds more than the engine's
            # in-flight allowance — reap (in completion order, overdue
            # waves failed first) until the chosen replica frees a slot
            while replica.n_inflight >= self.engine.max_inflight \
                    and self._inflight:
                self._reap_one(block=True)
        xb = np.stack([r.x for r in reqs])
        t0 = self.clock.now()
        try:
            handle = self.engine.submit(replica, xb, micro_batch=mb)
        except FaultError as e:
            # the submission itself was refused (crashed replica,
            # transient submit error): credit the placement charge back,
            # degrade the replica, park the wave for retry elsewhere
            lane.pool.complete(replica, work_s)
            lane.pool.mark_failure(replica, t0, reason=type(e).__name__)
            lane.metrics.record_fault(t0, "submit_error")
            if tr.enabled:
                tr.instant("wave_failed", t=t0, cat="router", tid=lane.tid,
                           model=lane.name, replica=replica.index,
                           kind="submit_error", attempt=attempt)
            self._park_retry(lane, reqs, attempt, t0,
                             exclude | {replica.index}, e)
            return 0
        replica.n_inflight += 1
        lane.n_inflight += 1
        self._wave_seq += 1
        deadline_t = None
        timeout_s = lane.wave_deadline_s(work_s)
        if timeout_s is not None:
            deadline_t = t0 + timeout_s
            handle.deadline_t = deadline_t
        wave = _InFlightWave(lane=lane, reqs=reqs, replica=replica,
                             handle=handle, t0=t0, work_s=work_s,
                             n_valid=n, seq=self._wave_seq,
                             deadline_t=deadline_t, attempt=attempt,
                             exclude=exclude)
        if self.engine.blocking:
            # a failed blocking wave (0) parked its requests for retry;
            # report only what actually completed
            return self._complete(wave)
        self._inflight.append(wave)
        if tr.enabled:
            tr.counter("inflight", lane.n_inflight, t=t0, tid=lane.tid)
        return n

    # -- completion --------------------------------------------------------
    @staticmethod
    def _completion_key(w: _InFlightWave):
        """Reap order: known completion times ascending (the discrete-event
        contract — callbacks settle in event order), then submission order
        for handles that don't pre-announce (real devices: FIFO)."""
        rt = w.handle.ready_t
        return (0, rt, w.seq) if rt is not None else (1, 0.0, w.seq)

    def _settle(self, wave: _InFlightWave) -> int:
        self._inflight.remove(wave)
        return self._complete(wave)

    def _release(self, wave: _InFlightWave) -> None:
        """Undo a wave's in-flight accounting (pool work charge, replica
        and lane in-flight counts) — the shared first step of settling a
        completion and of failing a wave."""
        wave.lane.pool.complete(wave.replica, wave.work_s)
        wave.replica.n_inflight -= 1
        wave.lane.n_inflight -= 1
        self._poll_s = _POLL_MIN_S       # progress: reset the poll backoff

    def _complete(self, wave: _InFlightWave) -> int:
        """Wait on one wave and run its completion: stamp ``done_t``,
        settle metrics, credit the pool, feed the SLO controller or lane
        EWMA, close the wave/request trace spans. A wave that fails —
        typed fault from the wait, or an output flunking the integrity
        guard — goes to the retry path instead; returns the number of
        requests actually served (0 on failure)."""
        lane = wave.lane
        try:
            y, mask = wave.handle.wait()
        except FaultError as e:
            self._release(wave)
            self._after_failure(wave, e, self.clock.now())
            return 0
        # a scripted handle knows the true completion instant (possibly
        # earlier than this reap); a real device doesn't — the clock
        # reading after the blocking wait is the completion
        done = wave.handle.done_t
        if done is None:
            done = self.clock.now()
        self._release(wave)
        y = np.asarray(y)
        mask = np.asarray(mask)
        n, mb = wave.n_valid, lane.micro_batch
        if not (mask[:n].all() and not mask[n:].any()):
            # a bare assert here would vanish under ``python -O`` and let
            # an executor that mislabels its padding hand garbage rows to
            # clients — this is a result-integrity check, not a debug aid
            raise RuntimeError(
                f"lane {lane.name!r}: executor returned an invalid wave "
                f"mask {mask.tolist()} for {n} valid rows in a wave of "
                f"{mb} — padded rows must be masked out and valid rows "
                "masked in (see the submit_wave padding contract)")
        if lane.cfg.integrity_check \
                and not wave_integrity_ok(y[:n], lane.output_bound):
            # corrupt output is a failure, not a contract bug: the wave is
            # retried on another replica, never served to a client
            self._after_failure(
                wave,
                CorruptWave(
                    f"lane {lane.name!r}: wave output on replica "
                    f"{wave.replica.index} is non-finite or exceeds the "
                    f"proven bound {lane.output_bound:g}"),
                done)
            return 0
        lane.pool.mark_success(wave.replica, done)
        for r in wave.reqs:
            r.done_t = done
        for i, r in enumerate(wave.reqs):
            r.result = y[i]
            lane.metrics.record_completion(done, done - r.arrival_t)
        lane.metrics.record_wave(done, n, mb, service_s=done - wave.t0)
        lane.observe_service(done - wave.t0)
        tr = self.tracer
        if tr.enabled:
            # the dispatch span carries the FIFO-cost-model *predicted*
            # service time next to its measured duration — one
            # predicted-vs-measured training row per wave (obs.report)
            args = {"model": lane.name, "platform": self.platform,
                    "n_valid": n, "micro_batch": mb,
                    "replica": wave.replica.index}
            if lane.service is not None:
                args["predicted_ms"] = \
                    lane.service.wave_service_s(mb) * 1e3
                if lane.slo is not None:
                    # the controller's EWMA-corrected estimate, for
                    # auditing admission decisions (distinct from the raw
                    # model prediction above)
                    args["predicted_ewma_ms"] = wave.work_s * 1e3
            tr.add_span("wave", wave.t0, done, cat="router",
                        pid=1 + wave.replica.index, tid=lane.tid, args=args)
            for r in wave.reqs:
                # request span: arrival (enqueue) -> completion; duration
                # is exactly the latency ServeMetrics recorded, so
                # span-derived percentiles match snapshots to the bit
                tr.add_span("request", r.arrival_t, done, cat="router",
                            tid=lane.tid,
                            args={"uid": r.uid, "model": lane.name})
            tr.counter("backlog", len(lane.pending), t=done, tid=lane.tid)
            tr.counter("wave_occupancy", n / max(mb, 1), t=done,
                       tid=lane.tid)
            if not self.engine.blocking:
                tr.counter("inflight", lane.n_inflight, t=done,
                           tid=lane.tid)
        return n

    # -- failure path ------------------------------------------------------
    def _shed_wave(self, lane: _Lane, reqs: List[ServeRequest], now: float,
                   reason: str, exc: Optional[BaseException] = None) -> None:
        """Terminal failure: mark every request shed with a typed reason
        ("no_replica", "retries_exhausted") — the caller got a request
        object back from ``submit`` and reads the verdict off it."""
        tr = self.tracer
        for r in reqs:
            r.shed = True
            r.error = reason if exc is None else f"{reason}: {exc}"
            r.done_t = now
            lane.n_shed += 1
            lane.metrics.record_shed(now, reason=reason)
            if tr.enabled:
                tr.instant("shed", t=now, cat="router", tid=lane.tid,
                           uid=r.uid, model=lane.name, reason=reason)
                tr.counter("shed_total", lane.n_shed, t=now, tid=lane.tid)
                tr.add_span("request", r.arrival_t, now, cat="router",
                            tid=lane.tid,
                            args={"uid": r.uid, "model": lane.name,
                                  "shed": True, "reason": reason})

    def _park_retry(self, lane: _Lane, reqs: List[ServeRequest],
                    attempt: int, now: float, exclude: FrozenSet[int],
                    exc: BaseException) -> None:
        """Queue a failed wave's requests for re-dispatch after exponential
        backoff, or shed them once the retry budget is spent."""
        if attempt >= lane.cfg.max_retries:
            self._shed_wave(lane, reqs, now, reason="retries_exhausted",
                            exc=exc)
            return
        backoff = lane.cfg.retry_backoff_ms / 1e3 * (2 ** attempt)
        self._retries.append(_RetryWave(lane=lane, reqs=reqs,
                                        not_before_t=now + backoff,
                                        attempt=attempt + 1,
                                        exclude=exclude))
        if self.tracer.enabled:
            self.tracer.instant("wave_retry", t=now, cat="router",
                                tid=lane.tid, model=lane.name,
                                attempt=attempt + 1,
                                backoff_ms=backoff * 1e3)

    def _after_failure(self, wave: _InFlightWave, exc: BaseException,
                       now: float) -> None:
        """Post-release bookkeeping for a failed wave: degrade the replica,
        count the fault, cancel the handle, park the requests for retry on
        a different replica. ``arrival_t`` is untouched — the retried
        requests' latency keeps accruing from first arrival."""
        lane = wave.lane
        kind = {WaveTimeout: "timeout", CorruptWave: "integrity"} \
            .get(type(exc))
        if kind is None:
            kind = "crash" if "Crash" in type(exc).__name__ else "error"
        lane.pool.mark_failure(wave.replica, now,
                               reason=type(exc).__name__)
        lane.metrics.record_fault(now, kind)
        wave.handle.cancel()
        if self.tracer.enabled:
            self.tracer.instant("wave_failed", t=now, cat="router",
                                tid=lane.tid, model=lane.name,
                                replica=wave.replica.index, kind=kind,
                                attempt=wave.attempt)
            self.tracer.counter("inflight", lane.n_inflight, t=now,
                                tid=lane.tid)
        self._park_retry(lane, wave.reqs, wave.attempt, now,
                         wave.exclude | {wave.replica.index}, exc)

    def _fail_overdue(self, now: float) -> int:
        """Cancel every in-flight wave past its deadline whose handle
        isn't already ready (a result that made it in time is served even
        if reaped late); returns the number of waves failed."""
        overdue = [w for w in self._inflight
                   if w.deadline_t is not None and now >= w.deadline_t
                   and not w.handle.ready(now)]
        for w in overdue:
            self._inflight.remove(w)
            w.handle.cancel()
            self._release(w)
            self._after_failure(
                w, WaveTimeout(
                    f"wave on replica {w.replica.index} missed its "
                    f"deadline t={w.deadline_t:.6f} (now t={now:.6f})"),
                now)
        return len(overdue)

    def _reap_one(self, block: bool) -> int:
        """One reaping step: fail overdue waves, else settle the earliest
        ready wave, else (blocking) sleep toward the next event — a
        scripted completion, a wave deadline, or (blind real-device
        handles) the capped-backoff poll tick. Returns requests served
        this step, or -1 when non-blocking and nothing was actionable."""
        now = self.clock.now()
        if self._fail_overdue(now):
            return 0
        ready = [w for w in self._inflight if w.handle.ready(now)]
        if ready:
            return self._settle(min(ready, key=self._completion_key))
        if not block:
            return -1
        events = [w.handle.ready_t for w in self._inflight
                  if w.handle.ready_t is not None
                  and math.isfinite(w.handle.ready_t)]
        deadlines = [w.deadline_t for w in self._inflight
                     if w.deadline_t is not None]
        blind = any(w.handle.ready_t is None for w in self._inflight)
        if blind and not deadlines:
            # legacy blocking path (real devices, timeouts off): wait on
            # the earliest submission — the handle's own wait blocks
            return self._settle(min(self._inflight,
                                    key=self._completion_key))
        targets = events + deadlines
        if targets:
            target = min(targets)
            if blind:
                # never sleep past the poll tick while blind handles may
                # complete unannounced; back the tick off while idle
                target = min(target, now + self._poll_s)
                self._poll_s = min(self._poll_s * 2, _POLL_MAX_S)
            self.clock.sleep(max(target - now, 0.0))
            return 0
        # only scripted lost waves remain (ready_t = inf, no deadline):
        # settling raises the handle's typed WaveTimeout -> retry/shed,
        # so even a deadline-less blocking drain terminates
        return self._settle(min(self._inflight, key=self._completion_key))

    def reap(self, block: bool = False) -> int:
        """Settle completed in-flight waves (all of them with ``block``);
        returns the number of requests whose results landed. Overdue waves
        are failed onto the retry path first. A no-op under the blocking
        engine — waves never park in the table there."""
        served = 0
        while self._inflight:
            progressed = self._reap_one(block)
            if progressed < 0:
                break
            served += progressed
        return served

    def _dispatch_retries(self, now: float) -> int:
        """Re-dispatch every parked retry whose backoff has expired."""
        due = [rw for rw in self._retries if now >= rw.not_before_t]
        served = 0
        for rw in due:
            self._retries.remove(rw)
            served += self._dispatch(rw.lane, len(rw.reqs), reqs=rw.reqs,
                                     attempt=rw.attempt, exclude=rw.exclude)
        return served

    # -- event loop hooks --------------------------------------------------
    def step(self, now: Optional[float] = None) -> int:
        """Reap finished waves, then dispatch every lane whose wave is full
        or whose oldest pending request has hit the max-wait deadline.
        Returns #requests dispatched (== completed under the blocking
        engine)."""
        now = self.clock.now() if now is None else now
        self.reap()
        served = 0
        served += self._dispatch_retries(self.clock.now())
        for lane in self.lanes.values():
            while len(lane.pending) >= lane.micro_batch:
                served += self._dispatch(lane, lane.micro_batch)
            dl = lane.deadline()
            if dl is not None and now >= dl:
                served += self._dispatch(lane, lane.micro_batch)
        return served

    def next_deadline(self) -> Optional[float]:
        """Earliest pending batch deadline across lanes (None when idle)."""
        dls = [d for d in (lane.deadline() for lane in self.lanes.values())
               if d is not None]
        return min(dls) if dls else None

    def _next_wake(self) -> Optional[float]:
        """Earliest event the loop must wake for: a batch deadline, a
        scripted in-flight completion, a wave deadline, or a retry-backoff
        expiry. Real-device handles announce no ready_t; the caller bounds
        its sleep with the poll backoff instead. A scripted *lost* wave
        (``ready_t = inf``) is not an event — its wave deadline is."""
        times = [d for d in (self.next_deadline(),) if d is not None]
        times += [w.handle.ready_t for w in self._inflight
                  if w.handle.ready_t is not None
                  and math.isfinite(w.handle.ready_t)]
        times += [w.deadline_t for w in self._inflight
                  if w.deadline_t is not None]
        times += [rw.not_before_t for rw in self._retries]
        return min(times) if times else None

    def _has_blind_inflight(self) -> bool:
        return any(w.handle.ready_t is None for w in self._inflight)

    def dispatch_one(self, model: str, max_n: Optional[int] = None) -> int:
        """Dispatch at most one (possibly partial) wave for one lane —
        the explicit-stepping hook the ``TinyModelServer`` shim drives."""
        lane = self._lane(model)
        n = lane.micro_batch if max_n is None else min(int(max_n),
                                                       lane.micro_batch)
        return self._dispatch(lane, n)

    def flush(self, model: Optional[str] = None) -> int:
        """Force-dispatch pending requests (partial waves included)."""
        lanes = [self._lane(model)] if model else list(self.lanes.values())
        served = 0
        for lane in lanes:
            while lane.pending:
                served += self._dispatch(lane, lane.micro_batch)
        return served

    def drain(self) -> int:
        """Flush everything, reap every in-flight wave, and run parked
        retries to a verdict (served or shed); the end-of-trace barrier.
        Terminates even with lost waves in flight: every retry chain is
        bounded by ``max_retries`` and every blocking reap step either
        settles, fails, or advances the clock toward a finite event."""
        served = self.flush()
        while self._inflight or self._retries:
            if self._inflight:
                self.reap(block=True)
            if self._retries:
                t = min(rw.not_before_t for rw in self._retries)
                self.clock.sleep(max(t - self.clock.now(), 0.0))
                self._dispatch_retries(self.clock.now())
        return served

    # -- trace replay ------------------------------------------------------
    def run_trace(self, model: str, trace: Trace,
                  make_query: Callable[[int], np.ndarray]
                  ) -> List[ServeRequest]:
        """Replay an arrival trace against one lane in (clock) real time.

        Between arrivals the router sleeps only as far as the next event —
        a batch deadline or (async engine) a scripted in-flight completion
        — so deadline flushes and completion reaps fire at the right
        moment even in arrival gaps. Under a ``ManualClock`` this loop is
        an exact simulation: sleeps advance the clock instantly and
        service time is whatever the executor (or a scripted fake) makes
        of it.
        """
        t0 = self.clock.now()
        out: List[ServeRequest] = []
        arr = np.asarray(trace.arrivals)
        i = 0
        while i < len(arr):
            target = t0 + float(arr[i])
            if self.clock.now() >= target:
                # due (or late) arrival: submit before stepping. While the
                # server was busy these requests were conceptually queuing
                # — admitting the whole late burst first lets it coalesce
                # into full waves, as it would in a threaded server, and
                # ``arrival_t=target`` keeps the blocked wait on the books.
                out.append(self.submit(model, make_query(i),
                                       arrival_t=target))
                i += 1
                continue
            self.step()
            wake = self._next_wake()
            if self._has_blind_inflight():
                # real-device waves in flight: wake to reap at least every
                # poll interval so completion stamping tracks the device
                # (capped exponential backoff; any settle resets the floor)
                poll = self.clock.now() + self._poll_s
                self._poll_s = min(self._poll_s * 2, _POLL_MAX_S)
                wake = poll if wake is None else min(wake, poll)
            if wake is not None and wake < target:
                self.clock.sleep(max(wake - self.clock.now(), 0.0))
                self.step()
            else:
                self.clock.sleep(max(target - self.clock.now(), 0.0))
        # drain the tail: honour remaining deadlines and scripted
        # completions in event order, then flush + reap what's left
        wake = self._next_wake()
        while wake is not None:
            self.clock.sleep(max(wake - self.clock.now(), 0.0))
            self.step()
            wake = self._next_wake()
        self.drain()
        return out

    # -- observability -----------------------------------------------------
    def stats(self) -> Dict[str, Dict]:
        """Per-lane snapshot: metrics window + SLO estimates + replicas."""
        now = self.clock.now()
        out: Dict[str, Dict] = {}
        for name, lane in self.lanes.items():
            snap = lane.metrics.snapshot(now)
            d = {"metrics": snap, "micro_batch": lane.micro_batch,
                 "pending": len(lane.pending),
                 "inflight": lane.n_inflight,
                 "retries_pending": sum(len(rw.reqs)
                                        for rw in self._retries
                                        if rw.lane is lane),
                 "replicas": lane.pool.stats()}
            if lane.slo is not None:
                d["slo"] = {
                    "p99_budget_ms": lane.slo.p99_budget_ms,
                    "wave_service_ms":
                        lane.slo.wave_service_s(lane.micro_batch) * 1e3,
                    "arrival_qps": lane.slo.arrival_qps(now),
                    "utilization":
                        lane.slo.utilization(now, lane.micro_batch),
                    "occupancy_estimate": lane.slo.occupancy_estimate(
                        now, lane.micro_batch,
                        lane.cfg.max_wait_ms / 1e3),
                }
            out[name] = d
        return out
