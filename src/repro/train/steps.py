"""Train-step builders: pjit SPMD step, grad accumulation, explicit-DP step
with int8-compressed gradient all-reduce.

``make_train_step`` is what the dry-run lowers for every train_4k cell:
loss -> grads (GSPMD inserts the DP reduce + FSDP reduce-scatters) -> AdamW.

``make_ddp_compressed_step`` is the explicit data-parallel variant built on
shard_map: per-shard grads -> int8 psum with error feedback -> update. It
exists to make the gradient-compression trick real and testable (the pjit
path's all-reduce is implicit and can't be compressed from user code).
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models.layers import shard_map
from repro.models.model import Model
from repro.optim.adamw import Optimizer
from repro.parallel.collectives import compressed_psum_tree


class TrainState(NamedTuple):
    params: Any
    opt: Any


def make_train_step(model: Model, optimizer: Optimizer,
                    microbatches: int = 1) -> Callable:
    """SPMD train step. With microbatches>1, grads are accumulated over
    sequential microbatches (the paper's reuse-factor trade — latency for
    working-set — applied to the training step)."""

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def train_step(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                state.params, batch
            )
        else:
            def mb_slice(b, i):
                return jax.tree.map(
                    lambda x: x.reshape(microbatches, -1, *x.shape[1:])[i], b
                )

            def acc_body(carry, i):
                gsum, lsum = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    state.params, mb_slice(batch, i)
                )
                return (jax.tree.map(jnp.add, gsum, g), lsum + l), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params
            )
            (gsum, lsum), _ = jax.lax.scan(
                acc_body, (zeros, jnp.zeros((), jnp.float32)),
                jnp.arange(microbatches),
            )
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = lsum / microbatches
            metrics = {}
        new_params, new_opt, opt_metrics = optimizer.update(
            grads, state.opt, state.params
        )
        out = {"loss": loss, **opt_metrics}
        out.update({k: v for k, v in (metrics or {}).items()})
        return TrainState(new_params, new_opt), out

    return train_step


def make_eval_step(model: Model) -> Callable:
    def eval_step(params, batch):
        loss, metrics = model.loss(params, batch)
        return {"loss": loss, **metrics}

    return eval_step


# ---------------------------------------------------------------------------
# explicit-DP with compressed gradients
# ---------------------------------------------------------------------------

class DDPState(NamedTuple):
    params: Any
    opt: Any
    err: Any          # error-feedback residuals (f32, per shard)


def make_ddp_compressed_step(loss_fn: Callable, optimizer: Optimizer,
                             mesh: Mesh, data_axes=("data",)) -> Callable:
    """Params replicated, batch sharded over data_axes; per-shard grads are
    all-reduced as int8 with error feedback, then AdamW runs replicated."""
    axis_size = 1
    for a in data_axes:
        axis_size *= mesh.shape[a]

    def local_step(params, opt, err, batch):
        (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        mean_grads, new_err = compressed_psum_tree(grads, err, data_axes, axis_size)
        new_params, new_opt, om = optimizer.update(mean_grads, opt, params)
        loss = jax.lax.pmean(loss, data_axes)
        return new_params, new_opt, new_err, loss, om["grad_norm"]

    def step(state: DDPState, batch):
        rep = lambda tree: jax.tree.map(lambda _: P(), tree)  # noqa: E731
        bspec = jax.tree.map(lambda _: P(data_axes), batch)
        fn = shard_map(
            local_step, mesh,
            in_specs=(rep(state.params), rep(state.opt), rep(state.err), bspec),
            out_specs=(rep(state.params), rep(state.opt), rep(state.err), P(), P()),
        )
        new_p, new_o, new_e, loss, gn = fn(state.params, state.opt, state.err, batch)
        return DDPState(new_p, new_o, new_e), {"loss": loss, "grad_norm": gn}

    return step


def init_ddp_state(params, optimizer: Optimizer) -> DDPState:
    err = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return DDPState(params=params, opt=optimizer.init(params), err=err)
