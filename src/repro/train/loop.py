"""Fault-tolerant training loop: checkpoint/restart, straggler watchdog,
preemption safety, deterministic resume.

Mechanisms (each unit-tested with injected faults in tests/test_train_loop.py):

  * **auto-resume**: on start, the loop restores the latest checkpoint if one
    exists; the data pipeline is a pure function of step, so resume is exact.
  * **preemption / crash**: checkpoints are atomic (checkpoint/), so a kill
    at any instant loses at most `ckpt_every` steps.
  * **straggler watchdog**: per-step wall time is tracked against a running
    median; `slow_factor`x outliers increment a straggler counter. After
    `max_consecutive_slow` consecutive slow steps the loop checkpoints and
    raises ``ElasticRestart`` — on a real pod the scheduler remaps the slice
    (excluding the slow host) and relaunches; restore reshards onto the new
    mesh (checkpoint.restore takes any target sharding).
  * **fault hooks**: `step_hook(step)` lets tests inject latency or
    exceptions at precise steps to exercise every path.
"""

from __future__ import annotations

import dataclasses
import logging
import statistics
from typing import Any, Callable, Dict, List, Optional

import jax

from repro.checkpoint.checkpoint import CheckpointManager, latest_step
from repro.obs import timer as obs_timer

log = logging.getLogger("repro.train")


class ElasticRestart(RuntimeError):
    """Raised when the watchdog requests a mesh remap; the launcher catches
    this, rebuilds the mesh from surviving devices, and calls run() again."""


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep_n: int = 3
    log_every: int = 10
    slow_factor: float = 3.0
    max_consecutive_slow: int = 5
    watchdog_warmup: int = 10
    # floor on the reference step time: sub-millisecond steps (toy models,
    # tests) sit inside OS scheduler jitter, so comparing against their raw
    # median makes the watchdog fire on noise rather than stragglers
    watchdog_min_step_s: float = 0.05


@dataclasses.dataclass
class LoopResult:
    final_step: int
    metrics_history: List[Dict]
    resumed_from: Optional[int]
    straggler_events: int


def run_training(
    train_step: Callable,
    init_state: Any,
    batch_fn: Callable[[int], Any],
    cfg: LoopConfig,
    step_hook: Optional[Callable[[int], None]] = None,
    time_fn: Optional[Callable[[], float]] = None,
) -> LoopResult:
    """Run (or resume) training until cfg.total_steps."""
    if time_fn is None:
        time_fn = obs_timer.now   # injectable process-wide clock
    mgr = CheckpointManager(cfg.ckpt_dir, every=cfg.ckpt_every, keep_n=cfg.keep_n)
    state = init_state
    start = 0
    resumed_from = None
    if latest_step(cfg.ckpt_dir) is not None:
        state, start, manifest = mgr.restore_latest(init_state)
        resumed_from = start
        log.info("resumed from step %d", start)

    history: List[Dict] = []
    step_times: List[float] = []
    consecutive_slow = 0
    straggler_events = 0

    step = start
    try:
        while step < cfg.total_steps:
            t0 = time_fn()
            if step_hook is not None:
                step_hook(step)
            batch = batch_fn(step)
            state, metrics = train_step(state, batch)
            # block so the watchdog measures real step time
            jax.block_until_ready(jax.tree.leaves(metrics)[0])
            dt = time_fn() - t0
            step += 1
            step_times.append(dt)

            if step % cfg.log_every == 0:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"], m["step_time_s"] = step, dt
                history.append(m)
                log.info("step %d %s", step, m)

            # ---- straggler watchdog -----------------------------------
            if len(step_times) > cfg.watchdog_warmup:
                med = max(statistics.median(step_times[-50:]),
                          cfg.watchdog_min_step_s)
                if dt > cfg.slow_factor * med:
                    consecutive_slow += 1
                    straggler_events += 1
                    log.warning("slow step %d: %.3fs vs median %.3fs", step, dt, med)
                else:
                    consecutive_slow = 0
                if consecutive_slow >= cfg.max_consecutive_slow:
                    mgr.maybe_save(step, state, block=True, force=True)
                    raise ElasticRestart(
                        f"{consecutive_slow} consecutive straggler steps at {step}"
                    )

            mgr.maybe_save(step, state)
    except ElasticRestart:
        raise
    except BaseException:
        # crash path: best-effort synchronous checkpoint, then re-raise
        try:
            mgr.maybe_save(step, state, block=True, force=True)
        except BaseException:  # pragma: no cover
            pass
        raise
    finally:
        mgr.wait()

    mgr.maybe_save(step, state, block=True, force=True)
    return LoopResult(
        final_step=step,
        metrics_history=history,
        resumed_from=resumed_from,
        straggler_events=straggler_events,
    )
