"""Dataflow pipeline model + FIFO buffer-depth optimization (paper §3.1.2).

The paper sizes inter-layer FIFO buffers by RTL-simulating the whole design
with oversized FIFOs, recording the maximum occupancy of each, then setting
depth = max_occupancy + 1. On TPU there is no RTL, but the same question —
"how much buffering does a producer/consumer pipeline need to sustain full
throughput?" — appears in (a) the tiny-model dataflow pipeline we emit for
deployment and (b) host->device prefetch in the input pipeline.

This module implements a cycle-accurate discrete-event simulation of a linear
dataflow pipeline (stages with initiation interval II, pipeline latency L, and
rate conversion elems_in -> elems_out), the occupancy recorder, and the
depth-optimization pass. `optimize_fifo_depths` reproduces the paper's
workflow: simulate big -> record max -> shrink to max+1 -> re-simulate and
assert zero throughput loss.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class Stage:
    """One dataflow stage.

    Consumes ``elems_in`` tokens, then ``latency`` cycles later emits
    ``elems_out`` tokens; can start a new batch every ``ii`` cycles
    (initiation interval — the paper's reuse factor shows up here: RF=r
    multiplies II by r).
    """

    name: str
    ii: int = 1
    latency: int = 1
    elems_in: int = 1
    elems_out: int = 1


BIG_DEPTH = 1 << 20


def simulate_pipeline(
    stages: Sequence[Stage],
    n_tokens: int,
    depths: Sequence[int],
    max_cycles: int = 50_000_000,
) -> Tuple[int, List[int]]:
    """Simulate a linear pipeline fed with ``n_tokens`` input tokens.

    depths[i] is the capacity of the FIFO *in front of* stage i (depths[0] is
    the input FIFO, assumed fed at 1 token/cycle); an extra output FIFO of
    unbounded size collects results. Returns (total_cycles, max_occupancy per
    FIFO). A stage stalls if its input lacks elems_in tokens or its output
    FIFO lacks space for elems_out.
    """
    n = len(stages)
    occ = [0] * (n + 1)           # occ[i]: tokens in FIFO feeding stage i; occ[n] = output
    max_occ = [0] * (n + 1)
    next_free = [0] * n           # cycle at which stage may initiate again
    # in-flight completions: list of (finish_cycle, stage_idx)
    inflight: List[Tuple[int, int]] = []
    fed = 0
    produced_total = 0
    expected_out = n_tokens
    for st in stages:
        expected_out = (expected_out // st.elems_in) * st.elems_out

    cycle = 0
    while produced_total < expected_out:
        if cycle > max_cycles:
            raise RuntimeError("pipeline simulation did not converge (deadlock?)")
        # 1) retire in-flight work finishing this cycle
        still = []
        for fin, i in inflight:
            if fin == cycle:
                occ[i + 1] += stages[i].elems_out
                max_occ[i + 1] = max(max_occ[i + 1], occ[i + 1])
                if i + 1 == n:
                    produced_total += stages[i].elems_out
            else:
                still.append((fin, i))
        inflight = still
        # 2) feed input FIFO (1 token per cycle, respecting its depth)
        if fed < n_tokens and occ[0] < depths[0]:
            occ[0] += 1
            fed += 1
            max_occ[0] = max(max_occ[0], occ[0])
        # 3) stage initiations (downstream first, frees space for upstream)
        for i in reversed(range(n)):
            st = stages[i]
            out_cap = depths[i + 1] if i + 1 < n else BIG_DEPTH
            out_occ = occ[i + 1] if i + 1 <= n else 0
            if (
                cycle >= next_free[i]
                and occ[i] >= st.elems_in
                and (i + 1 == n or out_occ + st.elems_out <= out_cap)
            ):
                occ[i] -= st.elems_in
                next_free[i] = cycle + st.ii
                inflight.append((cycle + max(st.latency, 1), i))
        cycle += 1
    return cycle, max_occ


def optimize_fifo_depths(
    stages: Sequence[Stage], n_tokens: int
) -> Dict[str, object]:
    """Paper §3.1.2 as an optimization pass.

    1. simulate with effectively-unbounded FIFOs,
    2. record per-FIFO max occupancy,
    3. set depth = max_occupancy + 1,
    4. re-simulate and verify total cycles did not regress.
    Returns dict with baseline/optimized depths, cycles, and the resource
    saving (sum of depths, the BRAM/LUT analogue).
    """
    n = len(stages)
    big = [BIG_DEPTH] * (n + 1)
    base_cycles, max_occ = simulate_pipeline(stages, n_tokens, big)
    opt_depths = [m + 1 for m in max_occ]
    opt_cycles, _ = simulate_pipeline(stages, n_tokens, opt_depths)
    return {
        "baseline_depths": big[: n + 1],
        "optimized_depths": opt_depths,
        "baseline_cycles": base_cycles,
        "optimized_cycles": opt_cycles,
        "throughput_preserved": opt_cycles <= base_cycles,
        "total_buffer_elems": sum(opt_depths),
    }


#: Modeled element throughput of one pipeline stage, elements per simulated
#: cycle. One simulated cycle stands for "the time a stage needs to chew
#: through this many accumulator elements"; the absolute value only sets the
#: cycle unit, the *ratios* between stages are what size the FIFOs.
STAGE_ELEMS_PER_CYCLE = 8192

#: Fixed per-initiation cost in simulated cycles: the dispatch/launch/sync
#: overhead a stage pays every time it starts a micro-batch, independent of
#: the micro-batch size. This is the term that makes tiny micro-batches
#: expensive (many hops) and is what the micro-batch autotuner trades against
#: pipeline fill/drain latency (which grows with the micro-batch).
HOP_OVERHEAD_CYCLES = 8


def micro_batch_stage(name: str, work: int, micro_batch: int = 1,
                      *, elems_per_cycle: int = STAGE_ELEMS_PER_CYCLE,
                      overhead: int = HOP_OVERHEAD_CYCLES) -> Stage:
    """Simulation stage for one compiled deploy stage at a micro-batch size.

    ``work`` is the stage's per-sample element count (``fifo_work``); a
    micro-batch of ``micro_batch`` samples costs
    ``overhead + ceil(work * micro_batch / elems_per_cycle)`` cycles, and the
    stage is busy for the whole service time (ii == latency — the executor
    runs one micro-batch at a time per stage). Total batch cycles therefore
    trade hop overhead (favors big micro-batches) against pipeline fill/drain
    (favors small ones) — the optimum the FIFO-model autotuner searches for.
    """
    mb = max(int(micro_batch), 1)
    lat = int(overhead) + max(1, -(-int(work) * mb // int(elems_per_cycle)))
    return Stage(name=name, ii=lat, latency=lat, elems_in=1, elems_out=1)


def mlp_pipeline_stages(layer_dims: Sequence[int], reuse_factor: int = 1) -> List[Stage]:
    """Build the dataflow stage graph of an MLP deployment.

    Each dense layer consumes its full input vector and emits its output
    vector; II scales with the reuse factor (paper §3.3.2: RF = number of
    times each multiplier is reused; latency ~ RF)."""
    stages = []
    for i in range(len(layer_dims) - 1):
        fan_in, fan_out = layer_dims[i], layer_dims[i + 1]
        stages.append(
            Stage(
                name=f"dense_{i}",
                ii=max(reuse_factor, 1),
                latency=max(reuse_factor, 1) + 2,  # mult chain + accum + act
                elems_in=fan_in,
                elems_out=fan_out,
            )
        )
    return stages


def conv_pipeline_stages(shapes: Sequence[Tuple[int, int, int, int]]) -> List[Stage]:
    """Stages for a conv stack; shapes: (in_elems, out_elems, ii, latency)."""
    return [
        Stage(name=f"conv_{i}", ii=ii, latency=lat, elems_in=ein, elems_out=eout)
        for i, (ein, eout, ii, lat) in enumerate(shapes)
    ]


def prefetch_depth(producer_period: float, consumer_period: float, jitter: float = 2.0) -> int:
    """Host->device prefetch-buffer depth from the same occupancy logic:
    enough slots to cover consumer stalls of `jitter` periods."""
    ratio = producer_period / max(consumer_period, 1e-9)
    return max(2, int(jitter * max(ratio, 1.0)) + 1)
