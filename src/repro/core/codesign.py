"""End-to-end codesign driver — the paper's §5 methodology as one function:

  1. train a float baseline,
  2. hyperparameter-search the architecture scored by accuracy + BOPs
     (ASHA or BO, core/search.py),
  3. lower the bit width until quality degrades ("smallest width retaining
     the baseline"), Fig. 4's procedure,
  4. streamline + deploy (integer thresholds), report hardware cost.

Used by examples/mlperf_tiny_*.py and the Fig. 2/3/4 benchmarks with small
budgets; everything here is dataset- and model-agnostic via callables.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# minimal Adam for tiny models (the big stack uses optim/adamw.py)
# ---------------------------------------------------------------------------

def train_tiny(
    loss_fn: Callable,            # (params, batch, rngkey) -> scalar
    params,
    batch_fn: Callable[[int], Any],
    steps: int = 200,
    lr: float = 1e-3,
    seed: int = 0,
) -> Tuple[Any, List[float]]:
    m = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    v = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)

    @jax.jit
    def step_fn(params, m, v, batch, t):
        loss, g = jax.value_and_grad(loss_fn)(params, batch)
        b1, b2, eps = 0.9, 0.999, 1e-8
        m = jax.tree.map(lambda m_, g_: b1 * m_ + (1 - b1) * g_, m, g)
        v = jax.tree.map(lambda v_, g_: b2 * v_ + (1 - b2) * g_ ** 2, v, g)
        tf = t.astype(jnp.float32) + 1
        def upd(p, m_, v_):
            mh = m_ / (1 - b1 ** tf)
            vh = v_ / (1 - b2 ** tf)
            return p - lr * mh / (jnp.sqrt(vh) + eps)
        params = jax.tree.map(upd, params, m, v)
        return params, m, v, loss

    losses = []
    for t in range(steps):
        batch = batch_fn(t)
        params, m, v, loss = step_fn(params, m, v, batch, jnp.int32(t))
        losses.append(float(loss))
    return params, losses


# ---------------------------------------------------------------------------
# bit-width descent (Fig. 4 procedure)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class BitwidthScanResult:
    entries: List[Dict]           # bits, accuracy, bops
    chosen_bits: int


def bitwidth_descent(
    eval_at_bits: Callable[[int], Tuple[float, float]],  # bits -> (quality, bops)
    bit_ladder: Sequence[int] = (32, 8, 6, 4, 3, 2, 1),
    tolerance: float = 0.02,
) -> BitwidthScanResult:
    """Lower precision until quality drops > tolerance below the float
    baseline; choose the smallest width that retains it (paper §5)."""
    entries = []
    baseline = None
    chosen = bit_ladder[0]
    for bits in bit_ladder:
        q, bops = eval_at_bits(bits)
        entries.append({"bits": bits, "quality": q, "bops": bops})
        if baseline is None:
            baseline = q
        if q >= baseline - tolerance:
            chosen = bits
    return BitwidthScanResult(entries=entries, chosen_bits=chosen)


# ---------------------------------------------------------------------------
# deployment report (the per-model rows of paper Tables 1 / 5)
# ---------------------------------------------------------------------------

# TPU v5e-style deployment constants for the latency/energy model
PEAK_INT8_OPS = 394e12      # int8 TOPS per chip
PEAK_BF16_FLOPS = 197e12
HBM_BW = 819e9
CHIP_WATTS = 200.0          # board power envelope (energy model)


def deploy_report(model_cost, batch: int = 1, bits: int = 8) -> Dict[str, float]:
    """Roofline latency + energy per inference for a tiny model on one chip.

    The FPGA latency/energy columns of Table 5 become a TPU roofline model:
    latency = max(compute-term, memory-term); energy = power x latency.
    """
    ops = 2.0 * model_cost.flops / 2.0 * batch      # MACs*2 = flops
    peak = PEAK_INT8_OPS if bits <= 8 else PEAK_BF16_FLOPS
    compute_s = model_cost.flops * batch / peak
    bytes_moved = model_cost.wm_bits / 8 + model_cost.flops * batch / 4  # weights + acts
    memory_s = bytes_moved / HBM_BW
    latency = max(compute_s, memory_s, 1e-9)
    return {
        "latency_us": latency * 1e6,
        "energy_uJ": latency * CHIP_WATTS * 1e6,
        "bound": "memory" if memory_s > compute_s else "compute",
        "bops": model_cost.bops,
        "wm_bits": model_cost.wm_bits,
        "params": model_cost.n_params,
    }
