"""Hardware-cost metrics: BOPs, weight memory, inference cost C, FLOPs.

Implements the paper's Eq. 1 / Eq. 2 verbatim:

  BOPs ~= m*n*k^2 * (b_a*b_w + b_a + b_w + log2(n*k^2))          (Eq. 1)
  C     = 0.5 * (BOPs/BOPs_ref + WM/WM_ref)                      (Eq. 2)

plus FLOPs counting for float models (the Fig. 2 x-axis) and the
6*N*D model-FLOPs rule used by the LM-scale roofline (§Roofline).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Iterable, List, Optional


def conv_bops(m: int, n: int, k: int, b_a: int, b_w: int, out_hw: int = 1) -> float:
    """Eq. 1 for one conv layer, times the number of output positions.

    m: out channels, n: in channels, k: kernel size, out_hw: H_out*W_out.
    The paper's Eq. 1 counts MACs per output position; multiply by positions
    for total BOPs of the layer.
    """
    per_pos = m * n * k * k * (b_a * b_w + b_a + b_w + math.log2(max(n * k * k, 2)))
    return per_pos * out_hw


def dense_bops(m: int, n: int, b_a: int, b_w: int) -> float:
    """Eq. 1 with k=1 (fully connected)."""
    return conv_bops(m, n, 1, b_a, b_w, out_hw=1)


def weight_memory_bits(n_weights: int, b_w: int) -> int:
    """WM: total bits to store the weights."""
    return n_weights * b_w


def inference_cost(bops: float, wm: float, bops_ref: float, wm_ref: float) -> float:
    """Eq. 2 relative inference cost."""
    return 0.5 * (bops / bops_ref + wm / wm_ref)


@dataclass
class LayerCost:
    name: str
    bops: float
    wm_bits: int
    flops: float
    n_params: int
    traffic_bytes: float = 0.0   # per-query memory traffic (schedule costing)


def dense_cost(name, in_dim, out_dim, b_a=8, b_w=8, bias=True) -> LayerCost:
    n_w = in_dim * out_dim + (out_dim if bias else 0)
    return LayerCost(
        name=name,
        bops=dense_bops(out_dim, in_dim, b_a, b_w),
        wm_bits=weight_memory_bits(n_w, b_w),
        flops=2.0 * in_dim * out_dim,
        n_params=n_w,
    )


def conv_cost(name, in_ch, out_ch, k, out_h, out_w, b_a=8, b_w=8, bias=True) -> LayerCost:
    n_w = k * k * in_ch * out_ch + (out_ch if bias else 0)
    return LayerCost(
        name=name,
        bops=conv_bops(out_ch, in_ch, k, b_a, b_w, out_hw=out_h * out_w),
        wm_bits=weight_memory_bits(n_w, b_w),
        flops=2.0 * k * k * in_ch * out_ch * out_h * out_w,
        n_params=n_w,
    )


@dataclass
class ModelCost:
    layers: List[LayerCost]

    @property
    def bops(self) -> float:
        return sum(l.bops for l in self.layers)

    @property
    def wm_bits(self) -> int:
        return sum(l.wm_bits for l in self.layers)

    @property
    def flops(self) -> float:
        return sum(l.flops for l in self.layers)

    @property
    def n_params(self) -> int:
        return sum(l.n_params for l in self.layers)

    @property
    def traffic_bytes(self) -> float:
        return sum(l.traffic_bytes for l in self.layers)

    def cost_vs(self, ref: "ModelCost") -> float:
        return inference_cost(self.bops, self.wm_bits, ref.bops, ref.wm_bits)

    def table(self) -> str:
        rows = [f"{'layer':24s} {'params':>10s} {'BOPs':>14s} {'WM[bits]':>12s} {'FLOPs':>14s}"]
        for l in self.layers:
            rows.append(
                f"{l.name:24s} {l.n_params:>10d} {l.bops:>14.3e} {l.wm_bits:>12d} {l.flops:>14.3e}"
            )
        rows.append(
            f"{'TOTAL':24s} {self.n_params:>10d} {self.bops:>14.3e} {self.wm_bits:>12d} {self.flops:>14.3e}"
        )
        return "\n".join(rows)


# ---------------------------------------------------------------------------
# compiled-schedule costing (deploy.lower stage lists)
# ---------------------------------------------------------------------------

def conv_input_band_bytes(geom, block_h: int) -> float:
    """Input bytes one query streams through the fused direct-conv kernel at
    a given output-row block size.

    The kernel fetches per-row-block *bands* of
    ``(block_h - 1) * stride + kernel`` padded input rows (halo rows
    duplicated across adjacent bands — the line-buffer overlap), so smaller
    blocks re-fetch more halo rows while bigger blocks need a bigger VMEM
    accumulator. This is the byte term the ``block_h`` autotuner minimizes
    (``deploy.autotune.plan_block_h``) and what ``stage_traffic_bytes``
    charges a tuned direct stage.
    """
    from repro.kernels.conv_threshold import band_rows, same_pads

    bh = max(1, min(int(block_h), geom.out_h))
    n_blocks = -(-geom.out_h // bh)
    if geom.padding == "SAME":
        (_, _), (pw_lo, pw_hi) = same_pads(geom.in_h, geom.in_w, geom.out_h,
                                           geom.out_w, geom.stride,
                                           geom.kernel)
        wp = geom.in_w + pw_lo + pw_hi
    else:
        wp = geom.in_w
    return 4.0 * n_blocks * band_rows(bh, geom.stride, geom.kernel) \
        * wp * geom.in_ch


def stage_traffic_bytes(stage) -> float:
    """Memory-traffic model of one lowered deploy stage, for a single
    batch-1 query (the MLPerf SingleStream unit; batched execution
    amortizes the parameter term, which this model deliberately does not).

    The stage reads its input codes and writes its output codes (int32,
    4 bytes) and reads its parameters (int8 weight codes, int32
    thresholds). Conv stages are lowering-aware — the point of the
    fused direct-conv kernel: an ``im2col``-lowered stage additionally
    writes *and* re-reads the materialized (OH*OW, K*K*C) patch matrix,
    the O(K^2*C) blow-up the ``direct`` kernel keeps in-register. This is
    the byte term the kernel benchmark and the scenario energy proxy chart
    next to Eq. 1's BOPs.
    """
    io = 4.0 * (int(getattr(stage, "in_dim", 0))
                + int(getattr(stage, "out_dim", 0)))
    bank = getattr(stage, "stage", None)        # ThresholdDense, if fused
    params = 0.0
    if bank is not None:
        params = (float(math.prod(bank.w_int.shape))          # int8 codes
                  + 4.0 * float(math.prod(bank.thresholds.shape)))
    w = getattr(stage, "w", None)               # FloatHeadStage
    if w is not None:
        params = 4.0 * float(math.prod(w.shape))
    geom = getattr(stage, "geom", None)
    if geom is not None and getattr(stage, "lowering", "direct") == "im2col":
        patch = (geom.out_h * geom.out_w
                 * geom.kernel * geom.kernel * geom.in_ch)
        io += 2.0 * 4.0 * patch                 # write + read the im2col mat
    elif geom is not None:
        # direct lowering always streams per-row-block bands (halo rows
        # duplicated per block) — tuned block_h or the planner's default —
        # so the banded fetch replaces the flat input term either way and
        # tuned vs untuned traffic stays comparable
        bh = getattr(stage, "block_h", None)
        if not bh:
            from repro.kernels.ops import plan_conv_blocks

            bh = plan_conv_blocks(geom.out_h, geom.out_w, geom.out_ch)
        io += conv_input_band_bytes(geom, bh) \
            - 4.0 * int(getattr(stage, "in_dim", 0))
    return io + params


def stage_cost(stage) -> LayerCost:
    """Eq. 1/2 cost of one lowered deploy stage, by duck type.

    Works on any ``deploy.lower`` stage: conv stages carry a ``geom``
    (kernel/out-tile geometry -> conv_bops), matmul-like stages carry
    in_dim/out_dim, and data-movement stages (pool/flatten) cost zero BOPs.
    ``in_bits``/``stage.weight_bits`` feed Eq. 1's b_a/b_w, so the energy
    proxy of a compiled conv schedule is precision-aware end to end, and
    ``traffic_bytes`` carries the lowering-aware memory term (im2col
    patch-matrix bytes vs none for the fused direct kernel).
    """
    name = getattr(stage, "name", type(stage).__name__)
    b_a = int(getattr(stage, "in_bits", 8))
    bank = getattr(stage, "stage", None)        # ThresholdDense, if fused
    b_w = int(getattr(bank, "weight_bits", 8))
    geom = getattr(stage, "geom", None)
    traffic = stage_traffic_bytes(stage)
    if geom is not None:                        # FusedConvThresholdStage
        c = conv_cost(name, geom.in_ch, geom.out_ch, geom.kernel,
                      geom.out_h, geom.out_w, b_a, b_w, bias=False)
        c.traffic_bytes = traffic
        return c
    w = getattr(stage, "w", None)               # FloatHeadStage
    if bank is not None or w is not None:
        c = dense_cost(name, int(stage.in_dim), int(stage.out_dim),
                       b_a, b_w, bias=w is not None)
        c.traffic_bytes = traffic
        return c
    # pool / flatten / fallback chains: no MACs, just movement
    return LayerCost(name=name, bops=0.0, wm_bits=0, flops=0.0, n_params=0,
                     traffic_bytes=traffic)


def schedule_cost(stages: Iterable) -> ModelCost:
    """ModelCost of a compiled ``StageSchedule.stages`` list — the energy
    proxy the MLPerf-Tiny scenario runtime attaches to conv deployments."""
    return ModelCost([stage_cost(s) for s in stages])


# ---------------------------------------------------------------------------
# megakernel residency accounting + residency-aware traffic model
# ---------------------------------------------------------------------------

#: VMEM cap the megakernel residency planner budgets against — the same
#: conservative per-program working-set budget the block_h / block_mn
#: models use (``deploy.autotune.VMEM_BUDGET_BYTES``; real cores have
#: ~16 MB, the margin covers compiler padding to (8, 128) tiles and the
#: revolving input/output row blocks).
MEGAKERNEL_VMEM_BYTES = 1 << 21


def megakernel_residency_bytes(stages, block_m: int = 128) -> dict:
    """VMEM working set of an entire FusedThresholdStage run fused into one
    resident megakernel (``kernels.megakernel``): every stage's int8 weight
    matrix and int32 threshold bank live in VMEM for the whole wave, plus
    the two revolving inter-stage FIFO tiles (int32, ``block_m`` rows by
    the widest intermediate dim) and the input/output row blocks. This is
    the byte accounting ``deploy.lower.plan_megakernel`` sums against
    ``MEGAKERNEL_VMEM_BYTES`` — all components reported so the audit trail
    (and ``scripts/check_megakernel_residency.py``) can re-add them.
    """
    stages = list(stages)
    weight = sum(int(math.prod(s.stage.w_int.shape)) for s in stages)
    bank = sum(4 * int(math.prod(s.stage.thresholds.shape)) for s in stages)
    dims = [int(stages[0].in_dim)] + [int(s.out_dim) for s in stages]
    inter = max(dims[1:-1], default=0)
    tile = (4 * block_m * (dims[0] + dims[-1])    # input + output row blocks
            + 2 * 4 * block_m * inter)            # two revolving FIFO tiles
    return {"weight_bytes": int(weight), "bank_bytes": int(bank),
            "tile_bytes": int(tile),
            "total_bytes": int(weight + bank + tile)}


def megakernel_traffic_bytes(stages, wave_rows: int) -> float:
    """Residency-aware HBM traffic of one fused wave: parameters are
    fetched ONCE (they stay resident across the whole wave), activations
    cross HBM only at the segment boundary — the wave input is read and the
    final codes written; every inter-stage tensor lives in VMEM scratch."""
    stages = list(stages)
    res = megakernel_residency_bytes(stages)
    io = 4.0 * wave_rows * (int(stages[0].in_dim) + int(stages[-1].out_dim))
    return io + res["weight_bytes"] + res["bank_bytes"]


def staged_traffic_bytes(stages, wave_rows: int) -> float:
    """The per-stage dispatch baseline the megakernel deletes: every stage
    program re-reads its parameters and round-trips its input and output
    activations through HBM (the inter-stage write+read the fused kernel
    keeps on-chip). The difference vs ``megakernel_traffic_bytes`` is the
    modeled saving the autotuner ranks the megakernel/staged choice by."""
    total = 0.0
    for s in stages:
        total += 4.0 * wave_rows * (int(s.in_dim) + int(s.out_dim))
        total += float(math.prod(s.stage.w_int.shape))
        total += 4.0 * float(math.prod(s.stage.thresholds.shape))
    return total


# ---------------------------------------------------------------------------
# LM-scale model FLOPs (used by launch/roofline.py)
# ---------------------------------------------------------------------------

def lm_model_flops(n_active_params: int, n_tokens: int, training: bool = True) -> float:
    """6*N*D for training (fwd+bwd), 2*N*D for inference forward."""
    mult = 6.0 if training else 2.0
    return mult * n_active_params * n_tokens
