"""Streamlining: fold float bookkeeping into integer multi-threshold ops.

This is the paper's C2 (§3.5, after Umuroglu & Jahre 2017). For a uniformly
quantized network, every float chain

    acc(int32) --*s_w*s_a--> float --BN--> float --ReLU--> float --quant--> q_out

is monotonic in the integer accumulator, so it collapses to a bank of integer
thresholds per output channel:

    q_out = sum_i [ acc >= T[c, i] ]          (i = 1 .. 2^bits - 1)

The deployed graph then contains only int8 weights, int32 accumulators,
integer threshold compares, and one power-of-two output scale — exactly what
FINN emits as "multi-threshold" nodes, and what our Pallas kernel
(kernels/multi_threshold.py) executes on TPU.

Exactness note: the float reference uses round-half-up at quant boundaries
(thresholds are the half-step points); jnp.round is half-even, so we define
``quant_act_ref`` with half-up semantics and test against it. Off-boundary
inputs (measure-1 set) agree with any tie rule.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.qlayers import QDense, QDenseBatchNorm
from repro.core.quantizers import IntQuantizer, quantize_po2


def quant_act_ref(y, s_out: float, qmax: int):
    """Unsigned activation quant with round-half-up: clip(floor(y/s+0.5),0,qmax)."""
    return jnp.clip(jnp.floor(y / s_out + 0.5), 0, qmax).astype(jnp.int32)


@dataclasses.dataclass
class ThresholdDense:
    """A streamlined (deployment-form) dense stage.

    y_int = multi_threshold(x_int @ w_int, thresholds)  in [0, 2^act_bits - 1]
    float value of the output = y_int * out_scale.
    """

    w_int: jnp.ndarray        # (in, out) int8 codes
    thresholds: jnp.ndarray   # (out, n_steps) int32, sorted along steps
    out_scale: float          # po2 scalar
    act_bits: int

    @property
    def n_steps(self) -> int:
        return 2 ** self.act_bits - 1


def multi_threshold(acc, thresholds):
    """Reference multi-threshold: out[..., c] = #{i : acc[..., c] >= T[c, i]}.

    acc: (..., C) int32;  thresholds: (C, S) int32  ->  (..., C) int32.
    """
    return jnp.sum(
        acc[..., None] >= thresholds[(None,) * (acc.ndim - 1)], axis=-1
    ).astype(jnp.int32)


def multi_threshold_sorted(acc, thresholds):
    """``multi_threshold`` in O(log S) per element for *sorted* banks.

    streamline_dense always emits monotone threshold banks, so the count
    #{i : acc >= T[c, i]} equals searchsorted(T[c], acc, side='right') —
    exact for duplicate thresholds too. This is what the deployed executor
    runs on CPU, where the O(S) broadcast compare dominates at 8-bit
    activations (S = 255).
    """
    find = jax.vmap(
        lambda t, a: jnp.searchsorted(t, a, side="right"),
        in_axes=(0, -1), out_axes=-1,
    )
    return find(thresholds, acc).astype(jnp.int32)


def _fold_affine(params, eps: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(k_folded, b_folded) per paper Eqs. 3-4 — works for QDenseBatchNorm
    params; plain QDense params fold to (w, b)."""
    if "gamma" in params:
        v = params["gamma"] / jnp.sqrt(params["sigma2"] + eps)
        return params["w"] * v[None, :], v * (params["b"] - params["mu"]) + params["beta"]
    return params["w"], params["b"]


def streamline_dense(
    params,
    *,
    weight_bits: int,
    act_bits: int,
    in_scale: float,
    bn_eps: float = 1e-3,
    relu: bool = True,
) -> ThresholdDense:
    """Convert one (QDense[BatchNorm] + ReLU + act-quant) stage to thresholds.

    ``in_scale`` is the float value of one input integer step (the previous
    stage's out_scale, or the input quant scale for the first layer).
    """
    k_folded, b_folded = _fold_affine(params, bn_eps)

    # --- integer weights, per-output-channel symmetric scale -------------
    wq = IntQuantizer(bits=weight_bits, signed=True, narrow=True, axis=0)
    w_int, s_w = wq.quantize_int(k_folded)          # s_w: (1, out)
    s_w = jnp.squeeze(s_w, axis=0)                  # (out,)

    # --- choose a po2 output scale covering the pre-activation range -----
    # heuristic range: |acc| <= in_qmax * sum|w|; cover the relu output range
    qmax_out = 2 ** act_bits - 1
    in_qmax = 2 ** (act_bits - 1) - 1  # inputs assumed same grid width
    reach = jnp.max(jnp.sum(jnp.abs(k_folded), axis=0) * in_scale * in_qmax + jnp.abs(b_folded))
    s_out = float(quantize_po2(jnp.maximum(reach, 1e-8) / qmax_out))

    # --- thresholds on the integer accumulator ---------------------------
    # float preact for channel c:  y = acc * (s_w[c] * in_scale) + b_folded[c]
    # quant boundary i (half-up):  y >= (i - 0.5) * s_out
    #  => acc >= ((i - 0.5) * s_out - b[c]) / (s_w[c] * in_scale)
    steps = jnp.arange(1, qmax_out + 1, dtype=jnp.float32)      # (S,)
    denom = s_w * in_scale                                      # (out,) > 0
    bound = (steps[None, :] - 0.5) * s_out                      # (1, S)
    t_float = (bound - b_folded[:, None]) / denom[:, None]      # (out, S)
    thresholds = jnp.ceil(t_float).astype(jnp.int32)
    if not relu:
        raise NotImplementedError("streamlining currently targets ReLU stages")

    return ThresholdDense(
        w_int=w_int.astype(jnp.int8),
        thresholds=thresholds,
        out_scale=s_out,
        act_bits=act_bits,
    )


def apply_threshold_dense(stage: ThresholdDense, x_int):
    """Run one streamlined stage on integer inputs: (..., in) int -> (..., out) int."""
    acc = jnp.matmul(x_int.astype(jnp.int32), stage.w_int.astype(jnp.int32))
    return multi_threshold(acc, stage.thresholds)


def float_ref_dense(params, x, *, weight_bits, act_bits, s_out, bn_eps=1e-3):
    """The float-graph reference for one stage (fold -> quant w -> relu -> quant)."""
    k_folded, b_folded = _fold_affine(params, bn_eps)
    wq = IntQuantizer(bits=weight_bits, signed=True, narrow=True, axis=0)
    w_int, s_w = wq.quantize_int(k_folded)
    w_hat = w_int.astype(jnp.float32) * s_w
    y = x @ w_hat + b_folded
    y = jax.nn.relu(y)
    qmax = 2 ** act_bits - 1
    return quant_act_ref(y, s_out, qmax)


@dataclasses.dataclass
class StreamlinedMLP:
    """A fully streamlined MLP: integer in, integer threshold stages, one
    final float affine head (logits don't need quantizing — paper §3.1.1
    removes softmax since max(logits) suffices)."""

    in_scale: float
    stages: List[ThresholdDense]
    head_w: jnp.ndarray
    head_b: jnp.ndarray
    head_w_int: Optional[jnp.ndarray] = None
    head_scale: Optional[jnp.ndarray] = None

    def __call__(self, x_int):
        h = x_int
        for st in self.stages:
            h = apply_threshold_dense(st, h)
        # final stage: int accumulation, single float rescale at the very end
        last_scale = self.stages[-1].out_scale if self.stages else self.in_scale
        logits = h.astype(jnp.float32) @ self.head_w * last_scale + self.head_b
        return logits

    def predict(self, x_int):
        return jnp.argmax(self(x_int), axis=-1)


def streamline_mlp(layer_defs: Sequence, params_list: Sequence, in_scale: float,
                   head_params, bn_eps: float = 1e-3) -> StreamlinedMLP:
    """Streamline a stack of quantized dense(+BN)+ReLU stages + linear head."""
    stages = []
    scale = in_scale
    for ld, p in zip(layer_defs, params_list):
        st = streamline_dense(
            p,
            weight_bits=ld.weight_bits,
            act_bits=ld.act_bits,
            in_scale=scale,
            bn_eps=bn_eps,
        )
        stages.append(st)
        scale = st.out_scale
    return StreamlinedMLP(
        in_scale=in_scale,
        stages=stages,
        head_w=head_params["w"],
        head_b=head_params["b"],
    )


def constant_fold(graph):
    """QIR constant folding (paper §3.5 step 1): precompute nodes whose inputs
    are all initializers. Operates on core.qir.Graph."""
    from repro.core import qir

    changed = True
    while changed:
        changed = False
        for node in list(graph.nodes):
            if node.op in ("Quant",) and all(i in graph.initializers for i in node.inputs):
                x = graph.initializers[node.inputs[0]]
                q = IntQuantizer(bits=node.attrs.get("bits", 8))
                graph.initializers[node.outputs[0]] = np.asarray(q(jnp.asarray(x)))
                graph.nodes.remove(node)
                changed = True
    return graph
