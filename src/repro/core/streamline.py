"""Streamlining: fold float bookkeeping into integer multi-threshold ops.

This is the paper's C2 (§3.5, after Umuroglu & Jahre 2017). For a uniformly
quantized network, every float chain

    acc(int32) --*s_w*s_a--> float --BN--> float --ReLU--> float --quant--> q_out

is monotonic in the integer accumulator, so it collapses to a bank of integer
thresholds per output channel:

    q_out = sum_i [ acc >= T[c, i] ]          (i = 1 .. 2^bits - 1)

The deployed graph then contains only int8 weights, int32 accumulators,
integer threshold compares, and one power-of-two output scale — exactly what
FINN emits as "multi-threshold" nodes, and what our Pallas kernel
(kernels/multi_threshold.py) executes on TPU.

Exactness note: the float reference uses round-half-up at quant boundaries
(thresholds are the half-step points); jnp.round is half-even, so we define
``quant_act_ref`` with half-up semantics and test against it. Off-boundary
inputs (measure-1 set) agree with any tie rule.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.qlayers import QDense, QDenseBatchNorm
from repro.core.quantizers import IntQuantizer, quantize_po2


def quant_act_ref(y, s_out: float, qmax: int):
    """Unsigned activation quant with round-half-up: clip(floor(y/s+0.5),0,qmax)."""
    return jnp.clip(jnp.floor(y / s_out + 0.5), 0, qmax).astype(jnp.int32)


@dataclasses.dataclass
class ThresholdDense:
    """A streamlined (deployment-form) matmul stage.

    y_int = multi_threshold(x_int @ w_int, thresholds)  in [0, 2^act_bits - 1]
    float value of the output = y_int * out_scale. Convolutions lower to the
    same form with w_int holding the (kh*kw*cin, cout) im2col matrix.
    """

    w_int: jnp.ndarray        # (in, out) int8 codes
    thresholds: jnp.ndarray   # (out, n_steps) int32, sorted along steps
    out_scale: float          # po2 scalar
    act_bits: int
    weight_bits: int = 8      # for the BOPs stage costing (core/bops.py)

    @property
    def n_steps(self) -> int:
        return 2 ** self.act_bits - 1


def multi_threshold(acc, thresholds):
    """Reference multi-threshold: out[..., c] = #{i : acc[..., c] >= T[c, i]}.

    acc: (..., C) int32;  thresholds: (C, S) int32  ->  (..., C) int32.
    """
    return jnp.sum(
        acc[..., None] >= thresholds[(None,) * (acc.ndim - 1)], axis=-1
    ).astype(jnp.int32)


def multi_threshold_sorted(acc, thresholds):
    """``multi_threshold`` in O(log S) per element for *sorted* banks.

    streamline_dense always emits monotone threshold banks, so the count
    #{i : acc >= T[c, i]} equals searchsorted(T[c], acc, side='right') —
    exact for duplicate thresholds too. This is what the deployed executor
    runs on CPU, where the O(S) broadcast compare dominates at 8-bit
    activations (S = 255).
    """
    if thresholds.shape[1] == 1:
        # single-step banks (1-bit / bipolar sign): one broadcast compare
        return (acc >= thresholds[..., 0]).astype(jnp.int32)
    find = jax.vmap(
        lambda t, a: jnp.searchsorted(t, a, side="right"),
        in_axes=(0, -1), out_axes=-1,
    )
    return find(thresholds, acc).astype(jnp.int32)


def _fold_affine(params, eps: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(k_folded, b_folded) per paper Eqs. 3-4 — works for QDenseBatchNorm
    params; plain QDense params fold to (w, b)."""
    if "gamma" in params:
        v = params["gamma"] / jnp.sqrt(params["sigma2"] + eps)
        return params["w"] * v[None, :], v * (params["b"] - params["mu"]) + params["beta"]
    return params["w"], params["b"]


def choose_act_scale(k2d, b, *, in_scale: float, act_bits: int,
                     in_qmax: Optional[int] = None) -> float:
    """Pick the po2 activation scale covering one stage's pre-act range.

    Heuristic reach: |acc| <= in_qmax * sum|w| per output channel, plus the
    bias. ``in_qmax`` is the largest input code (127 for signed 8-bit input
    images, 2^bits - 1 for the unsigned inter-stage codes); the historical
    default (2^(act_bits-1) - 1) matches the original dense streamliner.
    """
    qmax_out = 2 ** act_bits - 1
    if in_qmax is None:
        in_qmax = 2 ** (act_bits - 1) - 1  # inputs assumed same grid width
    reach = jnp.max(jnp.sum(jnp.abs(k2d), axis=0) * in_scale * in_qmax
                    + jnp.abs(b))
    return float(quantize_po2(jnp.maximum(reach, 1e-8) / qmax_out))


def make_threshold_stage(
    w_int,
    s_w,
    b,
    *,
    in_scale: float,
    act_bits: int,
    s_out: Optional[float] = None,
    bipolar: bool = False,
    weight_bits: int = 8,
    in_qmax: Optional[int] = None,
) -> ThresholdDense:
    """Build the integer threshold bank for one already-quantized stage.

    ``w_int`` (in, out) integer weight codes with per-output-channel scale
    ``s_w``; float pre-activation for channel c is

        y = acc * (s_w[c] * in_scale) + b[c].

    Two activation flavors:
      * half-up unsigned quant (requires a preceding ReLU): boundary i is
        y >= (i - 0.5) * s_out  =>  acc >= ceil(((i-0.5)*s_out - b) / denom)
      * ``bipolar`` — FINN's sign activation in unipolar encoding: a single
        threshold at y >= 0, output codes {0, 1} with out_scale 1 (the next
        layer's weights are export-folded to consume the codes directly).
    """
    s_w = jnp.reshape(jnp.asarray(s_w, jnp.float32), (-1,))      # (out,)
    b = jnp.reshape(jnp.asarray(b, jnp.float32), (-1,))
    denom = s_w * in_scale                                       # (out,) > 0
    if bipolar:
        t_float = (0.0 - b[:, None]) / denom[:, None]            # (out, 1)
        out_scale, act_bits = 1.0, 1
    else:
        if s_out is None:
            s_out = choose_act_scale(
                jnp.abs(w_int.astype(jnp.float32)) * s_w[None, :], b,
                in_scale=in_scale, act_bits=act_bits, in_qmax=in_qmax)
        qmax_out = 2 ** act_bits - 1
        steps = jnp.arange(1, qmax_out + 1, dtype=jnp.float32)   # (S,)
        bound = (steps[None, :] - 0.5) * s_out                   # (1, S)
        t_float = (bound - b[:, None]) / denom[:, None]          # (out, S)
        out_scale = float(s_out)
    return ThresholdDense(
        w_int=w_int.astype(jnp.int8),
        thresholds=jnp.ceil(t_float).astype(jnp.int32),
        out_scale=out_scale,
        act_bits=act_bits,
        weight_bits=weight_bits,
    )


def streamline_dense(
    params,
    *,
    weight_bits: int,
    act_bits: int,
    in_scale: float,
    bn_eps: float = 1e-3,
    relu: bool = True,
    s_out: Optional[float] = None,
    in_qmax: Optional[int] = None,
) -> ThresholdDense:
    """Convert one (QDense[BatchNorm] + ReLU + act-quant) stage to thresholds.

    ``in_scale`` is the float value of one input integer step (the previous
    stage's out_scale, or the input quant scale for the first layer).
    """
    if not relu:
        raise NotImplementedError("streamlining currently targets ReLU stages")
    k_folded, b_folded = _fold_affine(params, bn_eps)

    # --- integer weights, per-output-channel symmetric scale -------------
    wq = IntQuantizer(bits=weight_bits, signed=True, narrow=True, axis=0)
    w_int, s_w = wq.quantize_int(k_folded)          # s_w: (1, out)
    s_w = jnp.squeeze(s_w, axis=0)                  # (out,)

    if s_out is None:
        s_out = choose_act_scale(k_folded, b_folded, in_scale=in_scale,
                                 act_bits=act_bits, in_qmax=in_qmax)
    return make_threshold_stage(
        w_int, s_w, b_folded, in_scale=in_scale, act_bits=act_bits,
        s_out=s_out, weight_bits=weight_bits)


def _fold_affine_conv(params, eps: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-output-channel BN fold for a (kh, kw, cin, cout) conv kernel."""
    if "gamma" in params:
        v = params["gamma"] / jnp.sqrt(params["sigma2"] + eps)
        k = params["w"] * v[None, None, None, :]
        return k, v * (params["b"] - params["mu"]) + params["beta"]
    return params["w"], params["b"]


def streamline_conv(
    params,
    *,
    weight_bits: int,
    act_bits: int,
    in_scale: float,
    bn_eps: float = 1e-3,
    s_out: Optional[float] = None,
    in_qmax: Optional[int] = None,
    bipolar: bool = False,
) -> ThresholdDense:
    """Convert one (Conv2D [BatchNorm] + ReLU + act-quant) stage to thresholds.

    The conv reduces to a matmul on the im2col patch matrix, so the result is
    the same ``ThresholdDense`` form with w_int of shape (kh*kw*cin, cout) —
    exactly what ``deploy.lower`` feeds the fused Pallas kernel.
    """
    k_folded, b_folded = _fold_affine_conv(params, bn_eps)
    k2d = jnp.reshape(k_folded, (-1, k_folded.shape[-1]))   # (kh*kw*cin, out)
    wq = IntQuantizer(bits=weight_bits, signed=True, narrow=True, axis=0)
    w_int, s_w = wq.quantize_int(k2d)
    s_w = jnp.squeeze(s_w, axis=0)
    if s_out is None and not bipolar:
        s_out = choose_act_scale(k2d, b_folded, in_scale=in_scale,
                                 act_bits=act_bits, in_qmax=in_qmax)
    return make_threshold_stage(
        w_int, s_w, b_folded, in_scale=in_scale, act_bits=act_bits,
        s_out=s_out, bipolar=bipolar, weight_bits=weight_bits)


def apply_threshold_dense(stage: ThresholdDense, x_int):
    """Run one streamlined stage on integer inputs: (..., in) int -> (..., out) int."""
    acc = jnp.matmul(x_int.astype(jnp.int32), stage.w_int.astype(jnp.int32))
    return multi_threshold(acc, stage.thresholds)


def float_ref_dense(params, x, *, weight_bits, act_bits, s_out, bn_eps=1e-3):
    """The float-graph reference for one stage (fold -> quant w -> relu -> quant)."""
    k_folded, b_folded = _fold_affine(params, bn_eps)
    wq = IntQuantizer(bits=weight_bits, signed=True, narrow=True, axis=0)
    w_int, s_w = wq.quantize_int(k_folded)
    w_hat = w_int.astype(jnp.float32) * s_w
    y = x @ w_hat + b_folded
    y = jax.nn.relu(y)
    qmax = 2 ** act_bits - 1
    return quant_act_ref(y, s_out, qmax)


@dataclasses.dataclass
class StreamlinedMLP:
    """A fully streamlined MLP: integer in, integer threshold stages, one
    final float affine head (logits don't need quantizing — paper §3.1.1
    removes softmax since max(logits) suffices)."""

    in_scale: float
    stages: List[ThresholdDense]
    head_w: jnp.ndarray
    head_b: jnp.ndarray
    head_w_int: Optional[jnp.ndarray] = None
    head_scale: Optional[jnp.ndarray] = None

    def __call__(self, x_int):
        h = x_int
        for st in self.stages:
            h = apply_threshold_dense(st, h)
        # final stage: int accumulation, single float rescale at the very end
        last_scale = self.stages[-1].out_scale if self.stages else self.in_scale
        logits = h.astype(jnp.float32) @ self.head_w * last_scale + self.head_b
        return logits

    def predict(self, x_int):
        return jnp.argmax(self(x_int), axis=-1)


def streamline_mlp(layer_defs: Sequence, params_list: Sequence, in_scale: float,
                   head_params, bn_eps: float = 1e-3) -> StreamlinedMLP:
    """Streamline a stack of quantized dense(+BN)+ReLU stages + linear head."""
    stages = []
    scale = in_scale
    for ld, p in zip(layer_defs, params_list):
        st = streamline_dense(
            p,
            weight_bits=ld.weight_bits,
            act_bits=ld.act_bits,
            in_scale=scale,
            bn_eps=bn_eps,
        )
        stages.append(st)
        scale = st.out_scale
    return StreamlinedMLP(
        in_scale=in_scale,
        stages=stages,
        head_w=head_params["w"],
        head_b=head_params["b"],
    )


def constant_fold(graph):
    """QIR constant folding (paper §3.5 step 1): precompute nodes whose inputs
    are all initializers. Operates on core.qir.Graph."""
    from repro.core import qir

    changed = True
    while changed:
        changed = False
        for node in list(graph.nodes):
            if node.op in ("Quant",) and all(i in graph.initializers for i in node.inputs):
                x = graph.initializers[node.inputs[0]]
                q = IntQuantizer(bits=node.attrs.get("bits", 8))
                graph.initializers[node.outputs[0]] = np.asarray(q(jnp.asarray(x)))
                graph.nodes.remove(node)
                changed = True
    return graph
