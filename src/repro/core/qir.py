"""QIR — a QONNX-style interchange format for arbitrary-precision QNNs.

The paper's C8: hls4ml and FINN exchange quantized models through QONNX, an
ONNX extension whose key addition is a ``Quant(bitwidth, scale, zero_point,
signed, narrow)`` node. QIR is the same idea as a minimal, dependency-free
JSON graph so the training flow (core/qlayers) and the deployment flow
(core/streamline + kernels/) share one artifact:

  train (QAT)  --export-->  QIR json  --import-->  streamline/deploy

Supported ops: Dense, Conv2D, MaxPool, Flatten, BatchNorm, Relu, Quant,
MultiThreshold, TopK, Mul. Weights live in ``initializers`` (name -> ndarray,
stored base64 in JSON).

Quant node semantics (attrs select the flavor):
  * default             — dynamic min-max IntQuantizer (the QAT fake-quant)
  * ``attrs["scale"]``  — fixed-grid unsigned quant with half-up rounding,
    value = clip(floor(x/s + 0.5), 0, 2^bits - 1) * s. This is the form the
    conv exporter emits: the scale is frozen at export so the deployed
    integer thresholds (core/streamline.py) reproduce it bit-exactly.
  * ``attrs["bipolar"]``— FINN's bipolar activation in unipolar encoding:
    value = [x >= 0] in {0, 1} standing for sign(x) in {-1, +1}. Layers
    consuming it carry export-folded weights (w' = 2w, b' = b - sum(w)) so
    the graph stays affine in the 0/1 codes.
"""

from __future__ import annotations

import base64
import dataclasses
import io
import json
from typing import Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class QuantSpec:
    bits: int = 8
    signed: bool = True
    narrow: bool = False
    po2_scale: bool = False

    def to_dict(self):
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d):
        return cls(**d)


@dataclasses.dataclass
class Node:
    op: str
    name: str
    inputs: List[str]
    outputs: List[str]
    attrs: Dict = dataclasses.field(default_factory=dict)
    quant: Optional[QuantSpec] = None

    def to_dict(self):
        d = {
            "op": self.op,
            "name": self.name,
            "inputs": self.inputs,
            "outputs": self.outputs,
            "attrs": self.attrs,
        }
        if self.quant is not None:
            d["quant"] = self.quant.to_dict()
        return d

    @classmethod
    def from_dict(cls, d):
        q = QuantSpec.from_dict(d["quant"]) if "quant" in d else None
        return cls(d["op"], d["name"], d["inputs"], d["outputs"], d.get("attrs", {}), q)


def _enc(a: np.ndarray) -> Dict:
    buf = io.BytesIO()
    np.save(buf, np.asarray(a), allow_pickle=False)
    return {"b64": base64.b64encode(buf.getvalue()).decode("ascii")}


def _dec(d: Dict) -> np.ndarray:
    return np.load(io.BytesIO(base64.b64decode(d["b64"])), allow_pickle=False)


@dataclasses.dataclass
class Graph:
    nodes: List[Node] = dataclasses.field(default_factory=list)
    initializers: Dict[str, np.ndarray] = dataclasses.field(default_factory=dict)
    inputs: List[str] = dataclasses.field(default_factory=list)
    outputs: List[str] = dataclasses.field(default_factory=list)
    meta: Dict = dataclasses.field(default_factory=dict)

    # -- serialization ----------------------------------------------------
    def to_json(self) -> str:
        return json.dumps(
            {
                "nodes": [n.to_dict() for n in self.nodes],
                "initializers": {k: _enc(v) for k, v in self.initializers.items()},
                "inputs": self.inputs,
                "outputs": self.outputs,
                "meta": self.meta,
            }
        )

    @classmethod
    def from_json(cls, s: str) -> "Graph":
        d = json.loads(s)
        return cls(
            nodes=[Node.from_dict(n) for n in d["nodes"]],
            initializers={k: _dec(v) for k, v in d["initializers"].items()},
            inputs=d["inputs"],
            outputs=d["outputs"],
            meta=d.get("meta", {}),
        )

    def save(self, path: str):
        with open(path, "w") as f:
            f.write(self.to_json())

    @classmethod
    def load(cls, path: str) -> "Graph":
        with open(path) as f:
            return cls.from_json(f.read())

    # -- execution (reference interpreter) --------------------------------
    def run(self, feeds: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        import jax.numpy as jnp

        env: Dict[str, np.ndarray] = dict(self.initializers)
        env.update(feeds)
        for node in self.nodes:
            x = [jnp.asarray(env[i]) for i in node.inputs]
            env[node.outputs[0]] = np.asarray(eval_node(node, x))
        return {o: env[o] for o in self.outputs}


# ---------------------------------------------------------------------------
# single-node evaluation (shared by Graph.run and repro.deploy's fallback)
# ---------------------------------------------------------------------------

def eval_node(node: Node, x: List):
    """Evaluate one QIR node on already-fetched (jnp) input values.

    Traceable — the deploy fallback stage calls this inside jit; Graph.run
    wraps it eagerly per node.
    """
    import jax
    import jax.numpy as jnp

    from repro.core.quantizers import IntQuantizer
    from repro.core.streamline import multi_threshold

    if node.op == "Dense":
        y = x[0] @ x[1]
        if len(x) > 2:
            y = y + x[2]
    elif node.op == "Conv2D":
        stride = int(node.attrs.get("stride", 1))
        y = jax.lax.conv_general_dilated(
            x[0], x[1],
            window_strides=(stride, stride),
            padding=node.attrs.get("padding", "SAME"),
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )
        if len(x) > 2:
            y = y + x[2]
    elif node.op == "MaxPool":
        win = int(node.attrs.get("window", 2))
        stride = int(node.attrs.get("stride", win))
        init = (jnp.iinfo(x[0].dtype).min
                if jnp.issubdtype(x[0].dtype, jnp.integer) else -jnp.inf)
        y = jax.lax.reduce_window(
            x[0], init, jax.lax.max, (1, win, win, 1), (1, stride, stride, 1),
            node.attrs.get("padding", "VALID"))
    elif node.op == "Flatten":
        y = x[0].reshape(x[0].shape[0], -1)
    elif node.op == "Relu":
        y = jnp.maximum(x[0], 0)
    elif node.op == "BatchNorm":
        xx, gamma, beta, mu, var = x
        eps = node.attrs.get("eps", 1e-3)
        y = gamma * (xx - mu) / jnp.sqrt(var + eps) + beta
    elif node.op == "Quant":
        if node.attrs.get("bipolar"):
            # unipolar encoding of the bipolar sign activation: [x >= 0]
            y = (x[0] >= 0).astype(jnp.float32)
        elif node.attrs.get("scale") is not None:
            s = float(node.attrs["scale"])
            qmax = 2 ** node.quant.bits - 1
            y = jnp.clip(jnp.floor(x[0] / s + 0.5), 0, qmax) * s
        else:
            q = IntQuantizer(
                bits=node.quant.bits,
                signed=node.quant.signed,
                narrow=node.quant.narrow,
            )
            y = q(x[0])
    elif node.op == "MultiThreshold":
        y = multi_threshold(x[0].astype(jnp.int32), jnp.asarray(x[1]))
    elif node.op == "TopK":
        y = jnp.argmax(x[0], axis=-1)
    elif node.op == "Mul":
        y = x[0] * x[1]
    else:
        raise NotImplementedError(f"QIR op {node.op}")
    return y


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------

def export_qmlp(layer_defs, params_list, head_params, meta=None,
                freeze_scales: bool = False,
                in_scale: float = 1.0 / 127.0,
                bn_eps: float = 1e-3) -> Graph:
    """Export a QDense/QDenseBatchNorm stack + linear head to QIR.

    With ``freeze_scales`` the activation Quant nodes carry the po2 scale
    the streamliner would pick (chained from ``in_scale``), so the unfused
    ``Graph.run`` reference uses the same half-up deployment grid as the
    compiled integer schedule instead of dynamic min-max fake-quant — the
    compiled-vs-unfused parity then holds at the decision level. ``bn_eps``
    must match the value later passed to ``lower_graph`` so the BN fold
    behind the frozen scales stays in lockstep with the deployed thresholds.
    """
    g = Graph(inputs=["x"], outputs=["logits"], meta=meta or {})
    prev = "x"
    scale = in_scale
    for i, (ld, p) in enumerate(zip(layer_defs, params_list)):
        wname, bname = f"w{i}", f"b{i}"
        g.initializers[wname] = np.asarray(p["w"])
        g.initializers[bname] = np.asarray(p["b"])
        out = f"h{i}_fc"
        g.nodes.append(
            Node(
                "Dense",
                f"dense{i}",
                [prev, wname, bname],
                [out],
                attrs={"weight_bits": getattr(ld, "weight_bits", 8)},
            )
        )
        prev = out
        if "gamma" in p:
            for stat in ("gamma", "beta", "mu", "sigma2"):
                g.initializers[f"{stat}{i}"] = np.asarray(p[stat])
            out = f"h{i}_bn"
            g.nodes.append(
                Node(
                    "BatchNorm",
                    f"bn{i}",
                    [prev, f"gamma{i}", f"beta{i}", f"mu{i}", f"sigma2{i}"],
                    [out],
                )
            )
            prev = out
        out = f"h{i}_relu"
        g.nodes.append(Node("Relu", f"relu{i}", [prev], [out]))
        prev = out
        out = f"h{i}_q"
        attrs = {}
        if freeze_scales:
            from repro.core.streamline import _fold_affine, choose_act_scale

            import jax.numpy as jnp

            k_f, b_f = _fold_affine(
                {k: jnp.asarray(v) for k, v in p.items()}, bn_eps)
            s_out = choose_act_scale(k_f, b_f, in_scale=scale,
                                     act_bits=ld.act_bits)
            attrs["scale"] = s_out
            scale = s_out
        g.nodes.append(
            Node(
                "Quant",
                f"quant{i}",
                [prev],
                [out],
                attrs=attrs,
                quant=QuantSpec(bits=ld.act_bits,
                                signed=not freeze_scales),
            )
        )
        prev = out
    g.initializers["w_head"] = np.asarray(head_params["w"])
    g.initializers["b_head"] = np.asarray(head_params["b"])
    g.nodes.append(Node("Dense", "head", [prev, "w_head", "b_head"], ["logits"]))
    return g


def _conv_out_hw(h: int, w: int, k: int, stride: int, padding: str):
    if padding == "SAME":
        return -(-h // stride), -(-w // stride)
    return (h - k) // stride + 1, (w - k) // stride + 1


def export_qcnn(model, params, in_scale: float = 1.0 / 128.0, meta=None,
                calibrate=None) -> Graph:
    """Export a Table-1 conv model (``ICModel`` or ``CNVModel``) to QIR.

    Mirrors ``export_qmlp`` for the spatial models: every conv layer becomes a
    ``Conv2D -> [Relu] -> Quant`` chain with per-layer ``QuantSpec``s, plus
    ``MaxPool``/``Flatten`` nodes where the architecture has them. Two export
    decisions make the graph *exactly* streamlinable (the lowered integer
    schedule reproduces ``Graph.run`` bit for bit, ties included):

      * weights are stored fake-quantized with power-of-two per-channel
        scales (recorded via ``attrs["w_scale"]``) and biases snapped to the
        integer-accumulator grid, so with a po2 ``in_scale`` every float in
        the reference interpreter is an exact multiple of a po2 step;
      * the binary CNV is exported in FINN's unipolar form: activations are
        ``[x >= 0]`` codes in {0, 1} and downstream weights are folded as
        ``w' = 2w, b' = b - sum(w)`` so arithmetic stays affine in the codes
        (its ``meta["in_scale"]`` is 1.0 — input codes are the values).

    ``in_scale`` is the float value of one step of the 8-bit input image
    (ignored for CNV); keep it a power of two for the exactness guarantee.
    ``calibrate`` (optional, multi-bit models) is a batch of integer input
    codes used to measure real post-ReLU activation ranges; without it the
    per-layer scales come from the worst-case reach bound, which wastes most
    of the code range and costs accuracy (post-training static calibration
    is what the hls4ml flow does with its profiling pass).
    """
    if getattr(model, "weight_bits", 8) == 1 and hasattr(model, "channels"):
        return _export_cnv(model, params, meta)
    if hasattr(model, "conv_layers"):
        return _export_ic(model, params, in_scale, meta, calibrate)
    raise TypeError(f"no QIR conv exporter for {type(model).__name__}")


def _export_ic(model, params, in_scale: float, meta, calibrate=None) -> Graph:
    import jax
    import jax.numpy as jnp

    from repro.core.quantizers import IntQuantizer, quantize_po2
    from repro.core.streamline import choose_act_scale

    g = Graph(inputs=["x"], outputs=["logits"],
              meta=dict(meta or {}, model=type(model).__name__,
                        in_scale=in_scale))
    convs = model.conv_layers()
    h, w, cin = model.in_hw, model.in_hw, model.in_ch
    scale, in_qmax = in_scale, 127          # signed 8-bit input codes
    hcal = (None if calibrate is None
            else jnp.asarray(calibrate, jnp.float32) * in_scale)
    prev = "x"
    for i, (ld, p) in enumerate(zip(convs, params["convs"])):
        wk = np.asarray(p["w"], np.float32)             # (k, k, cin, f)
        wq = IntQuantizer(bits=ld.weight_bits, signed=True, narrow=True,
                          axis=0, po2=True)
        w_int, s_w = wq.quantize_int(jnp.asarray(wk.reshape(-1, ld.out_ch)))
        s_w = np.asarray(s_w, np.float32).reshape(-1)   # (f,) po2
        w_hat = (np.asarray(w_int, np.float32) * s_w).reshape(wk.shape)
        grid = s_w * scale                              # accumulator step
        b_q = np.asarray(np.round(np.asarray(p["b"]) / grid) * grid,
                         np.float32)
        oh, ow = _conv_out_hw(h, w, ld.kernel, ld.stride, ld.padding)
        g.initializers[f"cw{i}"] = w_hat
        g.initializers[f"cb{i}"] = b_q
        g.initializers[f"cws{i}"] = s_w
        g.nodes.append(Node(
            "Conv2D", f"conv{i}", [prev, f"cw{i}", f"cb{i}"], [f"c{i}_conv"],
            attrs={"kernel": ld.kernel, "stride": ld.stride,
                   "padding": ld.padding, "weight_bits": ld.weight_bits,
                   "w_scale": f"cws{i}",
                   "in_shape": [h, w, cin], "out_shape": [oh, ow, ld.out_ch]}))
        g.nodes.append(Node("Relu", f"relu{i}", [f"c{i}_conv"], [f"c{i}_relu"]))
        qmax_out = 2 ** ld.act_bits - 1
        if hcal is not None:
            y = jax.lax.conv_general_dilated(
                hcal, jnp.asarray(w_hat), (ld.stride, ld.stride), ld.padding,
                dimension_numbers=("NHWC", "HWIO", "NHWC")) + jnp.asarray(b_q)
            r = jnp.maximum(y, 0)
            s_out = float(quantize_po2(
                jnp.maximum(jnp.max(r), 1e-8) / qmax_out))
            hcal = jnp.clip(jnp.floor(r / s_out + 0.5), 0, qmax_out) * s_out
        else:
            s_out = choose_act_scale(
                jnp.asarray(w_hat.reshape(-1, ld.out_ch)), jnp.asarray(b_q),
                in_scale=scale, act_bits=ld.act_bits, in_qmax=in_qmax)
        g.nodes.append(Node(
            "Quant", f"quant{i}", [f"c{i}_relu"], [f"c{i}_q"],
            attrs={"scale": s_out},
            quant=QuantSpec(bits=ld.act_bits, signed=False)))
        prev = f"c{i}_q"
        scale, in_qmax = s_out, 2 ** ld.act_bits - 1
        h, w, cin = oh, ow, ld.out_ch
    g.nodes.append(Node("Flatten", "flatten", [prev], ["flat"],
                        attrs={"in_shape": [h, w, cin]}))
    wq_head = IntQuantizer(bits=model.weight_bits, axis=0)
    g.initializers["w_head"] = np.asarray(
        wq_head(jnp.asarray(params["head"]["w"])), np.float32)
    g.initializers["b_head"] = np.asarray(params["head"]["b"], np.float32)
    g.nodes.append(Node("Dense", "head", ["flat", "w_head", "b_head"],
                        ["logits"]))
    return g


def _export_cnv(model, params, meta) -> Graph:
    g = Graph(inputs=["x"], outputs=["logits"],
              meta=dict(meta or {}, model=type(model).__name__,
                        in_scale=1.0))
    convs = model.conv_layers()
    h, w, cin = model.in_hw, model.in_hw, model.in_ch
    prev = "x"
    for i, (ld, p) in enumerate(zip(convs, params["convs"])):
        sgn = np.where(np.asarray(p["w"]) >= 0, 1.0, -1.0).astype(np.float32)
        if i == 0:
            wk, b_q = sgn, None       # signed input codes: plain +-1 taps
        else:
            wk = 2.0 * sgn            # unipolar folding: x = 2q - 1
            b_q = -np.sum(sgn, axis=(0, 1, 2)).astype(np.float32)
        oh, ow = _conv_out_hw(h, w, ld.kernel, ld.stride, ld.padding)
        g.initializers[f"cw{i}"] = wk
        g.initializers[f"cws{i}"] = np.ones((ld.out_ch,), np.float32)
        ins = [prev, f"cw{i}"]
        if b_q is not None:
            g.initializers[f"cb{i}"] = b_q
            ins.append(f"cb{i}")
        g.nodes.append(Node(
            "Conv2D", f"conv{i}", ins, [f"c{i}_conv"],
            attrs={"kernel": ld.kernel, "stride": ld.stride,
                   "padding": ld.padding, "weight_bits": 1,
                   "w_scale": f"cws{i}",
                   "in_shape": [h, w, cin], "out_shape": [oh, ow, ld.out_ch]}))
        g.nodes.append(Node("Quant", f"sign{i}", [f"c{i}_conv"], [f"c{i}_q"],
                            attrs={"bipolar": True},
                            quant=QuantSpec(bits=1, signed=False)))
        prev = f"c{i}_q"
        h, w, cin = oh, ow, ld.out_ch
        if i in model.pool_after:
            g.nodes.append(Node(
                "MaxPool", f"pool{i}", [prev], [f"p{i}"],
                attrs={"window": 2, "stride": 2, "padding": "VALID",
                       "in_shape": [h, w, cin],
                       "out_shape": [h // 2, w // 2, cin]}))
            prev = f"p{i}"
            h, w = h // 2, w // 2
    g.nodes.append(Node("Flatten", "flatten", [prev], ["flat"],
                        attrs={"in_shape": [h, w, cin]}))
    prev = "flat"
    dims = [h * w * cin, *model.fc, model.n_classes]
    for j, p in enumerate(params["fcs"]):
        sgn = np.where(np.asarray(p["w"]) >= 0, 1.0, -1.0).astype(np.float32)
        g.initializers[f"fw{j}"] = 2.0 * sgn
        g.initializers[f"fb{j}"] = -np.sum(sgn, axis=0).astype(np.float32)
        last = j == len(params["fcs"]) - 1
        out = "logits" if last else f"f{j}_fc"
        attrs = {"weight_bits": 1}
        if not last:
            g.initializers[f"fws{j}"] = np.ones((dims[j + 1],), np.float32)
            attrs["w_scale"] = f"fws{j}"
        g.nodes.append(Node("Dense", f"fc{j}", [prev, f"fw{j}", f"fb{j}"],
                            [out], attrs=attrs))
        if not last:
            g.nodes.append(Node("Quant", f"fsign{j}", [out], [f"f{j}_q"],
                                attrs={"bipolar": True},
                                quant=QuantSpec(bits=1, signed=False)))
            prev = f"f{j}_q"
    return g
