"""QIR — a QONNX-style interchange format for arbitrary-precision QNNs.

The paper's C8: hls4ml and FINN exchange quantized models through QONNX, an
ONNX extension whose key addition is a ``Quant(bitwidth, scale, zero_point,
signed, narrow)`` node. QIR is the same idea as a minimal, dependency-free
JSON graph so the training flow (core/qlayers) and the deployment flow
(core/streamline + kernels/) share one artifact:

  train (QAT)  --export-->  QIR json  --import-->  streamline/deploy

Supported ops: Dense, Conv2D, BatchNorm, Relu, Quant, MultiThreshold, TopK.
Weights live in ``initializers`` (name -> ndarray, stored base64 in JSON).
"""

from __future__ import annotations

import base64
import dataclasses
import io
import json
from typing import Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class QuantSpec:
    bits: int = 8
    signed: bool = True
    narrow: bool = False
    po2_scale: bool = False

    def to_dict(self):
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d):
        return cls(**d)


@dataclasses.dataclass
class Node:
    op: str
    name: str
    inputs: List[str]
    outputs: List[str]
    attrs: Dict = dataclasses.field(default_factory=dict)
    quant: Optional[QuantSpec] = None

    def to_dict(self):
        d = {
            "op": self.op,
            "name": self.name,
            "inputs": self.inputs,
            "outputs": self.outputs,
            "attrs": self.attrs,
        }
        if self.quant is not None:
            d["quant"] = self.quant.to_dict()
        return d

    @classmethod
    def from_dict(cls, d):
        q = QuantSpec.from_dict(d["quant"]) if "quant" in d else None
        return cls(d["op"], d["name"], d["inputs"], d["outputs"], d.get("attrs", {}), q)


def _enc(a: np.ndarray) -> Dict:
    buf = io.BytesIO()
    np.save(buf, np.asarray(a), allow_pickle=False)
    return {"b64": base64.b64encode(buf.getvalue()).decode("ascii")}


def _dec(d: Dict) -> np.ndarray:
    return np.load(io.BytesIO(base64.b64decode(d["b64"])), allow_pickle=False)


@dataclasses.dataclass
class Graph:
    nodes: List[Node] = dataclasses.field(default_factory=list)
    initializers: Dict[str, np.ndarray] = dataclasses.field(default_factory=dict)
    inputs: List[str] = dataclasses.field(default_factory=list)
    outputs: List[str] = dataclasses.field(default_factory=list)
    meta: Dict = dataclasses.field(default_factory=dict)

    # -- serialization ----------------------------------------------------
    def to_json(self) -> str:
        return json.dumps(
            {
                "nodes": [n.to_dict() for n in self.nodes],
                "initializers": {k: _enc(v) for k, v in self.initializers.items()},
                "inputs": self.inputs,
                "outputs": self.outputs,
                "meta": self.meta,
            }
        )

    @classmethod
    def from_json(cls, s: str) -> "Graph":
        d = json.loads(s)
        return cls(
            nodes=[Node.from_dict(n) for n in d["nodes"]],
            initializers={k: _dec(v) for k, v in d["initializers"].items()},
            inputs=d["inputs"],
            outputs=d["outputs"],
            meta=d.get("meta", {}),
        )

    def save(self, path: str):
        with open(path, "w") as f:
            f.write(self.to_json())

    @classmethod
    def load(cls, path: str) -> "Graph":
        with open(path) as f:
            return cls.from_json(f.read())

    # -- execution (reference interpreter) --------------------------------
    def run(self, feeds: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        import jax.numpy as jnp

        env: Dict[str, np.ndarray] = dict(self.initializers)
        env.update(feeds)
        for node in self.nodes:
            x = [jnp.asarray(env[i]) for i in node.inputs]
            env[node.outputs[0]] = np.asarray(eval_node(node, x))
        return {o: env[o] for o in self.outputs}


# ---------------------------------------------------------------------------
# single-node evaluation (shared by Graph.run and repro.deploy's fallback)
# ---------------------------------------------------------------------------

def eval_node(node: Node, x: List):
    """Evaluate one QIR node on already-fetched (jnp) input values.

    Traceable — the deploy fallback stage calls this inside jit; Graph.run
    wraps it eagerly per node.
    """
    import jax.numpy as jnp

    from repro.core.quantizers import IntQuantizer
    from repro.core.streamline import multi_threshold

    if node.op == "Dense":
        y = x[0] @ x[1]
        if len(x) > 2:
            y = y + x[2]
    elif node.op == "Relu":
        y = jnp.maximum(x[0], 0)
    elif node.op == "BatchNorm":
        xx, gamma, beta, mu, var = x
        eps = node.attrs.get("eps", 1e-3)
        y = gamma * (xx - mu) / jnp.sqrt(var + eps) + beta
    elif node.op == "Quant":
        q = IntQuantizer(
            bits=node.quant.bits,
            signed=node.quant.signed,
            narrow=node.quant.narrow,
        )
        y = q(x[0])
    elif node.op == "MultiThreshold":
        y = multi_threshold(x[0].astype(jnp.int32), jnp.asarray(x[1]))
    elif node.op == "TopK":
        y = jnp.argmax(x[0], axis=-1)
    elif node.op == "Mul":
        y = x[0] * x[1]
    else:
        raise NotImplementedError(f"QIR op {node.op}")
    return y


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------

def export_qmlp(layer_defs, params_list, head_params, meta=None) -> Graph:
    """Export a QDense/QDenseBatchNorm stack + linear head to QIR."""
    g = Graph(inputs=["x"], outputs=["logits"], meta=meta or {})
    prev = "x"
    for i, (ld, p) in enumerate(zip(layer_defs, params_list)):
        wname, bname = f"w{i}", f"b{i}"
        g.initializers[wname] = np.asarray(p["w"])
        g.initializers[bname] = np.asarray(p["b"])
        out = f"h{i}_fc"
        g.nodes.append(
            Node(
                "Dense",
                f"dense{i}",
                [prev, wname, bname],
                [out],
                attrs={"weight_bits": getattr(ld, "weight_bits", 8)},
            )
        )
        prev = out
        if "gamma" in p:
            for stat in ("gamma", "beta", "mu", "sigma2"):
                g.initializers[f"{stat}{i}"] = np.asarray(p[stat])
            out = f"h{i}_bn"
            g.nodes.append(
                Node(
                    "BatchNorm",
                    f"bn{i}",
                    [prev, f"gamma{i}", f"beta{i}", f"mu{i}", f"sigma2{i}"],
                    [out],
                )
            )
            prev = out
        out = f"h{i}_relu"
        g.nodes.append(Node("Relu", f"relu{i}", [prev], [out]))
        prev = out
        out = f"h{i}_q"
        g.nodes.append(
            Node(
                "Quant",
                f"quant{i}",
                [prev],
                [out],
                quant=QuantSpec(bits=ld.act_bits, signed=True),
            )
        )
        prev = out
    g.initializers["w_head"] = np.asarray(head_params["w"])
    g.initializers["b_head"] = np.asarray(head_params["b"])
    g.nodes.append(Node("Dense", "head", [prev, "w_head", "b_head"], ["logits"]))
    return g
