"""Arbitrary-precision quantizers with straight-through estimators.

The paper (C1) trains with quantization-aware training at 1-12 bit precision
via QKeras / Brevitas. This module is the JAX equivalent: every quantizer is a
pure function ``q(x) -> x_hat`` whose backward pass is the straight-through
estimator (identity inside the representable range, zero outside), implemented
with ``jax.custom_vjp``.

Quantizer zoo (mirrors what the submissions used):
  * ``FixedPointQuantizer``  - QKeras-style ``quantized_bits(bits, integer)``
                               (hls4ml IC: 8 total / 2 integer; AD: 6-12 bit)
  * ``IntQuantizer``         - Brevitas-style signed/unsigned integer with a
                               learned or static power-of-two / affine scale
                               (FINN KWS: 3-bit weights+activations)
  * ``BinaryQuantizer``      - bipolar {-1,+1} (FINN CNV-W1A1)
  * ``TernaryQuantizer``     - {-1,0,+1} with threshold
  * ``quantize_po2``         - power-of-two scale helper (shift-only rescale,
                               the FPGA-friendly scale FINN streamlining uses)

All quantizers expose ``bits`` so the BOPs cost model (core/bops.py) can read
the precision straight off a model definition.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# straight-through rounding primitives
# ---------------------------------------------------------------------------

@jax.custom_vjp
def ste_round(x):
    """round-to-nearest-even with identity gradient."""
    return jnp.round(x)


def _ste_round_fwd(x):
    return jnp.round(x), None


def _ste_round_bwd(_, g):
    return (g,)


ste_round.defvjp(_ste_round_fwd, _ste_round_bwd)


@jax.custom_vjp
def ste_clip(x, lo, hi):
    """clip whose gradient is 1 inside [lo, hi] and 0 outside (saturating STE)."""
    return jnp.clip(x, lo, hi)


def _ste_clip_fwd(x, lo, hi):
    return jnp.clip(x, lo, hi), (x, lo, hi)


def _ste_clip_bwd(res, g):
    x, lo, hi = res
    mask = jnp.logical_and(x >= lo, x <= hi).astype(g.dtype)
    return (g * mask, None, None)


ste_clip.defvjp(_ste_clip_fwd, _ste_clip_bwd)


@jax.custom_vjp
def ste_sign(x):
    """bipolar sign with clipped-identity gradient (BinaryNet hard-tanh STE)."""
    return jnp.where(x >= 0, 1.0, -1.0).astype(x.dtype)


def _ste_sign_fwd(x):
    return jnp.where(x >= 0, 1.0, -1.0).astype(x.dtype), x


def _ste_sign_bwd(x, g):
    mask = (jnp.abs(x) <= 1.0).astype(g.dtype)
    return (g * mask,)


ste_sign.defvjp(_ste_sign_fwd, _ste_sign_bwd)


# ---------------------------------------------------------------------------
# scale helpers
# ---------------------------------------------------------------------------

def quantize_po2(scale, lo=2.0 ** -24, hi=2.0 ** 24):
    """Snap a positive scale to the nearest power of two.

    FINN's streamlining prefers po2 scales because on an FPGA they are free
    bit-shifts; on TPU they stay exact across bf16 rescales, so we keep the
    option and use it for threshold folding (core/streamline.py).
    """
    scale = jnp.clip(scale, lo, hi)
    return 2.0 ** jnp.round(jnp.log2(scale))


def minmax_scale(x, qmax, axis=None, keepdims=True, eps=1e-8):
    """Symmetric per-tensor / per-channel scale from the max-abs statistic."""
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=keepdims)
    return jnp.maximum(amax, eps) / qmax


# ---------------------------------------------------------------------------
# quantizer definitions
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FixedPointQuantizer:
    """QKeras ``quantized_bits(bits, integer, keep_negative=1)`` equivalent.

    Value grid: step = 2^(integer - (bits-1)) for signed numbers; the
    representable range is [-2^integer, 2^integer - step].
    """

    bits: int = 8
    integer: int = 2
    signed: bool = True

    @property
    def step(self) -> float:
        frac_bits = self.bits - self.integer - (1 if self.signed else 0)
        return 2.0 ** (-frac_bits)

    @property
    def qmin(self) -> float:
        return -(2.0 ** self.integer) if self.signed else 0.0

    @property
    def qmax(self) -> float:
        return 2.0 ** self.integer - self.step

    def __call__(self, x):
        x = ste_clip(x, self.qmin, self.qmax)
        return ste_round(x / self.step) * self.step

    def int_repr(self, x):
        """Integer code for a (already clipped) value — used by streamlining."""
        return jnp.round(jnp.clip(x, self.qmin, self.qmax) / self.step).astype(jnp.int32)


@dataclasses.dataclass(frozen=True)
class IntQuantizer:
    """Brevitas-style integer quantizer with a runtime (min-max) scale.

    ``q(x) = clip(round(x / s), qmin, qmax) * s`` with s per-tensor or
    per-channel (``axis``). ``po2`` snaps the scale to a power of two.
    """

    bits: int = 8
    signed: bool = True
    axis: Optional[int] = None
    po2: bool = False
    narrow: bool = False  # symmetric range [-qmax, qmax] (weights)

    @property
    def qmax(self) -> int:
        return 2 ** (self.bits - 1) - 1 if self.signed else 2 ** self.bits - 1

    @property
    def qmin(self) -> int:
        if not self.signed:
            return 0
        return -self.qmax if self.narrow else -(2 ** (self.bits - 1))

    def scale(self, x):
        if self.axis is None:
            s = minmax_scale(x, self.qmax)
        else:
            s = minmax_scale(x, self.qmax, axis=self.axis, keepdims=True)
        if self.po2:
            s = quantize_po2(s)
        return jax.lax.stop_gradient(s)

    def __call__(self, x):
        s = self.scale(x)
        q = ste_round(x / s)
        q = ste_clip(q, float(self.qmin), float(self.qmax))
        return q * s

    def quantize_int(self, x):
        """Return (int codes, scale) — the deployment-side representation."""
        s = self.scale(x)
        q = jnp.clip(jnp.round(x / s), self.qmin, self.qmax)
        dt = jnp.int8 if self.bits <= 8 else jnp.int32
        return q.astype(dt), s


@dataclasses.dataclass(frozen=True)
class BinaryQuantizer:
    """Bipolar {-scale,+scale} quantizer (CNV-W1A1)."""

    bits: int = 1
    scale_value: float = 1.0

    def __call__(self, x):
        return ste_sign(x) * self.scale_value


@dataclasses.dataclass(frozen=True)
class TernaryQuantizer:
    """{-1, 0, +1} * scale with dead-zone threshold (default 0.5*E|x|-ish)."""

    bits: int = 2
    threshold: float = 0.05

    def __call__(self, x):
        pos = (x > self.threshold).astype(x.dtype)
        neg = (x < -self.threshold).astype(x.dtype)
        hard = pos - neg
        # STE: gradient of identity within [-1, 1]
        return hard + (ste_clip(x, -1.0, 1.0) - jax.lax.stop_gradient(ste_clip(x, -1.0, 1.0)))


def make_quantizer(bits: int, kind: str = "int", **kw):
    """Factory keyed the way configs express precision."""
    if bits >= 32 or kind == "none":
        return None
    if bits == 1 or kind == "binary":
        return BinaryQuantizer()
    if kind == "ternary":
        return TernaryQuantizer()
    if kind == "fixed":
        return FixedPointQuantizer(bits=bits, **kw)
    return IntQuantizer(bits=bits, **kw)


# ---------------------------------------------------------------------------
# activation fake-quant used inside LM blocks (W8A8 path)
# ---------------------------------------------------------------------------

def fake_quant_act(x, bits: int = 8):
    """Per-tensor symmetric activation fake-quant (QAT for LM stacks)."""
    if bits >= 16:
        return x
    q = IntQuantizer(bits=bits, signed=True)
    return q(x)
