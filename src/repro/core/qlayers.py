"""Quantization-aware layers (QDense, QConv2D, QDenseBatchNorm).

These are the paper's building blocks, expressed as pure init/apply pairs
(params are plain pytrees — no flax dependency):

  * ``QDense``          - FC layer with weight/activation quantizers attached.
  * ``QConv2D``         - NHWC conv with the same quantizer hooks.
  * ``QDenseBatchNorm`` - the paper's §3.3.1 contribution: BN folded into the
                          FC kernel *during training* (Eqs. 3-4), so the
                          deployed layer is a single affine:
                             k_folded = v * k_FC
                             b_folded = v * (b_FC - mu) + beta,
                          v = gamma / sqrt(sigma^2 + eps).

The deployment ("streamlined") path of each layer produces integer-only
arithmetic via core/streamline.py and runs on the fused Pallas kernel
(kernels/qmatmul.py) when enabled.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.quantizers import IntQuantizer, make_quantizer

Params = Any


def _init_dense(key, in_dim, out_dim, dtype=jnp.float32):
    wkey, _ = jax.random.split(key)
    limit = (6.0 / (in_dim + out_dim)) ** 0.5  # glorot uniform, like QKeras
    w = jax.random.uniform(wkey, (in_dim, out_dim), dtype, -limit, limit)
    b = jnp.zeros((out_dim,), dtype)
    return {"w": w, "b": b}


@dataclasses.dataclass(frozen=True)
class QDense:
    in_dim: int
    out_dim: int
    weight_bits: int = 8
    act_bits: int = 8
    weight_kind: str = "int"
    act_kind: str = "int"
    use_bias: bool = True
    relu: bool = False  # merged ReLU (paper §3.1.3)

    def init(self, key, dtype=jnp.float32) -> Params:
        return _init_dense(key, self.in_dim, self.out_dim, dtype)

    @property
    def wq(self):
        return make_quantizer(self.weight_bits, self.weight_kind, axis=0)

    @property
    def aq(self):
        return make_quantizer(self.act_bits, self.act_kind)

    def apply(self, params: Params, x, train: bool = True):
        w = params["w"]
        if self.wq is not None:
            w = self.wq(w)
        y = x @ w
        if self.use_bias:
            y = y + params["b"]
        if self.relu:
            y = jax.nn.relu(y)
        if self.aq is not None:
            y = self.aq(y)
        return y

    def n_params(self) -> int:
        return self.in_dim * self.out_dim + (self.out_dim if self.use_bias else 0)


@dataclasses.dataclass(frozen=True)
class QDenseBatchNorm:
    """FC + BN folded during the forward pass (paper Eqs. 3-4).

    Training keeps separate (k_FC, b_FC, gamma, beta, mu, sigma2); every
    forward computes the folded kernel and quantizes *the folded kernel*, so
    train-time arithmetic matches the deployed integer layer exactly — this is
    why the paper's Table 4 "With folding" row changes AUC.
    """

    in_dim: int
    out_dim: int
    weight_bits: int = 8
    act_bits: int = 8
    relu: bool = True
    momentum: float = 0.99
    eps: float = 1e-3

    def init(self, key, dtype=jnp.float32) -> Params:
        p = _init_dense(key, self.in_dim, self.out_dim, dtype)
        p.update(
            gamma=jnp.ones((self.out_dim,), dtype),
            beta=jnp.zeros((self.out_dim,), dtype),
            mu=jnp.zeros((self.out_dim,), dtype),
            sigma2=jnp.ones((self.out_dim,), dtype),
        )
        return p

    @property
    def wq(self):
        return make_quantizer(self.weight_bits, "int", axis=0)

    @property
    def aq(self):
        return make_quantizer(self.act_bits, "int")

    def fold(self, params: Params) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Eqs. 3-4: returns (k_folded, b_folded)."""
        v = params["gamma"] / jnp.sqrt(params["sigma2"] + self.eps)
        k_folded = params["w"] * v[None, :]
        b_folded = v * (params["b"] - params["mu"]) + params["beta"]
        return k_folded, b_folded

    def apply(self, params: Params, x, train: bool = True):
        """Returns (y, new_params) in train mode; (y, params) in eval mode."""
        if train:
            # batch statistics over all leading axes
            y_fc = x @ params["w"] + params["b"]
            red = tuple(range(y_fc.ndim - 1))
            mu_b = jnp.mean(y_fc, axis=red)
            var_b = jnp.var(y_fc, axis=red)
            m = self.momentum
            params = dict(
                params,
                mu=m * params["mu"] + (1 - m) * jax.lax.stop_gradient(mu_b),
                sigma2=m * params["sigma2"] + (1 - m) * jax.lax.stop_gradient(var_b),
            )
            # fold with *batch* stats so training sees the deployed arithmetic
            v = params["gamma"] / jnp.sqrt(var_b + self.eps)
            k_folded = params["w"] * v[None, :]
            b_folded = v * (params["b"] - mu_b) + params["beta"]
        else:
            k_folded, b_folded = self.fold(params)

        if self.wq is not None:
            k_folded = self.wq(k_folded)
        y = x @ k_folded + b_folded
        if self.relu:
            y = jax.nn.relu(y)
        if self.aq is not None:
            y = self.aq(y)
        return y, params

    def n_params(self) -> int:
        return self.in_dim * self.out_dim + 5 * self.out_dim


@dataclasses.dataclass(frozen=True)
class QConv2D:
    """NHWC conv with quantizer hooks + optional merged ReLU."""

    in_ch: int
    out_ch: int
    kernel: int = 3
    stride: int = 1
    padding: str = "SAME"
    weight_bits: int = 8
    act_bits: int = 8
    weight_kind: str = "int"
    relu: bool = False
    use_bias: bool = True

    def init(self, key, dtype=jnp.float32) -> Params:
        fan_in = self.in_ch * self.kernel * self.kernel
        fan_out = self.out_ch * self.kernel * self.kernel
        limit = (6.0 / (fan_in + fan_out)) ** 0.5
        w = jax.random.uniform(
            key, (self.kernel, self.kernel, self.in_ch, self.out_ch), dtype, -limit, limit
        )
        return {"w": w, "b": jnp.zeros((self.out_ch,), dtype)}

    @property
    def wq(self):
        return make_quantizer(self.weight_bits, self.weight_kind, axis=(0, 1, 2))

    @property
    def aq(self):
        return make_quantizer(self.act_bits, "int")

    def apply(self, params: Params, x, train: bool = True):
        w = params["w"]
        if self.wq is not None:
            # per-output-channel scale over (kh, kw, cin)
            q = IntQuantizer(bits=self.weight_bits, signed=True, narrow=True)
            qmax = q.qmax
            amax = jnp.max(jnp.abs(w), axis=(0, 1, 2), keepdims=True)
            s = jax.lax.stop_gradient(jnp.maximum(amax, 1e-8) / qmax)
            from repro.core.quantizers import ste_clip, ste_round

            w = ste_clip(ste_round(w / s), float(q.qmin), float(q.qmax)) * s
        y = jax.lax.conv_general_dilated(
            x, w,
            window_strides=(self.stride, self.stride),
            padding=self.padding,
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )
        if self.use_bias:
            y = y + params["b"]
        if self.relu:
            y = jax.nn.relu(y)
        if self.aq is not None:
            y = self.aq(y)
        return y

    def n_params(self) -> int:
        return self.kernel * self.kernel * self.in_ch * self.out_ch + (
            self.out_ch if self.use_bias else 0
        )
