# The paper's primary contribution as a composable library:
#   quantizers  - arbitrary-precision QAT (C1)
#   qlayers     - QDense / QConv / QDenseBatchNorm folding (C3)
#   streamline  - integer multi-threshold deployment graphs (C2)
#   bops        - BOPs / WM / inference-cost metrics (C7, Eqs. 1-2)
#   search      - ASHA + BO-lite hardware-aware NAS (C7)
#   dataflow    - FIFO-depth optimization for dataflow pipelines (C5)
#   qir         - QONNX-style interchange format (C8)
#   codesign    - the end-to-end §5 methodology driver
