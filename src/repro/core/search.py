"""Hardware-aware NAS: adaptive ASHA + a TPE-style Bayesian-optimization-lite.

The paper uses (a) KerasTuner Bayesian optimization for the hls4ml IC model
(§3.1.1, Fig. 2) and (b) Determined AI's adaptive ASHA (§3.2.1, Fig. 3) for
the FINN CNV scan and KWS loss-weight search. Both are reimplemented here as
dependency-free drivers over a user-supplied

    objective(config: dict, budget: int, rng) -> float   (higher is better)

ASHA follows Li et al. 2020: rungs at budgets eta^k * r_min; a trial is
promoted to the next rung if it ranks in the top 1/eta of completed trials at
its rung. The implementation is synchronous-in-batches (we have one host) but
keeps ASHA's promotion rule, which is what distinguishes it from plain
successive halving.

BOLite is a kernel-density TPE: observations are split at quantile gamma into
good/bad sets; candidates are sampled from the good-set KDE and scored by the
density ratio l(x)/g(x).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np


# ---------------------------------------------------------------------------
# search space
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Choice:
    name: str
    options: Tuple

    def sample(self, rng: np.random.Generator):
        return self.options[int(rng.integers(len(self.options)))]

    def index(self, v) -> int:
        return self.options.index(v)


def sample_config(space: Sequence[Choice], rng: np.random.Generator) -> Dict:
    return {c.name: c.sample(rng) for c in space}


# ---------------------------------------------------------------------------
# ASHA
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Trial:
    config: Dict
    rung: int = 0
    score: float = -math.inf
    budget_used: int = 0
    alive: bool = True


def asha_search(
    objective: Callable,
    space: Sequence[Choice],
    *,
    n_trials: int = 32,
    r_min: int = 1,
    eta: int = 2,
    max_rung: int = 3,
    seed: int = 0,
) -> Tuple[Trial, List[Trial]]:
    """Adaptive ASHA. Returns (best_trial, all_trials)."""
    rng = np.random.default_rng(seed)
    trials = [Trial(config=sample_config(space, rng)) for _ in range(n_trials)]
    rung_scores: Dict[int, List[float]] = {k: [] for k in range(max_rung + 1)}

    # evaluate every trial at rung 0
    for t in trials:
        t.score = float(objective(t.config, r_min, rng))
        t.budget_used = r_min
        rung_scores[0].append(t.score)

    # promotion loop: a trial at rung k is promoted when it is in the top
    # 1/eta of *completed* rung-k scores (ASHA's asynchronous rule).
    progressed = True
    while progressed:
        progressed = False
        for t in trials:
            if not t.alive or t.rung >= max_rung:
                continue
            scores = rung_scores[t.rung]
            if len(scores) < eta:
                continue
            cutoff = float(np.quantile(np.asarray(scores), 1.0 - 1.0 / eta))
            if t.score >= cutoff:
                t.rung += 1
                budget = r_min * (eta ** t.rung)
                t.score = float(objective(t.config, budget, rng))
                t.budget_used += budget
                rung_scores[t.rung].append(t.score)
                progressed = True
            else:
                t.alive = False  # halted at this rung

    best = max(trials, key=lambda t: (t.rung, t.score))
    return best, trials


# ---------------------------------------------------------------------------
# BO-lite (TPE)
# ---------------------------------------------------------------------------

def _kde_logpdf(x: np.ndarray, samples: np.ndarray, bw: float) -> float:
    if len(samples) == 0:
        return 0.0
    d2 = (x[None, :] - samples) ** 2
    logk = -0.5 * d2.sum(axis=1) / bw ** 2
    return float(np.log(np.exp(logk).mean() + 1e-12))


def bo_search(
    objective: Callable,
    space: Sequence[Choice],
    *,
    n_trials: int = 50,
    n_startup: int = 10,
    gamma: float = 0.25,
    n_candidates: int = 32,
    budget: int = 1,
    seed: int = 0,
) -> Tuple[Dict, List[Tuple[Dict, float]]]:
    """TPE-style BO over a discrete space. Returns (best_config, history)."""
    rng = np.random.default_rng(seed)
    history: List[Tuple[Dict, float]] = []

    def encode(cfg: Dict) -> np.ndarray:
        return np.array(
            [c.index(cfg[c.name]) / max(len(c.options) - 1, 1) for c in space],
            dtype=np.float64,
        )

    for i in range(n_trials):
        if i < n_startup or len(history) < 4:
            cfg = sample_config(space, rng)
        else:
            xs = np.stack([encode(c) for c, _ in history])
            ys = np.array([s for _, s in history])
            cut = np.quantile(ys, 1.0 - gamma)
            good = xs[ys >= cut]
            bad = xs[ys < cut]
            bw = 0.2
            best_cand, best_ratio = None, -math.inf
            for _ in range(n_candidates):
                cand = sample_config(space, rng)
                x = encode(cand)
                ratio = _kde_logpdf(x, good, bw) - _kde_logpdf(x, bad, bw)
                if ratio > best_ratio:
                    best_ratio, best_cand = ratio, cand
            cfg = best_cand
        score = float(objective(cfg, budget, rng))
        history.append((cfg, score))

    best_cfg = max(history, key=lambda t: t[1])[0]
    return best_cfg, history


# ---------------------------------------------------------------------------
# predictor-evaluated codesign sweeps (the fleet-scale mode)
# ---------------------------------------------------------------------------

def predictor_objective(predict_ms: Callable[[Dict], float],
                        feature_fn: Callable[[Dict], Dict]) -> Callable:
    """Wrap a learned wave-cost predictor as a search objective.

    ``feature_fn(config) -> feature dict`` maps a search-space point to
    the versioned ``repro.costmodel`` feature schema (typically via
    ``features_from_model_cost``); the score is the *negative* predicted
    wave cost, so both drivers' higher-is-better convention minimizes
    cost. The objective is pure arithmetic — no compile, no execution, no
    wall clock — which is what lets the quantization x tiling x
    micro-batch scans run thousands of points.
    """

    def objective(config: Dict, budget: int, rng) -> float:
        del budget, rng   # a prediction has no fidelity knob or noise
        return -float(predict_ms(feature_fn(config)))

    return objective


def predictor_sweep(predict_ms: Callable[[Dict], float],
                    feature_fn: Callable[[Dict], Dict],
                    space: Sequence[Choice], *,
                    method: str = "bo", n_trials: int = 64, seed: int = 0,
                    accuracy_fn: Optional[Callable[[Dict], float]] = None
                    ) -> Dict[str, object]:
    """Predictor-evaluated codesign sweep over a discrete space.

    Runs the existing BO/ASHA drivers with ``predictor_objective`` — the
    Fig. 2/3 scans without wall-clock. Returns the best config, every
    evaluated row (config + predicted cost, plus ``accuracy`` when an
    ``accuracy_fn`` surrogate is supplied), and the Pareto-front indices
    over (predicted cost, accuracy).
    """
    obj = predictor_objective(predict_ms, feature_fn)
    if method == "bo":
        best_cfg, history = bo_search(obj, space, n_trials=n_trials,
                                      seed=seed)
        evaluated = [(cfg, score) for cfg, score in history]
    elif method == "asha":
        best, trials = asha_search(obj, space, n_trials=n_trials, seed=seed)
        best_cfg = best.config
        evaluated = [(t.config, t.score) for t in trials]
    else:
        raise ValueError(f"method {method!r}: expected bo|asha")
    rows = []
    for cfg, score in evaluated:
        row = {"config": dict(cfg), "predicted_ms": -float(score)}
        if accuracy_fn is not None:
            row["accuracy"] = float(accuracy_fn(cfg))
        rows.append(row)
    out: Dict[str, object] = {
        "method": method,
        "n_evaluated": len(rows),
        "best": {"config": dict(best_cfg),
                 "predicted_ms": float(predict_ms(feature_fn(best_cfg)))},
        "rows": rows,
    }
    if accuracy_fn is not None:
        pts = [(r["predicted_ms"], r["accuracy"]) for r in rows]
        out["pareto"] = pareto_front(pts)
    return out


# ---------------------------------------------------------------------------
# Pareto utilities (accuracy vs. cost plots of Figs. 2-4)
# ---------------------------------------------------------------------------

def pareto_front(points: Sequence[Tuple[float, float]]) -> List[int]:
    """Indices of the Pareto-optimal set minimizing x (cost), maximizing y
    (accuracy)."""
    idx = sorted(range(len(points)), key=lambda i: (points[i][0], -points[i][1]))
    front, best_y = [], -math.inf
    for i in idx:
        if points[i][1] > best_y:
            front.append(i)
            best_y = points[i][1]
    return front
