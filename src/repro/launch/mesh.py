"""Production mesh construction.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — smoke tests and benches see 1 CPU device;
only launch/dryrun.py (which sets XLA_FLAGS before any import) sees 512.

    single-pod:  (16, 16)      -> ("data", "model")        256 chips
    multi-pod :  (2, 16, 16)   -> ("pod", "data", "model") 512 chips

``make_elastic_mesh`` builds the best-fitting mesh from whatever devices are
currently alive — the restore path of the elastic-restart story (a failed
host shrinks the data axis; checkpoint.restore reshards onto the new mesh).
"""

from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "any jax import (launch/dryrun.py does this)"
        )
    try:
        return jax.make_mesh(shape, axes, devices=devices[:n])
    except TypeError:  # older make_mesh without devices kwarg
        from jax.sharding import Mesh

        return Mesh(np.array(devices[:n]).reshape(shape), axes)


def make_elastic_mesh(model_parallel: int = 1,
                      devices: Optional[Sequence] = None):
    """Best mesh from the devices that are alive: ("data", "model") with the
    data axis absorbing whatever count remains after TP."""
    from jax.sharding import Mesh

    devices = list(devices if devices is not None else jax.devices())
    n = len(devices)
    mp = max(1, min(model_parallel, n))
    while n % mp != 0:
        mp -= 1
    dp = n // mp
    return Mesh(np.array(devices[: dp * mp]).reshape(dp, mp), ("data", "model"))


def mesh_devices(mesh) -> int:
    return math.prod(mesh.shape.values()) if hasattr(mesh.shape, "values") else mesh.size
