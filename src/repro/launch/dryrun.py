import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. constructs ShapeDtypeStruct stand-ins for params, optimizer state,
     caches, and batch (jax.eval_shape — zero allocation),
  3. jits the right entry point (train_step / prefill / decode_step) with
     explicit in_shardings from the logical rules,
  4. .lower().compile() — success proves the sharding config is coherent,
  5. records memory_analysis, cost_analysis, and the static HLO analysis
     (loop-scaled FLOPs + collective bytes by type) to a JSON artifact that
     launch/roofline.py and EXPERIMENTS.md consume.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all            # every cell
  ... --mesh multi --seq-parallel --quant 8 --remat dots        # variants
"""

import argparse
import dataclasses
import json
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import timer as obs_timer
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import ArchConfig, SHAPES, ShapeConfig, shape_applicable
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.models.model import Model
from repro.optim.adamw import make_optimizer
from repro.parallel.sharding import logical_to_spec, use_mesh_rules
from repro.train.steps import TrainState, make_train_step


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins, shardable, no allocation)
# ---------------------------------------------------------------------------

def batch_logical_axes(n_batch: int, mesh) -> Optional[tuple]:
    """Largest batch sharding that divides n_batch: (pod,data) > (data,) > None."""
    names = mesh.axis_names
    cands = []
    if "pod" in names:
        cands.append(("pod", "data"))
    cands.append(("data",))
    for axes in cands:
        ways = 1
        for a in axes:
            ways *= mesh.shape[a]
        if n_batch % ways == 0:
            return axes
    return None


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        if cfg.embed_inputs:
            toks = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        else:
            toks = jax.ShapeDtypeStruct((B, 1, cfg.d_model), jnp.dtype(cfg.dtype))
        return {"tokens_or_embeds": toks}
    specs: Dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.embed_inputs:
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    else:
        specs["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.dtype(cfg.dtype))
        if cfg.mrope:
            specs["positions"] = jax.ShapeDtypeStruct((3, B, S), jnp.int32)
    if shape.kind == "train":
        specs["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    return specs


def batch_shardings(cfg, shape, mesh, batch_axes_):
    """NamedSharding tree matching input_specs."""
    def sh(*axes):
        return NamedSharding(mesh, P(*axes))

    b = batch_axes_
    out = {}
    for k in input_specs(cfg, shape):
        if k in ("tokens", "labels"):
            out[k] = sh(b, None)
        elif k == "embeds":
            out[k] = sh(b, None, None)
        elif k == "positions":
            out[k] = sh(None, b, None)
        elif k == "tokens_or_embeds":
            out[k] = sh(b, None) if cfg.embed_inputs else sh(b, None, None)
    return out


def arch_rules(cfg: ArchConfig, mesh, baxes) -> dict:
    """Per-arch logical-rule fix-ups: a logical axis maps to 'model' only when
    the corresponding dimension divides the mesh axis (e.g. qwen1.5's 20
    heads and every GQA kv=8 fall back to replicated on a 16-way model axis;
    TP then lives on d_ff / vocab / head-flattened dims)."""
    mw = mesh.shape["model"]

    def fit(n):
        return ("model",) if n and n % mw == 0 else None

    return {
        "batch": baxes,
        "heads": fit(cfg.n_heads),
        "heads_flat": fit(cfg.n_heads * cfg.hd),  # wo fan-in: flattened H*hd
        "kv_heads": fit(cfg.n_kv_heads),
        "vocab": fit(cfg.vocab),
        "mlp": fit(cfg.d_ff),
        "model": fit(cfg.d_inner if cfg.has_ssm else cfg.d_model),
        "kv_seq": ("model",),
    }


# ---------------------------------------------------------------------------
# named sharding strategies (the §Perf hillclimbing levers)
# ---------------------------------------------------------------------------

def strategy_rules(name: str, cfg: ArchConfig, mesh, shape) -> dict:
    """Rule overrides applied on top of arch_rules. Each is one hypothesis in
    EXPERIMENTS.md §Perf; 'baseline' is the paper-faithful FSDP+TP layout."""
    names = mesh.axis_names
    all_axes = tuple(a for a in ("pod", "data", "model") if a in names)
    if name == "baseline":
        return {}
    if name == "seqpar":
        # sequence-parallel residual stream: inter-layer activations shard S
        # over the model axis instead of replicating
        return {"seq": ("model",)}
    if name == "fsdp2d":
        # kill tensor parallelism: batch over BOTH axes (pure DP), params
        # FSDP-sharded over both axes. Needs global_batch % n_devices == 0
        # and fan-in dims % n_devices == 0 (all assigned archs satisfy this
        # for train_4k).
        return {
            "batch": all_axes, "fsdp": all_axes,
            "heads": None, "kv_heads": None, "heads_flat": None,
            "vocab": None, "mlp": None, "model": None, "experts": None,
            "kv_seq": None,
        }
    if name == "tponly":
        # decode layout: no FSDP — params live sharded over 'model' only, so
        # no per-token parameter all-gathers; batch stays on data axes
        return {"fsdp": None}
    if name == "ep":
        # expert parallelism: experts over the model axis (MoE archs whose
        # expert count divides it), TP inside the expert turned off
        return {"experts": ("model",), "mlp": None}
    if name == "fsdppod":
        # multi-pod: extend FSDP over BOTH data-parallel axes so optimizer
        # state and params halve per device on the 512-chip mesh
        dp = tuple(a for a in ("pod", "data") if a in names)
        return {"fsdp": dp}
    raise ValueError(f"unknown strategy {name}")


def combined_strategy_rules(spec: str, cfg, mesh, shape) -> dict:
    """Comma-separated strategy names, merged left to right."""
    rules: dict = {}
    for name in spec.split(","):
        rules.update(strategy_rules(name.strip(), cfg, mesh, shape))
    return rules


STRATEGIES = ("baseline", "seqpar", "fsdp2d", "tponly", "ep", "fsdppod")


# ---------------------------------------------------------------------------
# per-leaf local (per-device) byte accounting from spec trees
# ---------------------------------------------------------------------------

def local_bytes(sds_tree, spec_tree, mesh) -> int:
    total = 0
    leaves = jax.tree.leaves(sds_tree)
    specs = jax.tree.leaves(spec_tree, is_leaf=lambda x: isinstance(x, P))
    assert len(leaves) == len(specs), (len(leaves), len(specs))
    for leaf, spec in zip(leaves, specs):
        ways = 1
        for entry in spec:
            if entry is None:
                continue
            for ax in (entry if isinstance(entry, tuple) else (entry,)):
                ways *= mesh.shape[ax]
        total += leaf.size * leaf.dtype.itemsize // ways
    return int(total)


# ---------------------------------------------------------------------------
# cell runner
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             rules_override: Optional[dict] = None, quant_bits: int = 16,
             remat: Optional[str] = None, microbatches: int = 1,
             strategy: Optional[str] = None, attn_impl: Optional[str] = None,
             out_dir: str = "artifacts/dryrun", tag: str = "baseline",
             verbose: bool = True) -> Dict[str, Any]:
    cfg = get_config(arch)
    if remat:
        cfg = dataclasses.replace(cfg, remat=remat)
    if attn_impl:
        cfg = dataclasses.replace(cfg, attn_impl=attn_impl)
    shape = SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    mesh_name = "multi" if multi_pod else "single"
    result: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "tag": tag,
        "quant_bits": quant_bits,
    }
    if not ok:
        result["status"] = "skipped"
        result["reason"] = reason
        _write(result, out_dir)
        return result

    t0 = obs_timer.now()
    mesh = make_production_mesh(multi_pod=multi_pod)
    baxes = batch_logical_axes(shape.global_batch, mesh)
    rules = arch_rules(cfg, mesh, baxes)
    if strategy and strategy != "baseline":
        rules.update(combined_strategy_rules(strategy, cfg, mesh, shape))
    if rules_override:
        rules.update(rules_override)

    try:
        with use_mesh_rules(mesh, rules):
            model = Model(cfg)
            pspecs = model.param_specs()
            params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
            if quant_bits < 16 and shape.kind != "train":
                params_sds = jax.eval_shape(
                    lambda p: model.quantize_params(p, quant_bits), params_sds
                )
                pspecs = _quantized_specs(params_sds, pspecs)
            psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                               is_leaf=lambda x: isinstance(x, P))
            bspec = batch_shardings(cfg, shape, mesh, baxes)
            bs_sds = input_specs(cfg, shape)

            if shape.kind == "train":
                opt = make_optimizer()
                opt_sds = jax.eval_shape(opt.init, params_sds)
                opt_sh = type(opt_sds)(
                    step=NamedSharding(mesh, P()),
                    m=jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                                   is_leaf=lambda x: isinstance(x, P)),
                    v=jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                                   is_leaf=lambda x: isinstance(x, P)),
                )
                state_sds = TrainState(params=params_sds, opt=opt_sds)
                state_sh = TrainState(params=psh, opt=opt_sh)
                step_fn = make_train_step(model, opt, microbatches=microbatches)
                jitted = jax.jit(step_fn, in_shardings=(state_sh, bspec),
                                 donate_argnums=(0,))
                lowered = jitted.lower(state_sds, bs_sds)
                state_local = local_bytes(params_sds, pspecs, mesh) + local_bytes(
                    opt_sds.m, pspecs, mesh) + local_bytes(opt_sds.v, pspecs, mesh)
                result["cache_local_bytes"] = 0
            elif shape.kind == "prefill":
                fn = model.prefill
                jitted = jax.jit(fn, in_shardings=(psh, bspec))
                lowered = jitted.lower(params_sds, bs_sds)
                state_local = local_bytes(params_sds, pspecs, mesh)
                result["cache_local_bytes"] = 0
            else:  # decode
                cache_sds = jax.eval_shape(
                    lambda: model.cache_init(shape.global_batch, shape.seq_len)
                )
                cspecs = model.cache_specs()
                csh = jax.tree.map(lambda s: NamedSharding(mesh, s), cspecs,
                                   is_leaf=lambda x: isinstance(x, P))
                tok_sh = bspec["tokens_or_embeds"]
                jitted = jax.jit(
                    model.decode_step,
                    in_shardings=(psh, csh, tok_sh, NamedSharding(mesh, P())),
                    donate_argnums=(1,),
                )
                lowered = jitted.lower(
                    params_sds, cache_sds, bs_sds["tokens_or_embeds"],
                    jax.ShapeDtypeStruct((), jnp.int32),
                )
                state_local = local_bytes(params_sds, pspecs, mesh)
                result["cache_local_bytes"] = local_bytes(cache_sds, cspecs, mesh)

            t_lower = obs_timer.now()
            compiled = lowered.compile()
            t_compile = obs_timer.now()

            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis() or {}
            hlo_stats = analyze_hlo(compiled.as_text())

            result.update(
                status="ok",
                lower_s=round(t_lower - t0, 2),
                compile_s=round(t_compile - t_lower, 2),
                n_devices=int(np.prod(list(mesh.shape.values()))),
                batch_axes=list(baxes) if baxes else [],
                params_local_bytes=local_bytes(params_sds, pspecs, mesh),
                state_local_bytes=state_local,
                memory_analysis={
                    k: int(getattr(mem, k))
                    for k in ("argument_size_in_bytes", "output_size_in_bytes",
                              "temp_size_in_bytes", "alias_size_in_bytes",
                              "generated_code_size_in_bytes")
                    if hasattr(mem, k)
                },
                xla_cost_analysis={
                    "flops": float(cost.get("flops", -1.0)),
                    "bytes_accessed": float(cost.get("bytes accessed", -1.0)),
                },
                hlo_flops_per_device=float(hlo_stats.flops),
                collective_bytes_per_device=float(hlo_stats.collective_bytes),
                collectives_by_type={k: float(v) for k, v in hlo_stats.by_type.items()},
                collectives_count={k: int(v) for k, v in hlo_stats.by_count.items()},
            )
            if verbose:
                print(f"[dryrun] {arch} x {shape_name} x {mesh_name} ({tag}): OK "
                      f"compile={result['compile_s']}s "
                      f"flops/dev={hlo_stats.flops:.3e} "
                      f"coll B/dev={hlo_stats.collective_bytes:.3e} "
                      f"params/dev={result['params_local_bytes']/2**30:.2f}GiB")
                print("  memory_analysis:", result["memory_analysis"])
    except Exception as e:  # noqa: BLE001 — a failing cell is a recorded bug
        result["status"] = "error"
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-4000:]
        if verbose:
            print(f"[dryrun] {arch} x {shape_name} x {mesh_name} ({tag}): "
                  f"FAILED {result['error']}")
    _write(result, out_dir)
    return result


def _quantized_specs(params_sds, pspecs):
    """Spec tree matching the quantized param structure: w_int inherits w's
    spec; w_scale keeps the output-channel shards but replicates every axis
    whose size collapsed to 1 (the fan-in axis — and with scan-stacked layer
    params that is dim 1, not dim 0)."""
    def visit(sds, spec):
        if isinstance(sds, dict) and "w_int" in sds:
            wspec = spec["w"] if isinstance(spec, dict) and "w" in spec else P()
            wlist = list(wspec) + [None] * (sds["w_int"].ndim - len(wspec))
            sshape = sds["w_scale"].shape
            sspec = P(*[None if sshape[i] == 1 else wlist[i]
                        for i in range(len(sshape))])
            out = {"w_int": wspec, "w_scale": sspec}
            if "b" in sds:
                out["b"] = spec.get("b", P()) if isinstance(spec, dict) else P()
            return out
        if isinstance(sds, dict):
            return {k: visit(v, spec[k] if isinstance(spec, dict) else spec)
                    for k, v in sds.items()}
        return spec

    return visit(params_sds, pspecs)


def _write(result: Dict[str, Any], out_dir: str):
    os.makedirs(out_dir, exist_ok=True)
    name = (f"{result['arch']}__{result['shape']}__{result['mesh']}"
            f"__{result.get('tag', 'baseline')}.json")
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(result, f, indent=1)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true", help="run every cell")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--quant", type=int, default=16)
    ap.add_argument("--remat", choices=["full", "dots", "dots_saveable",
                                        "none"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--strategy", default=None,
                    help=f"comma-separated from {STRATEGIES}")
    ap.add_argument("--attn-impl", choices=["auto", "naive", "chunked"])
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    rules = {"seq": ("model",)} if args.seq_parallel else None
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    cells = []
    if args.all:
        for arch in ARCH_IDS:
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    n_ok = n_fail = n_skip = 0
    for arch, shape in cells:
        for mp in meshes:
            if args.skip_existing:
                name = (f"{arch}__{shape}__{'multi' if mp else 'single'}"
                        f"__{args.tag}.json")
                path = os.path.join(args.out, name)
                if os.path.exists(path):
                    with open(path) as f:
                        if json.load(f).get("status") == "ok":
                            continue
            r = run_cell(arch, shape, multi_pod=mp, rules_override=rules,
                         quant_bits=args.quant, remat=args.remat,
                         microbatches=args.microbatches,
                         strategy=args.strategy, attn_impl=args.attn_impl,
                         out_dir=args.out, tag=args.tag)
            n_ok += r["status"] == "ok"
            n_fail += r["status"] == "error"
            n_skip += r["status"] == "skipped"
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped (by assignment), {n_fail} FAILED")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
