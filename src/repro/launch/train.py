"""Production training launcher: mesh construction, SPMD train step, sharded
data pipeline, fault-tolerant loop with elastic restart.

On a real slice this is the per-process entry point (jax.distributed handles
multi-host); on this container it runs the same code on the local devices
(1 on CPU, or N with --force-devices N for integration testing).

    PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --reduced \
        --steps 50 --batch 8 --seq 64
"""

import argparse
import dataclasses
import logging

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.data.synthetic import SyntheticTokens
from repro.launch.mesh import make_elastic_mesh
from repro.models.model import Model
from repro.optim.adamw import make_optimizer
from repro.parallel.sharding import use_mesh_rules
from repro.train.loop import ElasticRestart, LoopConfig, run_training
from repro.train.steps import TrainState, make_train_step

logging.basicConfig(level=logging.INFO, format="%(message)s")
log = logging.getLogger("repro.launch.train")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="llama3-8b")
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-scale same-family config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--quant-bits", type=int, default=16)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--max-elastic-restarts", type=int, default=2)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.quant_bits < 16:
        cfg = dataclasses.replace(cfg, weight_bits=args.quant_bits)

    data = SyntheticTokens(vocab=cfg.vocab, seq_len=args.seq)
    devices = list(jax.devices())
    restarts = 0

    while True:
        mesh = make_elastic_mesh(args.model_parallel, devices)
        log.info("mesh %s over %d devices", dict(mesh.shape), mesh.size)
        with use_mesh_rules(mesh):
            model = Model(cfg)
            params = model.init(jax.random.PRNGKey(0))
            opt = make_optimizer(base_lr=3e-4, warmup=10, total=args.steps)
            state = TrainState(params=params, opt=opt.init(params))
            step_fn = jax.jit(
                make_train_step(model, opt, microbatches=args.microbatches),
                donate_argnums=(0,))
            bsh = NamedSharding(mesh, P("data", None))

            def batch_fn(step):
                b = data.batch(step, args.batch)
                return {k: jax.device_put(jnp.asarray(v), bsh) for k, v in b.items()}

            lcfg = LoopConfig(total_steps=args.steps,
                              ckpt_every=args.ckpt_every,
                              ckpt_dir=args.ckpt_dir, log_every=10)
            try:
                with mesh:
                    res = run_training(step_fn, state, batch_fn, lcfg)
                break
            except ElasticRestart as e:
                restarts += 1
                log.warning("elastic restart %d: %s", restarts, e)
                if restarts > args.max_elastic_restarts:
                    raise
                # on a real pod the scheduler would hand back the healthy
                # devices; here we keep the same set and resume from ckpt
                continue

    last = res.metrics_history[-1] if res.metrics_history else {}
    log.info("finished at step %d (resumed_from=%s): %s",
             res.final_step, res.resumed_from, last)
    return res


if __name__ == "__main__":
    main()
