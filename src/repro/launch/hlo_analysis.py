"""Static analysis of compiled (SPMD-partitioned) HLO text.

Why this exists: ``compiled.cost_analysis()`` counts a while-loop body ONCE —
for scan-over-layers models that under-reports FLOPs by the layer count, and
collective bytes are not reported at all. This module parses the HLO text:

  * splits it into computations,
  * extracts per-computation dot/conv FLOPs and collective output bytes
    (all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute, sync and -start/-done async forms),
  * recovers while-loop trip counts from the loop-condition comparison
    constant (scan lowers to `lt(iter, C)`),
  * propagates totals bottom-up through the call graph (while x trip count,
    call/fusion x 1),

yielding per-device totals for the §Roofline terms. Everything is validated
against known graphs in tests/test_hlo_analysis.py (scan x N gives exactly
N x the body FLOPs, psum bytes match array size, etc.).
"""

from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1, "token": 0,
}

SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
COLLECTIVE_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def shape_bytes(type_str: str) -> int:
    """Sum bytes over every dtype[dims] occurrence in a type string
    (handles tuples)."""
    total = 0
    for dtype, dims in SHAPE_RE.findall(type_str):
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dtype]
    return total


def _shape_elems(type_str: str) -> int:
    m = SHAPE_RE.search(type_str)
    if not m:
        return 1
    dims = m.group(2)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


@dataclasses.dataclass
class Op:
    name: str
    lhs_type: str
    opcode: str
    body: str            # full remainder of the line


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    constants: Dict[str, int]


_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^)]*\))?.*\{\s*$")
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*((?:\([^)]*\)|[\w\[\],\{\}\s]*?))\s*"
    r"([\w\-]+)\((.*)$"
)
_CONST_RE = re.compile(r"constant\((-?\d+)\)")


_COMMENT_RE = re.compile(r"/\*.*?\*/")


def parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        # strip /*index=N*/-style comments: tuples with >5 elements embed
        # them in headers and op lines, and the '=' inside breaks the
        # is-this-a-header check
        stripped = _COMMENT_RE.sub("", line).rstrip()
        if cur is None:
            if stripped.endswith("{") and ("=" not in stripped.split("{")[0] or
                                           stripped.startswith("ENTRY")):
                m = _COMP_HEADER.match(stripped.strip())
                if m:
                    cur = Computation(name=m.group(1), ops=[], constants={})
            continue
        if stripped.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_LINE.match(stripped)
        if m:
            name, lhs_type, opcode, body = m.groups()
            cur.ops.append(Op(name=name, lhs_type=lhs_type, opcode=opcode,
                              body=body))
            if opcode == "constant":
                cm = _CONST_RE.search(f"constant({body}")
                if cm:
                    try:
                        cur.constants[name] = int(cm.group(1))
                    except ValueError:
                        pass
    return comps


_OPERAND_RE = re.compile(r"%([\w\.\-]+)")


def _dot_flops(op: Op, name_to_type: Dict[str, str]) -> float:
    """2 * prod(output dims) * prod(contracting dim sizes of lhs)."""
    out_elems = _shape_elems(op.lhs_type)
    # lhs type: inline `dot(f32[..] %a, ..)` or resolved from the def of %a
    lhs_m = SHAPE_RE.search(op.body.split(",")[0])
    if lhs_m:
        lhs_dims = [int(d) for d in lhs_m.group(2).split(",") if d] or [1]
    else:
        names = _OPERAND_RE.findall(op.body)
        if not names or names[0] not in name_to_type:
            return 0.0
        m = SHAPE_RE.search(name_to_type[names[0]])
        if not m:
            return 0.0
        lhs_dims = [int(d) for d in m.group(2).split(",") if d] or [1]
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.body)
    contract = 1
    if cm and cm.group(1):
        for idx in cm.group(1).split(","):
            i = int(idx)
            if i < len(lhs_dims):
                contract *= lhs_dims[i]
    return 2.0 * out_elems * contract


def _conv_flops(op: Op, name_to_type: Optional[Dict[str, str]] = None) -> float:
    out_elems = _shape_elems(op.lhs_type)
    kern = re.search(r"size=([\dx]+)", op.body)
    k = 1
    if kern:
        for d in kern.group(1).split("x"):
            k *= int(d)
    # input feature count: second operand's kernel shape includes cin.
    # Compiled HLO often prints operands without inline types — resolve the
    # operand names through the module-wide name->type map.
    shapes = SHAPE_RE.findall(op.body.split("window=")[0])
    cin = 1
    if len(shapes) >= 2:
        dims = [int(d) for d in shapes[1][1].split(",") if d]
        if len(dims) >= 2:
            cin = dims[-2]
    elif name_to_type:
        names = _OPERAND_RE.findall(op.body.split("window=")[0])
        if len(names) >= 2 and names[1] in name_to_type:
            m = SHAPE_RE.search(name_to_type[names[1]])
            if m:
                dims = [int(d) for d in m.group(2).split(",") if d]
                if len(dims) >= 2:
                    cin = dims[-2]
    return 2.0 * out_elems * k * cin


def _called_computations(op: Op) -> List[Tuple[str, str]]:
    """[(role, computation_name)] referenced by this op."""
    out = []
    for key in ("condition", "body", "calls", "to_apply"):
        m = re.search(rf"{key}=%?([\w\.\-]+)", op.body)
        if m:
            out.append((key, m.group(1)))
    bm = re.search(r"branch_computations=\{([^}]*)\}", op.body)
    if bm:
        for name in bm.group(1).split(","):
            out.append(("branch", name.strip().lstrip("%")))
    return out


def _while_trip_count(cond: Computation) -> int:
    """Trip count from `compare(iter, C), direction=LT` in the condition."""
    best = None
    for op in cond.ops:
        if op.opcode == "compare":
            refs = re.findall(r"%([\w\.\-]+)", op.body)
            for r in refs:
                if r in cond.constants:
                    c = cond.constants[r]
                    if "direction=LT" in op.body:
                        best = c
                    elif best is None:
                        best = c
    if best is None:
        vals = [v for v in cond.constants.values() if v > 0]
        best = max(vals) if vals else 1
    return max(int(best), 1)


@dataclasses.dataclass
class HloStats:
    flops: float = 0.0
    collective_bytes: float = 0.0
    by_type: Dict[str, float] = dataclasses.field(default_factory=dict)
    by_count: Dict[str, int] = dataclasses.field(default_factory=dict)

    def scaled(self, k: float) -> "HloStats":
        return HloStats(
            flops=self.flops * k,
            collective_bytes=self.collective_bytes * k,
            by_type={t: v * k for t, v in self.by_type.items()},
            by_count={t: int(v * k) for t, v in self.by_count.items()},
        )

    def add(self, other: "HloStats"):
        self.flops += other.flops
        self.collective_bytes += other.collective_bytes
        for t, v in other.by_type.items():
            self.by_type[t] = self.by_type.get(t, 0.0) + v
        for t, v in other.by_count.items():
            self.by_count[t] = self.by_count.get(t, 0) + v


_TRIP_RE = re.compile(r'"known_trip_count":\s*\{\s*"n":\s*"(\d+)"')


def analyze_hlo(hlo: str, entry: Optional[str] = None) -> HloStats:
    comps = parse_computations(hlo)
    if not comps:
        return HloStats()
    entry_name = entry
    if entry_name is None:
        m = re.search(r"ENTRY\s+%?([\w\.\-]+)", hlo)
        entry_name = m.group(1) if m else next(iter(comps))

    # global op-name -> result-type map (names are module-unique)
    name_to_type: Dict[str, str] = {}
    for comp in comps.values():
        for op in comp.ops:
            name_to_type[op.name] = op.lhs_type

    memo: Dict[str, HloStats] = {}

    def total(name: str, stack=()) -> HloStats:
        if name in memo:
            return memo[name]
        if name not in comps or name in stack:
            return HloStats()
        comp = comps[name]
        stats = HloStats()
        for op in comp.ops:
            if op.opcode == "dot":
                stats.flops += _dot_flops(op, name_to_type)
            elif op.opcode == "convolution":
                stats.flops += _conv_flops(op, name_to_type)
            else:
                for kind in COLLECTIVE_KINDS:
                    if op.opcode == kind or op.opcode == kind + "-start":
                        b = shape_bytes(op.lhs_type)
                        if op.opcode.endswith("-start"):
                            # async tuple holds (operand, result): halve
                            b = b / 2
                        stats.collective_bytes += b
                        stats.by_type[kind] = stats.by_type.get(kind, 0.0) + b
                        stats.by_count[kind] = stats.by_count.get(kind, 0) + 1
                        break
            # recurse into called computations
            calls = _called_computations(op)
            if op.opcode == "while":
                cond = next((c for r, c in calls if r == "condition"), None)
                body = next((c for r, c in calls if r == "body"), None)
                tm = _TRIP_RE.search(op.body)
                if tm:  # XLA annotates scan loops with known_trip_count
                    trips = max(int(tm.group(1)), 1)
                else:
                    trips = _while_trip_count(comps[cond]) if cond in comps else 1
                if body:
                    stats.add(total(body, stack + (name,)).scaled(trips))
                if cond in comps:
                    stats.add(total(cond, stack + (name,)).scaled(trips))
            else:
                for _, c in calls:
                    stats.add(total(c, stack + (name,)))
        memo[name] = stats
        return stats

    return total(entry_name)
