"""Serving launcher: continuous-batching engine with optional int8 deployment
quantization — the paper's streamlined-deployment path for the LM archs —
plus the tiny-model stack behind ``--stack tiny``: a compiled Table-1 model
served through the ``repro.serve`` router with a replica pool and a
selectable dispatch engine.

    PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b \
        --reduced --requests 16 --quant-bits 8

    PYTHONPATH=src python -m repro.launch.serve --stack tiny \
        --tiny-model kws --replicas 2 --engine async --requests 256
"""

import argparse
import logging

import numpy as np

import jax

from repro.obs import timer as obs_timer

from repro.configs import ARCH_IDS, get_config
from repro.models.model import Model
from repro.serving.engine import Request, ServeEngine

logging.basicConfig(level=logging.INFO, format="%(message)s")
log = logging.getLogger("repro.launch.serve")


def _run_tiny(args):
    """Compile one tiny model, spread it over ``--replicas`` pool slots
    (one physical CPU device — the pool is logical, the dispatch overlap
    real via JAX async dispatch), and drive a Poisson trace through the
    router under the chosen engine."""
    from repro.core.qir import export_qmlp
    from repro.deploy import compile_graph
    from repro.deploy.autotune import autotune_model
    from repro.models.tiny import ADAutoencoder, KWSMLP
    from repro.serve import (
        AsyncEngine,
        ReplicaPool,
        Router,
        RouterConfig,
        ServiceModel,
        SyncEngine,
        measure_wave_service_s,
        poisson_trace,
    )

    in_scale = 1.0 / 127.0
    model, dim = ((KWSMLP(), 490) if args.tiny_model == "kws"
                  else (ADAutoencoder(), 128))
    params = model.init(jax.random.PRNGKey(0))
    hidden_defs, _ = model.layers()
    graph = export_qmlp(hidden_defs, params["hidden"], params["head"],
                        meta={"model": type(model).__name__},
                        freeze_scales=True, in_scale=in_scale)
    cm = compile_graph(graph, in_scale=in_scale, use_pallas=False)
    if args.autotune != "off":
        cm.apply_tuned(autotune_model(cm, batch=32, mode=args.autotune))
    mb = cm.default_micro_batch
    if args.autotune == "model":
        # cold-start path: no wall-clock reads before the first request —
        # the learned predictor prices admission from wave 0 and the
        # router's EWMA corrects it online (docs/costmodel.md)
        from repro.costmodel import load_default
        from repro.serve import PredictedServiceModel

        service = PredictedServiceModel.from_predictor(load_default(), cm)
    else:
        service = ServiceModel.from_compiled(
            cm, probe_batch=mb).recalibrated(
                measure_wave_service_s(cm, mb), mb)
    engine = AsyncEngine() if args.engine == "async" else SyncEngine()

    # every replica slot shares the one compiled executor: submit_wave is
    # stateless, so N slots = N logical devices on the single CPU
    pool = ReplicaPool(factory=lambda: cm, devices=[None] * args.replicas)
    router = Router({args.tiny_model: pool},
                    RouterConfig(micro_batch=mb),
                    service_models={args.tiny_model: service},
                    engine=engine)
    rng = np.random.default_rng(args.seed)
    qps = args.qps or 0.5 * args.replicas * service.saturation_qps(mb)
    trace = poisson_trace(qps=qps, n=args.requests, seed=args.seed)
    t0 = obs_timer.now()
    reqs = router.run_trace(
        args.tiny_model, trace,
        lambda i: rng.integers(-127, 128, (dim,)).astype(np.int32))
    dt = obs_timer.now() - t0
    served = [r for r in reqs if not r.shed]
    lats_ms = np.asarray([r.latency_s for r in served]) * 1e3
    snap = router.stats()[args.tiny_model]["metrics"]
    log.info("tiny stack: %s x%d replicas, %s engine, wave=%d",
             args.tiny_model, args.replicas, args.engine, mb)
    log.info("offered %.0f qps | served %d/%d in %.2fs (%.0f qps)",
             qps, len(served), len(reqs), dt, len(served) / max(dt, 1e-9))
    log.info("p50 %.2f ms | p99 %.2f ms | wave p50 %.2f ms | occupancy %.2f",
             float(np.percentile(lats_ms, 50)),
             float(np.percentile(lats_ms, 99)),
             snap.wave_service_p50_ms, snap.mean_occupancy)
    return {"served": len(served), "n": len(reqs),
            "p99_ms": float(np.percentile(lats_ms, 99)),
            "throughput_qps": len(served) / max(dt, 1e-9)}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--stack", choices=("lm", "tiny"), default="lm",
                    help="lm: continuous-batching ServeEngine; tiny: "
                         "compiled Table-1 model through the serve router")
    ap.add_argument("--tiny-model", choices=("kws", "ad"), default="kws")
    ap.add_argument("--autotune", choices=("off", "probe", "model"),
                    default="probe",
                    help="tiny stack tuning: probe = measured search, "
                         "model = probe-free learned cost model (cold-"
                         "start admission priced by the predictor), "
                         "off = compiled defaults")
    ap.add_argument("--replicas", type=int, default=1)
    ap.add_argument("--engine", choices=("sync", "async"), default="sync")
    ap.add_argument("--qps", type=float, default=0.0,
                    help="offered load; 0 = half the pool's saturation")
    ap.add_argument("--arch", choices=ARCH_IDS, default="internlm2-1.8b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--quant-bits", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.stack == "tiny":
        return _run_tiny(args)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.encoder_only:
        raise SystemExit(f"{args.arch} is encoder-only: no decode path")

    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if args.quant_bits < 16:
        params = model.quantize_params(params, bits=args.quant_bits)
        log.info("deployment quantization: int%d weights", args.quant_bits)

    eng = ServeEngine(model, params, n_slots=args.slots, max_len=args.max_len)
    rng = np.random.default_rng(args.seed)
    t0 = obs_timer.now()
    for i in range(args.requests):
        plen = int(rng.integers(3, 12))
        eng.submit(Request(
            uid=i, prompt=rng.integers(0, cfg.vocab, plen).astype(np.int32),
            max_new_tokens=args.max_new))
    steps = eng.run_until_drained()
    dt = obs_timer.now() - t0

    s = eng.stats()
    log.info("drained %d requests in %d steps / %.2fs", s["n_requests"],
             steps, dt)
    log.info("TTFT %.1f ms | latency %.1f ms | %.1f tok/s",
             s["mean_ttft_s"] * 1e3, s["mean_latency_s"] * 1e3,
             s["throughput_tok_s"])
    return s


if __name__ == "__main__":
    main()
