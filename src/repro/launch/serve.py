"""Serving launcher: continuous-batching engine with optional int8 deployment
quantization — the paper's streamlined-deployment path for the LM archs.

    PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b \
        --reduced --requests 16 --quant-bits 8
"""

import argparse
import logging

import numpy as np

import jax

from repro.obs import timer as obs_timer

from repro.configs import ARCH_IDS, get_config
from repro.models.model import Model
from repro.serving.engine import Request, ServeEngine

logging.basicConfig(level=logging.INFO, format="%(message)s")
log = logging.getLogger("repro.launch.serve")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="internlm2-1.8b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--quant-bits", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.encoder_only:
        raise SystemExit(f"{args.arch} is encoder-only: no decode path")

    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if args.quant_bits < 16:
        params = model.quantize_params(params, bits=args.quant_bits)
        log.info("deployment quantization: int%d weights", args.quant_bits)

    eng = ServeEngine(model, params, n_slots=args.slots, max_len=args.max_len)
    rng = np.random.default_rng(args.seed)
    t0 = obs_timer.now()
    for i in range(args.requests):
        plen = int(rng.integers(3, 12))
        eng.submit(Request(
            uid=i, prompt=rng.integers(0, cfg.vocab, plen).astype(np.int32),
            max_new_tokens=args.max_new))
    steps = eng.run_until_drained()
    dt = obs_timer.now() - t0

    s = eng.stats()
    log.info("drained %d requests in %d steps / %.2fs", s["n_requests"],
             steps, dt)
    log.info("TTFT %.1f ms | latency %.1f ms | %.1f tok/s",
             s["mean_ttft_s"] * 1e3, s["mean_latency_s"] * 1e3,
             s["throughput_tok_s"])
    return s


if __name__ == "__main__":
    main()
