"""Roofline analysis over dry-run artifacts (EXPERIMENTS.md §Roofline).

For every (arch x shape x mesh) JSON produced by launch/dryrun.py this
derives the three roofline terms on TPU v5e constants:

    compute term    = HLO_FLOPs / (chips x 197 TFLOP/s bf16)
    memory term     = HLO_bytes / (chips x 819 GB/s HBM)
    collective term = collective_bytes / (chips x 50 GB/s ICI per link)

HLO_FLOPs comes from the loop-aware static HLO analysis (per device,
already divided by chip count by SPMD partitioning); memory bytes use XLA's
bytes-accessed where available, with a floor of (params + args + outputs)
per device; collective bytes are summed per device from the partitioned HLO.

MODEL_FLOPS = 6*N_active*D (train) / 2*N_active*D (inference) gives the
"useful fraction" ratio that catches remat/redundancy waste.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from dataclasses import dataclass
from typing import Dict, List, Optional

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import SHAPES
from repro.core.bops import lm_model_flops

# TPU v5e hardware constants (per chip)
PEAK_BF16 = 197e12          # FLOP/s
PEAK_INT8 = 394e12          # OP/s — the W8A8 streamlined path runs here
HBM_BW = 819e9              # B/s
ICI_BW = 50e9               # B/s per link (≈2 links usable per collective step)


@dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    tag: str
    status: str
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0
    dominant: str = ""
    model_flops: float = 0.0
    hlo_flops_per_dev: float = 0.0
    useful_ratio: float = 0.0
    roofline_fraction: float = 0.0
    reason: str = ""

    @property
    def t_total_overlap(self) -> float:
        """Lower-bound step time if compute/memory/collectives fully overlap."""
        return max(self.t_compute, self.t_memory, self.t_collective)


def load_artifacts(dirname: str = "artifacts/dryrun") -> List[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def analyze(rec: dict) -> RooflineRow:
    row = RooflineRow(arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
                      tag=rec.get("tag", "baseline"), status=rec["status"])
    if rec["status"] != "ok":
        row.reason = rec.get("reason", rec.get("error", ""))
        return row

    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    n_dev = rec["n_devices"]

    # --- compute term (per-device HLO flops from loop-aware analysis) -----
    # int8-quantized cells run the MXU at its int8 peak (the paper's
    # narrowest-native-width principle)
    peak = PEAK_INT8 if rec.get("quant_bits", 16) <= 8 else PEAK_BF16
    flops_dev = rec["hlo_flops_per_device"]
    row.hlo_flops_per_dev = flops_dev
    row.t_compute = flops_dev / peak

    # --- memory term -------------------------------------------------------
    # xla bytes_accessed counts loop bodies once; floor with the working set
    # that must stream at least once per step: params + opt state + args/outs
    xla_bytes = max(rec["xla_cost_analysis"].get("bytes_accessed", 0.0), 0.0)
    mem = rec.get("memory_analysis", {})
    working_set = (rec.get("state_local_bytes", 0)
                   + rec.get("cache_local_bytes", 0)
                   + mem.get("output_size_in_bytes", 0) / max(n_dev, 1))
    bytes_dev = max(xla_bytes / max(n_dev, 1), working_set)
    row.t_memory = bytes_dev / HBM_BW

    # --- collective term ----------------------------------------------------
    coll_dev = rec["collective_bytes_per_device"]
    row.t_collective = coll_dev / ICI_BW

    terms = {"compute": row.t_compute, "memory": row.t_memory,
             "collective": row.t_collective}
    row.dominant = max(terms, key=terms.get)

    # --- useful-FLOPs ratio -------------------------------------------------
    if shape.kind == "train":
        n_tokens = shape.global_batch * shape.seq_len
        row.model_flops = lm_model_flops(cfg.n_active_params(), n_tokens, True)
    elif shape.kind == "prefill":
        n_tokens = shape.global_batch * shape.seq_len
        row.model_flops = lm_model_flops(cfg.n_active_params(), n_tokens, False)
    else:  # decode: one new token per sequence
        row.model_flops = lm_model_flops(cfg.n_active_params(),
                                         shape.global_batch, False)
    total_hlo = flops_dev * n_dev
    row.useful_ratio = row.model_flops / total_hlo if total_hlo else 0.0

    # roofline fraction: useful model FLOPs per second at the overlapped step
    # time, vs the peak of the whole slice
    t = row.t_total_overlap
    if t > 0:
        achieved = row.model_flops / t
        row.roofline_fraction = achieved / (n_dev * PEAK_BF16)
    return row


def render_table(rows: List[RooflineRow], mesh: str = "single",
                 tag: Optional[str] = "baseline") -> str:
    hdr = (f"{'arch':22s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s} "
           f"{'collect_s':>10s} {'bound':>10s} {'useful':>7s} {'roofline':>9s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        if r.mesh != mesh or (tag and r.tag != tag):
            continue
        if r.status == "skipped":
            lines.append(f"{r.arch:22s} {r.shape:12s} "
                         f"{'— skipped: ' + r.reason:s}")
            continue
        if r.status != "ok":
            lines.append(f"{r.arch:22s} {r.shape:12s} ERROR {r.reason[:60]}")
            continue
        lines.append(
            f"{r.arch:22s} {r.shape:12s} {r.t_compute:>10.4f} "
            f"{r.t_memory:>10.4f} {r.t_collective:>10.4f} {r.dominant:>10s} "
            f"{r.useful_ratio:>7.2f} {r.roofline_fraction:>8.1%}")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()

    rows = [analyze(rec) for rec in load_artifacts(args.dir)]
    print(render_table(rows, mesh=args.mesh, tag=args.tag))

    ok = [r for r in rows if r.status == "ok" and r.mesh == args.mesh
          and r.tag == args.tag]
    if ok:
        worst = min(ok, key=lambda r: r.roofline_fraction)
        coll = max(ok, key=lambda r: r.t_collective /
                   max(r.t_total_overlap, 1e-12))
        print(f"\nworst roofline fraction : {worst.arch} x {worst.shape} "
              f"({worst.roofline_fraction:.1%}, {worst.dominant}-bound)")
        print(f"most collective-bound   : {coll.arch} x {coll.shape} "
              f"(collective {coll.t_collective:.4f}s of "
              f"{coll.t_total_overlap:.4f}s)")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump([r.__dict__ for r in rows], f, indent=1)


if __name__ == "__main__":
    main()
