"""Flash attention Pallas kernel (GQA + causal + sliding window).

TPU adaptation of the paper's C4 insight — keep the hot loop's working set
on-chip: the (bq, bk) score tile, the online-softmax stats, and the output
accumulator all live in VMEM/VREGs across the KV sweep; only q/k/v block
streams and one final output write touch HBM. The (Sq x Sk) score matrix is
never materialized.

Grid: (B, H, Sq/bq, Sk/bk) with the KV dimension innermost (sequential —
the online-softmax carry lives in VMEM scratch). GQA is handled in the k/v
BlockSpec index_map: query head h reads kv head h // (H / Hkv), so no
k/v replication tensor is ever built.

Fully-masked (future) KV blocks are skipped with pl.when — for causal
attention that halves the executed grid, same FLOPs saving as the paper's
layer-merging removed stages.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  n_kv: int, block_q: int, block_k: int, scale: float,
                  causal: bool, window: int, q_offset: int, kv_len: int):
    i = pl.program_id(2)          # q block
    j = pl.program_id(3)          # kv block

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # block-level causal/window skip: any (qpos, kpos) pair valid?
    q_lo = i * block_q + q_offset
    q_hi = q_lo + block_q - 1
    k_lo = j * block_k
    k_hi = k_lo + block_k - 1
    live = k_lo < kv_len                      # padded KV blocks never run
    if causal:
        live = jnp.logical_and(live, k_lo <= q_hi)
    if window > 0:
        live = jnp.logical_and(live, k_hi > q_lo - window)

    @pl.when(live)
    def _update():
        q = q_ref[0, 0].astype(jnp.float32)              # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)              # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                        # (bq, bk)

        q_pos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_pos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        ok = k_pos < kv_len                   # mask padded keys exactly
        if causal:
            ok &= k_pos <= q_pos
        if window > 0:
            ok &= k_pos > q_pos - window
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_ref[:, :1]                            # (bq, 1)
        l_prev = l_ref[:, :1]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(j == n_kv - 1)
    def _finalize():
        l = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention(
    q: jnp.ndarray,               # (B, H, Sq, D)
    k: jnp.ndarray,               # (B, Hkv, Sk, D)
    v: jnp.ndarray,               # (B, Hkv, Sk, D)
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    kv_len: Optional[int] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Flash attention. Sq % block_q == 0, Sk % block_k == 0 (ops pads;
    ``kv_len`` masks the KV padding exactly).

    D should be lane-aligned (128) for MXU efficiency on real hardware."""
    B, H, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    kv_len = Sk if kv_len is None else kv_len
    assert H % Hkv == 0, (H, Hkv)
    G = H // Hkv
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0
    n_kv = Sk // block_k

    kernel = functools.partial(
        _flash_kernel, n_kv=n_kv, block_q=block_q, block_k=block_k,
        scale=D ** -0.5, causal=causal, window=window, q_offset=q_offset,
        kv_len=kv_len,
    )
    return pl.pallas_call(
        kernel,
        grid=(B, H, Sq // block_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 128), jnp.float32),   # running max
            pltpu.VMEM((block_q, 128), jnp.float32),   # running denom
            pltpu.VMEM((block_q, D), jnp.float32),     # output accumulator
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
