# Pallas TPU kernels for the compute hot-spots the paper optimizes:
#   qmatmul         - fused int8 dataflow stage (matmul->dequant->bias->ReLU
#                     ->requant), the merged-stage form of C2+C3
#   multi_threshold - FINN integer multi-threshold activation (C2), plus the
#                     fully fused threshold_matmul stage
#   conv_threshold  - fused direct-conv stage: implicit im2col (shifted-
#                     window tap accumulation) + in-register thresholds —
#                     the paper's streaming conv dataflow, no patch matrix
#   flash_attention - VMEM-resident online-softmax attention (C4's "keep the
#                     working set on chip" applied to the LM archs)
# ops.py holds the jit'd public wrappers (padding + CPU interpret fallback);
# ref.py the pure-jnp oracles every kernel is tested against.
