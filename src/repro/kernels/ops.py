"""Jit'd public wrappers around the Pallas kernels.

Handles padding to block multiples, CPU fallback (interpret mode when no TPU
is attached — the container case), and shape restoration. These are the
entry points models/benchmarks call; tests sweep them against ref.py.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import conv_threshold as _ct
from repro.kernels import flash_attention as _fa
from repro.kernels import megakernel as _mk
from repro.kernels import multi_threshold as _mt
from repro.kernels import qmatmul as _qm
from repro.kernels import ref


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:  # pragma: no cover
        return False


def _pad_to(x, mult, axis):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), n


@functools.partial(jax.jit, static_argnames=("relu", "out_scale", "block_m",
                                             "block_n", "block_k", "interpret"))
def qmatmul(x_int, w_int, scale, bias=None, *, relu=False,
            out_scale: Optional[float] = None, block_m=128, block_n=128,
            block_k=128, interpret: Optional[bool] = None):
    """Fused int8 matmul stage; auto-pads to block multiples."""
    interp = (not _on_tpu()) if interpret is None else interpret
    M0, K0 = x_int.shape
    N0 = w_int.shape[1]
    x_p, _ = _pad_to(x_int, block_m, 0)
    x_p, _ = _pad_to(x_p, block_k, 1)
    w_p, _ = _pad_to(w_int, block_k, 0)
    w_p, _ = _pad_to(w_p, block_n, 1)
    s_p, _ = _pad_to(jnp.reshape(scale, (-1,)).astype(jnp.float32), block_n, 0)
    b = (jnp.reshape(bias, (-1,)).astype(jnp.float32) if bias is not None
         else jnp.zeros((N0,), jnp.float32))
    b_p, _ = _pad_to(b, block_n, 0)
    y = _qm.qmatmul(x_p, w_p, s_p, b_p, relu=relu, out_scale=out_scale,
                    block_m=min(block_m, x_p.shape[0]),
                    block_n=min(block_n, w_p.shape[1]),
                    block_k=min(block_k, x_p.shape[1]),
                    interpret=interp)
    return y[:M0, :N0]


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def multi_threshold(acc, thresholds, *, block_m=256,
                    interpret: Optional[bool] = None):
    """Multi-threshold activation; auto-pads rows."""
    interp = (not _on_tpu()) if interpret is None else interpret
    M0 = acc.shape[0]
    bm = min(block_m, max(M0, 8))
    acc_p, _ = _pad_to(acc.astype(jnp.int32), bm, 0)
    y = _mt.multi_threshold(acc_p, thresholds.astype(jnp.int32),
                            block_m=bm, interpret=interp)
    return y[:M0]


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret"))
def threshold_matmul(x_int, w_int, thresholds, *, block_m=128, block_n=128,
                     block_k=128, interpret: Optional[bool] = None):
    """Fused integer dense stage (matmul + multi-threshold)."""
    interp = (not _on_tpu()) if interpret is None else interpret
    M0, K0 = x_int.shape
    N0 = w_int.shape[1]
    x_p, _ = _pad_to(x_int, block_m, 0)
    x_p, _ = _pad_to(x_p, block_k, 1)
    w_p, _ = _pad_to(w_int, block_k, 0)
    w_p, _ = _pad_to(w_p, block_n, 1)
    # padded output channels need thresholds too; pad with INT32_MAX so the
    # padded channels output 0 (never reached)
    t_p = thresholds.astype(jnp.int32)
    pad_n = (-N0) % block_n
    if pad_n:
        t_p = jnp.concatenate(
            [t_p, jnp.full((pad_n, t_p.shape[1]), jnp.iinfo(jnp.int32).max,
                           jnp.int32)], axis=0)
    y = _mt.threshold_matmul(x_p, w_p, t_p,
                             block_m=min(block_m, x_p.shape[0]),
                             block_n=min(block_n, w_p.shape[1]),
                             block_k=min(block_k, x_p.shape[1]),
                             interpret=interp)
    return y[:M0, :N0]


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def mlp_megakernel(x_int, weights, banks, *, block_m=128,
                   interpret: Optional[bool] = None):
    """Whole-MLP-segment megakernel (all stages in one Pallas program).

    ``weights``/``banks`` are the per-stage ``ThresholdDense`` artifacts in
    schedule order (tuples, so jit treats them as a pytree of operands).
    Auto-pads the wave rows to the row block; padded rows are inert (their
    codes are discarded). The whole chain runs on-chip: weights and banks
    resident in VMEM, inter-stage activations in scratch tiles — see
    ``kernels.megakernel`` and ``docs/megakernel.md``.
    """
    interp = (not _on_tpu()) if interpret is None else interpret
    M0 = x_int.shape[0]
    bm = min(block_m, max(M0, 8))
    x_p, _ = _pad_to(x_int.astype(jnp.int32), bm, 0)
    y = _mk.mlp_megakernel(x_p, tuple(weights), tuple(banks),
                           block_m=bm, interpret=interp)
    return y[:M0]


def plan_conv_blocks(out_h: int, out_w: int, out_ch: int,
                     target_rows: int = 256,
                     acc_budget_bytes: int = 1 << 21) -> int:
    """Pick the output-row block for the fused direct-conv kernel.

    Autotuned from the *output tile* shape: enough rows that each program's
    flattened matmul M dimension (``block_h * out_w``) approaches
    ``target_rows`` (keeps the MXU busy), capped so the int32 accumulator
    block (``block_h * out_w * out_ch * 4`` bytes) stays inside a VMEM
    budget. Always at least 1 row; never more than ``out_h``.
    """
    block_h = max(1, min(out_h, target_rows // max(out_w, 1)))
    while (block_h > 1
           and block_h * out_w * max(out_ch, 1) * 4 > acc_budget_bytes):
        block_h -= 1
    return block_h


@functools.partial(jax.jit, static_argnames=("kernel", "stride", "padding",
                                             "out_h", "out_w", "block_h",
                                             "interpret"))
def conv_threshold(x_int, w2d, thresholds, *, kernel: int, stride: int,
                   padding: str, out_h: int, out_w: int,
                   block_h: Optional[int] = None,
                   interpret: Optional[bool] = None):
    """Fused direct-conv integer stage: NHWC codes -> threshold codes.

    Implicit im2col inside the Pallas kernel (shifted-window tap
    accumulation; see ``kernels.conv_threshold``) — the (OH*OW, K*K*C) patch
    matrix is never materialized. Handles SAME/VALID zero padding on the
    host (exact on integer codes whenever code 0 means value 0, the export
    contract), pads output rows so the row-block grid divides, and restores
    the unpadded shape. ``w2d`` is the (kh*kw*cin, cout) im2col weight
    matrix, ``thresholds`` the (cout, S) bank — the same stage artifact the
    im2col lowering feeds ``threshold_matmul``.
    """
    interp = (not _on_tpu()) if interpret is None else interpret
    n, h, w, c = x_int.shape
    if padding == "SAME":
        pad_h, pad_w = _ct.same_pads(h, w, out_h, out_w, stride, kernel)
        pads = ((0, 0), pad_h, pad_w, (0, 0))
    else:
        pads = ((0, 0), (0, 0), (0, 0), (0, 0))
    bh = plan_conv_blocks(out_h, out_w, w2d.shape[1]) \
        if block_h is None else min(block_h, out_h)
    oh_pad = -(-out_h // bh) * bh
    # extra zero rows so the padded grid's last block stays in bounds
    extra = ((oh_pad - 1) * stride + kernel) - (h + pads[1][0] + pads[1][1])
    if extra > 0:
        pads = (pads[0], (pads[1][0], pads[1][1] + extra), pads[2], pads[3])
    x_p = jnp.pad(x_int.astype(jnp.int32), pads)
    y = _ct.conv_threshold(x_p, w2d, thresholds, kernel=kernel,
                           stride=stride, out_h=oh_pad, out_w=out_w,
                           block_h=bh, interpret=interp)
    return y[:, :out_h]


@functools.partial(jax.jit, static_argnames=("causal", "window", "q_offset",
                                             "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                    block_q=128, block_k=128,
                    interpret: Optional[bool] = None):
    """Flash attention over (B, H, S, D) layout; pads S to block multiples.
    Padded KV rows are masked exactly inside the kernel via ``kv_len``."""
    interp = (not _on_tpu()) if interpret is None else interpret
    B, H, Sq0, D = q.shape
    Sk0 = k.shape[2]
    bq = min(block_q, max(Sq0, 8))
    bk = min(block_k, max(Sk0, 8))
    q_p, _ = _pad_to(q, bq, 2)
    k_p, _ = _pad_to(k, bk, 2)
    v_p, _ = _pad_to(v, bk, 2)
    out = _fa.flash_attention(q_p, k_p, v_p, causal=causal, window=window,
                              q_offset=q_offset, kv_len=Sk0,
                              block_q=bq, block_k=bk, interpret=interp)
    return out[:, :, :Sq0]


# re-export oracles for convenience
mlp_megakernel_ref = _mk.mlp_megakernel_ref
qmatmul_ref = ref.qmatmul_ref
multi_threshold_ref = ref.multi_threshold_ref
threshold_matmul_ref = ref.threshold_matmul_ref
flash_attention_ref = ref.flash_attention_ref
