"""Multi-threshold activation kernel (FINN streamlining, paper C2) and the
fully fused integer stage: int8 matmul -> int32 accum -> multi-threshold.

The multi-threshold op is the deployed form of (dequant -> BN -> ReLU ->
requant): for act_bits output bits it compares the integer accumulator
against S = 2^bits - 1 per-channel thresholds and outputs the count — a pure
integer op (no float anywhere), executed on the VPU with the thresholds
resident in VMEM.

Threshold layout: (C, S) is transposed to (S, C) before the kernel so the
channel axis is the 128-lane minor axis — each of the S compare steps is a
full-width (bm, C) vector op, and S (= 7 for 3-bit KWS, 255 worst-case) is
the sequential loop.

Deep banks (S >= ``DOUBLE_BUFFER_STEPS``) stream in slabs instead of
pinning the whole (S, C) bank per program: the slab rides a second
(sequential) grid dimension, so the Pallas pipeline's revolving block
buffers prefetch the next slab's DMA behind the current slab's compare
loop — the same grid-pipeline double-buffering the direct-conv kernel uses
for its input bands — and only two slabs ever occupy VMEM. Banks are
padded to a slab multiple with INT32_MAX rows (never reached by any
accumulator inside the 2^24 exactness bound, the same trick
``ops.threshold_matmul`` uses for padded channels), so the count is exact.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams


#: Banks at least this deep stream in double-buffered slabs instead of
#: riding whole in VMEM (carried-over ROADMAP item: S >= 256).
DOUBLE_BUFFER_STEPS = 256

#: Slab height for the streamed bank (rows of the (S, C) transposed bank
#: per grid step; multiple of the 8-row f32/int32 sublane tile).
BANK_SLAB = 64


def _mt_kernel(acc_ref, thr_ref, o_ref, *, n_steps: int):
    acc = acc_ref[...]                       # (bm, C) int32
    out = jnp.zeros_like(acc)

    def body(s, out):
        t = jax.lax.dynamic_slice_in_dim(thr_ref[...], s, 1, axis=0)  # (1, C)
        return out + (acc >= t).astype(jnp.int32)

    o_ref[...] = jax.lax.fori_loop(0, n_steps, body, out)


def _mt_slab_kernel(acc_ref, thr_ref, o_ref, *, slab: int):
    """One bank slab's compares, accumulated into the revisited out block.

    The slab grid dimension is sequential and the out block's index does
    not depend on it, so the output stays resident across slab steps while
    the pipeline prefetches slab s+1 behind slab s's compare loop.
    """
    s = pl.program_id(1)

    @pl.when(s == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    acc = acc_ref[...]                       # (bm, C) int32

    def body(i, out):
        t = jax.lax.dynamic_slice_in_dim(thr_ref[...], i, 1, axis=0)  # (1, C)
        return out + (acc >= t).astype(jnp.int32)

    o_ref[...] = jax.lax.fori_loop(0, slab, body, o_ref[...])


def multi_threshold(acc: jnp.ndarray, thresholds: jnp.ndarray, *,
                    block_m: int = 256, interpret: bool = False) -> jnp.ndarray:
    """acc (M, C) int32, thresholds (C, S) int32 -> (M, C) int32 in [0, S].

    M must divide block_m (ops.multi_threshold pads); C rides whole in VMEM
    (tiny-model channel counts: 12-512). Banks with S < DOUBLE_BUFFER_STEPS
    ride whole too; deeper banks stream in double-buffered BANK_SLAB slabs
    (module docstring)."""
    M, C = acc.shape
    S = thresholds.shape[1]
    assert thresholds.shape[0] == C
    block_m = min(block_m, M)
    assert M % block_m == 0, (M, block_m)
    thr_t = thresholds.T.astype(jnp.int32)   # (S, C): lanes = channels

    if S >= DOUBLE_BUFFER_STEPS:
        pad = (-S) % BANK_SLAB
        if pad:
            # INT32_MAX rows count nothing: no in-bound accumulator reaches
            # them (same padding contract as ops.threshold_matmul channels)
            thr_t = jnp.concatenate(
                [thr_t, jnp.full((pad, C), jnp.iinfo(jnp.int32).max,
                                 jnp.int32)], axis=0)
        n_slabs = thr_t.shape[0] // BANK_SLAB
        return pl.pallas_call(
            functools.partial(_mt_slab_kernel, slab=BANK_SLAB),
            grid=(M // block_m, n_slabs),
            in_specs=[
                pl.BlockSpec((block_m, C), lambda i, s: (i, 0)),
                pl.BlockSpec((BANK_SLAB, C), lambda i, s: (s, 0)),
            ],
            out_specs=pl.BlockSpec((block_m, C), lambda i, s: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((M, C), jnp.int32),
            compiler_params=_CompilerParams(
                # slab dim sequential: the revolving buffers double-buffer
                # the next slab fetch behind the current compare loop
                dimension_semantics=("parallel", "arbitrary"),
            ),
            interpret=interpret,
        )(acc, thr_t)

    return pl.pallas_call(
        functools.partial(_mt_kernel, n_steps=S),
        grid=(M // block_m,),
        in_specs=[
            pl.BlockSpec((block_m, C), lambda i: (i, 0)),
            pl.BlockSpec((S, C), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, C), jnp.int32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(acc, thr_t)


def _tmm_kernel(x_ref, w_ref, thr_ref, o_ref, acc_ref, *, n_k: int, n_steps: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(k == n_k - 1)
    def _threshold():
        acc = acc_ref[...]
        out = jnp.zeros_like(acc)

        def body(s, out):
            t = jax.lax.dynamic_slice_in_dim(thr_ref[...], s, 1, axis=0)
            return out + (acc >= t).astype(jnp.int32)

        o_ref[...] = jax.lax.fori_loop(0, n_steps, body, out)


def threshold_matmul(
    x_int: jnp.ndarray,            # (M, K) int8/int32
    w_int: jnp.ndarray,            # (K, N) int8
    thresholds: jnp.ndarray,       # (N, S) int32
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """One whole streamlined dense stage in a single kernel: the int32
    accumulator never leaves VMEM between the matmul and the activation."""
    M, K = x_int.shape
    K2, N = w_int.shape
    S = thresholds.shape[1]
    assert K == K2 and thresholds.shape[0] == N
    assert M % block_m == 0 and N % block_n == 0 and K % block_k == 0
    n_k = K // block_k
    thr_t = thresholds.T.astype(jnp.int32)   # (S, N)

    return pl.pallas_call(
        functools.partial(_tmm_kernel, n_k=n_k, n_steps=S),
        grid=(M // block_m, N // block_n, n_k),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
            pl.BlockSpec((S, block_n), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.int32),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x_int.astype(jnp.int8) if x_int.dtype == jnp.int8 else x_int.astype(jnp.int32),
      w_int, thr_t)
