"""Whole-network-resident megakernel: an entire MLP segment in ONE kernel.

The paper's FPGA dataflow architectures (and the FINN/hls4ml designs they
build on) win latency because *every* layer is on-fabric simultaneously —
weights resident, activations flowing layer to layer through on-chip FIFOs,
zero per-layer program dispatch. This kernel is the software analogue for
the KWS/AD-class MLP schedules, whose weights and threshold banks total
well under VMEM:

  * every stage's weight matrix and threshold bank is fetched ONCE per wave
    (constant block-index maps over a sequential grid — the Pallas pipeline
    never refetches a block whose index is unchanged) and stays resident
    in VMEM for all row blocks;
  * the inter-stage "FIFOs" are two revolving VMEM scratch tiles: each
    stage's int32 accumulator is thresholded into integer codes and written
    straight into the tile the next stage reads — activations never leave
    the chip between layers;
  * the grid iterates over the micro-batch wave's row blocks, so one
    ``pallas_call`` replaces the whole per-stage program sequence.

The per-stage path (``threshold_matmul`` / ``apply_fast``) stays as the
bit-exactness reference — integer accumulation and threshold counting are
order-free, so both paths produce identical integers (asserted on the
golden fixtures). The residency planner (``deploy.lower.plan_megakernel``)
decides when a segment fits; see ``docs/megakernel.md``.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams


def _count_thresholds(acc, thr_ref, n_steps: int):
    """Threshold count over one resident (S, N) bank slab: out = #(acc >= T)."""
    out = jnp.zeros_like(acc)

    def body(s, out):
        t = jax.lax.dynamic_slice_in_dim(thr_ref[...], s, 1, axis=0)  # (1, N)
        return out + (acc >= t).astype(jnp.int32)

    return jax.lax.fori_loop(0, n_steps, body, out)


def _mega_kernel(x_ref, *refs, n_stages: int, n_steps: Sequence[int],
                 out_dims: Sequence[int]):
    """One row block of the wave through ALL stages, entirely on-chip.

    ``refs`` layout (pallas_call order): the n_stages resident weight refs,
    the n_stages resident transposed-bank refs, the output ref, then the two
    revolving inter-stage FIFO tiles (absent when n_stages == 1).
    """
    w_refs = refs[:n_stages]
    t_refs = refs[n_stages:2 * n_stages]
    o_ref = refs[2 * n_stages]
    fifo = refs[2 * n_stages + 1:]

    h = x_ref[...].astype(jnp.int32)                    # (bm, K0)
    for d in range(n_stages):
        acc = jax.lax.dot_general(                      # int32 accumulator,
            h, w_refs[d][...].astype(jnp.int32),        # never leaves VMEM
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )
        codes = _count_thresholds(acc, t_refs[d], int(n_steps[d]))
        if d == n_stages - 1:
            o_ref[...] = codes
        else:
            buf = fifo[d % 2]                           # inter-stage FIFO tile
            buf[:, :out_dims[d]] = codes
            h = buf[:, :out_dims[d]]


def mlp_megakernel(x_int: jnp.ndarray,
                   weights: Sequence[jnp.ndarray],
                   banks: Sequence[jnp.ndarray], *,
                   block_m: int = 128,
                   interpret: bool = False) -> jnp.ndarray:
    """Run a whole FusedThresholdStage chain as one Pallas program.

    ``x_int`` is the flattened wave ``(M, K0)`` of int32 codes; ``weights``
    the per-stage ``(K_d, N_d)`` int8 matrices (``K_{d+1} == N_d``);
    ``banks`` the per-stage ``(N_d, S_d)`` int32 sorted threshold banks.
    Returns the LAST stage's ``(M, N_last)`` int32 codes; intermediate
    activations exist only in the kernel's VMEM scratch. M must divide
    ``block_m`` (``ops.mlp_megakernel`` pads).
    """
    assert len(weights) == len(banks) and weights
    M, K0 = x_int.shape
    n_stages = len(weights)
    assert M % block_m == 0, (M, block_m)
    dims = []
    k_prev = K0
    for w, b in zip(weights, banks):
        assert w.shape[0] == k_prev, (w.shape, k_prev)
        assert b.shape[0] == w.shape[1], (b.shape, w.shape)
        k_prev = int(w.shape[1])
        dims.append(k_prev)
    thr_t = [b.T.astype(jnp.int32) for b in banks]      # (S, N): lanes = chans

    # constant index maps: weights/banks are fetched once and stay resident
    # across the (sequential) row-block grid — the VMEM residency the
    # planner budgets for
    in_specs = [pl.BlockSpec((block_m, K0), lambda i: (i, 0))]
    for w in weights:
        in_specs.append(pl.BlockSpec(w.shape, lambda i: (0, 0)))
    for t in thr_t:
        in_specs.append(pl.BlockSpec(t.shape, lambda i: (0, 0)))

    scratch = []
    if n_stages > 1:
        fifo_width = max(dims[:-1])
        scratch = [pltpu.VMEM((block_m, fifo_width), jnp.int32),
                   pltpu.VMEM((block_m, fifo_width), jnp.int32)]

    return pl.pallas_call(
        functools.partial(_mega_kernel, n_stages=n_stages,
                          n_steps=tuple(int(t.shape[0]) for t in thr_t),
                          out_dims=tuple(dims)),
        grid=(M // block_m,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m, dims[-1]), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, dims[-1]), jnp.int32),
        scratch_shapes=scratch,
        compiler_params=_CompilerParams(
            # sequential grid: consecutive row blocks reuse the resident
            # weight/bank blocks instead of refetching them
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
    )(x_int.astype(jnp.int32), *weights, *thr_t)


def mlp_megakernel_ref(x_int, weights, banks) -> jnp.ndarray:
    """Pure-jnp oracle: the same chain, stage by stage (order-free ints)."""
    from repro.core.streamline import multi_threshold

    h = jnp.asarray(x_int, jnp.int32)
    for w, b in zip(weights, banks):
        acc = jnp.matmul(h, jnp.asarray(w, jnp.int32))
        h = multi_threshold(acc, jnp.asarray(b, jnp.int32))
    return h
