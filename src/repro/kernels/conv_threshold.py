"""Fused direct-conv + multi-threshold Pallas kernel (no materialized im2col).

The paper's FPGA dataflow convs never materialize an im2col matrix: line
buffers stream shifted input windows straight into the MAC array and the
activation happens before anything leaves the chip. This kernel is that
design on the TPU: for one NHWC input tile it performs *implicit* im2col —
a static K x K tap loop where every tap contributes one shifted-window
(rows, C) x (C, F) matmul into an int32 accumulator held in VMEM/registers —
then applies the per-channel multi-threshold activation in-register and
writes back only the integer output codes. Versus the im2col lowering
(``deploy.lower`` building the (OH*OW, K*K*C) patch matrix and feeding
``threshold_matmul``) this removes the O(K^2*C) memory blow-up per conv
stage entirely: HBM sees the input once, the weights once, and the output
once.

Weight layout is shared with the im2col path: ``w2d`` is the
(kh*kw*cin, cout) matrix of ``core.streamline.ThresholdDense`` with feature
order (kh, kw, c) row-major, so tap (kh, kw) owns the contiguous row block
``[(kh*K + kw)*C, (kh*K + kw + 1)*C)``. One stage artifact serves both
lowerings, which is what makes the bit-exactness tests cheap.

Grid: ``(N, OH_padded // block_h)`` — one program per sample per block of
output rows. The host wrapper (``kernels.ops.conv_threshold``) zero-pads the
input spatially (SAME padding plus bottom rows so the row-block grid
divides; zero padding is exact on integer codes whenever code 0 means value
0 — the export contract) and picks ``block_h`` from the output-tile shape.
Channels ride whole in VMEM like ``multi_threshold`` does — tiny-model
channel counts are 3..512.

**Line-buffer DMA:** the input block spec carries only the rows a row block
actually reads — ``(block_h - 1) * stride + kernel`` rows, halo included —
not the whole sample. The host wrapper restructures the padded input into
per-block row *bands* (``_row_bands``: band j = input rows
``[j * block_h * stride, j * block_h * stride + band_rows)``, overlapping
rows duplicated once), so the Pallas grid pipeline streams exactly one band
per program and its revolving block buffers double-buffer the fetch — the
next row block's band DMA overlaps the current block's tap matmuls, the TPU
analogue of the paper's line-buffer streaming. Before this the block spec
pinned the whole padded sample per program (index map ignored the row-block
index), so every row block refetched the full input.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._compat import CompilerParams as _CompilerParams


def same_pads(h: int, w: int, out_h: int, out_w: int, stride: int,
              kernel: int):
    """XLA/TF SAME zero-pad widths: ((low_h, high_h), (low_w, high_w)).

    Low side gets floor(pad/2). Single source of truth for every conv path
    (im2col, direct CPU, Pallas host wrapper) — the bit-exactness contract
    between the lowerings depends on identical pad splits.
    """
    ph = max((out_h - 1) * stride + kernel - h, 0)
    pw = max((out_w - 1) * stride + kernel - w, 0)
    return (ph // 2, ph - ph // 2), (pw // 2, pw - pw // 2)


def band_rows(block_h: int, stride: int, kernel: int) -> int:
    """Input rows one output-row block reads: body rows plus the halo the
    K x K taps reach past the block boundary. The single source of truth for
    the band layout shared by the kernel, the host wrapper, and the traffic
    model (``core.bops.conv_input_band_bytes``)."""
    return (block_h - 1) * stride + kernel


def _row_bands(x_pad: jnp.ndarray, block_h: int, stride: int,
               kernel: int, n_blocks: int) -> jnp.ndarray:
    """Restructure (N, HP, WP, C) into per-row-block bands
    (N, n_blocks, band_rows, WP, C): band j starts at input row
    ``j * block_h * stride`` and carries exactly the rows that output-row
    block j reads (halo included, duplicated across adjacent bands). This is
    what lets the Pallas block spec fetch only the needed rows per program.
    """
    rs = block_h * stride                          # rows consumed per block
    br = band_rows(block_h, stride, kernel)
    rows = jnp.arange(n_blocks)[:, None] * rs + jnp.arange(br)[None, :]
    return jnp.take(x_pad, rows, axis=1)           # (N, nb, br, WP, C)


def _conv_thr_kernel(x_ref, w_ref, thr_ref, o_ref, *, kernel: int,
                     stride: int, block_h: int, out_w: int, in_ch: int,
                     n_steps: int):
    """One (sample, output-row-block) program.

    x_ref:   (1, 1, band_rows, WP, C) int32 — only this block's input rows
             (halo included); the grid pipeline double-buffers the band
             fetch against the previous program's tap matmuls
    w_ref:   (K*K*C, F)     int   — shared im2col weight layout
    thr_ref: (S, F)         int32 — threshold bank, steps-major
    o_ref:   (1, block_h, OW, F)  int32 output codes
    """
    x = x_ref[0, 0]                                # (band_rows, WP, C)
    rh = (block_h - 1) * stride + 1                # input rows per tap slice
    rw = (out_w - 1) * stride + 1
    acc = jnp.zeros((block_h * out_w, w_ref.shape[1]), jnp.int32)
    for kh in range(kernel):                       # static K x K tap loop
        for kw in range(kernel):
            # band-local rows: all-static shifted-window slice + decimation
            xs = x[kh:kh + rh:stride, kw:kw + rw:stride, :]
            tap = (kh * kernel + kw) * in_ch
            w_tap = w_ref[tap:tap + in_ch, :].astype(jnp.int32)
            acc += jax.lax.dot_general(
                xs.reshape(block_h * out_w, in_ch), w_tap,
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32,
            )

    out = jnp.zeros_like(acc)

    def body(s, out):
        t = jax.lax.dynamic_slice_in_dim(thr_ref[...], s, 1, axis=0)  # (1, F)
        return out + (acc >= t).astype(jnp.int32)

    out = jax.lax.fori_loop(0, n_steps, body, out)
    o_ref[0] = out.reshape(block_h, out_w, w_ref.shape[1])


def conv_threshold(
    x_pad: jnp.ndarray,            # (N, HP, WP, C) int32, already zero-padded
    w2d: jnp.ndarray,              # (K*K*C, F) int8/int32, (kh, kw, c)-major
    thresholds: jnp.ndarray,       # (F, S) int32, sorted along S
    *,
    kernel: int,
    stride: int,
    out_h: int,                    # unpadded output rows wanted
    out_w: int,
    block_h: int,
    interpret: bool = False,
) -> jnp.ndarray:
    """One whole streamlined conv stage in a single kernel.

    Requires ``out_h % block_h == 0`` and the input padded tall enough for
    the last row block: ``HP >= (out_h - 1) * stride + kernel`` (the host
    wrapper guarantees both). The input is restructured into per-row-block
    bands so every grid program fetches only the ``band_rows`` input rows it
    reads (halo included) — the Pallas pipeline then double-buffers the next
    band's fetch behind the current block's tap matmuls, instead of pinning
    the whole padded sample per program. Returns (N, out_h, out_w, F) int32
    codes.
    """
    n, hp, wp, c = x_pad.shape
    f = w2d.shape[1]
    s = thresholds.shape[1]
    assert w2d.shape[0] == kernel * kernel * c, (w2d.shape, kernel, c)
    assert thresholds.shape[0] == f
    assert out_h % block_h == 0, (out_h, block_h)
    assert hp >= (out_h - 1) * stride + kernel, (hp, out_h, stride, kernel)
    assert wp >= (out_w - 1) * stride + kernel, (wp, out_w, stride, kernel)
    thr_t = thresholds.T.astype(jnp.int32)         # (S, F): lanes = channels
    n_blocks = out_h // block_h
    br = band_rows(block_h, stride, kernel)
    x_band = _row_bands(x_pad.astype(jnp.int32), block_h, stride, kernel,
                        n_blocks)                  # (N, nb, br, WP, C)

    return pl.pallas_call(
        functools.partial(
            _conv_thr_kernel, kernel=kernel, stride=stride, block_h=block_h,
            out_w=out_w, in_ch=c, n_steps=s),
        grid=(n, n_blocks),
        in_specs=[
            pl.BlockSpec((1, 1, br, wp, c), lambda i, j: (i, j, 0, 0, 0)),
            pl.BlockSpec((kernel * kernel * c, f), lambda i, j: (0, 0)),
            pl.BlockSpec((s, f), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_h, out_w, f),
                               lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, out_h, out_w, f), jnp.int32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x_band, w2d, thr_t)


def direct_conv_acc(x_pad: jnp.ndarray, w2d: jnp.ndarray, *, kernel: int,
                    stride: int, out_h: int, out_w: int,
                    as_float: bool = False) -> jnp.ndarray:
    """The kernel's accumulator as plain jnp — shifted-window tap sums, no
    materialized patch matrix. CPU/XLA fast path and the oracle the Pallas
    kernel is tested against.

    With ``as_float`` the taps accumulate in float32 (exact for integer
    values while partial sums stay below 2^24 — the ``_float_mm_safe``
    bound), which takes the SGEMM path on CPU. Returns (N, out_h, out_w, F)
    int32.
    """
    n, hp, wp, c = x_pad.shape
    rh = (out_h - 1) * stride + 1
    rw = (out_w - 1) * stride + 1
    dt = jnp.float32 if as_float else jnp.int32
    x = x_pad.astype(dt)
    acc = jnp.zeros((n, out_h, out_w, w2d.shape[1]), dt)
    for kh in range(kernel):
        for kw in range(kernel):
            xs = x[:, kh:kh + rh:stride, kw:kw + rw:stride, :]
            tap = (kh * kernel + kw) * c
            acc = acc + xs @ w2d[tap:tap + c, :].astype(dt)
    return acc.astype(jnp.int32)
