"""Version shims for the Pallas TPU API, shared by every kernel module."""

from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across 0.4.x/0.5.x;
# accept both so the kernels load on either side of the rename.
CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
