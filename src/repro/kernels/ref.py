"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth the kernels are tested against
(tests/test_kernels.py sweeps shapes/dtypes and assert_allclose's). They are
also the portable fallbacks used on CPU.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def qmatmul_ref(
    x_int: jnp.ndarray,          # (M, K) int8
    w_int: jnp.ndarray,          # (K, N) int8
    scale: jnp.ndarray,          # (N,) or (1, N) f32 — s_x * s_w per out channel
    bias: Optional[jnp.ndarray] = None,   # (N,) f32
    *,
    relu: bool = False,
    out_scale: Optional[float] = None,    # requant: y_int8 = round(y / out_scale)
) -> jnp.ndarray:
    """The fused streamlined dataflow stage (paper C2+C3 merged):

        int8 matmul -> int32 accum -> per-channel dequant -> +bias -> ReLU
        -> (optional) requant to int8.

    Returns f32 (out_scale=None) or int8.
    """
    acc = jax.lax.dot_general(
        x_int.astype(jnp.int32), w_int.astype(jnp.int32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    y = acc.astype(jnp.float32) * jnp.reshape(scale, (1, -1))
    if bias is not None:
        y = y + jnp.reshape(bias, (1, -1))
    if relu:
        y = jnp.maximum(y, 0.0)
    if out_scale is None:
        return y
    q = jnp.round(y / out_scale)
    return jnp.clip(q, -128, 127).astype(jnp.int8)


def multi_threshold_ref(acc: jnp.ndarray, thresholds: jnp.ndarray) -> jnp.ndarray:
    """FINN multi-threshold: out[m, c] = #{ i : acc[m, c] >= T[c, i] }.

    acc: (M, C) int32; thresholds: (C, S) int32 (sorted along S).
    Output (M, C) int32 in [0, S].
    """
    return jnp.sum(
        acc[:, :, None] >= thresholds[None, :, :], axis=-1
    ).astype(jnp.int32)


def threshold_matmul_ref(x_int, w_int, thresholds) -> jnp.ndarray:
    """Fused integer stage: int8 matmul -> multi-threshold activation.

    x_int (M, K) int8/int32, w_int (K, N) int8, thresholds (N, S) int32.
    """
    acc = jax.lax.dot_general(
        x_int.astype(jnp.int32), w_int.astype(jnp.int32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    return multi_threshold_ref(acc, thresholds)


def flash_attention_ref(
    q: jnp.ndarray,              # (B, H, Sq, D)
    k: jnp.ndarray,              # (B, Hkv, Sk, D)
    v: jnp.ndarray,              # (B, Hkv, Sk, D)
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,           # absolute position of q[0] (prefill chunks)
) -> jnp.ndarray:
    """Dense-softmax oracle with GQA, causal and sliding-window masks."""
    B, H, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, Sq, D).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qg, kf) * (D ** -0.5)
    q_pos = jnp.arange(Sq) + q_offset
    k_pos = jnp.arange(Sk)
    ok = jnp.ones((Sq, Sk), bool)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        ok &= k_pos[None, :] > q_pos[:, None] - window
    s = jnp.where(ok[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(jnp.float32))
    return out.reshape(B, H, Sq, D).astype(q.dtype)
