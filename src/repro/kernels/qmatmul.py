"""Fused int8 dataflow-stage kernel: matmul -> dequant -> bias -> ReLU -> requant.

This is the TPU form of the paper's merged dataflow stage (DESIGN.md C3):
on the FPGA one pipeline stage computes the quantized matmul, folded-BN
affine, and merged ReLU back-to-back without leaving the fabric; here one
Pallas kernel keeps the int32 accumulator in VMEM scratch across the K loop
and applies the epilogue in-register before a single write to HBM — the
activation tensor never round-trips at float width.

Reuse factor (paper C6): ``n_k = K // block_k`` is the number of times each
output tile's multiplier path is revisited. block_k = K (RF=1) maximizes
parallel use of the MXU at max VMEM footprint; smaller block_k trades
latency for working set, exactly the FPGA RF trade.

Grid: (M/bm, N/bn, K/bk), K innermost ("arbitrary" — sequential accumulate);
M/N parallel. All block dims MXU-aligned (multiples of 128 for f32/int8 lanes;
int8 sublane packing prefers bm % 32 == 0).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams


def _qmatmul_kernel(x_ref, w_ref, scale_ref, bias_ref, o_ref, acc_ref, *,
                    n_k: int, relu: bool, out_scale: Optional[float]):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(k == n_k - 1)
    def _epilogue():
        y = acc_ref[...].astype(jnp.float32) * scale_ref[...]      # (bm,bn)*(1,bn)
        y = y + bias_ref[...]
        if relu:
            y = jnp.maximum(y, 0.0)
        if out_scale is None:
            o_ref[...] = y.astype(o_ref.dtype)
        else:
            q = jnp.round(y * (1.0 / out_scale))
            o_ref[...] = jnp.clip(q, -128, 127).astype(jnp.int8)


def qmatmul(
    x_int: jnp.ndarray,            # (M, K) int8
    w_int: jnp.ndarray,            # (K, N) int8
    scale: jnp.ndarray,            # (N,) f32 per-out-channel dequant scale
    bias: Optional[jnp.ndarray] = None,
    *,
    relu: bool = False,
    out_scale: Optional[float] = None,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Fused quantized matmul. Shapes must be divisible by the block sizes
    (ops.qmatmul pads). Returns (M, N) f32, or int8 when out_scale is set."""
    M, K = x_int.shape
    K2, N = w_int.shape
    assert K == K2, (K, K2)
    assert M % block_m == 0 and N % block_n == 0 and K % block_k == 0, (
        (M, N, K), (block_m, block_n, block_k))
    n_k = K // block_k
    scale2d = jnp.reshape(scale.astype(jnp.float32), (1, N))
    bias2d = (jnp.reshape(bias.astype(jnp.float32), (1, N)) if bias is not None
              else jnp.zeros((1, N), jnp.float32))
    out_dtype = jnp.int8 if out_scale is not None else jnp.float32

    kernel = functools.partial(_qmatmul_kernel, n_k=n_k, relu=relu,
                               out_scale=out_scale)
    return pl.pallas_call(
        kernel,
        grid=(M // block_m, N // block_n, n_k),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, block_n), lambda i, j, k: (0, j)),
            pl.BlockSpec((1, block_n), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x_int, w_int, scale2d, bias2d)
