"""AdamW + gradient clipping + cosine LR schedule, pure JAX pytree ops.

Kept dependency-free (no optax in this container). The optimizer state
(m, v in f32) is sharded like the parameters (same PartitionSpec tree), so
FSDP covers optimizer memory too — at 314B params that is the difference
between fitting and not fitting.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def clip_by_global_norm(grads, max_norm: float):
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), gn


def adamw_update(
    grads,
    state: AdamWState,
    params,
    lr: jnp.ndarray,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
) -> Tuple[Any, AdamWState]:
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * gf
        v = b2 * v + (1 - b2) * jnp.square(gf)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v)


def cosine_schedule(base_lr: float, warmup: int, total: int) -> Callable:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * base_lr * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, jnp.maximum(cos, 0.1 * base_lr))

    return lr


@dataclasses.dataclass(frozen=True)
class Optimizer:
    lr_fn: Callable
    max_grad_norm: float = 1.0
    b1: float = 0.9
    b2: float = 0.95
    weight_decay: float = 0.1

    def init(self, params) -> AdamWState:
        return adamw_init(params)

    def update(self, grads, state, params):
        grads, gn = clip_by_global_norm(grads, self.max_grad_norm)
        lr = self.lr_fn(state.step + 1)
        new_p, new_s = adamw_update(
            grads, state, params, lr,
            b1=self.b1, b2=self.b2, weight_decay=self.weight_decay,
        )
        return new_p, new_s, {"grad_norm": gn, "lr": lr}


def make_optimizer(base_lr: float = 3e-4, warmup: int = 100, total: int = 10_000,
                   max_grad_norm: float = 1.0, weight_decay: float = 0.1) -> Optimizer:
    return Optimizer(lr_fn=cosine_schedule(base_lr, warmup, total),
                     max_grad_norm=max_grad_norm, weight_decay=weight_decay)
