"""Batched serving engine: continuous-batching prefill + decode slots.

The inference-side dataflow of the paper (stream data through a fixed
pipeline, never let buffers idle) maps to slot-based continuous batching:

  * a fixed decode batch of `n_slots` sequences (static shapes -> one XLA
    program, no recompiles),
  * new requests are prefied one at a time and their KV state written into a
    free slot (per-slot cache insert via dynamic_update_slice on the batch
    axis),
  * every engine step decodes all active slots; finished sequences free
    their slot immediately.

Works on CPU with the reduced configs (examples/serve_lm.py,
tests/test_serving.py) and lowers unchanged for the dry-run decode cells.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.model import Model


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray          # (P,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    # filled by the engine:
    output: List[int] = dataclasses.field(default_factory=list)
    submit_t: float = 0.0
    first_token_t: float = 0.0
    done_t: float = 0.0


class ServeEngine:
    def __init__(self, model: Model, params, n_slots: int = 4,
                 max_len: int = 256, greedy: bool = True):
        self.model = model
        self.cfg = model.cfg
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.greedy = greedy

        self.caches = model.cache_init(n_slots, max_len)
        self.active: List[Optional[Request]] = [None] * n_slots
        self.positions = np.zeros(n_slots, np.int64)
        self.last_token = np.zeros((n_slots, 1), np.int32)
        self.queue: List[Request] = []
        self.finished: List[Request] = []

        self._decode = jax.jit(model.decode_step)
        self._prefill_one = jax.jit(self._prefill_impl)

    # -- prefill one request into slot via single-token steps (exact KV) ---
    def _prefill_impl(self, params, caches, tokens, start):
        """tokens (1, P) processed one at a time with scan; returns caches
        for batch of 1 and last logits."""

        def body(carry, t):
            caches, idx = carry
            logits, caches = self.model.decode_step(
                params, caches, t[None, None], idx
            )
            return (caches, idx + 1), logits

        (caches, _), logits = jax.lax.scan(body, (caches, start), tokens[0])
        return caches, logits[-1]

    def submit(self, req: Request):
        req.submit_t = time.monotonic()
        self.queue.append(req)

    def _insert_into_slot(self, slot: int, req: Request):
        one_cache = self.model.cache_init(1, self.max_len)
        toks = jnp.asarray(req.prompt, jnp.int32)[None]
        one_cache, last_logits = self._prefill_one(
            self.params, one_cache, toks, jnp.zeros((), jnp.int32)
        )

        # caches are stacked (groups, batch, ...) pytrees — batch axis = 1
        def write_slot(batch_c, one_c):
            start = [0] * batch_c.ndim
            start[1] = slot
            return jax.lax.dynamic_update_slice(
                batch_c, one_c.astype(batch_c.dtype), tuple(start)
            )

        self.caches = jax.tree.map(write_slot, self.caches, one_cache)
        tok = int(jnp.argmax(last_logits[-1]))
        req.output.append(tok)
        req.first_token_t = time.monotonic()
        self.active[slot] = req
        self.positions[slot] = len(req.prompt)
        self.last_token[slot, 0] = tok
        # the prefill-emitted token can already terminate the request
        self._maybe_finish(slot, tok)

    def _maybe_finish(self, slot: int, tok: int) -> bool:
        req = self.active[slot]
        done = (
            len(req.output) >= req.max_new_tokens
            or (req.eos_id is not None and tok == req.eos_id)
            or self.positions[slot] >= self.max_len - 1
        )
        if done:
            req.done_t = time.monotonic()
            self.finished.append(req)
            self.active[slot] = None
        return done

    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.active) if r is None]

    def step(self):
        """One engine iteration: admit from queue, then one decode step."""
        for slot in self._free_slots():
            if not self.queue:
                break
            self._insert_into_slot(slot, self.queue.pop(0))

        if not any(r is not None for r in self.active):
            return

        # per-slot positions: the decode step takes a (B,) cur_index vector,
        # so slots at different sequence lengths advance together.
        cur = jnp.asarray(self.positions, jnp.int32)
        logits, self.caches = self._decode(
            self.params, self.caches, jnp.asarray(self.last_token), cur
        )
        next_tokens = np.asarray(jnp.argmax(logits[:, 0], axis=-1), np.int32)

        for i, req in enumerate(self.active):
            if req is None:
                continue
            tok = int(next_tokens[i])
            req.output.append(tok)
            self.positions[i] += 1
            self.last_token[i, 0] = tok
            self._maybe_finish(i, tok)

    def run_until_drained(self, max_steps: int = 10_000):
        steps = 0
        while (self.queue or any(r is not None for r in self.active)) and steps < max_steps:
            self.step()
            steps += 1
        return steps

    # -- metrics -----------------------------------------------------------
    def stats(self) -> Dict[str, float]:
        if not self.finished:
            return {}
        ttfts = [r.first_token_t - r.submit_t for r in self.finished]
        lats = [r.done_t - r.submit_t for r in self.finished]
        toks = sum(len(r.output) for r in self.finished)
        span = max(r.done_t for r in self.finished) - min(
            r.submit_t for r in self.finished
        )
        return {
            "n_requests": len(self.finished),
            "mean_ttft_s": float(np.mean(ttfts)),
            "mean_latency_s": float(np.mean(lats)),
            "throughput_tok_s": toks / max(span, 1e-9),
        }


# ---------------------------------------------------------------------------
# multi-tenant tiny-model serving (repro.deploy integration)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TinyRequest:
    """One inference request against a named tiny model."""

    uid: int
    model: str
    x: np.ndarray                        # (features...,) single sample
    submit_t: float = 0.0
    done_t: float = 0.0
    result: Optional[np.ndarray] = None


class TinyModelServer:
    """All Table-1 tiny models served concurrently from one shared queue.

    The LM engine above batches sequences into decode slots; the tiny-model
    analogue batches same-model requests into one ``offline`` call per step.
    Tenants are compiled deployments (``repro.deploy`` executors, or anything
    exposing ``offline(batch) -> outputs``); each engine step drains up to
    ``max_batch`` queued requests *per tenant*, so a burst on one model
    cannot starve the others — the slot fairness idea applied across models
    instead of across sequences.
    """

    def __init__(self, models: Dict[str, Any], max_batch: int = 32):
        self.models = dict(models)
        self.max_batch = max_batch
        self.queue: List[TinyRequest] = []
        self.finished: List[TinyRequest] = []
        self._uid = 0

    def submit(self, model: str, x: np.ndarray) -> TinyRequest:
        if model not in self.models:
            raise KeyError(f"unknown tiny model {model!r}; "
                           f"tenants: {sorted(self.models)}")
        req = TinyRequest(uid=self._uid, model=model, x=np.asarray(x),
                          submit_t=time.monotonic())
        self._uid += 1
        self.queue.append(req)
        return req

    def step(self) -> int:
        """Admit and run one batch per tenant; returns #requests served."""
        served = 0
        by_model: Dict[str, List[TinyRequest]] = {}
        remaining: List[TinyRequest] = []
        for req in self.queue:
            group = by_model.setdefault(req.model, [])
            if len(group) < self.max_batch:
                group.append(req)
            else:
                remaining.append(req)
        self.queue = remaining
        for name, group in by_model.items():
            xb = jnp.asarray(np.stack([r.x for r in group]))
            yb = np.asarray(jax.block_until_ready(
                self.models[name].offline(xb)))
            now = time.monotonic()
            for r, y in zip(group, yb):
                r.result = y
                r.done_t = now
                self.finished.append(r)
            served += len(group)
        return served

    def run_until_drained(self, max_steps: int = 10_000) -> int:
        steps = 0
        while self.queue and steps < max_steps:
            self.step()
            steps += 1
        return steps

    def stats(self) -> Dict[str, Dict[str, float]]:
        """Per-tenant and aggregate latency/throughput."""
        if not self.finished:
            return {}
        out: Dict[str, Dict[str, float]] = {}
        span = (max(r.done_t for r in self.finished)
                - min(r.submit_t for r in self.finished))
        for name in self.models:
            lats = [r.done_t - r.submit_t for r in self.finished
                    if r.model == name]
            if not lats:
                continue
            out[name] = {
                "n": len(lats),
                "p50_ms": float(np.percentile(lats, 50) * 1e3),
                "p99_ms": float(np.percentile(lats, 99) * 1e3),
            }
        out["_aggregate"] = {
            "n": len(self.finished),
            "throughput_qps": len(self.finished) / max(span, 1e-9),
        }
        return out
