"""Batched serving engine: continuous-batching prefill + decode slots.

The inference-side dataflow of the paper (stream data through a fixed
pipeline, never let buffers idle) maps to slot-based continuous batching:

  * a fixed decode batch of `n_slots` sequences (static shapes -> one XLA
    program, no recompiles),
  * new requests are prefied one at a time and their KV state written into a
    free slot (per-slot cache insert via dynamic_update_slice on the batch
    axis),
  * every engine step decodes all active slots; finished sequences free
    their slot immediately.

Works on CPU with the reduced configs (examples/serve_lm.py,
tests/test_serving.py) and lowers unchanged for the dry-run decode cells.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.obs import timer as obs_timer
from repro.models.model import Model


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray          # (P,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    # filled by the engine:
    output: List[int] = dataclasses.field(default_factory=list)
    submit_t: float = 0.0
    first_token_t: float = 0.0
    done_t: float = 0.0


class ServeEngine:
    def __init__(self, model: Model, params, n_slots: int = 4,
                 max_len: int = 256, greedy: bool = True):
        self.model = model
        self.cfg = model.cfg
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.greedy = greedy

        self.caches = model.cache_init(n_slots, max_len)
        self.active: List[Optional[Request]] = [None] * n_slots
        self.positions = np.zeros(n_slots, np.int64)
        self.last_token = np.zeros((n_slots, 1), np.int32)
        self.queue: List[Request] = []
        self.finished: List[Request] = []

        self._decode = jax.jit(model.decode_step)
        self._prefill_one = jax.jit(self._prefill_impl)

    # -- prefill one request into slot via single-token steps (exact KV) ---
    def _prefill_impl(self, params, caches, tokens, start):
        """tokens (1, P) processed one at a time with scan; returns caches
        for batch of 1 and last logits."""

        def body(carry, t):
            caches, idx = carry
            logits, caches = self.model.decode_step(
                params, caches, t[None, None], idx
            )
            return (caches, idx + 1), logits

        (caches, _), logits = jax.lax.scan(body, (caches, start), tokens[0])
        return caches, logits[-1]

    def submit(self, req: Request):
        req.submit_t = obs_timer.now()
        self.queue.append(req)

    def _insert_into_slot(self, slot: int, req: Request):
        one_cache = self.model.cache_init(1, self.max_len)
        toks = jnp.asarray(req.prompt, jnp.int32)[None]
        one_cache, last_logits = self._prefill_one(
            self.params, one_cache, toks, jnp.zeros((), jnp.int32)
        )

        # caches are stacked (groups, batch, ...) pytrees — batch axis = 1
        def write_slot(batch_c, one_c):
            start = [0] * batch_c.ndim
            start[1] = slot
            return jax.lax.dynamic_update_slice(
                batch_c, one_c.astype(batch_c.dtype), tuple(start)
            )

        self.caches = jax.tree.map(write_slot, self.caches, one_cache)
        tok = int(jnp.argmax(last_logits[-1]))
        req.output.append(tok)
        req.first_token_t = obs_timer.now()
        self.active[slot] = req
        self.positions[slot] = len(req.prompt)
        self.last_token[slot, 0] = tok
        # the prefill-emitted token can already terminate the request
        self._maybe_finish(slot, tok)

    def _maybe_finish(self, slot: int, tok: int) -> bool:
        req = self.active[slot]
        done = (
            len(req.output) >= req.max_new_tokens
            or (req.eos_id is not None and tok == req.eos_id)
            or self.positions[slot] >= self.max_len - 1
        )
        if done:
            req.done_t = obs_timer.now()
            self.finished.append(req)
            self.active[slot] = None
        return done

    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.active) if r is None]

    def step(self):
        """One engine iteration: admit from queue, then one decode step."""
        for slot in self._free_slots():
            if not self.queue:
                break
            self._insert_into_slot(slot, self.queue.pop(0))

        if not any(r is not None for r in self.active):
            return

        # per-slot positions: the decode step takes a (B,) cur_index vector,
        # so slots at different sequence lengths advance together.
        cur = jnp.asarray(self.positions, jnp.int32)
        logits, self.caches = self._decode(
            self.params, self.caches, jnp.asarray(self.last_token), cur
        )
        next_tokens = np.asarray(jnp.argmax(logits[:, 0], axis=-1), np.int32)

        for i, req in enumerate(self.active):
            if req is None:
                continue
            tok = int(next_tokens[i])
            req.output.append(tok)
            self.positions[i] += 1
            self.last_token[i, 0] = tok
            self._maybe_finish(i, tok)

    def run_until_drained(self, max_steps: int = 10_000):
        steps = 0
        while (self.queue or any(r is not None for r in self.active)) and steps < max_steps:
            self.step()
            steps += 1
        return steps

    # -- metrics -----------------------------------------------------------
    def stats(self) -> Dict[str, float]:
        if not self.finished:
            return {}
        ttfts = [r.first_token_t - r.submit_t for r in self.finished]
        lats = [r.done_t - r.submit_t for r in self.finished]
        toks = sum(len(r.output) for r in self.finished)
        span = max(r.done_t for r in self.finished) - min(
            r.submit_t for r in self.finished
        )
        return {
            "n_requests": len(self.finished),
            "mean_ttft_s": float(np.mean(ttfts)),
            "mean_latency_s": float(np.mean(lats)),
            "throughput_tok_s": toks / max(span, 1e-9),
        }


# ---------------------------------------------------------------------------
# multi-tenant tiny-model serving (repro.deploy integration)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TinyRequest:
    """One inference request against a named tiny model."""

    uid: int
    model: str
    x: np.ndarray                        # (features...,) single sample
    submit_t: float = 0.0
    done_t: float = 0.0
    result: Optional[np.ndarray] = None


class _OfflineWaveAdapter:
    """Wave API for legacy tenants that only expose ``offline(batch)``.

    The router dispatches through ``submit_wave``; a tenant without one
    (an arbitrary research model behind ``CompiledJaxModel``, say) gets
    this adapter: no padding, the wave is just the batch, every row valid.
    """

    def __init__(self, model: Any):
        self.model = model
        self.default_micro_batch = 1

    def submit_wave(self, x, valid=None, micro_batch=None):
        y = self.model.offline(jnp.asarray(np.asarray(x)))
        n = np.asarray(x).shape[0]
        mask = np.ones(n, bool) if valid is None else np.asarray(valid, bool)
        return y, mask


class TinyModelServer:
    """All Table-1 tiny models served concurrently from one shared queue.

    Since the ``repro.serve`` subsystem landed, this class is a
    *compatibility shim* over the dynamic-batching router: the legacy API
    (``submit``/``step``/``run_until_drained``/``stats``) is unchanged, but
    every batch now dispatches through the executor's compiled segment
    waves (``CompiledTinyModel.submit_wave`` — the PR-4 streaming path)
    instead of a bare ``offline`` call, with one router lane per tenant so
    a burst on one model cannot starve the others. Tenants without a wave
    API still work through ``_OfflineWaveAdapter``. New code should use
    ``repro.serve.Router`` directly (SLO admission, deadline batching,
    replica placement, sliding-window metrics live there).
    """

    def __init__(self, models: Dict[str, Any], max_batch: int = 32,
                 engine: Any = None):
        from repro.serve import Router, RouterConfig

        self.models = dict(models)
        self.max_batch = max_batch
        self.queue: List[TinyRequest] = []
        self.finished: List[TinyRequest] = []
        self._uid = 0
        # explicitly-stepped router: waves of up to max_batch per tenant,
        # dispatched only from step() (legacy drain semantics, no deadline).
        # ``engine`` passes through to the router (e.g.
        # ``repro.serve.AsyncEngine()`` to overlap tenants' waves across a
        # replica pool); step() reaps before reading results, so the
        # legacy submit/step/stats contract holds under either engine.
        self.router = Router(
            {name: (m if hasattr(m, "submit_wave")
                    else _OfflineWaveAdapter(m))
             for name, m in self.models.items()},
            RouterConfig(micro_batch=max_batch, auto_dispatch=False,
                         max_wait_ms=0.0),
            engine=engine)
        self._routed: Dict[int, Any] = {}   # TinyRequest.uid -> ServeRequest

    def submit(self, model: str, x: np.ndarray) -> TinyRequest:
        if model not in self.models:
            raise KeyError(f"unknown tiny model {model!r}; "
                           f"tenants: {sorted(self.models)}")
        req = TinyRequest(uid=self._uid, model=model, x=np.asarray(x),
                          submit_t=obs_timer.now())
        self._uid += 1
        self.queue.append(req)
        self._routed[req.uid] = self.router.submit(model, req.x,
                                                   arrival_t=req.submit_t)
        return req

    def step(self) -> int:
        """Run one wave per tenant; returns #requests served."""
        served = 0
        for name in self.models:
            served += self.router.dispatch_one(name, max_n=self.max_batch)
        # settle async in-flight waves before reading results back (a
        # no-op under the default blocking engine)
        self.router.reap(block=True)
        if served:
            still: List[TinyRequest] = []
            for req in self.queue:
                routed = self._routed[req.uid]
                if routed.result is not None:
                    req.result = np.asarray(routed.result)
                    req.done_t = routed.done_t
                    self.finished.append(req)
                    del self._routed[req.uid]
                else:
                    still.append(req)
            self.queue = still
        return served

    def run_until_drained(self, max_steps: int = 10_000) -> int:
        steps = 0
        while self.queue and steps < max_steps:
            self.step()
            steps += 1
        return steps

    def stats(self) -> Dict[str, Dict[str, float]]:
        """Per-tenant and aggregate latency/throughput (legacy shape, plus
        the router's wave occupancy per tenant)."""
        if not self.finished:
            return {}
        out: Dict[str, Dict[str, float]] = {}
        span = (max(r.done_t for r in self.finished)
                - min(r.submit_t for r in self.finished))
        router_stats = self.router.stats()
        for name in self.models:
            lats = [r.done_t - r.submit_t for r in self.finished
                    if r.model == name]
            if not lats:
                continue
            out[name] = {
                "n": len(lats),
                "p50_ms": float(np.percentile(lats, 50) * 1e3),
                "p99_ms": float(np.percentile(lats, 99) * 1e3),
                "wave_occupancy":
                    router_stats[name]["metrics"].mean_occupancy,
            }
        out["_aggregate"] = {
            "n": len(self.finished),
            "throughput_qps": len(self.finished) / max(span, 1e-9),
        }
        return out
