"""The one injectable timer every measurement in ``src/repro`` reads.

Raw ``time.time()`` / ``time.perf_counter()`` calls used to be scattered
through ``deploy/scenarios.py``, ``deploy/executor.py``, ``deploy/autotune.py``
and the launch/serving shims — each one a place a deterministic test could
not reach. This module is now the single point of truth (enforced by
``scripts/check_no_raw_clock.py``): everything times itself through
``obs.timer.now()``, and a test swaps the process-wide timer for a manual
clock (the ``serve/clock.py`` pattern, made global):

    from repro.obs import timer
    with timer.fake(ManualClock()) as clock:
        ...            # every now()/sleep() in repro reads the fake

The only two files allowed to touch the ``time`` module directly are this
one and ``repro/serve/clock.py`` (whose clock *objects* plug in here).

``now()`` is a monotonic high-resolution stamp for measuring durations;
``walltime()`` is the epoch stamp for provenance metadata (checkpoint
manifests, bench artifacts) — the two must never be mixed.
"""

from __future__ import annotations

import contextlib
import time as _time
from typing import Iterator, Optional


class PerfTimer:
    """The real timer: ``perf_counter`` durations, real sleeps."""

    def now(self) -> float:
        return _time.perf_counter()

    def sleep(self, seconds: float) -> None:
        if seconds > 0:
            _time.sleep(seconds)

    def walltime(self) -> float:
        return _time.time()


_TIMER: object = PerfTimer()


def get_timer() -> object:
    return _TIMER


def set_timer(timer: Optional[object]) -> object:
    """Install a timer object (``now()``/``sleep()``); returns the previous
    one so callers can restore it. ``None`` restores the real timer."""
    global _TIMER
    old = _TIMER
    _TIMER = timer if timer is not None else PerfTimer()
    return old


@contextlib.contextmanager
def fake(timer: object) -> Iterator[object]:
    """Scoped timer swap: install ``timer`` for the block, restore after.
    The fixture-shaped entry point for deterministic-clock tests."""
    old = set_timer(timer)
    try:
        yield timer
    finally:
        set_timer(old)


def now() -> float:
    """Monotonic seconds from the installed timer (durations only)."""
    return _TIMER.now()


def sleep(seconds: float) -> None:
    _TIMER.sleep(seconds)


def walltime() -> float:
    """Epoch seconds (provenance stamps). Falls back to the real clock when
    the installed timer has no ``walltime`` (manual clocks measure
    durations, not dates)."""
    wt = getattr(_TIMER, "walltime", None)
    return wt() if wt is not None else _time.time()
