"""Span-derived reports: latency percentiles recomputed from the trace and
the predicted-vs-measured service-time trail.

Two consumers drive the shapes here:

  * **Cross-checking** — the serve stack's sliding-window metrics
    (``serve.metrics``) and the trace record the same completions through
    different paths; ``latency_percentiles`` recomputes p50/p90/p99 from
    request spans with the *same arithmetic* (same floats, same
    ``np.percentile``), so under a ``ManualClock`` the two must agree to
    the bit — the consistency test that keeps instrumentation honest.
  * **The rule4ml direction (ROADMAP #5)** — every dispatch span carries
    the FIFO-cost-model *predicted* wave service time next to its measured
    duration; ``prediction_error`` aggregates the error statistics per
    (model, platform). That table is the raw training set for a learned
    service-time predictor: accumulate it across bench runs and you have
    predicted-vs-measured pairs for every wave the server ever ran.
"""

from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from repro.obs.tracer import Tracer

#: Span names the serve instrumentation records (single source of truth —
#: the router and these reports must agree on them).
REQUEST_SPAN = "request"
WAVE_SPAN = "wave"
STAGE_SPAN = "stage"


def request_latencies_ms(tracer: Tracer, model: Optional[str] = None
                         ) -> np.ndarray:
    """Per-request latency (ms) from request spans, shed requests excluded
    — the same population ``ServeMetrics`` aggregates."""
    lats = []
    for e in tracer.spans(name=REQUEST_SPAN):
        a = e.args or {}
        if a.get("shed"):
            continue
        if model is not None and a.get("model") != model:
            continue
        lats.append((e.t1 - e.t0) * 1e3)
    return np.asarray(lats)


def latency_percentiles(tracer: Tracer, model: Optional[str] = None
                        ) -> Dict[str, float]:
    """p50/p90/p99 (ms) recomputed from request spans with the exact
    arithmetic of ``ServeMetrics.snapshot`` — same floats in, same
    ``np.percentile`` call, bit-identical out (tested)."""
    lats = request_latencies_ms(tracer, model)
    if lats.size == 0:
        return {"n": 0, "p50_ms": 0.0, "p90_ms": 0.0, "p99_ms": 0.0}
    p50, p90, p99 = (float(np.percentile(lats, q)) for q in (50, 90, 99))
    return {"n": int(lats.size), "p50_ms": p50, "p90_ms": p90, "p99_ms": p99}


def prediction_records(tracer: Tracer) -> List[Dict]:
    """Flat (model, platform, micro_batch, n_valid, predicted_ms,
    measured_ms) rows from wave spans — the learned-cost-model training
    set, one row per dispatched wave."""
    rows = []
    for e in tracer.spans(name=WAVE_SPAN):
        a = e.args or {}
        if a.get("predicted_ms") is None:
            continue
        rows.append({
            "model": a.get("model", ""),
            "platform": a.get("platform", ""),
            "micro_batch": a.get("micro_batch"),
            "n_valid": a.get("n_valid"),
            "predicted_ms": float(a["predicted_ms"]),
            "measured_ms": (e.t1 - e.t0) * 1e3,
        })
    return rows


def export_prediction_records(tracer: Tracer, path: str) -> str:
    """Write ``prediction_records`` as a deterministic JSONL shard.

    One sorted-key JSON object per line, rows in span order — the
    accumulable on-disk form ``repro.costmodel.dataset`` harvests
    (``load_trace_records``): archive a shard per traced run and the
    training table rebuilds byte-identically from the archive alone.
    """
    import json
    import os

    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        for r in prediction_records(tracer):
            f.write(json.dumps(r, sort_keys=True, separators=(",", ":")))
            f.write("\n")
    return path


def prediction_error(tracer: Tracer) -> Dict[str, Dict]:
    """Prediction-error statistics per ``model@platform``.

    Per group: wave count, mean/median absolute relative error
    (|measured - predicted| / predicted), and the signed bias
    (mean (measured - predicted) / predicted — positive means the FIFO
    model is optimistic, the usual case when dispatch overhead is
    uncalibrated). This is the table ``BENCH_obs.json`` publishes and the
    number a learned predictor has to beat.
    """
    groups: Dict[str, List[Dict]] = {}
    for r in prediction_records(tracer):
        groups.setdefault(f"{r['model']}@{r['platform']}", []).append(r)
    out: Dict[str, Dict] = {}
    for key, rows in sorted(groups.items()):
        pred = np.asarray([r["predicted_ms"] for r in rows])
        meas = np.asarray([r["measured_ms"] for r in rows])
        rel = (meas - pred) / np.maximum(pred, 1e-12)
        out[key] = {
            "n_waves": len(rows),
            "predicted_ms_mean": float(pred.mean()),
            "measured_ms_mean": float(meas.mean()),
            "mean_abs_rel_err": float(np.abs(rel).mean()),
            "median_abs_rel_err": float(np.median(np.abs(rel))),
            "bias_rel": float(rel.mean()),
        }
    return out


def stage_medians_ms(tracer: Tracer) -> Dict[str, float]:
    """Median duration (ms) per stage from ``stage`` probe spans — the
    span-derived form of ``CompiledTinyModel.stage_latencies``, used to
    cross-check the returned breakdown against the trace."""
    per: Dict[str, List[float]] = {}
    for e in tracer.spans(name=STAGE_SPAN):
        a = e.args or {}
        per.setdefault(str(a.get("stage", "?")), []).append(e.t1 - e.t0)
    out = {}
    for name, ts in per.items():
        ts.sort()
        out[name] = ts[len(ts) // 2] * 1e3
    return out
