"""repro.obs — end-to-end tracing, counters, and timeline export.

The observability layer under the compile→execute→serve stack
(``docs/observability.md``): one injectable process-wide timer
(``obs.timer`` — the only place raw clocks live, enforced by
``scripts/check_no_raw_clock.py``), a ring-buffered thread-safe ``Tracer``
with spans / instant events / counter series and a near-zero-overhead
``NullTracer`` default, exporters to Chrome trace-event JSON (load in
Perfetto: pid per replica, tid per lane/segment/FIFO) and flat JSONL, and
span-derived reports — latency percentiles that must match the serve
metrics to the bit, and the FIFO-model predicted-vs-measured service-time
table that seeds the learned cost model (ROADMAP direction 5).

    from repro.obs import Tracer, export_chrome
    tracer = Tracer()                       # or Tracer(clock=ManualClock())
    router = Router({"ic": cm}, cfg, tracer=tracer)
    router.run_trace("ic", poisson_trace(200, 512), make_query)
    export_chrome(tracer, "serve_trace.json")   # open in ui.perfetto.dev
"""

from repro.obs import timer  # noqa: F401
from repro.obs.export import (  # noqa: F401
    chrome_events,
    chrome_json,
    export_chrome,
    export_jsonl,
    jsonl_lines,
)
from repro.obs.report import (  # noqa: F401
    export_prediction_records,
    latency_percentiles,
    prediction_error,
    prediction_records,
    request_latencies_ms,
    stage_medians_ms,
)
from repro.obs.tracer import (  # noqa: F401
    COUNTER,
    INSTANT,
    NULL_TRACER,
    SPAN,
    NullTracer,
    TraceEvent,
    Tracer,
)
