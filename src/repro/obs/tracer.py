"""Spans, instant events, and counter series for the compile→execute→serve
stack.

The paper's credibility rests on per-stage accounting (II/FIFO tables,
auditable µs latencies); this tracer is that discipline applied to our own
runtime. Every layer records into one ``Tracer``:

  * **spans** — named intervals with a category, a ``(pid, tid)``
    attribution (exported as Perfetto process/track), and free-form args.
    ``tracer.span(...)`` is a context manager; ``add_span`` records a
    finished interval from explicit timestamps (how the router records a
    request's arrival→completion after the fact).
  * **instants** — point events (``enqueue``, ``admit``, ``shed``).
  * **counters** — time series (queue backlog, FIFO occupancy, replica
    outstanding work) rendered as counter tracks.

Events land in a bounded ring (oldest dropped first, drop count kept), so
a long-running server can stay traced without unbounded memory. Appends
are lock-protected — the router's threads and the host queue loop may
interleave. Time comes from an injectable clock (``serve.clock`` objects
plug straight in); under a ``ManualClock`` a traced run is a deterministic
discrete-event record, and ``obs.export`` serializes it byte-identically
across runs.

``NULL_TRACER`` is the default everywhere: a ``NullTracer`` whose methods
are no-ops returning shared singletons, so the disabled path costs one
attribute lookup and an empty call — nothing allocates, nothing locks.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
from typing import Dict, List, Optional

from repro.obs import timer as _timer

#: Event kinds (``TraceEvent.kind``).
SPAN, INSTANT, COUNTER = "span", "instant", "counter"


@dataclasses.dataclass
class TraceEvent:
    """One recorded event. ``t1`` is meaningful for spans only; ``value``
    for counters only. Times are seconds in the tracer's clock domain."""

    kind: str
    name: str
    cat: str
    t0: float
    t1: float = 0.0
    pid: int = 0
    tid: int = 0
    value: float = 0.0
    args: Optional[Dict] = None
    seq: int = 0                  # record order (stable export tiebreak)

    @property
    def dur(self) -> float:
        return self.t1 - self.t0


class _Span:
    """Live span handle: ``with tracer.span(...) as sp: sp.set(k=v)``.
    Records on exit; ``set`` attaches args discovered mid-span (the
    dispatch span learns its measured service time this way)."""

    __slots__ = ("_tracer", "name", "cat", "pid", "tid", "args", "t0")

    def __init__(self, tracer, name, cat, pid, tid, args):
        self._tracer = tracer
        self.name, self.cat = name, cat
        self.pid, self.tid = pid, tid
        self.args = args
        self.t0 = 0.0

    def set(self, **kwargs) -> "_Span":
        if self.args is None:
            self.args = {}
        self.args.update(kwargs)
        return self

    def __enter__(self) -> "_Span":
        self.t0 = self._tracer.now()
        return self

    def __exit__(self, *exc) -> bool:
        self._tracer.add_span(self.name, self.t0, self._tracer.now(),
                              cat=self.cat, pid=self.pid, tid=self.tid,
                              args=self.args)
        return False


class _NullSpan:
    """Shared do-nothing span: the NullTracer's context manager."""

    __slots__ = ()

    def set(self, **kwargs) -> "_NullSpan":
        return self

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False


_NULL_SPAN = _NullSpan()


class Tracer:
    """Ring-buffered, thread-safe event recorder with an injectable clock.

    ``clock`` is any object with ``now()`` (``serve.clock.SystemClock`` /
    ``ManualClock``); ``None`` reads the process-wide ``obs.timer`` — the
    same source the instrumented code measures with, so spans and manual
    timings never disagree. ``capacity`` bounds memory: the oldest events
    fall off first and ``n_dropped`` counts them (an exporter that claims
    completeness must check it).
    """

    enabled = True

    def __init__(self, clock: Optional[object] = None,
                 capacity: int = 1 << 16):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self._clock = clock
        self.capacity = int(capacity)
        self._events: collections.deque = collections.deque(maxlen=capacity)
        self._lock = threading.Lock()
        self._seq = 0
        self.n_dropped = 0

    # -- time --------------------------------------------------------------
    def now(self) -> float:
        return self._clock.now() if self._clock is not None \
            else _timer.now()

    # -- recording ---------------------------------------------------------
    def _record(self, ev: TraceEvent) -> None:
        with self._lock:
            ev.seq = self._seq
            self._seq += 1
            if len(self._events) == self.capacity:
                self.n_dropped += 1
            self._events.append(ev)

    def span(self, name: str, cat: str = "", pid: int = 0, tid: int = 0,
             **args) -> _Span:
        """Context manager timing a block into one span event."""
        return _Span(self, name, cat, pid, tid, args or None)

    def add_span(self, name: str, t0: float, t1: float, cat: str = "",
                 pid: int = 0, tid: int = 0,
                 args: Optional[Dict] = None) -> None:
        """Record a finished interval from explicit clock readings."""
        self._record(TraceEvent(SPAN, name, cat, float(t0), float(t1),
                                pid, tid, args=args))

    def instant(self, name: str, t: Optional[float] = None, cat: str = "",
                pid: int = 0, tid: int = 0, **args) -> None:
        t = self.now() if t is None else float(t)
        self._record(TraceEvent(INSTANT, name, cat, t, t, pid, tid,
                                args=args or None))

    def counter(self, name: str, value: float, t: Optional[float] = None,
                cat: str = "", pid: int = 0, tid: int = 0) -> None:
        """One sample of a counter series (rendered as a counter track)."""
        t = self.now() if t is None else float(t)
        self._record(TraceEvent(COUNTER, name, cat, t, t, pid, tid,
                                value=float(value)))

    # -- reading -----------------------------------------------------------
    def events(self, kind: Optional[str] = None, name: Optional[str] = None,
               cat: Optional[str] = None) -> List[TraceEvent]:
        """Snapshot of the ring (record order), optionally filtered."""
        with self._lock:
            evs = list(self._events)
        if kind is not None:
            evs = [e for e in evs if e.kind == kind]
        if name is not None:
            evs = [e for e in evs if e.name == name]
        if cat is not None:
            evs = [e for e in evs if e.cat == cat]
        return evs

    def spans(self, name: Optional[str] = None,
              cat: Optional[str] = None) -> List[TraceEvent]:
        return self.events(kind=SPAN, name=name, cat=cat)

    def counters(self, name: Optional[str] = None) -> List[TraceEvent]:
        return self.events(kind=COUNTER, name=name)

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self._seq = 0
            self.n_dropped = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)

    def __bool__(self) -> bool:
        # without this, __len__ makes an *empty* tracer falsy and
        # ``tracer or NULL_TRACER`` silently discards a fresh tracer
        # before its first event; a real tracer is always truthy
        return True


class NullTracer:
    """The disabled tracer: every method is a no-op over shared singletons.

    This is the default ``tracer=`` everywhere, so the instrumented hot
    paths pay only an attribute lookup and an empty call when tracing is
    off — no allocation, no lock, no clock read. ``enabled`` lets bulk
    recorders (the host queue loop's per-hop occupancy counters) skip
    entire loops in one branch.
    """

    enabled = False
    capacity = 0
    n_dropped = 0

    def now(self) -> float:
        return 0.0

    def span(self, name: str, cat: str = "", pid: int = 0, tid: int = 0,
             **args) -> _NullSpan:
        return _NULL_SPAN

    def add_span(self, *a, **kw) -> None:
        pass

    def instant(self, *a, **kw) -> None:
        pass

    def counter(self, *a, **kw) -> None:
        pass

    def events(self, *a, **kw) -> List[TraceEvent]:
        return []

    spans = events
    counters = events

    def clear(self) -> None:
        pass

    def __len__(self) -> int:
        return 0

    def __bool__(self) -> bool:
        # deliberately falsy: the disabled tracer is the "no tracing"
        # sentinel, so ``tracer or NULL_TRACER`` and enabled-style checks
        # both treat it as absent
        return False


#: The shared default NullTracer instance.
NULL_TRACER = NullTracer()
