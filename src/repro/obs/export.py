"""Trace exporters: Chrome trace-event JSON (Perfetto / ``chrome://tracing``)
and flat JSONL.

The Chrome export maps the tracer's attribution onto Perfetto's model —
``pid`` becomes a process row (pid 0 the router, pid 1+N replica N, by the
serve instrumentation's convention), ``tid`` a track inside it (one per
lane / segment / FIFO), spans become ``"X"`` complete events, instants
``"i"``, counter series ``"C"`` counter tracks. Open the file with
https://ui.perfetto.dev (or ``chrome://tracing``) and the server run reads
as a timeline: request tracks over router lanes, wave execution on replica
rows, backlog and FIFO occupancy as counter plots underneath.

Serialization is **deterministic**: events export in record order, keys
are sorted, separators fixed, timestamps are exact float arithmetic on the
recorded clock readings — so two runs under the same ``ManualClock``
schedule produce byte-identical files (asserted by ``tests/test_obs.py``;
it is what makes trace diffs reviewable).

The JSONL export is the flat machine-readable form (one event per line)
for downstream analysis — the prediction-error training set of
``obs.report`` reads either representation.
"""

from __future__ import annotations

import json
import os
from typing import Dict, List, Optional, Tuple

from repro.obs.tracer import COUNTER, INSTANT, SPAN, TraceEvent, Tracer


def _sanitize(args: Optional[Dict]) -> Optional[Dict]:
    """Args become plain JSON: numpy scalars unwrap, everything else that
    isn't a JSON primitive stringifies (determinism requires values whose
    text form is stable — no default object reprs with addresses)."""
    if not args:
        return None

    def scalar(v):
        item = getattr(v, "item", None)
        if item is not None and getattr(v, "shape", None) == ():
            v = item()
        return v

    out = {}
    for k, v in args.items():
        v = scalar(v)
        if isinstance(v, (bool, int, float, str, type(None))):
            out[str(k)] = v
        elif isinstance(v, (list, tuple)):
            out[str(k)] = [x if isinstance(x, (bool, int, float, str))
                           else str(x) for x in map(scalar, v)]
        else:
            out[str(k)] = str(v)
    return out


def chrome_events(events: List[TraceEvent],
                  process_names: Optional[Dict[int, str]] = None,
                  thread_names: Optional[Dict[Tuple[int, int], str]] = None,
                  ) -> List[Dict]:
    """Convert tracer events to Chrome trace-event dicts (ts/dur in µs)."""
    out: List[Dict] = []
    for pid in sorted(process_names or {}):
        out.append({"ph": "M", "name": "process_name", "pid": pid, "tid": 0,
                    "args": {"name": process_names[pid]}})
    for pid, tid in sorted(thread_names or {}):
        out.append({"ph": "M", "name": "thread_name", "pid": pid, "tid": tid,
                    "args": {"name": thread_names[(pid, tid)]}})
    for e in events:
        base = {"name": e.name, "cat": e.cat or "trace",
                "ts": e.t0 * 1e6, "pid": e.pid, "tid": e.tid}
        if e.kind == SPAN:
            base.update(ph="X", dur=(e.t1 - e.t0) * 1e6)
            args = _sanitize(e.args)
            if args:
                base["args"] = args
        elif e.kind == INSTANT:
            base.update(ph="i", s="t")
            args = _sanitize(e.args)
            if args:
                base["args"] = args
        elif e.kind == COUNTER:
            base.update(ph="C", args={e.name: e.value})
        else:  # pragma: no cover — tracer only records the three kinds
            continue
        out.append(base)
    return out


def chrome_json(tracer: Tracer,
                process_names: Optional[Dict[int, str]] = None,
                thread_names: Optional[Dict[Tuple[int, int], str]] = None,
                meta: Optional[Dict] = None) -> str:
    """The full Chrome trace document as a deterministic JSON string."""
    doc = {
        "traceEvents": chrome_events(tracer.events(), process_names,
                                     thread_names),
        "displayTimeUnit": "ms",
        "otherData": {"n_dropped": tracer.n_dropped,
                      **(_sanitize(meta) or {})},
    }
    return json.dumps(doc, sort_keys=True, separators=(",", ":")) + "\n"


def export_chrome(tracer: Tracer, path: str,
                  process_names: Optional[Dict[int, str]] = None,
                  thread_names: Optional[Dict[Tuple[int, int], str]] = None,
                  meta: Optional[Dict] = None) -> str:
    """Write the Perfetto-loadable trace file; returns the path."""
    text = chrome_json(tracer, process_names, thread_names, meta)
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    with open(path, "w") as f:
        f.write(text)
    return path


def jsonl_lines(tracer: Tracer) -> List[str]:
    """One deterministic JSON object per event, record order."""
    lines = []
    for e in tracer.events():
        d = {"kind": e.kind, "name": e.name, "cat": e.cat,
             "t0": e.t0, "t1": e.t1, "pid": e.pid, "tid": e.tid,
             "seq": e.seq}
        if e.kind == COUNTER:
            d["value"] = e.value
        args = _sanitize(e.args)
        if args:
            d["args"] = args
        lines.append(json.dumps(d, sort_keys=True, separators=(",", ":")))
    return lines


def export_jsonl(tracer: Tracer, path: str) -> str:
    """Write the flat JSONL form; returns the path."""
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    with open(path, "w") as f:
        for line in jsonl_lines(tracer):
            f.write(line + "\n")
    return path
