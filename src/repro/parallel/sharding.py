"""Logical-axis sharding rules for the production mesh.

Mesh axes (launch/mesh.py):
    single-pod : ("data", "model")                    16 x 16 = 256 chips
    multi-pod  : ("pod", "data", "model")         2 x 16 x 16 = 512 chips

Logical activation/parameter axes map onto mesh axes through LOGICAL_RULES —
the MaxText pattern, so changing a sharding strategy is a one-line rule edit
(and that is exactly what the §Perf hillclimbing iterates on).

Default strategy (the "baseline" recorded in EXPERIMENTS.md):
    batch        -> (pod, data)     pure DP across pods + data axis
    vocab/heads/mlp/experts -> model   tensor parallelism
    fsdp         -> data            parameter + optimizer-state FSDP
    kv_seq       -> model           sequence-sharded KV cache for decode

``shard(x, axes)`` applies a with_sharding_constraint when a mesh context is
active and is the identity otherwise, so the same model code runs on a
laptop CPU, in smoke tests, and on a 512-chip dry-run.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[str, None, Tuple[str, ...]]

# logical axis -> mesh axis (or tuple of mesh axes)
LOGICAL_RULES = {
    "batch": ("pod", "data"),
    "batch_nopod": ("data",),
    "fsdp": ("data",),
    "model": ("model",),
    "vocab": ("model",),
    "heads": ("model",),
    "heads_flat": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "experts": None,          # experts replicated; TP inside expert (baseline)
    "kv_seq": ("model",),     # decode: sequence-sharded KV cache
    "seq": None,              # activations: sequence replicated (baseline)
    "embed": None,
    "layers": None,           # scan/stack axis of layer params
}


class _State(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: dict = dict(LOGICAL_RULES)


_STATE = _State()


@contextlib.contextmanager
def use_mesh_rules(mesh: Optional[Mesh], rules: Optional[dict] = None):
    """Activate a mesh + logical rules for model code built inside the block."""
    prev = (_STATE.mesh, _STATE.rules)
    merged = dict(LOGICAL_RULES)
    if rules:
        merged.update(rules)
    if mesh is not None:
        # drop rules that reference axes the mesh doesn't have (e.g. "pod"
        # on the single-pod mesh)
        def _filter(v):
            if v is None:
                return None
            axes = tuple(a for a in (v if isinstance(v, tuple) else (v,))
                         if a in mesh.axis_names)
            return axes or None

        merged = {k: _filter(v) for k, v in merged.items()}
    _STATE.mesh, _STATE.rules = mesh, merged
    try:
        yield
    finally:
        _STATE.mesh, _STATE.rules = prev


def active_mesh() -> Optional[Mesh]:
    return _STATE.mesh


def active_rules() -> dict:
    return _STATE.rules


def logical_to_spec(axes: Sequence[Optional[str]]) -> P:
    """Map a tuple of logical axis names (or None) to a PartitionSpec."""
    rules = _STATE.rules
    out = []
    for a in axes:
        if a is None:
            out.append(None)
        else:
            r = rules.get(a, None)
            if r is None:
                out.append(None)
            elif isinstance(r, tuple) and len(r) == 1:
                out.append(r[0])
            else:
                out.append(r)
    return P(*out)


def shard(x, axes: Sequence[Optional[str]]):
    """with_sharding_constraint under the active mesh; identity otherwise."""
    mesh = _STATE.mesh
    if mesh is None:
        return x
    spec = logical_to_spec(axes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(axes: Sequence[Optional[str]]) -> Optional[NamedSharding]:
    mesh = _STATE.mesh
    if mesh is None:
        return None
    return NamedSharding(mesh, logical_to_spec(axes))


def mesh_axis_size(name: str) -> int:
    mesh = _STATE.mesh
    if mesh is None or name not in mesh.axis_names:
        return 1
    return mesh.shape[name]


def batch_axes() -> Tuple[str, ...]:
    """Concrete mesh axes the batch is sharded over (for shard_map specs)."""
    r = _STATE.rules.get("batch")
    if r is None:
        return ()
    return r if isinstance(r, tuple) else (r,)


def model_axes() -> Tuple[str, ...]:
    r = _STATE.rules.get("model")
    if r is None:
        return ()
    return r if isinstance(r, tuple) else (r,)
