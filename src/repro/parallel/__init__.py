from repro.parallel.sharding import (  # noqa: F401
    LOGICAL_RULES,
    active_mesh,
    logical_to_spec,
    shard,
    use_mesh_rules,
)
