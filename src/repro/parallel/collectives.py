"""Distributed-optimization collectives: int8-compressed gradient all-reduce.

The paper's C1 insight — quantize to the narrowest width the hardware moves
natively — applied to the *interconnect*: gradients are quantized to int8
with a per-tensor scale before the data-parallel all-reduce, cutting DP
collective bytes 4x (f32) / 2x (bf16). An error-feedback buffer accumulates
the quantization residual so convergence is preserved (1-bit-Adam-style EF).

``compressed_psum_tree`` runs inside shard_map over the data axes. The
integer sum itself is exact; the only lossy step is the local quantization,
which EF corrects over steps.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.layers import shard_map


def _quantize(g, axis_size: int):
    """int8 codes + scale chosen so the *summed* int32 can't overflow."""
    amax = jnp.max(jnp.abs(g))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_psum(g, axis_names, axis_size: int):
    """Quantize -> int psum -> dequant with psum'ed scales (per-shard scale).

    Exactness: each shard contributes q_i * s_i; we all-reduce the int32
    codes weighted per shard by transmitting (q_i, s_i) — implemented as
    psum of q_i * s_i reconstructed locally, i.e. psum over f32 of the
    *dequantized* tensor would defeat the purpose, so instead every shard
    uses the max scale: psum(max-scale) keeps codes commensurable.
    """
    gf = g.astype(jnp.float32)
    amax = jnp.max(jnp.abs(gf))
    amax_global = jax.lax.pmax(amax, axis_names)
    scale = jnp.maximum(amax_global, 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int32)
    total = jax.lax.psum(q, axis_names)
    mean = total.astype(jnp.float32) * (scale / axis_size)
    err = gf - q.astype(jnp.float32) * scale     # local quantization residual
    return mean.astype(g.dtype), err


def compressed_psum_tree(grads, err_state, axis_names, axis_size: int):
    """Apply compressed_psum leaf-wise with error feedback.

    grads: local (per-shard) gradient pytree; err_state: same-structure f32
    residual pytree (or None at step 0). Returns (mean_grads, new_err_state).
    """
    leaves, tdef = jax.tree.flatten(grads)
    errs = tdef.flatten_up_to(err_state) if err_state is not None else [None] * len(leaves)
    outs, new_errs = [], []
    for g, e in zip(leaves, errs):
        gin = g.astype(jnp.float32) + (e if e is not None else 0.0)
        mean, err = compressed_psum(gin, axis_names, axis_size)
        outs.append(mean.astype(g.dtype))
        new_errs.append(err)
    return tdef.unflatten(outs), tdef.unflatten(new_errs)


def collective_bytes_saved(grads, from_dtype=jnp.float32) -> int:
    """Bytes saved per DP all-reduce by the int8 compression (reporting)."""
    n = sum(g.size for g in jax.tree.leaves(grads))
    return n * (jnp.dtype(from_dtype).itemsize - 1)
