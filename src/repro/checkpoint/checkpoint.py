"""Atomic, async, resharding checkpoints — no orbax dependency.

Layout:  <dir>/step_<n>/arrays.npz + manifest.json     (atomic via tmp+rename)

Fault-tolerance properties needed at 1000+ nodes, implemented here:
  * **atomic**: a checkpoint is visible only after os.replace of the final
    directory name — a killed writer never leaves a half checkpoint that
    restore could pick up.
  * **async**: `CheckpointManager.save(..., block=False)` snapshots to host
    memory on the caller thread (cheap) and writes on a background thread,
    keeping serialization off the training critical path.
  * **elastic / resharding**: arrays are stored unsharded (gathered); restore
    device_puts onto *any* target sharding/mesh, so a job restarted on a
    different slice topology (node failure, elastic resize) resumes cleanly.
  * **retention**: keep_n oldest checkpoints are garbage-collected.

Multi-host note: on a real pod each process would write only its addressable
shards (process-local npz + a shard manifest); the single-host container
exercises the gather path. The manifest format already records shardings so
the per-host layout is a straight extension.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

from repro.obs import timer as obs_timer


def _key_str(p) -> str:
    """Stable string for any KeyEntry kind (DictKey.key, SequenceKey.idx,
    GetAttrKey.name for NamedTuples like TrainState, FlattenedIndexKey.key)."""
    for attr in ("key", "name", "idx"):
        if hasattr(p, attr):
            return str(getattr(p, attr))
    return str(p)


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat["/".join(_key_str(p) for p in path)] = np.asarray(leaf)
    return flat


def _unflatten_into(tree_like, flat: Dict[str, np.ndarray]):
    paths = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    for path, leaf in paths[0]:
        key = "/".join(_key_str(p) for p in path)
        leaves.append(flat[key])
    return jax.tree_util.tree_unflatten(paths[1], leaves)


def save(directory: str, step: int, tree, extra_meta: Optional[dict] = None):
    """Write one checkpoint atomically."""
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(tree)
    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_ckpt_")
    try:
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        manifest = {
            "step": step,
            "keys": sorted(flat.keys()),
            "shapes": {k: list(v.shape) for k, v in flat.items()},
            "dtypes": {k: str(v.dtype) for k, v in flat.items()},
            "time": obs_timer.walltime(),
        }
        if extra_meta:
            manifest["meta"] = extra_meta
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        final = os.path.join(directory, f"step_{step:010d}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and os.path.exists(
            os.path.join(directory, name, "manifest.json")
        ):
            steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(directory: str, tree_like, step: Optional[int] = None,
            shardings=None) -> Tuple[Any, int, dict]:
    """Load a checkpoint into the structure of ``tree_like``.

    ``shardings``: optional pytree (same structure) of Sharding objects —
    arrays are device_put onto them, which is how elastic restarts reshard
    onto a different mesh.
    Returns (tree, step, manifest).
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:010d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(path, "arrays.npz")) as z:
        flat = {k: z[k] for k in z.files}
    tree = _unflatten_into(tree_like, flat)
    if shardings is not None:
        sh_flat, _ = jax.tree_util.tree_flatten(shardings)
        leaves, tdef = jax.tree_util.tree_flatten(tree)
        tree = tdef.unflatten(
            [jax.device_put(l, s) for l, s in zip(leaves, sh_flat)]
        )
    return tree, step, manifest


class CheckpointManager:
    """save-every-N with async write + retention, plus auto-resume."""

    def __init__(self, directory: str, every: int = 100, keep_n: int = 3):
        self.directory = directory
        self.every = every
        self.keep_n = keep_n
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def maybe_save(self, step: int, tree, block: bool = False,
                   extra_meta: Optional[dict] = None, force: bool = False):
        if not force and (step == 0 or step % self.every != 0):
            return False
        self.wait()
        # snapshot on caller thread (device->host copy), write async
        flat_host = jax.tree.map(lambda x: np.asarray(x), tree)

        def work():
            try:
                save(self.directory, step, flat_host, extra_meta)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        if block:
            self.wait()
        return True

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1])
            for n in os.listdir(self.directory)
            if n.startswith("step_")
        )
        for s in steps[: -self.keep_n]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"),
                          ignore_errors=True)

    def restore_latest(self, tree_like, shardings=None):
        return restore(self.directory, tree_like, shardings=shardings)
