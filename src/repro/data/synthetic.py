"""Deterministic synthetic datasets standing in for the offline-unavailable
MLPerf Tiny datasets (CIFAR-10, ToyADMOS, Speech Commands) and LM token
streams.

All generators are keyed by (seed, step) through a counter-based Philox
bit-generator, so any batch is reproducible from its index alone — which is
what makes checkpoint/restart exact (the data pipeline needs no state beyond
the step number) and multi-host sharding trivial (each host draws its own
shard deterministically).

The class-structured generators plant real signal (class-dependent means /
planted anomalies) so accuracy-like metrics behave qualitatively like the
paper's (quantization cliffs, Pareto fronts) even without the real data.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np


def _rng(seed: int, step: int) -> np.random.Generator:
    return np.random.Generator(np.random.Philox(key=seed, counter=step))


@dataclasses.dataclass(frozen=True)
class SyntheticTokens:
    """LM token stream with Zipfian unigram structure + Markov bigram signal
    (so loss decreases measurably during the example trainings)."""

    vocab: int
    seq_len: int
    seed: int = 0

    def batch(self, step: int, batch_size: int) -> Dict[str, np.ndarray]:
        r = _rng(self.seed, step)
        # Zipf-ish marginal
        ranks = np.arange(1, self.vocab + 1)
        probs = 1.0 / ranks
        probs /= probs.sum()
        toks = r.choice(self.vocab, size=(batch_size, self.seq_len + 1), p=probs)
        # plant bigram predictability: with p=0.5, next = (prev*7+3) % vocab
        flip = r.random((batch_size, self.seq_len)) < 0.5
        nxt = (toks[:, :-1] * 7 + 3) % self.vocab
        toks[:, 1:] = np.where(flip, nxt, toks[:, 1:])
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }


@dataclasses.dataclass(frozen=True)
class SyntheticImages:
    """CIFAR-like (32x32x3) images with class-dependent frequency content."""

    n_classes: int = 10
    hw: int = 32
    seed: int = 0

    def batch(self, step: int, batch_size: int) -> Tuple[np.ndarray, np.ndarray]:
        r = _rng(self.seed, step)
        y = r.integers(0, self.n_classes, size=batch_size)
        xs = []
        grid = np.linspace(0, 2 * np.pi, self.hw)
        gx, gy = np.meshgrid(grid, grid)
        for c in y:
            base = (
                np.sin((c + 1) * gx)[..., None]
                + np.cos((c + 1) * gy)[..., None]
                + 0.3 * (c / self.n_classes)
            )
            img = np.repeat(base, 3, axis=-1) + 0.35 * r.standard_normal((self.hw, self.hw, 3))
            xs.append(img)
        x = np.stack(xs).astype(np.float32)
        return x / np.abs(x).max(), y.astype(np.int32)


@dataclasses.dataclass(frozen=True)
class SyntheticMelWindows:
    """AD task stand-in: 128-dim mel windows; normals live on a low-rank
    manifold, anomalies get off-manifold noise (so AUC is meaningful)."""

    dim: int = 128
    rank: int = 8
    seed: int = 0

    def _basis(self) -> np.ndarray:
        r = _rng(self.seed, 0)
        b, _ = np.linalg.qr(r.standard_normal((self.dim, self.rank)))
        return b

    def batch(self, step: int, batch_size: int, anomaly_frac: float = 0.0):
        r = _rng(self.seed, step + 1)
        basis = self._basis()
        z = r.standard_normal((batch_size, self.rank))
        x = z @ basis.T + 0.05 * r.standard_normal((batch_size, self.dim))
        n_anom = int(batch_size * anomaly_frac)
        y = np.zeros(batch_size, np.int32)
        if n_anom:
            x[:n_anom] += 0.7 * r.standard_normal((n_anom, self.dim))
            y[:n_anom] = 1
        return x.astype(np.float32), y


@dataclasses.dataclass(frozen=True)
class SyntheticMFCC:
    """KWS stand-in: 490-dim MFCC-like features, 12 classes with imbalanced
    'unknown' class (paper: ~17x more frequent) and class-dependent means."""

    dim: int = 490
    n_classes: int = 12
    unknown_class: int = 11
    unknown_boost: float = 17.0
    seed: int = 0

    def class_probs(self) -> np.ndarray:
        p = np.ones(self.n_classes)
        p[self.unknown_class] = self.unknown_boost
        return p / p.sum()

    def batch(self, step: int, batch_size: int, balanced: bool = False):
        r = _rng(self.seed, step + 7)
        if balanced:
            y = r.integers(0, self.n_classes, size=batch_size)
        else:
            y = r.choice(self.n_classes, size=batch_size, p=self.class_probs())
        protos = _rng(self.seed, 1).standard_normal((self.n_classes, self.dim))
        x = protos[y] + 0.8 * r.standard_normal((batch_size, self.dim))
        return x.astype(np.float32), y.astype(np.int32)
