from repro.data.synthetic import (  # noqa: F401
    SyntheticImages,
    SyntheticMelWindows,
    SyntheticMFCC,
    SyntheticTokens,
)
from repro.data.pipeline import DataPipeline  # noqa: F401
