"""Sharded, prefetching input pipeline.

The prefetch-buffer depth is sized by the paper's FIFO-depth logic
(core/dataflow.prefetch_depth): simulate producer/consumer rates, size the
buffer to max occupancy + 1 — on TPU the "FIFO" is the host-side prefetch
queue that hides data-generation latency behind the device step.

Multi-host design: batches are functions of (seed, step), so each process
can build exactly its addressable shard with jax.make_array_from_callback —
no inter-host data traffic, no pipeline state to checkpoint beyond `step`.
"""

from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import numpy as np

from repro.core.dataflow import prefetch_depth


class DataPipeline:
    """Wraps a ``batch_fn(step) -> pytree of np arrays`` with prefetch."""

    def __init__(
        self,
        batch_fn: Callable[[int], Any],
        start_step: int = 0,
        producer_period_s: float = 0.001,
        consumer_period_s: float = 0.01,
        sharding: Optional[jax.sharding.Sharding] = None,
    ):
        self.batch_fn = batch_fn
        self.sharding = sharding
        self.depth = prefetch_depth(producer_period_s, consumer_period_s)
        self._q: "queue.Queue" = queue.Queue(maxsize=self.depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._produce, daemon=True)
        self._thread.start()

    def _produce(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.batch_fn(step)
            if self.sharding is not None:
                batch = jax.tree.map(
                    lambda x: jax.device_put(x, self.sharding), batch
                )
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        step, batch = self._q.get()
        return step, batch

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)

    def __enter__(self):
        return self

    def __exit__(self, *a):
        self.close()
