"""Reproducible training table from the measurement exhaust of the stack.

Three harvest sources, all things the repo already emits:

- ``obs.report.prediction_records`` traces — per-wave (predicted, measured)
  service times from any traced server run (the richest source; also
  carries the analytic FIFO prediction as the baseline column);
- ``TunedConfig`` audit trails — every measured probe the autotuner paid
  for (micro-batch candidates, the block_mn refinement probe, the
  megakernel-vs-staged probe) becomes a labeled row instead of being
  thrown away;
- accumulated ``BENCH_*.json`` — the per-model wave-service anchors the
  serving benchmark publishes.

Rows join a target (measured per-wave milliseconds) with the versioned
feature schema via a caller-supplied resolver ``features_for(model,
platform, micro_batch, segment_mode) -> dict | None`` (``None`` skips the
row — e.g. a trace naming a model this process has not compiled).

Determinism contract: ``Dataset.to_json_str`` sorts rows by a total key
and serializes with fixed separators + sorted keys, so the same input
records — in any order — produce a byte-identical on-disk table. That is
what makes a retrained predictor artifact reproducible from archived CI
artifacts alone.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.costmodel.features import FEATURE_NAMES, FEATURE_SCHEMA_VERSION

DATASET_SCHEMA_VERSION = 1

#: features_for(model, platform, micro_batch, segment_mode) -> feats | None
FeatureResolver = Callable[[str, str, int, Optional[str]],
                           Optional[Dict[str, float]]]


def _row(model: str, platform: str, source: str, micro_batch: int,
         segment_mode: Optional[str], measured_ms: float,
         analytic_ms: Optional[float],
         feats: Dict[str, float]) -> Dict:
    return {
        "model": str(model),
        "platform": str(platform),
        "source": str(source),
        "micro_batch": int(micro_batch),
        "segment_mode": segment_mode,
        "measured_ms": float(measured_ms),
        "analytic_ms": None if analytic_ms is None else float(analytic_ms),
        "features": {k: float(feats[k]) for k in FEATURE_NAMES},
    }


def rows_from_trace_records(records: Iterable[Dict],
                            features_for: FeatureResolver) -> List[Dict]:
    """Rows from ``obs.report.prediction_records`` output (or its JSONL
    export): one labeled wave per record, analytic FIFO prediction kept as
    the baseline column."""
    rows = []
    for r in records:
        measured = float(r.get("measured_ms") or 0.0)
        if measured <= 0.0:
            continue
        mb = int(r.get("micro_batch") or 0)
        if mb <= 0:
            continue
        feats = features_for(r["model"], r.get("platform", "cpu"), mb,
                             r.get("segment_mode"))
        if feats is None:
            continue
        rows.append(_row(r["model"], r.get("platform", "cpu"), "trace", mb,
                         r.get("segment_mode"), measured,
                         r.get("predicted_ms"), feats))
    return rows


def _config_model_name(cfg: Dict) -> str:
    # TunedConfig.key is "<Model>-<backend>-<schedule digest>"
    key = str(cfg.get("key", ""))
    parts = key.rsplit("-", 2)
    return parts[0] if len(parts) == 3 else key


def rows_from_tuned_config(cfg, features_for: FeatureResolver) -> List[Dict]:
    """Rows from one ``TunedConfig`` audit trail (dataclass or dict).

    Every measured probe becomes a row: micro-batch candidates
    (``probe_ms`` over ``n_micro`` waves), the megakernel-vs-staged probe,
    and the block_mn refinement probe. Model-mode configs contribute
    nothing — their candidates carry predictions, not measurements.
    """
    if hasattr(cfg, "to_dict"):
        cfg = cfg.to_dict()
    model = _config_model_name(cfg)
    platform = str(cfg.get("platform", "cpu"))
    mode = cfg.get("segment_mode") or "staged"
    rows = []
    for cand in cfg.get("candidates") or []:
        probe = cand.get("probe_ms")
        n_micro = int(cand.get("n_micro") or 0)
        mb = int(cand.get("micro_batch") or 0)
        if probe is None or n_micro <= 0 or mb <= 0:
            continue
        feats = features_for(model, platform, mb, mode)
        if feats is None:
            continue
        rows.append(_row(model, platform, "autotune", mb, mode,
                         float(probe) / n_micro, None, feats))
    seg = cfg.get("segment_mode_model") or {}
    seg_probe = seg.get("probe_ms") or {}
    n_micro = int(seg.get("n_micro") or 0)
    wave = int(seg.get("wave_rows") or cfg.get("micro_batch") or 0)
    if n_micro > 0 and wave > 0:
        for seg_mode, ms in sorted(seg_probe.items()):
            if ms is None:
                continue
            feats = features_for(model, platform, wave, seg_mode)
            if feats is None:
                continue
            rows.append(_row(model, platform, "autotune", wave, seg_mode,
                             float(ms) / n_micro, None, feats))
    blk = cfg.get("block_mn_probe") or {}
    blk_probe = blk.get("probe_ms") or {}
    n_micro = int(blk.get("n_micro") or 0)
    wave = int(blk.get("wave_rows") or 0)
    if n_micro > 0 and wave > 0:
        for pick, ms in sorted(blk_probe.items()):
            if ms is None:
                continue
            feats = features_for(model, platform, wave, mode)
            if feats is None:
                continue
            rows.append(_row(model, platform, "autotune", wave, mode,
                             float(ms) / n_micro, None, feats))
    return rows


def rows_from_bench_doc(doc: Dict,
                        features_for: FeatureResolver) -> List[Dict]:
    """Rows from an accumulated ``BENCH_*.json`` document.

    Currently understands the serving benchmark's per-model anchors
    (``doc["models"][name]["wave_service_ms" | "micro_batch"]``); other
    documents contribute nothing rather than erroring, so a whole
    artifact directory can be fed in unfiltered.
    """
    rows = []
    platform = str(doc.get("provenance", {}).get("backend",
                                                 doc.get("backend", "cpu")))
    for name, entry in sorted((doc.get("models") or {}).items()):
        if not isinstance(entry, dict):
            continue
        ms = entry.get("wave_service_ms")
        mb = int(entry.get("micro_batch") or 0)
        if ms is None or float(ms) <= 0.0 or mb <= 0:
            continue
        feats = features_for(name, platform, mb, entry.get("segment_mode"))
        if feats is None:
            continue
        rows.append(_row(name, platform, "bench", mb,
                         entry.get("segment_mode"), float(ms), None, feats))
    return rows


def load_trace_records(path: str) -> List[Dict]:
    """Read a JSONL shard written by ``obs.report.export_prediction_records``."""
    records = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                records.append(json.loads(line))
    return records


@dataclasses.dataclass
class Dataset:
    """The on-disk training table: versioned features joined with targets."""

    rows: List[Dict]
    feature_names: Tuple[str, ...] = FEATURE_NAMES
    schema_version: int = FEATURE_SCHEMA_VERSION

    def __post_init__(self):
        self.rows = sorted(self.rows, key=_sort_key)

    def X(self) -> np.ndarray:
        return np.array([[r["features"][k] for k in self.feature_names]
                         for r in self.rows], np.float64)

    def y_ms(self) -> np.ndarray:
        return np.array([r["measured_ms"] for r in self.rows], np.float64)

    def models(self) -> List[str]:
        return sorted({r["model"] for r in self.rows})

    def to_json_str(self) -> str:
        doc = {
            "dataset_schema_version": DATASET_SCHEMA_VERSION,
            "feature_schema_version": int(self.schema_version),
            "feature_names": list(self.feature_names),
            "n_rows": len(self.rows),
            "rows": self.rows,
        }
        return json.dumps(doc, sort_keys=True, separators=(",", ":")) + "\n"

    def save(self, path: str) -> str:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            f.write(self.to_json_str())
        return path

    @classmethod
    def load(cls, path: str) -> "Dataset":
        with open(path) as f:
            doc = json.load(f)
        if int(doc["feature_schema_version"]) != FEATURE_SCHEMA_VERSION:
            raise ValueError(
                f"dataset feature schema v{doc['feature_schema_version']} "
                f"!= v{FEATURE_SCHEMA_VERSION}; rebuild the table")
        return cls(rows=doc["rows"],
                   feature_names=tuple(doc["feature_names"]),
                   schema_version=int(doc["feature_schema_version"]))


def _sort_key(r: Dict):
    return (r["model"], r["platform"], r["source"], r["micro_batch"],
            r["segment_mode"] or "", r["measured_ms"],
            -1.0 if r["analytic_ms"] is None else r["analytic_ms"])


def build_dataset(features_for: FeatureResolver, *,
                  trace_records: Iterable[Dict] = (),
                  tuned_configs: Iterable = (),
                  bench_docs: Iterable[Dict] = ()) -> Dataset:
    """Join all three harvest sources into one deterministic table."""
    rows: List[Dict] = []
    rows.extend(rows_from_trace_records(trace_records, features_for))
    for cfg in tuned_configs:
        rows.extend(rows_from_tuned_config(cfg, features_for))
    for doc in bench_docs:
        rows.extend(rows_from_bench_doc(doc, features_for))
    return Dataset(rows=rows)


def compiled_feature_resolver(models: Dict[str, object]) -> FeatureResolver:
    """The standard resolver: look the model name up in a dict of
    ``CompiledTinyModel``s and extract ``wave_features``. Unknown names
    resolve to ``None`` (row skipped) so traces mentioning models this
    process never compiled are harvested gracefully."""
    from repro.costmodel.features import wave_features

    def resolve(model: str, platform: str, micro_batch: int,
                segment_mode: Optional[str]) -> Optional[Dict[str, float]]:
        cm = models.get(model)
        if cm is None:
            return None
        return wave_features(cm, micro_batch, segment_mode)

    return resolve
