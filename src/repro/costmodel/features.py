"""Deterministic wave features from static compiled-model structure.

Everything here is pure arithmetic over the compiled schedule — no clocks,
no probes, no RNG — so the same model at the same micro-batch always maps
to the same feature vector, on any machine. That determinism is what makes
``REPRO_AUTOTUNE=model`` reproducible and the dataset builder byte-stable.

The schema is versioned: ``FEATURE_SCHEMA_VERSION`` must be bumped whenever
``FEATURE_NAMES`` (names, order, or semantics) changes, and every shipped
predictor artifact records the version it was trained under
(``scripts/check_costmodel_schema.py`` enforces the match in ``make lint``).

Feature sources mirror the hand-built cost models the predictor is meant to
beat, plus the structural terms they ignore:

- ``log_wave_cycles`` — the analytic FIFO fill/drain cost of one wave
  (``core.dataflow.micro_batch_stage`` summed over stages), the backbone
  the autotuner ranks micro-batches by today;
- Eq. 1 BOPs / schedule traffic / parameter bytes (``core.bops``);
- conv banded-input bytes at the planned ``block_h`` and megakernel
  residency bytes (the tiling/dispatch terms);
- stage/segment counts and widths — the per-wave *dispatch overhead*
  proxies the FIFO model has no term for (the +0.7 AD bias in
  ``BENCH_obs.json`` lives here), which is exactly what a model trained on
  measured waves can learn.
"""

from __future__ import annotations

import math
from typing import Dict, Optional

import numpy as np

#: Bump when FEATURE_NAMES (names, order, or semantics) changes. Shipped
#: predictor artifacts record the version they were trained under; loading
#: a mismatched artifact is an error, never a silent misread.
FEATURE_SCHEMA_VERSION = 1

#: Canonical feature order. ``feature_vector`` lays dicts out in exactly
#: this order; predictor artifacts store the list and refuse to score a
#: different one.
FEATURE_NAMES = (
    "log_wave_cycles",        # FIFO fill+drain cycles of one wave at mb
    "log_micro_batch",
    "log_bops",               # Eq. 1 BOPs per sample (whole schedule)
    "log_traffic_bytes",      # per-sample schedule memory traffic
    "log_param_bytes",        # resident weight codes + threshold banks
    "log_band_bytes",         # conv banded-input bytes at planned block_h
    "log_residency_bytes",    # megakernel VMEM working set (0 when staged)
    "log_wave_traffic_bytes", # dispatch-mode-aware traffic of one wave
    "n_stages",
    "n_segments",             # host dispatch hops per wave
    "n_conv_stages",
    "n_dense_stages",
    "log_max_width",          # widest stage in/out dim
    "megakernel",             # 1.0 when the wave dispatches fused runs
)


def feature_vector(feats: Dict[str, float]) -> np.ndarray:
    """Lay a feature dict out in ``FEATURE_NAMES`` order.

    Raises ``KeyError`` on a missing feature — a silent zero-fill would
    let a schema drift slip past the predictor unnoticed.
    """
    return np.array([float(feats[name]) for name in FEATURE_NAMES],
                    dtype=np.float64)


def features_from_costs(*, wave_cycles: float, micro_batch: int,
                        bops: float, traffic_bytes: float,
                        param_bytes: float, band_bytes: float = 0.0,
                        residency_bytes: float = 0.0,
                        wave_traffic_bytes: Optional[float] = None,
                        n_stages: int, n_segments: int = 1,
                        n_conv_stages: int = 0, n_dense_stages: int = 0,
                        max_width: float = 1.0,
                        megakernel: bool = False) -> Dict[str, float]:
    """Assemble the schema dict from raw cost numbers.

    The shared low-level constructor: ``wave_features`` feeds it numbers
    measured off a compiled model, ``features_from_model_cost`` feeds it
    numbers from an uncompiled search-space point, and the synthetic
    bootstrap fleet feeds it a grid — all three paths emit the identical
    schema.
    """
    if wave_traffic_bytes is None:
        wave_traffic_bytes = float(micro_batch) * float(traffic_bytes)
    return {
        "log_wave_cycles": math.log1p(max(float(wave_cycles), 0.0)),
        "log_micro_batch": math.log1p(max(int(micro_batch), 1)),
        "log_bops": math.log1p(max(float(bops), 0.0)),
        "log_traffic_bytes": math.log1p(max(float(traffic_bytes), 0.0)),
        "log_param_bytes": math.log1p(max(float(param_bytes), 0.0)),
        "log_band_bytes": math.log1p(max(float(band_bytes), 0.0)),
        "log_residency_bytes": math.log1p(max(float(residency_bytes), 0.0)),
        "log_wave_traffic_bytes": math.log1p(
            max(float(wave_traffic_bytes), 0.0)),
        "n_stages": float(n_stages),
        "n_segments": float(n_segments),
        "n_conv_stages": float(n_conv_stages),
        "n_dense_stages": float(n_dense_stages),
        "log_max_width": math.log1p(max(float(max_width), 1.0)),
        "megakernel": 1.0 if megakernel else 0.0,
    }


def _resolve_segment_mode(cm, segment_mode: Optional[str]) -> str:
    """``None`` means "whatever the compiled model would dispatch"."""
    if segment_mode in ("megakernel", "staged"):
        return segment_mode
    if getattr(cm, "megakernel", False) is False:
        return "staged"
    return "megakernel" if getattr(cm, "_mega_plans", None) else "staged"


def wave_features(cm, micro_batch: int,
                  segment_mode: Optional[str] = None) -> Dict[str, float]:
    """Feature dict for one wave of a ``CompiledTinyModel`` at a micro-batch.

    ``segment_mode`` forces the dispatch flavor the features describe
    ("staged" | "megakernel"); ``None`` follows the model's current mode.
    Forcing "megakernel" re-plans residency from the schedule (independent
    of ``cm.megakernel``) so the autotuner can score both flavors of the
    same model without mutating it.
    """
    from repro.core.bops import (conv_input_band_bytes,
                                 megakernel_residency_bytes,
                                 megakernel_traffic_bytes, schedule_cost,
                                 staged_traffic_bytes)
    from repro.core.dataflow import micro_batch_stage
    from repro.deploy.executor import stage_work
    from repro.deploy.lower import plan_megakernel

    mb = max(int(micro_batch), 1)
    stages = cm.schedule.stages
    mode = _resolve_segment_mode(cm, segment_mode)

    wave_cycles = sum(
        micro_batch_stage(s.name, stage_work(s), mb).latency for s in stages)

    mc = schedule_cost(stages)
    bops, traffic = float(mc.bops), float(mc.traffic_bytes)

    param_bytes = 0.0
    band_bytes = 0.0
    n_conv = n_dense = 0
    max_width = 1.0
    for s in stages:
        max_width = max(max_width, float(getattr(s, "in_dim", 0)),
                        float(getattr(s, "out_dim", 0)))
        bank = getattr(s, "stage", None)       # ThresholdDense, if fused
        if bank is not None:
            param_bytes += float(math.prod(bank.w_int.shape))
            param_bytes += 4.0 * float(math.prod(bank.thresholds.shape))
        w = getattr(s, "w", None)              # FloatHeadStage
        if w is not None:
            param_bytes += 4.0 * float(math.prod(w.shape))
        geom = getattr(s, "geom", None)
        if geom is not None:
            n_conv += 1
            bh = getattr(s, "block_h", None)
            if not bh:
                from repro.kernels.ops import plan_conv_blocks

                bh = plan_conv_blocks(geom.out_h, geom.out_w, geom.out_ch)
            band_bytes += conv_input_band_bytes(geom, bh)
        elif bank is not None or w is not None:
            n_dense += 1

    # Dispatch-mode-aware wave traffic: start from the staged per-sample
    # model scaled by the wave, then swap each planned fused run's staged
    # bytes for its residency-aware bytes when scoring the megakernel mode.
    # Plans are recomputed from the schedule so a "megakernel" score never
    # depends on what mode the model object currently happens to be in.
    wave_traffic = float(mb) * traffic
    residency = 0.0
    is_mega = False
    if mode == "megakernel":
        for seg in cm.segments:
            plan = plan_megakernel(
                stages, seg,
                budget_bytes=getattr(cm, "megakernel_budget_bytes", None))
            if plan is None:
                continue
            is_mega = True
            run = stages[plan.start:plan.stop]
            res = megakernel_residency_bytes(run, block_m=plan.block_m)
            residency += float(res["total_bytes"])
            wave_traffic += (megakernel_traffic_bytes(run, mb)
                             - staged_traffic_bytes(run, mb))

    return features_from_costs(
        wave_cycles=wave_cycles, micro_batch=mb, bops=bops,
        traffic_bytes=traffic, param_bytes=param_bytes,
        band_bytes=band_bytes, residency_bytes=residency,
        wave_traffic_bytes=wave_traffic, n_stages=len(stages),
        n_segments=len(cm.segments), n_conv_stages=n_conv,
        n_dense_stages=n_dense, max_width=max_width, megakernel=is_mega)


def features_from_model_cost(mc, micro_batch: int, *, n_segments: int = 1,
                             n_conv_stages: int = 0,
                             megakernel: bool = False) -> Dict[str, float]:
    """Feature dict for an *uncompiled* search-space point.

    ``benchmarks/fig2``/``fig3`` score quantization × tiling × micro-batch
    sweeps against the predictor without ever compiling or running the
    candidate — the codesign loop at fleet scale. Structural terms the
    ``core.bops.ModelCost`` cannot carry (per-stage widths, band bytes) are
    approximated from layer parameter counts; the approximation is
    monotone in the same quantities the trained features are, which is all
    a *ranking* sweep needs.
    """
    from repro.core.dataflow import micro_batch_stage

    mb = max(int(micro_batch), 1)
    layers = mc.layers
    wave_cycles = sum(
        micro_batch_stage(l.name, max(int(l.flops // 2), 1), mb).latency
        for l in layers)
    param_bytes = float(mc.wm_bits) / 8.0
    traffic = float(mc.traffic_bytes) or param_bytes
    max_width = max((math.sqrt(max(l.n_params, 1)) for l in layers),
                    default=1.0)
    return features_from_costs(
        wave_cycles=wave_cycles, micro_batch=mb, bops=float(mc.bops),
        traffic_bytes=traffic, param_bytes=param_bytes,
        n_stages=len(layers), n_segments=n_segments,
        n_conv_stages=n_conv_stages,
        n_dense_stages=len(layers) - n_conv_stages,
        max_width=max_width, megakernel=megakernel)
