"""Seedable pure-numpy wave-cost predictor with save/load artifacts.

A bagged ridge regressor in log-millisecond space: features are
standardized against the training set, each ensemble member fits a
closed-form L2 solution on a seeded bootstrap resample, and predictions
take the member median — GBM-lite robustness to the outlier waves a serve
trace always contains (GC pauses, first-dispatch compiles) without any new
dependency. Everything is deterministic given ``seed``, so a saved
artifact retrains byte-identically from the same dataset.

Artifacts are plain JSON carrying the feature schema version and the
feature-name list they were trained under; ``WaveCostPredictor.load``
refuses a schema mismatch (``scripts/check_costmodel_schema.py`` runs the
same check against the shipped default in ``make lint``).
"""

from __future__ import annotations

import dataclasses
import json
import math
import os
from typing import Dict, Iterable, List, Optional, Sequence, Union

import numpy as np

from repro.costmodel.features import (FEATURE_NAMES, FEATURE_SCHEMA_VERSION,
                                      features_from_costs)

#: Env var pointing at an alternative predictor artifact; the shipped
#: bootstrap-trained default is used when unset.
ARTIFACT_ENV = "REPRO_COSTMODEL_ARTIFACT"

_EPS_MS = 1e-6


def default_artifact_path() -> str:
    """Shipped artifact, overridable via ``REPRO_COSTMODEL_ARTIFACT``."""
    env = os.environ.get(ARTIFACT_ENV, "").strip()
    if env:
        return env
    return os.path.join(os.path.dirname(__file__), "artifacts",
                        "default.json")


@dataclasses.dataclass
class WaveCostPredictor:
    """Bagged ridge over the versioned feature schema, predicting wave ms."""

    feature_names: List[str]
    schema_version: int
    mean: np.ndarray              # (F,) feature standardization
    std: np.ndarray               # (F,)
    weights: np.ndarray           # (members, F + 1); last column is bias
    l2: float
    seed: int
    log_target: bool = True
    meta: Dict = dataclasses.field(default_factory=dict)

    # -- fitting ----------------------------------------------------------

    @classmethod
    def fit(cls, X: np.ndarray, y_ms: np.ndarray, *, l2: float = 1e-2,
            seed: int = 0, n_members: int = 8, subsample: float = 1.0,
            feature_names: Sequence[str] = FEATURE_NAMES,
            meta: Optional[Dict] = None) -> "WaveCostPredictor":
        X = np.asarray(X, np.float64)
        y = np.asarray(y_ms, np.float64)
        if X.ndim != 2 or X.shape[0] != y.shape[0] or X.shape[0] == 0:
            raise ValueError(
                f"bad training shapes X={X.shape} y={y.shape}")
        if X.shape[1] != len(feature_names):
            raise ValueError(
                f"{X.shape[1]} feature columns != "
                f"{len(feature_names)} feature names")
        mean = X.mean(axis=0)
        std = X.std(axis=0)
        std = np.where(std < 1e-12, 1.0, std)
        Z = (X - mean) / std
        t = np.log(np.maximum(y, _EPS_MS))
        n, f = Z.shape
        eye = np.eye(f + 1)
        eye[-1, -1] = 0.0                      # never regularize the bias
        members = []
        k = max(1, int(round(subsample * n)))
        for m in range(max(int(n_members), 1)):
            rng = np.random.default_rng(int(seed) * 100003 + m)
            idx = (rng.integers(0, n, size=k) if n_members > 1
                   else np.arange(n))
            A = np.hstack([Z[idx], np.ones((len(idx), 1))])
            w = np.linalg.solve(A.T @ A + float(l2) * eye, A.T @ t[idx])
            members.append(w)
        return cls(feature_names=list(feature_names),
                   schema_version=FEATURE_SCHEMA_VERSION, mean=mean,
                   std=std, weights=np.stack(members), l2=float(l2),
                   seed=int(seed), meta=dict(meta or {}))

    @classmethod
    def fit_rows(cls, rows: Iterable[Dict], **kw) -> "WaveCostPredictor":
        """Fit from dataset rows ({"features": {...}, "measured_ms": y})."""
        rows = list(rows)
        names = kw.get("feature_names", FEATURE_NAMES)
        X = np.array([[r["features"][k] for k in names] for r in rows],
                     np.float64)
        y = np.array([r["measured_ms"] for r in rows], np.float64)
        return cls.fit(X, y, **kw)

    # -- scoring ----------------------------------------------------------

    def predict_ms(self, feats: Union[Dict[str, float], np.ndarray]
                   ) -> Union[float, np.ndarray]:
        """Predicted wave service milliseconds.

        Accepts one feature dict, one (F,) vector, or an (N, F) matrix;
        scalar in, scalar out.
        """
        if isinstance(feats, dict):
            x = np.array([[float(feats[k]) for k in self.feature_names]],
                         np.float64)
            return float(self._predict(x)[0])
        x = np.asarray(feats, np.float64)
        if x.ndim == 1:
            return float(self._predict(x[None, :])[0])
        return self._predict(x)

    def _predict(self, X: np.ndarray) -> np.ndarray:
        Z = (X - self.mean) / self.std
        A = np.hstack([Z, np.ones((Z.shape[0], 1))])
        per_member = A @ self.weights.T                 # (N, members)
        z = np.median(per_member, axis=1)
        return np.exp(z) if self.log_target else z

    # -- artifacts --------------------------------------------------------

    def to_dict(self) -> Dict:
        return {
            "kind": "ridge_bag",
            "schema_version": int(self.schema_version),
            "feature_names": list(self.feature_names),
            "mean": [float(v) for v in self.mean],
            "std": [float(v) for v in self.std],
            "weights": [[float(v) for v in row] for row in self.weights],
            "l2": float(self.l2),
            "seed": int(self.seed),
            "log_target": bool(self.log_target),
            "meta": self.meta,
        }

    @classmethod
    def from_dict(cls, d: Dict) -> "WaveCostPredictor":
        if int(d["schema_version"]) != FEATURE_SCHEMA_VERSION:
            raise ValueError(
                f"predictor artifact schema v{d['schema_version']} != "
                f"feature schema v{FEATURE_SCHEMA_VERSION}; retrain the "
                "artifact (see docs/costmodel.md)")
        if list(d["feature_names"]) != list(FEATURE_NAMES):
            raise ValueError(
                "predictor artifact feature names do not match "
                "repro.costmodel.features.FEATURE_NAMES")
        return cls(feature_names=list(d["feature_names"]),
                   schema_version=int(d["schema_version"]),
                   mean=np.asarray(d["mean"], np.float64),
                   std=np.asarray(d["std"], np.float64),
                   weights=np.asarray(d["weights"], np.float64),
                   l2=float(d["l2"]), seed=int(d["seed"]),
                   log_target=bool(d.get("log_target", True)),
                   meta=dict(d.get("meta", {})))

    def save(self, path: str) -> str:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, sort_keys=True, indent=1)
            f.write("\n")
        return path

    @classmethod
    def load(cls, path: Optional[str] = None) -> "WaveCostPredictor":
        with open(path or default_artifact_path()) as f:
            return cls.from_dict(json.load(f))


def load_default() -> WaveCostPredictor:
    """The shipped (or ``REPRO_COSTMODEL_ARTIFACT``-overridden) predictor."""
    return WaveCostPredictor.load(default_artifact_path())


# ---------------------------------------------------------------------------
# validation
# ---------------------------------------------------------------------------

def _abs_rel_err(measured: np.ndarray, predicted: np.ndarray) -> np.ndarray:
    # same convention as obs.report.prediction_error: error relative to the
    # *prediction*, so the learned and analytic columns compare one-to-one
    # with the BENCH_obs.json baseline
    return np.abs(measured - predicted) / np.maximum(predicted, _EPS_MS)


def leave_one_model_out(rows: Sequence[Dict], **fit_kw) -> Dict[str, Dict]:
    """LOMO validation: hold out each model family, train on the rest.

    Rows are dataset rows (``dataset.Dataset.rows``). Returns per-held-out
    model ``median_abs_rel_err`` / ``mean_abs_rel_err`` for the learned
    predictor and — where rows carry the analytic FIFO prediction
    (``analytic_ms``) — the same stats for the hand-built baseline, plus a
    pooled "overall" entry. The acceptance bar is learned ≤ analytic on
    the pooled median: the learned model must beat the cost model it was
    bootstrapped from.
    """
    rows = list(rows)
    models = sorted({r["model"] for r in rows})
    out: Dict[str, Dict] = {}
    pooled_learned: List[float] = []
    pooled_analytic: List[float] = []
    for held in models:
        train = [r for r in rows if r["model"] != held]
        test = [r for r in rows if r["model"] == held]
        if not train or not test:
            continue
        pred = WaveCostPredictor.fit_rows(train, **fit_kw)
        names = pred.feature_names
        X = np.array([[r["features"][k] for k in names] for r in test])
        meas = np.array([r["measured_ms"] for r in test], np.float64)
        learned = _abs_rel_err(meas, np.asarray(pred.predict_ms(X)))
        entry = {
            "n": len(test),
            "median_abs_rel_err": float(np.median(learned)),
            "mean_abs_rel_err": float(np.mean(learned)),
        }
        pooled_learned.extend(learned.tolist())
        analytic_pairs = [(r["measured_ms"], r["analytic_ms"])
                          for r in test if r.get("analytic_ms") is not None]
        if analytic_pairs:
            am = np.array([p[0] for p in analytic_pairs], np.float64)
            ap = np.array([p[1] for p in analytic_pairs], np.float64)
            analytic = _abs_rel_err(am, ap)
            entry["analytic_median_abs_rel_err"] = float(np.median(analytic))
            entry["analytic_mean_abs_rel_err"] = float(np.mean(analytic))
            pooled_analytic.extend(analytic.tolist())
        out[held] = entry
    overall: Dict[str, float] = {"n": len(pooled_learned)}
    if pooled_learned:
        overall["median_abs_rel_err"] = float(np.median(pooled_learned))
        overall["mean_abs_rel_err"] = float(np.mean(pooled_learned))
    if pooled_analytic:
        overall["analytic_median_abs_rel_err"] = float(
            np.median(pooled_analytic))
        overall["analytic_mean_abs_rel_err"] = float(
            np.mean(pooled_analytic))
    out["overall"] = overall
    return out


# ---------------------------------------------------------------------------
# bootstrap fleet — the synthetic prior behind the shipped default artifact
# ---------------------------------------------------------------------------

#: Synthetic cost law the bootstrap fleet is labeled with: CPU-flavored
#: seconds-per-FIFO-cycle, per-segment host dispatch overhead, and a
#: per-byte traffic term. The *constants* are rough; what matters is that
#: the shipped prior already knows "cycles + dispatch hops + bytes" so a
#: cold fleet gets sane rankings before any measured rows arrive, and
#: retraining on real traces only sharpens it.
BOOTSTRAP_SEC_PER_CYCLE = 2e-9
BOOTSTRAP_SEC_PER_SEGMENT = 8e-5
BOOTSTRAP_SEC_PER_BYTE = 2e-10


def bootstrap_rows(seed: int = 0) -> List[Dict]:
    """Deterministic synthetic fleet: a grid of MLP/conv-ish structures ×
    micro-batches, labeled by the analytic cost law above. No RNG, no
    clocks — the same rows on every machine, so the committed default
    artifact is reproducible from source."""
    del seed  # grid is fully deterministic; kept for signature stability
    rows: List[Dict] = []
    widths = (16, 64, 256, 512)
    depths = (2, 4, 8)
    micro_batches = (1, 4, 16, 64)
    for w in widths:
        for d in depths:
            for mb in micro_batches:
                for n_seg in (1, 2):
                    for mega in (False, True):
                        work = w * w
                        # mirror core.dataflow.micro_batch_stage's law
                        cyc = d * (8 + max(1, math.ceil(work * mb / 8192)))
                        params = float(d * (w * w + 4 * w * 3))
                        traffic = params + 4.0 * 2 * w * d
                        residency = params if mega else 0.0
                        wave_traffic = (params + 4.0 * mb * 2 * w if mega
                                        else mb * traffic)
                        # cycles + host hops + per-program launches + bytes
                        sec = (cyc * BOOTSTRAP_SEC_PER_CYCLE
                               + n_seg * BOOTSTRAP_SEC_PER_SEGMENT
                               + (n_seg if mega else d) * 0.25
                               * BOOTSTRAP_SEC_PER_SEGMENT
                               + wave_traffic * BOOTSTRAP_SEC_PER_BYTE)
                        feats = features_from_costs(
                            wave_cycles=cyc, micro_batch=mb,
                            bops=64.0 * work * d, traffic_bytes=traffic,
                            param_bytes=params, residency_bytes=residency,
                            wave_traffic_bytes=wave_traffic, n_stages=d,
                            n_segments=n_seg, n_dense_stages=d,
                            max_width=w, megakernel=mega)
                        rows.append({
                            "model": f"boot_w{w}_d{d}_s{n_seg}",
                            "platform": "bootstrap",
                            "source": "bootstrap",
                            "micro_batch": mb,
                            "segment_mode": ("megakernel" if mega
                                             else "staged"),
                            "measured_ms": sec * 1e3,
                            "analytic_ms": None,
                            "features": feats,
                        })
    return rows


def make_default_artifact(path: Optional[str] = None) -> str:
    """(Re)train the shipped default artifact from the bootstrap fleet."""
    target = path or os.path.join(os.path.dirname(__file__), "artifacts",
                                  "default.json")
    pred = WaveCostPredictor.fit_rows(
        bootstrap_rows(), l2=1e-2, seed=0, n_members=8,
        meta={"trained_on": "bootstrap_rows", "n_rows": len(bootstrap_rows())})
    return pred.save(target)
