"""repro.costmodel — learned wave-cost predictor (ROADMAP direction 5).

The autotuner and the SLO admission controller both need to know how long
one wave of a model takes on a platform. Nine PRs of infrastructure answer
that with *measured probes* per (model, platform) — fine for four Table-1
models, wrong for a fleet of hundreds of exported variants. This package
closes the rule4ml loop: a deterministic feature extractor over the static
compiled structure (`features`), a reproducible training table harvested
from the observability traces and autotune audit trails the stack already
emits (`dataset`), and a small seedable pure-numpy predictor with save/load
artifacts (`model`). Consumers: ``REPRO_AUTOTUNE=model`` (probe-free
autotuning, ``deploy.autotune``), cold-start admission pricing
(``serve.slo.PredictedServiceModel``), and predictor-evaluated codesign
sweeps (``core.search.predictor_sweep``). See ``docs/costmodel.md``.
"""

from repro.costmodel.features import (
    FEATURE_NAMES,
    FEATURE_SCHEMA_VERSION,
    feature_vector,
    features_from_costs,
    features_from_model_cost,
    wave_features,
)
from repro.costmodel.dataset import (
    DATASET_SCHEMA_VERSION,
    Dataset,
    build_dataset,
    compiled_feature_resolver,
    load_trace_records,
    rows_from_bench_doc,
    rows_from_trace_records,
    rows_from_tuned_config,
)
from repro.costmodel.model import (
    WaveCostPredictor,
    bootstrap_rows,
    default_artifact_path,
    leave_one_model_out,
    load_default,
    make_default_artifact,
)

__all__ = [
    "FEATURE_NAMES",
    "FEATURE_SCHEMA_VERSION",
    "feature_vector",
    "features_from_costs",
    "features_from_model_cost",
    "wave_features",
    "DATASET_SCHEMA_VERSION",
    "Dataset",
    "build_dataset",
    "compiled_feature_resolver",
    "load_trace_records",
    "rows_from_bench_doc",
    "rows_from_trace_records",
    "rows_from_tuned_config",
    "WaveCostPredictor",
    "bootstrap_rows",
    "default_artifact_path",
    "leave_one_model_out",
    "load_default",
    "make_default_artifact",
]
