"""QIR graph lowering: from an exported interchange graph to a stage schedule.

This is the compiler half of the paper's deployment flow (FINN's
``Streamline -> to-HLS-layers`` stage, hls4ml's ``convert``): walk a
``core.qir.Graph``, greedily fuse every

    Dense|Conv2D -> [BatchNorm] -> [Relu] -> Quant

chain into a single integer dataflow stage (int8 matmul -> int32 accumulator
-> multi-threshold; convs go through im2col so they ride the same fused
kernel), and emit a static ``StageSchedule`` the executor turns into one jit
program. The matcher is op-generic: ``_match_chain`` produces a
``ChainMatch`` and ``stage_for`` dispatches on the head op, so adding a new
matmul-like op means one builder, not a new matcher.

Stage kinds covering every exported graph:

  * ``FusedThresholdStage``     — streamlined integer dense stage; runs on
    the fused Pallas kernel (``kernels.ops.threshold_matmul``) on TPU, or as
    the XLA-fused searchsorted reference inside the same jit program on CPU.
  * ``FusedConvThresholdStage`` — streamlined integer conv stage, with the
    bank built by ``core.streamline`` (BN folded into the kernel, exact
    half-up rounding; FINN-style bipolar sign banks for the binary CNV).
    Two lowerings share the one stage artifact, selected by ``lowering``:

      - ``"direct"`` (default) — the fused direct-conv Pallas kernel
        (``kernels.ops.conv_threshold``): implicit im2col via shifted-window
        tap accumulation inside the kernel, thresholds in-register, no
        materialized patch matrix. The CPU fast path is XLA's native conv
        (``mm_float``) or the same tap accumulation in int32.
      - ``"im2col"``  — fallback behind ``conv_lowering="im2col"`` /
        ``REPRO_CONV_LOWERING=im2col``: materialize the (OH*OW, K*K*C)
        patch matrix and ride the dense ``threshold_matmul``.

    Both produce identical integers (integer accumulation is order-free),
    so the bit-exactness contract is lowering-independent.
  * ``IntPoolStage``            — MaxPool on integer codes (max commutes
    with the monotone code -> value map, so pooling codes is exact).
  * ``FlattenStage``            — NHWC -> flat reshape between conv and FC.
  * ``FloatHeadStage``          — the final Dense head: int codes -> float
    logits in one affine (the paper drops softmax; argmax suffices).
  * ``RefChainStage``           — fallback: any suffix of nodes the matcher
    does not recognize runs through a float JAX interpreter, so *any*
    exported graph is executable (just not fused).

The schedule records value scales at every boundary so integer and float
stages compose exactly.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.qir import Graph, Node
from repro.core.streamline import (
    ThresholdDense,
    apply_threshold_dense,
    make_threshold_stage,
    multi_threshold,
    multi_threshold_sorted,
    streamline_conv,
    streamline_dense,
)


# ---------------------------------------------------------------------------
# im2col
# ---------------------------------------------------------------------------

def im2col(x, kernel: int, stride: int, padding: str):
    """Extract conv patches: (N, H, W, C) -> (N, OH, OW, kernel*kernel*C).

    Feature order is (kh, kw, c) row-major — identical to reshaping an HWIO
    kernel to (kh*kw*cin, cout), so ``patches @ w2d`` is the convolution.
    SAME zero-pads like XLA/TF (low side gets floor(pad/2)); zero padding is
    exact on integer codes whenever code 0 means value 0 (signed inputs and
    unsigned half-up codes — the bipolar CNV uses VALID convs only).
    """
    from repro.kernels.conv_threshold import same_pads

    n, h, w, c = x.shape
    if padding == "SAME":
        oh, ow = -(-h // stride), -(-w // stride)
        pad_h, pad_w = same_pads(h, w, oh, ow, stride, kernel)
        x = jnp.pad(x, ((0, 0), pad_h, pad_w, (0, 0)))
    else:
        oh, ow = (h - kernel) // stride + 1, (w - kernel) // stride + 1
    cols = [x[:, i:i + stride * (oh - 1) + 1:stride,
              j:j + stride * (ow - 1) + 1:stride, :]
            for i in range(kernel) for j in range(kernel)]
    return jnp.concatenate(cols, axis=-1)


# ---------------------------------------------------------------------------
# stage kinds
# ---------------------------------------------------------------------------

def _float_mm_safe(w_int, in_bits: int) -> bool:
    """True when the stage's integer matmul can run *exactly* in float32.

    Integer arithmetic in float32 is exact while every partial sum stays
    below 2^24; any accumulation order then yields the same integers, so the
    accumulator can take the BLAS SGEMM path on CPU (int32 matmuls lower to
    scalar loops there) without giving up bit-exactness. The bound is the
    worst case over output channels: sum_k |w_int[k, c]| times the largest
    input code."""
    colsum = np.sum(np.abs(np.asarray(w_int, np.int64)), axis=0)
    worst = int(colsum.max()) if colsum.size else 0
    return worst * ((1 << in_bits) - 1) < (1 << 24)


def _apply_act(stage: ThresholdDense, affine, acc):
    """Integer activation on the accumulator, fastest exact form available.

    ``affine`` is the O(1) arithmetic short-cut (mul, add) per channel:
    when every scale in the stage is a power of two and the bias sits on the
    accumulator grid (the conv exporter's contract), the half-up quant
    q = clip(floor(acc*mul + add), 0, S) is exact in float32 and therefore
    bit-identical to counting thresholds — without the O(log S) gather loop.
    Otherwise fall back to the sorted-bank searchsorted (or, for single-step
    sign banks, one broadcast compare)."""
    if affine is not None:
        mul, add = affine
        q = jnp.floor(acc.astype(jnp.float32) * mul + add)
        return jnp.clip(q, 0, stage.n_steps).astype(jnp.int32)
    return multi_threshold_sorted(acc, stage.thresholds)


@dataclasses.dataclass
class FusedThresholdStage:
    """One streamlined integer dense stage (see core/streamline.py)."""

    name: str
    stage: ThresholdDense
    in_dim: int
    out_dim: int
    in_scale: float
    in_bits: int = 8
    mm_float: bool = False   # exact float32 GEMM path (see _float_mm_safe)
    affine: Optional[tuple] = None   # exact O(1) activation (see _apply_act)
    block_m: Optional[int] = None    # tuned kernel row block (None = default)
    block_n: Optional[int] = None    # tuned kernel col block (None = default)

    @property
    def out_scale(self) -> float:
        return self.stage.out_scale

    @property
    def macs(self) -> int:
        return self.in_dim * self.out_dim

    def _acc(self, x_int):
        if self.mm_float:
            return jnp.matmul(x_int.astype(jnp.float32),
                              self.stage.w_int.astype(jnp.float32)
                              ).astype(jnp.int32)
        return jnp.matmul(x_int.astype(jnp.int32),
                          self.stage.w_int.astype(jnp.int32))

    def apply_ref(self, x_int):
        return apply_threshold_dense(self.stage, x_int)

    def apply_fast(self, x_int):
        """CPU/XLA path: (exact-float or int32) matmul + exact activation
        — bit-identical to ``apply_ref``, SGEMM-backed when the bound
        allows, O(1) or O(log S) in the step count."""
        return _apply_act(self.stage, self.affine, self._acc(x_int))

    def apply_kernel(self, x_int, *, interpret: Optional[bool] = None):
        from repro.kernels import ops

        # int32, not int8: inter-stage codes are UNSIGNED in
        # [0, 2^act_bits - 1], so 8-bit activations (128..255) would wrap
        # negative under an int8 cast. The kernel takes either width.
        return ops.threshold_matmul(
            x_int.astype(jnp.int32), self.stage.w_int, self.stage.thresholds,
            block_m=self.block_m or 128, block_n=self.block_n or 128,
            interpret=interpret)


@dataclasses.dataclass
class ConvGeom:
    """Static conv geometry a fused conv stage needs at trace time."""

    kernel: int
    stride: int
    padding: str
    in_h: int
    in_w: int
    in_ch: int
    out_h: int
    out_w: int
    out_ch: int


CONV_LOWERINGS = ("direct", "im2col")


def default_conv_lowering() -> str:
    """The preferred conv lowering, overridable via REPRO_CONV_LOWERING."""
    kind = os.environ.get("REPRO_CONV_LOWERING", "direct").strip() or "direct"
    if kind not in CONV_LOWERINGS:
        raise ValueError(
            f"REPRO_CONV_LOWERING={kind!r}; expected one of {CONV_LOWERINGS}")
    return kind


@dataclasses.dataclass
class FusedConvThresholdStage:
    """One streamlined integer conv stage (direct or im2col lowering).

    ``stage.w_int`` holds the (kernel*kernel*in_ch, out_ch) im2col weight
    matrix; the integer accumulator and threshold bank are identical to the
    dense case, so both lowerings — the fused direct-conv kernel and the
    im2col + ``threshold_matmul`` fallback — consume one stage artifact and
    produce identical integers.
    """

    name: str
    stage: ThresholdDense
    geom: ConvGeom
    in_scale: float
    in_bits: int = 8
    mm_float: bool = False   # exact float32 GEMM path (see _float_mm_safe)
    affine: Optional[tuple] = None   # exact O(1) activation (see _apply_act)
    lowering: str = "direct"         # "direct" | "im2col"
    block_h: Optional[int] = None    # tuned output-row block (None = planner)

    @property
    def out_scale(self) -> float:
        return self.stage.out_scale

    @property
    def in_dim(self) -> int:
        return self.geom.in_h * self.geom.in_w * self.geom.in_ch

    @property
    def out_dim(self) -> int:
        return self.geom.out_h * self.geom.out_w * self.geom.out_ch

    @property
    def macs(self) -> int:
        g = self.geom
        return g.out_h * g.out_w * g.kernel * g.kernel * g.in_ch * g.out_ch

    @property
    def fifo_work(self) -> int:
        """Per-token work driving the FIFO-depth simulation.

        The im2col lowering materializes (OH*OW, K*K*C) patch tiles, so its
        pipeline work scales with the patch traffic (= ``macs``). The fused
        direct kernel streams shifted windows in-register and emits only
        output tiles, so its FIFO pressure scales with the output tile
        count — sizing fused-stage FIFOs from im2col tile counts would
        over-buffer them (paper §3.1.2: depth follows observed occupancy).
        """
        g = self.geom
        if self.lowering == "direct":
            return g.out_h * g.out_w * g.out_ch
        return self.macs

    def _pad_same(self, x):
        """SAME zero padding on integer codes (exact: code 0 is value 0)."""
        from repro.kernels.conv_threshold import same_pads

        g = self.geom
        if g.padding != "SAME":
            return x
        pad_h, pad_w = same_pads(g.in_h, g.in_w, g.out_h, g.out_w,
                                 g.stride, g.kernel)
        return jnp.pad(x, ((0, 0), pad_h, pad_w, (0, 0)))

    def _cols2d(self, x_int):
        g = self.geom
        x = x_int.reshape(-1, g.in_h, g.in_w, g.in_ch)
        cols = im2col(x, g.kernel, g.stride, g.padding)
        return cols.reshape(-1, g.kernel * g.kernel * g.in_ch)

    def _shape_out(self, y2d, n):
        g = self.geom
        return y2d.reshape(n, g.out_h, g.out_w, g.out_ch)

    def apply_ref(self, x_int):
        acc = jnp.matmul(self._cols2d(x_int).astype(jnp.int32),
                         self.stage.w_int.astype(jnp.int32))
        return self._shape_out(multi_threshold(acc, self.stage.thresholds),
                               x_int.shape[0])

    def apply_fast(self, x_int):
        """CPU/XLA path, algorithm selected by ``lowering``.

        * ``direct``  — no patch matrix ever: with the exactness bound
          satisfied the accumulator comes from XLA's native float32
          convolution (integer-valued, so bit-identical to the int32 path
          but Eigen-optimized); otherwise the kernel's shifted-window tap
          accumulation runs in int32.
        * ``im2col``  — materialize the patch matrix and matmul (float32
          SGEMM when the bound allows, int32 otherwise) — the baseline the
          fused kernel is benchmarked against.
        """
        g = self.geom
        if self.lowering == "direct":
            x = x_int.reshape(-1, g.in_h, g.in_w, g.in_ch)
            if self.mm_float:
                w4 = self.stage.w_int.astype(jnp.float32).reshape(
                    g.kernel, g.kernel, g.in_ch, g.out_ch)
                acc = jax.lax.conv_general_dilated(
                    x.astype(jnp.float32), w4, (g.stride, g.stride),
                    g.padding,
                    dimension_numbers=("NHWC", "HWIO", "NHWC")
                ).astype(jnp.int32)
            else:
                from repro.kernels.conv_threshold import direct_conv_acc

                acc = direct_conv_acc(
                    self._pad_same(x), self.stage.w_int, kernel=g.kernel,
                    stride=g.stride, out_h=g.out_h, out_w=g.out_w)
            return _apply_act(self.stage, self.affine, acc)
        cols = self._cols2d(x_int)
        if self.mm_float:
            acc = jnp.matmul(cols.astype(jnp.float32),
                             self.stage.w_int.astype(jnp.float32)
                             ).astype(jnp.int32)
        else:
            acc = jnp.matmul(cols.astype(jnp.int32),
                             self.stage.w_int.astype(jnp.int32))
        return self._shape_out(
            _apply_act(self.stage, self.affine, acc), x_int.shape[0])

    def apply_kernel(self, x_int, *, interpret: Optional[bool] = None):
        from repro.kernels import ops

        g = self.geom
        if self.lowering == "direct":
            x = x_int.reshape(-1, g.in_h, g.in_w, g.in_ch)
            return ops.conv_threshold(
                x.astype(jnp.int32), self.stage.w_int, self.stage.thresholds,
                kernel=g.kernel, stride=g.stride, padding=g.padding,
                out_h=g.out_h, out_w=g.out_w, block_h=self.block_h,
                interpret=interpret)
        y = ops.threshold_matmul(
            self._cols2d(x_int).astype(jnp.int32), self.stage.w_int,
            self.stage.thresholds, interpret=interpret)
        return self._shape_out(y, x_int.shape[0])


@dataclasses.dataclass
class IntPoolStage:
    """MaxPool executed directly on integer codes.

    Exact because code -> value is monotone (value = code * scale for the
    half-up banks; value = 2*code - 1 for bipolar), so max commutes with the
    decoding either way. Scale passes through unchanged.
    """

    name: str
    window: int
    stride: int
    padding: str
    in_h: int
    in_w: int
    ch: int
    out_h: int
    out_w: int
    in_scale: float
    in_bits: int = 8

    @property
    def out_scale(self) -> float:
        return self.in_scale

    @property
    def in_dim(self) -> int:
        return self.in_h * self.in_w * self.ch

    @property
    def out_dim(self) -> int:
        return self.out_h * self.out_w * self.ch

    @property
    def macs(self) -> int:
        return self.out_h * self.out_w * self.ch * self.window * self.window

    def apply_ref(self, x):
        x = x.reshape(-1, self.in_h, self.in_w, self.ch)
        init = (jnp.iinfo(x.dtype).min
                if jnp.issubdtype(x.dtype, jnp.integer) else -jnp.inf)
        return jax.lax.reduce_window(
            x, init, jax.lax.max, (1, self.window, self.window, 1),
            (1, self.stride, self.stride, 1), self.padding)


@dataclasses.dataclass
class FlattenStage:
    """NHWC -> (N, H*W*C) reshape between the conv stack and the FC head."""

    name: str
    in_dim: int
    in_scale: float
    in_bits: int = 8

    @property
    def out_dim(self) -> int:
        return self.in_dim

    @property
    def out_scale(self) -> float:
        return self.in_scale

    @property
    def macs(self) -> int:
        return self.in_dim  # pure data movement

    def apply_ref(self, x):
        return x.reshape(x.shape[0], -1)


@dataclasses.dataclass
class FloatHeadStage:
    """Final affine head: logits = x_int * in_scale @ w + b (float out)."""

    name: str
    w: jnp.ndarray
    b: jnp.ndarray
    in_dim: int
    out_dim: int
    in_scale: float
    in_bits: int = 8

    @property
    def macs(self) -> int:
        return self.in_dim * self.out_dim

    def apply_ref(self, x_int):
        return x_int.astype(jnp.float32) @ self.w * self.in_scale + self.b


@dataclasses.dataclass
class RefChainStage:
    """Fallback float interpreter over a run of QIR nodes.

    Consumes the float value of its input (the executor multiplies integer
    codes by ``in_scale`` first) and emits float; exact QIR.run semantics.
    """

    name: str
    nodes: List[Node]
    initializers: Dict[str, np.ndarray]
    in_name: str
    out_name: str
    in_dim: int
    out_dim: int
    in_scale: float
    in_bits: int = 8

    def apply_ref(self, x_float):
        from repro.core.qir import eval_node

        env: Dict[str, jnp.ndarray] = {
            k: jnp.asarray(v) for k, v in self.initializers.items()
        }
        env[self.in_name] = x_float
        for node in self.nodes:
            env[node.outputs[0]] = eval_node(node, [env[i] for i in node.inputs])
        return env[self.out_name]


Stage = Union[FusedThresholdStage, FusedConvThresholdStage, IntPoolStage,
              FlattenStage, FloatHeadStage, RefChainStage]


@dataclasses.dataclass
class StageSchedule:
    """The static compilation artifact: an ordered list of stages plus the
    input quantization contract (integer codes with ``in_scale`` step)."""

    stages: List[Stage]
    in_scale: float
    meta: Dict = dataclasses.field(default_factory=dict)

    @property
    def n_fused(self) -> int:
        return sum(isinstance(s, (FusedThresholdStage,
                                  FusedConvThresholdStage))
                   for s in self.stages)

    @property
    def n_fused_conv(self) -> int:
        return sum(isinstance(s, FusedConvThresholdStage)
                   for s in self.stages)

    def layer_dims(self) -> List[int]:
        dims = [self.stages[0].in_dim]
        for s in self.stages:
            dims.append(s.out_dim)
        return dims

    def describe(self) -> str:
        rows = [f"schedule: {len(self.stages)} stages "
                f"({self.n_fused} fused int, {self.n_fused_conv} conv, "
                f"in_scale={self.in_scale:g})"]
        for s in self.stages:
            kind = type(s).__name__
            if isinstance(s, FusedConvThresholdStage):
                kind += f"[{s.lowering}]"
            rows.append(f"  {s.name:16s} {kind:24s} {s.in_dim:>6d} -> {s.out_dim}")
        return "\n".join(rows)


# ---------------------------------------------------------------------------
# segments (compiled streaming)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Segment:
    """A contiguous run of stages the executor treats as one unit.

    ``compiled`` segments are runs of fused/integer stages (everything except
    the fallback float interpreter) that the streaming executor compiles into
    a *single* jit program per micro-batch wave — micro-batches advance
    through all of the segment's stages inside ``jax.lax`` control flow, so
    Python is crossed once per segment instead of once per stage per
    micro-batch. A ``RefChainStage`` is a *host boundary*: it interprets
    arbitrary leftover QIR nodes, so it gets its own non-compiled segment and
    the wave returns to the host around it.
    """

    start: int   # first stage index (inclusive)
    stop: int    # last stage index (exclusive)
    compiled: bool

    @property
    def n_stages(self) -> int:
        return self.stop - self.start


def group_segments(stages: Sequence[Stage]) -> List[Segment]:
    """Group a stage schedule into maximal compiled segments split at host
    boundaries (``RefChainStage``). Every stage lands in exactly one segment
    and segment order is schedule order."""
    segments: List[Segment] = []
    run_start = 0
    for i, s in enumerate(stages):
        if isinstance(s, RefChainStage):
            if i > run_start:
                segments.append(Segment(run_start, i, compiled=True))
            segments.append(Segment(i, i + 1, compiled=False))
            run_start = i + 1
    if run_start < len(stages):
        segments.append(Segment(run_start, len(stages), compiled=True))
    return segments


# ---------------------------------------------------------------------------
# megakernel residency planner (the whole-network-resident fused path)
# ---------------------------------------------------------------------------

#: Fusing one stage is what ``threshold_matmul`` already does — the
#: megakernel only pays off once there is an inter-stage boundary to delete.
MEGAKERNEL_MIN_STAGES = 2


@dataclasses.dataclass(frozen=True)
class MegakernelSegment:
    """A planned whole-network-resident kernel covering stages
    ``[start, stop)`` — a run of consecutive ``FusedThresholdStage``s whose
    entire working set (weights + threshold banks + inter-stage FIFO tiles)
    fits the VMEM cap, so the executor dispatches the run as ONE program
    (``kernels.megakernel``) instead of one program per stage. Carries the
    planner's byte accounting as the audit trail (``docs/megakernel.md``).
    """

    start: int          # first fused stage index (inclusive)
    stop: int           # last fused stage index (exclusive)
    block_m: int        # wave row block the tile accounting assumed
    weight_bytes: int   # resident int8 weight matrices, all stages
    bank_bytes: int     # resident int32 threshold banks, all stages
    tile_bytes: int     # in/out row blocks + two revolving FIFO tiles
    budget_bytes: int   # the VMEM cap the plan was admitted under

    @property
    def n_stages(self) -> int:
        return self.stop - self.start

    @property
    def total_bytes(self) -> int:
        return self.weight_bytes + self.bank_bytes + self.tile_bytes


def plan_megakernel(stages: Sequence[Stage], segment: Segment, *,
                    block_m: int = 128,
                    budget_bytes: Optional[int] = None
                    ) -> Optional[MegakernelSegment]:
    """Walk one compiled ``Segment`` and plan its resident megakernel.

    Finds the longest run of consecutive ``FusedThresholdStage``s inside the
    segment (the MLP models are one segment that is entirely such a run,
    plus the float head) and admits it when the residency byte accounting
    (``core.bops.megakernel_residency_bytes``: every weight matrix, every
    threshold bank, the inter-stage FIFO tiles) fits the VMEM cap. Returns
    ``None`` when no run is long enough or the working set exceeds the
    budget — the executor then falls back to the per-stage path, which
    stays the bit-exactness reference.
    """
    from repro.core.bops import (MEGAKERNEL_VMEM_BYTES,
                                 megakernel_residency_bytes)

    budget = MEGAKERNEL_VMEM_BYTES if budget_bytes is None else budget_bytes
    if not segment.compiled:
        return None
    best = None          # longest run wins; earlier run breaks length ties
    i = segment.start
    while i < segment.stop:
        if isinstance(stages[i], FusedThresholdStage):
            j = i
            while j < segment.stop and isinstance(stages[j],
                                                  FusedThresholdStage):
                j += 1
            if best is None or (j - i) > (best[1] - best[0]):
                best = (i, j)
            i = j
        else:
            i += 1
    if best is None or best[1] - best[0] < MEGAKERNEL_MIN_STAGES:
        return None
    run = stages[best[0]:best[1]]
    res = megakernel_residency_bytes(run, block_m=block_m)
    if res["total_bytes"] > budget:
        return None      # does not fit resident: staged path
    return MegakernelSegment(start=best[0], stop=best[1], block_m=block_m,
                             weight_bytes=res["weight_bytes"],
                             bank_bytes=res["bank_bytes"],
                             tile_bytes=res["tile_bytes"],
                             budget_bytes=budget)


# ---------------------------------------------------------------------------
# pattern matcher
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ChainMatch:
    """One fusable Dense|Conv2D -> [BatchNorm] -> [Relu] -> Quant run."""

    kind: str                         # "dense" | "conv"
    head: Node
    params: Dict[str, np.ndarray]     # w, b (+ BN stats when present)
    act: str                          # "halfup" | "bipolar"
    act_bits: int
    weight_bits: int
    s_out: Optional[float]            # export-frozen activation scale
    w_scale: Optional[np.ndarray]     # per-channel scale: weights pre-quantized
    n_consumed: int


def _head_params(graph: Graph, node: Node) -> Optional[Dict[str, np.ndarray]]:
    """Pull (w, b) for a Dense/Conv2D node; None unless w is an initializer."""
    if len(node.inputs) < 2 or node.inputs[1] not in graph.initializers:
        return None
    w = graph.initializers[node.inputs[1]]
    b = (graph.initializers.get(node.inputs[2])
         if len(node.inputs) > 2 else None)
    if b is None:
        b = np.zeros((w.shape[-1],), np.float32)
    return {"w": w, "b": b}


def _is_linear_value(graph: Graph, name: str) -> bool:
    """True iff ``name`` has exactly one consumer and is not a graph output
    — the condition for fusing it away without dropping a reader."""
    if name in graph.outputs:
        return False
    return sum(name in n.inputs for n in graph.nodes) == 1


def _is_passthrough_value(graph: Graph, name: str) -> bool:
    """Weaker check for values that survive as stage outputs (pool/flatten):
    at most one consumer, so the stage pipeline stays a chain."""
    return sum(name in n.inputs for n in graph.nodes) <= 1


def _match_chain(graph: Graph, nodes: List[Node], i: int
                 ) -> Optional[ChainMatch]:
    """Try to match Dense|Conv2D -> [BatchNorm] -> [Relu] -> Quant at i.

    The chain must be linear: each intermediate value feeds exactly the next
    node and nothing else (fusion erases it from the runtime environment).
    A Relu is required for the half-up quant flavor (it is what makes the
    output codes unsigned); bipolar sign quants fuse without one.
    """
    head = nodes[i]
    if head.op not in ("Dense", "Conv2D"):
        return None
    if head.op == "Conv2D" and "in_shape" not in head.attrs:
        return None  # no static geometry: leave for the fallback interpreter
    params = _head_params(graph, head)
    if params is None:
        return None
    j = i + 1
    prev_out = head.outputs[0]
    if not _is_linear_value(graph, prev_out):
        return None
    if j < len(nodes) and nodes[j].op == "BatchNorm" and nodes[j].inputs[0] == prev_out:
        bn = nodes[j]
        try:
            stats = [graph.initializers[n] for n in bn.inputs[1:5]]
        except KeyError:
            return None
        params.update(gamma=stats[0], beta=stats[1], mu=stats[2], sigma2=stats[3])
        prev_out = bn.outputs[0]
        j += 1
        if not _is_linear_value(graph, prev_out):
            return None
    relu = False
    if j < len(nodes) and nodes[j].op == "Relu" and nodes[j].inputs[0] == prev_out:
        relu = True
        prev_out = nodes[j].outputs[0]
        j += 1
        if not _is_linear_value(graph, prev_out):
            return None
    if not (j < len(nodes) and nodes[j].op == "Quant"
            and nodes[j].inputs[0] == prev_out and nodes[j].quant is not None):
        return None
    quant = nodes[j]
    bipolar = bool(quant.attrs.get("bipolar"))
    if bipolar == relu:
        # half-up needs the ReLU; a sign bank after ReLU would be constant
        return None
    act_bits = quant.quant.bits
    weight_bits = head.attrs.get("weight_bits", act_bits)
    w_scale = None
    ws_name = head.attrs.get("w_scale")
    if ws_name is not None and ws_name in graph.initializers and "gamma" not in params:
        # pre-quantized weights; unusable under BN (folding rescales them)
        w_scale = graph.initializers[ws_name]
    s_out = quant.attrs.get("scale")
    return ChainMatch(
        kind="dense" if head.op == "Dense" else "conv",
        head=head, params=params,
        act="bipolar" if bipolar else "halfup",
        act_bits=act_bits, weight_bits=weight_bits,
        s_out=None if s_out is None else float(s_out),
        w_scale=w_scale, n_consumed=j + 1 - i)


def _threshold_for_chain(m: ChainMatch, scale: float,
                         bn_eps: float) -> ThresholdDense:
    """Streamline one matched chain into a ThresholdDense bank."""
    w = np.asarray(m.params["w"], np.float32)
    w2d = w.reshape(-1, w.shape[-1])
    if m.w_scale is not None:
        # weights already carry integer codes times a per-channel scale;
        # divide it back out (exact: the exporter used po2 / unit scales)
        s_w = jnp.reshape(jnp.asarray(m.w_scale, jnp.float32), (-1,))
        w_int = jnp.round(jnp.asarray(w2d) / s_w[None, :])
        return make_threshold_stage(
            w_int, s_w, m.params["b"], in_scale=scale, act_bits=m.act_bits,
            s_out=m.s_out, bipolar=m.act == "bipolar",
            weight_bits=m.weight_bits)
    if m.kind == "conv":
        return streamline_conv(
            m.params, weight_bits=m.weight_bits, act_bits=m.act_bits,
            in_scale=scale, bn_eps=bn_eps, s_out=m.s_out,
            bipolar=m.act == "bipolar")
    if m.act == "bipolar":
        from repro.core.quantizers import IntQuantizer

        wq = IntQuantizer(bits=m.weight_bits, signed=True, narrow=True, axis=0)
        w_int, s_w = wq.quantize_int(jnp.asarray(w2d))
        return make_threshold_stage(
            w_int, jnp.squeeze(s_w, axis=0), m.params["b"], in_scale=scale,
            act_bits=m.act_bits, bipolar=True, weight_bits=m.weight_bits)
    return streamline_dense(
        m.params, weight_bits=m.weight_bits, act_bits=m.act_bits,
        in_scale=scale, bn_eps=bn_eps, s_out=m.s_out)


def _exact_affine(m: ChainMatch, td: ThresholdDense, scale: float,
                  mm_safe: bool, in_bits: int) -> Optional[tuple]:
    """(mul, add) for the O(1) activation, or None when not provably exact.

    Requires: half-up flavor with an export-frozen s_out, pre-quantized
    weights whose per-channel scales (and in_scale/s_out) are powers of two,
    bias on the accumulator grid, and the 2^24 accumulator bound — i.e. the
    ``export_qcnn`` contract. Under those conditions every term of
    acc*mul + add is an exact float32 multiple of g/s_out, so floor/clip
    reproduce the threshold counts bit for bit.
    """
    if (m.act != "halfup" or m.s_out is None or m.w_scale is None
            or not mm_safe):
        return None
    s_w = np.asarray(m.w_scale, np.float64).reshape(-1)
    grids = np.concatenate([s_w, [scale, td.out_scale]])
    if not np.all(grids > 0):
        return None
    logs = np.log2(grids)
    if not np.all(logs == np.round(logs)):
        return None
    g = s_w * scale                        # accumulator grid per channel
    r1 = g / td.out_scale                  # activation grid in code units
    b = np.asarray(m.params["b"], np.float64).reshape(-1)
    if not (np.all(b / g == np.round(b / g)) and np.all(r1 <= 0.5)):
        return None                        # bias off-grid / 0.5 off-grid
    # every term of acc*mul + add is k*r1; exactness needs max|k| < 2^24
    colsum = np.sum(np.abs(np.asarray(td.w_int, np.int64)), axis=0)
    k_max = (colsum * ((1 << in_bits) - 1) + np.abs(b / g) + 0.5 / r1)
    if not np.all(k_max < (1 << 24)):
        return None
    mul = jnp.asarray((g / td.out_scale).astype(np.float32))
    add = jnp.asarray((b / td.out_scale + 0.5).astype(np.float32))
    return (mul, add)


def stage_for(m: ChainMatch, scale: float, in_bits: int = 8,
              bn_eps: float = 1e-3,
              conv_lowering: Optional[str] = None) -> Stage:
    """Build the fused stage for one matched chain — the op dispatch point."""
    td = _threshold_for_chain(m, scale, bn_eps)
    mm_float = _float_mm_safe(td.w_int, in_bits)
    affine = _exact_affine(m, td, scale, mm_float, in_bits)
    if m.kind == "conv":
        a = m.head.attrs
        ih, iw, ic = a["in_shape"]
        oh, ow, oc = a["out_shape"]
        geom = ConvGeom(kernel=int(a.get("kernel", m.params["w"].shape[0])),
                        stride=int(a.get("stride", 1)),
                        padding=a.get("padding", "SAME"),
                        in_h=int(ih), in_w=int(iw), in_ch=int(ic),
                        out_h=int(oh), out_w=int(ow), out_ch=int(oc))
        kind = conv_lowering or default_conv_lowering()
        if kind not in CONV_LOWERINGS:
            raise ValueError(f"conv_lowering={kind!r}; "
                             f"expected one of {CONV_LOWERINGS}")
        return FusedConvThresholdStage(name=m.head.name, stage=td, geom=geom,
                                       in_scale=scale, in_bits=in_bits,
                                       mm_float=mm_float, affine=affine,
                                       lowering=kind)
    w = m.params["w"]
    return FusedThresholdStage(name=m.head.name, stage=td,
                               in_dim=int(w.shape[0]),
                               out_dim=int(w.shape[1]),
                               in_scale=scale, in_bits=in_bits,
                               mm_float=mm_float, affine=affine)


def lower_graph(graph: Graph, in_scale: float = 1.0 / 127.0,
                bn_eps: float = 1e-3,
                conv_lowering: Optional[str] = None) -> StageSchedule:
    """Compile a QIR graph to a stage schedule.

    ``in_scale`` is the float value of one integer step of the (already
    quantized) network input — the paper's 8-bit input layer contract.
    Conv exporters record their contract in ``graph.meta["in_scale"]``.
    ``conv_lowering`` selects the conv stage algorithm ("direct" fused
    kernel by default, "im2col" fallback); None defers to the
    REPRO_CONV_LOWERING environment override.
    """
    stages: List[Stage] = []
    nodes = graph.nodes
    scale = in_scale
    in_bits = 8   # MLPerf-Tiny 8-bit input layer contract
    i = 0
    while i < len(nodes):
        m = _match_chain(graph, nodes, i)
        if m is not None:
            st = stage_for(m, scale, in_bits, bn_eps,
                           conv_lowering=conv_lowering)
            stages.append(st)
            scale = st.out_scale
            in_bits = st.stage.act_bits
            i += m.n_consumed
            continue
        node = nodes[i]
        if (node.op == "MaxPool" and "in_shape" in node.attrs
                and _is_passthrough_value(graph, node.outputs[0])):
            ih, iw, ch = (int(v) for v in node.attrs["in_shape"])
            win = int(node.attrs.get("window", 2))
            stride = int(node.attrs.get("stride", win))
            if "out_shape" in node.attrs:
                oh, ow = int(node.attrs["out_shape"][0]), int(node.attrs["out_shape"][1])
            elif node.attrs.get("padding", "VALID") == "SAME":
                oh, ow = -(-ih // stride), -(-iw // stride)
            else:
                oh, ow = (ih - win) // stride + 1, (iw - win) // stride + 1
            stages.append(IntPoolStage(
                name=node.name, window=win, stride=stride,
                padding=node.attrs.get("padding", "VALID"),
                in_h=ih, in_w=iw, ch=ch, out_h=oh, out_w=ow,
                in_scale=scale, in_bits=in_bits))
            i += 1
            continue
        if (node.op == "Flatten"
                and _is_passthrough_value(graph, node.outputs[0])):
            if "in_shape" in node.attrs:
                in_dim = int(np.prod(node.attrs["in_shape"]))
            else:
                in_dim = stages[-1].out_dim if stages else 1
            stages.append(FlattenStage(name=node.name, in_dim=in_dim,
                                       in_scale=scale, in_bits=in_bits))
            i += 1
            continue
        if node.op == "Dense" and i == len(nodes) - 1:
            params = _head_params(graph, node)
            if params is not None:
                stages.append(FloatHeadStage(
                    name=node.name,
                    w=jnp.asarray(params["w"], jnp.float32),
                    b=jnp.asarray(params["b"], jnp.float32),
                    in_dim=int(params["w"].shape[0]),
                    out_dim=int(params["w"].shape[1]),
                    in_scale=scale, in_bits=in_bits))
                i += 1
                continue
        # fallback: sweep the rest of the graph into one reference chain
        rest = nodes[i:]
        in_name = rest[0].inputs[0]
        out_name = graph.outputs[0] if graph.outputs else rest[-1].outputs[0]
        in_dim = stages[-1].out_dim if stages else _guess_dim(graph, in_name)
        out_dim = _guess_dim(graph, out_name, default=in_dim)
        stages.append(RefChainStage(
            name=f"ref[{rest[0].name}..{rest[-1].name}]",
            nodes=list(rest),
            initializers=dict(graph.initializers),
            in_name=in_name,
            out_name=out_name,
            in_dim=in_dim,
            out_dim=out_dim,
            in_scale=scale, in_bits=in_bits))
        scale = 1.0  # float domain from here on
        i = len(nodes)
    return StageSchedule(stages=stages, in_scale=in_scale,
                        meta=dict(graph.meta))


def _guess_dim(graph: Graph, name: str, default: int = 1) -> int:
    """Best-effort feature dim for fallback bookkeeping (FIFO sizing only)."""
    for node in graph.nodes:
        if name in node.outputs and node.op in ("Dense",):
            wname = node.inputs[1]
            if wname in graph.initializers:
                return int(graph.initializers[wname].shape[1])
    if name in graph.initializers:
        return int(graph.initializers[name].shape[-1])
    return default
