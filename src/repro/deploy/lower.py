"""QIR graph lowering: from an exported interchange graph to a stage schedule.

This is the compiler half of the paper's deployment flow (FINN's
``Streamline -> to-HLS-layers`` stage, hls4ml's ``convert``): walk a
``core.qir.Graph``, greedily fuse every

    Dense -> [BatchNorm] -> Relu -> Quant

chain into a single integer dataflow stage (int8 matmul -> int32 accumulator
-> multi-threshold) by calling ``core.streamline.streamline_dense``, and emit
a static ``StageSchedule`` the executor turns into one jit program.

Three stage kinds cover every exported graph:

  * ``FusedThresholdStage`` — the streamlined integer stage; runs on the
    fused Pallas kernel (``kernels.ops.threshold_matmul``) on TPU, or as the
    XLA-fused jnp reference inside the same jit program on CPU.
  * ``FloatHeadStage``      — the final Dense head: int codes -> float
    logits in one affine (the paper drops softmax; argmax suffices).
  * ``RefChainStage``       — fallback: any suffix of nodes the matcher does
    not recognize runs through a float JAX interpreter, so *any* exported
    graph is executable (just not fused).

The schedule records value scales at every boundary so integer and float
stages compose exactly.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.qir import Graph, Node
from repro.core.streamline import (
    ThresholdDense,
    apply_threshold_dense,
    multi_threshold_sorted,
    streamline_dense,
)


# ---------------------------------------------------------------------------
# stage kinds
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FusedThresholdStage:
    """One streamlined integer dataflow stage (see core/streamline.py)."""

    name: str
    stage: ThresholdDense
    in_dim: int
    out_dim: int
    in_scale: float

    @property
    def out_scale(self) -> float:
        return self.stage.out_scale

    def apply_ref(self, x_int):
        return apply_threshold_dense(self.stage, x_int)

    def apply_fast(self, x_int):
        """CPU/XLA path: int32 matmul + sorted-bank searchsorted activation
        — bit-identical to ``apply_ref`` but O(log S) in the step count."""
        acc = jnp.matmul(x_int.astype(jnp.int32),
                         self.stage.w_int.astype(jnp.int32))
        return multi_threshold_sorted(acc, self.stage.thresholds)

    def apply_kernel(self, x_int, *, interpret: Optional[bool] = None):
        from repro.kernels import ops

        # int32, not int8: inter-stage codes are UNSIGNED in
        # [0, 2^act_bits - 1], so 8-bit activations (128..255) would wrap
        # negative under an int8 cast. The kernel takes either width.
        return ops.threshold_matmul(
            x_int.astype(jnp.int32), self.stage.w_int, self.stage.thresholds,
            interpret=interpret)


@dataclasses.dataclass
class FloatHeadStage:
    """Final affine head: logits = x_int * in_scale @ w + b (float out)."""

    name: str
    w: jnp.ndarray
    b: jnp.ndarray
    in_dim: int
    out_dim: int
    in_scale: float

    def apply_ref(self, x_int):
        return x_int.astype(jnp.float32) @ self.w * self.in_scale + self.b


@dataclasses.dataclass
class RefChainStage:
    """Fallback float interpreter over a run of QIR nodes.

    Consumes the float value of its input (the executor multiplies integer
    codes by ``in_scale`` first) and emits float; exact QIR.run semantics.
    """

    name: str
    nodes: List[Node]
    initializers: Dict[str, np.ndarray]
    in_name: str
    out_name: str
    in_dim: int
    out_dim: int
    in_scale: float

    def apply_ref(self, x_float):
        from repro.core.qir import eval_node

        env: Dict[str, jnp.ndarray] = {
            k: jnp.asarray(v) for k, v in self.initializers.items()
        }
        env[self.in_name] = x_float
        for node in self.nodes:
            env[node.outputs[0]] = eval_node(node, [env[i] for i in node.inputs])
        return env[self.out_name]


Stage = Union[FusedThresholdStage, FloatHeadStage, RefChainStage]


@dataclasses.dataclass
class StageSchedule:
    """The static compilation artifact: an ordered list of stages plus the
    input quantization contract (integer codes with ``in_scale`` step)."""

    stages: List[Stage]
    in_scale: float
    meta: Dict = dataclasses.field(default_factory=dict)

    @property
    def n_fused(self) -> int:
        return sum(isinstance(s, FusedThresholdStage) for s in self.stages)

    def layer_dims(self) -> List[int]:
        dims = [self.stages[0].in_dim]
        for s in self.stages:
            dims.append(s.out_dim)
        return dims

    def describe(self) -> str:
        rows = [f"schedule: {len(self.stages)} stages "
                f"({self.n_fused} fused int, in_scale={self.in_scale:g})"]
        for s in self.stages:
            kind = type(s).__name__
            rows.append(f"  {s.name:16s} {kind:20s} {s.in_dim:>5d} -> {s.out_dim}")
        return "\n".join(rows)


# ---------------------------------------------------------------------------
# pattern matcher
# ---------------------------------------------------------------------------

def _dense_params(graph: Graph, node: Node) -> Optional[Dict[str, np.ndarray]]:
    """Pull (w, b) for a Dense node; None if weights are not initializers."""
    if len(node.inputs) < 2 or node.inputs[1] not in graph.initializers:
        return None
    w = graph.initializers[node.inputs[1]]
    b = (graph.initializers.get(node.inputs[2])
         if len(node.inputs) > 2 else None)
    if b is None:
        b = np.zeros((w.shape[1],), np.float32)
    return {"w": w, "b": b}


def _is_linear_value(graph: Graph, name: str) -> bool:
    """True iff ``name`` has exactly one consumer and is not a graph output
    — the condition for fusing it away without dropping a reader."""
    if name in graph.outputs:
        return False
    return sum(name in n.inputs for n in graph.nodes) == 1


def _match_fused_chain(graph: Graph, nodes: List[Node], i: int):
    """Try to match Dense -> [BatchNorm] -> Relu -> Quant starting at i.

    Returns (params, act_bits, weight_bits, n_consumed) or None. The chain
    must be linear: each intermediate value feeds exactly the next node and
    nothing else (fusion erases it from the runtime environment).
    """
    if nodes[i].op != "Dense":
        return None
    params = _dense_params(graph, nodes[i])
    if params is None:
        return None
    j = i + 1
    prev_out = nodes[i].outputs[0]
    if not _is_linear_value(graph, prev_out):
        return None
    if j < len(nodes) and nodes[j].op == "BatchNorm" and nodes[j].inputs[0] == prev_out:
        bn = nodes[j]
        try:
            stats = [graph.initializers[n] for n in bn.inputs[1:5]]
        except KeyError:
            return None
        params.update(gamma=stats[0], beta=stats[1], mu=stats[2], sigma2=stats[3])
        prev_out = bn.outputs[0]
        j += 1
        if not _is_linear_value(graph, prev_out):
            return None
    if not (j < len(nodes) and nodes[j].op == "Relu" and nodes[j].inputs[0] == prev_out):
        return None
    prev_out = nodes[j].outputs[0]
    j += 1
    if not _is_linear_value(graph, prev_out):
        return None
    if not (j < len(nodes) and nodes[j].op == "Quant"
            and nodes[j].inputs[0] == prev_out and nodes[j].quant is not None):
        return None
    act_bits = nodes[j].quant.bits
    weight_bits = nodes[i].attrs.get("weight_bits", act_bits)
    return params, act_bits, weight_bits, j + 1 - i


def lower_graph(graph: Graph, in_scale: float = 1.0 / 127.0,
                bn_eps: float = 1e-3) -> StageSchedule:
    """Compile a QIR graph to a stage schedule.

    ``in_scale`` is the float value of one integer step of the (already
    quantized) network input — the paper's 8-bit input layer contract.
    """
    stages: List[Stage] = []
    nodes = graph.nodes
    scale = in_scale
    i = 0
    while i < len(nodes):
        m = _match_fused_chain(graph, nodes, i)
        if m is not None:
            params, act_bits, weight_bits, consumed = m
            td = streamline_dense(
                params, weight_bits=weight_bits, act_bits=act_bits,
                in_scale=scale, bn_eps=bn_eps)
            stages.append(FusedThresholdStage(
                name=nodes[i].name, stage=td,
                in_dim=int(params["w"].shape[0]),
                out_dim=int(params["w"].shape[1]),
                in_scale=scale))
            scale = td.out_scale
            i += consumed
            continue
        node = nodes[i]
        if node.op == "Dense" and i == len(nodes) - 1:
            params = _dense_params(graph, node)
            if params is not None:
                stages.append(FloatHeadStage(
                    name=node.name,
                    w=jnp.asarray(params["w"], jnp.float32),
                    b=jnp.asarray(params["b"], jnp.float32),
                    in_dim=int(params["w"].shape[0]),
                    out_dim=int(params["w"].shape[1]),
                    in_scale=scale))
                i += 1
                continue
        # fallback: sweep the rest of the graph into one reference chain
        rest = nodes[i:]
        in_name = rest[0].inputs[0]
        out_name = graph.outputs[0] if graph.outputs else rest[-1].outputs[0]
        in_dim = stages[-1].out_dim if stages else _guess_dim(graph, in_name)
        out_dim = _guess_dim(graph, out_name, default=in_dim)
        stages.append(RefChainStage(
            name=f"ref[{rest[0].name}..{rest[-1].name}]",
            nodes=list(rest),
            initializers=dict(graph.initializers),
            in_name=in_name,
            out_name=out_name,
            in_dim=in_dim,
            out_dim=out_dim,
            in_scale=scale))
        scale = 1.0  # float domain from here on
        i = len(nodes)
    return StageSchedule(stages=stages, in_scale=in_scale,
                         meta=dict(graph.meta))


def _guess_dim(graph: Graph, name: str, default: int = 1) -> int:
    """Best-effort feature dim for fallback bookkeeping (FIFO sizing only)."""
    for node in graph.nodes:
        if name in node.outputs and node.op in ("Dense",):
            wname = node.inputs[1]
            if wname in graph.initializers:
                return int(graph.initializers[wname].shape[1])
    if name in graph.initializers:
        return int(graph.initializers[name].shape[-1])
    return default
