"""Executable form of a lowered QIR graph: one jit program + a micro-batched
streaming pipeline whose buffer depths come from the FIFO simulator.

Two execution modes mirror the paper's deployment measurements:

  * **offline**  — the whole stage schedule compiled into a single XLA
    program over the full batch (max throughput; MLPerf Offline). Fused
    integer stages run on the Pallas kernels on TPU — ``threshold_matmul``
    for dense stages, the fused direct-conv ``conv_threshold`` (no
    materialized im2col) for conv stages lowered ``direct`` — and as the
    XLA-fused jnp reference otherwise (same integers either way).
  * **streaming** — the batch is cut into micro-batches that flow through
    per-stage programs connected by bounded queues. The queue capacities are
    *decided* by ``core.dataflow.optimize_fifo_depths`` — the paper's
    simulate-big/record-max/shrink-to-max+1 pass finally feeds a real
    execution, instead of only printing a table.

The unfused per-node interpreter (``reference``) is kept as the baseline the
benchmarks compare against — it is what running the QIR graph layer by layer
without the compiler looks like.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dataflow import Stage as SimStage
from repro.core.dataflow import optimize_fifo_depths
from repro.core.qir import Graph
from repro.deploy.lower import (
    FlattenStage,
    FloatHeadStage,
    FusedConvThresholdStage,
    FusedThresholdStage,
    IntPoolStage,
    RefChainStage,
    StageSchedule,
    lower_graph,
)


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:  # pragma: no cover
        return False


@dataclasses.dataclass
class StreamingStats:
    """What the FIFO pass decided and what the pipeline actually did."""

    micro_batch: int
    n_micro: int
    fifo_depths: List[int]
    max_occupancy: List[int]
    sim_cycles: int


class CompiledTinyModel:
    """A compiled spatial-dataflow executor for one lowered QIR graph."""

    def __init__(self, schedule: StageSchedule, graph: Optional[Graph] = None,
                 use_pallas: Optional[bool] = None,
                 interpret: Optional[bool] = None):
        self.schedule = schedule
        self.graph = graph
        self.use_pallas = _on_tpu() if use_pallas is None else use_pallas
        self.interpret = interpret
        self._offline = jax.jit(self._run_all)
        self._stage_fns = [jax.jit(self._make_stage_fn(s))
                           for s in schedule.stages]

    # -- single-program (offline) path -----------------------------------
    def _apply_stage(self, s, h):
        if isinstance(s, (FusedThresholdStage, FusedConvThresholdStage)):
            if self.use_pallas:
                return s.apply_kernel(h, interpret=self.interpret)
            return s.apply_fast(h)
        if isinstance(s, (IntPoolStage, FlattenStage, FloatHeadStage)):
            return s.apply_ref(h)
        if isinstance(s, RefChainStage):
            if jnp.issubdtype(h.dtype, jnp.integer):
                h = h.astype(jnp.float32) * s.in_scale
            return s.apply_ref(h)
        raise TypeError(type(s))  # pragma: no cover

    def _make_stage_fn(self, s) -> Callable:
        return lambda h: self._apply_stage(s, h)

    def _run_all(self, x_int):
        h = x_int
        for s in self.schedule.stages:
            h = self._apply_stage(s, h)
        return h

    def offline(self, x_int) -> jnp.ndarray:
        """Full batch through the single fused program (MLPerf Offline)."""
        return self._offline(jnp.asarray(x_int))

    def stage_outputs(self, x_int) -> List[jnp.ndarray]:
        """Per-stage outputs (integer codes for fused stages) — the parity
        surface the exactness tests check against the float reference."""
        outs, h = [], jnp.asarray(x_int)
        for fn in self._stage_fns:
            h = fn(h)
            outs.append(h)
        return outs

    def predict(self, x_int) -> jnp.ndarray:
        return jnp.argmax(self.offline(x_int), axis=-1)

    # -- unfused reference (what the benchmarks beat) ---------------------
    def reference(self, x_int) -> jnp.ndarray:
        """Per-node eager interpretation of the source QIR graph."""
        if self.graph is None:
            raise ValueError("compile with graph= to keep the reference path")
        x = np.asarray(x_int, np.float32) * self.schedule.in_scale
        out = self.graph.run({self.graph.inputs[0]: x})
        return jnp.asarray(out[self.graph.outputs[0]])

    # -- per-stage timing (feeds the scenario stage_ms breakdown) ---------
    def stage_latencies(self, x, iters: int = 2) -> List[Dict[str, object]]:
        """Median wall-time per compiled stage on one representative batch.

        Runs the per-stage programs in schedule order (each stage's input is
        the previous stage's real output) so conv-vs-dense costs are visible
        in scenario reports."""
        import time

        out = []
        h = jnp.asarray(x)
        for s, fn in zip(self.schedule.stages, self._stage_fns):
            y = fn(h)
            jax.block_until_ready(y)  # compile + warm
            times = []
            for _ in range(max(iters, 1)):
                t0 = time.perf_counter()
                jax.block_until_ready(fn(h))
                times.append(time.perf_counter() - t0)
            times.sort()
            out.append({"stage": s.name, "kind": type(s).__name__,
                        "ms": times[len(times) // 2] * 1e3})
            h = y
        return out

    # -- streaming (micro-batched pipeline) -------------------------------
    def plan_streaming(self, n_micro: int) -> Tuple[List[int], int]:
        """Size the inter-stage queues with the paper's FIFO pass.

        Each stage's simulated latency is proportional to its work,
        parameterized on the lowering kind: MACs for dense stages, im2col
        tile counts (output tiles x patch size) for ``im2col`` conv stages,
        but only *output* tiles for ``direct`` fused conv stages — the
        fused kernel never emits patch tiles into the pipeline, so sizing
        its FIFOs from im2col counts would over-buffer (``fifo_work`` on
        each stage class). Rate mismatches between wide and narrow layers
        then show up as occupancy, exactly what the RTL simulation measured
        on the FPGA.
        """
        sim = []
        for s in self.schedule.stages:
            work = getattr(s, "fifo_work", None)
            if work is None:
                work = getattr(s, "macs", None)
            if work is None:
                work = s.in_dim * s.out_dim
            sim.append(SimStage(name=s.name, ii=1,
                                latency=max(1, work // 8192) + 1,
                                elems_in=1, elems_out=1))
        res = optimize_fifo_depths(sim, n_tokens=n_micro)
        return list(res["optimized_depths"]), int(res["optimized_cycles"])

    def streaming(self, x_int, micro_batch: int = 16
                  ) -> Tuple[jnp.ndarray, StreamingStats]:
        """Run the batch as a micro-batched pipeline with bounded queues.

        Numerically identical to ``offline``; the difference is the
        execution schedule: at most ``depth[i]`` micro-batches may queue in
        front of stage i, the capacities coming from the FIFO optimizer.
        """
        x_int = jnp.asarray(x_int)
        n = x_int.shape[0]
        pad = (-n) % micro_batch
        if pad:
            x_int = jnp.concatenate(
                [x_int, jnp.zeros((pad,) + x_int.shape[1:], x_int.dtype)])
        n_micro = x_int.shape[0] // micro_batch
        depths, sim_cycles = self.plan_streaming(n_micro)

        n_stages = len(self.schedule.stages)
        queues = [collections.deque() for _ in range(n_stages + 1)]
        max_occ = [0] * (n_stages + 1)
        feed = [(i, x_int[i * micro_batch:(i + 1) * micro_batch])
                for i in range(n_micro)]
        feed_i = 0
        done: List[Optional[jnp.ndarray]] = [None] * n_micro

        while feed_i < n_micro or any(len(q) > 0 for q in queues[:-1]):
            # admit into the input queue while its FIFO has room
            while feed_i < n_micro and len(queues[0]) < depths[0]:
                queues[0].append(feed[feed_i])
                max_occ[0] = max(max_occ[0], len(queues[0]))
                feed_i += 1
            # fire stages downstream-first so space frees upstream
            for si in reversed(range(n_stages)):
                out_cap = depths[si + 1] if si + 1 < n_stages else n_micro + 1
                if queues[si] and len(queues[si + 1]) < out_cap:
                    idx, h = queues[si].popleft()
                    h = self._stage_fns[si](h)
                    queues[si + 1].append((idx, h))
                    max_occ[si + 1] = max(max_occ[si + 1], len(queues[si + 1]))
            while queues[-1]:
                idx, y = queues[-1].popleft()
                done[idx] = y
        y = jnp.concatenate([jnp.asarray(d) for d in done])[:n]
        return y, StreamingStats(micro_batch=micro_batch, n_micro=n_micro,
                                 fifo_depths=depths, max_occupancy=max_occ,
                                 sim_cycles=sim_cycles)


def compile_graph(graph: Graph, in_scale: float = 1.0 / 127.0,
                  use_pallas: Optional[bool] = None,
                  interpret: Optional[bool] = None,
                  conv_lowering: Optional[str] = None) -> CompiledTinyModel:
    """The one-call deployment entry point: QIR json graph -> executor.

    ``conv_lowering`` picks the conv stage algorithm ("direct" fused kernel
    by default, "im2col" fallback) for both offline and streaming modes —
    the stage methods the executor dispatches through carry the choice.
    """
    schedule = lower_graph(graph, in_scale=in_scale,
                           conv_lowering=conv_lowering)
    return CompiledTinyModel(schedule, graph=graph, use_pallas=use_pallas,
                             interpret=interpret)


class CompiledJaxModel:
    """Deployment wrapper for models without a QIR export path: ``offline``
    is the whole forward as one jit program, ``reference`` the eager
    per-layer forward. The four Table-1 models all lower through the real
    compiler now (``export_qmlp``/``export_qcnn`` + ``compile_graph``); this
    stays as the harness for arbitrary research models."""

    def __init__(self, fwd: Callable, params, name: str = "jax"):
        self.name = name
        self.params = params
        self._fwd = fwd
        self._offline = jax.jit(fwd)

    def offline(self, x) -> jnp.ndarray:
        return self._offline(self.params, x)

    def reference(self, x) -> jnp.ndarray:
        return self._fwd(self.params, x)

    def predict(self, x) -> jnp.ndarray:
        return jnp.argmax(self.offline(x), axis=-1)
