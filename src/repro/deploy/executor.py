"""Executable form of a lowered QIR graph: one jit program + a micro-batched
streaming pipeline whose buffer depths come from the FIFO simulator.

Three execution modes mirror the paper's deployment measurements:

  * **offline**  — the whole stage schedule compiled into a single XLA
    program over the full batch (max throughput; MLPerf Offline). Fused
    integer stages run on the Pallas kernels on TPU — ``threshold_matmul``
    for dense stages, the fused direct-conv ``conv_threshold`` (no
    materialized im2col) for conv stages lowered ``direct`` — and as the
    XLA-fused jnp reference otherwise (same integers either way).
  * **streaming_compiled** — the deployment hot path: the stage schedule is
    grouped into *segments* (``lower.group_segments`` — maximal runs of
    fused/integer stages between host boundaries) and each segment executes
    the whole micro-batched wave as ONE jit program: micro-batches advance
    through the segment's stages inside ``jax.lax`` control flow, with
    buffers donated between segment programs where the backend supports it.
    Python is crossed once per segment, not once per stage per micro-batch.
  * **streaming_host** — the reference queue-loop pipeline: micro-batches
    flow through per-stage programs connected by bounded queues whose
    capacities are *decided* by ``core.dataflow.optimize_fifo_depths`` — the
    paper's simulate-big/record-max/shrink-to-max+1 pass feeding a real
    execution. Kept for its observable occupancy/backpressure stats; it is
    asserted bit-identical to the compiled path.

The unfused per-node interpreter (``reference``) is kept as the baseline the
benchmarks compare against — it is what running the QIR graph layer by layer
without the compiler looks like.

The default streaming micro-batch (and the direct-conv kernel's row block)
can come from the FIFO-model autotuner (``deploy.autotune``) via
``apply_tuned`` / ``compile_graph(..., autotune=True)`` instead of the
historical hard-coded 16.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dataflow import micro_batch_stage, optimize_fifo_depths
from repro.core.qir import Graph
from repro.obs import timer as obs_timer
from repro.obs.tracer import NULL_TRACER
from repro.deploy.lower import (
    FlattenStage,
    FloatHeadStage,
    FusedConvThresholdStage,
    FusedThresholdStage,
    IntPoolStage,
    MegakernelSegment,
    RefChainStage,
    Segment,
    StageSchedule,
    group_segments,
    lower_graph,
    plan_megakernel,
)

#: Historical default micro-batch; used only when no tuned config is applied.
DEFAULT_MICRO_BATCH = 16


def stage_work(s) -> int:
    """Per-sample element count driving the FIFO cost model for one stage:
    ``fifo_work`` where the stage defines it (lowering-aware for convs),
    MACs for matmul-like stages, in*out as the last resort. Shared by
    ``plan_streaming`` and the serve-side service-time model
    (``repro.serve.slo``) so the two never disagree about stage cost."""
    work = getattr(s, "fifo_work", None)
    if work is None:
        work = getattr(s, "macs", None)
    if work is None:
        work = s.in_dim * s.out_dim
    return int(work)


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:  # pragma: no cover
        return False


@dataclasses.dataclass
class StreamingStats:
    """What the FIFO pass decided and what the pipeline actually did.

    ``mode`` distinguishes the host queue loop ("host": ``max_occupancy`` is
    *observed*) from the compiled segment-wave path ("compiled":
    ``max_occupancy`` is the FIFO simulator's modeled occupancy — the
    compiled program has no per-hop queues to observe). ``segments`` lists
    the (start, stop) stage ranges of the executed segment grouping.
    """

    micro_batch: int
    n_micro: int
    fifo_depths: List[int]
    max_occupancy: List[int]
    sim_cycles: int
    mode: str = "host"
    segments: Optional[List[Tuple[int, int]]] = None
    #: stage ranges that executed as whole-network-resident megakernels
    #: (``docs/megakernel.md``); empty/None when every segment ran staged
    megakernel: Optional[List[Tuple[int, int]]] = None


class CompiledTinyModel:
    """A compiled spatial-dataflow executor for one lowered QIR graph."""

    def __init__(self, schedule: StageSchedule, graph: Optional[Graph] = None,
                 use_pallas: Optional[bool] = None,
                 interpret: Optional[bool] = None,
                 megakernel: Optional[bool] = None,
                 megakernel_budget_bytes: Optional[int] = None,
                 tracer=None):
        self.schedule = schedule
        self.graph = graph
        self.use_pallas = _on_tpu() if use_pallas is None else use_pallas
        self.interpret = interpret
        self.tuned = None          # deploy.autotune.TunedConfig, if applied
        #: megakernel dispatch: None = auto (fused whenever the residency
        #: planner admits the segment), True = same but assert-intent,
        #: False = force the per-stage reference path. The autotuner's
        #: measured megakernel-vs-staged choice lands here via apply_tuned.
        self.megakernel = megakernel
        self.megakernel_budget_bytes = megakernel_budget_bytes
        #: obs.Tracer sink for segment/stage spans and FIFO occupancy
        #: counters; NULL_TRACER keeps every instrumentation site a no-op
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self._rebuild()

    def set_tracer(self, tracer) -> "CompiledTinyModel":
        """Install (or clear, with ``None``) the obs tracer; returns self."""
        self.tracer = tracer if tracer is not None else NULL_TRACER
        return self

    def set_megakernel(self, mode: Optional[bool],
                       budget_bytes: Optional[int] = None
                       ) -> "CompiledTinyModel":
        """Re-plan megakernel dispatch (None = auto / True / False) and drop
        the stale compiled programs; ``budget_bytes`` overrides the planner's
        VMEM cap (tests force the fallback with a tiny one) and None restores
        the default cap. Returns self."""
        self.megakernel = mode
        self.megakernel_budget_bytes = budget_bytes
        self._rebuild()
        return self

    def _rebuild(self):
        """(Re)create every compiled entry point from the current schedule —
        called at construction and after ``apply_tuned`` mutates stage
        parameters (jit closures capture the stage objects at trace time, so
        stale programs must be dropped)."""
        # residency-plan megakernel runs per compiled segment first — the
        # offline and segment programs below dispatch through the plans
        self._mega_plans: Dict[int, MegakernelSegment] = {}
        self._mega_by_start: Dict[int, MegakernelSegment] = {}
        self.segments: List[Segment] = group_segments(self.schedule.stages)
        if self.megakernel is not False:
            for k, seg in enumerate(self.segments):
                plan = plan_megakernel(
                    self.schedule.stages, seg,
                    budget_bytes=self.megakernel_budget_bytes)
                if plan is not None:
                    self._mega_plans[k] = plan
                    self._mega_by_start[plan.start] = plan
        self._offline = jax.jit(self._run_all)
        self._stage_fns = [jax.jit(self._make_stage_fn(s))
                           for s in self.schedule.stages]
        self._segment_fns: Dict[int, Callable] = {}
        self._plan_cache: Dict[Tuple[int, int], Tuple[List[int], int]] = {}

    @property
    def default_micro_batch(self) -> int:
        return (self.tuned.micro_batch if self.tuned is not None
                else DEFAULT_MICRO_BATCH)

    def apply_tuned(self, cfg) -> "CompiledTinyModel":
        """Adopt an autotuned config (``deploy.autotune.TunedConfig``): the
        streaming default micro-batch, per-conv-stage ``block_h``,
        per-dense-stage ``block_m``/``block_n``, and the measured
        megakernel-vs-staged segment dispatch choice (schema v3) replace
        the magic constants. Returns self for chaining."""
        for s in self.schedule.stages:
            if isinstance(s, FusedConvThresholdStage):
                bh = cfg.block_h.get(s.name)
                if bh is not None:
                    s.block_h = min(int(bh), s.geom.out_h)
            elif isinstance(s, FusedThresholdStage):
                mn = getattr(cfg, "block_mn", {}).get(s.name)
                if mn is not None:
                    s.block_m, s.block_n = int(mn[0]), int(mn[1])
        mode = getattr(cfg, "segment_mode", None)
        if mode in ("megakernel", "staged"):
            self.megakernel = mode == "megakernel"
        self.tuned = cfg
        self._rebuild()
        return self

    # -- single-program (offline) path -----------------------------------
    def _apply_stage(self, s, h):
        if isinstance(s, (FusedThresholdStage, FusedConvThresholdStage)):
            if self.use_pallas:
                return s.apply_kernel(h, interpret=self.interpret)
            return s.apply_fast(h)
        if isinstance(s, (IntPoolStage, FlattenStage, FloatHeadStage)):
            return s.apply_ref(h)
        if isinstance(s, RefChainStage):
            if jnp.issubdtype(h.dtype, jnp.integer):
                h = h.astype(jnp.float32) * s.in_scale
            return s.apply_ref(h)
        raise TypeError(type(s))  # pragma: no cover

    def _make_stage_fn(self, s) -> Callable:
        return lambda h: self._apply_stage(s, h)

    def _apply_mega(self, plan: MegakernelSegment, h):
        """One planned stage run as a single program: the Pallas megakernel
        (weights/banks resident in VMEM, inter-stage tiles in scratch) on
        the kernel path, or the same chain as one straight-line fused XLA
        computation on CPU — either way ZERO per-stage dispatch, and
        bit-identical to the staged reference (order-free integer ops)."""
        stages = self.schedule.stages[plan.start:plan.stop]
        if self.use_pallas:
            from repro.kernels import ops

            return ops.mlp_megakernel(
                h.astype(jnp.int32),
                tuple(s.stage.w_int for s in stages),
                tuple(s.stage.thresholds for s in stages),
                block_m=plan.block_m, interpret=self.interpret)
        for s in stages:
            h = s.apply_fast(h)
        return h

    def _run_all(self, x_int):
        h = x_int
        stages = self.schedule.stages
        i = 0
        while i < len(stages):
            plan = self._mega_by_start.get(i)
            if plan is not None:
                h = self._apply_mega(plan, h)
                i = plan.stop
            else:
                h = self._apply_stage(stages[i], h)
                i += 1
        return h

    def offline(self, x_int) -> jnp.ndarray:
        """Full batch through the single fused program (MLPerf Offline)."""
        return self._offline(jnp.asarray(x_int))

    def stage_outputs(self, x_int) -> List[jnp.ndarray]:
        """Per-stage outputs (integer codes for fused stages) — the parity
        surface the exactness tests check against the float reference."""
        outs, h = [], jnp.asarray(x_int)
        for fn in self._stage_fns:
            h = fn(h)
            outs.append(h)
        return outs

    def predict(self, x_int) -> jnp.ndarray:
        return jnp.argmax(self.offline(x_int), axis=-1)

    # -- unfused reference (what the benchmarks beat) ---------------------
    def reference(self, x_int) -> jnp.ndarray:
        """Per-node eager interpretation of the source QIR graph."""
        if self.graph is None:
            raise ValueError("compile with graph= to keep the reference path")
        x = np.asarray(x_int, np.float32) * self.schedule.in_scale
        out = self.graph.run({self.graph.inputs[0]: x})
        return jnp.asarray(out[self.graph.outputs[0]])

    # -- per-stage timing (feeds the scenario stage_ms breakdown) ---------
    def stage_latencies(self, x, iters: int = 5) -> List[Dict[str, object]]:
        """Median wall-time per compiled stage on one representative batch.

        Per stage: one compile call, one *discarded* warm iteration, then
        ``iters`` timed samples, median reported — enough samples that the
        breakdown (and the autotuner's measured refinement it seeds) is
        stable against scheduler noise. Runs the per-stage programs in
        schedule order (each stage's input is the previous stage's real
        output) so conv-vs-dense costs are visible in scenario reports.

        Every timed sample is also recorded as a ``stage`` span on the
        model's tracer; the returned medians are computed from the SAME
        clock readings the spans carry, so
        ``obs.report.stage_medians_ms`` reproduces this breakdown from the
        trace exactly (cross-checked in tests)."""
        tr = self.tracer
        out = []
        h = jnp.asarray(x)
        for s, fn in zip(self.schedule.stages, self._stage_fns):
            y = fn(h)
            jax.block_until_ready(y)      # compile
            jax.block_until_ready(fn(h))  # discarded warm iteration
            times = []
            for it in range(max(iters, 1)):
                t0 = obs_timer.now()
                jax.block_until_ready(fn(h))
                t1 = obs_timer.now()
                if tr.enabled:
                    tr.add_span("stage", t0, t1, cat="probe",
                                args={"stage": s.name,
                                      "kind": type(s).__name__, "iter": it})
                times.append(t1 - t0)
            times.sort()
            out.append({"stage": s.name, "kind": type(s).__name__,
                        "ms": times[len(times) // 2] * 1e3})
            h = y
        return out

    # -- streaming (micro-batched pipeline) -------------------------------
    def plan_streaming(self, n_micro: int, micro_batch: int = 1
                       ) -> Tuple[List[int], int]:
        """Size the inter-stage queues with the paper's FIFO pass.

        Each stage's simulated service time scales with its per-sample work
        times the micro-batch size, plus a fixed per-hop overhead
        (``core.dataflow.micro_batch_stage``) — the cost model the
        micro-batch autotuner searches over. Work is parameterized on the
        lowering kind: MACs for dense stages, im2col tile counts (output
        tiles x patch size) for ``im2col`` conv stages, but only *output*
        tiles for ``direct`` fused conv stages — the fused kernel never
        emits patch tiles into the pipeline, so sizing its FIFOs from im2col
        counts would over-buffer (``fifo_work`` on each stage class). Rate
        mismatches between wide and narrow layers then show up as occupancy,
        exactly what the RTL simulation measured on the FPGA.

        Plans are memoized per (n_micro, micro_batch) — the simulation is
        deterministic, and the streaming entry points re-plan every call.
        """
        cached = self._plan_cache.get((n_micro, micro_batch))
        if cached is not None:
            return list(cached[0]), cached[1]
        sim = [micro_batch_stage(s.name, stage_work(s), micro_batch)
               for s in self.schedule.stages]
        res = optimize_fifo_depths(sim, n_tokens=n_micro)
        plan = (list(res["optimized_depths"]), int(res["optimized_cycles"]))
        self._plan_cache[(n_micro, micro_batch)] = plan
        return list(plan[0]), plan[1]

    def _pad_micro(self, x_int, micro_batch: int):
        x_int = jnp.asarray(x_int)
        n = x_int.shape[0]
        pad = (-n) % micro_batch
        if pad:
            x_int = jnp.concatenate(
                [x_int, jnp.zeros((pad,) + x_int.shape[1:], x_int.dtype)])
        return x_int, n, x_int.shape[0] // micro_batch

    def streaming_host(self, x_int, micro_batch: Optional[int] = None,
                       fifo_depths: Optional[Sequence[int]] = None,
                       feed_order: Optional[Sequence[int]] = None,
                       ) -> Tuple[jnp.ndarray, StreamingStats]:
        """The reference queue-loop pipeline: bounded host-side queues.

        Numerically identical to ``offline`` / ``streaming_compiled``; the
        difference is the execution schedule: at most ``depth[i]``
        micro-batches may queue in front of stage i, the capacities coming
        from the FIFO optimizer. This path crosses Python once per stage per
        micro-batch, so it is NOT the deployment hot path — it is kept as
        the observable reference: its occupancy stats are what validate the
        FIFO model, and the compiled path is asserted bit-identical to it.

        ``micro_batch=None`` resolves to the same (autotuned) default as
        ``streaming_compiled``, so the two entry points always compare the
        same schedule. ``fifo_depths`` overrides the optimizer's capacities
        (backpressure testing: depth-1 FIFOs must still make progress);
        ``feed_order`` permutes micro-batch admission (the idx bookkeeping
        must restore batch order regardless).
        """
        micro_batch = (int(micro_batch) if micro_batch
                       else self.default_micro_batch)
        x_int, n, n_micro = self._pad_micro(x_int, micro_batch)
        depths, sim_cycles = self.plan_streaming(n_micro,
                                                 micro_batch=micro_batch)
        if fifo_depths is not None:
            if len(fifo_depths) != len(depths):
                raise ValueError(
                    f"fifo_depths has {len(fifo_depths)} entries for "
                    f"{len(depths)} pipeline queues: {list(fifo_depths)}")
            depths = [max(1, int(d)) for d in fifo_depths]

        n_stages = len(self.schedule.stages)
        queues = [collections.deque() for _ in range(n_stages + 1)]
        max_occ = [0] * (n_stages + 1)
        order = list(feed_order) if feed_order is not None \
            else list(range(n_micro))
        if sorted(order) != list(range(n_micro)):
            raise ValueError(
                f"feed_order must be a permutation of range({n_micro}), "
                f"got {order}")
        feed = [(i, x_int[i * micro_batch:(i + 1) * micro_batch])
                for i in order]
        feed_i = 0
        done: List[Optional[jnp.ndarray]] = [None] * n_micro

        tr = self.tracer
        while feed_i < n_micro or any(len(q) > 0 for q in queues[:-1]):
            # admit into the input queue while its FIFO has room
            while feed_i < n_micro and len(queues[0]) < depths[0]:
                queues[0].append(feed[feed_i])
                max_occ[0] = max(max_occ[0], len(queues[0]))
                feed_i += 1
            if tr.enabled:
                tr.counter("fifo0", len(queues[0]), cat="fifo", tid=1)
            # fire stages downstream-first so space frees upstream
            for si in reversed(range(n_stages)):
                out_cap = depths[si + 1] if si + 1 < n_stages else n_micro + 1
                if queues[si] and len(queues[si + 1]) < out_cap:
                    idx, h = queues[si].popleft()
                    t0 = obs_timer.now() if tr.enabled else 0.0
                    h = self._stage_fns[si](h)
                    queues[si + 1].append((idx, h))
                    max_occ[si + 1] = max(max_occ[si + 1], len(queues[si + 1]))
                    if tr.enabled:
                        tr.add_span("fire", t0, obs_timer.now(), cat="fifo",
                                    tid=si + 1,
                                    args={"stage": self.schedule
                                          .stages[si].name, "micro": idx})
                        tr.counter(f"fifo{si + 1}", len(queues[si + 1]),
                                   cat="fifo", tid=si + 2)
            while queues[-1]:
                idx, y = queues[-1].popleft()
                done[idx] = y
        y = jnp.concatenate([jnp.asarray(d) for d in done])[:n]
        return y, StreamingStats(micro_batch=micro_batch, n_micro=n_micro,
                                 fifo_depths=depths, max_occupancy=max_occ,
                                 sim_cycles=sim_cycles, mode="host",
                                 segments=[(s.start, s.stop)
                                           for s in self.segments])

    # the historical name stays pointed at the observable reference path
    streaming = streaming_host

    # -- wave submission (the serve router's entry point) ------------------
    def submit_wave(self, x_int, valid: Optional[Sequence[bool]] = None,
                    micro_batch: Optional[int] = None
                    ) -> Tuple[jnp.ndarray, np.ndarray]:
        """Run ONE (possibly partially filled) micro-batch wave.

        The dynamic batcher (``repro.serve.router``) coalesces arriving
        requests into waves of at most ``micro_batch`` samples and cannot
        always fill a wave before its deadline — so this entry point accepts
        ``n <= micro_batch`` rows plus an optional ``valid`` mask, zero-pads
        up to the wave size (code 0 is value 0 under the export contract, so
        padding rows are inert), and pushes the wave through the SAME
        compiled segment programs as ``streaming_compiled`` (shape
        ``(1, micro_batch, ...)`` — one jit program per segment, compiled
        once per wave size). Returns ``(y, mask)`` where ``y`` covers the
        full wave and ``mask`` marks the rows that carry real queries;
        ``y[mask]`` is bit-identical to ``offline`` on the valid rows.

        The padding contract: invalid rows are forced to zero codes *before*
        execution (whatever the caller left in them), and nothing about an
        invalid row can perturb a valid one — stages are row-independent
        (matmul/conv/threshold act per sample), which the golden-model
        padded-wave tests assert.
        """
        mb = int(micro_batch) if micro_batch else self.default_micro_batch
        xb = np.asarray(x_int)
        n = xb.shape[0]
        if n > mb:
            raise ValueError(f"wave of {n} rows exceeds micro_batch={mb}")
        mask = np.ones(n, bool) if valid is None \
            else np.asarray(valid, bool).reshape(-1)
        if mask.shape[0] != n:
            raise ValueError(f"valid mask has {mask.shape[0]} entries "
                             f"for a wave of {n} rows")
        mask = np.concatenate([mask, np.zeros(mb - n, bool)])
        # pad + zero invalid rows on the HOST: the device only ever sees
        # the one constant (1, mb, ...) wave shape, so a lane serving
        # every fill level reuses a single compiled program — eager
        # device-side padding would trace a new program per fill level,
        # which is a mid-serve compile stall (a measured 20x wave-time
        # tail before this was moved host-side)
        buf = np.zeros((mb,) + xb.shape[1:], xb.dtype)
        buf[:n][mask[:n]] = xb[mask[:n]]
        wave = jnp.asarray(buf[None])
        try:
            wave = self._run_segments(wave, 1, mode="submit_wave")
        except Exception as e:
            # raw backend/runtime exceptions must not escape the serving
            # entry point untyped: wrap them so the router's failure
            # machinery (retry on another replica, quarantine) can catch
            # one class instead of guessing. The validation ValueErrors
            # above stay raw — a malformed wave is a caller bug, not a
            # device failure. Imported lazily on the failure path only:
            # deploy must not depend on serve at module level.
            from repro.serve.faults import WaveError

            raise WaveError(
                f"wave of {n}/{mb} rows failed in the compiled segment "
                f"pipeline: {type(e).__name__}: {e}") from e
        return wave[0], mask

    def _run_segments(self, wave, n_micro: int, mode: str):
        """Push a stacked wave through every segment program, recording one
        ``segment`` span per segment when a tracer is installed. Spans
        measure host-side dispatch (tid = segment index + 1); on CPU, where
        XLA dispatch is effectively synchronous, that is the execution time
        — on accelerators the wave-level span (router) is the honest
        end-to-end number."""
        tr = self.tracer
        for k, seg in enumerate(self.segments):
            t0 = obs_timer.now() if tr.enabled else 0.0
            if seg.compiled:
                wave = self._segment_fn(k)(wave)
            else:
                # host boundary: the fallback interpreter, per micro-batch
                outs = [wave[i] for i in range(n_micro)]
                for si in range(seg.start, seg.stop):
                    outs = [self._stage_fns[si](h) for h in outs]
                wave = jnp.stack(outs)
            if tr.enabled:
                tr.add_span("segment", t0, obs_timer.now(), cat="executor",
                            tid=k + 1,
                            args={"segment": k, "mode": mode,
                                  "compiled": bool(seg.compiled),
                                  "megakernel": k in self._mega_plans,
                                  "stages": [seg.start, seg.stop]})
        return wave

    # -- streaming, compiled (the deployment hot path) ---------------------
    def _segment_fn(self, k: int) -> Callable:
        """One jit program running segment k's whole micro-batch wave.

        Staged form: ``jax.lax.map`` advances every micro-batch through the
        segment's stage chain on device. When the residency planner admitted
        a megakernel for this segment, the planned stage run executes as ONE
        resident program over the *flattened* wave instead — no per-stage
        dispatch and no per-micro-batch loop (row-independent stages make
        the flattening exact); only the segment's pre/post remainder stages
        (e.g. the float head) still ride ``lax.map``. Either way the wave
        buffer is donated between segment programs on backends that support
        donation (TPU/GPU), so segment boundaries don't double-buffer the
        whole wave."""
        fn = self._segment_fns.get(k)
        if fn is None:
            seg = self.segments[k]
            plan = self._mega_plans.get(k)
            stages = self.schedule.stages[seg.start:seg.stop]

            def chain(run, h):
                for s in run:
                    h = self._apply_stage(s, h)
                return h

            if plan is None:
                def run_wave(wave):
                    return jax.lax.map(lambda h: chain(stages, h), wave)
            else:
                pre = self.schedule.stages[seg.start:plan.start]
                post = self.schedule.stages[plan.stop:seg.stop]

                def run_wave(wave):
                    if pre:
                        wave = jax.lax.map(lambda h: chain(pre, h), wave)
                    n_micro, mb = wave.shape[0], wave.shape[1]
                    flat = wave.reshape((n_micro * mb,) + wave.shape[2:])
                    flat = self._apply_mega(plan, flat)
                    wave = flat.reshape((n_micro, mb) + flat.shape[1:])
                    if post:
                        wave = jax.lax.map(lambda h: chain(post, h), wave)
                    return wave

            donate = (0,) if jax.default_backend() in ("tpu", "gpu") else ()
            fn = jax.jit(run_wave, donate_argnums=donate)
            self._segment_fns[k] = fn
        return fn

    def streaming_compiled(self, x_int, micro_batch: Optional[int] = None
                           ) -> Tuple[jnp.ndarray, StreamingStats]:
        """Run the batch as a micro-batched pipeline without the host loop.

        The batch is cut into micro-batches, stacked into one wave array,
        and pushed through each compiled segment as ONE jit program
        (``_segment_fn``); only host-boundary segments (fallback float
        chains) return to Python, once per micro-batch. Bit-identical to
        ``offline`` and ``streaming_host`` — same stage semantics, different
        schedule. ``micro_batch=None`` uses the autotuned default
        (``apply_tuned``), else ``DEFAULT_MICRO_BATCH``.
        """
        mb = int(micro_batch) if micro_batch else self.default_micro_batch
        x_int, n, n_micro = self._pad_micro(x_int, mb)
        depths, sim_cycles = self.plan_streaming(n_micro, micro_batch=mb)
        wave = x_int.reshape((n_micro, mb) + x_int.shape[1:])
        wave = self._run_segments(wave, n_micro, mode="streaming_compiled")
        y = wave.reshape((n_micro * mb,) + wave.shape[2:])[:n]
        # no host queues to observe: report the FIFO model's occupancy
        # (depth = max occupancy + 1 by construction of the optimizer)
        return y, StreamingStats(micro_batch=mb, n_micro=n_micro,
                                 fifo_depths=depths,
                                 max_occupancy=[d - 1 for d in depths],
                                 sim_cycles=sim_cycles, mode="compiled",
                                 segments=[(s.start, s.stop)
                                           for s in self.segments],
                                 megakernel=[(p.start, p.stop) for p in
                                             self._mega_plans.values()])


def compile_graph(graph: Graph, in_scale: float = 1.0 / 127.0,
                  use_pallas: Optional[bool] = None,
                  interpret: Optional[bool] = None,
                  conv_lowering: Optional[str] = None,
                  megakernel: Optional[bool] = None,
                  autotune: bool = False,
                  tuned=None, tracer=None) -> CompiledTinyModel:
    """The one-call deployment entry point: QIR json graph -> executor.

    ``conv_lowering`` picks the conv stage algorithm ("direct" fused kernel
    by default, "im2col" fallback) for both offline and streaming modes —
    the stage methods the executor dispatches through carry the choice.
    ``megakernel`` forces the whole-network-resident fused dispatch on
    (True) or off (False); the default None lets the residency planner
    decide per segment (``docs/megakernel.md``), and an applied tuned
    config's measured ``segment_mode`` choice overrides it.

    ``tuned`` applies a prebuilt ``deploy.autotune.TunedConfig``;
    ``autotune=True`` instead loads (or searches and caches) the config for
    this (model, platform) via ``deploy.autotune.autotune_model`` — honours
    the ``REPRO_AUTOTUNE*`` knobs, see ``docs/pipeline.md``.
    """
    schedule = lower_graph(graph, in_scale=in_scale,
                           conv_lowering=conv_lowering)
    cm = CompiledTinyModel(schedule, graph=graph, use_pallas=use_pallas,
                           interpret=interpret, megakernel=megakernel,
                           tracer=tracer)
    if tuned is not None:
        cm.apply_tuned(tuned)
    elif autotune:
        from repro.deploy.autotune import autotune_mode, autotune_model

        mode = autotune_mode()
        if mode != "off":
            cm.apply_tuned(autotune_model(cm, mode=mode))
    return cm


class CompiledJaxModel:
    """Deployment wrapper for models without a QIR export path: ``offline``
    is the whole forward as one jit program, ``reference`` the eager
    per-layer forward. The four Table-1 models all lower through the real
    compiler now (``export_qmlp``/``export_qcnn`` + ``compile_graph``); this
    stays as the harness for arbitrary research models."""

    def __init__(self, fwd: Callable, params, name: str = "jax"):
        self.name = name
        self.params = params
        self._fwd = fwd
        self._offline = jax.jit(fwd)

    def offline(self, x) -> jnp.ndarray:
        return self._offline(self.params, x)

    def reference(self, x) -> jnp.ndarray:
        return self._fwd(self.params, x)

    def predict(self, x) -> jnp.ndarray:
        return jnp.argmax(self.offline(x), axis=-1)
