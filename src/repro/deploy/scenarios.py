"""MLPerf-Tiny-style load scenarios over a compiled executor.

MLPerf Tiny (Banbury et al. 2021) measures every submission under fixed load
generators; the paper's Table 5 latency/energy numbers are its SingleStream
results. This module reproduces the four LoadGen modes against any object
with an ``offline(x) -> y`` callable (``deploy.executor`` compiled models):

  * SingleStream — one query at a time, batch 1; report latency percentiles
    (MLPerf scores the 90th percentile; we report p50/p90/p99).
  * MultiStream  — N concurrent streams issued as one batch per step.
  * Offline      — the whole query pool in one batch; max throughput.
  * Server       — Poisson arrivals at a target QPS into a single queue;
    latency includes queueing delay (the jitter the FIFO work absorbs).

Energy has no Joulescope here, so each report carries the paper-style proxy:
the roofline latency/energy model of ``core.codesign.deploy_report`` driven
by the model's BOPs/weight bits (``core.bops``), next to a measured proxy
``board_watts x measured_latency``.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

from repro.core.codesign import CHIP_WATTS, deploy_report
from repro.obs import timer as obs_timer
from repro.obs.tracer import NULL_TRACER


@dataclasses.dataclass
class ScenarioReport:
    scenario: str
    n_queries: int
    p50_ms: float
    p90_ms: float
    p99_ms: float
    throughput_qps: float
    energy_proxy_uJ: Optional[float] = None      # roofline (BOPs) model
    measured_energy_uJ: Optional[float] = None   # board watts x wall latency
    stage_ms: Optional[List[Dict]] = None        # per-stage latency breakdown
    extras: Dict = dataclasses.field(default_factory=dict)

    def row(self) -> Dict[str, object]:
        d = {
            "scenario": self.scenario,
            "n": self.n_queries,
            "p50_ms": round(self.p50_ms, 4),
            "p90_ms": round(self.p90_ms, 4),
            "p99_ms": round(self.p99_ms, 4),
            "qps": round(self.throughput_qps, 1),
        }
        if self.energy_proxy_uJ is not None:
            d["roofline_uJ"] = round(self.energy_proxy_uJ, 3)
        if self.measured_energy_uJ is not None:
            d["measured_uJ"] = round(self.measured_energy_uJ, 1)
        if self.stage_ms is not None:
            d["stage_ms"] = "|".join(
                f"{s['stage']}:{s['ms']:.3f}" for s in self.stage_ms)
        d.update(self.extras)
        return d


def _percentiles(lat_s: List[float]) -> Dict[str, float]:
    a = np.asarray(lat_s) * 1e3
    return {"p50": float(np.percentile(a, 50)),
            "p90": float(np.percentile(a, 90)),
            "p99": float(np.percentile(a, 99))}


def _finish(scenario, lats, n, span, model_cost=None, bits=8,
            stage_ms=None, **extras):
    p = _percentiles(lats)
    energy = None
    if model_cost is not None:
        energy = deploy_report(model_cost, batch=1, bits=bits)["energy_uJ"]
    return ScenarioReport(
        scenario=scenario, n_queries=n,
        p50_ms=p["p50"], p90_ms=p["p90"], p99_ms=p["p99"],
        throughput_qps=n / max(span, 1e-9),
        energy_proxy_uJ=energy,
        measured_energy_uJ=float(np.median(lats)) * CHIP_WATTS * 1e6,
        stage_ms=stage_ms,
        extras=extras)


def _stage_breakdown(compiled, x) -> Optional[List[Dict]]:
    """Per-stage latency probe on a representative batch, when the executor
    exposes one (``CompiledTinyModel.stage_latencies``); None otherwise.
    Uses the probe's own default sampling (median of 5 after a discarded
    warm iteration)."""
    probe = getattr(compiled, "stage_latencies", None)
    if probe is None:
        return None
    return probe(x)


def single_stream(infer: Callable, make_query: Callable[[int], np.ndarray],
                  n_queries: int = 64, warmup: int = 3,
                  model_cost=None, bits: int = 8,
                  compiled=None, tracer=None) -> ScenarioReport:
    """Batch-1 queries back to back; MLPerf scores p90 latency.

    ``make_query(i)`` returns ONE unbatched sample; the scenario adds the
    batch-1 axis (every scenario batches for itself). Pass the compiled
    executor as ``compiled`` to attach a per-stage latency breakdown.
    """
    tr = tracer if tracer is not None else NULL_TRACER
    for w in range(warmup):
        jax.block_until_ready(infer(np.asarray(make_query(w))[None]))
    lats = []
    t_start = obs_timer.now()
    for i in range(n_queries):
        x = np.asarray(make_query(i))[None]
        t0 = obs_timer.now()
        jax.block_until_ready(infer(x))
        lats.append(obs_timer.now() - t0)
    span = obs_timer.now() - t_start
    if tr.enabled:
        tr.add_span("scenario", t_start, t_start + span, cat="scenario",
                    args={"scenario": "SingleStream", "n": n_queries})
    stage_ms = (None if compiled is None
                else _stage_breakdown(compiled, np.asarray(make_query(0))[None]))
    return _finish("SingleStream", lats, n_queries, span, model_cost, bits,
                   stage_ms=stage_ms)


def multi_stream(infer: Callable, make_query: Callable[[int], np.ndarray],
                 n_streams: int = 8, n_queries: int = 64, warmup: int = 2,
                 model_cost=None, bits: int = 8,
                 tracer=None) -> ScenarioReport:
    """N concurrent streams per step: one batched inference serves all
    streams; a step's latency applies to every query in it."""
    tr = tracer if tracer is not None else NULL_TRACER
    steps = max(1, n_queries // n_streams)
    batch0 = np.stack([make_query(s) for s in range(n_streams)])
    for _ in range(warmup):
        jax.block_until_ready(infer(batch0))
    lats = []
    t_start = obs_timer.now()
    for i in range(steps):
        xb = np.stack([make_query(i * n_streams + s) for s in range(n_streams)])
        t0 = obs_timer.now()
        jax.block_until_ready(infer(xb))
        lats.extend([obs_timer.now() - t0] * n_streams)
    span = obs_timer.now() - t_start
    if tr.enabled:
        tr.add_span("scenario", t_start, t_start + span, cat="scenario",
                    args={"scenario": "MultiStream",
                          "n": steps * n_streams, "streams": n_streams})
    return _finish("MultiStream", lats, steps * n_streams, span,
                   model_cost, bits, streams=n_streams)


def offline(infer: Callable, make_query: Callable[[int], np.ndarray],
            n_samples: int = 256, warmup: int = 2, iters: int = 3,
            model_cost=None, bits: int = 8, compiled=None,
            tracer=None) -> ScenarioReport:
    """Whole pool in one batch; the throughput scenario.

    Times ``iters`` post-warmup runs and reports the *median* span — a
    single run's wall clock flaps on CPU noise, which is what used to flip
    marginal speedup flags (``beats_im2col``) between benchmark runs.
    """
    tr = tracer if tracer is not None else NULL_TRACER
    xb = np.stack([make_query(i) for i in range(n_samples)])
    for _ in range(warmup):
        jax.block_until_ready(infer(xb))
    spans = []
    for it in range(max(iters, 1)):
        t0 = obs_timer.now()
        jax.block_until_ready(infer(xb))
        t1 = obs_timer.now()
        if tr.enabled:
            tr.add_span("scenario", t0, t1, cat="scenario",
                        args={"scenario": "Offline", "n": n_samples,
                              "iter": it})
        spans.append(t1 - t0)
    spans.sort()
    span = spans[len(spans) // 2]
    per_query = span / n_samples
    stage_ms = None if compiled is None else _stage_breakdown(compiled, xb)
    return _finish("Offline", [per_query] * n_samples, n_samples, span,
                   model_cost, bits, stage_ms=stage_ms, batch=n_samples,
                   iters=max(iters, 1))


def streaming_pipeline(compiled, make_query: Callable[[int], np.ndarray],
                       n_samples: int = 256, micro_batch: Optional[int] = None,
                       warmup: int = 1, iters: int = 3,
                       model_cost=None, bits: int = 8,
                       tracer=None) -> ScenarioReport:
    """The Offline pool through the compiled streaming pipeline.

    Runs ``compiled.streaming_compiled`` (one jit program per segment wave)
    over the whole pool; ``micro_batch=None`` consumes the executor's
    autotuned default (``deploy.autotune``) instead of a magic constant.
    Reports the median span of ``iters`` runs like ``offline``, plus the
    FIFO plan that scheduled it.
    """
    tr = tracer if tracer is not None else NULL_TRACER
    xb = np.stack([make_query(i) for i in range(n_samples)])
    for _ in range(max(warmup, 1)):
        y, _ = compiled.streaming_compiled(xb, micro_batch=micro_batch)
        jax.block_until_ready(y)
    spans = []
    stats = None
    for it in range(max(iters, 1)):
        t0 = obs_timer.now()
        y, stats = compiled.streaming_compiled(xb, micro_batch=micro_batch)
        jax.block_until_ready(y)
        t1 = obs_timer.now()
        if tr.enabled:
            tr.add_span("scenario", t0, t1, cat="scenario",
                        args={"scenario": "StreamingOffline",
                              "n": n_samples, "iter": it})
        spans.append(t1 - t0)
    spans.sort()
    span = spans[len(spans) // 2]
    return _finish("StreamingOffline", [span / n_samples] * n_samples,
                   n_samples, span, model_cost, bits,
                   micro_batch=stats.micro_batch,
                   fifo_depths=str(stats.fifo_depths),
                   segments=str(stats.segments), batch=n_samples)


def server_poisson(infer: Callable, make_query: Callable[[int], np.ndarray],
                   qps: float = 200.0, n_queries: int = 128, seed: int = 0,
                   warmup: int = 3, model_cost=None, bits: int = 8,
                   tracer=None) -> ScenarioReport:
    """Poisson arrivals into a single-worker queue.

    Arrival times are drawn up front; the worker serves FIFO, so reported
    latency = queueing delay + service time. This is MLPerf's Server mode
    shrunk to one process: it answers "at what offered load do tails blow
    up", which is the question the paper's FIFO sizing answers on-chip.

    The whole query pool is materialized (and batched) before the clock
    starts, and the warmup ends with a discarded warm iteration on a real
    pool query (the ``stage_latencies`` convention) — so the compiled
    program is reused, warm, across the Poisson loop and no per-query
    host-side array construction or compile ever lands inside a measured
    latency.
    """
    tr = tracer if tracer is not None else NULL_TRACER
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / qps, n_queries))
    queries = [np.asarray(make_query(i))[None] for i in range(n_queries)]
    for w in range(max(warmup, 1)):
        jax.block_until_ready(infer(queries[w % n_queries]))
    jax.block_until_ready(infer(queries[0]))   # discarded warm iteration
    lats = []
    t_start = obs_timer.now()
    free_at = 0.0
    for i in range(n_queries):
        now = obs_timer.now() - t_start
        if now < arrivals[i]:
            obs_timer.sleep(arrivals[i] - now)
        jax.block_until_ready(infer(queries[i]))
        done = obs_timer.now() - t_start
        lats.append(done - arrivals[i])
        free_at = done
    span = free_at - arrivals[0]
    if tr.enabled:
        tr.add_span("scenario", t_start, t_start + free_at, cat="scenario",
                    args={"scenario": "Server", "n": n_queries,
                          "offered_qps": qps})
    return _finish("Server", lats, n_queries, span, model_cost, bits,
                   offered_qps=qps)


def server_streaming(compiled, make_query: Callable[[int], np.ndarray],
                     qps: float = 200.0, n_queries: int = 128, seed: int = 0,
                     max_wait_ms: float = 2.0,
                     p99_budget_ms: Optional[float] = None,
                     micro_batch: Optional[int] = None,
                     service_model=None, warmup: int = 1,
                     model_cost=None, bits: int = 8,
                     tracer=None, engine=None) -> ScenarioReport:
    """MLPerf Server mode over the dynamic-batching serve router.

    Where ``server_poisson`` serves each arrival alone (batch 1, one
    worker), this scenario drives the ``repro.serve`` router: Poisson
    arrivals are coalesced into padded micro-batch waves (the autotuned
    wave size by default, ``max_wait_ms`` deadline) and dispatched through
    the executor's compiled segment programs — the PR-4 streaming pipeline
    finally fed by request traffic rather than a pre-batched pool. With a
    ``p99_budget_ms`` the SLO controller sheds load it estimates would
    blow the budget; shed requests count into ``shed_rate`` but not into
    the latency percentiles (MLPerf Server accounting: an over-SLO result
    is invalid either way, an explicit shed is at least cheap).

    When the executor exposes ``offline``, every served result is checked
    bit-exact against it (``extras["bit_exact_vs_offline"]``) — padded
    partial waves included, which is the wave-padding contract under real
    traffic.
    """
    from repro.serve import Router, RouterConfig, poisson_trace

    class _Clock:
        """Adapter reading through the injectable obs timer
        (``repro.obs.timer``) so the deterministic-clock tests control
        the router too."""

        def now(self) -> float:
            return obs_timer.now()

        def sleep(self, seconds: float) -> None:
            if seconds > 0:
                obs_timer.sleep(seconds)

    queries = [np.asarray(make_query(i)) for i in range(n_queries)]
    submit = getattr(compiled, "submit_wave", None)
    for w in range(max(warmup, 0)):
        if submit is None:
            break
        y, _ = submit(queries[w % n_queries][None],
                      micro_batch=micro_batch)
        jax.block_until_ready(y)               # compile the wave program
    cfg = RouterConfig(max_wait_ms=max_wait_ms, micro_batch=micro_batch,
                       p99_budget_ms=p99_budget_ms)
    router = Router({"m": compiled}, cfg, clock=_Clock(),
                    service_models=(None if service_model is None
                                    else {"m": service_model}),
                    tracer=tracer, engine=engine)
    trace = poisson_trace(qps=qps, n=n_queries, seed=seed)
    reqs = router.run_trace("m", trace, lambda i: queries[i])
    served = [r for r in reqs if not r.shed]
    shed = len(reqs) - len(served)
    lats = [r.latency_s for r in served] or [0.0]
    span = (max(r.done_t for r in served) - min(r.arrival_t for r in served)
            if served else 1e-9)
    snap = router.stats()["m"]["metrics"]
    exact = None
    if served and hasattr(compiled, "offline"):
        xb = np.stack([r.x for r in served])
        y_ref = np.asarray(compiled.offline(xb))
        got = np.stack([np.asarray(r.result) for r in served])
        exact = bool(np.array_equal(got, y_ref)) if np.issubdtype(
            y_ref.dtype, np.integer) else bool(
            np.allclose(got, y_ref, rtol=1e-6, atol=1e-6))
    extras = dict(offered_qps=qps, served=len(served), shed=shed,
                  shed_rate=shed / max(len(reqs), 1),
                  micro_batch=router.lanes["m"].micro_batch,
                  wave_occupancy=snap.mean_occupancy,
                  n_waves=snap.n_waves)
    if p99_budget_ms is not None:
        extras["p99_budget_ms"] = p99_budget_ms
        extras["met_slo"] = bool(served) and bool(np.percentile(
            np.asarray(lats) * 1e3, 99) <= p99_budget_ms)
    if exact is not None:
        extras["bit_exact_vs_offline"] = exact
    return _finish("ServerStreaming", lats, len(served), span,
                   model_cost, bits, **extras)


def run_all_scenarios(infer: Callable, make_query: Callable[[int], np.ndarray],
                      n_queries: int = 64, n_streams: int = 8,
                      offline_samples: int = 256, server_qps: float = 200.0,
                      model_cost=None, bits: int = 8, compiled=None,
                      tracer=None) -> List[ScenarioReport]:
    """The full MLPerf-Tiny sweep for one deployed model.

    When ``compiled`` exposes a streaming executor
    (``CompiledTinyModel.streaming_compiled``), the sweep also measures the
    Offline pool through the compiled streaming pipeline at its (autotuned)
    default micro-batch; when it exposes the wave-submission API
    (``submit_wave``), the Server load is additionally replayed through
    the dynamic-batching router (``ServerStreaming``).
    """
    reports = [
        single_stream(infer, make_query, n_queries=n_queries,
                      model_cost=model_cost, bits=bits, compiled=compiled,
                      tracer=tracer),
        multi_stream(infer, make_query, n_streams=n_streams,
                     n_queries=n_queries, model_cost=model_cost, bits=bits,
                     tracer=tracer),
        offline(infer, make_query, n_samples=offline_samples,
                model_cost=model_cost, bits=bits, compiled=compiled,
                tracer=tracer),
        server_poisson(infer, make_query, qps=server_qps,
                       n_queries=n_queries, model_cost=model_cost, bits=bits,
                       tracer=tracer),
    ]
    if compiled is not None and hasattr(compiled, "streaming_compiled"):
        reports.append(streaming_pipeline(
            compiled, make_query, n_samples=offline_samples,
            model_cost=model_cost, bits=bits, tracer=tracer))
    if compiled is not None and hasattr(compiled, "submit_wave"):
        reports.append(server_streaming(
            compiled, make_query, qps=server_qps, n_queries=n_queries,
            model_cost=model_cost, bits=bits, tracer=tracer))
    return reports
