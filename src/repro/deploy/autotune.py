"""FIFO-model-driven autotuner for the streaming deployment hot path.

The streaming executor historically ran with a hard-coded micro-batch of 16
and the direct-conv kernel picked its output-row block from a fixed
heuristic. This module replaces both magic constants with a search that is
**model-first, wall-clock second** (the hls4ml codesign loop stance: estimate
before you build):

  1. **Micro-batch** — every candidate size is priced by the paper's §3.1.2
     FIFO pass (``CompiledTinyModel.plan_streaming`` →
     ``core.dataflow.optimize_fifo_depths``) under the micro-batch-aware
     cost model (``core.dataflow.micro_batch_stage``: per-hop overhead vs
     pipeline fill/drain). The model ranks all candidates; only the top few
     get short *measured* probes (``streaming_compiled`` wall time, seeded
     by the ``stage_latencies`` breakdown), and the fastest probe wins.
  2. **Conv row block (block_h)** — pure model: minimize the banded input
     traffic (``core.bops.conv_input_band_bytes`` — halo rows re-fetched
     per block) subject to the kernel's VMEM budget for the double-buffered
     band and the int32 accumulator.
  3. **Segment dispatch (megakernel vs staged)** — where the residency
     planner (``deploy.lower.plan_megakernel``) admits a whole-network-
     resident megakernel, the two dispatch modes are ranked by the
     residency traffic model (``core.bops.megakernel_traffic_bytes`` vs
     ``staged_traffic_bytes``) and refined by measured probes of both modes
     at the winning micro-batch; the choice persists as
     ``TunedConfig.segment_mode`` (schema v3).

The winning ``TunedConfig`` is cached as a JSON artifact per
(model, platform) so compile_graph / the scenario benchmarks consume the
tuned numbers instead of constants, and the choice is reproducible across
runs. Two search modes share the model half:

  * **probe** (default) — the measured refinements above run; every probe
    lands in the audit trail (and from there in the costmodel training
    table). Schema v4 adds the ``block_mn`` measured refinement at the
    winning micro-batch, mirroring the megakernel probe.
  * **model** (``REPRO_AUTOTUNE=model``) — probe-FREE: the learned wave-
    cost predictor (``repro.costmodel``, trained on exactly those audit
    trails plus serve traces) ranks micro-batch and megakernel-vs-staged;
    block_h/block_mn stay pure-model. Zero wall-clock reads, fully
    deterministic, and the resulting config records ``source:
    "predicted"`` so downstream consumers can tell the provenance apart.

Knobs (``autotune_mode()`` — an explicit tri-state, unknown values are an
error rather than silently enabling probes):

  * ``REPRO_AUTOTUNE=off|0``      — disable (compile_graph(autotune=True)
    becomes a no-op; defaults are used)
  * ``REPRO_AUTOTUNE=probe|1``    — model-ranked, measured-probe-refined
  * ``REPRO_AUTOTUNE=model``      — probe-free via the learned predictor
  * ``REPRO_AUTOTUNE_CACHE=dir``  — cache directory (default
    ``.repro_autotune``)
  * ``REPRO_AUTOTUNE_FORCE=1``    — ignore the cache and re-search
  * ``REPRO_COSTMODEL_ARTIFACT``  — predictor artifact for model mode
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.bops import conv_input_band_bytes, schedule_cost
from repro.deploy.lower import FusedConvThresholdStage, FusedThresholdStage
from repro.obs import timer as obs_timer
from repro.obs.tracer import NULL_TRACER

CONFIG_VERSION = 4   # v4: + source provenance (probed|predicted) and the
                     # block_mn measured-probe audit trail (older caches
                     # re-search; v3 added segment_mode, v2 block_m/block_n)

#: Candidate micro-batch sizes (powers of two; filtered to <= batch).
MICRO_CANDIDATES = (1, 2, 4, 8, 16, 32, 64)

#: Candidate dense matmul blocks (powers of two; MXU-friendly).
DENSE_BLOCK_CANDIDATES = (32, 64, 128, 256, 512)

#: VMEM budget for the kernel's per-program working set (bytes). The band
#: is charged twice — the grid pipeline double-buffers it.
VMEM_BUDGET_BYTES = 1 << 21

#: Matmul M target for the conv kernel (``block_h * out_w`` rows): the
#: tie-break when block sizes stream equal bytes. Matches the
#: ``kernels.ops.plan_conv_blocks`` heuristic.
TARGET_ROWS = 256


#: Spellings the tri-state accepts; anything else raises — a typo like
#: ``REPRO_AUTOTUNE=modle`` must not silently fall back to probing.
_MODE_SPELLINGS = {
    "off": ("off", "0", "", "false", "no", "none", "disable", "disabled"),
    "probe": ("probe", "1", "on", "true", "yes", "probed", "measure"),
    "model": ("model", "predict", "predicted", "predictor"),
}


def autotune_mode() -> str:
    """Explicit tri-state from ``REPRO_AUTOTUNE``: off | probe | model.

    Replaces the old truthy check, under which ``REPRO_AUTOTUNE=model``
    would have been misread as plain-enabled probing by every call site.
    Unknown spellings are a hard error, never a silent default.
    """
    raw = os.environ.get("REPRO_AUTOTUNE", "probe").strip().lower()
    for mode, spellings in _MODE_SPELLINGS.items():
        if raw in spellings:
            return mode
    raise ValueError(
        f"REPRO_AUTOTUNE={raw!r}: expected off|probe|model "
        "(see deploy.autotune docstring)")


def autotune_enabled() -> bool:
    return autotune_mode() != "off"


def autotune_force() -> bool:
    return os.environ.get("REPRO_AUTOTUNE_FORCE", "0") not in ("0", "")


def cache_dir() -> str:
    return os.environ.get("REPRO_AUTOTUNE_CACHE", ".repro_autotune")


@dataclasses.dataclass
class TunedConfig:
    """The autotuner's compiled artifact for one (model, platform).

    ``candidates`` is the audit trail: every micro-batch candidate with the
    modeled FIFO numbers that ranked it (and the probe result where one
    ran), so the benchmark JSON can show *why* the winner won.
    """

    key: str                          # schedule fingerprint
    platform: str                     # jax backend the probes ran on
    micro_batch: int
    block_h: Dict[str, int]           # conv stage name -> output-row block
    fifo_depths: List[int]            # depths at the winning micro-batch
    modeled_cycles: int               # FIFO-sim cycles at the winner
    modeled_traffic_bytes: float      # per-query schedule traffic (tuned)
    candidates: List[Dict] = dataclasses.field(default_factory=list)
    block_h_model: Dict[str, Dict] = dataclasses.field(default_factory=dict)
    block_mn: Dict[str, List[int]] = dataclasses.field(default_factory=dict)
    block_mn_model: Dict[str, Dict] = dataclasses.field(default_factory=dict)
    segment_mode: str = "staged"      # "megakernel" | "staged" dispatch
    segment_mode_model: Dict = dataclasses.field(default_factory=dict)
    block_mn_probe: Dict = dataclasses.field(default_factory=dict)
    seed_stage_ms: Optional[List[Dict]] = None   # stage_latencies seed
    probe_ms: Optional[Dict[str, float]] = None  # micro_batch -> median ms
    source: str = "probed"            # "probed" | "predicted" provenance
    version: int = CONFIG_VERSION

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict) -> "TunedConfig":
        fields = {f.name for f in dataclasses.fields(cls)}
        d = {k: v for k, v in d.items() if k in fields}
        d["block_h"] = {str(k): int(v)
                        for k, v in (d.get("block_h") or {}).items()}
        d["block_mn"] = {str(k): [int(v[0]), int(v[1])]
                         for k, v in (d.get("block_mn") or {}).items()}
        return cls(**d)


def config_path(key: str, directory: Optional[str] = None) -> str:
    return os.path.join(directory or cache_dir(), f"{key}.json")


def save_config(cfg: TunedConfig, directory: Optional[str] = None) -> str:
    path = config_path(cfg.key, directory)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(cfg.to_dict(), f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def load_config(key: str, directory: Optional[str] = None
                ) -> Optional[TunedConfig]:
    path = config_path(key, directory)
    if not os.path.exists(path):
        return None
    with open(path) as f:
        d = json.load(f)
    if d.get("version") != CONFIG_VERSION:
        return None   # stale schema: re-search
    return TunedConfig.from_dict(d)


def schedule_key(cm) -> str:
    """Stable fingerprint of (model, platform, schedule shape): the cache
    identity. Any change to the stage list, dims, lowerings, or conv
    geometry (kernel/stride/padding drive the halo model) re-tunes."""
    parts = [cm.schedule.meta.get("model", "model"),
             jax.default_backend(), f"{cm.schedule.in_scale:g}"]
    for s in cm.schedule.stages:
        part = (f"{type(s).__name__}:{s.name}:{s.in_dim}:{s.out_dim}:"
                f"{getattr(s, 'lowering', '')}")
        geom = getattr(s, "geom", None)
        if geom is not None:
            part += (f":k{geom.kernel}s{geom.stride}{geom.padding}"
                     f":{geom.in_h}x{geom.in_w}x{geom.in_ch}"
                     f"->{geom.out_h}x{geom.out_w}x{geom.out_ch}")
        parts.append(part)
    digest = hashlib.sha1("|".join(parts).encode()).hexdigest()[:10]
    return (f"{cm.schedule.meta.get('model', 'model')}-"
            f"{jax.default_backend()}-{digest}")


# ---------------------------------------------------------------------------
# conv row block: pure model
# ---------------------------------------------------------------------------

def block_h_candidates(out_h: int) -> List[int]:
    cands = {1}
    b = 2
    while b < out_h:
        cands.add(b)
        b *= 2
    cands.add(out_h)
    return sorted(cands)


def plan_block_h(geom, budget_bytes: int = VMEM_BUDGET_BYTES
                 ) -> Dict[str, object]:
    """Model-driven output-row block for one direct-conv stage.

    Minimize the banded input traffic (halo rows re-fetched per block,
    ``core.bops.conv_input_band_bytes``) over all block sizes whose working
    set fits VMEM: the int32 accumulator block plus TWO copies of the input
    band (the pipeline double-buffers the band fetch). Traffic ties — K=1
    convs and stride==kernel convs have no halo, so every block size
    streams the same bytes — break toward the matmul M target
    (``block_h * out_w`` near ``TARGET_ROWS``, the MXU-utilization
    heuristic of ``kernels.ops.plan_conv_blocks``). Returns the chosen
    block and the scored candidate table (the audit trail the benchmark
    JSON reports).
    """
    from repro.kernels.conv_threshold import band_rows, same_pads

    # the kernel's band blocks carry the SAME-padded width, not in_w
    if geom.padding == "SAME":
        (_, _), (pw_lo, pw_hi) = same_pads(geom.in_h, geom.in_w, geom.out_h,
                                           geom.out_w, geom.stride,
                                           geom.kernel)
        wp = geom.in_w + pw_lo + pw_hi
    else:
        wp = geom.in_w

    rows = []
    best = None

    def _key(r):
        return (r["input_bytes"], abs(r["block_h"] * geom.out_w
                                      - TARGET_ROWS))

    for bh in block_h_candidates(geom.out_h):
        acc_bytes = 4 * bh * geom.out_w * geom.out_ch
        band_bytes = 4 * band_rows(bh, geom.stride, geom.kernel) \
            * wp * geom.in_ch
        fits = acc_bytes + 2 * band_bytes <= budget_bytes
        traffic = conv_input_band_bytes(geom, bh)
        rows.append({"block_h": bh, "input_bytes": traffic,
                     "acc_bytes": acc_bytes, "band_bytes": band_bytes,
                     "fits_vmem": fits})
        if fits and (best is None or _key(rows[-1]) < _key(best)):
            best = rows[-1]
    if best is None:          # nothing fits: fall back to single rows
        best = rows[0]
    return {"block_h": int(best["block_h"]),
            "input_bytes": float(best["input_bytes"]),
            "candidates": rows}


# ---------------------------------------------------------------------------
# dense matmul blocks: pure model (same stance as block_h)
# ---------------------------------------------------------------------------

def plan_block_mn(in_dim: int, out_dim: int, n_steps: int = 255,
                  wave_rows: int = TARGET_ROWS,
                  budget_bytes: int = VMEM_BUDGET_BYTES,
                  candidates: Sequence[int] = DENSE_BLOCK_CANDIDATES
                  ) -> Dict[str, object]:
    """Model-driven ``(block_m, block_n)`` for one fused dense stage.

    ``wave_rows`` is the M the kernel will actually see — the autotuner
    passes the tuned micro-batch, since the kernel row-pads the wave up to
    ``block_m`` (an oversized row block is pure padding work).

    The ``threshold_matmul`` grid re-streams tiles: each x row-block is
    fetched once per *column* block and each w column-block once per *row*
    block, so for a wave of ``wave_rows`` rows the streamed bytes are

        ceil(N/bn) * M*K*4   (int32 activation codes)
      + ceil(M/bm) * K*N     (int8 weight codes)
      + ceil(M/bm) * N*S*4   (int32 threshold banks)

    — bigger ``bn`` cuts the x term, bigger ``bm`` cuts the w/threshold
    terms, and VMEM caps both: the double-buffered x and w tiles plus the
    int32 accumulator block and the bank slice must fit the same budget
    the conv ``block_h`` model uses. Ties break toward the MXU-native
    128x128 tile. Returns the choice plus the scored candidate table.
    """
    m_ref = max(int(wave_rows), 1)
    rows = []
    best = None

    def _key(r):
        return (r["stream_bytes"],
                abs(r["block_m"] - 128) + abs(r["block_n"] - 128))

    for bm in sorted({int(b) for b in candidates}):
        for bn in sorted({int(b) for b in candidates}):
            n_row = -(-m_ref // bm)
            n_col = -(-max(out_dim, 1) // bn)
            stream = (n_col * m_ref * in_dim * 4.0
                      + n_row * in_dim * out_dim * 1.0
                      + n_row * out_dim * n_steps * 4.0)
            vmem = (2 * 4 * bm * in_dim        # double-buffered x tile
                    + 2 * 1 * in_dim * bn      # double-buffered w tile
                    + 4 * bm * bn              # int32 accumulator
                    + 4 * bn * n_steps)        # threshold bank slice
            fits = vmem <= budget_bytes
            rows.append({"block_m": bm, "block_n": bn,
                         "stream_bytes": stream, "vmem_bytes": vmem,
                         "fits_vmem": fits})
            if fits and (best is None or _key(rows[-1]) < _key(best)):
                best = rows[-1]
    if best is None:              # nothing fits: smallest blocks
        best = min(rows, key=lambda r: r["vmem_bytes"])
    return {"block_m": int(best["block_m"]),
            "block_n": int(best["block_n"]),
            "stream_bytes": float(best["stream_bytes"]),
            "candidates": rows}


# ---------------------------------------------------------------------------
# SLO-constrained micro-batch (the serve router's operating point)
# ---------------------------------------------------------------------------

def slo_micro_batch(cm, p99_budget_ms: float,
                    stage_ms: Optional[List[Dict]] = None,
                    probe_batch: int = 8,
                    candidates: Sequence[int] = MICRO_CANDIDATES
                    ) -> Dict[str, object]:
    """Largest micro-batch whose modeled wave fill+drain fits the budget.

    The throughput objective (``autotune_model``) picks the micro-batch
    that drains an Offline pool fastest; a latency-budgeted server wants
    the *largest wave that still finishes inside the p99 budget* — bigger
    waves amortize dispatch overhead, but a full wave's service time lower-
    bounds every member's latency. The service model is the serve stack's
    (``repro.serve.slo.ServiceModel``): FIFO-model cycles calibrated to
    seconds by a ``stage_latencies`` probe at ``probe_batch``.
    """
    from repro.serve.slo import ServiceModel, slo_operating_point

    service = ServiceModel.from_compiled(cm, stage_ms=stage_ms,
                                         probe_batch=probe_batch)
    point = slo_operating_point(service, p99_budget_ms,
                                candidates=candidates)
    point["calibration"] = dict(service.calibration)
    return point


# ---------------------------------------------------------------------------
# micro-batch: FIFO model first, measured refinement second
# ---------------------------------------------------------------------------

def default_sample(cm, batch: int) -> jnp.ndarray:
    """A representative zero input batch shaped from the first stage."""
    s0 = cm.schedule.stages[0]
    if isinstance(s0, FusedConvThresholdStage):
        g = s0.geom
        return jnp.zeros((batch, g.in_h, g.in_w, g.in_ch), jnp.int32)
    return jnp.zeros((batch, s0.in_dim), jnp.int32)


def probe_streaming(cm, x, micro_batch: int, iters: int = 3,
                    runner: Optional[Callable] = None) -> float:
    """Median seconds of one streaming executor pass at a micro-batch size.

    The one wall-clock probe everywhere: the autotuner's measured
    refinement and the benchmark's compiled-vs-host comparison both call
    it, so their timing methodology cannot diverge. ``runner`` defaults to
    ``cm.streaming_compiled``; pass ``cm.streaming_host`` to time the
    reference path."""
    run = cm.streaming_compiled if runner is None else runner
    y, _ = run(x, micro_batch=micro_batch)
    jax.block_until_ready(y)       # compile + warm
    times = []
    for _ in range(max(iters, 1)):
        t0 = obs_timer.now()
        y, _ = run(x, micro_batch=micro_batch)
        jax.block_until_ready(y)
        times.append(obs_timer.now() - t0)
    times.sort()
    return times[len(times) // 2]


def autotune_model(cm, batch: int = 64,
                   candidates: Sequence[int] = MICRO_CANDIDATES,
                   topk: int = 3,
                   probe: Optional[Callable] = None,
                   sample: Optional[jnp.ndarray] = None,
                   directory: Optional[str] = None,
                   force: Optional[bool] = None,
                   tracer=None,
                   mode: Optional[str] = None,
                   predictor=None) -> TunedConfig:
    """Search (or load from cache) the TunedConfig for one compiled model.

    ``probe(cm, x, micro_batch) -> seconds`` overrides the wall-clock
    refinement — with a deterministic probe the whole search is
    deterministic (the model half always is). ``batch`` is the reference
    Offline pool the FIFO simulation prices.

    ``mode`` selects the search flavor: "probe" (measured refinement, the
    default) or "model" (probe-FREE — the ``repro.costmodel`` predictor
    ranks micro-batch and segment dispatch; zero wall-clock reads, zero
    model executions; the config records ``source: "predicted"``).
    ``None`` follows ``REPRO_AUTOTUNE``, with "off" read as "probe" — a
    direct call means the caller wants a search. ``predictor`` defaults to
    the shipped artifact (``repro.costmodel.load_default``).

    Each measured probe lands as a ``probe`` span (cat ``autotune``) on
    the tracer — ``tracer=`` or, by default, the model's own — carrying
    the candidate's modeled-vs-probed numbers, so the search's audit trail
    is visible on the same timeline as the serving it tunes.
    """
    tr = tracer if tracer is not None else getattr(cm, "tracer", NULL_TRACER)
    if mode is None:
        mode = autotune_mode()
        if mode == "off":
            mode = "probe"
    if mode not in ("probe", "model"):
        raise ValueError(f"autotune mode {mode!r}: expected probe|model")
    if mode == "model" and predictor is None:
        from repro.costmodel.model import load_default

        predictor = load_default()
    key = schedule_key(cm)
    if not (autotune_force() if force is None else force):
        cached = load_config(key, directory)
        if cached is not None:
            return cached

    # -- conv row blocks: pure model -------------------------------------
    block_h: Dict[str, int] = {}
    block_h_model: Dict[str, Dict] = {}
    for s in cm.schedule.stages:
        if isinstance(s, FusedConvThresholdStage) and s.lowering == "direct":
            plan = plan_block_h(s.geom)
            block_h[s.name] = plan["block_h"]
            block_h_model[s.name] = plan

    # -- micro-batch: rank every candidate by the FIFO model -------------
    mbs = sorted({int(m) for m in candidates if 1 <= int(m) <= batch})
    modeled = []
    for mb in mbs:
        n_micro = -(-batch // mb)
        depths, cycles = cm.plan_streaming(n_micro, micro_batch=mb)
        modeled.append({"micro_batch": mb, "n_micro": n_micro,
                        "modeled_cycles": cycles, "fifo_depths": depths})
    modeled.sort(key=lambda d: (d["modeled_cycles"], d["micro_batch"]))
    top = modeled[:max(1, topk)]

    seed_stage_ms = None
    probe_ms: Dict[str, float] = {}
    probe_fn = None
    x = None
    if mode == "model":
        # -- probe-free: the learned predictor prices EVERY candidate ----
        # (scoring is arithmetic, so there is no reason to stop at top-k);
        # total pool drain = waves x predicted per-wave service
        from repro.costmodel.features import wave_features

        for cand in modeled:
            wave_ms = float(predictor.predict_ms(
                wave_features(cm, cand["micro_batch"])))
            cand["predicted_wave_ms"] = wave_ms
            cand["predicted_total_ms"] = wave_ms * cand["n_micro"]
        winner = min(modeled, key=lambda d: (d["predicted_total_ms"],
                                             d["micro_batch"]))
    else:
        # -- measured refinement on the top candidates -------------------
        x = default_sample(cm, batch) if sample is None else sample
        if probe is None:
            # stage_latencies seeds the refinement: a cheap service-time
            # estimate decides how many probe repetitions noise requires
            seed_stage_ms = cm.stage_latencies(x[:min(batch, 8)])
            service_ms = sum(s["ms"] for s in seed_stage_ms)
            iters = 5 if service_ms < 5.0 else (3 if service_ms < 50.0
                                                else 1)
            probe_fn = lambda c, xx, mb: probe_streaming(c, xx, mb,
                                                         iters=iters)
        else:
            probe_fn = probe
        for cand in top:
            mb = cand["micro_batch"]
            t0 = obs_timer.now() if tr.enabled else 0.0
            t = float(probe_fn(cm, x, mb))
            probe_ms[str(mb)] = t * 1e3
            cand["probe_ms"] = t * 1e3
            if tr.enabled:
                tr.add_span("probe", t0, obs_timer.now(), cat="autotune",
                            args={"key": key, "micro_batch": mb,
                                  "n_micro": cand["n_micro"],
                                  "modeled_cycles": cand["modeled_cycles"],
                                  "probe_ms": t * 1e3})

        winner = min(top, key=lambda d: (d.get("probe_ms", float("inf")),
                                         d["modeled_cycles"]))
    if tr.enabled:
        tr.instant("autotune_winner", cat="autotune", key=key, mode=mode,
                   micro_batch=int(winner["micro_batch"]),
                   modeled_cycles=int(winner["modeled_cycles"]))

    # -- dense matmul blocks: pure model, at the winning wave size -------
    # (the tuned blocks govern the kernel on streaming/serving waves of
    # ``micro_batch`` rows; modeling a bigger reference M would pick a
    # block_m the kernel then row-pads every wave up to)
    block_mn: Dict[str, List[int]] = {}
    block_mn_model: Dict[str, Dict] = {}
    for s in cm.schedule.stages:
        if isinstance(s, FusedThresholdStage):
            plan = plan_block_mn(s.in_dim, s.out_dim,
                                 n_steps=int(s.stage.thresholds.shape[1]),
                                 wave_rows=int(winner["micro_batch"]))
            block_mn[s.name] = [plan["block_m"], plan["block_n"]]
            block_mn_model[s.name] = plan

    # -- dense blocks: measured refinement at the winning wave ------------
    # (mirrors the megakernel probe: model ranks, one probe pair decides,
    # ties break toward the model's pick; the probe pair lands in the
    # audit trail and from there in the costmodel training table).
    # ``apply_tuned`` discipline applies: the jit segment programs close
    # over the stage blocks at trace time, so every flip must _rebuild().
    block_mn_probe: Dict = {}
    if mode == "probe" and block_mn:
        wave = int(winner["micro_batch"])
        saved_blocks = {s.name: (s.block_m, s.block_n)
                        for s in cm.schedule.stages
                        if isinstance(s, FusedThresholdStage)}
        try:
            t_default = float(probe_fn(cm, x, wave))
            for s in cm.schedule.stages:
                if isinstance(s, FusedThresholdStage) and s.name in block_mn:
                    s.block_m, s.block_n = block_mn[s.name]
            cm._rebuild()
            t_tuned = float(probe_fn(cm, x, wave))
        finally:
            for s in cm.schedule.stages:
                if isinstance(s, FusedThresholdStage):
                    s.block_m, s.block_n = saved_blocks[s.name]
            cm._rebuild()
        pick = "tuned" if t_tuned <= t_default else "default"
        block_mn_probe = {
            "wave_rows": wave, "n_micro": -(-batch // wave),
            "probe_ms": {"tuned": t_tuned * 1e3,
                         "default": t_default * 1e3},
            "pick": pick,
        }
        if pick == "default":
            block_mn = {}
        if tr.enabled:
            tr.instant("block_mn_probe", cat="autotune", key=key,
                       pick=pick, tuned_ms=t_tuned * 1e3,
                       default_ms=t_default * 1e3)

    # -- segment dispatch: megakernel vs staged ---------------------------
    # Model first: the staged lax.map re-streams every stage's weights and
    # bank once per micro-batch, the megakernel fetches them once for the
    # whole flattened wave. Probe second: both modes measured at the
    # winning micro-batch; ties (e.g. deterministic probes) break toward
    # the mode the traffic model prefers.
    from repro.core.bops import (megakernel_traffic_bytes,
                                 staged_traffic_bytes)
    from repro.deploy.lower import plan_megakernel

    segment_mode = "staged"
    segment_mode_model: Dict = {}
    wave = int(winner["micro_batch"])
    plans = [p for p in (plan_megakernel(cm.schedule.stages, seg)
                         for seg in cm.segments) if p is not None]
    if plans:
        n_micro = -(-batch // wave)
        mega_b = staged_b = 0.0
        for p in plans:
            run = cm.schedule.stages[p.start:p.stop]
            mega_b += megakernel_traffic_bytes(run, n_micro * wave)
            staged_b += n_micro * staged_traffic_bytes(run, wave)
        segment_mode_model = {
            "wave_rows": wave, "n_micro": n_micro,
            "plans": [[p.start, p.stop] for p in plans],
            "megakernel_bytes": float(mega_b),
            "staged_bytes": float(staged_b),
            "bytes_saved": float(staged_b - mega_b),
        }
        model_pick = "megakernel" if mega_b <= staged_b else "staged"
        if mode == "model":
            from repro.costmodel.features import wave_features

            p_mega = float(predictor.predict_ms(
                wave_features(cm, wave, "megakernel")))
            p_staged = float(predictor.predict_ms(
                wave_features(cm, wave, "staged")))
            segment_mode_model["predicted_ms"] = {"megakernel": p_mega,
                                                  "staged": p_staged}
            if p_mega < p_staged:
                segment_mode = "megakernel"
            elif p_mega == p_staged:
                segment_mode = model_pick
        else:
            prev_mode = cm.megakernel
            try:
                cm.set_megakernel(True)
                t_mega = float(probe_fn(cm, x, wave))
                cm.set_megakernel(False)
                t_staged = float(probe_fn(cm, x, wave))
            finally:
                cm.set_megakernel(prev_mode)
            segment_mode_model["probe_ms"] = {"megakernel": t_mega * 1e3,
                                              "staged": t_staged * 1e3}
            if t_mega < t_staged:
                segment_mode = "megakernel"
            elif t_mega == t_staged:
                segment_mode = model_pick
        segment_mode_model["model_pick"] = model_pick
        if tr.enabled:
            tr.instant("segment_mode", cat="autotune", key=key,
                       mode=segment_mode, model_pick=model_pick,
                       bytes_saved=float(staged_b - mega_b))

    # traffic of the tuned schedule (block_h applied) — the modeled byte
    # number reported next to the choice
    saved = {s.name: s.block_h for s in cm.schedule.stages
             if isinstance(s, FusedConvThresholdStage)}
    try:
        for s in cm.schedule.stages:
            if isinstance(s, FusedConvThresholdStage) and s.name in block_h:
                s.block_h = block_h[s.name]
        traffic = float(schedule_cost(cm.schedule.stages).traffic_bytes)
    finally:
        for s in cm.schedule.stages:
            if isinstance(s, FusedConvThresholdStage):
                s.block_h = saved[s.name]

    cfg = TunedConfig(
        key=key, platform=jax.default_backend(),
        micro_batch=int(winner["micro_batch"]),
        block_h=block_h,
        fifo_depths=[int(d) for d in winner["fifo_depths"]],
        modeled_cycles=int(winner["modeled_cycles"]),
        modeled_traffic_bytes=traffic,
        candidates=modeled,
        block_h_model=block_h_model,
        block_mn=block_mn,
        block_mn_model=block_mn_model,
        segment_mode=segment_mode,
        segment_mode_model=segment_mode_model,
        block_mn_probe=block_mn_probe,
        seed_stage_ms=seed_stage_ms,
        probe_ms=probe_ms or None,
        source="predicted" if mode == "model" else "probed",
    )
    save_config(cfg, directory)
    return cfg
