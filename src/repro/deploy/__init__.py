"""repro.deploy — the QIR -> Pallas dataflow compiler and scenario runtime.

Closes the paper's loop: quantization-aware training exports a QIR graph
(``core.qir``: ``export_qmlp`` for the MLPs, ``export_qcnn`` for the conv
nets), this package streamlines and fuses it into integer dataflow stages
(``lower``), compiles the stage schedule into one jit program plus a
segment-compiled streaming pipeline whose FIFO depths, micro-batch, and
conv row blocks come from the FIFO-model autotuner (``executor``,
``autotune``), and measures it under the MLPerf Tiny load scenarios
(``scenarios``). See ``docs/pipeline.md`` for the streaming/autotune
architecture and ``docs/lowering.md`` for the stage/bit-exactness
contract.

What actually lowers to fused integer stages:

  * ``Dense  -> [BatchNorm] -> Relu -> Quant``  -> multi-threshold matmul
  * ``Conv2D -> [BatchNorm] -> Relu -> Quant``  -> fused direct-conv kernel
    (implicit im2col, thresholds in-register; ``conv_lowering="im2col"`` or
    REPRO_CONV_LOWERING=im2col falls back to patch-matrix + threshold_matmul)
  * ``Dense|Conv2D -> Quant(bipolar)``          -> single-threshold sign bank
    (the binary CNV path)
  * ``MaxPool`` / ``Flatten``                   -> integer pool / reshape
  * a trailing ``Dense``                        -> float logits head

Anything else falls back to a float per-node reference chain, so every
exported graph runs — just not fused.

    graph = export_qcnn(model, params, calibrate=x_cal)
    model = compile_graph(graph, in_scale=graph.meta["in_scale"])
    logits = model.offline(x_int)                     # MLPerf Offline
    reports = run_all_scenarios(model.offline, mk,    # the LoadGen sweep
                                compiled=model)       # + per-stage latency
"""

from repro.deploy.autotune import (  # noqa: F401
    TunedConfig,
    autotune_mode,
    autotune_model,
    load_config,
    save_config,
)
from repro.deploy.executor import (  # noqa: F401
    CompiledJaxModel,
    CompiledTinyModel,
    DEFAULT_MICRO_BATCH,
    StreamingStats,
    compile_graph,
)
from repro.deploy.lower import (  # noqa: F401
    CONV_LOWERINGS,
    ChainMatch,
    ConvGeom,
    default_conv_lowering,
    FlattenStage,
    FloatHeadStage,
    FusedConvThresholdStage,
    FusedThresholdStage,
    IntPoolStage,
    MegakernelSegment,
    RefChainStage,
    Segment,
    StageSchedule,
    group_segments,
    im2col,
    lower_graph,
    plan_megakernel,
    stage_for,
)
from repro.deploy.scenarios import (  # noqa: F401
    ScenarioReport,
    multi_stream,
    offline,
    run_all_scenarios,
    server_poisson,
    server_streaming,
    single_stream,
    streaming_pipeline,
)
