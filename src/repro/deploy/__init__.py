"""repro.deploy — the QIR -> Pallas dataflow compiler and scenario runtime.

Closes the paper's loop: quantization-aware training exports a QIR graph
(``core.qir``), this package streamlines and fuses it into integer dataflow
stages (``lower``), compiles the stage schedule into one jit program with an
optional FIFO-sized streaming pipeline (``executor``), and measures it under
the MLPerf Tiny load scenarios (``scenarios``).

    graph = export_qmlp(...)
    model = compile_graph(graph, in_scale=0.05)
    logits = model.offline(x_int)                     # MLPerf Offline
    reports = run_all_scenarios(model.offline, mk)    # the LoadGen sweep
"""

from repro.deploy.executor import (  # noqa: F401
    CompiledJaxModel,
    CompiledTinyModel,
    StreamingStats,
    compile_graph,
)
from repro.deploy.lower import (  # noqa: F401
    FloatHeadStage,
    FusedThresholdStage,
    RefChainStage,
    StageSchedule,
    lower_graph,
)
from repro.deploy.scenarios import (  # noqa: F401
    ScenarioReport,
    multi_stream,
    offline,
    run_all_scenarios,
    server_poisson,
    single_stream,
)
