"""The paper's four MLPerf Tiny submission models, in JAX with QAT.

Table 1 of the paper:
  IC  (hls4ml) : 8-12 bit CNN, 58 115 params, 83.5% acc   -> ``ICModel``
  IC  (FINN)   : 1-bit CNV-W1A1, 1 542 848 params, 84.5%  -> ``CNVModel``
  AD  (hls4ml) : 6-12 bit autoencoder, 22 285 params      -> ``ADAutoencoder``
  KWS (FINN)   : 3-bit MLP, 259 584 params, 82.5%         -> ``KWSMLP``

Parameter-count notes: CNV reproduces the paper count exactly (1 542 848).
The KWS MLP (490-256-256-256-12, no biases in the paper's count) matches
259 584 weights exactly. The IC and AD architectures follow the paper's
stated layer structure; where the prose is ambiguous the benchmark reports
our exact count next to the paper's.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.bops import ModelCost, conv_cost, dense_cost
from repro.core.qlayers import QConv2D, QDense, QDenseBatchNorm
from repro.core.quantizers import BinaryQuantizer, FixedPointQuantizer


# ---------------------------------------------------------------------------
# AD: autoencoder (hls4ml, 6-12 bit)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ADAutoencoder:
    """128 -> [72 72] -> 8 -> [72 72] -> 128; QDenseBatchNorm + ReLU hidden
    stages (paper §3.3: 5 hidden layers, width 72, downsampled 128-dim input)."""

    in_dim: int = 128
    width: int = 72
    bottleneck: int = 8
    weight_bits: int = 8
    act_bits: int = 8
    use_bn: bool = True

    @property
    def dims(self) -> List[int]:
        return [self.in_dim, self.width, self.width, self.bottleneck,
                self.width, self.width, self.in_dim]

    def layers(self):
        hidden = []
        ds = self.dims
        for i in range(len(ds) - 2):
            cls = QDenseBatchNorm if self.use_bn else QDense
            kw = {} if self.use_bn else {"relu": True}
            hidden.append(cls(ds[i], ds[i + 1], weight_bits=self.weight_bits,
                              act_bits=self.act_bits, **kw))
        head = QDense(ds[-2], ds[-1], weight_bits=self.weight_bits,
                      act_bits=32, relu=False)
        return hidden, head

    def init(self, key):
        hidden, head = self.layers()
        keys = jax.random.split(key, len(hidden) + 1)
        return {
            "hidden": [l.init(k) for l, k in zip(hidden, keys[:-1])],
            "head": head.init(keys[-1]),
        }

    def apply(self, params, x, train: bool = True):
        """Returns (recon, new_params) — BN stats update in train mode."""
        hidden, head = self.layers()
        new_hidden = []
        h = x
        for l, p in zip(hidden, params["hidden"]):
            if isinstance(l, QDenseBatchNorm):
                h, p = l.apply(p, h, train=train)
            else:
                h = l.apply(p, h, train=train)
            new_hidden.append(p)
        recon = head.apply(params["head"], h, train=train)
        return recon, {"hidden": new_hidden, "head": params["head"]}

    def anomaly_score(self, params, x):
        recon, _ = self.apply(params, x, train=False)
        return jnp.mean(jnp.square(recon - x), axis=-1)

    def cost(self) -> ModelCost:
        ds = self.dims
        ls = [dense_cost(f"fc{i}", ds[i], ds[i + 1], self.act_bits, self.weight_bits)
              for i in range(len(ds) - 1)]
        return ModelCost(ls)

    def n_params(self) -> int:
        hidden, head = self.layers()
        return sum(l.n_params() for l in hidden) + head.n_params()


# ---------------------------------------------------------------------------
# KWS: 3-bit MLP (FINN)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class KWSMLP:
    """490 (10 MFCC x 49 frames) -> 256 x3 (BN+ReLU) -> 12. 3-bit W/A,
    8-bit input (paper §3.4). Weight count 490*256+256*256*2+256*12=259 584."""

    in_dim: int = 490
    width: int = 256
    n_classes: int = 12
    weight_bits: int = 3
    act_bits: int = 3

    def layers(self):
        dims = [self.in_dim, self.width, self.width, self.width]
        hidden = [QDenseBatchNorm(dims[i], dims[i + 1], weight_bits=self.weight_bits,
                                  act_bits=self.act_bits) for i in range(3)]
        head = QDense(self.width, self.n_classes, weight_bits=self.weight_bits,
                      act_bits=32, relu=False)
        return hidden, head

    def init(self, key):
        hidden, head = self.layers()
        keys = jax.random.split(key, 4)
        return {"hidden": [l.init(k) for l, k in zip(hidden, keys[:3])],
                "head": head.init(keys[3])}

    def apply(self, params, x, train: bool = True):
        hidden, head = self.layers()
        new_hidden = []
        h = x
        for l, p in zip(hidden, params["hidden"]):
            h, p = l.apply(p, h, train=train)
            new_hidden.append(p)
        logits = head.apply(params["head"], h, train=train)
        return logits, {"hidden": new_hidden, "head": params["head"]}

    def cost(self) -> ModelCost:
        dims = [self.in_dim, self.width, self.width, self.width, self.n_classes]
        return ModelCost([
            dense_cost(f"fc{i}", dims[i], dims[i + 1], self.act_bits, self.weight_bits,
                       bias=False)
            for i in range(4)
        ])

    def n_weights(self) -> int:
        dims = [self.in_dim, self.width, self.width, self.width, self.n_classes]
        return sum(dims[i] * dims[i + 1] for i in range(4))


# ---------------------------------------------------------------------------
# IC: hls4ml v0.7 CNN (2-stack, no skips)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ICModel:
    """Paper §3.1.1 v0.7 model: 5 convs (32,4,32,32,4 filters; kernels
    1,4,4,4,4; strides 1,1,1,4,1) + dense head; fixed-point 8 total / 2
    integer bits (QKeras quantized_bits(8,2))."""

    filters: Tuple[int, ...] = (32, 4, 32, 32, 4)
    kernels: Tuple[int, ...] = (1, 4, 4, 4, 4)
    strides: Tuple[int, ...] = (1, 1, 1, 4, 1)
    n_classes: int = 10
    weight_bits: int = 8
    act_bits: int = 8
    in_hw: int = 32
    in_ch: int = 3

    def conv_layers(self):
        convs, cin = [], self.in_ch
        for f, k, s in zip(self.filters, self.kernels, self.strides):
            convs.append(QConv2D(cin, f, kernel=k, stride=s, padding="SAME",
                                 weight_bits=self.weight_bits,
                                 act_bits=self.act_bits, relu=True))
            cin = f
        return convs

    def feature_hw(self) -> int:
        hw = self.in_hw
        for s in self.strides:
            hw = -(-hw // s)  # ceil for SAME padding
        return hw

    def init(self, key):
        convs = self.conv_layers()
        keys = jax.random.split(key, len(convs) + 1)
        flat = self.feature_hw() ** 2 * self.filters[-1]
        head = QDense(flat, self.n_classes, weight_bits=self.weight_bits,
                      act_bits=32, relu=False)
        return {"convs": [c.init(k) for c, k in zip(convs, keys[:-1])],
                "head": head.init(keys[-1])}

    def apply(self, params, x, train: bool = True):
        convs = self.conv_layers()
        h = x
        for c, p in zip(convs, params["convs"]):
            h = c.apply(p, h, train=train)
        h = h.reshape(h.shape[0], -1)
        flat = self.feature_hw() ** 2 * self.filters[-1]
        head = QDense(flat, self.n_classes, weight_bits=self.weight_bits,
                      act_bits=32, relu=False)
        return head.apply(params["head"], h, train=train)

    def cost(self) -> ModelCost:
        ls, cin, hw = [], self.in_ch, self.in_hw
        for i, (f, k, s) in enumerate(zip(self.filters, self.kernels, self.strides)):
            hw = -(-hw // s)
            ls.append(conv_cost(f"conv{i}", cin, f, k, hw, hw,
                                self.act_bits, self.weight_bits))
            cin = f
        flat = hw * hw * self.filters[-1]
        ls.append(dense_cost("head", flat, self.n_classes,
                             self.act_bits, self.weight_bits))
        return ModelCost(ls)


# ---------------------------------------------------------------------------
# IC: CNV-W1A1 (FINN binary VGG)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CNVModel:
    """CNV-W1A1 (Umuroglu et al. 2017): 3 conv blocks (64,64 / 128,128 /
    256,256 3x3 VALID convs + 2x2 maxpool after the first two blocks... per
    the original: pool after each of the first two blocks and after none of
    the last) then FC 512, 512, 10. Binary W/A except 8-bit input layer.
    Weight count = 1 542 848 exactly (paper Table 1)."""

    channels: Tuple[int, ...] = (64, 64, 128, 128, 256, 256)
    fc: Tuple[int, ...] = (512, 512)
    n_classes: int = 10
    weight_bits: int = 1
    act_bits: int = 1
    in_hw: int = 32
    in_ch: int = 3
    pool_after: Tuple[int, ...] = (1, 3)  # 2x2 maxpool after these convs

    def conv_layers(self):
        convs, cin = [], self.in_ch
        for i, ch in enumerate(self.channels):
            # input layer consumes 8-bit images; the rest are binary
            convs.append(QConv2D(cin, ch, kernel=3, stride=1, padding="VALID",
                                 weight_bits=self.weight_bits,
                                 act_bits=8 if i == 0 else self.act_bits,
                                 weight_kind="binary", relu=False, use_bias=False))
            cin = ch
        return convs

    def init(self, key):
        convs = self.conv_layers()
        keys = jax.random.split(key, len(convs) + len(self.fc) + 1)
        params = {"convs": [c.init(k) for c, k in zip(convs, keys[: len(convs)])]}
        dims = [self.channels[-1], *self.fc, self.n_classes]
        fcs = []
        for i in range(len(dims) - 1):
            fc = QDense(dims[i], dims[i + 1], weight_bits=self.weight_bits,
                        act_bits=self.act_bits if i < len(dims) - 2 else 32,
                        weight_kind="binary", use_bias=False)
            fcs.append(fc.init(keys[len(convs) + i]))
        params["fcs"] = fcs
        return params

    def apply(self, params, x, train: bool = True):
        convs = self.conv_layers()
        h = x
        from repro.core.quantizers import ste_sign

        for i, (c, p) in enumerate(zip(convs, params["convs"])):
            h = c.apply(p, h, train=train)
            h = ste_sign(h)  # binary activation
            if i in self.pool_after:  # maxpool after blocks 1 and 2
                h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max,
                                          (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
        h = h.reshape(h.shape[0], -1)
        dims = [h.shape[-1], *self.fc, self.n_classes]
        for i, p in enumerate(params["fcs"]):
            fc = QDense(dims[i], dims[i + 1], weight_bits=self.weight_bits,
                        act_bits=32, weight_kind="binary", use_bias=False)
            h = fc.apply(p, h, train=train)
            if i < len(params["fcs"]) - 1:
                h = ste_sign(h)
        return h

    def n_weights(self) -> int:
        total, cin, hw = 0, self.in_ch, self.in_hw
        for i, ch in enumerate(self.channels):
            total += 3 * 3 * cin * ch
            cin = ch
        total += self.channels[-1] * self.fc[0]
        total += self.fc[0] * self.fc[1]
        total += self.fc[1] * self.n_classes
        return total

    def cost(self) -> ModelCost:
        ls, cin, hw = [], self.in_ch, self.in_hw
        for i, ch in enumerate(self.channels):
            hw = hw - 2  # VALID 3x3
            ls.append(conv_cost(f"conv{i}", cin, ch, 3, hw, hw,
                                8 if i == 0 else 1, 1, bias=False))
            if i in self.pool_after:
                hw //= 2
            cin = ch
        dims = [self.channels[-1], *self.fc, self.n_classes]
        for i in range(len(dims) - 1):
            ls.append(dense_cost(f"fc{i}", dims[i], dims[i + 1], 1, 1, bias=False))
        return ModelCost(ls)
