"""Attention: GQA + RoPE / M-RoPE / sliding-window, chunked (flash-style)
softmax for long sequences, and sequence-sharded KV-cache decode.

TPU adaptations worth noting (DESIGN.md §2):

  * ``chunked_attention`` is an online-softmax (flash) attention written in
    pure jnp with ``lax.scan`` over KV blocks — it never materializes the
    (S x S) score matrix, which is what makes the 32k-prefill cells lower
    with bounded memory. The Pallas kernel (kernels/flash_attention.py) is
    the TPU-optimized twin; this version is the portable oracle the dry-run
    lowers.

  * ``decode_attention`` writes the softmax over the cache explicitly
    (max / exp / sum / weighted-sum). With the KV cache sharded along the
    sequence axis (logical "kv_seq" -> mesh "model"), GSPMD turns those
    reductions into three tiny all-reduces — a flash-decode collective
    schedule with no shard_map needed.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models.layers import _dtype, _mx, linear_apply, linear_init
from repro.parallel.sharding import shard

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------

def rope_freqs(cfg: ArchConfig, positions):
    """positions (..., S) -> (cos, sin) of shape (..., S, hd//2)."""
    hd = cfg.hd
    inv = 1.0 / (cfg.rope_theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (B, S, H, hd); cos/sin: (B, S, hd//2) (broadcast over heads)."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    c, s = cos[..., None, :], sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(x.dtype)


def mrope_cos_sin(cfg: ArchConfig, positions_thw):
    """Qwen2-VL multimodal RoPE: positions_thw (3, B, S); head_dim halves are
    partitioned into (t, h, w) sections (cfg.mrope_sections sums to hd//2)."""
    sec = cfg.mrope_sections
    assert sum(sec) == cfg.hd // 2, "mrope sections must sum to head_dim//2"
    import numpy as np

    cos_all, sin_all = rope_freqs(cfg, positions_thw)       # (3, B, S, hd//2)
    splits = np.cumsum(sec)[:-1].tolist()
    cos_parts = jnp.split(cos_all, splits, axis=-1)
    sin_parts = jnp.split(sin_all, splits, axis=-1)
    cos = jnp.concatenate([cp[i] for i, cp in enumerate(cos_parts)], axis=-1)
    sin = jnp.concatenate([sp[i] for i, sp in enumerate(sin_parts)], axis=-1)
    return cos, sin                                          # (B, S, hd//2)


def positions_cos_sin(cfg: ArchConfig, positions):
    """positions: (B, S) int or (3, B, S) for mrope."""
    if cfg.mrope:
        if positions.ndim == 2:  # text-only fallback: same pos for t/h/w
            positions = jnp.broadcast_to(positions[None], (3, *positions.shape))
        return mrope_cos_sin(cfg, positions)
    return rope_freqs(cfg, positions)


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def attn_init(key, cfg: ArchConfig):
    kq, kk, kv, ko = jax.random.split(key, 4)
    H, K, hd, d = cfg.n_heads, cfg.n_kv_heads, cfg.hd, cfg.d_model
    return {
        "wq": linear_init(kq, d, (H, hd), cfg, bias=cfg.qkv_bias),
        "wk": linear_init(kk, d, (K, hd), cfg, bias=cfg.qkv_bias),
        "wv": linear_init(kv, d, (K, hd), cfg, bias=cfg.qkv_bias),
        "wo": linear_init(ko, H * hd, d, cfg, scale=(2 * cfg.n_layers * H * hd) ** -0.5),
    }


def attn_specs(cfg: ArchConfig):
    fsdp, heads = _mx("fsdp")[0], _mx("heads")[0]
    kvh, hflat = _mx("kv_heads")[0], _mx("heads_flat")[0]
    q = {"w": P(fsdp, heads, None)}
    kv = {"w": P(fsdp, kvh, None)}
    if cfg.qkv_bias:
        q["b"] = P(heads, None)
        kv["b"] = P(kvh, None)
    return {
        "wq": q,
        "wk": dict(kv),
        "wv": dict(kv),
        # fan-in of wo is flattened (H*hd,) — shardable even when H itself
        # does not divide the model axis (e.g. qwen1.5's 20 heads).
        "wo": {"w": P(hflat, fsdp)},
    }


# ---------------------------------------------------------------------------
# masks
# ---------------------------------------------------------------------------

def mask_bias(cfg: ArchConfig, q_pos, k_pos):
    """Additive mask bias: q_pos (Sq,), k_pos (Sk,) -> (Sq, Sk) float32."""
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if cfg.causal and not cfg.encoder_only:
        ok = ok & (k_pos[None, :] <= q_pos[:, None])
    if cfg.window > 0:
        ok = ok & (k_pos[None, :] > q_pos[:, None] - cfg.window)
    return jnp.where(ok, 0.0, NEG_INF)


# ---------------------------------------------------------------------------
# core attention
# ---------------------------------------------------------------------------

def naive_attention(q, k, v, bias):
    """q (B,Sq,H,hd), k/v (B,Sk,K,hd), bias (Sq,Sk) -> (B,Sq,H,hd)."""
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, Sq, K, G, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k).astype(jnp.float32)
    scores = scores * (hd ** -0.5) + bias[None, None, None]
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w.astype(v.dtype), v)
    return out.reshape(B, Sq, H, hd)


def live_block_pairs(cfg: ArchConfig, nq: int, nk: int, cq: int, ck: int):
    """Static (q_block, k_block) pairs that can contain unmasked entries,
    assuming contiguous monotone positions (true for every call site: train,
    prefill, and prefill-continuation all use arange positions).

    This is the causal-packing optimization (§Perf, beyond-paper): for causal
    self-attention only ~half the block pairs survive; for sliding-window
    attention only O(window/ck) diagonals survive; encoders keep all pairs.
    """
    pairs = []
    for qi in range(nq):
        q_lo, q_hi = qi * cq, qi * cq + cq - 1
        for ki in range(nk):
            k_lo, k_hi = ki * ck, ki * ck + ck - 1
            if cfg.causal and not cfg.encoder_only and k_lo > q_hi:
                continue                     # entirely in the future
            if cfg.window > 0 and k_hi <= q_lo - cfg.window:
                continue                     # entirely outside the window
            pairs.append((qi, ki))
    return pairs


def chunked_attention(cfg: ArchConfig, q, k, v, q_pos, k_pos):
    """Flash-style online-softmax attention over a statically packed set of
    live (q_block, kv_block) pairs.

    Never materializes more than (B, K, G, cq, ck) scores, and never computes
    a fully masked block: one lax.scan over the packed pair list carries the
    online-softmax state of all q blocks and updates the pair's q-block slot
    in place. Exact masking at block boundaries still comes from mask_bias.
    """
    B, Sq, H, hd = q.shape
    Sk, Kh = k.shape[1], k.shape[2]
    G = H // Kh
    cq = min(cfg.attn_chunk, Sq)
    ck = min(cfg.attn_chunk, Sk)
    assert Sq % cq == 0 and Sk % ck == 0
    nq, nk = Sq // cq, Sk // ck

    qg = jnp.moveaxis(q.reshape(B, nq, cq, Kh, G, hd), 1, 0)   # (nq,B,cq,K,G,hd)
    kc = jnp.moveaxis(k.reshape(B, nk, ck, Kh, hd), 1, 0)      # (nk,B,ck,K,hd)
    vc = jnp.moveaxis(v.reshape(B, nk, ck, Kh, hd), 1, 0)
    qp = q_pos.reshape(nq, cq)
    kp = k_pos.reshape(nk, ck)
    scale = hd ** -0.5

    pairs = live_block_pairs(cfg, nq, nk, cq, ck)
    qidx = jnp.asarray([p[0] for p in pairs], jnp.int32)
    kidx = jnp.asarray([p[1] for p in pairs], jnp.int32)

    def pair_step(carry, t):
        m, l, acc = carry                 # (nq,B,K,G,cq) / " / (nq,...,hd)
        qi, ki = qidx[t], kidx[t]
        qb = jax.lax.dynamic_index_in_dim(qg, qi, 0, keepdims=False)
        kb = jax.lax.dynamic_index_in_dim(kc, ki, 0, keepdims=False)
        vb = jax.lax.dynamic_index_in_dim(vc, ki, 0, keepdims=False)
        qpb = jax.lax.dynamic_index_in_dim(qp, qi, 0, keepdims=False)
        kpb = jax.lax.dynamic_index_in_dim(kp, ki, 0, keepdims=False)
        bias = mask_bias(cfg, qpb, kpb)
        s = jnp.einsum("bqkgh,bskh->bkgqs", qb, kb).astype(jnp.float32)
        s = s * scale + bias[None, None, None]

        m_prev = jax.lax.dynamic_index_in_dim(m, qi, 0, keepdims=False)
        l_prev = jax.lax.dynamic_index_in_dim(l, qi, 0, keepdims=False)
        a_prev = jax.lax.dynamic_index_in_dim(acc, qi, 0, keepdims=False)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        a_new = a_prev * corr[..., None] + jnp.einsum(
            "bkgqs,bskh->bkgqh", p.astype(vb.dtype), vb
        ).astype(jnp.float32)
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, qi, 0)
        l = jax.lax.dynamic_update_index_in_dim(l, l_new, qi, 0)
        acc = jax.lax.dynamic_update_index_in_dim(acc, a_new, qi, 0)
        return (m, l, acc), None

    m0 = jnp.full((nq, B, Kh, G, cq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((nq, B, Kh, G, cq), jnp.float32)
    a0 = jnp.zeros((nq, B, Kh, G, cq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(pair_step, (m0, l0, a0),
                                  jnp.arange(len(pairs)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]   # (nq, B, K, G, cq, hd)
    out = jnp.transpose(out, (1, 0, 4, 2, 3, 5)).reshape(B, Sq, H, hd)
    return out.astype(q.dtype)


def decode_attention(cfg: ArchConfig, q, k_cache, v_cache, cur_index):
    """Single-token attention against a (possibly seq-sharded) cache.

    q: (B, 1, H, hd); caches: (B, S, K, hd); cur_index: scalar int32 (or
    (B,) vector for per-slot serving) = number of valid cache positions.
    Softmax reductions over S are written explicitly so GSPMD lowers them to
    partial-reduce + small all-reduce when S is sharded (logical "kv_seq").
    """
    B, _, H, hd = q.shape
    S, Kh = k_cache.shape[1], k_cache.shape[2]
    G = H // Kh
    qg = q.reshape(B, Kh, G, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", qg, k_cache).astype(jnp.float32) * (hd ** -0.5)
    pos = jnp.arange(S, dtype=jnp.int32)
    cur = jnp.broadcast_to(jnp.asarray(cur_index), (B,))[:, None, None, None]
    valid = pos[None, None, None, :] < cur
    if cfg.window > 0:
        valid = valid & (pos[None, None, None, :] >= cur - cfg.window)
    s = jnp.where(valid, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)           # all-reduce(max) over kv_seq
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)           # all-reduce(sum)
    out = jnp.einsum("bkgs,bskh->bkgh", (p / l).astype(v_cache.dtype), v_cache)
    return out.reshape(B, 1, H, hd)


# ---------------------------------------------------------------------------
# full blocks
# ---------------------------------------------------------------------------

def attn_apply(cfg: ArchConfig, p, x, positions):
    """Training / prefill forward. x (B,S,d); positions (B,S) or (3,B,S)."""
    B, S, _ = x.shape
    q = linear_apply(cfg, p["wq"], x, out_logical=("batch", None, "heads", None))
    k = linear_apply(cfg, p["wk"], x, out_logical=("batch", None, "kv_heads", None))
    v = linear_apply(cfg, p["wv"], x, out_logical=("batch", None, "kv_heads", None))
    cos, sin = positions_cos_sin(cfg, positions)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    impl = cfg.attn_impl
    if impl == "auto":
        impl = "chunked" if S > 2048 else "naive"
    pos1d = positions[0] if positions.ndim == 3 else positions
    if impl == "chunked":
        out = chunked_attention(cfg, q, k, v, pos1d[0], pos1d[0])
    else:
        bias = mask_bias(cfg, pos1d[0], pos1d[0])
        out = naive_attention(q, k, v, bias)
    out = shard(out, ("batch", None, "heads", None))
    out = out.reshape(B, S, cfg.n_heads * cfg.hd)
    return linear_apply(cfg, p["wo"], out, out_logical=("batch", None, None))


def attn_cache_init(cfg: ArchConfig, batch: int, max_len: int, dtype=None):
    dtype = dtype or _dtype(cfg)
    cache_len = min(max_len, cfg.window) if cfg.window > 0 else max_len
    shape = (batch, cache_len, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def attn_cache_specs(cfg: ArchConfig):
    b, s = _mx("batch")[0], _mx("kv_seq")[0]
    return {"k": P(b, s, None, None), "v": P(b, s, None, None)}


def attn_decode(cfg: ArchConfig, p, x, cache, cur_index):
    """One decode step. x (B,1,d); cur_index scalar or (B,) per-slot vector.
    Returns (y, new_cache)."""
    B = x.shape[0]
    q = linear_apply(cfg, p["wq"], x)
    k = linear_apply(cfg, p["wk"], x)
    v = linear_apply(cfg, p["wv"], x)
    cur = jnp.broadcast_to(jnp.asarray(cur_index, jnp.int32), (B,))
    pos = cur[:, None]
    if cfg.mrope:
        pos = jnp.broadcast_to(pos[None], (3, B, 1))
    cos, sin = positions_cos_sin(cfg, pos)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    S = cache["k"].shape[1]
    write_idx = jnp.mod(cur, S) if cfg.window > 0 else cur
    if jnp.ndim(cur_index) == 0:
        # scalar path: dynamic_update_slice keeps decode cells scatter-free
        k_cache = jax.lax.dynamic_update_slice(cache["k"], k, (0, write_idx[0], 0, 0))
        v_cache = jax.lax.dynamic_update_slice(cache["v"], v, (0, write_idx[0], 0, 0))
    else:
        bidx = jnp.arange(B)
        k_cache = cache["k"].at[bidx, write_idx].set(k[:, 0])
        v_cache = cache["v"].at[bidx, write_idx].set(v[:, 0])
    k_cache = shard(k_cache, ("batch", "kv_seq", None, None))
    v_cache = shard(v_cache, ("batch", "kv_seq", None, None))

    if cfg.window > 0:
        # ring buffer: every slot valid once cur_index >= S
        n_valid = jnp.minimum(cur + 1, S)[:, None, None, None]
        out = _decode_ring(cfg, q, k_cache, v_cache, n_valid)
    else:
        out = decode_attention(cfg, q, k_cache, v_cache, cur + 1)
    out = out.reshape(B, 1, cfg.n_heads * cfg.hd)
    y = linear_apply(cfg, p["wo"], out, out_logical=("batch", None, None))
    return y, {"k": k_cache, "v": v_cache}


def _decode_ring(cfg, q, k_cache, v_cache, n_valid):
    """Window decode against a ring buffer: all slots < n_valid (broadcast
    (B,1,1,1)) are valid and already within the window by construction."""
    B, _, H, hd = q.shape
    S, Kh = k_cache.shape[1], k_cache.shape[2]
    G = H // Kh
    qg = q.reshape(B, Kh, G, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", qg, k_cache).astype(jnp.float32) * (hd ** -0.5)
    pos = jnp.arange(S, dtype=jnp.int32)
    s = jnp.where(pos[None, None, None, :] < n_valid, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bkgs,bskh->bkgh", (p / l).astype(v_cache.dtype), v_cache)
    return out.reshape(B, 1, H, hd)
