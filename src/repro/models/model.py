"""Top-level model: init / forward / loss / prefill / decode for any ArchConfig.

Entry points mirror the three lowering targets of the dry-run:
  * ``train_logits`` / ``loss``      -> train_step
  * ``prefill``                       -> prefill_32k cells
  * ``decode_step``                   -> decode_32k / long_500k cells

Input conventions (see launch/dryrun.input_specs):
  * text archs:   tokens (B, S) int32
  * vlm / audio:  embeds (B, S, d_model) (frontend stub) + labels;
                  vlm additionally takes positions (3, B, S) for M-RoPE.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import transformer as tfm
from repro.models.layers import (
    _dtype,
    _mx,
    embed_apply,
    embed_init,
    embed_specs,
    norm_apply,
    norm_init,
    norm_specs,
    quantize_linear_params,
)
from repro.parallel.sharding import shard


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig

    # -- params --------------------------------------------------------
    def init(self, key) -> Dict[str, Any]:
        k_embed, k_stack, k_head = jax.random.split(key, 3)
        p: Dict[str, Any] = {"blocks": tfm.stack_init(k_stack, self.cfg),
                             "final_norm": norm_init(self.cfg)}
        if self.cfg.embed_inputs:
            p["embed"] = embed_init(k_embed, self.cfg)
        if not self.cfg.tie_embeddings:
            head = (jax.random.normal(k_head, (self.cfg.d_model, self.cfg.vocab),
                                      jnp.float32) * self.cfg.d_model ** -0.5)
            p["head"] = {"w": head.astype(_dtype(self.cfg))}
        return p

    def param_specs(self) -> Dict[str, Any]:
        p: Dict[str, Any] = {"blocks": tfm.stack_specs(self.cfg),
                             "final_norm": norm_specs(self.cfg)}
        if self.cfg.embed_inputs:
            p["embed"] = embed_specs(self.cfg)
        if not self.cfg.tie_embeddings:
            p["head"] = {"w": P(_mx("fsdp")[0], _mx("vocab")[0])}
        return p

    # -- forward ---------------------------------------------------------
    def _inputs_to_h(self, params, batch):
        cfg = self.cfg
        if cfg.embed_inputs:
            h = embed_apply(cfg, params["embed"], batch["tokens"])
            B, S = batch["tokens"].shape
        else:
            h = shard(batch["embeds"].astype(_dtype(cfg)), ("batch", None, None))
            B, S = h.shape[:2]
        if "positions" in batch:
            positions = batch["positions"]
        else:
            positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        return h, positions

    def _head(self, params, h):
        cfg = self.cfg
        h = norm_apply(cfg, params["final_norm"], h)
        if not cfg.tie_embeddings and "w_int" in params["head"]:
            from repro.models.layers import linear_apply

            return linear_apply(cfg, params["head"], h,
                                out_logical=("batch", None, "vocab")).astype(jnp.float32)
        w = params["embed"]["table"].T if cfg.tie_embeddings else params["head"]["w"]
        logits = jax.lax.dot_general(h, w, (((h.ndim - 1,), (0,)), ((), ())))
        return shard(logits.astype(jnp.float32), ("batch", None, "vocab"))

    def train_logits(self, params, batch) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Returns (logits (B,S,V) f32, aux_loss)."""
        h, positions = self._inputs_to_h(params, batch)
        h, aux = tfm.stack_apply(self.cfg, params["blocks"], h, positions)
        return self._head(params, h), aux

    def loss(self, params, batch) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
        logits, aux = self.train_logits(params, batch)
        labels = batch["labels"]
        V = self.cfg.vocab
        lse = jax.nn.logsumexp(logits, axis=-1)
        iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
        lab = jnp.sum(jnp.where(iota == labels[..., None], logits, 0.0), axis=-1)
        mask = (labels >= 0).astype(jnp.float32)
        ce = jnp.sum((lse - lab) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        # z-loss keeps the softmax normalizer bounded (MaxText-style)
        zl = 1e-4 * jnp.sum(jnp.square(lse) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        total = ce + zl + 1e-2 * aux
        return total, {"ce": ce, "z_loss": zl, "aux": aux}

    # -- inference ---------------------------------------------------------
    def prefill(self, params, batch) -> Tuple[jnp.ndarray, Any]:
        """Full-sequence forward; returns (logits, caches filled up to S).

        Caches are rebuilt from the per-layer K/V by a second pass would be
        wasteful; instead attention runs normally and we return logits only —
        serving uses ``prefill_with_cache`` for small models; the dry-run
        lowers this full forward (the compute-dominant part of prefill).
        """
        h, positions = self._inputs_to_h(params, batch)
        h, _ = tfm.stack_apply(self.cfg, params["blocks"], h, positions)
        return self._head(params, h)

    def cache_init(self, batch: int, max_len: int, dtype=None):
        cfg = self.cfg
        one = lambda: tfm.block_cache_init(cfg, batch, max_len, dtype)  # noqa: E731
        if cfg.scan_layers:
            caches = jax.tree.map(
                lambda *xs: jnp.stack(xs), *[one() for _ in range(cfg.n_groups)]
            ) if cfg.n_groups > 1 else jax.tree.map(lambda x: x[None], one())
            return caches
        return [one() for _ in range(cfg.n_groups)]

    def cache_specs(self):
        cfg = self.cfg
        one = tfm.block_cache_specs(cfg)
        if cfg.scan_layers:
            return jax.tree.map(lambda s: P(None, *s), one,
                                is_leaf=lambda x: isinstance(x, P))
        return [one for _ in range(cfg.n_groups)]

    def decode_step(self, params, caches, tokens_or_embeds, cur_index):
        """One token for every sequence in the batch.

        tokens (B, 1) int32 or embeds (B, 1, d). Returns (logits (B, 1, V),
        new_caches).
        """
        cfg = self.cfg
        if cfg.embed_inputs:
            h = embed_apply(cfg, params["embed"], tokens_or_embeds)
        else:
            h = shard(tokens_or_embeds.astype(_dtype(cfg)), ("batch", None, None))
        h, new_caches = tfm.stack_decode(cfg, params["blocks"], caches, h, cur_index)
        return self._head(params, h), new_caches

    # -- deployment quantization (paper C1/C2 applied to the LM) -----------
    def quantize_params(self, params, bits: int = 8):
        """Convert every linear weight to int codes + scales (serve path).

        Block params carry a leading stacked-groups axis when scan_layers is
        on; quantization is vmapped over it so scales stay per-(layer, out-
        channel). Norms, embeddings, and MoE expert tensors (bare arrays)
        stay in bf16 — see DESIGN.md §Arch-applicability.
        """

        def qlin(p, stacked: bool):
            fn = lambda q: quantize_linear_params(q, bits)  # noqa: E731
            if stacked:
                return jax.vmap(fn)({k: v for k, v in p.items()})
            return fn(p)

        def visit(p, stacked):
            if isinstance(p, dict) and "w" in p and getattr(p["w"], "ndim", 0) >= (
                3 if stacked else 2
            ):
                return qlin(p, stacked)
            if isinstance(p, dict):
                return {
                    k: (v if k == "router" else visit(v, stacked))
                    for k, v in p.items()
                }
            return p

        out = {}
        for k, v in params.items():
            if k == "blocks":
                out[k] = visit(v, self.cfg.scan_layers)
            elif k == "head":
                out[k] = visit(v, False)
            else:
                out[k] = v
        return out
