"""Block assembly + scan-over-layers for every assigned architecture family.

A config's layers are grouped into ``n_groups`` identical *blocks* of
``block_period`` sublayers (dense: 1; MoE-every-2: 2; jamba: 8 = 7 mamba +
1 attention with alternating dense/MoE FFN). Blocks are homogeneous, so the
whole stack is one ``lax.scan`` over stacked block params — constant HLO size
in depth, which is what keeps 64-layer 314B-param dry-runs compilable.

Remat policy per block is a config lever (cfg.remat: full | dots | none) and
one of the §Perf hillclimbing knobs.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import ssm
from repro.models.layers import (
    mlp_apply,
    mlp_init,
    mlp_specs,
    moe_apply,
    moe_init,
    moe_specs,
    norm_apply,
    norm_init,
    norm_specs,
)


# ---------------------------------------------------------------------------
# one block (= block_period sublayers)
# ---------------------------------------------------------------------------

def block_init(key, cfg: ArchConfig) -> Dict[str, Any]:
    p: Dict[str, Any] = {}
    keys = jax.random.split(key, cfg.block_period * 4)
    for i in range(cfg.block_period):
        k_mix, k_ff = keys[4 * i], keys[4 * i + 1]
        sub: Dict[str, Any] = {"norm1": norm_init(cfg)}
        if cfg.layer_kind(i) == "attn":
            sub["attn"] = attn.attn_init(k_mix, cfg)
        else:
            sub["ssm"] = ssm.ssm_init(k_mix, cfg)
        if cfg.d_ff > 0:
            sub["norm2"] = norm_init(cfg)
            if cfg.layer_is_moe(i):
                sub["moe"] = moe_init(k_ff, cfg)
            else:
                sub["mlp"] = mlp_init(k_ff, cfg)
        p[f"sub{i}"] = sub
    return p


def block_specs(cfg: ArchConfig) -> Dict[str, Any]:
    p: Dict[str, Any] = {}
    for i in range(cfg.block_period):
        sub: Dict[str, Any] = {"norm1": norm_specs(cfg)}
        if cfg.layer_kind(i) == "attn":
            sub["attn"] = attn.attn_specs(cfg)
        else:
            sub["ssm"] = ssm.ssm_specs(cfg)
        if cfg.d_ff > 0:
            sub["norm2"] = norm_specs(cfg)
            if cfg.layer_is_moe(i):
                sub["moe"] = moe_specs(cfg)
            else:
                sub["mlp"] = mlp_specs(cfg)
        p[f"sub{i}"] = sub
    return p


def block_apply(cfg: ArchConfig, p, x, positions):
    """Forward through one block. Returns (x, aux_loss)."""
    from repro.parallel.sharding import shard

    # residual-stream constraint: logical "seq" is None in the baseline
    # rules (replicated) and "model" under sequence parallelism — flipping
    # that one rule re-shards every inter-layer activation (a §Perf lever).
    x = shard(x, ("batch", "seq", None))
    aux = jnp.zeros((), jnp.float32)
    for i in range(cfg.block_period):
        sub = p[f"sub{i}"]
        h = norm_apply(cfg, sub["norm1"], x)
        if cfg.layer_kind(i) == "attn":
            mixed = attn.attn_apply(cfg, sub["attn"], h, positions)
        else:
            mixed = ssm.ssm_apply(cfg, sub["ssm"], h)
        x = x + mixed
        if cfg.d_ff > 0:
            h = norm_apply(cfg, sub["norm2"], x)
            if cfg.layer_is_moe(i):
                y, a = moe_apply(cfg, sub["moe"], h)
                aux = aux + a
            else:
                y = mlp_apply(cfg, sub["mlp"], h)
            x = x + y
    return x, aux


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def block_cache_init(cfg: ArchConfig, batch: int, max_len: int, dtype=None):
    c: Dict[str, Any] = {}
    for i in range(cfg.block_period):
        if cfg.layer_kind(i) == "attn":
            c[f"sub{i}"] = attn.attn_cache_init(cfg, batch, max_len, dtype)
        else:
            c[f"sub{i}"] = ssm.ssm_cache_init(cfg, batch, dtype)
    return c


def block_cache_specs(cfg: ArchConfig):
    c: Dict[str, Any] = {}
    for i in range(cfg.block_period):
        if cfg.layer_kind(i) == "attn":
            c[f"sub{i}"] = attn.attn_cache_specs(cfg)
        else:
            c[f"sub{i}"] = ssm.ssm_cache_specs(cfg)
    return c


def block_decode(cfg: ArchConfig, p, x, cache, cur_index):
    new_cache: Dict[str, Any] = {}
    for i in range(cfg.block_period):
        sub = p[f"sub{i}"]
        h = norm_apply(cfg, sub["norm1"], x)
        if cfg.layer_kind(i) == "attn":
            mixed, new_cache[f"sub{i}"] = attn.attn_decode(
                cfg, sub["attn"], h, cache[f"sub{i}"], cur_index
            )
        else:
            mixed, new_cache[f"sub{i}"] = ssm.ssm_decode(
                cfg, sub["ssm"], h, cache[f"sub{i}"]
            )
        x = x + mixed
        if cfg.d_ff > 0:
            h = norm_apply(cfg, sub["norm2"], x)
            if cfg.layer_is_moe(i):
                y, _ = moe_apply(cfg, sub["moe"], h)
            else:
                y = mlp_apply(cfg, sub["mlp"], h)
            x = x + y
    return x, new_cache


# ---------------------------------------------------------------------------
# stack (scan over groups)
# ---------------------------------------------------------------------------

def stack_init(key, cfg: ArchConfig):
    keys = jax.random.split(key, cfg.n_groups)
    if cfg.scan_layers:
        return jax.vmap(lambda k: block_init(k, cfg))(keys)
    return [block_init(k, cfg) for k in keys]


def stack_specs(cfg: ArchConfig):
    one = block_specs(cfg)
    if not cfg.scan_layers:
        return [one for _ in range(cfg.n_groups)]
    # prepend the stacked "layers" axis (replicated) to every leaf spec
    def add_axis(spec: P) -> P:
        return P(None, *spec)

    return jax.tree.map(add_axis, one, is_leaf=lambda x: isinstance(x, P))


def _remat(cfg: ArchConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=policy)
    if cfg.remat == "dots_saveable":
        # save every matmul output, recompute elementwise/norm/softmax only —
        # usually the transformer sweet spot between 'full' and 'none'
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.dots_saveable)
    return jax.checkpoint(fn)


def stack_apply(cfg: ArchConfig, stacked, x, positions):
    """Forward through all groups. Returns (x, aux_loss)."""
    if not cfg.scan_layers:
        aux = jnp.zeros((), jnp.float32)
        for p in stacked:
            x, a = _remat(cfg, functools.partial(block_apply, cfg))(p, x, positions)
            aux = aux + a
        return x, aux

    def body(carry, p):
        x, aux = carry
        x, a = block_apply(cfg, p, x, positions)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(_remat(cfg, body), (x, jnp.zeros((), jnp.float32)),
                               stacked)
    return x, aux


def stack_decode(cfg: ArchConfig, stacked, caches, x, cur_index):
    """Decode step through all groups. Returns (x, new_caches)."""
    if not cfg.scan_layers:
        new = []
        for p, c in zip(stacked, caches):
            x, nc = block_decode(cfg, p, x, c, cur_index)
            new.append(nc)
        return x, new

    def body(x, pc):
        p, c = pc
        x, nc = block_decode(cfg, p, x, c, cur_index)
        return x, nc

    x, new_caches = jax.lax.scan(body, x, (stacked, caches))
    return x, new_caches
