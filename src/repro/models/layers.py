"""Shared LM building blocks: norms, embeddings, (quantized) linears, MLP, MoE.

Everything is an explicit init/apply pair over plain-dict pytrees. Each init
has a sibling ``*_specs`` returning the same-structured PartitionSpec tree
(logical axes, resolved by parallel/sharding.py), which is what the dry-run
uses for in_shardings.

Quantization: the paper's technique is a first-class feature here.
  * ``weight_bits >= 16``  -> bf16 baseline.
  * QAT (training)         -> fake-quant on weights via core.quantizers (STE).
  * serve path             -> real int8/int4 codes + per-channel scales
                              (``quantize_params``), executed with an int8
                              dot_general (MXU-native) — the TPU analogue of
                              the paper's "narrowest width the hardware
                              multiplies natively".
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core.quantizers import IntQuantizer
from repro.parallel.sharding import batch_axes, model_axes, shard

try:  # jax >= 0.6
    from jax import shard_map as _shard_map

    def shard_map(f, mesh, in_specs, out_specs):
        return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                          check_vma=False)
except (ImportError, TypeError):  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map_legacy

    def shard_map(f, mesh, in_specs, out_specs):
        return _shard_map_legacy(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=False)


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def norm_init(cfg: ArchConfig, d: Optional[int] = None):
    d = d or cfg.d_model
    if cfg.norm == "ln":
        return {"scale": jnp.ones((d,), _dtype(cfg)), "bias": jnp.zeros((d,), _dtype(cfg))}
    return {"scale": jnp.ones((d,), _dtype(cfg))}


def norm_specs(cfg: ArchConfig):
    if cfg.norm == "ln":
        return {"scale": P(), "bias": P()}
    return {"scale": P()}


def norm_apply(cfg: ArchConfig, p, x):
    xf = x.astype(jnp.float32)
    if cfg.norm == "ln":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        return (y * p["scale"].astype(jnp.float32)
                + p["bias"].astype(jnp.float32)).astype(x.dtype)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(ms + cfg.norm_eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# (quantized) linear
# ---------------------------------------------------------------------------

def linear_init(key, in_dim, out_shape, cfg: ArchConfig, bias: bool = False,
                scale: Optional[float] = None):
    """Weight (in_dim, *out_shape); trunc-normal init (1/sqrt(fan_in))."""
    out_shape = out_shape if isinstance(out_shape, tuple) else (out_shape,)
    std = scale if scale is not None else in_dim ** -0.5
    w = (jax.random.truncated_normal(key, -2.0, 2.0, (in_dim, *out_shape), jnp.float32)
         * std).astype(_dtype(cfg))
    p = {"w": w}
    if bias:
        p["b"] = jnp.zeros(out_shape, _dtype(cfg))
    return p


def linear_apply(cfg: ArchConfig, p, x, out_logical=None, fake_quant=True):
    """y = x @ w (+b). Handles bf16 baseline, QAT fake-quant, and int8 serve.

    The int8 serve path (p holds {"w_int", "w_scale"}) runs the MXU-native
    int8 x int8 -> int32 dot, then one fused rescale — the streamlined
    deployment form of the paper applied to LM matmuls.
    """
    if "w_int" in p:
        w_int, w_scale = p["w_int"], p["w_scale"]
        aq = IntQuantizer(bits=8, signed=True)
        x_int, s_x = aq.quantize_int(x.astype(jnp.float32))
        k = x.shape[-1]
        acc = jax.lax.dot_general(
            x_int, w_int,
            (((x_int.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )
        y = acc.astype(jnp.float32) * (s_x * w_scale)
        y = y.astype(_dtype(cfg))
    else:
        w = p["w"]
        if fake_quant and cfg.weight_bits < 16:
            wq = IntQuantizer(bits=cfg.weight_bits, signed=True, narrow=True)
            w = wq(w.astype(jnp.float32)).astype(w.dtype)
        y = jax.lax.dot_general(
            x, w, (((x.ndim - 1,), (0,)), ((), ())),
        )
    if "b" in p:
        y = y + p["b"]
    if out_logical is not None:
        y = shard(y, out_logical)
    return y


def quantize_linear_params(p, bits: int = 8):
    """Convert a float linear param dict to the int serve form (per-out-channel
    scales over the fan-in axis)."""
    w = jnp.asarray(p["w"], jnp.float32)
    q = IntQuantizer(bits=bits, signed=True, narrow=True, axis=0)
    flat = w.reshape(w.shape[0], -1)
    w_int, s = q.quantize_int(flat)
    out = {
        "w_int": w_int.reshape(w.shape).astype(jnp.int8),
        "w_scale": s.reshape((1,) * (w.ndim - len(s.shape) + 1) + s.shape[1:]).reshape(
            (1,) + w.shape[1:]).astype(jnp.float32),
    }
    if "b" in p:
        out["b"] = p["b"]
    return out


# ---------------------------------------------------------------------------
# embeddings
# ---------------------------------------------------------------------------

def embed_init(key, cfg: ArchConfig):
    e = (jax.random.normal(key, (cfg.vocab, cfg.d_model), jnp.float32)
         * cfg.d_model ** -0.5).astype(_dtype(cfg))
    return {"table": e}


def embed_specs(cfg: ArchConfig):
    return {"table": P(*_mx("vocab"), *_mx("fsdp"))}


def _mx(logical):
    """logical axis -> 1-tuple of (mesh axes or None) for P construction."""
    from repro.parallel.sharding import active_rules

    r = active_rules().get(logical)
    if r is None:
        return (None,)
    return (r if not (isinstance(r, tuple) and len(r) == 1) else r[0],)


def embed_apply(cfg: ArchConfig, p, tokens):
    x = jnp.take(p["table"], tokens, axis=0)
    return shard(x.astype(_dtype(cfg)), ("batch", None, None))


def head_apply(cfg: ArchConfig, p, x):
    logits = jax.lax.dot_general(
        x, p["table"].T if "table" in p else p["w"],
        (((x.ndim - 1,), (0,)), ((), ())),
    )
    return shard(logits.astype(jnp.float32), ("batch", None, "vocab"))


# ---------------------------------------------------------------------------
# dense MLP (SwiGLU)
# ---------------------------------------------------------------------------

def mlp_init(key, cfg: ArchConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi_gate": linear_init(k1, cfg.d_model, cfg.d_ff, cfg),
        "wi_up": linear_init(k2, cfg.d_model, cfg.d_ff, cfg),
        "wo": linear_init(k3, cfg.d_ff, cfg.d_model, cfg,
                          scale=(2 * cfg.n_layers * cfg.d_ff) ** -0.5),
    }


def mlp_specs(cfg: ArchConfig):
    in_spec = P(*_mx("fsdp"), *_mx("mlp"))
    out_spec = P(*_mx("mlp"), *_mx("fsdp"))
    return {"wi_gate": {"w": in_spec}, "wi_up": {"w": in_spec}, "wo": {"w": out_spec}}


def mlp_apply(cfg: ArchConfig, p, x):
    g = linear_apply(cfg, p["wi_gate"], x, out_logical=("batch", None, "mlp"))
    u = linear_apply(cfg, p["wi_up"], x, out_logical=("batch", None, "mlp"))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    y = linear_apply(cfg, p["wo"], h, out_logical=("batch", None, None))
    return y


# ---------------------------------------------------------------------------
# MoE (top-k routing, shard_map expert compute: DP tokens x TP expert d_ff)
# ---------------------------------------------------------------------------

def moe_init(key, cfg: ArchConfig):
    E = cfg.moe_experts
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    dt = _dtype(cfg)
    std_in = cfg.d_model ** -0.5
    std_out = (2 * cfg.n_layers * cfg.d_ff) ** -0.5
    p = {
        "router": {"w": (jax.random.normal(k1, (cfg.d_model, E), jnp.float32)
                         * std_in).astype(jnp.float32)},
        "wi_gate": (jax.random.normal(k2, (E, cfg.d_model, cfg.d_ff), jnp.float32)
                    * std_in).astype(dt),
        "wi_up": (jax.random.normal(k3, (E, cfg.d_model, cfg.d_ff), jnp.float32)
                  * std_in).astype(dt),
        "wo": (jax.random.normal(k4, (E, cfg.d_ff, cfg.d_model), jnp.float32)
               * std_out).astype(dt),
    }
    if cfg.moe_shared_expert:
        p["shared"] = mlp_init(k5, cfg)
    return p


def moe_specs(cfg: ArchConfig):
    ein = P(*_mx("experts"), *_mx("fsdp"), *_mx("mlp"))
    eout = P(*_mx("experts"), *_mx("mlp"), *_mx("fsdp"))
    p = {"router": {"w": P()}, "wi_gate": ein, "wi_up": ein, "wo": eout}
    if cfg.moe_shared_expert:
        p["shared"] = mlp_specs(cfg)
    return p


def _expert_ffn(x_ecd, wg, wu, wo):
    """x: (E, C, d); weights (E, d, f) / (E, f, d) -> (E, C, d)."""
    g = jnp.einsum("ecd,edf->ecf", x_ecd, wg)
    u = jnp.einsum("ecd,edf->ecf", x_ecd, wu)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x_ecd.dtype) * u
    return jnp.einsum("ecf,efd->ecd", h, wo)


def _moe_local(x, idx, weights, wg, wu, wo, *, E, k, capacity_factor, axis_names):
    """Per-data-shard dispatch -> TP expert FFN -> combine.

    Runs inside shard_map: x (Bl, S, d) local tokens; weights on 'model' axis
    hold a d_ff slice (f/M). FSDP gathering over 'data' happens in the caller
    (backward of all_gather = reduce_scatter = correct FSDP grads).
    """
    Bl, S, d = x.shape
    T = Bl * S
    xf = x.reshape(T, d)
    flat_e = idx.reshape(-1)                      # (T*k,)
    flat_w = weights.reshape(-1)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
    pos = jnp.sum(jnp.cumsum(onehot, axis=0) * onehot, axis=-1) - 1  # pos in expert
    C = max(int(T * k * capacity_factor / E), 1)
    keep = pos < C
    slot = flat_e * C + jnp.clip(pos, 0, C - 1)
    slot = jnp.where(keep, slot, E * C)           # dropped -> OOB row
    x_rep = jnp.repeat(xf, k, axis=0)             # token t repeated k times
    buf = jnp.zeros((E * C + 1, d), x.dtype).at[slot].add(x_rep)
    buf = buf[: E * C].reshape(E, C, d)
    h = _expert_ffn(buf, wg, wu, wo)              # (E, C, d) partial over f-slice
    # combine BEFORE the TP reduce: gather+weight+sum are linear in h, so the
    # psum moves to the (T, d) token buffer instead of the (E, C, d) capacity
    # buffer — k*capacity_factor x fewer collective bytes (§Perf, confirmed)
    flat_h = jnp.concatenate([h.reshape(E * C, d), jnp.zeros((1, d), h.dtype)], 0)
    y = flat_h[slot] * (flat_w * keep.astype(jnp.float32))[:, None].astype(h.dtype)
    y = y.reshape(T, k, d).sum(axis=1)
    if axis_names:
        y = jax.lax.psum(y, axis_names)           # TP reduce over 'model'
    return y.reshape(Bl, S, d)


def moe_apply(cfg: ArchConfig, p, x):
    """Returns (y, aux_loss)."""
    from repro.parallel.sharding import active_mesh

    E, k = cfg.moe_experts, cfg.moe_top_k
    logits = (x.astype(jnp.float32) @ p["router"]["w"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                 # (B, S, E)
    weights, idx = jax.lax.top_k(probs, k)                  # (B, S, k)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)

    # Switch-style load-balance aux loss
    me = jnp.mean(probs, axis=(0, 1))                        # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(idx, E, dtype=jnp.float32), axis=2), axis=(0, 1)
    ) / k
    aux = E * jnp.sum(me * ce)

    mesh = active_mesh()
    if mesh is None:
        y = _moe_local(x, idx, weights, p["wi_gate"], p["wi_up"], p["wo"],
                       E=E, k=k, capacity_factor=cfg.capacity_factor, axis_names=())
    else:
        bspec = P(batch_axes() or None, None, None)
        m_ax = model_axes()
        fsdp = _mx("fsdp")[0]
        ein = P(None, fsdp, m_ax or None)
        eout = P(None, m_ax or None, fsdp)

        def local_fn(xl, il, wl, wg, wu, wo):
            if fsdp is not None:  # FSDP all-gather (bwd: reduce-scatter)
                wg = jax.lax.all_gather(wg, fsdp, axis=1, tiled=True)
                wu = jax.lax.all_gather(wu, fsdp, axis=1, tiled=True)
                wo = jax.lax.all_gather(wo, fsdp, axis=2, tiled=True)
            return _moe_local(xl, il, wl, wg, wu, wo, E=E, k=k,
                              capacity_factor=cfg.capacity_factor,
                              axis_names=m_ax)

        y = shard_map(
            local_fn, mesh,
            in_specs=(bspec, bspec, bspec, ein, ein, eout),
            out_specs=bspec,
        )(x, idx, weights, p["wi_gate"], p["wi_up"], p["wo"])

    if cfg.moe_shared_expert:
        y = y + mlp_apply(cfg, p["shared"], x)
    return y, aux
