"""Mamba-1 selective-state-space block with a TPU-friendly chunked scan.

Hardware adaptation (DESIGN.md §2): GPU Mamba uses a fused CUDA selective-scan
kernel that keeps h in registers. On TPU the analogous structure is a
*chunked* scan: ``lax.scan`` over sequence chunks (sequential, O(T/c) steps)
with an ``associative_scan`` inside each chunk (parallel, log(c) depth,
VPU-friendly elementwise ops). This bounds the materialized state tensor to
(B, c, d_inner, n_state) per chunk instead of the full (B, T, ...) — the same
working-set discipline as the FPGA dataflow keeping activations on-chip.

``selective_scan_ref`` is the naive per-step oracle used by tests.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models.layers import _dtype, _mx, linear_apply, linear_init
from repro.parallel.sharding import shard


# ---------------------------------------------------------------------------
# selective scan
# ---------------------------------------------------------------------------

def selective_scan_ref(x, dt, B_t, C_t, A, D, h0=None):
    """Naive sequential oracle.

    x, dt: (B, T, di); B_t, C_t: (B, T, st); A: (di, st); D: (di,).
    Returns (y (B, T, di), h_last (B, di, st)).
    """
    Bsz, T, di = x.shape
    st = A.shape[1]
    h = jnp.zeros((Bsz, di, st), jnp.float32) if h0 is None else h0

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp
        da = jnp.exp(dt_t[..., None] * A)                     # (B, di, st)
        db = (dt_t * x_t)[..., None] * b_t[:, None, :]        # (B, di, st)
        h = da * h + db
        y = jnp.einsum("bds,bs->bd", h, c_t)
        return h, y

    xs = (
        jnp.moveaxis(x.astype(jnp.float32), 1, 0),
        jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
        jnp.moveaxis(B_t.astype(jnp.float32), 1, 0),
        jnp.moveaxis(C_t.astype(jnp.float32), 1, 0),
    )
    h, ys = jax.lax.scan(step, h, xs)
    y = jnp.moveaxis(ys, 0, 1) + x.astype(jnp.float32) * D
    return y.astype(x.dtype), h


def _assoc_combine(e1, e2):
    a1, b1 = e1
    a2, b2 = e2
    return a1 * a2, a2 * b1 + b2


def selective_scan_chunked(x, dt, B_t, C_t, A, D, chunk: int, h0=None):
    """Chunked selective scan: lax.scan over chunks, associative scan inside.

    Same signature/semantics as selective_scan_ref.
    """
    Bsz, T, di = x.shape
    st = A.shape[1]
    chunk = min(chunk, T)
    assert T % chunk == 0, f"seq {T} not divisible by ssm chunk {chunk}"
    nc = T // chunk

    xf = x.astype(jnp.float32)
    a = jnp.exp(dt.astype(jnp.float32)[..., None] * A)                  # (B,T,di,st)
    b = (dt.astype(jnp.float32) * xf)[..., None] * B_t.astype(jnp.float32)[:, :, None, :]
    a = jnp.moveaxis(a.reshape(Bsz, nc, chunk, di, st), 1, 0)           # (nc,B,c,di,st)
    b = jnp.moveaxis(b.reshape(Bsz, nc, chunk, di, st), 1, 0)
    c = jnp.moveaxis(
        C_t.astype(jnp.float32).reshape(Bsz, nc, chunk, st), 1, 0
    )                                                                    # (nc,B,c,st)

    h_init = jnp.zeros((Bsz, di, st), jnp.float32) if h0 is None else h0

    def chunk_step(h, inp):
        a_c, b_c, c_c = inp                                  # (B,c,di,st), (B,c,st)
        b_c = b_c.at[:, 0].add(a_c[:, 0] * h)
        _, h_all = jax.lax.associative_scan(_assoc_combine, (a_c, b_c), axis=1)
        y_c = jnp.einsum("bcds,bcs->bcd", h_all, c_c)
        return h_all[:, -1], y_c

    h_last, ys = jax.lax.scan(chunk_step, h_init, (a, b, c))
    y = jnp.moveaxis(ys, 0, 1).reshape(Bsz, T, di) + xf * D
    return y.astype(x.dtype), h_last


def selective_scan_step(x_t, dt_t, b_t, c_t, A, D, h):
    """Single decode step. x_t/dt_t (B, di); b_t/c_t (B, st); h (B, di, st)."""
    da = jnp.exp(dt_t.astype(jnp.float32)[..., None] * A)
    db = (dt_t * x_t).astype(jnp.float32)[..., None] * b_t.astype(jnp.float32)[:, None, :]
    h = da * h + db
    y = jnp.einsum("bds,bs->bd", h, c_t.astype(jnp.float32)) + x_t.astype(jnp.float32) * D
    return y.astype(x_t.dtype), h


# ---------------------------------------------------------------------------
# depthwise causal conv (K small: explicit shift-and-add)
# ---------------------------------------------------------------------------

def causal_conv1d(x, w, b, state=None):
    """x (B, T, di), w (K, di), b (di,). state (B, K-1, di) holds the last
    K-1 inputs of the previous segment (decode). Returns (y, new_state)."""
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)                  # (B, T+K-1, di)
    y = sum(xp[:, j: j + x.shape[1]] * w[j] for j in range(K))
    new_state = xp[:, -(K - 1):] if K > 1 else state
    return y + b, new_state


# ---------------------------------------------------------------------------
# mamba block
# ---------------------------------------------------------------------------

def ssm_init(key, cfg: ArchConfig):
    d, di, st, dtr, K = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank, cfg.ssm_conv
    keys = jax.random.split(key, 6)
    dt_std = dtr ** -0.5
    # dt bias: inverse-softplus of uniform[1e-3, 1e-1] (mamba init)
    u = jax.random.uniform(keys[4], (di,), jnp.float32, 1e-3, 1e-1)
    dt_bias = jnp.log(jnp.expm1(u))
    return {
        "in_proj": linear_init(keys[0], d, 2 * di, cfg),
        "conv_w": (jax.random.normal(keys[1], (K, di), jnp.float32) * K ** -0.5
                   ).astype(_dtype(cfg)),
        "conv_b": jnp.zeros((di,), _dtype(cfg)),
        "x_proj": linear_init(keys[2], di, dtr + 2 * st, cfg),
        "dt_proj": {"w": (jax.random.normal(keys[3], (dtr, di), jnp.float32)
                          * dt_std).astype(_dtype(cfg)),
                    "b": dt_bias.astype(jnp.float32)},
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, st + 1, dtype=jnp.float32)[None, :], (di, st))).copy(),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": linear_init(keys[5], di, d, cfg,
                                scale=(2 * cfg.n_layers * di) ** -0.5),
    }


def ssm_specs(cfg: ArchConfig):
    fsdp, m = _mx("fsdp")[0], _mx("model")[0]
    return {
        "in_proj": {"w": P(fsdp, m)},
        "conv_w": P(None, m),
        "conv_b": P(m),
        "x_proj": {"w": P(m, fsdp)},
        "dt_proj": {"w": P(fsdp, m), "b": P(m)},
        "A_log": P(m, None),
        "D": P(m),
        "out_proj": {"w": P(m, fsdp)},
    }


def ssm_apply(cfg: ArchConfig, p, x):
    """Training / prefill forward. x (B, T, d) -> (B, T, d)."""
    B, T, _ = x.shape
    di, st, dtr = cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    xz = linear_apply(cfg, p["in_proj"], x, out_logical=("batch", None, "model"))
    xs, z = jnp.split(xz, 2, axis=-1)
    xs, _ = causal_conv1d(xs, p["conv_w"], p["conv_b"])
    xs = jax.nn.silu(xs.astype(jnp.float32)).astype(x.dtype)
    xs = shard(xs, ("batch", None, "model"))

    dbc = linear_apply(cfg, p["x_proj"], xs)
    dt_r, B_t, C_t = jnp.split(dbc, [dtr, dtr + st], axis=-1)
    # dt_proj through linear_apply so the int8 serve path (w_int) works too
    dt_lin = linear_apply(cfg, {k: v for k, v in p["dt_proj"].items()
                                if k != "b"}, dt_r)
    dt = jax.nn.softplus(dt_lin.astype(jnp.float32) + p["dt_proj"]["b"])
    A = -jnp.exp(p["A_log"])
    y, _ = selective_scan_chunked(xs, dt, B_t, C_t, A, p["D"], cfg.ssm_chunk)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    return linear_apply(cfg, p["out_proj"], y, out_logical=("batch", None, None))


def ssm_cache_init(cfg: ArchConfig, batch: int, dtype=None):
    dtype = dtype or _dtype(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner), dtype),
        "h": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
    }


def ssm_cache_specs(cfg: ArchConfig):
    b, m = _mx("batch")[0], _mx("model")[0]
    return {"conv": P(b, None, m), "h": P(b, m, None)}


def ssm_decode(cfg: ArchConfig, p, x, cache):
    """One decode step. x (B, 1, d) -> (y (B, 1, d), new_cache)."""
    B = x.shape[0]
    di, st, dtr = cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    xz = linear_apply(cfg, p["in_proj"], x)
    xs, z = jnp.split(xz, 2, axis=-1)
    xs, conv_state = causal_conv1d(xs, p["conv_w"], p["conv_b"], cache["conv"])
    xs = jax.nn.silu(xs.astype(jnp.float32)).astype(x.dtype)

    dbc = linear_apply(cfg, p["x_proj"], xs)
    dt_r, B_t, C_t = jnp.split(dbc, [dtr, dtr + st], axis=-1)
    dt_lin = linear_apply(cfg, {k: v for k, v in p["dt_proj"].items()
                                if k != "b"}, dt_r)
    dt = jax.nn.softplus(dt_lin.astype(jnp.float32) + p["dt_proj"]["b"])
    A = -jnp.exp(p["A_log"])
    y, h = selective_scan_step(
        xs[:, 0], dt[:, 0], B_t[:, 0], C_t[:, 0], A, p["D"], cache["h"]
    )
    y = y[:, None] * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    y = linear_apply(cfg, p["out_proj"], y, out_logical=("batch", None, None))
    return y, {"conv": conv_state, "h": h}
