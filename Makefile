.PHONY: test test-fast bench bench-table6 bench-scenarios bench-serve \
	bench-scaling bench-obs bench-costmodel trace-demo lint lint-clock \
	lint-residency lint-assert lint-costmodel chaos example

test:            ## full tier-1 suite
	./scripts/test.sh

test-fast:       ## suite minus tests marked slow (QAT training loops)
	./scripts/test.sh --fast

bench:           ## every benchmark section
	PYTHONPATH=src python -m benchmarks.run

bench-table6:    ## MLPerf-Tiny scenario sweep over compiled deployments
	PYTHONPATH=src python -m benchmarks.run --only table6

bench-scenarios: ## scenario sweep, standalone (REPRO_FAST=1 for a quick pass)
	PYTHONPATH=src:. REPRO_FAST=$(REPRO_FAST) python benchmarks/table6_scenarios.py

bench-serve:     ## serving throughput-at-SLO curves over the dynamic batcher
	PYTHONPATH=src:. REPRO_FAST=$(REPRO_FAST) python benchmarks/serve_bench.py

bench-scaling:   ## throughput-at-SLO vs replica count (simulated pool)
	PYTHONPATH=src:. REPRO_FAST=$(REPRO_FAST) python benchmarks/serve_bench.py --scaling

bench-obs:       ## NullTracer overhead assert + FIFO prediction-error table
	PYTHONPATH=src:. REPRO_FAST=$(REPRO_FAST) python benchmarks/obs_bench.py

bench-costmodel: ## learned-predictor LOMO error + probed-vs-predicted autotune
	PYTHONPATH=src:. REPRO_FAST=$(REPRO_FAST) python benchmarks/costmodel_bench.py

trace-demo:      ## one traced server run -> Perfetto timeline artifact
	PYTHONPATH=src:. python benchmarks/obs_bench.py --demo

lint: lint-clock lint-residency lint-assert lint-costmodel  ## every static check CI runs

lint-clock:      ## no raw stdlib clock reads outside repro.obs.timer
	python scripts/check_no_raw_clock.py

lint-residency:  ## megakernel plans never exceed the VMEM cap (goldens)
	python scripts/check_megakernel_residency.py

lint-assert:     ## no bare asserts in serve/deploy (python -O safety)
	python scripts/check_no_bare_assert.py

lint-costmodel:  ## shipped predictor artifact matches the live feature schema
	python scripts/check_costmodel_schema.py

chaos:           ## deterministic fault-injection suite, plain and under -O
	PYTHONPATH=src python -m pytest -x -q tests/test_faults.py
	PYTHONPATH=src python -O -m pytest -x -q tests/test_faults.py

example:         ## the end-to-end codesign + compiled-deployment example
	PYTHONPATH=src python examples/mlperf_tiny_codesign.py
