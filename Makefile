.PHONY: test test-fast bench bench-table6 example

test:            ## full tier-1 suite
	./scripts/test.sh

test-fast:       ## suite minus tests marked slow (QAT training loops)
	./scripts/test.sh --fast

bench:           ## every benchmark section
	PYTHONPATH=src python -m benchmarks.run

bench-table6:    ## MLPerf-Tiny scenario sweep over compiled deployments
	PYTHONPATH=src python -m benchmarks.run --only table6

example:         ## the end-to-end codesign + compiled-deployment example
	PYTHONPATH=src python examples/mlperf_tiny_codesign.py
