.PHONY: test test-fast bench bench-table6 bench-scenarios bench-serve example

test:            ## full tier-1 suite
	./scripts/test.sh

test-fast:       ## suite minus tests marked slow (QAT training loops)
	./scripts/test.sh --fast

bench:           ## every benchmark section
	PYTHONPATH=src python -m benchmarks.run

bench-table6:    ## MLPerf-Tiny scenario sweep over compiled deployments
	PYTHONPATH=src python -m benchmarks.run --only table6

bench-scenarios: ## scenario sweep, standalone (REPRO_FAST=1 for a quick pass)
	PYTHONPATH=src:. REPRO_FAST=$(REPRO_FAST) python benchmarks/table6_scenarios.py

bench-serve:     ## serving throughput-at-SLO curves over the dynamic batcher
	PYTHONPATH=src:. REPRO_FAST=$(REPRO_FAST) python benchmarks/serve_bench.py

example:         ## the end-to-end codesign + compiled-deployment example
	PYTHONPATH=src python examples/mlperf_tiny_codesign.py
