#!/usr/bin/env python
"""Lint: no raw clock reads outside the two sanctioned files.

Every wall-clock read in ``src/repro`` must go through the injectable
obs timer (``repro.obs.timer``) or the serve clock (``repro.serve.clock``)
— that is what makes the whole stack a deterministic discrete-event
simulation under a fake clock, and what keeps exported traces
byte-reproducible. A raw ``time.time()`` / ``time.perf_counter()`` /
``time.monotonic()`` / ``time.sleep()`` anywhere else silently escapes the
injection point, so this script (wired into CI) fails the build on any
new one.

Usage: python scripts/check_no_raw_clock.py [root]
Exits 0 when clean, 1 with a file:line listing otherwise.
"""

from __future__ import annotations

import os
import re
import sys

#: The only files allowed to touch the stdlib clock directly.
ALLOWLIST = {
    os.path.join("src", "repro", "obs", "timer.py"),
    os.path.join("src", "repro", "serve", "clock.py"),
}

#: Raw clock reads we forbid. ``import time`` alone is fine (dead imports
#: are a different lint's job); *calling* the stdlib clock is not.
PATTERN = re.compile(
    r"\btime\.(time|perf_counter|perf_counter_ns|monotonic|monotonic_ns"
    r"|process_time|sleep)\s*\(")

#: Lines where the match is not a stdlib clock call.
EXEMPT_LINE = re.compile(r"^\s*#|\"\"\"|'''")


def scan(root: str) -> list[tuple[str, int, str]]:
    hits = []
    src = os.path.join(root, "src", "repro")
    for dirpath, _, files in os.walk(src):
        for fname in sorted(files):
            if not fname.endswith(".py"):
                continue
            path = os.path.join(dirpath, fname)
            rel = os.path.relpath(path, root)
            if rel in ALLOWLIST:
                continue
            with open(path, encoding="utf-8") as f:
                for i, line in enumerate(f, 1):
                    if PATTERN.search(line) and not EXEMPT_LINE.match(line):
                        hits.append((rel, i, line.rstrip()))
    return hits


def main() -> int:
    root = sys.argv[1] if len(sys.argv) > 1 else \
        os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    hits = scan(root)
    if hits:
        print("raw clock reads outside repro.obs.timer / repro.serve.clock "
              "(route them through the injectable timer):")
        for rel, i, line in hits:
            print(f"  {rel}:{i}: {line}")
        return 1
    print("check_no_raw_clock: clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
