#!/usr/bin/env python
"""CI check: every cached autotune config round-trips exactly.

For each ``*.json`` in the autotune cache (``REPRO_AUTOTUNE_CACHE``,
default ``.repro_autotune``): load -> re-save -> the bytes must be
identical and the parsed ``TunedConfig`` equal. A config that fails to
round-trip would silently re-tune (or worse, half-apply) on the next run.

Exits non-zero on any mismatch; prints one line per config checked.
"""

from __future__ import annotations

import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.deploy.autotune import TunedConfig, cache_dir  # noqa: E402


def main() -> int:
    paths = sorted(glob.glob(os.path.join(cache_dir(), "*.json")))
    if not paths:
        print(f"no autotune configs under {cache_dir()!r} — nothing to check")
        return 0
    failures = 0
    for path in paths:
        with open(path) as f:
            raw = f.read()
        cfg = TunedConfig.from_dict(json.loads(raw))
        out = json.dumps(cfg.to_dict(), indent=2, sort_keys=True) + "\n"
        ok = (json.loads(out) == json.loads(raw)
              and TunedConfig.from_dict(json.loads(out)) == cfg)
        print(f"{'ok  ' if ok else 'FAIL'} {path} "
              f"(micro_batch={cfg.micro_batch}, block_h={cfg.block_h})")
        failures += 0 if ok else 1
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
