#!/usr/bin/env python
"""CI check: the megakernel residency planner's byte accounting is sound.

For every golden fixture (``tests/golden/*.qir.json``) this compiles the
frozen graph and asserts, for each plan the planner emits, that

  * the working set never exceeds the VMEM cap it was admitted under
    (``core.bops.MEGAKERNEL_VMEM_BYTES`` by default);
  * the component bytes (weights + banks + tiles) re-add to the total and
    match a fresh ``megakernel_residency_bytes`` pass over the planned run
    — the plan's audit trail cannot drift from the accounting;
  * the plan covers a run of at least ``MEGAKERNEL_MIN_STAGES`` fused
    dense stages inside a compiled segment;

and that the planner behaves at the boundaries: the MLP goldens (kws, ad)
MUST admit a plan (their whole dense chain fits VMEM — the paper-class
case), and a deliberately tiny budget must reject everything (the staged
fallback the bit-exactness tests pin).

Exits non-zero on any violation; prints one line per model checked.
"""

from __future__ import annotations

import glob
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "..", "tests", "golden")

#: MLP goldens whose dense chains are known to fit resident.
MUST_PLAN = {"kws", "ad"}


def check_model(name: str, path: str) -> int:
    from repro.core.bops import megakernel_residency_bytes
    from repro.core.qir import Graph
    from repro.deploy import compile_graph
    from repro.deploy.lower import MEGAKERNEL_MIN_STAGES, plan_megakernel
    from repro.deploy.lower import FusedThresholdStage

    graph = Graph.load(path)
    cm = compile_graph(graph, in_scale=graph.meta["in_scale"],
                       use_pallas=False)
    failures = 0
    plans = sorted(cm._mega_plans.items())
    for k, plan in plans:
        run = cm.schedule.stages[plan.start:plan.stop]
        res = megakernel_residency_bytes(run, block_m=plan.block_m)
        ok = (plan.total_bytes <= plan.budget_bytes
              and plan.total_bytes == (plan.weight_bytes + plan.bank_bytes
                                       + plan.tile_bytes)
              and plan.total_bytes == res["total_bytes"]
              and plan.weight_bytes == res["weight_bytes"]
              and plan.bank_bytes == res["bank_bytes"]
              and plan.tile_bytes == res["tile_bytes"]
              and plan.n_stages >= MEGAKERNEL_MIN_STAGES
              and all(isinstance(s, FusedThresholdStage) for s in run)
              and cm.segments[k].compiled)
        print(f"{'ok  ' if ok else 'FAIL'} {name} segment {k}: stages "
              f"[{plan.start},{plan.stop}) resident {plan.total_bytes} "
              f"<= cap {plan.budget_bytes} "
              f"(w={plan.weight_bytes} banks={plan.bank_bytes} "
              f"tiles={plan.tile_bytes})")
        failures += 0 if ok else 1
    if name in MUST_PLAN and not plans:
        print(f"FAIL {name}: MLP golden admitted no megakernel plan")
        failures += 1
    if not plans and name not in MUST_PLAN:
        print(f"ok   {name}: no fused dense run (staged dispatch only)")
    # a tiny budget must reject every segment: the staged fallback exists
    for seg in cm.segments:
        if plan_megakernel(cm.schedule.stages, seg, budget_bytes=64):
            print(f"FAIL {name}: 64-byte budget still admitted a plan")
            failures += 1
    return failures


def main() -> int:
    paths = sorted(glob.glob(os.path.join(GOLDEN_DIR, "*.qir.json")))
    if not paths:
        print(f"no golden fixtures under {GOLDEN_DIR!r}")
        return 1
    failures = 0
    for path in paths:
        name = os.path.basename(path).split(".")[0]
        failures += check_model(name, path)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
