#!/usr/bin/env bash
# Tier-1 verify in one command. Extra args pass through to pytest:
#   scripts/test.sh            # full suite
#   scripts/test.sh --fast     # skip tests marked slow
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -x -q "$@"
