#!/usr/bin/env python
"""Lint: the shipped cost-model artifact matches the live feature schema.

``repro.costmodel`` ships a committed default predictor artifact
(``src/repro/costmodel/artifacts/default.json``) so probe-free autotuning
and cold-start admission work out of the box. The artifact embeds the
feature schema it was trained against; if ``features.py`` evolves (a
feature added, renamed, or reordered) without retraining and recommitting
the artifact, every load would raise at runtime — in whatever process
happens to call ``load_default()`` first. This check moves that failure
to CI:

  * the artifact parses and its ``schema_version`` / ``feature_names``
    match ``repro.costmodel.features`` exactly (order included — the
    weight vector is positional);
  * the loaded predictor produces a finite, positive prediction on a
    canonical feature point (weights are not NaN/garbage);
  * prediction is deterministic (two calls, identical bits).

Regenerate after a schema change with
``python -c "from repro.costmodel import make_default_artifact;
make_default_artifact()"``.

Usage: python scripts/check_costmodel_schema.py [root]
Exits 0 when clean, 1 with the mismatch listing otherwise.
"""

from __future__ import annotations

import json
import math
import os
import sys


def main() -> int:
    root = sys.argv[1] if len(sys.argv) > 1 else \
        os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, os.path.join(root, "src"))

    from repro.costmodel.features import (FEATURE_NAMES,
                                          FEATURE_SCHEMA_VERSION,
                                          features_from_costs)
    from repro.costmodel.model import WaveCostPredictor, default_artifact_path

    errors = []
    path = default_artifact_path()
    if not os.path.exists(path):
        print(f"check_costmodel_schema: missing artifact {path}")
        return 1

    with open(path, encoding="utf-8") as f:
        raw = json.load(f)
    if raw.get("schema_version") != FEATURE_SCHEMA_VERSION:
        errors.append(
            f"schema_version {raw.get('schema_version')} != live "
            f"{FEATURE_SCHEMA_VERSION}")
    if tuple(raw.get("feature_names", ())) != tuple(FEATURE_NAMES):
        errors.append(
            f"feature_names {raw.get('feature_names')} != live "
            f"{list(FEATURE_NAMES)} (order matters: weights are positional)")

    if not errors:
        predictor = WaveCostPredictor.load(path)
        feats = features_from_costs(
            wave_cycles=4096, micro_batch=16, bops=1 << 24,
            traffic_bytes=1 << 16, param_bytes=1 << 15, n_stages=4)
        a = float(predictor.predict_ms(feats))
        b = float(predictor.predict_ms(feats))
        if not (math.isfinite(a) and a > 0):
            errors.append(f"prediction on canonical point not finite/"
                          f"positive: {a}")
        if a != b:
            errors.append(f"prediction not deterministic: {a} != {b}")

    if errors:
        print("check_costmodel_schema: shipped artifact out of sync with "
              "repro.costmodel.features (retrain via make_default_artifact):")
        for e in errors:
            print(f"  {e}")
        return 1
    print("check_costmodel_schema: clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
