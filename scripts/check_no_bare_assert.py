#!/usr/bin/env python
"""Lint: no bare ``assert`` statements in the serving/deploy runtime.

``python -O`` strips assert statements. In library code that is fine for
debug invariants, but the serve router and the deploy executor use their
checks as *load-bearing* input validation and result-integrity guards —
a mask-contract check or a fifo-depth check that silently vanishes under
``-O`` turns a typed failure into served garbage. Those paths must raise
typed exceptions (``ValueError``, ``RuntimeError``, ``serve.faults.*``)
instead, and CI runs the chaos suite under ``python -O`` to prove the
failure handling doesn't evaporate.

This script (wired into ``make lint`` and CI) fails the build on any
``assert`` statement under ``src/repro/serve`` or ``src/repro/deploy``.
Test files keep using assert freely — pytest rewrites them.

Usage: python scripts/check_no_bare_assert.py [root]
Exits 0 when clean, 1 with a file:line listing otherwise.
"""

from __future__ import annotations

import os
import re
import sys

#: Packages whose asserts must be typed exceptions instead.
SCAN_DIRS = (
    os.path.join("src", "repro", "serve"),
    os.path.join("src", "repro", "deploy"),
)

#: An assert *statement* (line-leading); ``self.assertEqual`` or the word
#: inside a string/comment doesn't match.
PATTERN = re.compile(r"^\s*assert\b")

#: Lines where the match is not an assert statement.
EXEMPT_LINE = re.compile(r"^\s*#|\"\"\"|'''")


def scan(root: str) -> list[tuple[str, int, str]]:
    hits = []
    for sub in SCAN_DIRS:
        base = os.path.join(root, sub)
        for dirpath, _, files in os.walk(base):
            for fname in sorted(files):
                if not fname.endswith(".py"):
                    continue
                path = os.path.join(dirpath, fname)
                rel = os.path.relpath(path, root)
                with open(path, encoding="utf-8") as f:
                    for i, line in enumerate(f, 1):
                        if PATTERN.match(line) \
                                and not EXEMPT_LINE.match(line):
                            hits.append((rel, i, line.rstrip()))
    return hits


def main() -> int:
    root = sys.argv[1] if len(sys.argv) > 1 else \
        os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    hits = scan(root)
    if hits:
        print("bare assert statements in serve/deploy runtime code "
              "(they vanish under python -O; raise a typed exception):")
        for rel, i, line in hits:
            print(f"  {rel}:{i}: {line}")
        return 1
    print("check_no_bare_assert: clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
