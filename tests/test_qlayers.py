"""QDense / QConv2D / QDenseBatchNorm: the paper's Eqs. 3-4 BN folding and
merged-ReLU behaviour."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.qlayers import QConv2D, QDense, QDenseBatchNorm


def test_qdense_shapes_and_relu():
    layer = QDense(16, 8, weight_bits=8, act_bits=8, relu=True)
    p = layer.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16))
    y = layer.apply(p, x)
    assert y.shape == (4, 8)
    assert float(jnp.min(y)) >= 0.0            # merged ReLU


def test_qdense_full_precision_is_plain_matmul():
    layer = QDense(8, 4, weight_bits=32, act_bits=32)
    p = layer.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 8))
    np.testing.assert_allclose(
        np.asarray(layer.apply(p, x)), np.asarray(x @ p["w"] + p["b"]), rtol=1e-6)


def test_qdense_param_count():
    assert QDense(490, 256).n_params() == 490 * 256 + 256
    assert QDense(490, 256, use_bias=False).n_params() == 490 * 256


# ---------------------------------------------------------------------------
# QDenseBatchNorm — paper Eqs. 3-4
# ---------------------------------------------------------------------------

def test_bn_fold_equations_match_unfused():
    """Eval-mode folded layer == Dense -> BN computed separately (Eqs. 3-4)."""
    layer = QDenseBatchNorm(12, 6, weight_bits=32, act_bits=32, relu=False)
    p = layer.init(jax.random.PRNGKey(0))
    # give BN non-trivial running stats
    p = dict(p,
             mu=jax.random.normal(jax.random.PRNGKey(2), (6,)),
             sigma2=jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(3), (6,))) + 0.5,
             gamma=jax.random.normal(jax.random.PRNGKey(4), (6,)) + 1.0,
             beta=jax.random.normal(jax.random.PRNGKey(5), (6,)))
    x = jax.random.normal(jax.random.PRNGKey(1), (5, 12))

    y_folded, _ = layer.apply(p, x, train=False)
    # unfused reference
    y_fc = x @ p["w"] + p["b"]
    y_bn = (p["gamma"] * (y_fc - p["mu"]) / jnp.sqrt(p["sigma2"] + layer.eps)
            + p["beta"])
    np.testing.assert_allclose(np.asarray(y_folded), np.asarray(y_bn),
                               rtol=1e-5, atol=1e-5)


def test_bn_fold_kernel_formula():
    """fold() returns exactly k_folded = v*k, b_folded = v*(b-mu)+beta."""
    layer = QDenseBatchNorm(4, 3, relu=False)
    p = layer.init(jax.random.PRNGKey(0))
    p = dict(p, mu=jnp.asarray([1.0, -1.0, 0.5]),
             sigma2=jnp.asarray([4.0, 1.0, 0.25]),
             gamma=jnp.asarray([2.0, 3.0, 1.0]),
             beta=jnp.asarray([0.1, 0.2, 0.3]))
    k_folded, b_folded = layer.fold(p)
    v = np.asarray(p["gamma"]) / np.sqrt(np.asarray(p["sigma2"]) + layer.eps)
    np.testing.assert_allclose(np.asarray(k_folded), np.asarray(p["w"]) * v[None, :],
                               rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(b_folded),
        v * (np.asarray(p["b"]) - np.asarray(p["mu"])) + np.asarray(p["beta"]),
        rtol=1e-6)


def test_bn_running_stats_update_in_train():
    layer = QDenseBatchNorm(8, 4, momentum=0.5)
    p = layer.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 8)) * 3 + 1
    _, p1 = layer.apply(p, x, train=True)
    assert not np.allclose(np.asarray(p1["mu"]), 0.0)          # moved toward batch mean
    _, p2 = layer.apply(p1, x, train=False)
    np.testing.assert_array_equal(np.asarray(p2["mu"]), np.asarray(p1["mu"]))


def test_bn_train_uses_batch_stats_like_deployed_arithmetic():
    """Train-mode forward quantizes the *folded* kernel — outputs stay on the
    act-quant grid, matching the deployed integer layer."""
    layer = QDenseBatchNorm(8, 4, weight_bits=4, act_bits=4)
    p = layer.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (16, 8))
    y, _ = layer.apply(p, x, train=True)
    assert len(np.unique(np.asarray(y))) <= 2 ** 4 * 4  # coarse grid per channel


# ---------------------------------------------------------------------------
# QConv2D
# ---------------------------------------------------------------------------

def test_qconv_shapes():
    conv = QConv2D(3, 8, kernel=3, stride=2, relu=True)
    p = conv.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16, 3))
    y = conv.apply(p, x)
    assert y.shape == (2, 8, 8, 8)
    assert float(jnp.min(y)) >= 0.0


def test_qconv_quantization_error_bounded():
    conv_q = QConv2D(3, 4, kernel=3, weight_bits=8, act_bits=32, relu=False)
    conv_f = QConv2D(3, 4, kernel=3, weight_bits=32, act_bits=32, relu=False)
    p = conv_q.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 8, 3))
    yq = conv_q.apply(p, x)
    yf = conv_f.apply(p, x)
    rel = float(jnp.max(jnp.abs(yq - yf)) / (jnp.max(jnp.abs(yf)) + 1e-9))
    assert rel < 0.05                                           # 8-bit: ~0.4% steps


def test_gradients_flow_through_quantized_layers():
    layer = QDense(8, 4, weight_bits=4, act_bits=4, relu=True)
    p = layer.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8))

    def loss(p):
        return jnp.sum(jnp.square(layer.apply(p, x)))

    g = jax.grad(loss)(p)
    assert float(jnp.sum(jnp.abs(g["w"]))) > 0.0               # STE passes grads
    assert np.all(np.isfinite(np.asarray(g["w"])))
