"""Selective-scan equivalences: chunked (TPU-friendly) vs sequential oracle,
decode-step consistency, causal conv state handling."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.configs import get_config
from repro.models import ssm


def _scan_inputs(key, B, T, di, st_):
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (B, T, di))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, di)) - 1.0)
    B_t = jax.random.normal(ks[2], (B, T, st_))
    C_t = jax.random.normal(ks[3], (B, T, st_))
    A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(99), (di, st_)) * 0.3)
    D = jnp.ones((di,))
    return x, dt, B_t, C_t, A, D


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_chunked_scan_matches_sequential_oracle(chunk):
    x, dt, B_t, C_t, A, D = _scan_inputs(jax.random.PRNGKey(0), 2, 32, 6, 4)
    y_ref, h_ref = ssm.selective_scan_ref(x, dt, B_t, C_t, A, D)
    y_chk, h_chk = ssm.selective_scan_chunked(x, dt, B_t, C_t, A, D, chunk)
    np.testing.assert_allclose(np.asarray(y_chk), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_chk), np.asarray(h_ref),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 1000), st.sampled_from([2, 4, 8]))
def test_chunked_scan_property(seed, chunk):
    x, dt, B_t, C_t, A, D = _scan_inputs(jax.random.PRNGKey(seed), 1, 16, 4, 3)
    y_ref, _ = ssm.selective_scan_ref(x, dt, B_t, C_t, A, D)
    y_chk, _ = ssm.selective_scan_chunked(x, dt, B_t, C_t, A, D, chunk)
    np.testing.assert_allclose(np.asarray(y_chk), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)


def test_step_scan_matches_full():
    """Per-token selective_scan_step chains to the same outputs."""
    x, dt, B_t, C_t, A, D = _scan_inputs(jax.random.PRNGKey(1), 1, 8, 4, 3)
    y_ref, h_ref = ssm.selective_scan_ref(x, dt, B_t, C_t, A, D)
    h = jnp.zeros((1, 4, 3), jnp.float32)
    ys = []
    for t in range(8):
        y, h = ssm.selective_scan_step(x[:, t], dt[:, t], B_t[:, t], C_t[:, t],
                                       A, D, h)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(jnp.stack(ys, 1)), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), rtol=1e-4)


def test_causal_conv_is_causal_and_stateful():
    B, T, di, K = 1, 6, 3, 4
    w = jax.random.normal(jax.random.PRNGKey(0), (K, di))
    b = jnp.zeros((di,))
    x = jax.random.normal(jax.random.PRNGKey(1), (B, T, di))
    y_full, state = ssm.causal_conv1d(x, w, b)
    # causality: y[t] must not depend on x[t+1:]
    x2 = x.at[:, 3:].set(0.0)
    y2, _ = ssm.causal_conv1d(x2, w, b)
    np.testing.assert_allclose(np.asarray(y2[:, :3]), np.asarray(y_full[:, :3]),
                               rtol=1e-6)
    # streaming: two halves with carried state == full
    y_a, st_a = ssm.causal_conv1d(x[:, :3], w, b)
    y_b, _ = ssm.causal_conv1d(x[:, 3:], w, b, st_a)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([y_a, y_b], 1)), np.asarray(y_full),
        rtol=1e-5, atol=1e-6)


def test_ssm_block_decode_matches_forward():
    """Full mamba block: token-by-token decode == forward (falcon-mamba)."""
    cfg = get_config("falcon-mamba-7b").reduced()
    p = ssm.ssm_init(jax.random.PRNGKey(0), cfg)
    B, T = 1, 8
    x = jax.random.normal(jax.random.PRNGKey(1), (B, T, cfg.d_model))
    full = ssm.ssm_apply(cfg, p, x)

    cache = ssm.ssm_cache_init(cfg, B)
    outs = []
    for t in range(T):
        y, cache = ssm.ssm_decode(cfg, p, x[:, t: t + 1], cache)
        outs.append(y)
    stepped = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(stepped), np.asarray(full),
                               rtol=2e-3, atol=2e-3)
