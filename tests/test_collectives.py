"""Compressed-gradient collectives: quantization error bounds, error-feedback
accumulation, and (via a 1-device mesh) the shard_map path end-to-end.
Multi-device behaviour is exercised in test_multidevice.py (subprocess with
8 forced host devices)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.models.layers import shard_map
from repro.parallel.collectives import (
    collective_bytes_saved,
    compressed_psum,
    compressed_psum_tree,
)


def _mesh1():
    return Mesh(np.array(jax.devices()[:1]).reshape(1), ("data",))


def _run_in_shardmap(fn, *args):
    mesh = _mesh1()
    return shard_map(fn, mesh,
                     in_specs=tuple(P() for _ in args),
                     out_specs=(P(), P()))(*args)


def test_compressed_psum_error_bounded():
    g = jax.random.normal(jax.random.PRNGKey(0), (256,)) * 0.01

    def f(g):
        return compressed_psum(g, ("data",), 1)

    mean, err = _run_in_shardmap(f, g)
    scale = float(jnp.max(jnp.abs(g))) / 127.0
    assert float(jnp.max(jnp.abs(mean - g))) <= scale * 0.5 + 1e-9
    # err is exactly the quantization residual
    np.testing.assert_allclose(np.asarray(g - mean), np.asarray(err), atol=1e-7)


@pytest.mark.slow
def test_error_feedback_recovers_lost_mass():
    """Repeatedly sending the same gradient with EF converges the *cumulative*
    update to the true cumulative gradient (1-bit-Adam property)."""
    g = jax.random.normal(jax.random.PRNGKey(1), (128,)) * 1e-3
    err = jnp.zeros_like(g)
    total_sent = jnp.zeros_like(g)
    mesh = _mesh1()

    def f(gi, e):
        return compressed_psum_tree({"g": gi}, {"g": e}, ("data",), 1)

    fn = shard_map(f, mesh, in_specs=(P(), P()),
                   out_specs=({"g": P()}, {"g": P()}))
    for _ in range(20):
        out, new_err = fn(g, err)
        total_sent = total_sent + out["g"]
        err = new_err["g"]
    # telescoping: total_sent = 20*g - err_final, |err_final| <= half a step
    step = float(jnp.max(jnp.abs(g))) / 127.0
    np.testing.assert_allclose(np.asarray(total_sent), np.asarray(20 * g),
                               atol=step + 1e-7)


def test_compressed_psum_tree_structure():
    tree = {"a": jnp.ones((4,)), "b": {"c": jnp.ones((2, 2))}}
    err = jax.tree.map(jnp.zeros_like, tree)
    mesh = _mesh1()
    fn = shard_map(
        lambda t, e: compressed_psum_tree(t, e, ("data",), 1), mesh,
        in_specs=(jax.tree.map(lambda _: P(), tree),
                  jax.tree.map(lambda _: P(), err)),
        out_specs=(jax.tree.map(lambda _: P(), tree),
                   jax.tree.map(lambda _: P(), err)))
    mean, new_err = fn(tree, err)
    assert jax.tree.structure(mean) == jax.tree.structure(tree)
    np.testing.assert_allclose(np.asarray(mean["a"]), 1.0, rtol=0.02)


def test_bytes_saved_accounting():
    g = {"w": jnp.zeros((1000,)), "b": jnp.zeros((24,))}
    assert collective_bytes_saved(g) == 1024 * 3        # f32 -> int8


@pytest.mark.slow
def test_ddp_compressed_step_trains():
    """Full explicit-DP step on a 1-device mesh: loss decreases."""
    from repro.optim.adamw import make_optimizer
    from repro.train.steps import init_ddp_state, make_ddp_compressed_step

    w_true = jnp.asarray([2.0, -1.0, 0.5])

    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"]
        return jnp.mean((pred - batch["y"]) ** 2), {}

    opt = make_optimizer(base_lr=0.05, warmup=1, total=100, weight_decay=0.0)
    params = {"w": jnp.zeros(3)}
    state = init_ddp_state(params, opt)
    step = make_ddp_compressed_step(loss_fn, opt, _mesh1())

    rng = np.random.default_rng(0)
    losses = []
    for i in range(60):
        x = jnp.asarray(rng.standard_normal((16, 3)), jnp.float32)
        batch = {"x": x, "y": x @ w_true}
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < 0.05 * losses[0]
