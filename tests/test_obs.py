"""repro.obs tests: tracer semantics, deterministic export, and the
cross-checks that keep instrumentation honest.

The two load-bearing properties here are the ISSUE's acceptance criteria:

  * **byte-identical export** — two serve runs under the same
    ``ManualClock`` schedule must produce the same Chrome-trace bytes
    (trace diffs are only reviewable if identical runs serialize
    identically);
  * **bit-exact agreement** — p50/p90/p99 recomputed from request spans
    must equal the ``ServeMetrics`` snapshot with ``==``, not approx: the
    trace and the metrics window observe the same completions through
    different code paths, and any drift means one of them is lying.

Plus the ServeMetrics edge cases the tentpole work fixed (sheds-only cold
start opening the throughput window, the inclusive prune boundary) and
the timer/lint satellites.
"""

import json
import os
import subprocess
import sys
import threading

import numpy as np
import pytest

from repro.obs import (
    NULL_TRACER,
    Tracer,
    chrome_events,
    chrome_json,
    export_chrome,
    export_jsonl,
    jsonl_lines,
    latency_percentiles,
    prediction_error,
    prediction_records,
    request_latencies_ms,
    stage_medians_ms,
)
from repro.obs import timer as obs_timer
from repro.serve import (
    ManualClock,
    Router,
    RouterConfig,
    ServeMetrics,
    ServiceModel,
    poisson_trace,
)


class FakeClock:
    """now/sleep stand-in for the process-wide obs timer."""

    def __init__(self):
        self.t = 0.0

    def now(self) -> float:
        return self.t

    def sleep(self, s: float):
        assert s >= 0
        self.t += s

    def advance(self, s: float):
        self.t += s


@pytest.fixture()
def clock():
    with obs_timer.fake(FakeClock()) as ck:
        yield ck


class ScriptedModel:
    """submit_wave fake with the executor's padding contract (the
    test_serve idiom): each wave advances the manual clock by a fixed
    service time."""

    def __init__(self, clock, service_s=0.003, micro_batch=4):
        self.clock = clock
        self.service_s = service_s
        self.default_micro_batch = micro_batch

    def submit_wave(self, x, valid=None, micro_batch=None):
        mb = int(micro_batch or self.default_micro_batch)
        x = np.asarray(x)
        n = x.shape[0]
        mask = np.concatenate([np.ones(n, bool), np.zeros(mb - n, bool)])
        self.clock.advance(self.service_s)
        y = np.zeros((mb, 1), np.float32)
        y[:n, 0] = x.reshape(n, -1).sum(axis=1)
        return y, mask


def _mk(i):
    return np.full((4,), i, np.int32)


def _serve_run(n=32):
    """One deterministic traced serve run: fresh ManualClock, fresh
    tracer, same arrival trace — the unit the determinism tests repeat."""
    ck = ManualClock()
    tr = Tracer(clock=ck)
    model = ScriptedModel(ck, service_s=0.003, micro_batch=4)
    svc = ServiceModel(works=[("s", 64)], sec_per_cycle=1e-6)
    router = Router({"m": model}, RouterConfig(max_wait_ms=2.0),
                    clock=ck, service_models={"m": svc}, tracer=tr)
    router.run_trace("m", poisson_trace(qps=400.0, n=n, seed=3), _mk)
    return ck, tr, router


# ---------------------------------------------------------------------------
# tracer semantics
# ---------------------------------------------------------------------------

def test_span_context_manager_records_clock_interval():
    ck = ManualClock(start=5.0)
    tr = Tracer(clock=ck)
    with tr.span("work", cat="c", pid=2, tid=3) as sp:
        ck.advance(0.5)
        sp.set(k=1)
    (ev,) = tr.spans(name="work")
    assert (ev.t0, ev.t1) == (5.0, 5.5)
    assert ev.dur == 0.5
    assert (ev.pid, ev.tid, ev.cat) == (2, 3, "c")
    assert ev.args == {"k": 1}


def test_instant_counter_and_filters():
    tr = Tracer(clock=ManualClock())
    tr.instant("enqueue", t=1.0, cat="router", uid=7)
    tr.counter("backlog", 3, t=1.5, cat="router")
    tr.add_span("wave", 1.0, 2.0, cat="exec")
    assert len(tr) == 3
    (inst,) = tr.events(kind="instant")
    assert inst.t0 == inst.t1 == 1.0 and inst.args == {"uid": 7}
    (ctr,) = tr.counters(name="backlog")
    assert ctr.value == 3.0
    assert tr.spans(cat="exec")[0].name == "wave"
    assert tr.events(cat="router", kind="counter") == [ctr]


def test_ring_capacity_drops_oldest_and_counts():
    tr = Tracer(clock=ManualClock(), capacity=4)
    for i in range(6):
        tr.instant(f"i{i}", t=float(i))
    assert len(tr) == 4
    assert tr.n_dropped == 2
    evs = tr.events()
    assert [e.name for e in evs] == ["i2", "i3", "i4", "i5"]
    assert [e.seq for e in evs] == [2, 3, 4, 5]


def test_capacity_must_be_positive():
    with pytest.raises(ValueError):
        Tracer(capacity=0)


def test_clear_resets_ring_seq_and_drop_count():
    tr = Tracer(clock=ManualClock(), capacity=2)
    for i in range(5):
        tr.instant("x", t=float(i))
    tr.clear()
    assert len(tr) == 0 and tr.n_dropped == 0
    tr.instant("y", t=0.0)
    assert tr.events()[0].seq == 0


def test_concurrent_appends_keep_every_event_and_unique_seq():
    tr = Tracer(clock=ManualClock())
    n_threads, per = 8, 500

    def worker(k):
        for i in range(per):
            tr.instant("e", t=0.0, pid=k)

    ts = [threading.Thread(target=worker, args=(k,))
          for k in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    evs = tr.events()
    assert len(evs) == n_threads * per
    assert len({e.seq for e in evs}) == n_threads * per


def test_null_tracer_is_inert_and_shared():
    assert NULL_TRACER.enabled is False
    with NULL_TRACER.span("x") as sp:
        sp.set(a=1)
    assert NULL_TRACER.span("a") is NULL_TRACER.span("b")
    NULL_TRACER.add_span("x", 0.0, 1.0)
    NULL_TRACER.instant("x")
    NULL_TRACER.counter("x", 1.0)
    assert NULL_TRACER.events() == [] and len(NULL_TRACER) == 0
    assert NULL_TRACER.now() == 0.0


def test_router_keeps_an_empty_tracer_instance():
    """Regression: ``Tracer`` defines ``__len__``, which historically made
    an EMPTY tracer falsy, so ``tracer or NULL_TRACER`` silently degraded
    a fresh tracer to the NullTracer before its first event. Fixed by an
    explicit ``__bool__``; injection points testing ``is not None`` were
    always safe."""
    ck = ManualClock()
    tr = Tracer(clock=ck)
    router = Router({"m": ScriptedModel(ck)}, RouterConfig(),
                    clock=ck, tracer=tr)
    assert router.tracer is tr


def test_empty_tracer_is_truthy_null_tracer_is_falsy():
    """The ``__bool__`` fix: a real tracer is truthy even before its first
    event (``len() == 0``), while the disabled NullTracer stays falsy —
    so both injection idioms now keep a fresh tracer."""
    tr = Tracer(clock=ManualClock())
    assert len(tr) == 0 and bool(tr)
    assert (tr or NULL_TRACER) is tr
    assert not bool(NULL_TRACER)
    assert (NULL_TRACER or tr) is tr


# ---------------------------------------------------------------------------
# export
# ---------------------------------------------------------------------------

def test_chrome_events_shapes_and_metadata_order():
    tr = Tracer(clock=ManualClock())
    tr.add_span("wave", 0.001, 0.003, cat="router", pid=1, tid=2,
                args={"n": 4})
    tr.instant("shed", t=0.002, cat="router")
    tr.counter("backlog", 5, t=0.004)
    evs = chrome_events(tr.events(), process_names={1: "replica0",
                                                    0: "router"},
                        thread_names={(0, 1): "lane:m"})
    assert [e["ph"] for e in evs[:3]] == ["M", "M", "M"]
    assert evs[0]["args"]["name"] == "router"       # pids sorted
    assert evs[1]["args"]["name"] == "replica0"
    assert evs[2] == {"ph": "M", "name": "thread_name", "pid": 0, "tid": 1,
                      "args": {"name": "lane:m"}}
    span, inst, ctr = evs[3:]
    assert span["ph"] == "X" and span["ts"] == 0.001 * 1e6
    assert span["dur"] == (0.003 - 0.001) * 1e6
    assert span["args"] == {"n": 4}
    assert inst["ph"] == "i" and inst["s"] == "t"
    assert ctr["ph"] == "C" and ctr["args"] == {"backlog": 5.0}


def test_export_sanitizes_args_to_json_primitives():
    tr = Tracer(clock=ManualClock())
    tr.add_span("s", 0.0, 1.0, args={"a": np.float32(1.5),
                                     "b": [np.int32(2), "x"],
                                     "c": object()})
    (ev,) = chrome_events(tr.events())[0:1]
    args = ev["args"]
    assert args["a"] == 1.5 and type(args["a"]) is float
    assert args["b"] == [2, "x"]
    assert isinstance(args["c"], str)
    json.dumps(args)  # round-trips as plain JSON


def test_manual_clock_runs_export_byte_identically(tmp_path):
    """ISSUE acceptance: two fresh runs under the same ManualClock
    schedule produce byte-identical Chrome-trace and JSONL files."""
    _, tr1, router1 = _serve_run()
    _, tr2, router2 = _serve_run()
    s1 = chrome_json(tr1, **router1.trace_names())
    s2 = chrome_json(tr2, **router2.trace_names())
    assert s1 == s2
    assert len(tr1) > 0           # non-vacuous: the runs actually traced
    p1 = export_chrome(tr1, str(tmp_path / "a" / "t1.json"),
                       **router1.trace_names())
    p2 = export_chrome(tr2, str(tmp_path / "b" / "t2.json"),
                       **router2.trace_names())
    b1, b2 = open(p1, "rb").read(), open(p2, "rb").read()
    assert b1 == b2
    doc = json.loads(b1)
    assert doc["otherData"]["n_dropped"] == 0
    assert {e["ph"] for e in doc["traceEvents"]} >= {"M", "X", "i", "C"}
    assert jsonl_lines(tr1) == jsonl_lines(tr2)
    j1 = export_jsonl(tr1, str(tmp_path / "a" / "t1.jsonl"))
    assert all(json.loads(line) for line in open(j1))


def test_export_creates_parent_directories(tmp_path):
    tr = Tracer(clock=ManualClock())
    tr.instant("x", t=0.0)
    path = str(tmp_path / "deep" / "nested" / "trace.json")
    assert export_chrome(tr, path) == path
    assert os.path.exists(path)


# ---------------------------------------------------------------------------
# span-derived reports vs serve metrics — the bit-exact cross-check
# ---------------------------------------------------------------------------

def test_span_percentiles_equal_snapshot_to_the_bit():
    """ISSUE acceptance: p50/p90/p99 recomputed from request spans equal
    the ServeMetrics snapshot with ``==`` — same floats, same
    np.percentile, no approx."""
    _, tr, router = _serve_run()
    snap = router.stats()["m"]["metrics"]
    pct = latency_percentiles(tr, model="m")
    assert pct["n"] == snap.n_completed > 0
    assert pct["p50_ms"] == snap.p50_ms
    assert pct["p90_ms"] == snap.p90_ms
    assert pct["p99_ms"] == snap.p99_ms


def test_request_latency_population_excludes_sheds():
    tr = Tracer(clock=ManualClock())
    tr.add_span("request", 0.0, 0.010, args={"uid": 0, "model": "m"})
    tr.add_span("request", 1.0, 1.0, args={"uid": 1, "model": "m",
                                           "shed": True})
    tr.add_span("request", 0.0, 0.020, args={"uid": 2, "model": "other"})
    lats = request_latencies_ms(tr, model="m")
    np.testing.assert_array_equal(lats, [10.0])
    assert latency_percentiles(tr)["n"] == 2   # both models, sheds out


def test_wave_spans_carry_the_fifo_prediction():
    """Every dispatched wave records predicted_ms (the raw FIFO-cost-model
    estimate) next to its measured duration."""
    _, tr, router = _serve_run()
    waves = tr.spans(name="wave")
    rows = prediction_records(tr)
    assert len(rows) == len(waves) > 0
    svc = router.lanes["m"].service
    for row, ev in zip(rows, waves):
        assert row["predicted_ms"] == svc.wave_service_s(4) * 1e3
        assert row["measured_ms"] == (ev.t1 - ev.t0) * 1e3
        assert row["model"] == "m"


def test_prediction_error_statistics_are_exact():
    tr = Tracer(clock=ManualClock())
    base = {"model": "m", "platform": "cpu", "micro_batch": 4, "n_valid": 4}
    tr.add_span("wave", 0.0, 0.012, args={**base, "predicted_ms": 10.0})
    tr.add_span("wave", 0.0, 0.008, args={**base, "predicted_ms": 10.0})
    tr.add_span("wave", 0.0, 0.008, args=base)   # no prediction -> skipped
    assert len(prediction_records(tr)) == 2
    err = prediction_error(tr)["m@cpu"]
    assert err["n_waves"] == 2
    assert err["predicted_ms_mean"] == 10.0
    assert err["measured_ms_mean"] == pytest.approx(10.0)
    assert err["mean_abs_rel_err"] == pytest.approx(0.2)
    assert err["median_abs_rel_err"] == pytest.approx(0.2)
    assert err["bias_rel"] == pytest.approx(0.0, abs=1e-12)


def test_stage_latencies_cross_check_against_trace(clock, monkeypatch):
    """``stage_medians_ms`` recomputes the ``stage_latencies`` breakdown
    from the probe spans with identical arithmetic — medians must match
    exactly, float for float."""
    import jax

    from repro.core.qir import export_qmlp
    from repro.deploy import compile_graph
    from repro.models.tiny import KWSMLP

    model = KWSMLP(width=16)
    params = model.init(jax.random.PRNGKey(0))
    hidden_defs, _ = model.layers()
    graph = export_qmlp(hidden_defs, params["hidden"], params["head"])
    tr = Tracer()          # no clock= -> reads the faked obs timer
    cm = compile_graph(graph, in_scale=1.0 / 127.0, use_pallas=False,
                       tracer=tr)
    assert cm.tracer is tr

    costs = [0.002 * (i + 1) for i in range(len(cm.schedule.stages))]

    def fake_fn(c):
        def fn(h):
            clock.advance(c)
            return h
        return fn

    monkeypatch.setattr(cm, "_stage_fns", [fake_fn(c) for c in costs])
    breakdown = cm.stage_latencies(np.zeros((1, 490), np.int32), iters=3)
    assert len(tr.spans(name="stage")) == 3 * len(costs)
    med = stage_medians_ms(tr)
    assert set(med) == {b["stage"] for b in breakdown}
    for b in breakdown:
        assert med[b["stage"]] == b["ms"]


# ---------------------------------------------------------------------------
# ServeMetrics edge cases (tentpole fixes)
# ---------------------------------------------------------------------------

def test_snapshot_on_empty_window_is_all_zeros():
    snap = ServeMetrics(window_s=5.0).snapshot(123.4)
    assert snap.n_completed == snap.n_shed == snap.n_admitted == 0
    assert snap.p50_ms == snap.p99_ms == 0.0
    assert snap.throughput_qps == 0.0
    assert snap.shed_rate == 0.0 and snap.mean_occupancy == 0.0


def test_cold_start_sheds_open_the_throughput_window():
    """The fixed bug: a recorder idling from t=0 whose first traffic (all
    sheds) lands at t=100 must measure qps over the traffic span, not the
    recorder lifetime — sheds open the window too."""
    m = ServeMetrics(window_s=30.0, start_t=0.0)
    m.record_shed(100.0)
    m.record_completion(100.5, 0.010)
    assert m.first_event_t == 100.0
    snap = m.snapshot(101.0)
    assert snap.throughput_qps == 1.0 / (101.0 - 100.0)
    assert snap.shed_rate == 1.0    # 1 shed / (0 admits + 1 shed)


def test_sheds_only_window_reports_zero_qps_full_shed_rate():
    m = ServeMetrics(window_s=30.0)
    for t in (10.0, 10.1, 10.2):
        m.record_shed(t)
    snap = m.snapshot(11.0)
    assert snap.n_completed == 0 and snap.throughput_qps == 0.0
    assert snap.n_shed == 3 and snap.shed_rate == 1.0


def test_prune_boundary_is_inclusive():
    """An event stamped exactly at ``now - window_s`` stays (strict ``<``
    comparison) — the documented tie direction manual-clock tests rely
    on."""
    m = ServeMetrics(window_s=10.0)
    m.record_completion(0.0, 0.001)
    assert m.snapshot(10.0).n_completed == 1
    assert m.snapshot(10.0 + 1e-6).n_completed == 0


def test_wave_occupancy_histogram_with_mixed_micro_batch_sizes():
    """Waves dispatched under different micro-batch sizes (the autotuner
    can retune a lane mid-run): the histogram keys on n_valid and the
    mean normalizes each wave by ITS OWN micro_batch."""
    m = ServeMetrics(window_s=30.0)
    m.record_wave(1.0, 4, 4)     # full wave at mb=4
    m.record_wave(1.1, 2, 4)     # half wave at mb=4
    m.record_wave(1.2, 2, 8)     # quarter wave at mb=8
    snap = m.snapshot(2.0)
    assert snap.n_waves == 3
    assert snap.occupancy_hist == {4: 1, 2: 2}
    assert snap.mean_occupancy == pytest.approx((1.0 + 0.5 + 0.25) / 3)


# ---------------------------------------------------------------------------
# timer + lint satellites
# ---------------------------------------------------------------------------

def test_timer_fake_installs_and_restores():
    real = obs_timer.get_timer()
    fk = FakeClock()
    with obs_timer.fake(fk):
        assert obs_timer.get_timer() is fk
        fk.advance(2.5)
        assert obs_timer.now() == 2.5
        obs_timer.sleep(0.5)
        assert fk.t == 3.0
        # manual clocks have no walltime: provenance stamps fall back to
        # the real epoch clock rather than leaking fake durations
        assert obs_timer.walltime() > 1e9
    assert obs_timer.get_timer() is real


def test_tracer_without_clock_reads_process_timer(clock):
    tr = Tracer()
    clock.advance(7.0)
    assert tr.now() == 7.0
    with tr.span("s"):
        clock.advance(1.0)
    assert tr.spans(name="s")[0].dur == 1.0


def test_no_raw_clock_lint_passes():
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    res = subprocess.run(
        [sys.executable, os.path.join(root, "scripts",
                                      "check_no_raw_clock.py")],
        capture_output=True, text=True, cwd=root)
    assert res.returncode == 0, res.stdout + res.stderr
