"""Synthetic data + pipeline: (seed, step) determinism, prefetch, planted
structure (class signal / anomaly manifold / token predictability)."""

import numpy as np
import pytest

from repro.data.pipeline import DataPipeline
from repro.data.synthetic import (
    SyntheticImages,
    SyntheticMelWindows,
    SyntheticMFCC,
    SyntheticTokens,
)


def test_tokens_deterministic_by_step():
    d = SyntheticTokens(vocab=100, seq_len=16, seed=3)
    a = d.batch(step=5, batch_size=4)
    b = d.batch(step=5, batch_size=4)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = d.batch(step=6, batch_size=4)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_tokens_have_bigram_signal():
    """Planted next = (prev*7+3) % vocab with p=0.5. Because the planted
    value is computed from the pre-replacement stream, positions whose
    predecessor was itself replaced don't match the rule from the *final*
    stream — the measurable hit rate is ~p^2 + chance ≈ 0.27, still far
    above the ~2% chance level and learnable."""
    d = SyntheticTokens(vocab=50, seq_len=128, seed=0)
    b = d.batch(0, 32)
    pred = (b["tokens"][:, :-1] * 7 + 3) % 50
    hit = (b["tokens"][:, 1:] == pred).mean()
    assert 0.15 < hit < 0.7
    assert hit > 5 * (1.0 / 50)          # way above chance


def test_labels_are_next_tokens():
    d = SyntheticTokens(vocab=64, seq_len=8)
    b = d.batch(0, 2)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_images_shapes_and_classes():
    d = SyntheticImages()
    x, y = d.batch(0, 8)
    assert x.shape == (8, 32, 32, 3) and y.shape == (8,)
    assert x.dtype == np.float32 and np.abs(x).max() <= 1.0 + 1e-6


def test_images_class_separability():
    """Same-class images correlate more than cross-class ones."""
    d = SyntheticImages(seed=1)
    x, y = d.batch(0, 64)
    flat = x.reshape(64, -1)
    flat = flat - flat.mean(1, keepdims=True)
    flat /= np.linalg.norm(flat, axis=1, keepdims=True)
    sim = flat @ flat.T
    same = sim[y[:, None] == y[None, :]].mean()
    diff = sim[y[:, None] != y[None, :]].mean()
    assert same > diff + 0.1


def test_mel_anomalies_off_manifold():
    d = SyntheticMelWindows(seed=0)
    x, y = d.batch(0, 200, anomaly_frac=0.3)
    basis = d._basis()
    resid = x - (x @ basis) @ basis.T
    r = np.linalg.norm(resid, axis=1)
    assert r[y == 1].mean() > 2.0 * r[y == 0].mean()


def test_mfcc_class_imbalance():
    d = SyntheticMFCC(seed=0)
    _, y = d.batch(0, 4000)
    counts = np.bincount(y, minlength=12)
    assert counts[11] > 8 * np.median(counts[:11])   # ~17x unknown boost
    _, yb = d.batch(0, 4000, balanced=True)
    cb = np.bincount(yb, minlength=12)
    assert cb.max() < 3 * cb.min()


def test_pipeline_prefetch_order_and_close():
    d = SyntheticTokens(vocab=10, seq_len=4)
    with DataPipeline(lambda s: d.batch(s, 2), start_step=0) as pipe:
        steps = [next(pipe)[0] for _ in range(5)]
    assert steps == [0, 1, 2, 3, 4]


def test_pipeline_resume_from_step():
    d = SyntheticTokens(vocab=10, seq_len=4)
    with DataPipeline(lambda s: d.batch(s, 2), start_step=7) as pipe:
        step, batch = next(pipe)
    assert step == 7
    np.testing.assert_array_equal(batch["tokens"], d.batch(7, 2)["tokens"])
