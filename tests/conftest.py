"""Suite-wide configuration: the ``slow`` marker and the ``--fast`` toggle.

The full suite trains several tiny models with QAT and takes >5 min on CPU.
``pytest --fast`` (or ``REPRO_FAST=1``) skips everything marked
``@pytest.mark.slow`` so tier-1 verification stays quick:

    PYTHONPATH=src python -m pytest -q --fast
"""

from __future__ import annotations

import os
import sys

import pytest

# make `from _hypothesis_compat import ...` work regardless of rootdir layout
sys.path.insert(0, os.path.dirname(__file__))


def pytest_addoption(parser):
    parser.addoption(
        "--fast",
        action="store_true",
        default=False,
        help="skip tests marked slow (QAT training, long property sweeps)",
    )


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: takes >10s on CPU (training loops, big sweeps)"
    )


def pytest_collection_modifyitems(config, items):
    fast = config.getoption("--fast") or os.environ.get("REPRO_FAST", "") not in (
        "",
        "0",
    )
    if not fast:
        return
    skip = pytest.mark.skip(reason="skipped by --fast / REPRO_FAST=1")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip)
