"""Logical-axis sharding rules: rule resolution, mesh-axis filtering,
arch-specific fit rules (the qwen1.5 20-heads case), and no-mesh identity."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import get_config
from repro.parallel.sharding import (
    LOGICAL_RULES,
    active_mesh,
    batch_axes,
    logical_to_spec,
    mesh_axis_size,
    model_axes,
    shard,
    use_mesh_rules,
)


def _mesh1():
    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))


def test_shard_identity_without_mesh():
    x = jnp.ones((4, 4))
    assert shard(x, ("batch", None)) is x


def test_logical_to_spec_default_rules():
    with use_mesh_rules(None):
        assert logical_to_spec(("batch", None, "mlp")) == P(("pod", "data"), None, "model")
        assert logical_to_spec((None, "vocab")) == P(None, "model")
        assert logical_to_spec(("nonexistent",)) == P(None)


def test_mesh_filters_missing_axes():
    """Rules referencing 'pod' collapse on a single-pod mesh."""
    with use_mesh_rules(_mesh1()):
        assert logical_to_spec(("batch",)) == P("data")     # pod dropped
        assert mesh_axis_size("data") == 1
        assert mesh_axis_size("pod") == 1                   # absent -> 1
        assert batch_axes() == ("data",)
        assert model_axes() == ("model",)


def test_rules_override_and_restore():
    with use_mesh_rules(_mesh1(), {"seq": ("model",)}):
        assert logical_to_spec(("seq",)) == P("model")
        assert active_mesh() is not None
    assert active_mesh() is None
    assert LOGICAL_RULES["seq"] is None                     # global untouched


def test_shard_with_mesh_applies_constraint():
    with use_mesh_rules(_mesh1()):
        y = shard(jnp.ones((4, 8)), ("batch", "mlp"))
        assert y.shape == (4, 8)                            # constraint is a no-op on 1 dev


def test_arch_rules_head_divisibility():
    """qwen1.5 (20 heads) can't shard heads over a 16-way model axis; the
    dry-run's arch_rules must fall back to replicated heads but keep d_ff TP."""
    from repro.launch.dryrun import arch_rules

    class FakeMesh:
        shape = {"model": 16, "data": 16}
        axis_names = ("data", "model")

    cfg = get_config("qwen1.5-4b")
    rules = arch_rules(cfg, FakeMesh(), ("data",))
    assert rules["heads"] is None                  # 20 % 16 != 0
    assert rules["kv_heads"] is None               # 20 kv heads
    assert rules["mlp"] == ("model",)              # 6912 % 16 == 0
    assert rules["vocab"] == ("model",)

    cfg2 = get_config("llama3-8b")
    rules2 = arch_rules(cfg2, FakeMesh(), ("data",))
    assert rules2["heads"] == ("model",)           # 32 % 16 == 0


def test_param_specs_resolve_for_every_arch():
    """Every arch's spec tree must be constructible under both meshes."""
    from repro.configs import ARCH_IDS
    from repro.models.model import Model

    with use_mesh_rules(_mesh1()):
        for arch in ARCH_IDS:
            cfg = get_config(arch).reduced()
            specs = Model(cfg).param_specs()
            for leaf in jax.tree.leaves(
                    specs, is_leaf=lambda x: isinstance(x, P)):
                assert isinstance(leaf, P)
