"""FIFO-depth optimization (paper §3.1.2): discrete-event pipeline simulation
and the shrink-to-max+1 pass."""

import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core.dataflow import (
    BIG_DEPTH,
    Stage,
    conv_pipeline_stages,
    mlp_pipeline_stages,
    optimize_fifo_depths,
    prefetch_depth,
    simulate_pipeline,
)


def test_single_stage_throughput():
    stages = [Stage("s0", ii=1, latency=1, elems_in=1, elems_out=1)]
    cycles, occ = simulate_pipeline(stages, 100, [BIG_DEPTH, BIG_DEPTH])
    assert cycles <= 110          # ~1 token/cycle + pipeline fill
    assert occ[0] <= 2            # never queues up with matched rates


def test_rate_mismatch_accumulates_in_fifo():
    """A slow consumer (ii=4) behind a fast producer backs tokens up."""
    stages = [
        Stage("fast", ii=1, latency=1),
        Stage("slow", ii=4, latency=4),
    ]
    cycles, occ = simulate_pipeline(stages, 64, [BIG_DEPTH] * 3)
    assert occ[1] > 10            # inter-stage FIFO filled substantially


def test_optimize_preserves_throughput():
    stages = mlp_pipeline_stages([128, 72, 72, 8, 72, 72, 128], reuse_factor=4)
    res = optimize_fifo_depths(stages, n_tokens=128 * 4)
    assert res["throughput_preserved"]
    assert res["optimized_cycles"] <= res["baseline_cycles"]
    assert res["total_buffer_elems"] < BIG_DEPTH


def test_optimized_depths_are_max_occupancy_plus_one():
    stages = [Stage("a", ii=1, latency=2), Stage("b", ii=3, latency=3)]
    _, occ = simulate_pipeline(stages, 32, [BIG_DEPTH] * 3)
    res = optimize_fifo_depths(stages, 32)
    assert res["optimized_depths"] == [m + 1 for m in occ]


def test_reuse_factor_raises_latency():
    """Paper §3.3.2: higher RF = fewer parallel multipliers = longer latency."""
    t1 = optimize_fifo_depths(mlp_pipeline_stages([64, 32, 8], 1), 64)
    t8 = optimize_fifo_depths(mlp_pipeline_stages([64, 32, 8], 8), 64)
    assert t8["optimized_cycles"] > t1["optimized_cycles"]


def test_rate_conversion_elems():
    """A 4->1 downsampler stage consumes 4 tokens per output."""
    stages = [Stage("down", ii=1, latency=1, elems_in=4, elems_out=1)]
    cycles, _ = simulate_pipeline(stages, 64, [BIG_DEPTH, BIG_DEPTH])
    assert cycles >= 64           # bounded by input feed rate


def test_conv_pipeline_builder():
    stages = conv_pipeline_stages([(9, 3, 1, 2), (3, 1, 2, 4)])
    assert len(stages) == 2 and stages[1].ii == 2


@settings(max_examples=20, deadline=None)
@given(
    st.lists(st.tuples(st.integers(1, 4), st.integers(1, 6)), min_size=1,
             max_size=4),
    st.integers(8, 64),
)
def test_property_shrunk_fifos_never_regress(stage_params, n_tokens):
    """Property (the paper's claim): depth = max_occupancy + 1 loses zero
    throughput vs unbounded FIFOs, for any linear pipeline."""
    stages = [Stage(f"s{i}", ii=ii, latency=lat)
              for i, (ii, lat) in enumerate(stage_params)]
    res = optimize_fifo_depths(stages, n_tokens)
    assert res["optimized_cycles"] <= res["baseline_cycles"]


def test_prefetch_depth_scales_with_rate_ratio():
    assert prefetch_depth(0.001, 0.01) == 3        # fast producer: small buffer
    assert prefetch_depth(0.02, 0.01) >= 4         # slow producer: deeper buffer


def test_deadlock_detection():
    """A stage needing more input tokens than its FIFO can hold deadlocks;
    the simulator must detect it rather than spin forever."""
    stages = [Stage("s", ii=1, latency=1, elems_in=20, elems_out=1)]
    with pytest.raises(RuntimeError):
        simulate_pipeline(stages, 30, [5, 5], max_cycles=10_000)
