"""repro.deploy: QIR -> compiled executor parity and scenario runtime.

The contract under test is the paper's: streamlining/fusion is *exact* —
the compiled integer dataflow executor must produce bit-identical integer
activations to the streamlined float reference (half-up rounding semantics,
core/streamline.py) for the Table-1 MLP models, in every execution mode
(offline jit program, FIFO-sized streaming pipeline, Pallas kernel path).
"""

import copy

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.bops import schedule_cost
from repro.core.qir import Graph, Node, QuantSpec, export_qcnn, export_qmlp
from repro.core.streamline import (
    float_ref_dense,
    multi_threshold,
    multi_threshold_sorted,
)
from repro.deploy import (
    CompiledJaxModel,
    FlattenStage,
    FloatHeadStage,
    FusedConvThresholdStage,
    FusedThresholdStage,
    IntPoolStage,
    RefChainStage,
    compile_graph,
    lower_graph,
)
from repro.deploy.scenarios import (
    offline,
    run_all_scenarios,
    server_poisson,
    single_stream,
)
from repro.models.tiny import ADAutoencoder, CNVModel, ICModel, KWSMLP
from repro.serving.engine import TinyModelServer

IN_SCALE = 1.0 / 127.0


def _export(model, key=0):
    params = model.init(jax.random.PRNGKey(key))
    hidden_defs, _ = model.layers()
    graph = export_qmlp(hidden_defs, params["hidden"], params["head"],
                        meta={"model": type(model).__name__})
    return graph, params, hidden_defs


def _float_ref_chain(graph_model, x_int, hidden_defs, params, schedule):
    """Stage-by-stage streamlined float reference (the streamline.py oracle)."""
    h = x_int
    scale = IN_SCALE
    fused = [s for s in schedule.stages if isinstance(s, FusedThresholdStage)]
    for ld, p, st in zip(hidden_defs, params["hidden"], fused):
        h = float_ref_dense(p, h.astype(jnp.float32) * scale,
                            weight_bits=ld.weight_bits, act_bits=ld.act_bits,
                            s_out=st.stage.out_scale)
        scale = st.stage.out_scale
    logits = (h.astype(jnp.float32) @ params["head"]["w"] * scale
              + params["head"]["b"])
    return h, logits


@pytest.mark.parametrize("model_cls,in_dim", [(KWSMLP, 490),
                                              (ADAutoencoder, 128)])
def test_compiled_executor_matches_streamlined_float_reference(model_cls, in_dim):
    """Tentpole parity: compiled integer outputs == streamlined float ref,
    exactly, for both Table-1 MLP models."""
    model = model_cls()
    graph, params, hidden_defs = _export(model)
    cm = compile_graph(graph, in_scale=IN_SCALE, use_pallas=False)

    x_int = jnp.asarray(
        np.random.default_rng(0).integers(-127, 128, (16, in_dim)), jnp.int32)
    outs = cm.stage_outputs(x_int)
    ref_last_int, ref_logits = _float_ref_chain(model, x_int, hidden_defs,
                                                params, cm.schedule)
    # integer activations out of the last fused stage are bit-exact
    np.testing.assert_array_equal(np.asarray(outs[-2]),
                                  np.asarray(ref_last_int))
    np.testing.assert_allclose(np.asarray(outs[-1]), np.asarray(ref_logits),
                               rtol=1e-5, atol=1e-5)


def test_lowering_structure_kws():
    model = KWSMLP()
    graph, _, _ = _export(model)
    schedule = lower_graph(graph, in_scale=IN_SCALE)
    kinds = [type(s).__name__ for s in schedule.stages]
    assert kinds == ["FusedThresholdStage"] * 3 + ["FloatHeadStage"]
    assert schedule.layer_dims() == [490, 256, 256, 256, 12]
    assert schedule.n_fused == 3
    assert "stages" in schedule.describe()


def test_streaming_matches_offline_and_uses_fifo_depths():
    model = ADAutoencoder()
    graph, _, _ = _export(model)
    cm = compile_graph(graph, in_scale=IN_SCALE, use_pallas=False)
    x_int = jnp.asarray(
        np.random.default_rng(1).integers(-127, 128, (40, 128)), jnp.int32)
    y_off = cm.offline(x_int)
    y_str, stats = cm.streaming(x_int, micro_batch=8)
    np.testing.assert_array_equal(np.asarray(y_off), np.asarray(y_str))
    assert stats.n_micro == 5
    assert len(stats.fifo_depths) == len(cm.schedule.stages) + 1
    assert all(d >= 1 for d in stats.fifo_depths)
    # the pipeline respected the optimizer's capacities
    assert all(o <= d for o, d in zip(stats.max_occupancy, stats.fifo_depths))


def test_pallas_kernel_path_matches_reference_path():
    """use_pallas=True (interpret mode on CPU) produces the same integers."""
    model = KWSMLP(width=32)  # small so interpret mode stays fast
    graph, _, _ = _export(model)
    cm_ref = compile_graph(graph, in_scale=IN_SCALE, use_pallas=False)
    cm_pl = compile_graph(graph, in_scale=IN_SCALE, use_pallas=True,
                          interpret=True)
    x_int = jnp.asarray(
        np.random.default_rng(2).integers(-127, 128, (8, 490)), jnp.int32)
    np.testing.assert_allclose(np.asarray(cm_ref.offline(x_int)),
                               np.asarray(cm_pl.offline(x_int)),
                               rtol=1e-5, atol=1e-5)


def test_pallas_path_handles_unsigned_8bit_codes():
    """Regression: inter-stage codes are unsigned in [0, 255] at 8-bit
    activations; the kernel path must not wrap them through an int8 cast."""
    from repro.core.streamline import streamline_dense
    from repro.deploy.lower import FusedThresholdStage

    rng = np.random.default_rng(8)
    params = {"w": jnp.asarray(rng.standard_normal((12, 8)) * 0.2,
                               jnp.float32),
              "b": jnp.zeros((8,), jnp.float32)}
    td = streamline_dense(params, weight_bits=8, act_bits=8, in_scale=0.01)
    st = FusedThresholdStage(name="s", stage=td, in_dim=12, out_dim=8,
                             in_scale=0.01)
    x_int = jnp.asarray(rng.integers(0, 256, (8, 12)), jnp.int32)  # codes >127
    np.testing.assert_array_equal(
        np.asarray(st.apply_kernel(x_int, interpret=True)),
        np.asarray(st.apply_ref(x_int)))


def test_fan_out_intermediate_blocks_fusion_but_still_runs():
    """Regression: a fused chain whose intermediate value has a second
    consumer must not be fused away (the reader would dangle)."""
    rng = np.random.default_rng(9)
    w = rng.standard_normal((6, 4)).astype(np.float32)
    g = Graph(inputs=["x"], outputs=["y2"],
              initializers={"w": w, "b": np.zeros((4,), np.float32),
                            "m": np.full((4,), 0.5, np.float32)})
    from repro.core.qir import QuantSpec
    g.nodes = [
        Node("Dense", "d0", ["x", "w", "b"], ["h0"]),
        Node("Relu", "r0", ["h0"], ["h1"]),
        Node("Quant", "q0", ["h1"], ["h2"], quant=QuantSpec(bits=4)),
        Node("Mul", "m0", ["h0", "m"], ["y2"]),   # second consumer of h0
    ]
    cm = compile_graph(g, in_scale=0.1, use_pallas=False)
    assert not any(isinstance(s, FusedThresholdStage) for s in cm.schedule.stages)
    x_int = jnp.asarray(rng.integers(-7, 8, (3, 6)), jnp.int32)
    y = cm.offline(x_int)
    expect = ((np.asarray(x_int, np.float32) * 0.1) @ w) * 0.5
    np.testing.assert_allclose(np.asarray(y), expect, rtol=1e-5, atol=1e-6)


def test_multi_threshold_sorted_equals_reference():
    rng = np.random.default_rng(3)
    acc = jnp.asarray(rng.integers(-10_000, 10_000, (13, 7)), jnp.int32)
    thr = jnp.asarray(np.sort(rng.integers(-9_000, 9_000, (7, 255)), axis=1),
                      jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(multi_threshold_sorted(acc, thr)),
        np.asarray(multi_threshold(acc, thr)))
    # duplicate thresholds stay exact
    thr_dup = jnp.asarray(np.sort(rng.integers(-3, 3, (7, 31)), axis=1),
                          jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(multi_threshold_sorted(acc, thr_dup)),
        np.asarray(multi_threshold(acc, thr_dup)))


def test_unsupported_graph_falls_back_to_ref_chain():
    """A graph the matcher can't fuse still compiles and runs (float path)."""
    w = np.random.default_rng(4).standard_normal((6, 4)).astype(np.float32)
    g = Graph(inputs=["x"], outputs=["y"],
              initializers={"w": w, "m": np.full((4,), 2.0, np.float32)})
    g.nodes = [
        Node("Dense", "d0", ["x", "w"], ["h0"]),
        Node("Mul", "m0", ["h0", "m"], ["y"]),   # Mul breaks the fused pattern
    ]
    cm = compile_graph(g, in_scale=0.1, use_pallas=False)
    assert any(isinstance(s, RefChainStage) for s in cm.schedule.stages)
    x_int = jnp.asarray(
        np.random.default_rng(5).integers(-7, 8, (3, 6)), jnp.int32)
    y = cm.offline(x_int)
    expect = (np.asarray(x_int, np.float32) * 0.1) @ w * 2.0
    np.testing.assert_allclose(np.asarray(y), expect, rtol=1e-5, atol=1e-6)


def test_qir_roundtrip_preserves_compiled_outputs():
    """save -> load -> compile gives the same executor (weight_bits attrs
    survive serialization)."""
    model = KWSMLP(width=32)
    graph, _, _ = _export(model)
    graph2 = Graph.from_json(graph.to_json())
    cm1 = compile_graph(graph, in_scale=IN_SCALE, use_pallas=False)
    cm2 = compile_graph(graph2, in_scale=IN_SCALE, use_pallas=False)
    x_int = jnp.asarray(
        np.random.default_rng(6).integers(-127, 128, (4, 490)), jnp.int32)
    np.testing.assert_array_equal(np.asarray(cm1.offline(x_int)),
                                  np.asarray(cm2.offline(x_int)))


# ---------------------------------------------------------------------------
# conv schedules (export_qcnn -> im2col fused lowering)
# ---------------------------------------------------------------------------

def _export_ic(rng, in_hw=16):
    model = ICModel(in_hw=in_hw)
    params = model.init(jax.random.PRNGKey(3))
    cal = rng.integers(-127, 128, (8, in_hw, in_hw, 3)).astype(np.int32)
    graph = export_qcnn(model, params, calibrate=cal)
    return model, params, graph


def _export_cnv(rng):
    model = CNVModel(channels=(8, 8, 16, 16, 32, 32), fc=(32, 32))
    params = model.init(jax.random.PRNGKey(4))
    return model, params, export_qcnn(model, params)


def test_ic_conv_schedule_fuses_and_is_bit_exact_vs_graph_run():
    """Tentpole parity (IC): every conv chain fuses, and the compiled
    integer stages reproduce the unfused QIR ``Graph.run`` reference bit for
    bit — guaranteed by the exporter's po2-grid contract, ties included."""
    rng = np.random.default_rng(20)
    model, params, graph = _export_ic(rng)
    cm = compile_graph(graph, in_scale=graph.meta["in_scale"],
                       use_pallas=False)
    assert cm.schedule.n_fused_conv == len(model.filters)
    kinds = [type(s).__name__ for s in cm.schedule.stages]
    assert kinds == (["FusedConvThresholdStage"] * 5
                     + ["FlattenStage", "FloatHeadStage"])

    x = jnp.asarray(rng.integers(-127, 128, (8, 16, 16, 3)), jnp.int32)
    # intermediate integer codes vs the per-node interpreter
    quant_outs = [n.outputs[0] for n in graph.nodes if n.op == "Quant"]
    probe = copy.deepcopy(graph)
    probe.outputs = list(graph.outputs) + quant_outs
    run = probe.run({"x": np.asarray(x, np.float32) * graph.meta["in_scale"]})
    k = 0
    for s, o in zip(cm.schedule.stages, cm.stage_outputs(x)):
        if isinstance(s, FusedConvThresholdStage):
            np.testing.assert_array_equal(
                np.asarray(o) * s.stage.out_scale, run[quant_outs[k]])
            k += 1
    np.testing.assert_allclose(np.asarray(cm.offline(x)), run["logits"],
                               rtol=1e-5, atol=1e-5)
    # decisions match the float reference and the training-time forward
    logits = np.asarray(cm.offline(x))
    assert (np.argmax(logits, -1) == np.argmax(run["logits"], -1)).all()
    mlog = np.asarray(model.apply(
        params, np.asarray(x, np.float32) * graph.meta["in_scale"],
        train=False))
    assert (np.argmax(mlog, -1) == np.argmax(logits, -1)).mean() >= 0.75


def test_cnv_conv_schedule_bit_exact_and_matches_sign_forward():
    """Tentpole parity (CNV): the bipolar export is exactly streamlinable —
    compiled logits equal both the unfused ``Graph.run`` and a pure-sign
    binary forward of the model weights, bit for bit (integer float32
    arithmetic is exact below 2^24)."""
    rng = np.random.default_rng(21)
    model, params, graph = _export_cnv(rng)
    cm = compile_graph(graph, in_scale=graph.meta["in_scale"],
                       use_pallas=False)
    assert cm.schedule.n_fused_conv == len(model.channels)
    assert sum(isinstance(s, IntPoolStage)
               for s in cm.schedule.stages) == len(model.pool_after)
    assert sum(isinstance(s, FlattenStage) for s in cm.schedule.stages) == 1

    x = jnp.asarray(rng.integers(-127, 128, (4, 32, 32, 3)), jnp.int32)
    logits = np.asarray(cm.offline(x))
    run = graph.run({"x": np.asarray(x, np.float32)})["logits"]
    np.testing.assert_array_equal(logits, np.asarray(run))

    # pure-sign forward: sign weights, sign activations, no fake-quant
    h = jnp.asarray(x, jnp.float32)
    for i, p in enumerate(params["convs"]):
        w = jnp.where(p["w"] >= 0, 1.0, -1.0)
        h = jax.lax.conv_general_dilated(
            h, w, (1, 1), "VALID", dimension_numbers=("NHWC", "HWIO", "NHWC"))
        h = jnp.where(h >= 0, 1.0, -1.0)
        if i in model.pool_after:
            h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max,
                                      (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
    h = h.reshape(h.shape[0], -1)
    for j, p in enumerate(params["fcs"]):
        h = h @ jnp.where(p["w"] >= 0, 1.0, -1.0)
        if j < len(params["fcs"]) - 1:
            h = jnp.where(h >= 0, 1.0, -1.0)
    np.testing.assert_array_equal(logits, np.asarray(h))


@pytest.mark.parametrize("maker", [_export_ic, _export_cnv])
def test_streaming_matches_offline_on_conv_schedules(maker):
    """Offline-vs-streaming bit-exactness for conv schedules: the FIFO-sized
    micro-batched pipeline must produce the same integers as the single jit
    program."""
    rng = np.random.default_rng(22)
    model, _, graph = maker(rng)
    cm = compile_graph(graph, in_scale=graph.meta["in_scale"],
                       use_pallas=False)
    hw = model.in_hw
    x = jnp.asarray(rng.integers(-127, 128, (6, hw, hw, 3)), jnp.int32)
    y_off = cm.offline(x)
    y_str, stats = cm.streaming(x, micro_batch=2)
    np.testing.assert_array_equal(np.asarray(y_off), np.asarray(y_str))
    assert len(stats.fifo_depths) == len(cm.schedule.stages) + 1
    assert all(o <= d for o, d in zip(stats.max_occupancy, stats.fifo_depths))


def test_conv_pallas_kernel_path_matches_fast_path():
    """use_pallas=True (interpret mode on CPU) runs the im2col matrix through
    the fused threshold_matmul kernel and must produce the same integers."""
    rng = np.random.default_rng(23)
    model = ICModel(in_hw=8, filters=(4, 4), kernels=(3, 3), strides=(1, 2))
    params = model.init(jax.random.PRNGKey(5))
    cal = rng.integers(-127, 128, (4, 8, 8, 3)).astype(np.int32)
    graph = export_qcnn(model, params, calibrate=cal)
    cm_ref = compile_graph(graph, in_scale=graph.meta["in_scale"],
                           use_pallas=False)
    cm_pl = compile_graph(graph, in_scale=graph.meta["in_scale"],
                          use_pallas=True, interpret=True)
    x = jnp.asarray(rng.integers(-127, 128, (2, 8, 8, 3)), jnp.int32)
    np.testing.assert_allclose(np.asarray(cm_ref.offline(x)),
                               np.asarray(cm_pl.offline(x)),
                               rtol=1e-5, atol=1e-5)


def test_conv_bn_chain_fuses_and_matches_reference():
    """A float-weight Conv2D -> BatchNorm -> Relu -> Quant graph (no export
    metadata) still fuses: BN folds into the conv kernel per channel."""
    rng = np.random.default_rng(24)
    w = rng.standard_normal((3, 3, 2, 4)).astype(np.float32) * 0.3
    g = Graph(inputs=["x"], outputs=["y"], initializers={
        "w": w, "b": np.zeros((4,), np.float32),
        "gamma": rng.uniform(0.5, 1.5, (4,)).astype(np.float32),
        "beta": rng.standard_normal((4,)).astype(np.float32) * 0.1,
        "mu": rng.standard_normal((4,)).astype(np.float32) * 0.1,
        "sigma2": rng.uniform(0.5, 2.0, (4,)).astype(np.float32),
    })
    g.nodes = [
        Node("Conv2D", "c0", ["x", "w", "b"], ["h0"],
             attrs={"kernel": 3, "stride": 1, "padding": "SAME",
                    "weight_bits": 8,
                    "in_shape": [6, 6, 2], "out_shape": [6, 6, 4]}),
        Node("BatchNorm", "bn0", ["h0", "gamma", "beta", "mu", "sigma2"],
             ["h1"]),
        Node("Relu", "r0", ["h1"], ["h2"]),
        Node("Quant", "q0", ["h2"], ["y"], quant=QuantSpec(bits=4)),
    ]
    cm = compile_graph(g, in_scale=0.05, use_pallas=False)
    assert isinstance(cm.schedule.stages[0], FusedConvThresholdStage)
    x = jnp.asarray(rng.integers(-7, 8, (3, 6, 6, 2)), jnp.int32)
    y = np.asarray(cm.offline(x))
    assert y.shape == (3, 6, 6, 4)
    assert y.min() >= 0 and y.max() <= 15
    # exactness against the streamlined oracle (apply_ref == apply_fast)
    s = cm.schedule.stages[0]
    np.testing.assert_array_equal(np.asarray(s.apply_ref(x)), y)


def test_conv_schedule_fifo_work_uses_output_tiles():
    """Conv stages report im2col work (out tiles x patch), not in*out."""
    rng = np.random.default_rng(25)
    _, _, graph = _export_cnv(rng)
    cm = compile_graph(graph, in_scale=1.0, use_pallas=False)
    conv0 = cm.schedule.stages[0]
    assert isinstance(conv0, FusedConvThresholdStage)
    g = conv0.geom
    assert conv0.macs == g.out_h * g.out_w * 9 * g.in_ch * g.out_ch
    assert conv0.macs != conv0.in_dim * conv0.out_dim
    depths, cycles = cm.plan_streaming(4)
    assert len(depths) == len(cm.schedule.stages) + 1 and cycles > 0


def test_schedule_cost_covers_conv_stages():
    """bops.schedule_cost prices fused conv stages via Eq. 1 conv BOPs."""
    rng = np.random.default_rng(26)
    model, _, graph = _export_ic(rng, in_hw=8)
    cm = compile_graph(graph, in_scale=graph.meta["in_scale"],
                       use_pallas=False)
    cost = schedule_cost(cm.schedule.stages)
    conv_layers = [l for l in cost.layers if l.name.startswith("conv")]
    assert len(conv_layers) == cm.schedule.n_fused_conv
    assert all(l.bops > 0 for l in conv_layers)
    # pool/flatten stages carry no MACs
    flat = [l for l in cost.layers if l.name == "flatten"]
    assert flat and flat[0].bops == 0
    assert cost.bops > 0 and cost.wm_bits > 0


def test_scenario_reports_carry_stage_breakdown():
    rng = np.random.default_rng(27)
    _, _, graph = _export_ic(rng, in_hw=8)
    cm = compile_graph(graph, in_scale=graph.meta["in_scale"],
                       use_pallas=False)
    mk = lambda i: rng.integers(-127, 128, (8, 8, 3)).astype(np.int32)
    rep = offline(cm.offline, mk, n_samples=4, warmup=1, compiled=cm)
    assert rep.stage_ms is not None
    assert [s["stage"] for s in rep.stage_ms] == \
        [s.name for s in cm.schedule.stages]
    assert all(s["ms"] >= 0 for s in rep.stage_ms)
    assert "stage_ms" in rep.row()


# ---------------------------------------------------------------------------
# scenario runtime
# ---------------------------------------------------------------------------

def _tiny_compiled():
    model = KWSMLP(width=32)
    graph, _, _ = _export(model)
    return compile_graph(graph, in_scale=IN_SCALE, use_pallas=False)


def test_single_stream_and_offline_reports():
    cm = _tiny_compiled()
    mk = lambda i: np.random.default_rng(i).integers(
        -127, 128, (490,)).astype(np.int32)
    ss = single_stream(cm.offline, mk, n_queries=8, warmup=1,
                       model_cost=KWSMLP(width=32).cost(), bits=3)
    assert ss.scenario == "SingleStream" and ss.n_queries == 8
    assert 0 < ss.p50_ms <= ss.p99_ms
    assert ss.energy_proxy_uJ is not None and ss.energy_proxy_uJ > 0
    off = offline(cm.offline, mk, n_samples=32, warmup=1)
    assert off.throughput_qps > 0 and off.extras["batch"] == 32
    d = off.row()
    assert d["scenario"] == "Offline" and d["qps"] > 0


def test_server_poisson_latency_includes_queueing():
    cm = _tiny_compiled()
    mk = lambda i: np.zeros((490,), np.int32)
    rep = server_poisson(cm.offline, mk, qps=500.0, n_queries=16, warmup=1)
    assert rep.scenario == "Server" and rep.n_queries == 16
    assert rep.p99_ms >= rep.p50_ms > 0


@pytest.mark.slow
def test_run_all_scenarios_sweep():
    cm = _tiny_compiled()
    mk = lambda i: np.zeros((490,), np.int32)
    reports = run_all_scenarios(cm.offline, mk, n_queries=8, n_streams=4,
                                offline_samples=16, server_qps=500.0)
    assert [r.scenario for r in reports] == [
        "SingleStream", "MultiStream", "Offline", "Server"]


# ---------------------------------------------------------------------------
# multi-tenant serving integration
# ---------------------------------------------------------------------------

def test_tiny_model_server_multi_tenant():
    kws = _tiny_compiled()
    ad_model = ADAutoencoder(width=24)
    graph, _, _ = _export(ad_model)
    ad = compile_graph(graph, in_scale=IN_SCALE, use_pallas=False)

    server = TinyModelServer({"kws": kws, "ad": ad}, max_batch=4)
    rng = np.random.default_rng(7)
    for i in range(10):
        name = "kws" if i % 2 == 0 else "ad"
        dim = 490 if name == "kws" else 128
        server.submit(name, rng.integers(-127, 128, (dim,)).astype(np.int32))
    steps = server.run_until_drained()
    assert steps >= 2          # max_batch=4 forces multiple engine steps
    assert len(server.finished) == 10
    st = server.stats()
    assert st["kws"]["n"] == 5 and st["ad"]["n"] == 5
    assert st["_aggregate"]["throughput_qps"] > 0
    # results landed on the right requests
    for r in server.finished:
        assert r.result is not None
        assert r.result.shape == ((12,) if r.model == "kws" else (128,))
    with pytest.raises(KeyError):
        server.submit("nope", np.zeros((4,), np.int32))


def test_compiled_jax_model_wrapper():
    def fwd(p, x):
        return x @ p["w"]

    p = {"w": jnp.ones((4, 2))}
    cm = CompiledJaxModel(fwd, p, name="toy")
    x = jnp.ones((3, 4))
    np.testing.assert_array_equal(np.asarray(cm.offline(x)),
                                  np.asarray(cm.reference(x)))
    assert cm.predict(x).shape == (3,)
