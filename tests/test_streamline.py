"""Streamlining exactness: the integer multi-threshold deployment graph must
agree with the float QAT reference everywhere (paper C2 — FINN's streamlining
is exact, not approximate)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core.qlayers import QDense, QDenseBatchNorm
from repro.core.quantizers import IntQuantizer
from repro.core.streamline import (
    StreamlinedMLP,
    apply_threshold_dense,
    float_ref_dense,
    multi_threshold,
    quant_act_ref,
    streamline_dense,
    streamline_mlp,
)


def _random_bn_params(key, in_dim, out_dim):
    ks = jax.random.split(key, 6)
    return {
        "w": jax.random.normal(ks[0], (in_dim, out_dim)) * (in_dim ** -0.5),
        "b": jax.random.normal(ks[1], (out_dim,)) * 0.1,
        "gamma": jax.random.normal(ks[2], (out_dim,)) * 0.2 + 1.0,
        "beta": jax.random.normal(ks[3], (out_dim,)) * 0.1,
        "mu": jax.random.normal(ks[4], (out_dim,)) * 0.1,
        "sigma2": jax.nn.softplus(jax.random.normal(ks[5], (out_dim,))) + 0.5,
    }


@pytest.mark.parametrize("seed", range(4))
@pytest.mark.parametrize("bits", [3, 4, 8])
def test_threshold_stage_matches_float_reference(seed, bits):
    """Integer thresholds reproduce fold->quantW->matmul->ReLU->quantA exactly."""
    in_dim, out_dim = 24, 16
    params = _random_bn_params(jax.random.PRNGKey(seed), in_dim, out_dim)
    in_scale = 0.05
    stage = streamline_dense(params, weight_bits=bits, act_bits=bits,
                             in_scale=in_scale)

    in_qmax = 2 ** (bits - 1) - 1
    x_int = jax.random.randint(jax.random.PRNGKey(seed + 100), (64, in_dim),
                               -in_qmax, in_qmax + 1)
    y_int = apply_threshold_dense(stage, x_int)
    y_ref = float_ref_dense(params, x_int.astype(jnp.float32) * in_scale,
                            weight_bits=bits, act_bits=bits,
                            s_out=stage.out_scale)
    np.testing.assert_array_equal(np.asarray(y_int), np.asarray(y_ref))


def test_thresholds_sorted_and_output_in_range():
    params = _random_bn_params(jax.random.PRNGKey(0), 16, 8)
    stage = streamline_dense(params, weight_bits=4, act_bits=4, in_scale=0.1)
    t = np.asarray(stage.thresholds)
    assert np.all(np.diff(t, axis=1) >= 0)          # monotone banks
    x_int = jax.random.randint(jax.random.PRNGKey(1), (32, 16), -7, 8)
    y = np.asarray(apply_threshold_dense(stage, x_int))
    assert y.min() >= 0 and y.max() <= stage.n_steps


def test_multi_threshold_reference_count_semantics():
    acc = jnp.asarray([[-5, 0, 10]]).astype(jnp.int32).T   # (3,1)
    thr = jnp.asarray([[-3, 2], [-3, 2], [-3, 2]]).astype(jnp.int32)
    out = np.asarray(multi_threshold(acc, thr))
    np.testing.assert_array_equal(out[:, 0], [0, 1, 2])


def test_quant_act_ref_half_up():
    # boundary 0.5 rounds UP (FINN convention), unlike jnp.round's half-even
    y = quant_act_ref(jnp.asarray([0.5, 1.5, 2.5]), 1.0, 7)
    np.testing.assert_array_equal(np.asarray(y), [1, 2, 3])


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([2, 3, 4, 6]))
def test_streamline_property_exact_for_random_stages(seed, bits):
    """Property: for any BN params and int inputs, thresholds == float ref."""
    params = _random_bn_params(jax.random.PRNGKey(seed), 8, 5)
    stage = streamline_dense(params, weight_bits=bits, act_bits=bits,
                             in_scale=0.07)
    in_qmax = 2 ** (bits - 1) - 1
    x_int = jax.random.randint(jax.random.PRNGKey(seed ^ 1234), (16, 8),
                               -in_qmax, in_qmax + 1)
    y_int = apply_threshold_dense(stage, x_int)
    y_ref = float_ref_dense(params, x_int.astype(jnp.float32) * 0.07,
                            weight_bits=bits, act_bits=bits,
                            s_out=stage.out_scale)
    np.testing.assert_array_equal(np.asarray(y_int), np.asarray(y_ref))


def test_streamlined_mlp_end_to_end_prediction_parity():
    """Full pipeline: streamlined integer MLP predicts the same classes as
    the float QAT forward for a trained-ish stack."""
    key = jax.random.PRNGKey(0)
    dims = [12, 10, 8]
    bits = 4
    layer_defs = [QDenseBatchNorm(dims[i], dims[i + 1], weight_bits=bits,
                                  act_bits=bits) for i in range(2)]
    params_list = [_random_bn_params(jax.random.fold_in(key, i), dims[i], dims[i + 1])
                   for i in range(2)]
    head = QDense(dims[-1], 4, weight_bits=32, act_bits=32)
    head_params = head.init(jax.random.PRNGKey(9))

    smlp = streamline_mlp(layer_defs, params_list, in_scale=0.05,
                          head_params=head_params)

    x_int = jax.random.randint(jax.random.PRNGKey(2), (32, 12), -7, 8)
    pred_int = np.asarray(smlp.predict(x_int))

    # float reference: stage-by-stage quantized forward
    h = x_int
    scale = 0.05
    for ld, p, st_ in zip(layer_defs, params_list, smlp.stages):
        h = float_ref_dense(p, h.astype(jnp.float32) * scale,
                            weight_bits=bits, act_bits=bits, s_out=st_.out_scale)
        scale = st_.out_scale
    logits = h.astype(jnp.float32) @ head_params["w"] * scale + head_params["b"]
    pred_ref = np.asarray(jnp.argmax(logits, axis=-1))
    np.testing.assert_array_equal(pred_int, pred_ref)


def test_streamline_plain_dense_no_bn():
    """QDense (no BN) also streamlines (fold is identity)."""
    key = jax.random.PRNGKey(3)
    params = {"w": jax.random.normal(key, (10, 6)) * 0.3,
              "b": jnp.zeros((6,))}
    stage = streamline_dense(params, weight_bits=4, act_bits=4, in_scale=0.1)
    x_int = jax.random.randint(jax.random.PRNGKey(4), (8, 10), -7, 8)
    y_int = apply_threshold_dense(stage, x_int)
    y_ref = float_ref_dense(params, x_int.astype(jnp.float32) * 0.1,
                            weight_bits=4, act_bits=4, s_out=stage.out_scale)
    np.testing.assert_array_equal(np.asarray(y_int), np.asarray(y_ref))


def test_streamlined_stage_runs_on_pallas_kernel():
    """The deployment stage executes on kernels.ops.threshold_matmul with
    identical integer outputs — QIR -> kernel parity."""
    from repro.kernels import ops

    params = _random_bn_params(jax.random.PRNGKey(5), 16, 8)
    stage = streamline_dense(params, weight_bits=4, act_bits=4, in_scale=0.05)
    x_int = jax.random.randint(jax.random.PRNGKey(6), (24, 16), -7, 8)
    y_graph = apply_threshold_dense(stage, x_int)
    y_kernel = ops.threshold_matmul(
        x_int.astype(jnp.int8), stage.w_int, stage.thresholds,
        block_m=8, block_n=8, block_k=8)
    np.testing.assert_array_equal(np.asarray(y_kernel), np.asarray(y_graph))
