"""Regenerate the golden compiled-path fixtures.

    PYTHONPATH=src python tests/golden/generate.py

For each of the four Table-1 model families this freezes (a) the exported
QIR graph json and (b) the compiled executor's per-stage outputs on a fixed
input batch, so compiled-path bit-exactness cannot silently regress: the
regression test (``tests/test_golden.py``) recompiles the *frozen* graph —
weights included, no RNG in the loop — and compares integers exactly.

Small instances of each architecture keep the fixtures a few hundred KB
while covering every stage kind the compiler emits (dense/conv threshold
stages in both halfup and bipolar flavors, pool, flatten, float head).
Regenerate only when the export contract itself changes, and say why in
the commit message.
"""

from __future__ import annotations

import os

import jax
import numpy as np

MODELS = ("kws", "ad", "ic", "cnv")
HERE = os.path.dirname(os.path.abspath(__file__))


def build(name):
    """(graph, x_int) for one golden model — all randomness fixed-seed."""
    from repro.core.qir import export_qcnn, export_qmlp
    from repro.models.tiny import ADAutoencoder, CNVModel, ICModel, KWSMLP

    rng = np.random.default_rng(2022)       # paper year; arbitrary but fixed
    if name == "kws":
        model = KWSMLP(width=32)
        params = model.init(jax.random.PRNGKey(10))
        hidden, _ = model.layers()
        graph = export_qmlp(hidden, params["hidden"], params["head"],
                            meta={"model": "KWSMLP", "golden": name},
                            freeze_scales=True, in_scale=1.0 / 127.0)
        graph.meta["in_scale"] = 1.0 / 127.0
        x = rng.integers(-127, 128, (4, 490)).astype(np.int32)
    elif name == "ad":
        model = ADAutoencoder(width=24)
        params = model.init(jax.random.PRNGKey(11))
        hidden, _ = model.layers()
        graph = export_qmlp(hidden, params["hidden"], params["head"],
                            meta={"model": "ADAutoencoder", "golden": name},
                            freeze_scales=True, in_scale=1.0 / 127.0)
        graph.meta["in_scale"] = 1.0 / 127.0
        x = rng.integers(-127, 128, (4, 128)).astype(np.int32)
    elif name == "ic":
        model = ICModel(in_hw=16)
        params = model.init(jax.random.PRNGKey(12))
        cal = rng.integers(-127, 128, (8, 16, 16, 3)).astype(np.int32)
        graph = export_qcnn(model, params, calibrate=cal,
                            meta={"golden": name})
        x = rng.integers(-127, 128, (4, 16, 16, 3)).astype(np.int32)
    elif name == "cnv":
        model = CNVModel(channels=(8, 8, 16, 16, 32, 32), fc=(32, 32))
        params = model.init(jax.random.PRNGKey(13))
        graph = export_qcnn(model, params, meta={"golden": name})
        x = rng.integers(-127, 128, (4, 32, 32, 3)).astype(np.int32)
    else:
        raise KeyError(name)
    return graph, x


def main():
    from repro.deploy import compile_graph

    for name in MODELS:
        graph, x = build(name)
        cm = compile_graph(graph, in_scale=graph.meta["in_scale"],
                           use_pallas=False, conv_lowering="direct")
        outs = cm.stage_outputs(x)
        arrays = {"x": x}
        for i, o in enumerate(outs):
            arrays[f"stage_{i:02d}"] = np.asarray(o)
        graph.save(os.path.join(HERE, f"{name}.qir.json"))
        np.savez_compressed(os.path.join(HERE, f"{name}.golden.npz"),
                            **arrays)
        kinds = [type(s).__name__ for s in cm.schedule.stages]
        print(f"{name}: {len(outs)} stages {kinds} "
              f"logits_shape={arrays[f'stage_{len(outs)-1:02d}'].shape}")


if __name__ == "__main__":
    main()
