"""Megakernel unit tests: the whole-segment Pallas kernel against its
pure-jnp oracle, the residency planner's admit/reject logic, and the byte
accounting the planner and autotuner share (``docs/megakernel.md``).

Golden-fixture bit-exactness across executor entry points lives in
``tests/test_golden.py``; this file covers the pieces in isolation.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.bops import (
    MEGAKERNEL_VMEM_BYTES,
    megakernel_residency_bytes,
    megakernel_traffic_bytes,
    staged_traffic_bytes,
)
from repro.core.streamline import ThresholdDense
from repro.deploy import (
    FusedThresholdStage,
    MegakernelSegment,
    Segment,
    plan_megakernel,
)
from repro.kernels import ops

RNG = np.random.default_rng(7)


def _chain(in_dim, out_dims, steps):
    """Random (weights, banks) for a chained stage run; codes stay tiny so
    every accumulator is exact int32."""
    weights, banks = [], []
    k = in_dim
    for n, s in zip(out_dims, steps):
        weights.append(jnp.asarray(
            RNG.integers(-8, 9, (k, n)).astype(np.int8)))
        banks.append(jnp.asarray(
            np.sort(RNG.integers(-60, 60, (n, s)), axis=1).astype(np.int32)))
        k = n
    return weights, banks


def _fts(name, in_dim, out_dim, steps=7):
    w, b = _chain(in_dim, [out_dim], [steps])
    td = ThresholdDense(w_int=w[0], thresholds=b[0], out_scale=0.25,
                        act_bits=3)
    return FusedThresholdStage(name=name, stage=td, in_dim=in_dim,
                               out_dim=out_dim, in_scale=1.0)


# ---------------------------------------------------------------------------
# kernel vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,in_dim,out_dims,steps", [
    (16, 12, [24, 16], [7, 7]),          # two stages, even dims
    (12, 10, [18, 30, 6], [3, 15, 7]),   # three stages, ragged dims + pad
    (8, 20, [16], [255]),                # single stage: no FIFO scratch
    (33, 7, [9, 5, 11, 4], [7, 3, 3, 1]),  # deep chain, odd everything
])
def test_mlp_megakernel_matches_ref(m, in_dim, out_dims, steps):
    weights, banks = _chain(in_dim, out_dims, steps)
    x = jnp.asarray(RNG.integers(0, 8, (m, in_dim)).astype(np.int32))
    y = ops.mlp_megakernel(x, weights, banks, block_m=16, interpret=True)
    yr = ops.mlp_megakernel_ref(x, weights, banks)
    assert y.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yr))


def test_mlp_megakernel_output_range():
    """Codes are threshold counts in [0, S_last]."""
    weights, banks = _chain(10, [12, 8], [7, 3])
    x = jnp.asarray(RNG.integers(0, 8, (24, 10)).astype(np.int32))
    y = np.asarray(ops.mlp_megakernel(x, weights, banks, interpret=True))
    assert y.min() >= 0 and y.max() <= 3


# ---------------------------------------------------------------------------
# deep-bank double buffering (multi_threshold slab path, S >= 256)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,c,steps", [(16, 12, 256),   # exact slab multiple
                                       (24, 8, 300),    # INT32_MAX row pad
                                       (8, 40, 511)])   # 8-bit act worst case
def test_multi_threshold_deep_bank_slab_path_matches_ref(m, c, steps):
    from repro.kernels.multi_threshold import DOUBLE_BUFFER_STEPS
    assert steps >= DOUBLE_BUFFER_STEPS   # these hit the slab-grid kernel
    acc = jnp.asarray(RNG.integers(-5000, 5000, (m, c)).astype(np.int32))
    thr = jnp.asarray(np.sort(RNG.integers(-4000, 4000, (c, steps)), axis=1)
                      .astype(np.int32))
    y = ops.multi_threshold(acc, thr, block_m=16, interpret=True)
    yr = ops.multi_threshold_ref(acc, thr)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yr))


# ---------------------------------------------------------------------------
# residency planner
# ---------------------------------------------------------------------------

def test_plan_admits_fused_run_and_accounts_bytes():
    stages = [_fts("d0", 16, 32), _fts("d1", 32, 24), _fts("d2", 24, 8)]
    plan = plan_megakernel(stages, Segment(0, 3, compiled=True))
    assert isinstance(plan, MegakernelSegment)
    assert (plan.start, plan.stop, plan.n_stages) == (0, 3, 3)
    res = megakernel_residency_bytes(stages, block_m=plan.block_m)
    assert plan.weight_bytes == res["weight_bytes"]
    assert plan.bank_bytes == res["bank_bytes"]
    assert plan.tile_bytes == res["tile_bytes"]
    assert plan.total_bytes == res["total_bytes"] <= plan.budget_bytes


def test_plan_rejects_short_run_budget_and_uncompiled():
    stages = [_fts("d0", 16, 32), _fts("d1", 32, 8)]
    # a single fused stage is not worth a megakernel
    assert plan_megakernel(stages[:1], Segment(0, 1, compiled=True)) is None
    # the working set must fit the cap
    assert plan_megakernel(stages, Segment(0, 2, compiled=True),
                           budget_bytes=64) is None
    # host-boundary segments never fuse
    assert plan_megakernel(stages, Segment(0, 2, compiled=False)) is None


def test_plan_picks_longest_fused_run():
    """A non-fusable stage splits the segment; the longer run wins."""
    stages = [_fts("a0", 8, 8), _fts("a1", 8, 8),
              object(),                              # break in the chain
              _fts("b0", 8, 8), _fts("b1", 8, 8), _fts("b2", 8, 8)]
    plan = plan_megakernel(stages, Segment(0, 6, compiled=True))
    assert (plan.start, plan.stop) == (3, 6)


def test_residency_components_readd_and_default_budget():
    stages = [_fts("d0", 490, 32), _fts("d1", 32, 32)]
    res = megakernel_residency_bytes(stages)
    assert res["total_bytes"] == (res["weight_bytes"] + res["bank_bytes"]
                                  + res["tile_bytes"])
    assert res["weight_bytes"] == 490 * 32 + 32 * 32          # int8: 1 B/elem
    assert res["bank_bytes"] == 4 * 7 * (32 + 32)             # int32 banks
    assert MEGAKERNEL_VMEM_BYTES == 1 << 21


def test_traffic_model_megakernel_beats_staged():
    """The residency traffic model the autotuner ranks by: the fused wave
    skips every inter-stage HBM round-trip and re-fetch, so it can only
    save bytes — and the saving grows with chain depth."""
    stages = [_fts("d0", 64, 48), _fts("d1", 48, 48), _fts("d2", 48, 12)]
    for rows in (1, 16, 256):
        mega = megakernel_traffic_bytes(stages, rows)
        staged = staged_traffic_bytes(stages, rows)
        assert mega < staged
    # boundary io is identical; the delta is exactly the inter-stage
    # activations plus nothing else for a 1-deep "chain"
    one = [stages[0]]
    assert (staged_traffic_bytes(one, 8)
            == megakernel_traffic_bytes(one, 8))
