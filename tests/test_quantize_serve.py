"""Deployment quantization (Model.quantize_params) across arch families:
int8 forward parity, SSM projections included, decode path, and the spec
machinery the dry-run uses for quantized cells."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models.model import Model


@pytest.mark.parametrize("arch", ["llama3-8b", "falcon-mamba-7b",
                                  "qwen2-vl-2b"])
def test_quantized_forward_parity(arch):
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    q = model.quantize_params(params, bits=8)

    if cfg.embed_inputs:
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (1, 8),
                                              0, cfg.vocab)}
    else:
        batch = {"embeds": jax.random.normal(jax.random.PRNGKey(1),
                                             (1, 8, cfg.d_model))}
    lf, _ = model.train_logits(params, batch)
    lq, _ = model.train_logits(q, batch)
    # top-1 agreement on most positions
    agree = float((jnp.argmax(lf, -1) == jnp.argmax(lq, -1)).mean())
    assert agree >= 0.75, agree


def test_quantized_decode_runs():
    cfg = get_config("falcon-mamba-7b").reduced()
    model = Model(cfg)
    params = model.quantize_params(model.init(jax.random.PRNGKey(0)), bits=8)
    caches = model.cache_init(1, 8)
    logits, _ = model.decode_step(params, caches, jnp.zeros((1, 1), jnp.int32),
                                  jnp.zeros((), jnp.int32))
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))


def test_int8_weights_actually_int8():
    cfg = get_config("llama3-8b").reduced()
    model = Model(cfg)
    q = model.quantize_params(model.init(jax.random.PRNGKey(0)), bits=8)
    kinds = {l.dtype for l in jax.tree.leaves(q)}
    assert jnp.dtype(jnp.int8) in kinds
    # int8 leaves hold most of the parameter volume
    n_int = sum(l.size for l in jax.tree.leaves(q) if l.dtype == jnp.int8)
    n_all = sum(l.size for l in jax.tree.leaves(q))
    assert n_int / n_all > 0.5


def test_quantized_specs_match_structure():
    """The dry-run's quantized spec tree lines up leaf-for-leaf with the
    quantized params (incl. the scan-stacked scale-dim-1 rule)."""
    from jax.sharding import PartitionSpec as P

    from repro.launch.dryrun import _quantized_specs
    from repro.parallel.sharding import use_mesh_rules

    cfg = get_config("falcon-mamba-7b").reduced()
    model = Model(cfg)
    with use_mesh_rules(None):
        pspecs = model.param_specs()
    sds = jax.eval_shape(
        lambda k: model.quantize_params(model.init(k), 8), jax.random.PRNGKey(0))
    qspecs = _quantized_specs(sds, pspecs)
    leaves_s = jax.tree.leaves(sds)
    leaves_p = jax.tree.leaves(qspecs, is_leaf=lambda x: isinstance(x, P))
    assert len(leaves_s) == len(leaves_p)
    # every scale dim of size 1 must be unsharded
    flat = jax.tree_util.tree_flatten_with_path(sds)[0]
    specs = jax.tree_util.tree_flatten_with_path(
        qspecs, is_leaf=lambda x: isinstance(x, P))[0]
    for (path, leaf), (_, spec) in zip(flat, specs):
        if str(path[-1]) == "['w_scale']" or "w_scale" in str(path[-1]):
            padded = list(spec) + [None] * (leaf.ndim - len(spec))
            for dim, entry in zip(leaf.shape, padded):
                if dim == 1:
                    assert entry is None, (path, leaf.shape, spec)
