"""End-to-end system tests: the paper's full codesign methodology (§5) run on
synthetic stand-ins — train QAT -> fold BN -> streamline to integers ->
deploy report — plus the bit-width descent of Fig. 4."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.codesign import bitwidth_descent, deploy_report, train_tiny
from repro.core.qlayers import QDense, QDenseBatchNorm
from repro.core.streamline import streamline_mlp
from repro.data.synthetic import SyntheticMelWindows, SyntheticMFCC
from repro.models.tiny import ADAutoencoder, KWSMLP


def _auc(scores, labels):
    order = np.argsort(scores)
    ranks = np.empty_like(order, dtype=float)
    ranks[order] = np.arange(len(scores))
    pos = labels == 1
    n_pos, n_neg = pos.sum(), (~pos).sum()
    if n_pos == 0 or n_neg == 0:
        return 0.5
    return (ranks[pos].sum() - n_pos * (n_pos - 1) / 2) / (n_pos * n_neg)


@pytest.mark.slow
def test_ad_workflow_end_to_end():
    """AD task: QAT-train the autoencoder on normal windows, then anomaly
    scores must separate planted anomalies (AUC well above chance) — the
    system-level analogue of paper Table 4's AUC column."""
    model = ADAutoencoder(weight_bits=8, act_bits=8)
    data = SyntheticMelWindows(seed=0)
    params = model.init(jax.random.PRNGKey(0))

    def loss_fn(ps, batch):
        recon, _ = model.apply(ps, batch, train=False)
        return jnp.mean(jnp.square(recon - batch))

    def batch_fn(step):
        x, _ = data.batch(step, 64)                  # normals only
        return jnp.asarray(x)

    params, losses = train_tiny(loss_fn, params, batch_fn, steps=150, lr=2e-3)
    assert losses[-1] < 0.7 * losses[0]              # actually learned
    # (8-bit QAT caps how far the recon loss can fall; the real quality
    # criterion is the AUC below)

    x, y = data.batch(10_000, 400, anomaly_frac=0.25)
    scores = np.asarray(model.anomaly_score(params, jnp.asarray(x)))
    auc = _auc(scores, y)
    assert auc > 0.8, auc


@pytest.mark.slow
def test_kws_workflow_with_streamlined_deployment():
    """KWS task: QAT-train a small same-structure MLP, streamline to integer
    thresholds, and check the integer deployment predicts the same classes
    as the float graph on held-out data."""
    dims = [16, 12, 12]
    bits = 4
    layer_defs = [QDenseBatchNorm(dims[i], dims[i + 1], weight_bits=bits,
                                  act_bits=bits) for i in range(2)]
    head_def = QDense(dims[-1], 4, weight_bits=32, act_bits=32)

    key = jax.random.PRNGKey(0)
    params = {
        "hidden": [l.init(k) for l, k in zip(layer_defs, jax.random.split(key, 2))],
        "head": head_def.init(jax.random.fold_in(key, 5)),
    }

    protos = jax.random.normal(jax.random.PRNGKey(42), (4, 16)) * 2.0

    def make_batch(step):
        k = jax.random.PRNGKey(step)
        y = jax.random.randint(k, (64,), 0, 4)
        x = protos[y] + 0.5 * jax.random.normal(jax.random.fold_in(k, 1), (64, 16))
        return x, y

    def forward(ps, x, train):
        h = x
        new_hidden = []
        for l, p in zip(layer_defs, ps["hidden"]):
            h, p = l.apply(p, h, train=train)
            new_hidden.append(p)
        return head_def.apply(ps["head"], h, train=train), new_hidden

    def loss_fn(ps, batch):
        x, y = batch
        logits, _ = forward(ps, x, train=False)
        lse = jax.nn.logsumexp(logits, axis=-1)
        lab = jnp.take_along_axis(logits, y[:, None], 1)[:, 0]
        return jnp.mean(lse - lab)

    params, losses = train_tiny(loss_fn, params, make_batch, steps=200, lr=3e-3)
    assert losses[-1] < 0.5 * losses[0]

    # update BN stats with a few train-mode passes
    for s in range(5):
        x, _ = make_batch(1000 + s)
        _, params["hidden"] = forward(params, x, train=True)

    # ---- deploy: streamline to integer thresholds ----
    in_scale = 0.1
    smlp = streamline_mlp(layer_defs, params["hidden"], in_scale,
                          params["head"])
    x, y = make_batch(99_999)
    x_int = jnp.clip(jnp.round(x / in_scale), -127, 127).astype(jnp.int32)
    pred_int = np.asarray(smlp.predict(x_int))

    logits_float, _ = forward(params, x_int.astype(jnp.float32) * in_scale,
                              train=False)
    pred_float = np.asarray(jnp.argmax(logits_float, -1))

    agreement = (pred_int == pred_float).mean()
    assert agreement > 0.9, agreement
    acc = (pred_int == np.asarray(y)).mean()
    assert acc > 0.7, acc                           # deployed graph still works


def test_bitwidth_descent_finds_cliff():
    """Fig. 4 procedure on a synthetic quality curve with a cliff below 3
    bits (the paper's observed behaviour)."""

    def eval_at_bits(bits):
        quality = 0.9 if bits >= 3 else 0.9 - 0.2 * (3 - bits)
        return quality, bits * 100.0

    res = bitwidth_descent(eval_at_bits, bit_ladder=(32, 8, 6, 4, 3, 2, 1),
                           tolerance=0.02)
    assert res.chosen_bits == 3
    assert len(res.entries) == 7


def test_deploy_report_roofline_terms():
    cost = KWSMLP().cost()
    rep = deploy_report(cost, batch=1, bits=3)
    assert rep["latency_us"] > 0 and rep["energy_uJ"] > 0
    assert rep["bound"] in ("memory", "compute")
    # tiny MLP at batch 1 is definitively memory-bound on a TPU
    assert rep["bound"] == "memory"
