"""Train-step builders: gradient-accumulation equivalence (the reuse-factor
trade C6 applied to training), donation safety, metric plumbing."""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.data.synthetic import SyntheticTokens
from repro.models.model import Model
from repro.optim.adamw import make_optimizer
from repro.train.steps import TrainState, make_train_step


def _setup(arch="internlm2-1.8b"):
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    opt = make_optimizer(base_lr=1e-3, warmup=1, total=10)
    params = model.init(jax.random.PRNGKey(0))
    state = TrainState(params=params, opt=opt.init(params))
    data = SyntheticTokens(vocab=cfg.vocab, seq_len=16)
    batch = {k: jnp.asarray(v) for k, v in data.batch(0, 8).items()}
    return model, opt, state, batch


def test_microbatch_accumulation_matches_full_batch():
    """mb=4 grad accumulation produces (numerically) the same update as the
    single full-batch step for a dense arch — the trade is latency/memory,
    never the result."""
    model, opt, state, batch = _setup()
    s1, m1 = jax.jit(make_train_step(model, opt, microbatches=1))(state, batch)
    s4, m4 = jax.jit(make_train_step(model, opt, microbatches=4))(state, batch)
    np.testing.assert_allclose(float(m4["loss"]), float(m1["loss"]), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s4.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-5)


def test_optimizer_state_advances():
    model, opt, state, batch = _setup()
    s1, _ = jax.jit(make_train_step(model, opt))(state, batch)
    assert int(s1.opt.step) == 1
    s2, _ = jax.jit(make_train_step(model, opt))(s1, batch)
    assert int(s2.opt.step) == 2


def test_metrics_contain_lr_and_grad_norm():
    model, opt, state, batch = _setup()
    _, m = jax.jit(make_train_step(model, opt))(state, batch)
    assert set(m) >= {"loss", "grad_norm", "lr"}
    assert float(m["lr"]) > 0


def test_grad_clipping_bounds_update():
    """With max_grad_norm=1e-9 the params barely move."""
    model, _, state, batch = _setup()
    opt_tiny = make_optimizer(base_lr=1e-3, warmup=1, total=10,
                              max_grad_norm=1e-9)
    state = TrainState(params=state.params, opt=opt_tiny.init(state.params))
    s1, m = jax.jit(make_train_step(model, opt_tiny))(state, batch)
    # grad_norm reported is the pre-clip norm
    assert float(m["grad_norm"]) > 1e-6


def test_loss_decreases_over_steps():
    model, opt, state, _ = _setup()
    data = SyntheticTokens(vocab=model.cfg.vocab, seq_len=16)
    step = jax.jit(make_train_step(model, opt), donate_argnums=(0,))
    losses = []
    for t in range(12):
        batch = {k: jnp.asarray(v) for k, v in data.batch(t, 8).items()}
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
