"""Attention-layer equivalences: chunked (flash-jnp) vs naive, decode vs
full forward, M-RoPE, sliding window, ring-buffer decode."""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models import attention as attn


def _cfg(**kw):
    cfg = get_config("llama3-8b").reduced()
    return dataclasses.replace(cfg, **kw) if kw else cfg


def _qkv(cfg, B, S, key):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, cfg.n_heads, cfg.hd))
    k = jax.random.normal(ks[1], (B, S, cfg.n_kv_heads, cfg.hd))
    v = jax.random.normal(ks[2], (B, S, cfg.n_kv_heads, cfg.hd))
    return q, k, v


@pytest.mark.parametrize("chunk", [8, 16, 32])
def test_chunked_equals_naive(chunk):
    cfg = _cfg(attn_chunk=chunk)
    B, S = 2, 64
    q, k, v = _qkv(cfg, B, S, jax.random.PRNGKey(0))
    pos = jnp.arange(S)
    bias = attn.mask_bias(cfg, pos, pos)
    out_naive = attn.naive_attention(q, k, v, bias)
    out_chunk = attn.chunked_attention(cfg, q, k, v, pos, pos)
    np.testing.assert_allclose(np.asarray(out_chunk), np.asarray(out_naive),
                               rtol=2e-5, atol=2e-5)


def test_chunked_sliding_window_equals_naive():
    cfg = _cfg(window=24, attn_chunk=16)
    B, S = 1, 64
    q, k, v = _qkv(cfg, B, S, jax.random.PRNGKey(1))
    pos = jnp.arange(S)
    bias = attn.mask_bias(cfg, pos, pos)
    out_naive = attn.naive_attention(q, k, v, bias)
    out_chunk = attn.chunked_attention(cfg, q, k, v, pos, pos)
    np.testing.assert_allclose(np.asarray(out_chunk), np.asarray(out_naive),
                               rtol=2e-5, atol=2e-5)


def test_mask_bias_causal_and_window():
    cfg = _cfg(window=4)
    pos = jnp.arange(8)
    bias = np.asarray(attn.mask_bias(cfg, pos, pos))
    assert bias[0, 1] < -1e29                    # future masked
    assert bias[7, 7] == 0.0
    assert bias[7, 2] < -1e29                    # outside window
    assert bias[7, 4] == 0.0                     # inside window


def test_encoder_only_no_causal_mask():
    cfg = _cfg(encoder_only=True, causal=True)
    pos = jnp.arange(6)
    bias = np.asarray(attn.mask_bias(cfg, pos, pos))
    assert np.all(bias == 0.0)                   # hubert: bidirectional


def test_mrope_sections_rotate_differently():
    cfg = dataclasses.replace(get_config("qwen2-vl-2b").reduced())
    B, S = 1, 8
    # t/h/w positions differ -> different cos/sin than plain rope
    p3 = jnp.stack([jnp.arange(S)[None] * m for m in (1, 2, 3)])  # (3,1,S)
    cos3, sin3 = attn.positions_cos_sin(cfg, p3)
    cos1, sin1 = attn.positions_cos_sin(
        cfg, jnp.broadcast_to(jnp.arange(S)[None][None], (3, 1, S)))
    assert cos3.shape == (B, S, cfg.hd // 2)
    assert not np.allclose(np.asarray(cos3), np.asarray(cos1))


def test_rope_preserves_norm():
    cfg = _cfg()
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 16, cfg.n_heads, cfg.hd))
    cos, sin = attn.rope_freqs(cfg, jnp.arange(16)[None])
    y = attn.apply_rope(x, cos, sin)
    np.testing.assert_allclose(
        np.asarray(jnp.linalg.norm(y, axis=-1)),
        np.asarray(jnp.linalg.norm(x, axis=-1)), rtol=1e-5)


def test_attn_decode_matches_full_forward():
    """Step-by-step attn_decode == attn_apply on the same token stream."""
    cfg = _cfg()
    B, T = 1, 8
    p = attn.attn_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, T, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    full = attn.attn_apply(cfg, p, x, pos)

    cache = attn.attn_cache_init(cfg, B, T)
    outs = []
    for t in range(T):
        y, cache = attn.attn_decode(cfg, p, x[:, t: t + 1], cache,
                                    jnp.asarray(t, jnp.int32))
        outs.append(y)
    stepped = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(stepped), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_windowed_ring_buffer_decode_matches_full():
    """SWA decode with a ring cache smaller than the stream reproduces the
    windowed full forward (h2o-danube path)."""
    cfg = _cfg(window=4)
    B, T = 1, 12
    p = attn.attn_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, T, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    full = attn.attn_apply(cfg, p, x, pos)

    cache = attn.attn_cache_init(cfg, B, max_len=T)   # sized to window=4
    assert cache["k"].shape[1] == 4                   # ring buffer = window
    outs = []
    for t in range(T):
        y, cache = attn.attn_decode(cfg, p, x[:, t: t + 1], cache,
                                    jnp.asarray(t, jnp.int32))
        outs.append(y)
    stepped = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(stepped), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_per_slot_cur_index_vector_decode():
    """Serving path: (B,) per-slot positions advance independently."""
    cfg = _cfg()
    B = 2
    p = attn.attn_init(jax.random.PRNGKey(0), cfg)
    cache = attn.attn_cache_init(cfg, B, 8)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, 1, cfg.d_model))
    cur = jnp.asarray([0, 3], jnp.int32)
    y, new_cache = attn.attn_decode(cfg, p, x, cache, cur)
    assert y.shape == (B, 1, cfg.d_model)
    # slot 0 wrote at 0, slot 1 wrote at 3
    assert float(jnp.sum(jnp.abs(new_cache["k"][0, 0]))) > 0
    assert float(jnp.sum(jnp.abs(new_cache["k"][1, 3]))) > 0
    assert float(jnp.sum(jnp.abs(new_cache["k"][1, 0]))) == 0
