"""Checkpoint subsystem: atomicity, auto-resume, retention, async writes,
and resharding restore (the elastic-restart path)."""

import os
import threading

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint.checkpoint import CheckpointManager, latest_step, restore, save


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (4, 4)),
            "opt": {"m": jnp.zeros((4, 4)), "step": jnp.asarray(3)}}


def test_save_restore_roundtrip(tmp_path):
    s = _state()
    save(str(tmp_path), 10, s)
    r, step, manifest = restore(str(tmp_path), s)
    assert step == 10 and manifest["step"] == 10
    np.testing.assert_array_equal(np.asarray(r["w"]), np.asarray(s["w"]))
    np.testing.assert_array_equal(np.asarray(r["opt"]["step"]), 3)


def test_latest_step_picks_max(tmp_path):
    s = _state()
    for st in (5, 20, 10):
        save(str(tmp_path), st, s)
    assert latest_step(str(tmp_path)) == 20


def test_atomicity_partial_write_invisible(tmp_path):
    """A temp dir left by a killed writer must not be picked up by restore."""
    s = _state()
    save(str(tmp_path), 1, s)
    # simulate a torn write: a .tmp_ckpt_ dir with garbage
    os.makedirs(tmp_path / ".tmp_ckpt_dead" )
    (tmp_path / ".tmp_ckpt_dead" / "arrays.npz").write_bytes(b"garbage")
    assert latest_step(str(tmp_path)) == 1
    r, step, _ = restore(str(tmp_path), s)
    assert step == 1


def test_manager_retention_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), every=1, keep_n=2)
    s = _state()
    for st in range(1, 6):
        mgr.maybe_save(st, s, block=True)
    steps = sorted(int(n.split("_")[1]) for n in os.listdir(tmp_path)
                   if n.startswith("step_"))
    assert steps == [4, 5]


def test_manager_every_n(tmp_path):
    mgr = CheckpointManager(str(tmp_path), every=3, keep_n=10)
    s = _state()
    saved = [mgr.maybe_save(st, s, block=True) for st in range(1, 8)]
    assert saved == [False, False, True, False, False, True, False]


def test_async_save_overlaps_then_waits(tmp_path):
    mgr = CheckpointManager(str(tmp_path), every=1, keep_n=5)
    s = {"w": jnp.ones((256, 256))}
    assert mgr.maybe_save(1, s, block=False)
    mgr.wait()
    assert latest_step(str(tmp_path)) == 1


def test_restore_with_target_sharding(tmp_path):
    """Elastic path: restore device_puts onto an explicit sharding (here the
    1-device mesh — the mechanism is identical on a resized pod)."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    s = _state()
    save(str(tmp_path), 7, s)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1), ("data",))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), s)
    r, step, _ = restore(str(tmp_path), s, shardings=sh)
    assert step == 7
    assert r["w"].sharding == NamedSharding(mesh, P())


def test_overwrite_same_step(tmp_path):
    s1, s2 = _state(1), _state(2)
    save(str(tmp_path), 5, s1)
    save(str(tmp_path), 5, s2)
    r, _, _ = restore(str(tmp_path), s1)
    np.testing.assert_array_equal(np.asarray(r["w"]), np.asarray(s2["w"]))
