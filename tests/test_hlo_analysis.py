"""HLO static analysis validation on known graphs: scan x N scales FLOPs by
exactly N, collective bytes match array sizes, dot FLOPs = 2*M*N*K."""

import re

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import analyze_hlo, shape_bytes


def _compiled_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_dot_flops_exact():
    M, K, N = 8, 16, 4

    def f(a, b):
        return a @ b

    hlo = _compiled_text(f, jnp.ones((M, K)), jnp.ones((K, N)))
    stats = analyze_hlo(hlo)
    assert stats.flops == pytest.approx(2 * M * N * K)


def test_scan_scales_flops_by_trip_count():
    M = 8
    n_steps = 7

    def f(x, w):
        def body(c, _):
            return c @ w, None

        y, _ = jax.lax.scan(body, x, None, length=n_steps)
        return y

    hlo = _compiled_text(f, jnp.ones((M, M)), jnp.ones((M, M)))
    stats = analyze_hlo(hlo)
    assert stats.flops == pytest.approx(n_steps * 2 * M * M * M)


def test_nested_scan():
    def f(x, w):
        def inner(c, _):
            return c @ w, None

        def outer(c, _):
            y, _ = jax.lax.scan(inner, c, None, length=3)
            return y, None

        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    hlo = _compiled_text(f, jnp.ones((4, 4)), jnp.ones((4, 4)))
    stats = analyze_hlo(hlo)
    assert stats.flops == pytest.approx(15 * 2 * 4 ** 3)


def test_shape_bytes_parser():
    assert shape_bytes("f32[8,4]") == 128
    assert shape_bytes("bf16[10]") == 20
    assert shape_bytes("s8[3,3]") == 9
    assert shape_bytes("(f32[4], s32[2])") == 24
    assert shape_bytes("pred[16]") == 16


def test_zero_collectives_on_single_device_graph():
    hlo = _compiled_text(lambda x: x * 2, jnp.ones((4,)))
    stats = analyze_hlo(hlo)
    assert stats.collective_bytes == 0.0


def test_flops_counted_inside_remat():
    """jax.checkpoint re-runs the forward in the backward; the analysis must
    see the duplicated dots (that is what the 6ND/HLO ratio catches)."""
    w = jnp.ones((8, 8))

    def loss_plain(x, w):
        return jnp.sum(x @ w)

    def loss_remat(x, w):
        return jnp.sum(jax.checkpoint(lambda x: x @ w)(x))

    x = jnp.ones((8, 8))
    hlo_p = _compiled_text(jax.grad(loss_plain), x, w)
    hlo_r = _compiled_text(jax.grad(loss_remat), x, w)
    f_p = analyze_hlo(hlo_p).flops
    f_r = analyze_hlo(hlo_r).flops
    assert f_r >= f_p


def test_conv_flops_positive():
    def f(x, w):
        return jax.lax.conv_general_dilated(
            x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))

    hlo = _compiled_text(f, jnp.ones((1, 8, 8, 3)), jnp.ones((3, 3, 3, 4)))
    stats = analyze_hlo(hlo)
    # 2 * out_positions * k*k*cin = 2 * (8*8*4) * 9 * 3
    assert stats.flops == pytest.approx(2 * 64 * 4 * 9 * 3, rel=0.05)


def test_collective_bytes_on_forced_multidevice_hlo():
    """Hand-written HLO with an all-reduce: bytes must equal the array size."""
    hlo = """
HloModule test

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

ENTRY %main (x: f32[128,4]) -> f32[128,4] {
  %x = f32[128,4] parameter(0)
  ROOT %ar = f32[128,4] all-reduce(%x), to_apply=%add
}
"""
    stats = analyze_hlo(hlo)
    assert stats.collective_bytes == 128 * 4 * 4
    assert stats.by_type == {"all-reduce": 128 * 4 * 4}
    assert stats.by_count == {"all-reduce": 1}
