"""Hypothesis property tests for the Pallas kernels: random shapes, dtypes,
block sizes — every draw must match the ref.py oracle exactly (integer
kernels) or to fp tolerance (attention)."""

import numpy as np
import jax.numpy as jnp
from _hypothesis_compat import given, settings, strategies as st

from repro.kernels import ops
from repro.kernels.ref import (
    flash_attention_ref,
    multi_threshold_ref,
    qmatmul_ref,
    threshold_matmul_ref,
)


@settings(max_examples=12, deadline=None)
@given(
    st.integers(1, 70),            # M
    st.integers(1, 70),            # K
    st.integers(1, 70),            # N
    st.sampled_from([8, 16, 32]),  # block
    st.booleans(),                 # relu
    st.integers(0, 2 ** 31 - 1),
)
def test_qmatmul_property(m, k, n, block, relu, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(-127, 128, (m, k)).astype(np.int8))
    w = jnp.asarray(rng.integers(-127, 128, (k, n)).astype(np.int8))
    s = jnp.asarray(rng.uniform(1e-3, 1e-2, n).astype(np.float32))
    y = ops.qmatmul(x, w, s, None, relu=relu,
                    block_m=block, block_n=block, block_k=block)
    yr = qmatmul_ref(x, w, s, None, relu=relu)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=12, deadline=None)
@given(
    st.integers(1, 60), st.integers(1, 40), st.integers(1, 31),
    st.integers(0, 2 ** 31 - 1),
)
def test_multi_threshold_property(m, c, steps, seed):
    rng = np.random.default_rng(seed)
    acc = jnp.asarray(rng.integers(-10_000, 10_000, (m, c)).astype(np.int32))
    thr = jnp.asarray(np.sort(rng.integers(-9_000, 9_000, (c, steps)), axis=1)
                      .astype(np.int32))
    y = ops.multi_threshold(acc, thr, block_m=16)
    np.testing.assert_array_equal(np.asarray(y),
                                  np.asarray(multi_threshold_ref(acc, thr)))


@settings(max_examples=10, deadline=None)
@given(
    st.integers(1, 40), st.integers(1, 50), st.integers(1, 24),
    st.integers(1, 15), st.integers(0, 2 ** 31 - 1),
)
def test_threshold_matmul_property(m, k, n, steps, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(-127, 128, (m, k)).astype(np.int8))
    w = jnp.asarray(rng.integers(-127, 128, (k, n)).astype(np.int8))
    thr = jnp.asarray(
        np.sort(rng.integers(-40_000, 40_000, (n, steps)), axis=1)
        .astype(np.int32))
    y = ops.threshold_matmul(x, w, thr, block_m=16, block_n=16, block_k=16)
    np.testing.assert_array_equal(
        np.asarray(y), np.asarray(threshold_matmul_ref(x, w, thr)))


@settings(max_examples=8, deadline=None)
@given(
    st.integers(1, 2),                       # batch
    st.sampled_from([(2, 1), (4, 2), (4, 4)]),  # (H, Hkv)
    st.integers(3, 80),                      # Sq = Sk
    st.sampled_from([8, 16, 32]),            # D
    st.booleans(),                           # causal
    st.integers(0, 2 ** 31 - 1),
)
def test_flash_attention_property(b, heads, s, d, causal, seed):
    h, hkv = heads
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((b, h, s, d)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((b, hkv, s, d)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((b, hkv, s, d)).astype(np.float32))
    o = ops.flash_attention(q, k, v, causal=causal, block_q=16, block_k=16)
    orf = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(o), np.asarray(orf),
                               rtol=3e-5, atol=3e-5)
