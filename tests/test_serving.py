"""Serving engine: continuous batching correctness — engine outputs match a
sequential single-request decode; slots recycle; stats populate."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models.model import Model
from repro.serving.engine import Request, ServeEngine


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("internlm2-1.8b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _greedy_reference(model, params, prompt, n_new, max_len):
    """Sequential single-sequence greedy decode (ground truth)."""
    caches = model.cache_init(1, max_len)
    toks = list(prompt)
    decode = jax.jit(model.decode_step)
    out = []
    for t in range(len(prompt) + n_new - 1):
        cur = jnp.asarray(t, jnp.int32)
        tok = jnp.asarray([[toks[t]]], jnp.int32)
        logits, caches = decode(params, caches, tok, cur)
        nxt = int(jnp.argmax(logits[0, 0]))
        if t >= len(prompt) - 1:
            out.append(nxt)
            if len(out) >= n_new:
                break
            toks.append(nxt)
    return out


def test_engine_matches_sequential_decode(setup):
    cfg, model, params = setup
    prompt = np.asarray([3, 17, 42, 7], np.int32)
    n_new = 6
    ref = _greedy_reference(model, params, prompt, n_new, max_len=32)

    eng = ServeEngine(model, params, n_slots=2, max_len=32)
    req = Request(uid=0, prompt=prompt, max_new_tokens=n_new)
    eng.submit(req)
    eng.run_until_drained()
    assert req.output == ref


def test_continuous_batching_multiple_requests(setup):
    cfg, model, params = setup
    eng = ServeEngine(model, params, n_slots=2, max_len=32)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, prompt=rng.integers(0, cfg.vocab, 3).astype(np.int32),
                    max_new_tokens=4) for i in range(5)]   # 5 reqs > 2 slots
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained()
    assert len(eng.finished) == 5
    for r in reqs:
        assert len(r.output) == 4
    # each request's output matches its own sequential decode (slot isolation)
    for r in reqs[:2]:
        ref = _greedy_reference(model, params, r.prompt, 4, max_len=32)
        assert r.output == ref, (r.uid, r.output, ref)


def test_eos_frees_slot_early(setup):
    cfg, model, params = setup
    prompt = np.asarray([1, 2], np.int32)
    ref = _greedy_reference(model, params, prompt, 1, max_len=16)
    eos = ref[0]
    eng = ServeEngine(model, params, n_slots=1, max_len=16)
    req = Request(uid=0, prompt=prompt, max_new_tokens=10, eos_id=eos)
    eng.submit(req)
    eng.run_until_drained()
    assert req.output[-1] == eos and len(req.output) < 10


def test_stats(setup):
    cfg, model, params = setup
    eng = ServeEngine(model, params, n_slots=2, max_len=16)
    eng.submit(Request(uid=0, prompt=np.asarray([5], np.int32),
                       max_new_tokens=3))
    eng.run_until_drained()
    s = eng.stats()
    assert s["n_requests"] == 1
    assert s["throughput_tok_s"] > 0
    assert s["mean_ttft_s"] >= 0
