"""Multi-device integration tests, run in a subprocess with 8 forced host
devices (XLA_FLAGS must be set before jax initializes, so these cannot run
in-process — conftest deliberately does NOT set the flag globally)."""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(body: str, n_dev: int = 8, timeout: int = 420):
    src = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n_dev}"
        import numpy as np
        import jax, jax.numpy as jnp
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        assert jax.device_count() == {n_dev}
    """) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", src], env=env, timeout=timeout,
                       capture_output=True, text=True)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


@pytest.mark.slow
def test_spmd_train_step_8dev_matches_1dev():
    """The pjit train step on a 4x2 mesh produces the same loss trajectory as
    the single-device run — SPMD correctness of the whole stack."""
    _run("""
        import dataclasses
        from repro.configs import get_config
        from repro.models.model import Model
        from repro.optim.adamw import make_optimizer
        from repro.train.steps import TrainState, make_train_step
        from repro.parallel.sharding import use_mesh_rules
        from repro.data.synthetic import SyntheticTokens

        cfg = get_config("llama3-8b").reduced()
        model = Model(cfg)
        opt = make_optimizer(base_lr=1e-3, warmup=1, total=10)
        data = SyntheticTokens(vocab=cfg.vocab, seq_len=16)
        def batch(step):
            b = data.batch(step, 8)
            return {k: jnp.asarray(v) for k, v in b.items()}

        # single-device reference
        params = model.init(jax.random.PRNGKey(0))
        state = TrainState(params=params, opt=opt.init(params))
        step1 = jax.jit(make_train_step(model, opt))
        losses_1dev = []
        s = state
        for t in range(3):
            s, m = step1(s, batch(t))
            losses_1dev.append(float(m["loss"]))

        # 4x2 mesh SPMD
        mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
        with use_mesh_rules(mesh):
            model2 = Model(cfg)
            params2 = model2.init(jax.random.PRNGKey(0))
            state2 = TrainState(params=params2, opt=opt.init(params2))
            step8 = jax.jit(make_train_step(model2, opt))
            with mesh:
                losses_8dev = []
                s2 = state2
                for t in range(3):
                    s2, m2 = step8(s2, batch(t))
                    losses_8dev.append(float(m2["loss"]))

        np.testing.assert_allclose(losses_8dev, losses_1dev, rtol=2e-3)
        print("OK", losses_1dev, losses_8dev)
    """)


def test_moe_shardmap_8dev_matches_local():
    """shard_map MoE (EP/TP path) == single-device _moe_local result."""
    _run("""
        import dataclasses
        from repro.configs import get_config
        from repro.models.layers import moe_init, moe_apply
        from repro.parallel.sharding import use_mesh_rules

        cfg = dataclasses.replace(get_config("grok-1-314b").reduced(),
                                  capacity_factor=8.0)
        p = moe_init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 4, cfg.d_model))

        y_local, aux_local = moe_apply(cfg, p, x)

        mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
        with use_mesh_rules(mesh), mesh:
            y_mesh, aux_mesh = jax.jit(lambda p, x: moe_apply(cfg, p, x))(p, x)

        np.testing.assert_allclose(np.asarray(y_mesh), np.asarray(y_local),
                                   rtol=2e-2, atol=2e-2)
        np.testing.assert_allclose(float(aux_mesh), float(aux_local), rtol=1e-3)
        print("OK moe")
    """)


def test_compressed_allreduce_8dev():
    """int8-compressed gradient all-reduce across 8 real (host) devices:
    mean of per-shard gradients within quantization tolerance, EF captures
    the residual."""
    _run("""
        from repro.parallel.collectives import compressed_psum
        from repro.models.layers import shard_map

        mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
        g = jax.random.normal(jax.random.PRNGKey(0), (8, 512)) * 0.01

        def f(gl):
            gl = gl[0]
            mean, err = compressed_psum(gl, ("data",), 8)
            return mean[None], err[None]

        mean, err = shard_map(f, mesh, in_specs=(P("data"),),
                              out_specs=(P("data"), P("data")))(g)
        true_mean = jnp.mean(g, axis=0)
        scale = float(jnp.max(jnp.abs(g))) / 127.0
        # each shard's quantization error <= scale/2; mean error likewise
        err_bound = scale * 0.5 + 1e-9
        assert float(jnp.max(jnp.abs(mean[0] - true_mean))) <= err_bound
        print("OK compressed allreduce")
    """)


@pytest.mark.slow
def test_elastic_mesh_shrink_and_restore():
    """Simulated node failure: train on 8 devices, checkpoint, rebuild a
    6-device mesh from 'surviving' devices, restore, keep training."""
    _run("""
        import tempfile
        from repro.configs import get_config
        from repro.models.model import Model
        from repro.optim.adamw import make_optimizer
        from repro.train.steps import TrainState, make_train_step
        from repro.parallel.sharding import use_mesh_rules
        from repro.checkpoint.checkpoint import save, restore
        from repro.launch.mesh import make_elastic_mesh
        from repro.data.synthetic import SyntheticTokens

        cfg = get_config("internlm2-1.8b").reduced()
        model = Model(cfg)
        opt = make_optimizer(base_lr=1e-3, warmup=1, total=10)
        data = SyntheticTokens(vocab=cfg.vocab, seq_len=8)
        def batch(step, B):
            b = data.batch(step, B)
            return {k: jnp.asarray(v) for k, v in b.items()}

        mesh8 = make_elastic_mesh(model_parallel=2)
        assert dict(mesh8.shape) == {"data": 4, "model": 2}
        with use_mesh_rules(mesh8), mesh8:
            params = model.init(jax.random.PRNGKey(0))
            state = TrainState(params=params, opt=opt.init(params))
            step = jax.jit(make_train_step(model, opt))
            state, m = step(state, batch(0, 8))

        d = tempfile.mkdtemp()
        save(d, 1, state)

        # "lose" two devices -> 6 survive -> 3x2 mesh
        mesh6 = make_elastic_mesh(model_parallel=2, devices=jax.devices()[:6])
        assert dict(mesh6.shape) == {"data": 3, "model": 2}
        with use_mesh_rules(mesh6), mesh6:
            restored, step_n, _ = restore(d, state)
            state2 = jax.device_put(restored)  # reshard onto new topology
            step2 = jax.jit(make_train_step(model, opt))
            state2, m2 = step2(state2, batch(1, 6))
            assert np.isfinite(float(m2["loss"]))
        print("OK elastic", float(m["loss"]), float(m2["loss"]))
    """)


def test_dryrun_cell_inprocess_minimesh():
    """A miniature dry-run (4x2 mesh) exercises the full lower+compile path
    with the real input_specs/arch_rules machinery."""
    _run("""
        from repro.configs import get_config
        from repro.models.model import Model
        from repro.parallel.sharding import use_mesh_rules, logical_to_spec
        from repro.launch.dryrun import input_specs, arch_rules, batch_shardings
        from repro.configs.base import SHAPES
        import dataclasses

        cfg = get_config("llama3-8b").reduced()
        shape = dataclasses.replace(SHAPES["train_4k"], seq_len=64, global_batch=8)
        mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
        rules = arch_rules(cfg, mesh, ("data",))
        with use_mesh_rules(mesh, rules):
            model = Model(cfg)
            pspecs = model.param_specs()
            params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
            psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                               is_leaf=lambda x: isinstance(x, P))
            bsh = {k: v for k, v in batch_shardings(
                cfg, shape, mesh, ("data",)).items() if k != "labels"}
            bs = input_specs(cfg, shape)
            lowered = jax.jit(model.prefill, in_shardings=(psh, bsh)).lower(
                params_sds, {k: v for k, v in bs.items() if k != "labels"})
            compiled = lowered.compile()
            assert compiled.cost_analysis() is not None
        print("OK minimesh dryrun")
    """)
