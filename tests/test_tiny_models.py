"""The paper's four MLPerf Tiny submission models: parameter counts vs
Table 1, forward shapes, BOPs cost tables, and the AD anomaly score."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.bops import dense_bops, inference_cost
from repro.models.tiny import ADAutoencoder, CNVModel, ICModel, KWSMLP


def test_cnv_weight_count_matches_paper():
    assert CNVModel().n_weights() == 1_542_848        # Table 1, IC (FINN)


def test_kws_weight_count_matches_paper():
    assert KWSMLP().n_weights() == 259_584            # Table 1, KWS


def test_ad_param_count_near_paper():
    n = ADAutoencoder().n_params()
    # paper Table 1: 22 285 params. The paper's prose (5 hidden layers,
    # width 72, 128-d input) reads as 31 560 with BN; the exact layer list
    # behind 22 285 is not published, so this is a same-order check.
    assert n == 31_560
    assert 0.5 < n / 22_285 < 2.0


def test_ad_forward_and_score():
    model = ADAutoencoder()
    p = model.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 128))
    recon, _ = model.apply(p, x, train=True)
    assert recon.shape == (8, 128)
    scores = model.anomaly_score(p, x)
    assert scores.shape == (8,)
    assert np.all(np.isfinite(np.asarray(scores)))


def test_kws_forward():
    model = KWSMLP()
    p = model.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 490))
    logits, _ = model.apply(p, x, train=True)
    assert logits.shape == (4, 12)
    assert np.all(np.isfinite(np.asarray(logits)))


def test_ic_forward():
    model = ICModel()
    p = model.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 3))
    logits = model.apply(p, x, train=True)
    assert logits.shape == (2, 10)
    assert np.all(np.isfinite(np.asarray(logits)))


def test_cnv_forward():
    model = CNVModel()
    p = model.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 32, 3))
    logits = model.apply(p, x, train=True)
    assert logits.shape == (1, 10)
    assert np.all(np.isfinite(np.asarray(logits)))


# ---------------------------------------------------------------------------
# BOPs / cost model (paper Eqs. 1-2)
# ---------------------------------------------------------------------------

def test_dense_bops_eq1_hand_value():
    # Eq.1, k=1: m*n*(ba*bw + ba + bw + log2(n))
    v = dense_bops(m=4, n=8, b_a=3, b_w=3)
    expected = 4 * 8 * (9 + 3 + 3 + np.log2(8))
    assert v == pytest.approx(expected)


def test_inference_cost_eq2_reference_is_one():
    assert inference_cost(10.0, 20.0, 10.0, 20.0) == pytest.approx(1.0)
    assert inference_cost(5.0, 20.0, 10.0, 20.0) == pytest.approx(0.75)


def test_binary_bops_much_cheaper_than_8bit():
    """The FINN IC model implements 26x the params of the hls4ml IC model but
    binary ops are far cheaper — the paper's core cost trade."""
    cnv = CNVModel().cost()
    ic = ICModel().cost()
    assert cnv.n_params > 10 * ic.n_params
    # per-param BOPs of binary are way below 8-bit per-param BOPs
    assert (cnv.bops / cnv.n_params) < 0.3 * (ic.bops / ic.n_params)


def test_kws_cost_scales_with_bits():
    c3 = KWSMLP(weight_bits=3, act_bits=3).cost()
    c8 = KWSMLP(weight_bits=8, act_bits=8).cost()
    assert c8.bops > 2.0 * c3.bops
    assert c8.wm_bits == pytest.approx(c3.wm_bits * 8 / 3, rel=1e-6)


def test_cost_table_renders():
    t = ADAutoencoder().cost().table()
    assert "TOTAL" in t and "fc0" in t
