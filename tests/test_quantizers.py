"""Quantizer unit + property tests (hypothesis): range bounds, idempotence,
STE gradients, po2 scales — the invariants C1 rests on."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core.quantizers import (
    BinaryQuantizer,
    FixedPointQuantizer,
    IntQuantizer,
    TernaryQuantizer,
    fake_quant_act,
    make_quantizer,
    quantize_po2,
    ste_clip,
    ste_round,
    ste_sign,
)


# ---------------------------------------------------------------------------
# STE primitives
# ---------------------------------------------------------------------------

def test_ste_round_values_and_grad():
    x = jnp.asarray([-1.7, -0.5, 0.2, 0.5, 1.49])
    np.testing.assert_array_equal(np.asarray(ste_round(x)), np.round(np.asarray(x)))
    g = jax.grad(lambda x: jnp.sum(ste_round(x)))(x)
    np.testing.assert_array_equal(np.asarray(g), np.ones(5))   # identity grad


def test_ste_clip_grad_masks_outside():
    x = jnp.asarray([-2.0, -0.5, 0.5, 2.0])
    g = jax.grad(lambda x: jnp.sum(ste_clip(x, -1.0, 1.0)))(x)
    np.testing.assert_array_equal(np.asarray(g), [0.0, 1.0, 1.0, 0.0])


def test_ste_sign_hard_tanh_grad():
    x = jnp.asarray([-3.0, -0.9, 0.0, 0.9, 3.0])
    y = ste_sign(x)
    np.testing.assert_array_equal(np.asarray(y), [-1, -1, 1, 1, 1])
    g = jax.grad(lambda x: jnp.sum(ste_sign(x)))(x)
    np.testing.assert_array_equal(np.asarray(g), [0.0, 1.0, 1.0, 1.0, 0.0])


# ---------------------------------------------------------------------------
# fixed point (QKeras quantized_bits)
# ---------------------------------------------------------------------------

def test_fixed_point_grid():
    q = FixedPointQuantizer(bits=8, integer=2)
    assert q.step == 2.0 ** -5
    assert q.qmin == -4.0 and q.qmax == 4.0 - 2.0 ** -5
    x = jnp.asarray([0.1, -3.99, 10.0, -10.0])
    y = np.asarray(q(x))
    assert abs(y[0] - 0.09375) < 1e-6          # snapped to grid
    assert y[2] == pytest.approx(q.qmax)       # saturates high
    assert y[3] == pytest.approx(q.qmin)       # saturates low


@settings(max_examples=50, deadline=None)
@given(st.integers(2, 12), st.integers(0, 4))
def test_fixed_point_idempotent(bits, integer):
    q = FixedPointQuantizer(bits=bits, integer=integer)
    x = jnp.linspace(-10, 10, 101)
    y1 = q(x)
    y2 = q(y1)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-6)


# ---------------------------------------------------------------------------
# int quantizer (Brevitas style)
# ---------------------------------------------------------------------------

@settings(max_examples=50, deadline=None)
@given(st.integers(2, 8), st.booleans(), st.booleans())
def test_int_quantizer_bounded_error(bits, po2, narrow):
    q = IntQuantizer(bits=bits, po2=po2, narrow=narrow)
    x = jnp.asarray(np.random.default_rng(bits).standard_normal(256) * 3)
    y = q(x)
    s = float(jnp.max(q.scale(x)))
    # max quantization error is half a step (po2 snap can double the scale)
    bound = s * (1.0 if po2 else 0.5) + 1e-6
    assert float(jnp.max(jnp.abs(y - jnp.clip(x, q.qmin * s, q.qmax * s)))) <= bound


def test_int_quantizer_int_codes_in_range():
    q = IntQuantizer(bits=4, narrow=True)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((32, 8)))
    codes, s = q.quantize_int(x)
    assert codes.dtype == jnp.int8
    assert int(codes.min()) >= -7 and int(codes.max()) <= 7   # narrow: [-7, 7]
    np.testing.assert_allclose(np.asarray(codes * s), np.asarray(q(x)), atol=1e-6)


def test_per_channel_scales():
    q = IntQuantizer(bits=8, axis=0)
    x = jnp.stack([jnp.ones(4) * 0.1, jnp.ones(4) * 100.0])   # wildly different rows
    y = q(x.T)   # axis=0 -> per-column of (4, 2)
    rel_err = jnp.abs(y - x.T) / jnp.abs(x.T)
    assert float(jnp.max(rel_err)) < 0.01      # both channels well resolved


def test_po2_scale_is_power_of_two():
    s = quantize_po2(jnp.asarray([0.3, 1.5, 100.0]))
    logs = np.log2(np.asarray(s))
    np.testing.assert_allclose(logs, np.round(logs), atol=1e-6)


# ---------------------------------------------------------------------------
# binary / ternary
# ---------------------------------------------------------------------------

def test_binary_quantizer_bipolar():
    q = BinaryQuantizer()
    y = np.asarray(q(jnp.asarray([-0.3, 0.0, 2.0])))
    np.testing.assert_array_equal(y, [-1.0, 1.0, 1.0])


def test_ternary_quantizer_deadzone():
    q = TernaryQuantizer(threshold=0.5)
    y = np.asarray(q(jnp.asarray([-1.0, -0.2, 0.0, 0.2, 1.0])))
    np.testing.assert_array_equal(y, [-1.0, 0.0, 0.0, 0.0, 1.0])


def test_make_quantizer_dispatch():
    assert make_quantizer(32) is None
    assert isinstance(make_quantizer(1), BinaryQuantizer)
    assert isinstance(make_quantizer(8, "fixed"), FixedPointQuantizer)
    assert isinstance(make_quantizer(4), IntQuantizer)
    assert make_quantizer(8).bits == 8


def test_fake_quant_act_bits16_identity():
    x = jnp.asarray([1.234, -9.87])
    np.testing.assert_array_equal(np.asarray(fake_quant_act(x, 16)), np.asarray(x))


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 8))
def test_fake_quant_reduces_distinct_values(bits):
    x = jnp.asarray(np.random.default_rng(7).standard_normal(512))
    y = np.asarray(fake_quant_act(x, bits))
    assert len(np.unique(y)) <= 2 ** bits
