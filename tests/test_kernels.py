"""Per-kernel validation: sweep shapes/dtypes and assert_allclose against the
ref.py pure-jnp oracles (interpret=True executes the Pallas kernel body on
CPU)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops
from repro.kernels.ref import (
    flash_attention_ref,
    multi_threshold_ref,
    qmatmul_ref,
    threshold_matmul_ref,
)


RNG = np.random.default_rng(42)


def _int8(shape):
    return jnp.asarray(RNG.integers(-127, 128, shape).astype(np.int8))


# ---------------------------------------------------------------------------
# qmatmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [(8, 16, 8), (100, 70, 50), (128, 128, 128),
                                   (33, 200, 17), (256, 64, 192)])
@pytest.mark.parametrize("relu", [False, True])
def test_qmatmul_matches_ref(m, k, n, relu):
    x = _int8((m, k))
    w = _int8((k, n))
    s = jnp.asarray(RNG.uniform(1e-3, 1e-2, n).astype(np.float32))
    b = jnp.asarray(RNG.standard_normal(n).astype(np.float32))
    y = ops.qmatmul(x, w, s, b, relu=relu, block_m=32, block_n=32, block_k=32)
    yr = qmatmul_ref(x, w, s, b, relu=relu)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("out_scale", [0.125, 0.5, 0.03])
def test_qmatmul_requant_int8_exact(out_scale):
    x = _int8((64, 48))
    w = _int8((48, 40))
    s = jnp.asarray(RNG.uniform(1e-3, 5e-3, 40).astype(np.float32))
    b = jnp.asarray(RNG.standard_normal(40).astype(np.float32))
    y = ops.qmatmul(x, w, s, b, relu=True, out_scale=out_scale,
                    block_m=32, block_n=32, block_k=16)
    yr = qmatmul_ref(x, w, s, b, relu=True, out_scale=out_scale)
    assert y.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yr))


def test_qmatmul_no_bias():
    x = _int8((32, 32))
    w = _int8((32, 32))
    s = jnp.ones((32,), jnp.float32) * 0.01
    y = ops.qmatmul(x, w, s, None, block_m=16, block_n=16, block_k=16)
    yr = qmatmul_ref(x, w, s, None)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-6)


def test_qmatmul_reuse_factor_block_k_invariance():
    """Paper C6: the reuse factor (block_k = K/RF) must not change results."""
    x = _int8((64, 128))
    w = _int8((128, 64))
    s = jnp.full((64,), 0.005, jnp.float32)
    outs = [
        np.asarray(ops.qmatmul(x, w, s, None, block_m=32, block_n=32, block_k=bk))
        for bk in (128, 64, 32, 16)   # RF = 1, 2, 4, 8
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=1e-6)


# ---------------------------------------------------------------------------
# multi_threshold
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,c,steps", [(16, 8, 3), (37, 20, 7), (64, 12, 15),
                                       (128, 72, 255), (5, 3, 1)])
def test_multi_threshold_matches_ref(m, c, steps):
    acc = jnp.asarray(RNG.integers(-5000, 5000, (m, c)).astype(np.int32))
    thr = jnp.asarray(np.sort(RNG.integers(-4000, 4000, (c, steps)), axis=1)
                      .astype(np.int32))
    y = ops.multi_threshold(acc, thr, block_m=16)
    yr = multi_threshold_ref(acc, thr)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yr))


def test_multi_threshold_range():
    """Output is a count in [0, S] — the act_bits integer code range."""
    acc = jnp.asarray(RNG.integers(-100, 100, (40, 10)).astype(np.int32))
    thr = jnp.asarray(np.sort(RNG.integers(-90, 90, (10, 7)), axis=1).astype(np.int32))
    y = np.asarray(ops.multi_threshold(acc, thr))
    assert y.min() >= 0 and y.max() <= 7


@pytest.mark.parametrize("m,k,n,steps", [(32, 64, 32, 7), (100, 70, 50, 15),
                                         (64, 128, 40, 3)])
def test_threshold_matmul_matches_ref(m, k, n, steps):
    x = _int8((m, k))
    w = _int8((k, n))
    thr = jnp.asarray(np.sort(RNG.integers(-30000, 30000, (n, steps)), axis=1)
                      .astype(np.int32))
    y = ops.threshold_matmul(x, w, thr, block_m=32, block_n=32, block_k=32)
    yr = threshold_matmul_ref(x, w, thr)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yr))


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("h,hkv", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("sq,sk", [(64, 64), (65, 65), (32, 96)])
def test_flash_attention_matches_ref(h, hkv, sq, sk):
    q = jnp.asarray(RNG.standard_normal((2, h, sq, 16)).astype(np.float32))
    k = jnp.asarray(RNG.standard_normal((2, hkv, sk, 16)).astype(np.float32))
    v = jnp.asarray(RNG.standard_normal((2, hkv, sk, 16)).astype(np.float32))
    o = ops.flash_attention(q, k, v, causal=True, block_q=32, block_k=32)
    orf = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(orf), rtol=2e-5, atol=2e-5)


def test_flash_attention_noncausal():
    q = jnp.asarray(RNG.standard_normal((1, 2, 64, 16)).astype(np.float32))
    k = jnp.asarray(RNG.standard_normal((1, 2, 64, 16)).astype(np.float32))
    v = jnp.asarray(RNG.standard_normal((1, 2, 64, 16)).astype(np.float32))
    o = ops.flash_attention(q, k, v, causal=False, block_q=32, block_k=32)
    orf = flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(o), np.asarray(orf), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window", [16, 33])
def test_flash_attention_sliding_window(window):
    q = jnp.asarray(RNG.standard_normal((1, 2, 96, 16)).astype(np.float32))
    k = jnp.asarray(RNG.standard_normal((1, 2, 96, 16)).astype(np.float32))
    v = jnp.asarray(RNG.standard_normal((1, 2, 96, 16)).astype(np.float32))
    o = ops.flash_attention(q, k, v, causal=True, window=window,
                            block_q=32, block_k=32)
    orf = flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(o), np.asarray(orf), rtol=2e-5, atol=2e-5)


def test_flash_attention_q_offset_decode_chunk():
    """Continuation chunk: q holds positions [32, 48) of a 48-long stream."""
    S = 48
    q_all = jnp.asarray(RNG.standard_normal((1, 2, S, 16)).astype(np.float32))
    k = jnp.asarray(RNG.standard_normal((1, 2, S, 16)).astype(np.float32))
    v = jnp.asarray(RNG.standard_normal((1, 2, S, 16)).astype(np.float32))
    full = flash_attention_ref(q_all, k, v, causal=True)
    tail = ops.flash_attention(q_all[:, :, 32:], k, v, causal=True,
                               q_offset=32, block_q=16, block_k=16)
    np.testing.assert_allclose(np.asarray(tail), np.asarray(full[:, :, 32:]),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16():
    q = jnp.asarray(RNG.standard_normal((1, 2, 64, 32))).astype(jnp.bfloat16)
    k = jnp.asarray(RNG.standard_normal((1, 2, 64, 32))).astype(jnp.bfloat16)
    v = jnp.asarray(RNG.standard_normal((1, 2, 64, 32))).astype(jnp.bfloat16)
    o = ops.flash_attention(q, k, v, causal=True, block_q=32, block_k=32)
    orf = flash_attention_ref(q, k, v, causal=True)
    assert o.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(o, np.float32), np.asarray(orf, np.float32),
        rtol=2e-2, atol=2e-2)


def test_flash_attention_matches_model_attention():
    """Kernel agrees with the model-side chunked_attention oracle (which the
    LM stack lowers) — ties the kernel layer to the model layer."""
    from repro.configs import get_config
    from repro.models.attention import chunked_attention

    cfg = get_config("llama3-8b").reduced()
    B, S = 2, 64
    q = jnp.asarray(RNG.standard_normal((B, S, cfg.n_heads, cfg.hd)).astype(np.float32))
    k = jnp.asarray(RNG.standard_normal((B, S, cfg.n_kv_heads, cfg.hd)).astype(np.float32))
    v = jnp.asarray(RNG.standard_normal((B, S, cfg.n_kv_heads, cfg.hd)).astype(np.float32))
    pos = jnp.arange(S)
    out_model = chunked_attention(cfg, q, k, v, pos, pos)      # (B,S,H,hd)
    out_kernel = ops.flash_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        causal=True, block_q=32, block_k=32,
    ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out_kernel), np.asarray(out_model),
                               rtol=3e-5, atol=3e-5)
