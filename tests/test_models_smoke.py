"""Per-architecture smoke tests: every assigned arch instantiates a REDUCED
same-family config and runs one forward + one train step + (where applicable)
one decode step on CPU, asserting output shapes and no NaNs."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models.model import Model
from repro.optim.adamw import make_optimizer
from repro.train.steps import TrainState, make_train_step

B, S = 2, 32


def _batch(cfg, key):
    ks = jax.random.split(key, 3)
    if cfg.embed_inputs:
        batch = {
            "tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab),
            "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab),
        }
    else:
        batch = {
            "embeds": jax.random.normal(ks[0], (B, S, cfg.d_model),
                                        jnp.dtype(cfg.dtype)),
            "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab),
        }
        if cfg.mrope:
            pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, None],
                                   (3, B, S))
            batch["positions"] = pos
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    logits, aux = model.train_logits(params, _batch(cfg, jax.random.PRNGKey(1)))
    assert logits.shape == (B, S, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.slow
def test_one_train_step(arch):
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = make_optimizer(base_lr=1e-3, warmup=1, total=10)
    state = TrainState(params=params, opt=opt.init(params))
    step = jax.jit(make_train_step(model, opt))
    new_state, metrics = step(state, _batch(cfg, jax.random.PRNGKey(1)))
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    delta = sum(
        float(jnp.sum(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(jax.tree.leaves(new_state.params),
                        jax.tree.leaves(state.params)))
    assert delta > 0.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_path(arch):
    """The prefill entry point (what prefill_32k cells lower) on the reduced
    config: shapes + finiteness."""
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {k: v for k, v in _batch(cfg, jax.random.PRNGKey(2)).items()
             if k != "labels"}
    logits = model.prefill(params, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if not get_config(a).encoder_only])
def test_one_decode_step(arch):
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    caches = model.cache_init(B, max_len=16)
    if cfg.embed_inputs:
        tok = jnp.zeros((B, 1), jnp.int32)
    else:
        tok = jnp.zeros((B, 1, cfg.d_model), jnp.dtype(cfg.dtype))
    logits, new_caches = model.decode_step(params, caches, tok,
                                           jnp.zeros((), jnp.int32))
    assert logits.shape == (B, 1, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    # cache structure preserved
    assert jax.tree.structure(new_caches) == jax.tree.structure(caches)


@pytest.mark.parametrize("arch", ["llama3-8b", "falcon-mamba-7b",
                                  "jamba-v0.1-52b", "h2o-danube-1.8b"])
def test_decode_matches_prefill(arch):
    """Token-by-token decode reproduces the full-sequence forward logits —
    the KV-cache/SSM-state bookkeeping is exact.

    MoE archs need an over-provisioned capacity factor here: prefill drops
    over-capacity tokens (by design) while decode routes every token, so with
    drops the two are legitimately different."""
    import dataclasses

    cfg = dataclasses.replace(get_config(arch).reduced(), capacity_factor=8.0)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    T = 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, T), 0, cfg.vocab)

    full_logits, _ = model.train_logits(params, {"tokens": toks})

    caches = model.cache_init(1, max_len=T)
    decode = jax.jit(model.decode_step)
    step_logits = []
    for t in range(T):
        lg, caches = decode(params, caches, toks[:, t: t + 1],
                            jnp.asarray(t, jnp.int32))
        step_logits.append(lg[:, 0])
    step_logits = jnp.stack(step_logits, axis=1)
    np.testing.assert_allclose(
        np.asarray(step_logits, np.float32), np.asarray(full_logits, np.float32),
        rtol=2e-2, atol=2e-2)   # f32 reduced configs; scan vs parallel numerics


def test_param_count_formula_matches_actual():
    """cfg.n_params() (used for MODEL_FLOPS=6ND) matches the real pytree."""
    for arch in ARCH_IDS:
        cfg = get_config(arch).reduced()
        model = Model(cfg)
        params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        actual = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
        assert actual == cfg.n_params(), (arch, actual, cfg.n_params())


def test_full_config_param_counts_sane():
    """Full (non-reduced) configs land near their advertised sizes."""
    expect = {
        "llama3-8b": (7.5e9, 8.6e9),
        "falcon-mamba-7b": (6.5e9, 8.0e9),
        "grok-1-314b": (2.9e11, 3.4e11),
        "internlm2-1.8b": (1.6e9, 2.2e9),
        "qwen1.5-4b": (3.0e9, 4.5e9),
        "jamba-v0.1-52b": (4.6e11 / 10, 6.0e10),   # 52B-ish
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).n_params()
        assert lo <= n <= hi, (arch, n)


def test_moe_active_params_less_than_total():
    cfg = get_config("grok-1-314b")
    assert cfg.n_active_params() < cfg.n_params()
    # top-2 of 8 experts: active ffn ~ 1/4 of total ffn
    ratio = cfg.n_active_params() / cfg.n_params()
    assert 0.15 < ratio < 0.55


def test_quantize_params_int8_serve_path():
    """Model.quantize_params produces int8 weights and the quantized forward
    stays close to the bf16 forward (paper C1 applied to the LM)."""
    cfg = get_config("llama3-8b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    qparams = model.quantize_params(params, bits=8)
    # blocks got int codes
    flat = jax.tree_util.tree_flatten_with_path(qparams)[0]
    int_leaves = [l for p, l in flat if l.dtype == jnp.int8]
    assert len(int_leaves) > 0
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab)
    lf, _ = model.train_logits(params, {"tokens": toks})
    lq, _ = model.train_logits(qparams, {"tokens": toks})
    pf = jax.nn.softmax(lf, -1)
    pq = jax.nn.softmax(lq, -1)
    # distributions close in TV distance
    tv = float(0.5 * jnp.max(jnp.sum(jnp.abs(pf - pq), axis=-1)))
    assert tv < 0.2, tv
