"""Fused direct-conv kernel: property-based bit-exactness.

The contract under test is the lowering-independence of the po2 export
contract: for any conv geometry, a ``Conv2D -> Relu -> Quant`` chain built
under the exporter's grid rules (po2 per-channel weight scales, bias on the
accumulator grid, po2 frozen activation scale) must produce the *same
integers* through

  * the unfused ``Graph.run`` float interpreter (half-up rounding),
  * the direct lowering's CPU fast path (XLA conv / shifted-window taps),
  * the im2col lowering (patch matrix + threshold matmul), and
  * the fused direct-conv Pallas kernel (interpret mode on CPU),

ties included. The property sweep covers strides, SAME/VALID padding,
K in {1, 3, 5}, odd H/W, channel counts that are not a multiple of any
block size, forced multi-block row grids, and tie-threshold inputs
(``s_out`` chosen so *every* step boundary lands exactly on the
accumulator grid — the half-up tie rule fires on every step).
"""

import numpy as np
import jax.numpy as jnp
import pytest

from _hypothesis_compat import given, settings, strategies as st

from repro.core.qir import Graph, Node, QuantSpec
from repro.deploy.lower import FusedConvThresholdStage, lower_graph


def _conv_out_hw(h, w, k, stride, padding):
    if padding == "SAME":
        return -(-h // stride), -(-w // stride)
    return (h - k) // stride + 1, (w - k) // stride + 1


def _po2_conv_graph(rng, h, w, c, f, k, stride, padding, bits, ties):
    """One Conv2D -> Relu -> Quant chain under the po2 export contract.

    Mirrors ``core.qir._export_ic``: integer weight codes times a po2
    per-channel scale (recorded in ``attrs["w_scale"]``), bias snapped to
    the accumulator grid, po2 frozen activation scale. With ``ties`` the
    activation scale makes every threshold boundary an exact accumulator
    integer, so every step decision is a tie the half-up rule must break.
    """
    in_scale = 0.5                                   # po2 input step
    w_int = rng.integers(-7, 8, (k * k * c, f)).astype(np.float32)
    s_w = (2.0 ** rng.integers(-2, 1, (f,))).astype(np.float32)   # po2
    w_hat = (w_int * s_w).reshape(k, k, c, f)
    grid = s_w * in_scale                            # accumulator step
    b = (rng.integers(-5, 6, (f,)).astype(np.float32)) * grid
    if ties:
        # boundary (i - 0.5) * s_out on the grid: s_out = 2 * min(grid)
        s_out = float(2.0 * grid.min())
    else:
        s_out = float(2.0 ** rng.integers(-1, 3))
    oh, ow = _conv_out_hw(h, w, k, stride, padding)
    g = Graph(inputs=["x"], outputs=["y"], meta={"in_scale": in_scale},
              initializers={"w": w_hat, "b": b, "ws": s_w})
    g.nodes = [
        Node("Conv2D", "conv", ["x", "w", "b"], ["h0"],
             attrs={"kernel": k, "stride": stride, "padding": padding,
                    "weight_bits": 4, "w_scale": "ws",
                    "in_shape": [h, w, c], "out_shape": [oh, ow, f]}),
        Node("Relu", "relu", ["h0"], ["h1"]),
        Node("Quant", "quant", ["h1"], ["y"], attrs={"scale": s_out},
             quant=QuantSpec(bits=bits, signed=False)),
    ]
    return g, in_scale, (oh, ow)


def _check_all_paths(rng, h, w, c, f, k, stride, padding, bits, ties,
                     block_h=None):
    g, in_scale, (oh, ow) = _po2_conv_graph(
        rng, h, w, c, f, k, stride, padding, bits, ties)
    direct = lower_graph(g, in_scale=in_scale, conv_lowering="direct")
    i2c = lower_graph(g, in_scale=in_scale, conv_lowering="im2col")
    st_d, st_i = direct.stages[0], i2c.stages[0]
    assert isinstance(st_d, FusedConvThresholdStage)
    assert st_d.lowering == "direct" and st_i.lowering == "im2col"

    x_int = jnp.asarray(rng.integers(-15, 16, (2, h, w, c)), jnp.int32)

    # 1) unfused float interpreter (half-up reference), bit for bit
    run = g.run({"x": np.asarray(x_int, np.float32) * in_scale})["y"]
    y_d = np.asarray(st_d.apply_fast(x_int)).reshape(2, oh, ow, f)
    np.testing.assert_array_equal(y_d * st_d.stage.out_scale, run)
    # 2) the two lowerings agree exactly
    y_i = np.asarray(st_i.apply_fast(x_int)).reshape(2, oh, ow, f)
    np.testing.assert_array_equal(y_d, y_i)
    np.testing.assert_array_equal(np.asarray(st_d.apply_ref(x_int)), y_d)
    # 3) the fused Pallas kernel (interpret mode), incl. forced row blocks
    from repro.kernels import ops

    y_k = ops.conv_threshold(
        x_int, st_d.stage.w_int, st_d.stage.thresholds, kernel=k,
        stride=stride, padding=padding, out_h=oh, out_w=ow,
        block_h=block_h, interpret=True)
    np.testing.assert_array_equal(np.asarray(y_k), y_d)


@settings(max_examples=12)
@given(
    st.sampled_from([1, 3, 5]),          # kernel
    st.sampled_from([1, 2]),             # stride
    st.sampled_from(["SAME", "VALID"]),  # padding
    st.sampled_from([5, 7, 9]),          # odd H
    st.sampled_from([5, 7, 9]),          # odd W
    st.sampled_from([1, 3, 5]),          # C: never a block-size multiple
    st.sampled_from([2, 4, 5]),          # F
    st.sampled_from([2, 3]),             # act bits
    st.booleans(),                       # tie-threshold inputs
    st.integers(0, 10_000),              # data seed
)
def test_direct_conv_bit_exact_property(k, stride, padding, h, w, c, f,
                                        bits, ties, seed):
    rng = np.random.default_rng(seed)
    _check_all_paths(rng, h, w, c, f, k, stride, padding, bits, ties)


def test_direct_conv_forced_multiblock_grid():
    """block_h=1/2 forces the padded multi-block row grid (OH % block_h
    handling) on odd output heights."""
    rng = np.random.default_rng(99)
    for bh in (1, 2):
        _check_all_paths(rng, 7, 5, 3, 4, 3, 2, "SAME", 3, False,
                         block_h=bh)


def test_direct_conv_every_boundary_is_a_tie():
    """Deterministic tie sweep: s_out = 2*grid makes every threshold an
    exact accumulator integer — half-up must count the boundary in."""
    rng = np.random.default_rng(7)
    _check_all_paths(rng, 6, 6, 2, 3, 3, 1, "SAME", 2, True)
    _check_all_paths(rng, 8, 6, 4, 3, 5, 1, "VALID", 3, True)


def test_plan_conv_blocks_shapes():
    """The autotuner sizes row blocks from the output tile, within bounds."""
    from repro.kernels.ops import plan_conv_blocks

    assert plan_conv_blocks(32, 32, 16) == 8      # 256-row target
    assert plan_conv_blocks(1, 1024, 4) == 1      # never 0
    assert plan_conv_blocks(5, 3, 8) == 5         # capped at out_h
    # accumulator VMEM cap kicks in for huge channel counts
    assert plan_conv_blocks(64, 64, 8192, acc_budget_bytes=1 << 21) == 1


def test_conv_lowering_env_override(monkeypatch):
    """REPRO_CONV_LOWERING flips the default; explicit arg still wins;
    junk values fail loudly."""
    from repro.deploy.lower import default_conv_lowering

    monkeypatch.delenv("REPRO_CONV_LOWERING", raising=False)
    assert default_conv_lowering() == "direct"
    monkeypatch.setenv("REPRO_CONV_LOWERING", "im2col")
    assert default_conv_lowering() == "im2col"
    rng = np.random.default_rng(1)
    g, in_scale, _ = _po2_conv_graph(rng, 6, 6, 2, 3, 3, 1, "SAME", 2, False)
    assert lower_graph(g, in_scale=in_scale).stages[0].lowering == "im2col"
    assert lower_graph(g, in_scale=in_scale,
                       conv_lowering="direct").stages[0].lowering == "direct"
    monkeypatch.setenv("REPRO_CONV_LOWERING", "bogus")
    with pytest.raises(ValueError):
        lower_graph(g, in_scale=in_scale)
    with pytest.raises(ValueError):
        lower_graph(g, in_scale=in_scale, conv_lowering="also-bogus")


def test_conv_threshold_rejects_bad_geometry():
    from repro.kernels import conv_threshold as ct

    x = jnp.zeros((1, 4, 4, 2), jnp.int32)
    w2d = jnp.zeros((3 * 3 * 2, 4), jnp.int8)
    thr = jnp.zeros((4, 3), jnp.int32)
    with pytest.raises(AssertionError):
        ct.conv_threshold(x, w2d, thr, kernel=3, stride=1, out_h=4,
                          out_w=2, block_h=3, interpret=True)  # 4 % 3 != 0
