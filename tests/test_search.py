"""Hardware-aware NAS drivers: ASHA promotion semantics, BO-lite vs random,
Pareto front extraction (paper §3.1.1 / §3.2.1)."""

import math

import numpy as np
import pytest

from repro.core.search import Choice, asha_search, bo_search, pareto_front, sample_config

SPACE = [
    Choice("filters", (2, 4, 8, 16)),
    Choice("kernel", (1, 2, 3)),
    Choice("bits", (1, 2, 3, 4, 8)),
]


def _objective_planted(cfg, budget, rng):
    """Smooth objective with a planted optimum at (16, 3, 4); budget adds
    resolution (less noise), as in real epochs-as-budget searches."""
    score = -abs(cfg["filters"] - 16) / 16 - abs(cfg["kernel"] - 3) / 3 \
        - abs(cfg["bits"] - 4) / 8
    noise = rng.normal(0, 0.25 / math.sqrt(budget))
    return score + noise


def test_asha_finds_planted_optimum_region():
    best, trials = asha_search(_objective_planted, SPACE, n_trials=64,
                               r_min=1, eta=2, max_rung=4, seed=0)
    assert best.config["filters"] >= 8            # near-optimal region
    assert best.rung >= 2                         # actually promoted


def test_asha_spends_more_budget_on_good_trials():
    best, trials = asha_search(_objective_planted, SPACE, n_trials=32, seed=1)
    budgets = np.array([t.budget_used for t in trials])
    scores = np.array([t.score for t in trials])
    # correlation between final score and budget spent must be positive
    good = budgets[scores >= np.median(scores)].mean()
    bad = budgets[scores < np.median(scores)].mean()
    assert good > bad


def test_asha_halts_bad_trials():
    _, trials = asha_search(_objective_planted, SPACE, n_trials=32, seed=2)
    assert any(not t.alive for t in trials)       # some were halted


def test_bo_beats_random_on_average():
    rng = np.random.default_rng(0)

    def noiseless(cfg, budget, rng_):
        return _objective_planted(cfg, 10_000, rng)

    best_bo, hist = bo_search(noiseless, SPACE, n_trials=40, n_startup=8, seed=3)
    bo_best_score = max(s for _, s in hist)
    rand_scores = [noiseless(sample_config(SPACE, rng), 1, rng)
                   for _ in range(40)]
    assert bo_best_score >= np.max(rand_scores) - 0.05


def test_pareto_front():
    # (cost, accuracy)
    pts = [(1.0, 0.5), (2.0, 0.8), (3.0, 0.7), (0.5, 0.2), (2.5, 0.9)]
    front = pareto_front(pts)
    assert set(front) == {3, 0, 1, 4}             # 2 is dominated by 1


def test_sample_config_covers_space():
    rng = np.random.default_rng(0)
    seen = {sample_config(SPACE, rng)["bits"] for _ in range(200)}
    assert seen == {1, 2, 3, 4, 8}
