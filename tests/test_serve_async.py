"""Dispatch-engine tests: async replica overlap as an exact discrete-event
simulation, sync bit-identity, and the placement/admission bugs the
blocking router used to hide.

The harness is ``repro.serve.sim.ScriptedWaveModel``: a fake executor
speaking the ``submit_wave_async`` protocol — submitting a wave
*schedules* its completion on the manual clock (``ready_t = max(now,
busy_until) + service_s``) without advancing it, the way a real device
runs a wave in the background under JAX async dispatch. Each instance
serializes its own waves (one device, one pipeline); instances built by a
pool factory are independent, so waves on different replicas overlap.
Every expected latency below is worked out by hand, not by re-running the
router.
"""

import numpy as np
import pytest

from repro.serve import (
    AsyncEngine,
    ManualClock,
    Router,
    RouterConfig,
    ServiceModel,
    SyncEngine,
    queued_waves,
)
from repro.serve.sim import scripted_pool as _pool


# ---------------------------------------------------------------------------
# overlap: max, not sum
# ---------------------------------------------------------------------------

def test_two_replicas_overlap_in_max_not_sum_of_service_times():
    """Two full waves submitted back to back at t=0 on a two-replica pool:
    async they run concurrently (3ms || 5ms -> all done at 5ms); sync they
    serialize (3ms + 5ms -> 8ms)."""
    for engine, expect_end, expect_done in (
            (AsyncEngine(), 0.005, [0.003, 0.003, 0.005, 0.005]),
            (SyncEngine(), 0.008, [0.003, 0.003, 0.008, 0.008])):
        clock = ManualClock()
        pool = _pool(clock, [0.003, 0.005])
        router = Router({"m": pool}, RouterConfig(max_wait_ms=1.0),
                        clock=clock, engine=engine)
        reqs = [router.submit("m", np.ones((2,), np.int32),
                              arrival_t=0.0) for _ in range(4)]
        router.drain()
        assert clock.now() == pytest.approx(expect_end), type(engine)
        got = [r.done_t for r in reqs]
        np.testing.assert_allclose(got, expect_done, rtol=1e-12,
                                   err_msg=str(type(engine)))
        assert all(r.result is not None for r in reqs)
        # one wave per replica either way — the *schedule* differs
        assert [len(r.model.calls) for r in pool.replicas] == [1, 1]


def test_single_replica_serializes_waves_even_async():
    """One replica is one pipeline: two async waves on it run back to back
    (busy_until), not on top of each other."""
    clock = ManualClock()
    pool = _pool(clock, [0.003])
    router = Router({"m": pool}, RouterConfig(max_wait_ms=1.0),
                    clock=clock, engine=AsyncEngine())
    reqs = [router.submit("m", np.ones((2,), np.int32), arrival_t=0.0)
            for _ in range(4)]
    router.drain()
    assert [r.done_t for r in reqs] == \
        pytest.approx([0.003, 0.003, 0.006, 0.006])


def test_completions_settle_in_event_order():
    """Wave 1 (5ms, replica 0) is submitted before wave 2 (3ms, replica 1)
    but finishes after it: the reap must settle wave 2 first, so metrics
    see completions in event time order, not submission order."""
    clock = ManualClock()
    pool = _pool(clock, [0.005, 0.003])
    router = Router({"m": pool}, RouterConfig(max_wait_ms=1.0),
                    clock=clock, engine=AsyncEngine())
    reqs = [router.submit("m", np.ones((2,), np.int32), arrival_t=0.0)
            for _ in range(4)]
    router.drain()
    assert [r.done_t for r in reqs] == \
        pytest.approx([0.005, 0.005, 0.003, 0.003])
    lane = router.lanes["m"]
    times = [t for t, _ in lane.metrics._completions]
    assert times == sorted(times)          # settled in event order
    waves = [t for t, *_ in lane.metrics._waves]
    assert waves == sorted(waves)


def test_async_run_trace_overlap_exact_hand_sim():
    """mb=2, service=10ms, two replicas, arrivals [0,1,2,3] ms.

    Async: wave(r0,r1) submits @1ms on replica0 -> done 11ms; wave(r2,r3)
    submits @3ms on replica1, overlapping -> done 13ms.
      latencies = [11, 10, 11, 10] ms, trace ends at 13ms.
    Sync: wave 1 blocks the loop 1..11ms, r2/r3 arrive late (arrival_t
    kept), wave 2 runs 11..21ms.
      latencies = [11, 10, 19, 18] ms, trace ends at 21ms.
    """
    from repro.serve import replay_trace

    cases = ((AsyncEngine(), [11.0, 10.0, 11.0, 10.0], 0.013),
             (SyncEngine(), [11.0, 10.0, 19.0, 18.0], 0.021))
    for engine, expect_ms, expect_end in cases:
        clock = ManualClock()
        pool = _pool(clock, [0.010, 0.010])
        router = Router({"m": pool}, RouterConfig(max_wait_ms=5.0),
                        clock=clock, engine=engine)
        trace = replay_trace(np.asarray([0.0, 1.0, 2.0, 3.0]) * 1e-3)
        reqs = router.run_trace("m", trace,
                                lambda i: np.ones((4,), np.int32))
        got_ms = [r.latency_s * 1e3 for r in reqs]
        np.testing.assert_allclose(got_ms, expect_ms, rtol=1e-9,
                                   err_msg=str(type(engine)))
        assert clock.now() == pytest.approx(expect_end), type(engine)
        snap = router.stats()["m"]["metrics"]
        assert snap.p99_ms == pytest.approx(np.percentile(expect_ms, 99))
        assert snap.wave_service_p50_ms == pytest.approx(10.0)


def test_async_backpressure_caps_inflight_per_replica():
    """max_inflight=1 on one replica: the second wave's dispatch must
    block-reap the first before submitting, so submission times (and thus
    completions) serialize with no device-side queue."""
    clock = ManualClock()
    pool = _pool(clock, [0.004])
    router = Router({"m": pool}, RouterConfig(max_wait_ms=1.0),
                    clock=clock, engine=AsyncEngine(max_inflight=1))
    for _ in range(4):
        router.submit("m", np.ones((2,), np.int32), arrival_t=0.0)
    # wave 1 in flight; wave 2's dispatch reaped wave 1 first
    assert router.lanes["m"].n_inflight == 1
    assert clock.now() == pytest.approx(0.004)
    router.drain()
    assert clock.now() == pytest.approx(0.008)
    with pytest.raises(ValueError):
        AsyncEngine(max_inflight=0)


# ---------------------------------------------------------------------------
# sync bit-identity through the engine seam
# ---------------------------------------------------------------------------

def test_sync_engine_bit_identical_to_default_hand_trace():
    """The PR-5 hand-simulated 5-request trace, replayed through the
    default router and through an explicit SyncEngine: latencies, wave
    schedule, and percentiles must match to the bit (the engine seam adds
    no timing)."""
    from repro.serve import replay_trace
    from tests.test_serve import ScriptedModel

    results = []
    for engine in (None, SyncEngine()):
        clock = ManualClock()
        model = ScriptedModel(clock, service_s=0.003, micro_batch=2)
        router = Router({"m": model}, RouterConfig(max_wait_ms=5.0),
                        clock=clock, engine=engine)
        trace = replay_trace(np.asarray([0.0, 1.0, 10.0, 11.0, 30.0]) * 1e-3)
        reqs = router.run_trace("m", trace,
                                lambda i: np.ones((4,), np.int32))
        snap = router.stats()["m"]["metrics"]
        results.append(([r.latency_s for r in reqs], model.calls,
                        (snap.p50_ms, snap.p90_ms, snap.p99_ms)))
    (lat_a, calls_a, p_a), (lat_b, calls_b, p_b) = results
    assert lat_a == lat_b                  # bit-identical, not approx
    assert calls_a == calls_b == [(2, 2), (2, 2), (1, 2)]
    assert p_a == p_b
    np.testing.assert_allclose(np.asarray(lat_a) * 1e3,
                               [4.0, 3.0, 4.0, 3.0, 8.0], rtol=1e-9)


# ---------------------------------------------------------------------------
# placement: least work needs a real work estimate (bugfix)
# ---------------------------------------------------------------------------

def test_placement_avoids_busy_replica_with_service_model_no_slo():
    """No SLO controller, but a lane ServiceModel: placement must charge
    the modeled wave time, so a replica with a slow wave in flight loses
    to an idle one. (With the old work_s=0 charge all replicas tie forever
    and the tie-break — fewest dispatches, then index — would have sent
    wave 3 back to the *busy* replica 0.)"""
    clock = ManualClock()
    pool = _pool(clock, [0.005, 0.001])
    svc = ServiceModel(works=[("s", 0)], sec_per_cycle=0.004 / 9)
    router = Router({"m": pool}, RouterConfig(max_wait_ms=1.0),
                    clock=clock, service_models={"m": svc},
                    engine=AsyncEngine())
    lane = router.lanes["m"]
    assert lane.slo is None
    assert lane.work_estimate_s() == pytest.approx(0.004)
    x = np.ones((2,), np.int32)
    for _ in range(4):                      # wave1 -> r0, wave2 -> r1
        router.submit("m", x, arrival_t=0.0)
    r0, r1 = pool.replicas
    assert (r0.n_dispatched, r1.n_dispatched) == (1, 1)
    assert r0.outstanding_s == pytest.approx(0.004)
    clock.advance(0.002)
    router.step()                           # reaps wave2 (done @1ms) only
    assert (r0.n_inflight, r1.n_inflight) == (1, 0)
    for _ in range(2):                      # wave3: r0 busy -> r1 again
        router.submit("m", x, arrival_t=clock.now())
    assert (r0.n_dispatched, r1.n_dispatched) == (1, 2)
    assert len(r1.model.calls) == 2
    router.drain()
    assert r0.outstanding_s == r1.outstanding_s == 0.0


def test_placement_falls_back_to_measured_ewma_without_any_model():
    """No SLO, no ServiceModel: after the first completions the lane's
    EWMA of measured wave times becomes the placement charge (the last
    line of defense against the silent round-robin degeneration)."""
    clock = ManualClock()
    pool = _pool(clock, [0.005, 0.001])
    router = Router({"m": pool}, RouterConfig(max_wait_ms=1.0),
                    clock=clock, engine=AsyncEngine())
    lane = router.lanes["m"]
    assert lane.work_estimate_s() == 0.0    # nothing observed yet
    x = np.ones((2,), np.int32)
    for _ in range(4):
        router.submit("m", x, arrival_t=0.0)
    router.drain()
    # completions settle in event order: 1ms wave seeds the EWMA, 5ms
    # wave blends in at alpha=0.25
    assert lane.ewma_service_s == pytest.approx(0.75 * 0.001 + 0.25 * 0.005)
    assert lane.work_estimate_s() == lane.ewma_service_s
    # the next wave charges that estimate at placement
    router.submit("m", x, arrival_t=clock.now())
    router.submit("m", x, arrival_t=clock.now())
    charged = [r.outstanding_s for r in pool.replicas]
    assert max(charged) == pytest.approx(lane.ewma_service_s)
    router.drain()


# ---------------------------------------------------------------------------
# admission: in-flight waves are queue delay (bugfix)
# ---------------------------------------------------------------------------

def test_admission_counts_inflight_waves_hand_simulated():
    """One replica, mb=2, 10ms waves, 25ms budget, 2ms max-wait, six
    arrivals at t=0. Hand-worked admission estimates (est = max_wait +
    (backlog+1)*service):

      r0, r1: backlog 0            -> est 12ms, admit; wave 1 in flight
      r2, r3: 1 wave in flight     -> est 22ms, admit; wave 2 in flight
      r4, r5: 2 waves in flight    -> est 32ms > 25ms -> SHED

    The pre-fix router priced backlog as len(pending)//mb with no
    in-flight term: every estimate would have been 12ms and r4/r5 would
    have been admitted into a queue already worth ~30ms of service —
    exactly the silent SLO violation the blocking engine never exposed
    (its dispatch blocked the clock, so `lag_s` papered over the hole).
    """
    clock = ManualClock()
    pool = _pool(clock, [0.010])
    svc = ServiceModel(works=[("s", 0)], sec_per_cycle=0.010 / 9)
    assert svc.wave_service_s(2) == pytest.approx(0.010)
    router = Router(
        {"m": pool},
        RouterConfig(max_wait_ms=2.0, p99_budget_ms=25.0),
        clock=clock, service_models={"m": svc}, engine=AsyncEngine())
    reqs = [router.submit("m", np.ones((2,), np.int32), arrival_t=0.0)
            for _ in range(6)]
    assert [r.shed for r in reqs] == [False] * 4 + [True] * 2
    router.drain()
    served = [r for r in reqs if not r.shed]
    np.testing.assert_allclose([r.latency_s for r in served],
                               [0.010, 0.010, 0.020, 0.020], rtol=1e-9)
    # every served request inside the budget — the point of shedding
    assert max(r.latency_s for r in served) * 1e3 <= 25.0
    snap = router.stats()["m"]["metrics"]
    assert snap.n_shed == 2 and snap.n_completed == 4


def test_admission_divides_backlog_across_pool_workers():
    """Same setup as above but TWO replicas: the pool drains two waves per
    service period, so estimates fall by ~half and all six requests fit
    the 25ms budget. est = max_wait + ceil((inflight+1)/2)*service:
    r0/r1 12ms, r2/r3 12ms (1 in flight), r4/r5 22ms (2 in flight) — all
    admitted; waves land [10, 10, 20] ms."""
    clock = ManualClock()
    pool = _pool(clock, [0.010, 0.010])
    svc = ServiceModel(works=[("s", 0)], sec_per_cycle=0.010 / 9)
    router = Router(
        {"m": pool},
        RouterConfig(max_wait_ms=2.0, p99_budget_ms=25.0),
        clock=clock, service_models={"m": svc}, engine=AsyncEngine())
    reqs = [router.submit("m", np.ones((2,), np.int32), arrival_t=0.0)
            for _ in range(6)]
    assert [r.shed for r in reqs] == [False] * 6
    router.drain()
    np.testing.assert_allclose(
        [r.latency_s for r in reqs],
        [0.010, 0.010, 0.010, 0.010, 0.020, 0.020], rtol=1e-9)
    assert max(r.latency_s for r in reqs) * 1e3 <= 25.0


def test_queued_waves_formula():
    # empty queue: only your own wave (the controller's +1) remains
    assert queued_waves(0, 4) == 0
    # partial wave ahead: you join it — still zero *extra* waves
    assert queued_waves(3, 4) == 0
    # a full wave queued ahead of the one you join
    assert queued_waves(4, 4) == 1
    assert queued_waves(7, 4) == 1
    assert queued_waves(8, 4) == 2
    # in-flight waves are queue delay too
    assert queued_waves(0, 4, n_inflight=2) == 2
    assert queued_waves(5, 4, n_inflight=1) == 2
    with pytest.raises(ValueError):
        queued_waves(1, 0)
    with pytest.raises(ValueError):
        queued_waves(-1, 4)


# ---------------------------------------------------------------------------
# mask validation survives python -O (bugfix)
# ---------------------------------------------------------------------------

class _LyingModel:
    """Fake executor violating the padding contract: claims every row of
    the padded wave is valid."""

    default_micro_batch = 4

    def submit_wave(self, x, valid=None, micro_batch=None):
        mb = int(micro_batch or self.default_micro_batch)
        return np.zeros((mb, 1), np.float32), np.ones(mb, bool)


@pytest.mark.parametrize("engine", [SyncEngine(), AsyncEngine()])
def test_lying_executor_mask_raises_runtime_error(engine):
    clock = ManualClock()
    router = Router({"m": _LyingModel()}, RouterConfig(max_wait_ms=1.0),
                    clock=clock, engine=engine)
    router.submit("m", np.ones((2,), np.int32))
    clock.advance(0.002)
    with pytest.raises(RuntimeError, match="mask"):
        router.step()
        router.drain()


# ---------------------------------------------------------------------------
# the shim under an async engine
# ---------------------------------------------------------------------------

def test_tiny_model_server_shim_settles_results_under_async_engine():
    from repro.serving.engine import TinyModelServer

    class _Echo:
        default_micro_batch = 4

        def submit_wave(self, x, valid=None, micro_batch=None):
            x = np.asarray(x)
            mb = int(micro_batch or self.default_micro_batch)
            n = x.shape[0]
            mask = np.concatenate([np.ones(n, bool), np.zeros(mb - n, bool)])
            y = np.zeros((mb,) + x.shape[1:], x.dtype)
            y[:n] = x * 2
            return y, mask

    server = TinyModelServer({"echo": _Echo()}, max_batch=4,
                             engine=AsyncEngine())
    reqs = [server.submit("echo", np.full((3,), i, np.int32))
            for i in range(5)]
    server.run_until_drained()
    assert all(r.result is not None for r in reqs)
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(r.result, np.full((3,), 2 * i))
