"""Deterministic-clock unit tests for the MLPerf-Tiny scenario runtime.

The scenario functions (``deploy.scenarios``) read wall time only through
the process-wide injectable obs timer (``repro.obs.timer``), so a fake
clock installed there makes every latency, percentile, and throughput
number exactly computable: the fake ``infer`` advances the clock by a
scripted service time, ``sleep`` advances it by the requested amount, and
the tests then reproduce the expected numbers with independent arithmetic
— percentile math, MultiStream step accounting, Offline per-query
amortization, the Server mode's Poisson arrival bookkeeping (latency =
queueing delay + service), and the ``stage_ms`` breakdown summing to the
end-to-end latency.
"""

import numpy as np
import pytest

from repro.deploy.scenarios import (
    _percentiles,
    multi_stream,
    offline,
    server_poisson,
    server_streaming,
    single_stream,
    streaming_pipeline,
)
from repro.obs import timer as obs_timer


class FakeClock:
    """now/sleep stand-in: time only moves when told to."""

    def __init__(self):
        self.t = 0.0

    def now(self) -> float:
        return self.t

    # historical alias kept so tests can read the clock either way
    perf_counter = now

    def sleep(self, s: float):
        assert s >= 0
        self.t += s

    def advance(self, s: float):
        self.t += s


@pytest.fixture()
def clock():
    ck = FakeClock()
    with obs_timer.fake(ck):
        yield ck


def _mk(i):
    return np.zeros((4,), np.int32)


def test_percentile_math_matches_numpy():
    lats_s = [0.001 * (i + 1) for i in range(10)]
    p = _percentiles(lats_s)
    a = np.asarray(lats_s) * 1e3
    assert p["p50"] == float(np.percentile(a, 50))
    assert p["p90"] == float(np.percentile(a, 90))
    assert p["p99"] == float(np.percentile(a, 99))


def test_single_stream_reports_exact_latencies(clock):
    service = [0.004, 0.002, 0.010, 0.001, 0.003, 0.005, 0.007, 0.006]
    calls = []

    def infer(x):
        # warmup calls (3) then the measured queries, in order
        s = 0.001 if len(calls) < 3 else service[len(calls) - 3]
        calls.append(s)
        clock.advance(s)
        return np.zeros((1, 2), np.float32)

    rep = single_stream(infer, _mk, n_queries=len(service), warmup=3)
    expect = np.asarray(service) * 1e3
    assert rep.n_queries == len(service)
    assert rep.p50_ms == pytest.approx(float(np.percentile(expect, 50)))
    assert rep.p90_ms == pytest.approx(float(np.percentile(expect, 90)))
    assert rep.p99_ms == pytest.approx(float(np.percentile(expect, 99)))
    # back-to-back: the span is exactly the sum of service times
    assert rep.throughput_qps == pytest.approx(
        len(service) / sum(service))


def test_multi_stream_applies_step_latency_to_every_stream(clock):
    step_s = 0.005
    seen = []

    def infer(xb):
        seen.append(xb.shape)
        clock.advance(step_s)
        return np.zeros((xb.shape[0], 2), np.float32)

    rep = multi_stream(infer, _mk, n_streams=4, n_queries=12, warmup=1)
    # 12 queries / 4 streams = 3 steps (+1 warmup), all batched by 4
    assert seen == [(4, 4)] * 4
    assert rep.n_queries == 12
    assert rep.p50_ms == pytest.approx(step_s * 1e3)
    assert rep.p99_ms == pytest.approx(step_s * 1e3)
    assert rep.throughput_qps == pytest.approx(12 / (3 * step_s))


def test_offline_amortizes_batch_latency_per_query(clock):
    span_s = 0.064

    def infer(xb):
        clock.advance(span_s)
        return np.zeros((xb.shape[0], 2), np.float32)

    rep = offline(infer, _mk, n_samples=32, warmup=2)
    assert rep.extras["batch"] == 32
    assert rep.p50_ms == pytest.approx(span_s / 32 * 1e3)
    assert rep.throughput_qps == pytest.approx(32 / span_s)


def test_server_poisson_latency_is_queueing_plus_service(clock):
    """Reproduce the Server scenario's bookkeeping exactly: FIFO single
    worker, deterministic service, Poisson arrivals regenerated from the
    same seed — reported latency must equal completion - arrival."""
    qps, n, seed, service = 250.0, 24, 3, 0.007

    def infer(x):
        clock.advance(service)
        return np.zeros((1, 2), np.float32)

    rep = server_poisson(infer, _mk, qps=qps, n_queries=n, seed=seed,
                         warmup=2)
    arrivals = np.cumsum(
        np.random.default_rng(seed).exponential(1.0 / qps, n))
    expect, done = [], 0.0
    for a in arrivals:
        start = max(a, done)          # queue behind the previous completion
        done = start + service
        expect.append(done - a)
    expect_ms = np.asarray(expect) * 1e3
    assert rep.n_queries == n
    assert rep.p50_ms == pytest.approx(float(np.percentile(expect_ms, 50)))
    assert rep.p99_ms == pytest.approx(float(np.percentile(expect_ms, 99)))
    # offered load (service/interarrival ~ 1.75) forces real queueing:
    # tail latency must exceed bare service time
    assert rep.p99_ms > service * 1e3
    assert rep.extras["offered_qps"] == qps
    assert rep.throughput_qps == pytest.approx(
        n / (done - arrivals[0]))


def test_server_poisson_reuses_warm_program_per_query(clock):
    """Satellite: the Poisson loop must pre-materialize every query and
    run exactly warmup + 1 discarded-warm + n_queries inferences — the
    compile/warm work happens before the clock starts, never per query."""
    calls = []

    def infer(x):
        calls.append(np.asarray(x).shape)
        clock.advance(0.002)
        return np.zeros((1, 2), np.float32)

    made = []
    def mk(i):
        made.append(i)
        return np.zeros((4,), np.int32)

    rep = server_poisson(infer, mk, qps=400.0, n_queries=6, seed=1,
                         warmup=2)
    assert len(calls) == 2 + 1 + 6          # warmup, discarded warm, timed
    assert all(s == (1, 4) for s in calls)  # pre-batched (1, d) queries
    assert made == list(range(6))           # pool built once, up front
    assert rep.n_queries == 6


def test_server_streaming_exact_accounting_under_fake_clock(clock):
    """ServerStreaming through the real router under the fake clock: a
    zero-service wave executor makes every latency pure batching wait,
    reproduced here by an independent simulation of the documented
    contract (pairs dispatch on fill, partial waves at the deadline)."""
    waves = []

    class FakeCompiled:
        default_micro_batch = 2

        def submit_wave(self, x, valid=None, micro_batch=None):
            mb = int(micro_batch or self.default_micro_batch)
            n = np.asarray(x).shape[0]
            waves.append(n)
            mask = np.concatenate([np.ones(n, bool), np.zeros(mb - n, bool)])
            return np.zeros((mb, 2), np.float32), mask

    qps, n, seed, wait_ms = 250.0, 9, 4, 6.0
    rep = server_streaming(FakeCompiled(), _mk, qps=qps, n_queries=n,
                           seed=seed, max_wait_ms=wait_ms, micro_batch=2,
                           warmup=1)
    # independent reference: same arrivals (same seed), same batching rules
    arrivals = np.cumsum(
        np.random.default_rng(seed).exponential(1.0 / qps, n))
    w = wait_ms / 1e3
    expect, exp_waves, pending = [], [], []
    for a in arrivals:
        while pending and pending[0] + w < a:      # deadline flush first
            t = pending[0] + w
            expect.extend(t - p for p in pending)
            exp_waves.append(len(pending))
            pending = []
        pending.append(a)
        if len(pending) == 2:                      # full wave on fill
            expect.extend(a - p for p in pending)
            exp_waves.append(2)
            pending = []
    if pending:                                    # tail: deadline flush
        t = pending[0] + w
        expect.extend(t - p for p in pending)
        exp_waves.append(len(pending))
    expect_ms = np.asarray(sorted(expect)) * 1e3
    assert waves[1:] == exp_waves                  # waves[0] is the warmup
    assert rep.scenario == "ServerStreaming"
    assert rep.n_queries == n and rep.extras["shed"] == 0
    assert rep.extras["micro_batch"] == 2
    assert rep.extras["n_waves"] == len(exp_waves)
    got = np.asarray(sorted(
        [rep.p50_ms, rep.p90_ms, rep.p99_ms]))
    want = np.asarray([float(np.percentile(expect_ms, q))
                       for q in (50, 90, 99)])
    np.testing.assert_allclose(np.sort(want), got, rtol=1e-9, atol=1e-12)


def test_server_streaming_sheds_into_extras(clock):
    """With a p99 budget and a scripted service model the report carries
    the shed accounting and the met-SLO flag."""
    from repro.serve import ServiceModel

    class SlowWave:
        default_micro_batch = 2

        def submit_wave(self, x, valid=None, micro_batch=None):
            mb = int(micro_batch or 2)
            n = np.asarray(x).shape[0]
            clock.advance(0.050)               # 50ms per wave
            mask = np.concatenate([np.ones(n, bool), np.zeros(mb - n, bool)])
            return np.zeros((mb, 2), np.float32), mask

    svc = ServiceModel(works=[("s", 0)], sec_per_cycle=0.050 / 9)
    rep = server_streaming(SlowWave(), _mk, qps=500.0, n_queries=40,
                           seed=0, max_wait_ms=2.0, micro_batch=2,
                           p99_budget_ms=120.0, service_model=svc,
                           warmup=0)
    assert rep.extras["shed"] > 0
    assert rep.extras["served"] + rep.extras["shed"] == 40
    assert rep.extras["shed_rate"] == pytest.approx(
        rep.extras["shed"] / 40)
    assert rep.extras["p99_budget_ms"] == 120.0
    assert rep.extras["met_slo"] == (rep.p99_ms <= 120.0)


def test_stage_ms_breakdown_sums_to_end_to_end(clock, monkeypatch):
    """``stage_latencies`` accounting: with scripted per-stage costs the
    breakdown must recover each stage cost exactly and sum to the
    end-to-end latency of the chained pipeline."""
    from repro.core.qir import export_qmlp
    from repro.deploy import compile_graph
    from repro.models.tiny import KWSMLP
    import jax

    model = KWSMLP(width=16)
    params = model.init(jax.random.PRNGKey(0))
    hidden_defs, _ = model.layers()
    graph = export_qmlp(hidden_defs, params["hidden"], params["head"])
    cm = compile_graph(graph, in_scale=1.0 / 127.0, use_pallas=False)

    # stage_latencies reads the obs timer, already faked by the fixture
    costs = [0.002 * (i + 1) for i in range(len(cm.schedule.stages))]

    def fake_fn(c):
        def fn(h):
            clock.advance(c)
            return h
        return fn

    monkeypatch.setattr(cm, "_stage_fns", [fake_fn(c) for c in costs])
    x = np.zeros((1, 490), np.int32)
    breakdown = cm.stage_latencies(x, iters=3)
    assert [b["stage"] for b in breakdown] == \
        [s.name for s in cm.schedule.stages]
    for b, c in zip(breakdown, costs):
        assert b["ms"] == pytest.approx(c * 1e3)
    # the breakdown is additive: sum == end-to-end pipeline latency
    t0 = clock.perf_counter()
    h = x
    for fn in cm._stage_fns:
        h = fn(h)
    e2e_ms = (clock.perf_counter() - t0) * 1e3
    assert sum(b["ms"] for b in breakdown) == pytest.approx(e2e_ms)


def test_offline_reports_median_span_over_iters(clock):
    """Satellite: offline(iters=) must report the MEDIAN of the timed
    spans, not a single (noisy) run."""
    spans = iter([0.010, 0.010,          # warmup (2)
                  0.090, 0.032, 0.001])  # timed: median = 0.032

    def infer(xb):
        clock.advance(next(spans))
        return np.zeros((xb.shape[0], 2), np.float32)

    rep = offline(infer, _mk, n_samples=32, warmup=2, iters=3)
    assert rep.extras["iters"] == 3
    assert rep.p50_ms == pytest.approx(0.032 / 32 * 1e3)
    assert rep.throughput_qps == pytest.approx(32 / 0.032)


def test_streaming_pipeline_scenario_uses_tuned_default(clock):
    """The streaming scenario consumes the executor's (autotuned) default
    micro-batch and reports the FIFO plan that scheduled the run."""
    calls = []

    class FakeStats:
        micro_batch = 8
        fifo_depths = [2, 2]
        segments = [(0, 2)]

    class FakeCompiled:
        def streaming_compiled(self, xb, micro_batch=None):
            calls.append(micro_batch)
            clock.advance(0.016)
            return np.zeros((xb.shape[0], 2), np.float32), FakeStats()

    rep = streaming_pipeline(FakeCompiled(), _mk, n_samples=16,
                             warmup=1, iters=3)
    assert rep.scenario == "StreamingOffline"
    assert calls == [None] * 4            # warmup + 3 timed, tuned default
    assert rep.extras["micro_batch"] == 8
    assert rep.extras["fifo_depths"] == "[2, 2]"
    assert rep.p50_ms == pytest.approx(0.016 / 16 * 1e3)
    assert rep.throughput_qps == pytest.approx(16 / 0.016)


def test_offline_report_attaches_stage_breakdown(clock, monkeypatch):
    """The Offline report's stage_ms rows come from the compiled model's
    probe and align 1:1 with its schedule."""

    class FakeCompiled:
        def stage_latencies(self, x, iters=2):
            return [{"stage": "s0", "kind": "K", "ms": 1.0},
                    {"stage": "s1", "kind": "K", "ms": 2.0}]

    def infer(xb):
        clock.advance(0.004)
        return np.zeros((xb.shape[0], 2), np.float32)

    rep = offline(infer, _mk, n_samples=8, warmup=1, compiled=FakeCompiled())
    assert [s["stage"] for s in rep.stage_ms] == ["s0", "s1"]
    assert "stage_ms" in rep.row()
    assert rep.row()["stage_ms"] == "s0:1.000|s1:2.000"
