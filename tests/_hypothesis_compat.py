"""Offline stand-in for ``hypothesis`` so property tests collect and run.

The container has no network access and no ``hypothesis`` wheel. Rather than
skipping every property test, this shim implements the tiny slice of the API
the suite uses (``given``, ``settings``, ``strategies.integers/booleans/
sampled_from/lists/tuples``) as a deterministic example generator: each
``@given`` test runs ``max_examples`` pseudo-random draws from a fixed seed,
so the properties are still exercised — just without shrinking or the
database. When the real ``hypothesis`` is installed (requirements-dev.txt)
it is used unchanged.

Usage in tests:

    from _hypothesis_compat import given, settings, strategies as st
"""

from __future__ import annotations

import functools  # noqa: F401 - used when real hypothesis present
import random

try:  # pragma: no cover - exercised when hypothesis is installed
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _Strategy:
        """A strategy is just a draw(rng) callable."""

        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng: random.Random):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def sampled_from(seq):
            elems = list(seq)
            return _Strategy(lambda rng: elems[rng.randrange(len(elems))])

        @staticmethod
        def lists(elem, min_size=0, max_size=10):
            def draw(rng):
                n = rng.randint(min_size, max_size)
                return [elem.draw(rng) for _ in range(n)]

            return _Strategy(draw)

        @staticmethod
        def tuples(*elems):
            return _Strategy(lambda rng: tuple(e.draw(rng) for e in elems))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    strategies = _Strategies()

    def settings(max_examples: int = 20, **_kw):
        """Record max_examples on the test function for ``given`` to read."""

        def deco(fn):
            fn._compat_max_examples = max_examples
            return fn

        return deco

    def given(*strats):
        def deco(fn):
            inner = fn
            n_default = getattr(fn, "_compat_max_examples", 20)

            # NOTE: no functools.wraps — it would set __wrapped__ and pytest
            # would resolve the inner signature's argument names as fixtures.
            def wrapper():
                n = getattr(wrapper, "_compat_max_examples", n_default)
                rng = random.Random(0xC0DE51)
                for i in range(n):
                    drawn = [s.draw(rng) for s in strats]
                    try:
                        inner(*drawn)
                    except Exception as e:  # noqa: BLE001 - re-raise with repro info
                        raise AssertionError(
                            f"property falsified on example {i}: args={drawn!r}"
                        ) from e

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper

        return deco
