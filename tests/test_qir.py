"""QIR (QONNX-analogue) interchange: JSON roundtrip, reference interpreter
parity with the training-side forward, constant folding (paper C8 / §3.5)."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.qir import Graph, Node, QuantSpec, export_qmlp
from repro.core.qlayers import QDense, QDenseBatchNorm
from repro.core.streamline import constant_fold


def _tiny_mlp(key):
    defs = [QDenseBatchNorm(6, 5, weight_bits=4, act_bits=4),
            QDenseBatchNorm(5, 4, weight_bits=4, act_bits=4)]
    params = [d.init(k) for d, k in zip(defs, jax.random.split(key, 2))]
    head = QDense(4, 3, weight_bits=32, act_bits=32)
    head_p = head.init(jax.random.fold_in(key, 7))
    return defs, params, head_p


def test_roundtrip_preserves_graph():
    defs, params, head_p = _tiny_mlp(jax.random.PRNGKey(0))
    g = export_qmlp(defs, params, head_p, meta={"task": "kws"})
    g2 = Graph.from_json(g.to_json())
    assert [n.op for n in g2.nodes] == [n.op for n in g.nodes]
    assert g2.meta == {"task": "kws"}
    for k, v in g.initializers.items():
        np.testing.assert_array_equal(g2.initializers[k], v)


def test_save_load(tmp_path):
    defs, params, head_p = _tiny_mlp(jax.random.PRNGKey(1))
    g = export_qmlp(defs, params, head_p)
    p = tmp_path / "model.qir.json"
    g.save(str(p))
    g2 = Graph.load(str(p))
    assert len(g2.nodes) == len(g.nodes)


def test_interpreter_matches_eval_forward():
    """Graph.run == the qlayers eval-mode forward it was exported from —
    the property QONNX needs so hls4ml/FINN deploy what Brevitas trained."""
    defs, params, head_p = _tiny_mlp(jax.random.PRNGKey(2))
    g = export_qmlp(defs, params, head_p)
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(3), (4, 6)))
    out = g.run({"x": x})["logits"]

    h = jnp.asarray(x)
    for d, p in zip(defs, params):
        h, _ = d.apply(p, h, train=False)
    ref = h @ head_p["w"] + head_p["b"]
    # The exported graph applies BN then ReLU then Quant separately; the
    # layer's eval path folds BN into the (quantized) kernel. These agree to
    # quantization tolerance, not exactly:
    np.testing.assert_allclose(out, np.asarray(ref), rtol=0.35, atol=0.35)
    # class decisions should broadly agree
    agree = (np.argmax(out, -1) == np.asarray(jnp.argmax(ref, -1))).mean()
    assert agree >= 0.5


def test_interpreter_ops():
    g = Graph(inputs=["x"], outputs=["y"])
    g.initializers["w"] = np.eye(3, dtype=np.float32) * 2
    g.nodes.append(Node("Dense", "d", ["x", "w"], ["h"]))
    g.nodes.append(Node("Relu", "r", ["h"], ["y"]))
    out = g.run({"x": np.asarray([[-1.0, 0.5, 2.0]], np.float32)})["y"]
    np.testing.assert_array_equal(out, [[0.0, 1.0, 4.0]])


def test_topk_node():
    g = Graph(inputs=["x"], outputs=["y"])
    g.nodes.append(Node("TopK", "t", ["x"], ["y"]))
    out = g.run({"x": np.asarray([[0.1, 0.9, 0.3]])})["y"]
    assert int(out[0]) == 1


def test_constant_folding_precomputes_quant_of_initializers():
    g = Graph(inputs=["x"], outputs=["y"])
    g.initializers["w"] = np.linspace(-1, 1, 12).reshape(3, 4).astype(np.float32)
    g.nodes.append(Node("Quant", "qw", ["w"], ["wq"], attrs={"bits": 4},
                        quant=QuantSpec(bits=4)))
    g.nodes.append(Node("Dense", "d", ["x", "wq"], ["y"]))
    n_before = len(g.nodes)
    g = constant_fold(g)
    assert len(g.nodes) == n_before - 1           # Quant node removed
    assert "wq" in g.initializers                  # precomputed at compile time
    out = g.run({"x": np.ones((1, 3), np.float32)})["y"]
    assert out.shape == (1, 4)
