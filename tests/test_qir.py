"""QIR (QONNX-analogue) interchange: JSON roundtrip, reference interpreter
parity with the training-side forward, constant folding (paper C8 / §3.5),
and the conv-node semantics (Conv2D / MaxPool / Flatten) behind
``export_qcnn``."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.qir import Graph, Node, QuantSpec, export_qcnn, export_qmlp
from repro.core.qlayers import QDense, QDenseBatchNorm
from repro.core.streamline import constant_fold
from repro.models.tiny import CNVModel, ICModel


def _tiny_mlp(key):
    defs = [QDenseBatchNorm(6, 5, weight_bits=4, act_bits=4),
            QDenseBatchNorm(5, 4, weight_bits=4, act_bits=4)]
    params = [d.init(k) for d, k in zip(defs, jax.random.split(key, 2))]
    head = QDense(4, 3, weight_bits=32, act_bits=32)
    head_p = head.init(jax.random.fold_in(key, 7))
    return defs, params, head_p


def test_roundtrip_preserves_graph():
    defs, params, head_p = _tiny_mlp(jax.random.PRNGKey(0))
    g = export_qmlp(defs, params, head_p, meta={"task": "kws"})
    g2 = Graph.from_json(g.to_json())
    assert [n.op for n in g2.nodes] == [n.op for n in g.nodes]
    assert g2.meta == {"task": "kws"}
    for k, v in g.initializers.items():
        np.testing.assert_array_equal(g2.initializers[k], v)


def test_save_load(tmp_path):
    defs, params, head_p = _tiny_mlp(jax.random.PRNGKey(1))
    g = export_qmlp(defs, params, head_p)
    p = tmp_path / "model.qir.json"
    g.save(str(p))
    g2 = Graph.load(str(p))
    assert len(g2.nodes) == len(g.nodes)


def test_interpreter_matches_eval_forward():
    """Graph.run == the qlayers eval-mode forward it was exported from —
    the property QONNX needs so hls4ml/FINN deploy what Brevitas trained."""
    defs, params, head_p = _tiny_mlp(jax.random.PRNGKey(2))
    g = export_qmlp(defs, params, head_p)
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(3), (4, 6)))
    out = g.run({"x": x})["logits"]

    h = jnp.asarray(x)
    for d, p in zip(defs, params):
        h, _ = d.apply(p, h, train=False)
    ref = h @ head_p["w"] + head_p["b"]
    # The exported graph applies BN then ReLU then Quant separately; the
    # layer's eval path folds BN into the (quantized) kernel. These agree to
    # quantization tolerance, not exactly:
    np.testing.assert_allclose(out, np.asarray(ref), rtol=0.35, atol=0.35)
    # class decisions should broadly agree
    agree = (np.argmax(out, -1) == np.asarray(jnp.argmax(ref, -1))).mean()
    assert agree >= 0.5


def test_interpreter_ops():
    g = Graph(inputs=["x"], outputs=["y"])
    g.initializers["w"] = np.eye(3, dtype=np.float32) * 2
    g.nodes.append(Node("Dense", "d", ["x", "w"], ["h"]))
    g.nodes.append(Node("Relu", "r", ["h"], ["y"]))
    out = g.run({"x": np.asarray([[-1.0, 0.5, 2.0]], np.float32)})["y"]
    np.testing.assert_array_equal(out, [[0.0, 1.0, 4.0]])


def test_topk_node():
    g = Graph(inputs=["x"], outputs=["y"])
    g.nodes.append(Node("TopK", "t", ["x"], ["y"]))
    out = g.run({"x": np.asarray([[0.1, 0.9, 0.3]])})["y"]
    assert int(out[0]) == 1


def test_conv2d_node_matches_lax_conv():
    rng = np.random.default_rng(10)
    x = rng.standard_normal((2, 6, 6, 3)).astype(np.float32)
    w = rng.standard_normal((3, 3, 3, 4)).astype(np.float32)
    b = rng.standard_normal((4,)).astype(np.float32)
    g = Graph(inputs=["x"], outputs=["y"],
              initializers={"w": w, "b": b})
    g.nodes.append(Node("Conv2D", "c", ["x", "w", "b"], ["y"],
                        attrs={"stride": 2, "padding": "SAME"}))
    out = g.run({"x": x})["y"]
    ref = jax.lax.conv_general_dilated(
        jnp.asarray(x), jnp.asarray(w), (2, 2), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC")) + b
    np.testing.assert_allclose(out, np.asarray(ref), rtol=1e-6, atol=1e-6)
    assert out.shape == (2, 3, 3, 4)


def test_maxpool_node_float_and_integer():
    x = np.arange(16, dtype=np.float32).reshape(1, 4, 4, 1)
    g = Graph(inputs=["x"], outputs=["y"])
    g.nodes.append(Node("MaxPool", "p", ["x"], ["y"],
                        attrs={"window": 2, "stride": 2}))
    out = g.run({"x": x})["y"]
    np.testing.assert_array_equal(out.reshape(2, 2), [[5, 7], [13, 15]])
    # integer codes pool exactly (init value must not be -inf cast to int)
    xi = np.asarray([[-5, -9], [-7, -8]], np.int32).reshape(1, 2, 2, 1)
    out_i = g.run({"x": xi})["y"]
    assert out_i.reshape(()) == -5 and out_i.dtype == np.int32


def test_flatten_node_row_major():
    x = np.arange(12, dtype=np.float32).reshape(1, 2, 3, 2)
    g = Graph(inputs=["x"], outputs=["y"])
    g.nodes.append(Node("Flatten", "f", ["x"], ["y"]))
    out = g.run({"x": x})["y"]
    np.testing.assert_array_equal(out, x.reshape(1, 12))


def test_quant_node_fixed_scale_and_bipolar():
    g = Graph(inputs=["x"], outputs=["y"])
    g.nodes.append(Node("Quant", "q", ["x"], ["y"], attrs={"scale": 0.5},
                        quant=QuantSpec(bits=2, signed=False)))
    # half-up on the fixed grid: clip(floor(x/0.5 + 0.5), 0, 3) * 0.5
    out = g.run({"x": np.asarray([[-1.0, 0.24, 0.25, 1.1, 9.0]])})["y"]
    np.testing.assert_array_equal(out, [[0.0, 0.0, 0.5, 1.0, 1.5]])

    gb = Graph(inputs=["x"], outputs=["y"])
    gb.nodes.append(Node("Quant", "s", ["x"], ["y"], attrs={"bipolar": True},
                         quant=QuantSpec(bits=1, signed=False)))
    out = gb.run({"x": np.asarray([[-0.1, 0.0, 2.0]])})["y"]
    np.testing.assert_array_equal(out, [[0.0, 1.0, 1.0]])  # [x >= 0]


def test_export_qcnn_ic_structure_and_roundtrip():
    model = ICModel(in_hw=8, filters=(4, 4), kernels=(3, 3), strides=(1, 2))
    params = model.init(jax.random.PRNGKey(0))
    g = export_qcnn(model, params)
    ops = [n.op for n in g.nodes]
    assert ops == ["Conv2D", "Relu", "Quant"] * 2 + ["Flatten", "Dense"]
    assert g.meta["in_scale"] == 1.0 / 128.0
    # per-layer QuantSpecs with export-frozen po2 scales
    for n in g.nodes:
        if n.op == "Quant":
            assert n.quant.bits == model.act_bits
            s = n.attrs["scale"]
            assert s > 0 and np.log2(s) == round(np.log2(s))
        if n.op == "Conv2D":
            assert n.attrs["w_scale"] in g.initializers
            assert "in_shape" in n.attrs and "out_shape" in n.attrs
    g2 = Graph.from_json(g.to_json())
    x = np.random.default_rng(0).integers(-127, 128, (2, 8, 8, 3))
    np.testing.assert_array_equal(
        g.run({"x": x.astype(np.float32) / 128.0})["logits"],
        g2.run({"x": x.astype(np.float32) / 128.0})["logits"])


def test_export_qcnn_cnv_structure():
    model = CNVModel(channels=(4, 4, 8, 8, 8, 8), fc=(16, 16))
    params = model.init(jax.random.PRNGKey(1))
    g = export_qcnn(model, params)
    ops = [n.op for n in g.nodes]
    assert ops.count("Conv2D") == 6 and ops.count("MaxPool") == 2
    assert ops.count("Dense") == 3 and ops.count("Flatten") == 1
    assert g.meta["in_scale"] == 1.0   # unipolar codes are the values
    quants = [n for n in g.nodes if n.op == "Quant"]
    assert all(n.attrs.get("bipolar") for n in quants)
    # unipolar folding: downstream conv weights are 2*sign with -sum(w) bias
    w1 = g.initializers["cw1"]
    assert set(np.unique(w1)) == {-2.0, 2.0}
    np.testing.assert_array_equal(
        g.initializers["cb1"], -np.sum(w1 / 2.0, axis=(0, 1, 2)))


def test_constant_folding_precomputes_quant_of_initializers():
    g = Graph(inputs=["x"], outputs=["y"])
    g.initializers["w"] = np.linspace(-1, 1, 12).reshape(3, 4).astype(np.float32)
    g.nodes.append(Node("Quant", "qw", ["w"], ["wq"], attrs={"bits": 4},
                        quant=QuantSpec(bits=4)))
    g.nodes.append(Node("Dense", "d", ["x", "wq"], ["y"]))
    n_before = len(g.nodes)
    g = constant_fold(g)
    assert len(g.nodes) == n_before - 1           # Quant node removed
    assert "wq" in g.initializers                  # precomputed at compile time
    out = g.run({"x": np.ones((1, 3), np.float32)})["y"]
    assert out.shape == (1, 4)
