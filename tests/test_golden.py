"""Golden-file regression: compiled-path bit-exactness can't silently drift.

``tests/golden/`` holds frozen QIR exports + expected per-stage outputs for
small instances of all four Table-1 model families (see
``tests/golden/generate.py``). Everything here recompiles the *frozen*
graph — no RNG, no training — so any change to the streamliner, the
lowering, or the executors that perturbs a single integer fails loudly:

  * every integer stage output must match the fixture bit for bit, under
    BOTH conv lowerings (direct fused kernel and im2col fallback);
  * the conv models must also reproduce the live unfused ``Graph.run``
    interpreter exactly (the po2 export contract, ties included);
  * the streaming (FIFO-pipelined) executor must equal offline;
  * the Pallas kernel path (interpret mode on CPU) must produce the same
    integers.
"""

import os

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.qir import Graph
from repro.deploy import FusedConvThresholdStage, compile_graph

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")
MODELS = ("kws", "ad", "ic", "cnv")


def _load(name):
    graph = Graph.load(os.path.join(GOLDEN_DIR, f"{name}.qir.json"))
    data = np.load(os.path.join(GOLDEN_DIR, f"{name}.golden.npz"))
    stages = [data[k] for k in sorted(data.files) if k.startswith("stage_")]
    return graph, data["x"], stages


def _assert_stage_match(got, want, label):
    got = np.asarray(got)
    if np.issubdtype(want.dtype, np.integer):
        np.testing.assert_array_equal(got, want, err_msg=label)
    else:
        # float head logits: affine of exact integers; allow fp assoc drift
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5,
                                   err_msg=label)


@pytest.mark.parametrize("name", MODELS)
@pytest.mark.parametrize("lowering", ["direct", "im2col"])
def test_golden_stage_outputs_bit_exact(name, lowering):
    graph, x, want_stages = _load(name)
    cm = compile_graph(graph, in_scale=graph.meta["in_scale"],
                       use_pallas=False, conv_lowering=lowering)
    outs = cm.stage_outputs(jnp.asarray(x))
    assert len(outs) == len(want_stages)
    for i, (got, want) in enumerate(zip(outs, want_stages)):
        _assert_stage_match(got, want, f"{name}[{lowering}] stage {i}")


@pytest.mark.parametrize("name", ("ic", "cnv"))
def test_golden_conv_models_match_live_graph_run(name):
    """The frozen conv exports still reproduce the unfused per-node
    interpreter bit for bit — the compiled path and Graph.run can't drift
    apart without this failing."""
    graph, x, want_stages = _load(name)
    cm = compile_graph(graph, in_scale=graph.meta["in_scale"],
                       use_pallas=False, conv_lowering="direct")
    quant_outs = [n.outputs[0] for n in graph.nodes if n.op == "Quant"]
    probe = Graph(nodes=graph.nodes, initializers=graph.initializers,
                  inputs=graph.inputs,
                  outputs=list(graph.outputs) + quant_outs,
                  meta=graph.meta)
    run = probe.run(
        {"x": np.asarray(x, np.float32) * graph.meta["in_scale"]})
    k = 0
    for s, want in zip(cm.schedule.stages, want_stages):
        if isinstance(s, FusedConvThresholdStage):
            np.testing.assert_array_equal(
                want.reshape(run[quant_outs[k]].shape) * s.stage.out_scale,
                run[quant_outs[k]])
            k += 1
    np.testing.assert_allclose(want_stages[-1], run["logits"],
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("name", ("kws", "ad"))
def test_golden_mlps_bit_exact_vs_streamlined_float_reference(name):
    """MLP exports carry float weights (quantized at lowering), so their
    exactness oracle is the streamlined float reference chain
    (``core.streamline.float_ref_dense``, half-up semantics) rebuilt from
    the frozen initializers: every integer stage must match it bit for
    bit, and the head logits to float tolerance."""
    from repro.core.streamline import float_ref_dense

    graph, x, want_stages = _load(name)
    init = graph.initializers
    h = jnp.asarray(x, jnp.float32) * graph.meta["in_scale"]
    denses = [n for n in graph.nodes if n.op == "Dense" and n.name != "head"]
    quants = [n for n in graph.nodes if n.op == "Quant"]
    assert len(denses) == len(quants) == len(want_stages) - 1
    for i, (dn, qn) in enumerate(zip(denses, quants)):
        params = {"w": jnp.asarray(init[f"w{i}"]),
                  "b": jnp.asarray(init[f"b{i}"])}
        if f"gamma{i}" in init:
            params.update({k: jnp.asarray(init[f"{k}{i}"])
                           for k in ("gamma", "beta", "mu", "sigma2")})
        s_out = float(qn.attrs["scale"])
        h_int = float_ref_dense(params, h,
                                weight_bits=dn.attrs["weight_bits"],
                                act_bits=qn.quant.bits, s_out=s_out)
        np.testing.assert_array_equal(np.asarray(h_int), want_stages[i],
                                      err_msg=f"{name} stage {i}")
        h = h_int.astype(jnp.float32) * s_out
    logits = (np.asarray(h) @ init["w_head"] + init["b_head"])
    np.testing.assert_allclose(want_stages[-1], logits,
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("name", MODELS)
def test_golden_streaming_matches_frozen_offline(name):
    graph, x, want_stages = _load(name)
    cm = compile_graph(graph, in_scale=graph.meta["in_scale"],
                       use_pallas=False)
    y_str, stats = cm.streaming(jnp.asarray(x), micro_batch=2)
    _assert_stage_match(y_str, want_stages[-1], f"{name} streaming")
    assert len(stats.fifo_depths) == len(cm.schedule.stages) + 1


def test_golden_ic_pallas_kernel_path_bit_exact():
    """The fused direct-conv Pallas kernel (interpret mode) reproduces the
    frozen integers on the conv-heaviest golden."""
    graph, x, want_stages = _load("ic")
    cm = compile_graph(graph, in_scale=graph.meta["in_scale"],
                       use_pallas=True, interpret=True,
                       conv_lowering="direct")
    outs = cm.stage_outputs(jnp.asarray(x[:2]))
    for i, (got, want) in enumerate(zip(outs, want_stages)):
        _assert_stage_match(got, want[:2], f"ic[pallas] stage {i}")


# ---------------------------------------------------------------------------
# megakernel dispatch: {staged, megakernel} x {offline, streaming, waves}
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ("kws", "ad"))
@pytest.mark.parametrize("mode", ["staged", "megakernel"])
def test_golden_mlp_dispatch_modes_bit_exact(name, mode):
    """Both segment dispatch modes reproduce the frozen logits across every
    executor entry point: the whole-network-resident megakernel
    (``docs/megakernel.md``) is integer-exact against the per-stage path
    because threshold counting is order-free."""
    graph, x, want_stages = _load(name)
    want = want_stages[-1]
    cm = compile_graph(graph, in_scale=graph.meta["in_scale"],
                       use_pallas=False, megakernel=(mode == "megakernel"))
    if mode == "megakernel":
        assert cm._mega_plans, f"{name}: planner admitted no megakernel run"
    else:
        assert not cm._mega_plans
    xj = jnp.asarray(x)
    _assert_stage_match(cm.offline(xj), want, f"{name}[{mode}] offline")
    y_str, stats = cm.streaming_compiled(xj, micro_batch=2)
    _assert_stage_match(y_str, want, f"{name}[{mode}] streaming_compiled")
    assert bool(stats.megakernel) == (mode == "megakernel")
    # submit_wave: a partially filled wave with an explicit valid mask —
    # padding rows must not perturb the real queries
    valid = np.array([True, False, True])
    y_w, mask = cm.submit_wave(x[:3], valid=valid, micro_batch=4)
    assert mask.tolist() == [True, False, True, False]
    _assert_stage_match(np.asarray(y_w)[mask], want[:3][valid],
                        f"{name}[{mode}] submit_wave")


@pytest.mark.parametrize("name", ("kws", "ad"))
def test_golden_mlp_megakernel_pallas_interpret_bit_exact(name):
    """The actual Pallas megakernel program (interpret mode on CPU) — not
    just the straight-line XLA fallback — reproduces the frozen integers."""
    graph, x, want_stages = _load(name)
    cm = compile_graph(graph, in_scale=graph.meta["in_scale"],
                       use_pallas=True, interpret=True, megakernel=True)
    assert cm._mega_plans
    _assert_stage_match(cm.offline(jnp.asarray(x)), want_stages[-1],
                        f"{name}[pallas-mega] offline")


@pytest.mark.parametrize("name", ("kws", "ad"))
def test_golden_mlp_megakernel_fallback_when_budget_rejects(name):
    """Force-requesting the megakernel under a VMEM budget too small for
    the segment's weights+banks+tiles falls back to the staged path — and
    the outputs stay frozen-exact (the fallback IS the reference)."""
    graph, x, want_stages = _load(name)
    cm = compile_graph(graph, in_scale=graph.meta["in_scale"],
                       use_pallas=False, megakernel=True)
    assert cm._mega_plans
    cm.set_megakernel(True, budget_bytes=64)
    assert cm._mega_plans == {}
    xj = jnp.asarray(x)
    _assert_stage_match(cm.offline(xj), want_stages[-1],
                        f"{name}[fallback] offline")
    y_str, stats = cm.streaming_compiled(xj, micro_batch=2)
    _assert_stage_match(y_str, want_stages[-1], f"{name}[fallback] streaming")
    assert not stats.megakernel
    # restoring the default budget re-admits the plan
    cm.set_megakernel(None)
    assert cm._mega_plans
