"""repro.serve: deterministic router/batching/SLO tests plus the golden
padded-wave bit-exactness contract.

Everything timing-shaped runs under ``ManualClock`` with scripted service
times, so batching deadlines, latency percentiles, and shed rates are
exact arithmetic the tests recompute independently (the hand-simulated
trace below is worked out on paper, not by re-running the router). The
golden-model section then closes the loop on real executors: partially
filled waves — the padding the dynamic batcher creates under real
traffic — must be bit-identical to ``offline`` on all four Table-1
families.
"""

import os

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.qir import Graph
from repro.deploy import compile_graph
from repro.serve import (
    AsyncEngine,
    ManualClock,
    ReplicaPool,
    Router,
    RouterConfig,
    ServeMetrics,
    ServiceModel,
    SLOController,
    SyncEngine,
    diurnal_trace,
    mmpp_trace,
    poisson_trace,
    replay_trace,
    slo_operating_point,
)

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")
MODELS = ("kws", "ad", "ic", "cnv")


def _load(name):
    graph = Graph.load(os.path.join(GOLDEN_DIR, f"{name}.qir.json"))
    data = np.load(os.path.join(GOLDEN_DIR, f"{name}.golden.npz"))
    return graph, data["x"]


class ScriptedModel:
    """submit_wave fake with the executor's padding contract: each wave
    advances the manual clock by a scripted service time, outputs identify
    their input row (sum of codes) so results can be traced back."""

    def __init__(self, clock, service_s=0.003, micro_batch=4):
        self.clock = clock
        self.service_s = service_s
        self.default_micro_batch = micro_batch
        self.calls = []          # (n_valid, micro_batch) per wave

    def submit_wave(self, x, valid=None, micro_batch=None):
        mb = int(micro_batch or self.default_micro_batch)
        x = np.asarray(x)
        n = x.shape[0]
        assert n <= mb
        mask = np.ones(n, bool) if valid is None else np.asarray(valid, bool)
        mask = np.concatenate([mask, np.zeros(mb - n, bool)])
        self.calls.append((int(mask.sum()), mb))
        s = self.service_s(len(self.calls)) if callable(self.service_s) \
            else self.service_s
        self.clock.advance(s)
        y = np.zeros((mb, 1), np.float32)
        y[:n, 0] = x.reshape(n, -1).sum(axis=1)
        return y, mask


# ---------------------------------------------------------------------------
# traffic
# ---------------------------------------------------------------------------

def test_poisson_trace_deterministic_and_rate():
    a = poisson_trace(qps=100.0, n=500, seed=7)
    b = poisson_trace(qps=100.0, n=500, seed=7)
    np.testing.assert_array_equal(a.arrivals, b.arrivals)
    assert a.n == 500 and a.arrivals[0] >= 0
    assert np.all(np.diff(a.arrivals) >= 0)
    # LLN: realized rate within 20% of offered
    assert a.offered_qps == pytest.approx(100.0, rel=0.2)
    c = poisson_trace(qps=100.0, n=500, seed=8)
    assert not np.array_equal(a.arrivals, c.arrivals)


def test_mmpp_trace_is_burstier_than_poisson():
    """The burstiness signal: inter-arrival coefficient of variation of an
    MMPP with far-apart rate states exceeds Poisson's CV of ~1."""
    p = np.diff(poisson_trace(qps=100.0, n=2000, seed=0).arrivals)
    m = np.diff(mmpp_trace((10.0, 1000.0), dwell_s=0.5, n=2000,
                           seed=0).arrivals)
    cv = lambda d: np.std(d) / np.mean(d)
    assert cv(m) > 1.5 * cv(p)


def test_diurnal_trace_ramps_with_the_rate():
    """Raised-cosine rate: the mid-period half of the cycle (around the
    peak) must hold the bulk of the arrivals."""
    period = 4.0
    t = diurnal_trace(qps_low=5.0, qps_high=200.0, period_s=period,
                      n=400, seed=1)
    phase = np.mod(t.arrivals, period) / period
    near_peak = np.mean((phase > 0.25) & (phase < 0.75))
    assert near_peak > 0.7
    np.testing.assert_array_equal(
        t.arrivals,
        diurnal_trace(5.0, 200.0, period, 400, seed=1).arrivals)


def test_replay_and_scaled_traces():
    t = replay_trace([5.0, 5.5, 7.0])
    np.testing.assert_allclose(t.arrivals, [0.0, 0.5, 2.0])
    double = t.scaled(2.0)
    np.testing.assert_allclose(double.arrivals, [0.0, 0.25, 1.0])
    assert double.offered_qps == pytest.approx(2 * t.offered_qps)
    with pytest.raises(ValueError):
        t.scaled(0.0)
    with pytest.raises(ValueError):
        poisson_trace(qps=0.0, n=4)
    with pytest.raises(ValueError):
        mmpp_trace((), dwell_s=1.0, n=4)
    with pytest.raises(ValueError):
        diurnal_trace(10.0, 5.0, 1.0, 4)   # high < low


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def test_metrics_sliding_window_prunes_old_events():
    m = ServeMetrics(window_s=10.0)
    m.record_admit(0.0)
    m.record_completion(1.0, 0.005)
    m.record_wave(1.0, 3, 4)
    snap = m.snapshot(5.0)
    assert snap.n_completed == 1 and snap.n_waves == 1
    # 20s later everything fell out of the window
    snap = m.snapshot(21.0)
    assert snap.n_completed == 0 and snap.n_waves == 0
    assert snap.p99_ms == 0.0 and snap.throughput_qps == 0.0


def test_metrics_percentiles_shed_rate_and_occupancy():
    m = ServeMetrics(window_s=60.0)
    lats = [0.001 * (i + 1) for i in range(10)]
    for i, l in enumerate(lats):
        m.record_admit(float(i))
        m.record_completion(float(i), l)
    for _ in range(3):
        m.record_shed(9.0)
    m.record_wave(9.0, 4, 4)
    m.record_wave(9.0, 2, 4)
    snap = m.snapshot(10.0)
    expect = np.asarray(lats) * 1e3
    assert snap.p50_ms == float(np.percentile(expect, 50))
    assert snap.p99_ms == float(np.percentile(expect, 99))
    assert snap.shed_rate == pytest.approx(3 / 13)
    assert snap.occupancy_hist == {4: 1, 2: 1}
    assert snap.mean_occupancy == pytest.approx(0.75)
    assert snap.throughput_qps == pytest.approx(10 / 10.0)
    assert "p99_ms" in snap.row()


# ---------------------------------------------------------------------------
# router batching under a manual clock
# ---------------------------------------------------------------------------

def test_full_wave_dispatches_inline_partial_waits_for_deadline():
    clock = ManualClock()
    model = ScriptedModel(clock, service_s=0.0, micro_batch=4)
    router = Router({"m": model}, RouterConfig(max_wait_ms=5.0),
                    clock=clock)
    x = np.ones((8,), np.int32)
    for _ in range(3):
        router.submit("m", x)
    assert model.calls == []                 # partial wave: no dispatch yet
    router.step()
    assert model.calls == []                 # deadline (5ms) not reached
    clock.advance(0.0049)
    router.step()
    assert model.calls == []
    clock.advance(0.0002)                    # past the 5ms deadline
    assert router.step() == 3
    assert model.calls == [(3, 4)]           # padded partial wave
    req = router.submit("m", x)
    for _ in range(3):
        req = router.submit("m", x)
    assert model.calls[-1] == (4, 4)         # full wave went inline
    assert req.result is not None and not req.shed


def test_batch_deadline_anchors_to_oldest_pending_request():
    clock = ManualClock()
    model = ScriptedModel(clock, service_s=0.0, micro_batch=8)
    router = Router({"m": model}, RouterConfig(max_wait_ms=10.0),
                    clock=clock)
    router.submit("m", np.ones((2,), np.int32))
    clock.advance(0.008)
    router.submit("m", np.ones((2,), np.int32))   # younger request
    assert router.next_deadline() == pytest.approx(0.010)
    clock.advance(0.002)
    assert router.step() == 2                     # oldest hit its deadline
    assert model.calls == [(2, 8)]


def test_router_exact_p99_vs_hand_simulated_trace():
    """Replay a 5-request trace whose schedule is worked out by hand:

    mb=2, max_wait=5ms, service=3ms/wave, arrivals [0,1,10,11,30] ms.
      r0@0ms queues; r1@1ms fills the wave -> dispatch@1ms, done@4ms
        (lat r0=4ms, r1=3ms)
      r2@10ms queues; r3@11ms fills -> dispatch@11ms, done@14ms
        (lat r2=4ms, r3=3ms)
      r4@30ms queues alone; deadline 35ms -> flush@35ms, done@38ms
        (lat r4=8ms)
    """
    clock = ManualClock()
    model = ScriptedModel(clock, service_s=0.003, micro_batch=2)
    router = Router({"m": model}, RouterConfig(max_wait_ms=5.0),
                    clock=clock)
    trace = replay_trace(np.asarray([0.0, 1.0, 10.0, 11.0, 30.0]) * 1e-3)
    reqs = router.run_trace("m", trace, lambda i: np.ones((4,), np.int32))
    got_ms = [r.latency_s * 1e3 for r in reqs]
    expect_ms = [4.0, 3.0, 4.0, 3.0, 8.0]
    np.testing.assert_allclose(got_ms, expect_ms, rtol=1e-9)
    assert model.calls == [(2, 2), (2, 2), (1, 2)]
    snap = router.stats()["m"]["metrics"]
    assert snap.p50_ms == pytest.approx(np.percentile(expect_ms, 50))
    assert snap.p90_ms == pytest.approx(np.percentile(expect_ms, 90))
    assert snap.p99_ms == pytest.approx(np.percentile(expect_ms, 99))
    assert snap.mean_occupancy == pytest.approx((1 + 1 + 0.5) / 3)
    assert snap.shed_rate == 0.0


def test_router_sheds_at_overload_and_keeps_served_under_budget():
    """2x overload: offered rate twice the wave-service capacity. The SLO
    controller must shed a substantial fraction and — because admission
    bounds estimated completion by the budget — every *served* request
    stays inside it."""
    clock = ManualClock()
    mb, service_s = 4, 0.004
    model = ScriptedModel(clock, service_s=service_s, micro_batch=mb)
    # scripted service model that matches the fake exactly: one stage whose
    # cycles scale so wave_service_s(mb) == service_s at every mb
    svc = ServiceModel(works=[("s", 0)], sec_per_cycle=service_s / 9)
    assert svc.wave_service_s(mb) == pytest.approx(service_s)
    budget_ms = 25.0
    router = Router(
        {"m": model},
        RouterConfig(max_wait_ms=2.0, p99_budget_ms=budget_ms),
        clock=clock, service_models={"m": svc})
    capacity = mb / service_s                      # 1000 qps
    trace = poisson_trace(qps=2 * capacity, n=400, seed=3)
    reqs = router.run_trace("m", trace, lambda i: np.ones((4,), np.int32))
    served = [r for r in reqs if not r.shed]
    shed_rate = 1 - len(served) / len(reqs)
    assert 0.25 < shed_rate < 0.75
    lat_ms = np.asarray([r.latency_s for r in served]) * 1e3
    assert float(lat_ms.max()) <= budget_ms + 1e-6
    snap = router.stats()["m"]["metrics"]
    assert snap.n_shed + snap.n_admitted == len(reqs)
    slo = router.stats()["m"]["slo"]
    assert slo["utilization"] > 1.0                # offered 2x capacity
    assert slo["occupancy_estimate"] > 0.0


def test_router_no_shedding_below_saturation():
    """At 0.5x capacity with a sane budget nothing should shed. The
    max-wait must be long enough for waves to fill (deadline-flushing
    singleton waves would halve the capacity the load is scaled to)."""
    clock = ManualClock()
    mb, service_s = 4, 0.004
    model = ScriptedModel(clock, service_s=service_s, micro_batch=mb)
    svc = ServiceModel(works=[("s", 0)], sec_per_cycle=service_s / 9)
    router = Router(
        {"m": model},
        RouterConfig(max_wait_ms=10.0, p99_budget_ms=50.0),
        clock=clock, service_models={"m": svc})
    trace = poisson_trace(qps=0.5 * mb / service_s, n=200, seed=5)
    reqs = router.run_trace("m", trace, lambda i: np.ones((4,), np.int32))
    assert all(not r.shed for r in reqs)
    assert router.stats()["m"]["metrics"].shed_rate == 0.0


def test_router_unknown_model_raises():
    router = Router({"m": ScriptedModel(ManualClock())})
    with pytest.raises(KeyError):
        router.submit("nope", np.zeros((2,), np.int32))


# ---------------------------------------------------------------------------
# replica pool
# ---------------------------------------------------------------------------

def test_replica_pool_needs_factory_beyond_one_device():
    with pytest.raises(ValueError, match="factory"):
        ReplicaPool(ScriptedModel(ManualClock()), devices=[None, None])
    with pytest.raises(ValueError):
        ReplicaPool()


def test_replica_pool_places_by_least_outstanding_work():
    clock = ManualClock()
    pool = ReplicaPool(factory=lambda: ScriptedModel(clock),
                       devices=[None, None, None])
    assert pool.n_replicas == 3
    r0 = pool.place(work_s=5.0)
    r1 = pool.place(work_s=1.0)
    r2 = pool.place(work_s=1.0)
    assert {r0.index, r1.index, r2.index} == {0, 1, 2}
    # next wave lands on the least-loaded replica (1 or 2, tie -> 1)
    r = pool.place(work_s=0.5)
    assert r.index == 1
    pool.complete(r0, 5.0)
    assert pool.place(work_s=0.1).index == 0
    stats = pool.stats()
    assert [s["replica"] for s in stats] == [0, 1, 2]
    assert all(s["outstanding_s"] >= 0 for s in stats)


def test_router_spreads_waves_across_replicas():
    clock = ManualClock()
    mk = lambda: ScriptedModel(clock, service_s=0.001, micro_batch=2)
    pool = ReplicaPool(factory=mk, devices=[None, None])
    svc = ServiceModel(works=[("s", 0)], sec_per_cycle=0.001 / 9)
    router = Router({"m": pool},
                    RouterConfig(max_wait_ms=1.0, p99_budget_ms=100.0),
                    clock=clock, service_models={"m": svc})
    for _ in range(8):
        router.submit("m", np.ones((2,), np.int32))
    dispatched = [r.n_dispatched for r in pool.replicas]
    assert sum(dispatched) == 4 and min(dispatched) >= 1


# ---------------------------------------------------------------------------
# SLO controller / service model
# ---------------------------------------------------------------------------

def test_service_model_from_compiled_calibrates_cycles():
    graph, x = _load("kws")
    cm = compile_graph(graph, in_scale=graph.meta["in_scale"],
                       use_pallas=False)
    svc = ServiceModel.from_compiled(cm, probe_batch=4)
    assert svc.sec_per_cycle > 0
    assert svc.calibration["probe_batch"] == 4
    assert svc.calibration["modeled_cycles"] == svc.wave_cycles(4)
    # cycles grow with the wave, capacity favors bigger waves
    assert svc.wave_cycles(32) > svc.wave_cycles(1)
    assert svc.saturation_qps(32) > svc.saturation_qps(1)


def test_slo_controller_admission_and_littles_law():
    svc = ServiceModel(works=[("s", 0)], sec_per_cycle=1e-3)  # 9ms / wave
    ctl = SLOController(p99_budget_ms=30.0, service=svc, window_s=10.0)
    # (backlog+1)*9ms + wait: 2 waves ahead + 2ms wait = 29ms fits ...
    assert ctl.admit(0.0, backlog_waves=2, micro_batch=4, max_wait_s=0.002)
    # ... 3 waves ahead = 38ms does not
    assert not ctl.admit(0.0, backlog_waves=3, micro_batch=4,
                         max_wait_s=0.002)
    # Little's law: 100 qps at W = 2ms wait + 9ms service -> L = 1.1
    for i in range(101):
        ctl.observe_arrival(i / 100.0)
    assert ctl.arrival_qps(1.0) == pytest.approx(100.0, rel=0.02)
    est = ctl.occupancy_estimate(1.0, micro_batch=4, max_wait_s=0.002)
    assert est == pytest.approx(100.0 * 0.011, rel=0.05)
    # measured service drift moves the EWMA correction
    before = ctl.wave_service_s(4)
    ctl.observe_service(4, measured_s=2 * before)
    assert ctl.wave_service_s(4) > before


def test_slo_operating_point_largest_wave_under_budget():
    svc = ServiceModel(works=[("s", 8192)], sec_per_cycle=1e-3)
    # service(mb) = (8 + mb) ms -> budget 20ms admits up to mb=8
    point = slo_operating_point(svc, p99_budget_ms=20.0,
                                candidates=(1, 2, 4, 8, 16, 32))
    assert point["micro_batch"] == 8 and point["fits_budget"]
    # throughput grows with the wave until the budget wall
    sats = [c["saturation_qps"] for c in point["candidates"]]
    assert sats == sorted(sats)
    # an impossible budget falls back to the smallest wave, flagged
    tiny = slo_operating_point(svc, p99_budget_ms=1.0,
                               candidates=(4, 8))
    assert tiny["micro_batch"] == 4 and not tiny["fits_budget"]


# ---------------------------------------------------------------------------
# padded-wave bit-exactness on the golden models (the acceptance contract)
# ---------------------------------------------------------------------------

def _assert_rows_equal(got, want, label):
    got, want = np.asarray(got), np.asarray(want)
    if np.issubdtype(want.dtype, np.integer):
        np.testing.assert_array_equal(got, want, err_msg=label)
    else:
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6,
                                   err_msg=label)


@pytest.mark.parametrize("name", MODELS)
def test_submit_wave_padded_partial_is_bit_exact(name):
    graph, x = _load(name)
    cm = compile_graph(graph, in_scale=graph.meta["in_scale"],
                       use_pallas=False)
    x = jnp.asarray(x)
    y_off = np.asarray(cm.offline(x))
    n = min(3, x.shape[0])                   # partial: 3 rows in a wave of 8
    y, mask = cm.submit_wave(x[:n], micro_batch=8)
    assert mask.tolist() == [True] * n + [False] * (8 - n)
    _assert_rows_equal(np.asarray(y)[mask], y_off[:n], f"{name} padded wave")
    # holes in the valid mask stay inert too
    valid = np.asarray([True, False, True])
    y2, m2 = cm.submit_wave(x[:3], valid=valid, micro_batch=4)
    _assert_rows_equal(np.asarray(y2)[m2], y_off[[0, 2]],
                       f"{name} masked wave")
    with pytest.raises(ValueError):
        cm.submit_wave(x[:3], micro_batch=2)     # 3 rows > wave of 2
    with pytest.raises(ValueError):
        cm.submit_wave(x[:3], valid=np.ones(2, bool), micro_batch=4)


@pytest.mark.parametrize("engine_cls", [SyncEngine, AsyncEngine],
                         ids=["sync", "async"])
@pytest.mark.parametrize("name", MODELS)
def test_router_serves_golden_models_bit_exact(name, engine_cls):
    """The acceptance path: requests through the dynamic batcher — full
    waves AND a deadline-flushed padded partial wave — match offline,
    through BOTH dispatch engines (async parks waves in the in-flight
    table and reaps them at drain; results must be identical)."""
    graph, x = _load(name)
    cm = compile_graph(graph, in_scale=graph.meta["in_scale"],
                       use_pallas=False)
    y_off = np.asarray(cm.offline(jnp.asarray(x)))
    clock = ManualClock()
    router = Router({name: cm},
                    RouterConfig(max_wait_ms=1.0, micro_batch=3),
                    clock=clock, engine=engine_cls())
    reqs = [router.submit(name, np.asarray(x[i]))
            for i in range(x.shape[0])]       # goldens have 4 rows: 3 + 1
    clock.advance(0.002)
    router.step()                             # deadline-flush the partial
    router.drain()                            # settle async in-flight waves
    assert all(r.result is not None for r in reqs)
    for i, r in enumerate(reqs):
        _assert_rows_equal(r.result, y_off[i], f"{name} req {i}")
    snap = router.stats()[name]["metrics"]
    assert snap.n_waves == 2 and snap.occupancy_hist.get(1) == 1
