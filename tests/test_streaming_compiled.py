"""Compiled streaming pipeline: segment grouping + three-way bit-exactness.

The contract: ``streaming_compiled`` (one jit program per segment wave, no
host loop on the hot path) must produce exactly the integers of
``streaming_host`` (the queue-loop reference) and ``offline`` (the single
fused program) — on every golden model, under backpressure (depth-1 FIFOs),
out-of-order admission, and non-dividing micro-batches.
"""

import os

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.qir import Graph, Node, QuantSpec
from repro.deploy import (
    RefChainStage,
    Segment,
    compile_graph,
    group_segments,
)

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")
MODELS = ("kws", "ad", "ic", "cnv")


def _load(name):
    graph = Graph.load(os.path.join(GOLDEN_DIR, f"{name}.qir.json"))
    data = np.load(os.path.join(GOLDEN_DIR, f"{name}.golden.npz"))
    return graph, data["x"]


def _assert_same(got, want, label):
    got, want = np.asarray(got), np.asarray(want)
    if np.issubdtype(want.dtype, np.integer):
        np.testing.assert_array_equal(got, want, err_msg=label)
    else:
        # float head logits: exact integers through one affine; the three
        # paths batch rows identically so bitwise equality is expected, but
        # only the integer contract is guaranteed
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6,
                                   err_msg=label)


@pytest.mark.parametrize("name", MODELS)
def test_streaming_compiled_equals_host_and_offline(name):
    """Acceptance: streaming_compiled == streaming_host == offline on all
    four golden models."""
    graph, x = _load(name)
    cm = compile_graph(graph, in_scale=graph.meta["in_scale"],
                       use_pallas=False)
    x = jnp.asarray(x)
    y_off = cm.offline(x)
    y_host, st_h = cm.streaming_host(x, micro_batch=2)
    y_cmp, st_c = cm.streaming_compiled(x, micro_batch=2)
    _assert_same(y_host, y_off, f"{name} host-vs-offline")
    _assert_same(y_cmp, y_off, f"{name} compiled-vs-offline")
    _assert_same(y_cmp, y_host, f"{name} compiled-vs-host")
    assert st_c.mode == "compiled" and st_h.mode == "host"
    assert st_c.micro_batch == st_h.micro_batch == 2
    assert st_c.segments == st_h.segments
    # fully fused schedules are one compiled segment: zero host boundaries
    assert st_c.segments == [(0, len(cm.schedule.stages))]
    # modeled occupancy obeys the optimizer's depth = occ + 1 construction
    assert all(o < d for o, d in zip(st_c.max_occupancy, st_c.fifo_depths))


def test_streaming_legacy_alias_is_host_path():
    graph, x = _load("kws")
    cm = compile_graph(graph, in_scale=graph.meta["in_scale"],
                       use_pallas=False)
    y, st = cm.streaming(jnp.asarray(x), micro_batch=2)
    assert st.mode == "host"
    _assert_same(y, cm.offline(jnp.asarray(x)), "alias")


def test_group_segments_splits_at_host_boundaries():
    class _Fused:            # stand-ins: anything not RefChainStage compiles
        pass

    ref = RefChainStage.__new__(RefChainStage)
    f = _Fused()
    assert group_segments([f, f, f]) == [Segment(0, 3, True)]
    assert group_segments([f, ref, f]) == [
        Segment(0, 1, True), Segment(1, 2, False), Segment(2, 3, True)]
    assert group_segments([ref]) == [Segment(0, 1, False)]
    assert group_segments([ref, ref]) == [
        Segment(0, 1, False), Segment(1, 2, False)]
    assert group_segments([f, f, ref]) == [
        Segment(0, 2, True), Segment(2, 3, False)]


def test_streaming_compiled_crosses_host_boundary():
    """A schedule with a fallback float chain still runs compiled streaming:
    the RefChain segment returns to the host, everything else is waved."""
    rng = np.random.default_rng(4)
    w = rng.standard_normal((6, 4)).astype(np.float32) * 0.3
    g = Graph(inputs=["x"], outputs=["y"],
              initializers={"w": w, "b": np.zeros((4,), np.float32),
                            "m": np.full((4,), 2.0, np.float32)})
    g.nodes = [
        Node("Dense", "d0", ["x", "w", "b"], ["h0"]),
        Node("Relu", "r0", ["h0"], ["h1"]),
        Node("Quant", "q0", ["h1"], ["h2"], quant=QuantSpec(bits=4)),
        Node("Mul", "m0", ["h2", "m"], ["y"]),    # unfusable suffix
    ]
    cm = compile_graph(g, in_scale=0.1, use_pallas=False)
    kinds = [seg.compiled for seg in cm.segments]
    assert kinds == [True, False]   # fused stage, then the host fallback
    x = jnp.asarray(rng.integers(-7, 8, (10, 6)), jnp.int32)
    y_off = cm.offline(x)
    y_cmp, st = cm.streaming_compiled(x, micro_batch=4)   # pads 10 -> 12
    np.testing.assert_allclose(np.asarray(y_cmp), np.asarray(y_off),
                               rtol=1e-6, atol=1e-6)
    assert st.segments == [(0, 1), (1, 2)]


def test_streaming_host_depth_one_fifos_make_progress():
    """Backpressure safety: capacity-1 queues everywhere must still drain
    the whole batch (downstream-first firing frees space upstream) and the
    observed occupancy must respect the forced depths."""
    graph, x = _load("ad")
    cm = compile_graph(graph, in_scale=graph.meta["in_scale"],
                       use_pallas=False)
    x = jnp.asarray(x)
    ones = [1] * (len(cm.schedule.stages) + 1)
    y, st = cm.streaming_host(x, micro_batch=2, fifo_depths=ones)
    _assert_same(y, cm.offline(x), "depth-1")
    assert st.fifo_depths == ones
    assert all(o <= 1 for o in st.max_occupancy[:-1])


def test_streaming_host_out_of_order_feed_restores_batch_order():
    """The idx bookkeeping must reassemble the batch no matter the
    admission order of micro-batches."""
    graph, x = _load("kws")
    cm = compile_graph(graph, in_scale=graph.meta["in_scale"],
                       use_pallas=False)
    x = jnp.asarray(x)
    n_micro = x.shape[0] // 2
    y_rev, _ = cm.streaming_host(x, micro_batch=2,
                                 feed_order=list(reversed(range(n_micro))))
    _assert_same(y_rev, cm.offline(x), "reversed feed")
    # typed, not AssertionError: the permutation check is load-bearing
    # input validation and must survive python -O
    with pytest.raises(ValueError, match="permutation"):
        cm.streaming_host(x, micro_batch=2, feed_order=[0] * n_micro)


def test_streaming_compiled_pads_non_dividing_micro_batch():
    graph, x = _load("ic")
    cm = compile_graph(graph, in_scale=graph.meta["in_scale"],
                       use_pallas=False)
    x = jnp.asarray(x)[:3]          # 3 % 2 != 0 -> one padded micro-batch
    y, st = cm.streaming_compiled(x, micro_batch=2)
    _assert_same(y, cm.offline(x), "padded tail")
    assert y.shape[0] == 3 and st.n_micro == 2
